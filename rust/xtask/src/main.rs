//! Repo-local concurrency lints for the shadow-sync fabric.
//!
//! `cargo run -p xtask -- lint` walks `rust/src/**` and enforces the
//! invariants that `rustc` cannot see but the fabric's correctness
//! arguments (docs/CONCURRENCY.md, rust/tests/loom_models.rs) rely on:
//!
//! 1. **relaxed-ordering** — no `Ordering::Relaxed` on any atomic whose
//!    identifier is in the version/epoch/generation counter registry
//!    ([`RELAXED_REGISTRY`]). Those counters publish cross-thread happens-
//!    before edges; a Relaxed store/RMW on one is exactly the bug class the
//!    loom mutation models (`relaxed_dirty_bump_is_caught`) demonstrate.
//!    Deliberate exceptions live in [`RELAXED_ALLOWLIST`] with their
//!    justification.
//! 2. **std-sync-import** — no direct `std::sync` / `std::thread` paths in
//!    `src/sync/**` or `src/tensor/**` (outside `#[cfg(test)]`): all
//!    primitives must go through the `sync::prim` facade so the loom cfg
//!    swaps them onto the model checker.
//! 3. **hogwild-mark-dirty** — every public `HogwildBuffer` method that
//!    stores into the shared buffer must call `mark_dirty_range` (the
//!    dirty-epoch bump helper); a write path that skips the bump silently
//!    breaks the delta gate's scan-skip cache and the repartitioner's
//!    measured write rates.
//! 4. **unsafe-needs-safety** — every `unsafe` token carries a `SAFETY:`
//!    comment on the same line or within the three lines above it.
//! 5. **concurrency-doc** — every registry identifier appears in
//!    docs/CONCURRENCY.md, so the ordering table and the lint registry
//!    cannot drift apart.
//!
//! The binary is dependency-free on purpose: a hand-rolled,
//! length-preserving lexer ([`strip`]) blanks comments and string/char
//! literals (so text inside them never trips a lint) while keeping byte
//! offsets and line numbers identical to the raw source.

#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Atomic counter identifiers that carry happens-before edges between
/// threads. Any `Relaxed` access to one of these is a lint violation
/// unless allowlisted. Kept in sync with docs/CONCURRENCY.md (lint 5).
const RELAXED_REGISTRY: &[&str] = &[
    "gen",            // repartition plan generation (RepartitionController)
    "adopted_gen",    // per-trainer adopted plan generation
    "generation",     // allreduce round generation (StripedState)
    "chunk_versions", // central per-chunk push versions (SyncPsGroup)
    "epochs",         // per-chunk dirty epochs (DirtyEpochs)
    "chunks_done",    // allreduce folded-chunk counter
    "cursor",         // allreduce epoch-tagged claim cursor / sketch ring index
    "filled",         // quantile-sketch filled watermark
    "heartbeat",      // per-trainer liveness stamps (HealthController)
    "departed",       // lock-claimed roster-exit flags (HealthController)
    "head",           // SPSC ring consume cursor (SpscRing)
    "tail",           // SPSC ring publish cursor (SpscRing)
    "delegated",      // shared-nothing outstanding-grant counter (SnState)
    "returned",       // shared-nothing folded-stripe return counter (SnState)
    "published",      // shared-nothing parked-round epoch stamp (SnState)
    "placement_version", // embedding bucket-placement epoch (EmbeddingSystem)
];

/// A deliberately-Relaxed use of a registry identifier, with the argument
/// for why it is benign. Surfaced verbatim in the lint's `--explain`-style
/// output so the exception is as visible as the rule.
struct AllowEntry {
    /// matched as a suffix of the repo-relative path (forward slashes)
    file_suffix: &'static str,
    ident: &'static str,
    reason: &'static str,
}

const RELAXED_ALLOWLIST: &[AllowEntry] = &[
    AllowEntry {
        file_suffix: "src/sync/ps.rs",
        ident: "cursor",
        reason: "quantile-sketch ring index: slot choice under contention is \
                 deliberately racy; two recorders sharing a slot merely drop a \
                 sample, and the sketch is an estimator",
    },
    AllowEntry {
        file_suffix: "src/sync/ps.rs",
        ident: "filled",
        reason: "overshoot-guard load: `filled` is published by a Release \
                 fetch_add, so a Relaxed read can only under-count, which \
                 keeps the guard conservative",
    },
];

/// Substrings (on lexed text) that mean a `HogwildBuffer` method writes
/// into the shared element array.
const HOGWILD_WRITE_MARKERS: &[&str] = &[".store(", "store_unmarked(", "compare_exchange"];
const HOGWILD_BUMP_HELPER: &str = "mark_dirty_range(";

#[derive(Debug, PartialEq)]
struct Violation {
    file: String,
    line: usize,
    lint: &'static str,
    msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.lint, self.msg)
    }
}

// ---------------------------------------------------------------------------
// Lexer: length-preserving comment/string stripping
// ---------------------------------------------------------------------------

/// Blank comments, string literals, and char literals out of `src`,
/// replacing every byte except `\n` with a space, so the output has the
/// same length and line structure as the input. Handles nested block
/// comments, escapes, raw strings (`r"…"`, `r#"…"#`), byte strings, and
/// the char-vs-lifetime ambiguity (`'a'` is a char; `&'a` is not).
fn strip(src: &str) -> String {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let blank = |out: &mut [u8], lo: usize, hi: usize| {
        for x in &mut out[lo..hi] {
            if *x != b'\n' {
                *x = b' ';
            }
        }
    };
    let is_ident = |c: u8| c.is_ascii_alphanumeric() || c == b'_';
    let mut i = 0;
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if j + 1 < n && b[j] == b'/' && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if j + 1 < n && b[j] == b'*' && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
        } else if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j.min(n));
            i = j;
        } else if (c == b'r' || c == b'b') && (i == 0 || !is_ident(b[i - 1])) {
            if let Some((hashes, body_start)) = raw_string_open(b, i) {
                // raw (byte) string: closed by `"` followed by `hashes` #s
                let close = format!("\"{}", "#".repeat(hashes));
                let j = match src[body_start..].find(&close) {
                    Some(r) => body_start + r + close.len(),
                    None => n,
                };
                blank(&mut out, i, j);
                i = j;
            } else if c == b'b' && i + 1 < n && b[i + 1] == b'"' {
                // byte string: reuse the plain-string scan from the quote
                let mut j = i + 2;
                while j < n {
                    if b[j] == b'\\' {
                        j += 2;
                    } else if b[j] == b'"' {
                        j += 1;
                        break;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut out, i, j.min(n));
                i = j;
            } else {
                i += 1;
            }
        } else if c == b'\'' {
            // char literal iff `'\…'` or `'x'`; otherwise a lifetime
            let is_char = (i + 1 < n && b[i + 1] == b'\\')
                || (i + 2 < n && b[i + 2] == b'\'' && b[i + 1] != b'\'');
            if is_char {
                let mut j = i + 1;
                while j < n {
                    if b[j] == b'\\' {
                        j += 2;
                    } else if b[j] == b'\'' {
                        j += 1;
                        break;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut out, i, j.min(n));
                i = j;
            } else {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    String::from_utf8(out).expect("blanking ASCII bytes keeps the source valid UTF-8")
}

/// If `b[i..]` opens a raw (byte) string (`r"`, `r#"`, `br#"` …), return
/// `(hash_count, index_past_opening_quote)`.
fn raw_string_open(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i + 1;
    if b[i] == b'b' {
        if j < b.len() && b[j] == b'r' {
            j += 1;
        } else {
            return None;
        }
    }
    let mut hashes = 0;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < b.len() && b[j] == b'"' {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Byte offset of the start of each line (for pos → line mapping).
fn line_starts(src: &str) -> Vec<usize> {
    let mut v = vec![0];
    for (i, c) in src.bytes().enumerate() {
        if c == b'\n' {
            v.push(i + 1);
        }
    }
    v
}

fn line_of(starts: &[usize], pos: usize) -> usize {
    starts.partition_point(|&s| s <= pos)
}

/// Byte spans of `#[cfg(test)]`-gated items (attribute through the
/// matching close brace), computed on lexed text so commented-out
/// attributes don't count.
fn test_spans(stripped: &str) -> Vec<(usize, usize)> {
    let b = stripped.as_bytes();
    let mut spans = Vec::new();
    let mut from = 0;
    while let Some(rel) = stripped[from..].find("#[cfg(test)]") {
        let start = from + rel;
        // first `{` after the attribute opens the gated item's body
        let Some(open_rel) = stripped[start..].find('{') else { break };
        let open = start + open_rel;
        let mut depth = 0usize;
        let mut end = stripped.len();
        for (k, &c) in b[open..].iter().enumerate() {
            if c == b'{' {
                depth += 1;
            } else if c == b'}' {
                depth -= 1;
                if depth == 0 {
                    end = open + k + 1;
                    break;
                }
            }
        }
        spans.push((start, end));
        from = end;
    }
    spans
}

fn in_spans(spans: &[(usize, usize)], pos: usize) -> bool {
    spans.iter().any(|&(lo, hi)| pos >= lo && pos < hi)
}

/// Identifiers on a line: maximal `[A-Za-z0-9_]+` runs that don't start
/// with a digit.
fn idents(line: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let b = line.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if b[i].is_ascii_alphabetic() || b[i] == b'_' {
            let start = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            out.push(&line[start..i]);
        } else {
            i += 1;
        }
    }
    out
}

/// `needle` present in `hay` as a path/token (previous byte is not part of
/// an identifier)? Enough to tell `std::sync` from `mystd::sync`.
fn path_token(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(rel) = hay[from..].find(needle) {
        let at = from + rel;
        let ok = at == 0 || {
            let p = hay.as_bytes()[at - 1];
            !(p.is_ascii_alphanumeric() || p == b'_')
        };
        if ok {
            return true;
        }
        from = at + 1;
    }
    false
}

// ---------------------------------------------------------------------------
// One parsed source file
// ---------------------------------------------------------------------------

struct FileData {
    /// path relative to `rust/`, forward slashes (e.g. `src/sync/ps.rs`)
    rel: String,
    raw: String,
    stripped: String,
    spans: Vec<(usize, usize)>,
    starts: Vec<usize>,
}

impl FileData {
    fn new(rel: &str, raw: &str) -> Self {
        let stripped = strip(raw);
        let spans = test_spans(&stripped);
        let starts = line_starts(raw);
        Self { rel: rel.to_string(), raw: raw.to_string(), stripped, spans, starts }
    }

    /// Lexed lines with (1-based line number, byte offset of line start).
    fn code_lines(&self) -> impl Iterator<Item = (usize, usize, &str)> {
        self.stripped
            .lines()
            .scan(0usize, |off, l| {
                let start = *off;
                *off += l.len() + 1;
                Some((start, l))
            })
            .enumerate()
            .map(|(i, (start, l))| (i + 1, start, l))
    }
}

// ---------------------------------------------------------------------------
// Lints
// ---------------------------------------------------------------------------

fn lint_relaxed(f: &FileData) -> Vec<Violation> {
    if !f.rel.starts_with("src/") || f.rel.starts_with("src/mc/") {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (line_no, start, line) in f.code_lines() {
        if in_spans(&f.spans, start) {
            continue;
        }
        let ids = idents(line);
        if !ids.contains(&"Relaxed") {
            continue;
        }
        for reg in RELAXED_REGISTRY {
            if !ids.contains(reg) {
                continue;
            }
            let allowed = RELAXED_ALLOWLIST
                .iter()
                .any(|a| a.ident == *reg && f.rel.ends_with(a.file_suffix));
            if !allowed {
                out.push(Violation {
                    file: f.rel.clone(),
                    line: line_no,
                    lint: "relaxed-ordering",
                    msg: format!(
                        "`{reg}` is a registered happens-before counter; use \
                         Acquire/Release/SeqCst or add an allowlist entry with a \
                         justification (see docs/CONCURRENCY.md)"
                    ),
                });
            }
        }
    }
    out
}

fn lint_std_sync(f: &FileData) -> Vec<Violation> {
    let scoped = (f.rel.starts_with("src/sync/") || f.rel.starts_with("src/tensor/"))
        && f.rel != "src/sync/prim.rs";
    if !scoped {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (line_no, start, line) in f.code_lines() {
        if in_spans(&f.spans, start) {
            continue;
        }
        for needle in ["std::sync", "std::thread"] {
            if path_token(line, needle) {
                out.push(Violation {
                    file: f.rel.clone(),
                    line: line_no,
                    lint: "std-sync-import",
                    msg: format!(
                        "direct `{needle}` in the fabric; go through `sync::prim` \
                         so the loom cfg can swap in the model checker"
                    ),
                });
            }
        }
    }
    out
}

fn lint_hogwild(f: &FileData) -> Vec<Violation> {
    if f.rel != "src/tensor/mod.rs" {
        return Vec::new();
    }
    let mut out = Vec::new();
    let Some(impl_at) = f.stripped.find("impl HogwildBuffer") else {
        return out;
    };
    let Some(open_rel) = f.stripped[impl_at..].find('{') else {
        return out;
    };
    let body = match brace_span(&f.stripped, impl_at + open_rel) {
        Some((lo, hi)) => &f.stripped[lo..hi],
        None => return out,
    };
    let body_off = impl_at + open_rel;
    let mut from = 0;
    while let Some(rel) = body[from..].find("pub fn ") {
        let at = from + rel;
        let name: String = body[at + 7..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        let Some(fn_open_rel) = body[at..].find('{') else { break };
        let Some((lo, hi)) = brace_span(body, at + fn_open_rel) else { break };
        let fn_body = &body[lo..hi];
        let writes = HOGWILD_WRITE_MARKERS.iter().any(|m| fn_body.contains(m));
        if writes && !fn_body.contains(HOGWILD_BUMP_HELPER) {
            out.push(Violation {
                file: f.rel.clone(),
                line: line_of(&f.starts, body_off + at),
                lint: "hogwild-mark-dirty",
                msg: format!(
                    "pub fn `{name}` stores into the shared buffer without calling \
                     `mark_dirty_range`; the delta gate's scan cache and the \
                     repartitioner's write rates would miss these writes"
                ),
            });
        }
        from = hi;
    }
    out
}

/// Span of the brace-delimited block opening at `open` (byte index of a
/// `{` in lexed text): `(open, index_past_close)`.
fn brace_span(stripped: &str, open: usize) -> Option<(usize, usize)> {
    let b = stripped.as_bytes();
    debug_assert_eq!(b[open], b'{');
    let mut depth = 0usize;
    for (k, &c) in b[open..].iter().enumerate() {
        if c == b'{' {
            depth += 1;
        } else if c == b'}' {
            depth -= 1;
            if depth == 0 {
                return Some((open, open + k + 1));
            }
        }
    }
    None
}

fn lint_unsafe(f: &FileData) -> Vec<Violation> {
    if !f.rel.starts_with("src/") {
        return Vec::new();
    }
    let raw_lines: Vec<&str> = f.raw.lines().collect();
    let mut out = Vec::new();
    for (line_no, _start, line) in f.code_lines() {
        if !idents(line).contains(&"unsafe") {
            continue;
        }
        // same line or up to three lines above, on RAW text (the comment
        // the lexer blanks is exactly what we are looking for)
        let lo = line_no.saturating_sub(4); // 0-based index of line_no-3
        let covered = raw_lines[lo..line_no].iter().any(|l| l.contains("SAFETY:"));
        if !covered {
            out.push(Violation {
                file: f.rel.clone(),
                line: line_no,
                lint: "unsafe-needs-safety",
                msg: "`unsafe` without a `// SAFETY:` comment on the same line or \
                      within the three lines above"
                    .to_string(),
            });
        }
    }
    out
}

/// Registry identifiers missing from the CONCURRENCY.md text.
fn missing_doc_idents(doc: &str) -> Vec<&'static str> {
    let ids: std::collections::HashSet<&str> = idents(doc).into_iter().collect();
    RELAXED_REGISTRY.iter().copied().filter(|r| !ids.contains(r)).collect()
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut entries: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// Repo root, derived from this crate's fixed location at `<repo>/rust/xtask`.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(Path::parent)
        .expect("xtask sits at <repo>/rust/xtask")
        .to_path_buf()
}

/// Run every lint over `<repo>/rust/src/**` plus the doc cross-check.
/// Returns `(files_scanned, violations)`.
fn collect_violations(repo: &Path) -> Result<(usize, Vec<Violation>), String> {
    let rust_dir = repo.join("rust");
    let mut files = Vec::new();
    walk_rs(&rust_dir.join("src"), &mut files);
    let mut violations = Vec::new();
    let mut scanned = 0usize;
    for path in &files {
        let raw = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(&rust_dir)
            .expect("walked under rust/")
            .to_string_lossy()
            .replace('\\', "/");
        let fd = FileData::new(&rel, &raw);
        scanned += 1;
        violations.extend(lint_relaxed(&fd));
        violations.extend(lint_std_sync(&fd));
        violations.extend(lint_hogwild(&fd));
        violations.extend(lint_unsafe(&fd));
    }
    match std::fs::read_to_string(repo.join("docs/CONCURRENCY.md")) {
        Ok(doc) => {
            for ident in missing_doc_idents(&doc) {
                violations.push(Violation {
                    file: "docs/CONCURRENCY.md".to_string(),
                    line: 1,
                    lint: "concurrency-doc",
                    msg: format!(
                        "registry counter `{ident}` has no entry in the atomics \
                         table; document its ordering and invariant"
                    ),
                });
            }
        }
        Err(_) => violations.push(Violation {
            file: "docs/CONCURRENCY.md".to_string(),
            line: 1,
            lint: "concurrency-doc",
            msg: "missing: the atomics/ordering table must exist and cover the \
                  lint registry"
                .to_string(),
        }),
    }
    Ok((scanned, violations))
}

fn run_lint() -> ExitCode {
    let (scanned, violations) = match collect_violations(&repo_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("xtask lint: {e}");
            return ExitCode::FAILURE;
        }
    };
    if violations.is_empty() {
        println!("xtask lint: OK ({scanned} files, 5 lints, 0 violations)");
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!("xtask lint: {} violation(s) in {scanned} files", violations.len());
        ExitCode::FAILURE
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            ExitCode::from(2)
        }
    }
}

// ---------------------------------------------------------------------------
// Tests: the lexer, and every lint against seeded violations
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn fd(rel: &str, src: &str) -> FileData {
        FileData::new(rel, src)
    }

    #[test]
    fn strip_blanks_comments_and_strings_preserving_length() {
        let src =
            "let a = 1; // Relaxed cursor\nlet s = \"Relaxed cursor\";\n/* gen */ let b = 2;\n";
        let out = strip(src);
        assert_eq!(out.len(), src.len());
        assert_eq!(out.matches('\n').count(), src.matches('\n').count());
        assert!(!out.contains("Relaxed"));
        assert!(!out.contains("gen"));
        assert!(out.contains("let a = 1;"));
        assert!(out.contains("let b = 2;"));
    }

    #[test]
    fn strip_handles_raw_strings_chars_and_lifetimes() {
        let src = "let r = r#\"cursor \"quoted\" gen\"#; let c = '\\''; let l: &'static str = x;";
        let out = strip(src);
        assert_eq!(out.len(), src.len());
        assert!(!out.contains("cursor"));
        assert!(!out.contains("quoted"));
        // the lifetime must survive (it is code, not a literal)
        assert!(out.contains("&'static str"));
    }

    #[test]
    fn strip_handles_nested_block_comments() {
        let src = "a /* x /* y */ cursor */ b";
        let out = strip(src);
        assert!(!out.contains("cursor"));
        assert!(out.starts_with('a') && out.ends_with('b'));
    }

    #[test]
    fn test_spans_cover_gated_modules() {
        let src =
            "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn gated() {}\n}\nfn also_live() {}\n";
        let stripped = strip(src);
        let spans = test_spans(&stripped);
        assert_eq!(spans.len(), 1);
        let gated_at = src.find("gated").unwrap();
        assert!(in_spans(&spans, gated_at));
        assert!(!in_spans(&spans, src.find("live").unwrap()));
        assert!(!in_spans(&spans, src.find("also_live").unwrap()));
    }

    #[test]
    fn relaxed_lint_catches_registry_counters() {
        let f = fd(
            "src/sync/repartition.rs",
            "fn bump(&self) {\n    self.generation.fetch_add(1, Ordering::Relaxed);\n}\n",
        );
        let v = lint_relaxed(&f);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 2);
        assert_eq!(v[0].lint, "relaxed-ordering");
        assert!(v[0].msg.contains("generation"));
    }

    #[test]
    fn relaxed_lint_guards_the_health_roster_atomics() {
        // heartbeat stamps and departed flags joined the registry with the
        // fault fabric: a Relaxed touch on either would break the watchdog's
        // staleness reads or the lock-claimed depart handshake
        let beat = fd(
            "src/sync/health.rs",
            "fn beat(&self, t: usize) {\n    self.heartbeat[t].store(now, Relaxed);\n}\n",
        );
        let v = lint_relaxed(&beat);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("heartbeat"));
        let flag = fd(
            "src/sync/driver.rs",
            "fn gone(&self, t: usize) -> bool {\n    self.departed[t].load(Relaxed)\n}\n",
        );
        let v = lint_relaxed(&flag);
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("departed"));
    }

    #[test]
    fn relaxed_lint_ignores_unregistered_counters_comments_and_tests() {
        let src = "fn ok(&self) {\n    // the cursor comment mentions Relaxed harmlessly\n    \
                   self.batches.fetch_add(1, Relaxed);\n}\n#[cfg(test)]\nmod tests {\n    fn t() \
                   { x.generation.load(Relaxed); }\n}\n";
        let f = fd("src/metrics/mod.rs", src);
        assert!(lint_relaxed(&f).is_empty());
    }

    #[test]
    fn relaxed_lint_honors_the_allowlist() {
        let src = "fn rec(&self) {\n    let i = self.cursor.fetch_add(1, Relaxed);\n    let n = \
                   self.filled.load(Relaxed);\n}\n";
        assert!(lint_relaxed(&fd("src/sync/ps.rs", src)).is_empty());
        // the same code anywhere else is a violation
        assert_eq!(lint_relaxed(&fd("src/sync/allreduce.rs", src)).len(), 2);
    }

    #[test]
    fn relaxed_lint_skips_the_model_checker_itself() {
        let f = fd("src/mc/atomic.rs", "self.cursor.load(Relaxed);\n");
        assert!(lint_relaxed(&f).is_empty());
    }

    #[test]
    fn std_sync_lint_flags_direct_imports_outside_tests() {
        let src = "use std::sync::Mutex;\nfn f() { std::thread::spawn(|| {}); }\n#[cfg(test)]\n\
                   mod tests {\n    use std::sync::Arc;\n}\n";
        let v = lint_std_sync(&fd("src/sync/driver.rs", src));
        assert_eq!(v.len(), 2);
        assert_eq!((v[0].line, v[1].line), (1, 2));
        // prim.rs is the facade: exempt
        assert!(lint_std_sync(&fd("src/sync/prim.rs", src)).is_empty());
        // out-of-scope modules may use std directly
        assert!(lint_std_sync(&fd("src/metrics/mod.rs", src)).is_empty());
    }

    #[test]
    fn hogwild_lint_requires_the_dirty_bump() {
        let src = "impl HogwildBuffer {\n    pub fn set(&self, i: usize, v: f32) {\n        \
                   self.data[i].store(v.to_bits(), Relaxed);\n        self.mark_dirty_range(i, i \
                   + 1);\n    }\n    pub fn sneaky(&self, i: usize, v: f32) {\n        \
                   self.data[i].store(v.to_bits(), Relaxed);\n    }\n    pub fn get(&self, i: \
                   usize) -> f32 {\n        f32::from_bits(self.data[i].load(Relaxed))\n    }\n}\n";
        let v = lint_hogwild(&fd("src/tensor/mod.rs", src));
        assert_eq!(v.len(), 1);
        assert!(v[0].msg.contains("sneaky"));
        // only tensor/mod.rs hosts the impl
        assert!(lint_hogwild(&fd("src/sync/ps.rs", src)).is_empty());
    }

    #[test]
    fn unsafe_lint_wants_an_adjacent_safety_comment() {
        let bad = "fn f() {\n    let p = x.as_ptr();\n    unsafe { *p }\n}\n";
        let v = lint_unsafe(&fd("src/runtime/pjrt.rs", bad));
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 3);
        let good = "fn f() {\n    let p = x.as_ptr();\n    // SAFETY: x outlives this call\n    \
                    unsafe { *p }\n}\n";
        assert!(lint_unsafe(&fd("src/runtime/pjrt.rs", good)).is_empty());
        let same_line = "unsafe impl Send for T {} // SAFETY: T is a plain counter\n";
        assert!(lint_unsafe(&fd("src/mc/sync.rs", same_line)).is_empty());
        // UnsafeCell is an identifier, not the keyword
        assert!(lint_unsafe(&fd("src/mc/atomic.rs", "use std::cell::UnsafeCell;\n")).is_empty());
    }

    #[test]
    fn allowlist_entries_are_registered_and_justified() {
        for a in RELAXED_ALLOWLIST {
            assert!(
                RELAXED_REGISTRY.contains(&a.ident),
                "allowlisted `{}` is not a registry counter",
                a.ident
            );
            assert!(
                a.reason.len() > 40,
                "allowlist entry `{}` needs a real written justification",
                a.ident
            );
        }
    }

    #[test]
    fn doc_lint_cross_checks_the_registry() {
        let full = RELAXED_REGISTRY.join(" | ");
        assert!(missing_doc_idents(&full).is_empty());
        let missing = missing_doc_idents("only `cursor` and `gen` documented");
        assert!(!missing.is_empty());
        assert!(missing.contains(&"filled"));
        assert!(!missing.contains(&"cursor"));
    }

    /// The real tree must be lint-clean: this is the acceptance check that
    /// `cargo run -p xtask -- lint` passes, wired into `cargo test`.
    #[test]
    fn real_tree_is_clean() {
        let (scanned, violations) = collect_violations(&repo_root()).expect("readable tree");
        assert!(scanned > 30, "expected to scan the whole library, got {scanned} files");
        assert!(
            violations.is_empty(),
            "tree has lint violations:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
