//! Integration: load real AOT artifacts and execute them via PJRT.
//! Requires `make artifacts` (skipped with a clear message otherwise).

use std::path::PathBuf;
use std::sync::Arc;

use shadowsync::config::ModelMeta;
use shadowsync::runtime::Runtime;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("tiny.meta.json").exists()
}

#[test]
fn tiny_train_step_runs_and_descends() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::load(&artifacts_dir(), "tiny").unwrap();
    let model = rt.load_model(&meta, &artifacts_dir()).unwrap();
    let mut io = model.new_io();

    let b = meta.batch;
    let dense: Vec<f32> = (0..b * meta.num_dense).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
    let labels: Vec<f32> = (0..b).map(|i| (i % 3 == 0) as u8 as f32).collect();
    io.pooled_host.iter_mut().enumerate().for_each(|(i, v)| *v = ((i % 11) as f32 - 5.0) / 50.0);

    // plain SGD on the flat params must reduce the loss
    let first = model.train_step(&mut io, &dense, &labels).unwrap();
    assert!(first.is_finite() && first > 0.0);
    let mut loss = first;
    for _ in 0..40 {
        loss = model.train_step(&mut io, &dense, &labels).unwrap();
        for (w, g) in io.w_host.iter_mut().zip(io.grad_w.clone()) {
            *w -= 0.05 * g;
        }
    }
    assert!(
        loss < 0.8 * first,
        "loss did not descend: first={first} last={loss}"
    );
    // gradients flow to the embeddings too
    assert!(io.grad_emb.iter().any(|&g| g != 0.0));
}

#[test]
fn eval_step_aggregates_match_batch() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::load(&artifacts_dir(), "tiny").unwrap();
    let model = rt.load_model(&meta, &artifacts_dir()).unwrap();
    let mut io = model.new_io();
    let b = meta.batch;
    let dense = vec![0.1f32; b * meta.num_dense];
    let labels: Vec<f32> = (0..b).map(|i| (i % 4 == 0) as u8 as f32).collect();
    let out = model.eval_step(&mut io, &dense, &labels).unwrap();
    let want_labels: f32 = labels.iter().sum();
    assert_eq!(out.label_sum, want_labels);
    assert!(out.pred_sum > 0.0 && out.pred_sum < b as f32);
    assert!(out.loss_sum.is_finite() && out.loss_sum > 0.0);
}

#[test]
fn concurrent_execution_is_correct() {
    // The Executable Send+Sync claim: many threads execute the same
    // compiled module; each must get its own correct results.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::load(&artifacts_dir(), "tiny").unwrap();
    let model = rt.load_model(&meta, &artifacts_dir()).unwrap();
    let model = Arc::new(model);

    // reference: loss per distinct label pattern, computed serially
    let b = meta.batch;
    let dense = vec![0.2f32; b * meta.num_dense];
    let mk_labels =
        |k: usize| -> Vec<f32> { (0..b).map(|i| (i % (k + 2) == 0) as u8 as f32).collect() };
    let mut want = Vec::new();
    {
        let mut io = model.new_io();
        for k in 0..4 {
            want.push(model.train_step(&mut io, &dense, &mk_labels(k)).unwrap());
        }
    }
    let mut handles = Vec::new();
    for k in 0..4usize {
        let model = model.clone();
        let dense = dense.clone();
        let labels = mk_labels(k);
        handles.push(std::thread::spawn(move || {
            let mut io = model.new_io();
            let mut losses = Vec::new();
            for _ in 0..10 {
                losses.push(model.train_step(&mut io, &dense, &labels).unwrap());
            }
            losses
        }));
    }
    for (k, h) in handles.into_iter().enumerate() {
        for loss in h.join().unwrap() {
            assert!(
                (loss - want[k]).abs() < 1e-4 * want[k].abs(),
                "thread {k}: got {loss}, want {}",
                want[k]
            );
        }
    }
}

#[test]
fn w0_matches_python_init() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let meta = ModelMeta::load(&artifacts_dir(), "tiny").unwrap();
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_model(&meta, &artifacts_dir()).unwrap();
    // rust reimplementation of init_params must agree bit-for-bit
    let ours = shadowsync::util::rng::dense_init(&meta.layer_dims(), meta.seed);
    assert_eq!(ours.len(), model.w0.len());
    let diffs = ours.iter().zip(&model.w0).filter(|(a, b)| a != b).count();
    assert_eq!(diffs, 0, "{diffs} mismatching params between rust and python init");
}
