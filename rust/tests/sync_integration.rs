//! Cross-module sync semantics under real multi-threading (no artifacts
//! needed): shadow threads + Hogwild workers + sync PSs / AllReduce groups
//! interacting on shared replicas.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::Arc;
use std::time::Duration;

use shadowsync::config::{RunConfig, SyncAlgo};
use shadowsync::metrics::Metrics;
use shadowsync::net::{Network, Role};
use shadowsync::sync::driver::{
    spawn_shadow, spawn_shadow_pool, spawn_shadow_pool_adaptive, ShadowTask,
};
use shadowsync::sync::partition::lpt_contiguous_ranges;
use shadowsync::sync::{
    build_group, build_strategy, AllReduceGroup, BmufSync, DeltaGate, EasgdSync, MaSync,
    ParamRange, PartitionPlan, ReduceEngine, RepartitionController, SyncCtx, SyncPsGroup,
    SyncStrategy, WireCodec,
};
use shadowsync::tensor::HogwildBuffer;
use shadowsync::util::rng::Rng;

/// Simulated "workers": threads that keep pulling a replica toward a
/// trainer-specific target while shadow threads sync replicas to consensus.
fn spawn_pullers(
    replica: Arc<HogwildBuffer>,
    target: f32,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while !stop.load(Relaxed) {
            let t = vec![target; replica.len()];
            replica.lerp_toward_slice(&t, 0.05);
            std::thread::sleep(Duration::from_micros(200));
        }
    })
}

#[test]
fn shadow_easgd_reaches_consensus_across_trainers() {
    let p = 64;
    let n = 3;
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
    let sync_ps = Arc::new(SyncPsGroup::build(&vec![0.0; p], 2, &mut net));
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());

    let replicas: Vec<_> = (0..n)
        .map(|i| Arc::new(HogwildBuffer::from_slice(&vec![i as f32 * 4.0; p])))
        .collect();
    let stop = Arc::new(AtomicBool::new(false));
    let mut shadows = Vec::new();
    let mut pullers = Vec::new();
    for (i, r) in replicas.iter().enumerate() {
        // workers pull toward trainer-specific optima (0, 4, 8)
        pullers.push(spawn_pullers(r.clone(), i as f32 * 4.0, stop.clone()));
        shadows.push(spawn_shadow(
            Box::new(EasgdSync::new(sync_ps.clone(), 0.3)),
            r.clone(),
            nodes[i],
            net.clone(),
            metrics.clone(),
            stop.clone(),
            Duration::from_micros(500),
            i,
        ));
    }
    std::thread::sleep(Duration::from_millis(300));
    stop.store(true, Relaxed);
    for h in shadows {
        h.join().unwrap().unwrap();
    }
    for h in pullers {
        h.join().unwrap();
    }
    // central copy must sit strictly inside the span of trainer targets —
    // the hub pulled everyone toward consensus while workers kept training
    let central = sync_ps.central.to_vec();
    let mean = central.iter().sum::<f32>() / p as f32;
    assert!(mean > 0.5 && mean < 7.5, "central mean {mean} not in consensus band");
    assert!(metrics.snapshot().syncs > 10);
    // every replica was pulled off its private optimum
    let r0 = replicas[0].to_vec();
    assert!(r0.iter().sum::<f32>() / p as f32 > 0.1);
}

#[test]
fn shadow_ma_with_stragglers_and_leavers() {
    let p = 32;
    let n = 3;
    let group = Arc::new(AllReduceGroup::new(n, p));
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    let stops: Vec<_> = (0..n).map(|_| Arc::new(AtomicBool::new(false))).collect();
    let mut shadows = Vec::new();
    let replicas: Vec<_> = (0..n)
        .map(|i| Arc::new(HogwildBuffer::from_slice(&vec![(i * 10) as f32; p])))
        .collect();
    for i in 0..n {
        shadows.push(spawn_shadow(
            Box::new(MaSync::new(group.clone(), 0.5, p)),
            replicas[i].clone(),
            nodes[i],
            net.clone(),
            metrics.clone(),
            stops[i].clone(),
            Duration::from_micros(300),
            i,
        ));
    }
    // trainer 0 "finishes its shard" early and leaves; the others continue
    std::thread::sleep(Duration::from_millis(50));
    stops[0].store(true, Relaxed);
    std::thread::sleep(Duration::from_millis(100));
    for s in &stops {
        s.store(true, Relaxed);
    }
    for h in shadows {
        h.join().unwrap().unwrap(); // no deadlock, no error
    }
    // remaining members kept converging toward each other
    let a = replicas[1].to_vec();
    let b = replicas[2].to_vec();
    let gap = shadowsync::tensor::ops::mean_abs_diff(&a, &b);
    assert!(gap < 2.0, "replicas 1,2 still {gap} apart");
    assert_eq!(group.active(), 0);
}

#[test]
fn shadow_bmuf_moves_global_toward_average() {
    let p = 16;
    let group = Arc::new(AllReduceGroup::new(2, p));
    let mut net = Network::new(None);
    let n0 = net.add_node(Role::Trainer);
    let n1 = net.add_node(Role::Trainer);
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    let r0 = Arc::new(HogwildBuffer::from_slice(&vec![2.0; p]));
    let r1 = Arc::new(HogwildBuffer::from_slice(&vec![6.0; p]));
    let stop = Arc::new(AtomicBool::new(false));
    let h0 = spawn_shadow(
        Box::new(BmufSync::new(group.clone(), 0.5, 1.0, 0.0, &vec![0.0; p])),
        r0.clone(),
        n0,
        net.clone(),
        metrics.clone(),
        stop.clone(),
        Duration::from_micros(300),
        0,
    );
    let h1 = spawn_shadow(
        Box::new(BmufSync::new(group.clone(), 0.5, 1.0, 0.0, &vec![0.0; p])),
        r1.clone(),
        n1,
        net.clone(),
        metrics.clone(),
        stop.clone(),
        Duration::from_micros(300),
        1,
    );
    std::thread::sleep(Duration::from_millis(200));
    stop.store(true, Relaxed);
    h0.join().unwrap().unwrap();
    h1.join().unwrap().unwrap();
    // both replicas converge toward the average (4.0)
    for r in [&r0, &r1] {
        let v = r.to_vec();
        let mean = v.iter().sum::<f32>() / p as f32;
        assert!((mean - 4.0).abs() < 1.0, "replica mean {mean} far from 4.0");
    }
    assert!(metrics.snapshot().syncs >= 4);
}

/// Drive `rounds` synchronized collective rounds of `strategy_for` across
/// `n` trainers and return (network, nodes, metrics) for traffic checks.
fn drive_collective_rounds<F>(
    n: usize,
    p: usize,
    rounds: u64,
    strategy_for: F,
) -> (Arc<Network>, Vec<shadowsync::net::NodeId>, Arc<Metrics>)
where
    F: Fn(usize) -> Box<dyn SyncStrategy> + Sync,
{
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    std::thread::scope(|s| {
        for (i, &node) in nodes.iter().enumerate() {
            let net = net.clone();
            let metrics = metrics.clone();
            let mut strategy = strategy_for(i);
            s.spawn(move || {
                let replica = HogwildBuffer::from_slice(&vec![i as f32; p]);
                let ctx = SyncCtx::full(&replica, node, &net, &metrics);
                for _ in 0..rounds {
                    strategy.sync_round(&ctx).unwrap();
                }
                strategy.leave();
            });
        }
    });
    (net, nodes, metrics)
}

/// Acceptance: after an MA run, trainer NIC counters carry the *measured*
/// chunked-ring traffic, matching `2·(n-1)/n · bytes` per round within one
/// chunk-segment of rounding per hop.
#[test]
fn ma_ring_traffic_lands_on_trainer_nics() {
    let (n, p, chunks, rounds) = (4usize, 10_000usize, 8usize, 25u64);
    let group = Arc::new(AllReduceGroup::new(n, p).with_chunks(chunks));
    let g = group.clone();
    let (net, nodes, metrics) =
        drive_collective_rounds(n, p, rounds, move |_| -> Box<dyn SyncStrategy> {
            Box::new(MaSync::new(g.clone(), 0.5, p))
        });
    let formula = group.ring_bytes_per_member(n) * rounds;
    assert!(formula > 0);
    // one element of rounding per chunk, per hop, per round
    let slack = rounds * 2 * (n as u64 - 1) * chunks as u64 * 4;
    let mut measured_total = 0u64;
    for &node in &nodes {
        let (tx, rx) = (net.tx(node), net.rx(node));
        assert!(
            tx.abs_diff(formula) <= slack,
            "tx {tx} vs ring formula {formula} (slack {slack})"
        );
        assert!(
            rx.abs_diff(formula) <= slack,
            "rx {rx} vs ring formula {formula} (slack {slack})"
        );
        measured_total += tx;
    }
    // the recorded sync-byte metric is exactly the measured wire traffic
    let snap = metrics.snapshot();
    assert_eq!(snap.sync_bytes, measured_total);
    assert_eq!(snap.syncs, n as u64 * rounds);
    // aggregate ring traffic is exact regardless of chunking
    assert_eq!(measured_total, 2 * (n as u64 - 1) * p as u64 * 4 * rounds);
}

/// Acceptance: delta-gated chunked EASGD pushes — recorded sync bytes
/// always equal the sync-PS NIC counters, and once the replicas converge
/// below the gate, rounds stop moving bytes entirely (both legs).
#[test]
fn delta_gated_easgd_metrics_agree_with_nic_counters() {
    let p = 96;
    let mut net = Network::new(None);
    let t = net.add_node(Role::Trainer);
    let group = Arc::new(
        SyncPsGroup::build(&vec![0.0; p], 3, &mut net).with_push_chunking(8, 1e-3),
    );
    let metrics = Metrics::new();
    let local = HogwildBuffer::from_slice(&vec![1.0; p]);
    let mut s = EasgdSync::new(group.clone(), 0.5);
    let ctx = SyncCtx::full(&local, t, &net, &metrics);
    for _ in 0..30 {
        s.sync_round(&ctx).unwrap();
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.syncs, 30);
    assert_eq!(
        net.role_bytes(Role::SyncPs),
        snap.sync_bytes,
        "metrics.sync_bytes must track the sync-PS NICs exactly"
    );
    // alpha = 0.5 with a single trainer meets central at the midpoint in
    // one round (gap -> 0), so every later round skips every chunk
    let st = group.elastic_sync_stats(&local, 0.5, t, &net);
    assert_eq!(st.bytes, 0);
    assert_eq!(st.chunks_pushed, 0);
    assert_eq!(st.chunks_skipped, (p / 8) as u64);
    let traffic = group.traffic();
    assert!(traffic.chunks_skipped > 0, "converged rounds must skip");
    assert!(traffic.push_fraction() < 1.0);
    // total bytes stayed strictly below 30 full rounds
    assert!(snap.sync_bytes < 30 * group.round_bytes());
}

/// Churn stress, engine-parameterized: members leave and rejoin at
/// staggered points while rounds pipeline (across the overlapped engine's
/// two parity banks, or through the shared-nothing engine's depth-2
/// deposit rings), and *every* generation's mean must stay bit-identical
/// to a single-threaded fold of its contributions in ring-position order —
/// the fixed summation order survives deposit/reduce overlap and
/// membership churn.
fn churn_stays_bit_identical(engine: ReduceEngine) {
    let (n, p, chunks) = (6usize, 193usize, 5usize);
    let g = Arc::new(AllReduceGroup::new(n, p).with_chunks(chunks).with_engine(engine));
    assert_eq!(g.engine(), engine);
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
    let net = Arc::new(net);
    let mut hs = Vec::new();
    for t in 0..n {
        let g = g.clone();
        let net = net.clone();
        let node = nodes[t];
        hs.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xC4A2 ^ t as u64);
            let my_rounds = 60 + (rng.next_u64() % 60) as usize;
            // each thread churns (leaves, then rejoins) once, at its own
            // staggered point, between two of its own rounds
            let churn_at = 5 + t * 9;
            let mut log = Vec::with_capacity(my_rounds);
            for r in 0..my_rounds {
                if r == churn_at {
                    // churn window: sit out until (bounded-wait) at least
                    // one round closed without us, then rejoin
                    let gen0 = g.completed_rounds();
                    g.leave();
                    for _ in 0..1_000_000 {
                        if g.completed_rounds() > gen0 {
                            break;
                        }
                        std::thread::yield_now();
                    }
                    g.join().expect("rejoin after leave");
                }
                // fractional values whose f32 sum is association-order
                // sensitive — any reordering would change the bits
                let v: Vec<f32> = (0..p)
                    .map(|_| (rng.next_u64() % 1_000_003) as f32 * 1e-3 - 500.0)
                    .collect();
                let mut buf = v.clone();
                let out = g.allreduce_mean(&mut buf, node, &net).unwrap();
                log.push((out.generation, out.position, out.contributors, v, buf));
            }
            g.leave();
            log
        }));
    }
    let mut by_gen: HashMap<u64, Vec<(usize, usize, Vec<f32>, Vec<f32>)>> = HashMap::new();
    for h in hs {
        for (gen, pos, parts, v, mean) in h.join().unwrap() {
            by_gen.entry(gen).or_default().push((pos, parts, v, mean));
        }
    }
    assert!(by_gen.len() >= 60, "expected 60+ generations, got {}", by_gen.len());
    let mut shrunk_rounds = 0;
    for (gen, mut entries) in by_gen {
        entries.sort_by_key(|e| e.0);
        if entries.len() < n {
            shrunk_rounds += 1; // closed while someone was churned out
        }
        // the reported contributor count is exact for every member
        for (pos, parts, _, _) in &entries {
            assert_eq!(*parts, entries.len(), "gen {gen} pos {pos}");
        }
        // bit-identical to the position-order fold
        let mut reference = entries[0].2.clone();
        for e in &entries[1..] {
            for (acc, &x) in reference.iter_mut().zip(&e.2) {
                *acc += x;
            }
        }
        let inv = 1.0 / entries.len() as f32;
        for acc in reference.iter_mut() {
            *acc *= inv;
        }
        for (pos, _, _, mean) in &entries {
            for (a, b) in mean.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "gen {gen} pos {pos}: {a} != {b}");
            }
        }
    }
    assert_eq!(g.active(), 0);
    // churn must actually have produced shrunken rounds for this test to
    // mean anything (6 staggered leave/rejoin windows over ~60 rounds)
    assert!(shrunk_rounds > 0, "no round ever closed during a churn window");
}

#[test]
fn churn_with_overlapped_rounds_stays_bit_identical_to_position_order_reference() {
    churn_stays_bit_identical(ReduceEngine::Overlapped);
}

#[test]
fn churn_with_shared_nothing_rounds_stays_bit_identical_to_position_order_reference() {
    churn_stays_bit_identical(ReduceEngine::SharedNothing);
}

/// The engine CI's stress/chaos matrix selects via `SHADOWSYNC_REDUCE_ENGINE`
/// (defaults to the run's normal default when unset or unparseable).
fn engine_from_env(default: ReduceEngine) -> ReduceEngine {
    std::env::var("SHADOWSYNC_REDUCE_ENGINE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// The same churn property under whatever engine the CI matrix points at —
/// release-mode stress rows exercise each engine dimension through here.
#[test]
fn churn_with_env_selected_engine_stays_bit_identical() {
    let engine = engine_from_env(ReduceEngine::Overlapped);
    if engine == ReduceEngine::SerialMutex {
        // the serial baseline folds in arrival order by design: the
        // position-order reference does not apply
        return;
    }
    churn_stays_bit_identical(engine);
}

/// Acceptance: the adaptive quantile gate + dirty-epoch scan skips keep
/// `metrics.sync_bytes` exactly equal to the sync-PS NIC counters, and the
/// live skip-rate metric reflects the gate's decisions.
#[test]
fn adaptive_gate_with_dirty_epochs_tracks_nic_counters_exactly() {
    let p = 256;
    let chunk = 16;
    let mut net = Network::new(None);
    let t = net.add_node(Role::Trainer);
    let group = Arc::new(
        SyncPsGroup::build(&vec![0.0; p], 2, &mut net)
            .with_push_chunking(chunk, 0.0)
            .with_adaptive_gate(0.5),
    );
    let metrics = Metrics::new();
    let local = HogwildBuffer::from_slice(&vec![0.0; p]).with_dirty_epochs(chunk);
    let mut s = EasgdSync::new(group.clone(), 0.4);
    let ctx = SyncCtx::full(&local, t, &net, &metrics);
    let mut rng = Rng::new(0xD1A7);
    for round in 0..50 {
        // perturb a few random subranges between rounds (workers writing)
        for _ in 0..(round % 4) {
            let lo = (rng.next_u64() as usize) % (p - 8);
            let noise: Vec<f32> = (0..8).map(|_| rng.u01() - 0.5).collect();
            local.axpy_range(lo, 0.3, &noise);
        }
        s.sync_round(&ctx).unwrap();
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.syncs, 50);
    assert_eq!(
        net.role_bytes(Role::SyncPs),
        snap.sync_bytes,
        "metrics.sync_bytes must track the sync-PS NICs exactly under \
         adaptive gating + dirty-epoch skips"
    );
    let traffic = group.traffic();
    assert_eq!(traffic.bytes_moved, snap.sync_bytes);
    // the chunk counters flow identically through metrics and the snapshot
    assert_eq!(snap.sync_chunks_pushed, traffic.chunks_pushed);
    assert_eq!(snap.sync_chunks_skipped, traffic.chunks_skipped);
    assert_eq!(snap.sync_scan_skipped, traffic.chunks_scan_skipped);
    // the adaptive gate engaged (post-warmup rounds skip), and idle chunks
    // exercised the dirty-epoch scan fast path
    assert!(traffic.chunks_skipped > 0, "adaptive gate never skipped");
    assert!(traffic.chunks_scan_skipped > 0, "dirty epochs never skipped a scan");
    assert!(snap.sync_skip_rate() > 0.0);
}

/// Same acceptance check for BMUF, on a flat (single-chunk) ring.
#[test]
fn bmuf_ring_traffic_lands_on_trainer_nics() {
    let (n, p, rounds) = (3usize, 9_999usize, 10u64);
    let group = Arc::new(AllReduceGroup::new(n, p));
    let g = group.clone();
    let (net, nodes, _metrics) =
        drive_collective_rounds(n, p, rounds, move |_| -> Box<dyn SyncStrategy> {
            Box::new(BmufSync::new(g.clone(), 0.5, 1.0, 0.0, &vec![0.0; p]))
        });
    let formula = group.ring_bytes_per_member(n) * rounds;
    let slack = rounds * 2 * (n as u64 - 1) * 4; // flat: one segment's rounding
    for &node in &nodes {
        assert!(
            net.tx(node).abs_diff(formula) <= slack,
            "tx {} vs ring formula {formula}",
            net.tx(node)
        );
    }
}

/// Satellite/acceptance: a `P = 1, S = 1` partition plan is bit-identical
/// to the pre-refactor single-strategy path — final replicas, the central
/// copy, `metrics.sync_bytes`, and every NIC counter — for EASGD, driven
/// deterministically (sequential rounds, identical perturbations).
#[test]
fn p1_easgd_partition_fabric_is_bit_identical_to_single_strategy_path() {
    let p = 96usize;
    let rounds = 10usize;
    // pre-generate deterministic inputs shared by both paths
    let mut rng = Rng::new(0x51D);
    let init: Vec<Vec<f32>> = (0..2)
        .map(|_| (0..p).map(|_| rng.u01() * 4.0 - 2.0).collect())
        .collect();
    let perturb: Vec<Vec<Vec<f32>>> = (0..rounds)
        .map(|_| (0..2).map(|_| (0..p).map(|_| rng.u01() - 0.5).collect()).collect())
        .collect();
    let cfg = RunConfig {
        num_trainers: 2,
        easgd_chunk_elems: 8,
        delta_threshold: 1e-3,
        ..RunConfig::default()
    };

    type Fingerprint = (Vec<Vec<u32>>, Vec<u32>, u64, u64, Vec<(u64, u64)>);
    let fingerprint = |replicas: &[HogwildBuffer],
                       central: &HogwildBuffer,
                       sync_bytes: u64,
                       ps_bytes: u64,
                       nics: Vec<(u64, u64)>|
     -> Fingerprint {
        (
            replicas
                .iter()
                .map(|r| r.to_vec().iter().map(|x| x.to_bits()).collect())
                .collect(),
            central.to_vec().iter().map(|x| x.to_bits()).collect(),
            sync_bytes,
            ps_bytes,
            nics,
        )
    };

    // legacy path: whole-vector strategies falling back to the group gate
    let legacy: Fingerprint = {
        let mut net = Network::new(None);
        let nodes = [net.add_node(Role::Trainer), net.add_node(Role::Trainer)];
        let group = Arc::new(
            SyncPsGroup::build(&vec![0.0; p], 2, &mut net)
                .with_push_chunking(cfg.easgd_chunk_elems, cfg.delta_threshold),
        );
        let metrics = Metrics::new();
        let replicas: Vec<HogwildBuffer> = init
            .iter()
            .map(|v| HogwildBuffer::from_slice(v).with_dirty_epochs(cfg.easgd_chunk_elems))
            .collect();
        let mut strategies: Vec<EasgdSync> =
            (0..2).map(|_| EasgdSync::new(group.clone(), 0.4)).collect();
        for r in 0..rounds {
            for t in 0..2 {
                replicas[t].axpy(0.5, &perturb[r][t]);
                let ctx = SyncCtx::full(&replicas[t], nodes[t], &net, &metrics);
                strategies[t].sync_round(&ctx).unwrap();
            }
        }
        let nics = nodes.iter().map(|&n| (net.tx(n), net.rx(n))).collect();
        fingerprint(
            &replicas,
            &group.central,
            metrics.snapshot().sync_bytes,
            net.role_bytes(Role::SyncPs),
            nics,
        )
    };

    // partitioned path: the P = 1 plan + build_strategy (per-strategy gate)
    let partitioned: Fingerprint = {
        let plan = PartitionPlan::build(p, &cfg).unwrap();
        assert_eq!(plan.len(), 1, "default config must produce the single plan");
        let mut net = Network::new(None);
        let nodes = [net.add_node(Role::Trainer), net.add_node(Role::Trainer)];
        let group = Arc::new(
            SyncPsGroup::build(&vec![0.0; p], 2, &mut net)
                .with_push_chunking(cfg.easgd_chunk_elems, cfg.delta_threshold),
        );
        let metrics = Metrics::new();
        let replicas: Vec<HogwildBuffer> = init
            .iter()
            .map(|v| HogwildBuffer::from_slice(v).with_dirty_epochs(cfg.easgd_chunk_elems))
            .collect();
        let w0 = vec![0.0f32; p];
        let mut strategies: Vec<Box<dyn SyncStrategy>> = (0..2)
            .map(|t| {
                build_strategy(&cfg, &plan.partitions[0], t, &w0, Some(group.clone()), None)
                    .unwrap()
            })
            .collect();
        for r in 0..rounds {
            for t in 0..2 {
                replicas[t].axpy(0.5, &perturb[r][t]);
                let ctx = SyncCtx {
                    local: &replicas[t],
                    range: plan.partitions[0].range,
                    partition: 0,
                    trainer_node: nodes[t],
                    net: &net,
                    metrics: &metrics,
                };
                strategies[t].sync_round(&ctx).unwrap();
            }
        }
        let nics = nodes.iter().map(|&n| (net.tx(n), net.rx(n))).collect();
        fingerprint(
            &replicas,
            &group.central,
            metrics.snapshot().sync_bytes,
            net.role_bytes(Role::SyncPs),
            nics,
        )
    };

    assert_eq!(legacy, partitioned, "P=1 fabric must be bit-identical to the legacy path");
    // the run must actually exercise the gate (some skips, some pushes)
    assert!(legacy.2 > 0, "nothing ever moved");
}

/// Same `P = 1` equivalence for the decentralized algorithms: the
/// range-scoped read/AllReduce/elastic-pull wrapper must be bit-identical
/// to the legacy whole-vector round (deterministic singleton rings).
#[test]
fn p1_collective_partition_fabric_matches_single_strategy_path() {
    let p = 73usize;
    let rounds = 8usize;
    let mut rng = Rng::new(0xB0F);
    let w0: Vec<f32> = (0..p).map(|_| rng.u01() * 2.0 - 1.0).collect();
    let perturb: Vec<Vec<f32>> = (0..rounds)
        .map(|_| (0..p).map(|_| rng.u01() - 0.5).collect())
        .collect();
    for algo in [SyncAlgo::Ma, SyncAlgo::Bmuf] {
        let cfg = RunConfig { algo, num_trainers: 1, num_sync_ps: 0, ..RunConfig::default() };
        let drive = |mut strategy: Box<dyn SyncStrategy>, range: ParamRange| -> (Vec<u32>, u64) {
            let mut net = Network::new(None);
            let node = net.add_node(Role::Trainer);
            let metrics = Metrics::new();
            let replica = HogwildBuffer::from_slice(&w0);
            for pert in &perturb {
                replica.axpy(0.25, pert);
                let ctx = SyncCtx {
                    local: &replica,
                    range,
                    partition: 0,
                    trainer_node: node,
                    net: &net,
                    metrics: &metrics,
                };
                strategy.sync_round(&ctx).unwrap();
            }
            strategy.leave();
            (replica.to_vec().iter().map(|x| x.to_bits()).collect(), metrics.snapshot().syncs)
        };
        let legacy: Box<dyn SyncStrategy> = match algo {
            SyncAlgo::Ma => Box::new(MaSync::new(build_group(&cfg, 0, p), cfg.alpha, p)),
            _ => Box::new(BmufSync::new(
                build_group(&cfg, 0, p),
                cfg.alpha,
                cfg.bmuf_eta,
                cfg.bmuf_momentum,
                &w0,
            )),
        };
        let plan = PartitionPlan::build(p, &cfg).unwrap();
        let partitioned =
            build_strategy(&cfg, &plan.partitions[0], 0, &w0, None, Some(build_group(&cfg, 0, p)))
                .unwrap();
        let a = drive(legacy, ParamRange::full(p));
        let b = drive(partitioned, plan.partitions[0].range);
        assert_eq!(a, b, "{algo:?}: P=1 fabric diverged from the legacy path");
    }
}

/// Acceptance: a hybrid partitioned fabric — EASGD partitions (with their
/// own per-partition gates) next to MA partitions (each with its own ring)
/// — driven by 2-thread shadow pools on 2 trainers, completes end-to-end
/// with `metrics.sync_bytes` exactly equal to the summed sync-PS NIC
/// counters plus the ring NIC counters.
#[test]
fn hybrid_partition_fabric_accounts_every_byte() {
    let len = 1024usize;
    let chunk = 64usize;
    let ranges = lpt_contiguous_ranges(len, 4, chunk);
    let mut net = Network::new(None);
    let nodes = [net.add_node(Role::Trainer), net.add_node(Role::Trainer)];
    let sync_ps = Arc::new(
        SyncPsGroup::build(&vec![0.0; len], 2, &mut net).with_push_chunking(chunk, 1e-4),
    );
    // partitions 0-1: EASGD; partitions 2-3: MA over their own rings
    let ma_groups: Vec<Arc<AllReduceGroup>> = ranges[2..]
        .iter()
        .map(|r| Arc::new(AllReduceGroup::new(2, r.len).with_chunks(4)))
        .collect();
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    let mut replicas = Vec::new();
    for (t, &node) in nodes.iter().enumerate() {
        let replica = Arc::new(
            HogwildBuffer::from_slice(&vec![t as f32 + 1.0; len]).with_dirty_epochs(chunk),
        );
        replicas.push(replica.clone());
        let tasks: Vec<ShadowTask> = ranges
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let strategy: Box<dyn SyncStrategy> = if i < 2 {
                    Box::new(
                        EasgdSync::new(sync_ps.clone(), 0.3).with_gate(DeltaGate::new(1e-4, 0.0)),
                    )
                } else {
                    Box::new(MaSync::new(ma_groups[i - 2].clone(), 0.3, r.len))
                };
                ShadowTask { partition: i, range: *r, strategy }
            })
            .collect();
        handles.push(spawn_shadow_pool(
            tasks,
            replica,
            node,
            net.clone(),
            metrics.clone(),
            stop.clone(),
            Duration::from_micros(200),
            t,
            2,
        ));
    }
    std::thread::sleep(Duration::from_millis(250));
    stop.store(true, Relaxed);
    for h in handles {
        h.join().unwrap().unwrap();
    }
    let snap = metrics.snapshot();
    assert!(snap.syncs > 0);
    // every partition ran rounds (the per-partition gap metric is live)
    assert_eq!(snap.partition_syncs.len(), 4);
    for (i, &s) in snap.partition_syncs.iter().enumerate() {
        assert!(s > 0, "partition {i} never synced: {:?}", snap.partition_syncs);
    }
    // byte identity: EASGD legs land on the sync-PS tier (both directions
    // == role_bytes); ring hops are trainer-to-trainer, so the collective
    // tx is total trainer tx minus the trainer→PS push legs (== sync-PS rx)
    let trainer_tx: u64 = nodes.iter().map(|&n| net.tx(n)).sum();
    let ring_tx = trainer_tx - net.role_rx(Role::SyncPs);
    assert_eq!(
        snap.sync_bytes,
        net.role_bytes(Role::SyncPs) + ring_tx,
        "metrics.sync_bytes must equal summed sync-PS + ring NIC counters"
    );
    // the EASGD partitions pulled the replicas together through the hub;
    // the MA partitions averaged them through their rings
    let (a, b) = (replicas[0].to_vec(), replicas[1].to_vec());
    for r in &ranges {
        let gap = shadowsync::tensor::ops::mean_abs_diff(&a[r.lo()..r.hi()], &b[r.lo()..r.hi()]);
        assert!(gap < 0.6, "partition {r:?} never converged: gap {gap}");
    }
}

/// Acceptance (adaptive repartitioning churn): a hybrid EASGD+MA fabric on
/// 2 trainers repartitions repeatedly mid-training, under concurrent
/// replica writes, and the byte accounting stays *exact* — every recorded
/// sync byte equals the sync-PS NIC counters plus the ring tx — while no
/// cutover ever loses a partition, leaks collective-group membership, or
/// corrupts the replicas/central vector.
#[test]
fn mid_training_repartition_keeps_byte_accounting_exact() {
    let len = 4096usize;
    let chunk = 64usize;
    let cfg = RunConfig {
        num_trainers: 2,
        sync_partitions: 4,
        shadow_threads: 2,
        easgd_chunk_elems: chunk,
        delta_threshold: 1e-4,
        repartition_every: 50,
        algo_map: Some("easgd:0-2,ma:3".parse().unwrap()),
        ..RunConfig::default()
    };
    let mut net = Network::new(None);
    let nodes = [net.add_node(Role::Trainer), net.add_node(Role::Trainer)];
    let w0 = vec![0.0f32; len];
    let sync_ps = Arc::new(
        SyncPsGroup::build(&w0, 2, &mut net).with_push_chunking(chunk, cfg.delta_threshold),
    );
    let plan = PartitionPlan::build(len, &cfg).unwrap();
    let groups: Vec<Option<Arc<AllReduceGroup>>> = plan
        .partitions
        .iter()
        .map(|p| match p.algo {
            SyncAlgo::Ma | SyncAlgo::Bmuf => Some(build_group(&cfg, p.index, p.range.len)),
            _ => None,
        })
        .collect();
    let controller = Arc::new(RepartitionController::new(
        &cfg,
        len,
        Some(sync_ps.clone()),
        plan.clone(),
        groups.clone(),
    ));
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));
    let mut pools = Vec::new();
    let mut writers = Vec::new();
    for (t, &node) in nodes.iter().enumerate() {
        let replica = Arc::new(
            HogwildBuffer::from_slice(&vec![t as f32 + 1.0; len]).with_dirty_epochs(chunk),
        );
        let tasks: Vec<ShadowTask> = plan
            .partitions
            .iter()
            .map(|p| ShadowTask {
                partition: p.index,
                range: p.range,
                strategy: build_strategy(
                    &cfg,
                    p,
                    t,
                    &w0,
                    Some(sync_ps.clone()),
                    groups[p.index].clone(),
                )
                .unwrap(),
            })
            .collect();
        pools.push(spawn_shadow_pool_adaptive(
            tasks,
            replica.clone(),
            node,
            net.clone(),
            metrics.clone(),
            stop.clone(),
            Duration::ZERO,
            t,
            cfg.shadow_threads,
            Some(controller.clone()),
            None,
        ));
        // writers keep the hot first quarter dirty so replans have skew
        let stop = stop.clone();
        writers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xFEED ^ t as u64);
            while !stop.load(Relaxed) {
                let lo = (rng.next_u64() as usize) % (len / 4);
                let noise: Vec<f32> = (0..32).map(|_| rng.u01() - 0.5).collect();
                let lo = lo.min(len - 32);
                replica.axpy_range(lo, 0.3, &noise);
                std::thread::sleep(Duration::from_micros(100));
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(400));
    stop.store(true, Relaxed);
    let mut rounds = 0u64;
    for h in pools {
        rounds += h.join().unwrap().unwrap();
    }
    for w in writers {
        w.join().unwrap();
    }
    assert!(rounds > 0);
    assert!(
        controller.repartitions() >= 1,
        "no cutover was ever adopted — the churn test proved nothing"
    );
    // exact byte identity across every cutover: recorded sync bytes ==
    // sync-PS NIC bytes (both EASGD legs) + ring tx (trainer-to-trainer)
    let snap = metrics.snapshot();
    let trainer_tx: u64 = nodes.iter().map(|&n| net.tx(n)).sum();
    let ring_tx = trainer_tx - net.role_rx(Role::SyncPs);
    assert_eq!(
        snap.sync_bytes,
        net.role_bytes(Role::SyncPs) + ring_tx,
        "byte accounting must stay exact across mid-training repartitions"
    );
    // the sync-PS group's own ledger agrees with the EASGD share
    assert_eq!(sync_ps.traffic().bytes_moved, net.role_bytes(Role::SyncPs));
    // every partition index kept syncing across the replans
    assert_eq!(snap.partition_syncs.len(), 4);
    for (i, &s) in snap.partition_syncs.iter().enumerate() {
        assert!(s > 0, "partition {i} starved: {:?}", snap.partition_syncs);
    }
    // per-partition byte resolution covered all partitions too
    assert_eq!(snap.partition_sync_bytes.len(), 4);
    assert!(snap.partition_sync_bytes.iter().all(|&b| b > 0));
    // no epoch leaked collective membership: the current epoch's groups
    // were fully vacated by strategy leave()s and/or departs
    for g in controller.current_epoch().groups.iter().flatten() {
        assert_eq!(g.active(), 0, "leaked membership in a repartition epoch group");
    }
    // central + replicas stayed well-formed through every cutover
    assert!(sync_ps.central.to_vec().iter().all(|x| x.is_finite()));
}

/// Deterministic cutover exactness: with no concurrent writers, a single
/// trainer's delta-gated EASGD fabric repartitions mid-run and still
/// converges local and central to within the gate everywhere — a chunk can
/// never be lost by a replan (a lost chunk would stay at its initial gap),
/// and recorded bytes equal the NIC counters exactly.
#[test]
fn repartition_preserves_every_chunk_of_the_replica() {
    let len = 2048usize;
    let chunk = 32usize;
    let cfg = RunConfig {
        num_trainers: 1,
        sync_partitions: 4,
        shadow_threads: 2,
        easgd_chunk_elems: chunk,
        delta_threshold: 1e-4,
        repartition_every: 10,
        ..RunConfig::default()
    };
    let mut net = Network::new(None);
    let node = net.add_node(Role::Trainer);
    let w0 = vec![0.0f32; len];
    let sync_ps = Arc::new(
        SyncPsGroup::build(&w0, 2, &mut net).with_push_chunking(chunk, cfg.delta_threshold),
    );
    let plan = PartitionPlan::build(len, &cfg).unwrap();
    let controller = Arc::new(RepartitionController::new(
        &cfg,
        len,
        Some(sync_ps.clone()),
        plan.clone(),
        vec![None; plan.len()],
    ));
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));
    // every element starts 2.0 away from central: convergence below the
    // gate everywhere proves every chunk was owned by some partition in
    // every epoch
    let replica = Arc::new(HogwildBuffer::from_slice(&vec![2.0; len]).with_dirty_epochs(chunk));
    let tasks: Vec<ShadowTask> = plan
        .partitions
        .iter()
        .map(|p| ShadowTask {
            partition: p.index,
            range: p.range,
            strategy: build_strategy(&cfg, p, 0, &w0, Some(sync_ps.clone()), None).unwrap(),
        })
        .collect();
    let pool = spawn_shadow_pool_adaptive(
        tasks,
        replica.clone(),
        node,
        net.clone(),
        metrics.clone(),
        stop.clone(),
        Duration::ZERO,
        0,
        cfg.shadow_threads,
        Some(controller.clone()),
        None,
    );
    std::thread::sleep(Duration::from_millis(200));
    stop.store(true, Relaxed);
    pool.join().unwrap().unwrap();
    assert!(controller.repartitions() >= 1, "no repartition cutover ever happened");
    let lv = replica.to_vec();
    let cv = sync_ps.central.to_vec();
    for (i, (l, c)) in lv.iter().zip(&cv).enumerate() {
        let gap = (l - c).abs();
        assert!(
            gap <= cfg.delta_threshold,
            "element {i} never converged (gap {gap}): its chunk was lost by a replan"
        );
    }
    // byte accounting is exact here too
    assert_eq!(metrics.snapshot().sync_bytes, net.role_bytes(Role::SyncPs));
}

/// Acceptance (wire codecs): the hybrid EASGD+MA fabric under every lossy
/// codec — delta gates on, a seeded drop plan faulting transfers, push
/// retries riding them out — and the byte identity holds bit-exactly:
/// `metrics.sync_bytes` equals the summed sync-PS NIC counters plus the
/// ring tx, with every counter now seeing codec-compressed bytes.
#[test]
fn codec_fabric_accounts_every_byte_under_gating_and_faults() {
    for codec in [WireCodec::Fp16, WireCodec::Int8, WireCodec::TopK(0.25)] {
        let len = 1024usize;
        let chunk = 64usize;
        let ranges = lpt_contiguous_ranges(len, 4, chunk);
        let mut net = Network::new(None);
        let nodes = [net.add_node(Role::Trainer), net.add_node(Role::Trainer)];
        let sync_ps = Arc::new(
            SyncPsGroup::build(&vec![0.0; len], 2, &mut net)
                .with_push_chunking(chunk, 1e-4)
                .with_push_retry(8, Duration::from_micros(10)),
        );
        // the CI matrix rotates the reduce engine through this byte-identity
        // check too: ring accounting is engine-independent by construction
        let engine = engine_from_env(ReduceEngine::Overlapped);
        let ma_groups: Vec<Arc<AllReduceGroup>> = ranges[2..]
            .iter()
            .map(|r| {
                Arc::new(
                    AllReduceGroup::new(2, r.len)
                        .with_chunks(4)
                        .with_engine(engine)
                        .with_codec(codec),
                )
            })
            .collect();
        let plan = Arc::new(
            shadowsync::net::fault::FaultPlan::parse("drop:t0@0.05", 0xC0DEC).unwrap(),
        );
        let net = Arc::new(net.with_faults(plan.clone()));
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        let mut replicas = Vec::new();
        for (t, &node) in nodes.iter().enumerate() {
            let replica = Arc::new(
                HogwildBuffer::from_slice(&vec![t as f32 + 1.0; len]).with_dirty_epochs(chunk),
            );
            replicas.push(replica.clone());
            let tasks: Vec<ShadowTask> = ranges
                .iter()
                .enumerate()
                .map(|(i, r)| {
                    let strategy: Box<dyn SyncStrategy> = if i < 2 {
                        Box::new(
                            EasgdSync::new(sync_ps.clone(), 0.3)
                                .with_gate(DeltaGate::new(1e-4, 0.0))
                                .with_codec(codec),
                        )
                    } else {
                        Box::new(
                            MaSync::new(ma_groups[i - 2].clone(), 0.3, r.len).with_codec(codec),
                        )
                    };
                    ShadowTask { partition: i, range: *r, strategy }
                })
                .collect();
            handles.push(spawn_shadow_pool(
                tasks,
                replica,
                node,
                net.clone(),
                metrics.clone(),
                stop.clone(),
                Duration::from_micros(200),
                t,
                2,
            ));
        }
        std::thread::sleep(Duration::from_millis(250));
        stop.store(true, Relaxed);
        for h in handles {
            h.join().unwrap().unwrap();
        }
        let snap = metrics.snapshot();
        assert!(snap.syncs > 0, "{codec}: no sync rounds completed");
        for (i, &s) in snap.partition_syncs.iter().enumerate() {
            assert!(s > 0, "{codec}: partition {i} never synced");
        }
        let trainer_tx: u64 = nodes.iter().map(|&n| net.tx(n)).sum();
        let ring_tx = trainer_tx - net.role_rx(Role::SyncPs);
        assert_eq!(
            snap.sync_bytes,
            net.role_bytes(Role::SyncPs) + ring_tx,
            "{codec}: metrics.sync_bytes diverged from the NIC counters"
        );
        // the per-partition ledger covers the same bytes, codec-compressed
        let part_total: u64 = snap.partition_sync_bytes.iter().sum();
        assert_eq!(part_total, snap.sync_bytes, "{codec}: per-partition ledger diverged");
        // the compressed wire still pulls the replicas together: error
        // feedback keeps the lossy legs converging instead of drifting
        let (a, b) = (replicas[0].to_vec(), replicas[1].to_vec());
        for r in &ranges {
            let gap =
                shadowsync::tensor::ops::mean_abs_diff(&a[r.lo()..r.hi()], &b[r.lo()..r.hi()]);
            assert!(gap < 0.8, "{codec}: partition {r:?} never converged (gap {gap})");
        }
    }
}
