//! Cross-module sync semantics under real multi-threading (no artifacts
//! needed): shadow threads + Hogwild workers + sync PSs / AllReduce groups
//! interacting on shared replicas.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::Arc;
use std::time::Duration;

use shadowsync::metrics::Metrics;
use shadowsync::net::{Network, Role};
use shadowsync::sync::driver::spawn_shadow;
use shadowsync::sync::{
    AllReduceGroup, BmufSync, EasgdSync, MaSync, ReduceEngine, SyncCtx, SyncPsGroup,
    SyncStrategy,
};
use shadowsync::tensor::HogwildBuffer;
use shadowsync::util::rng::Rng;

/// Simulated "workers": threads that keep pulling a replica toward a
/// trainer-specific target while shadow threads sync replicas to consensus.
fn spawn_pullers(
    replica: Arc<HogwildBuffer>,
    target: f32,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while !stop.load(Relaxed) {
            let t = vec![target; replica.len()];
            replica.lerp_toward_slice(&t, 0.05);
            std::thread::sleep(Duration::from_micros(200));
        }
    })
}

#[test]
fn shadow_easgd_reaches_consensus_across_trainers() {
    let p = 64;
    let n = 3;
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
    let sync_ps = Arc::new(SyncPsGroup::build(&vec![0.0; p], 2, &mut net));
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());

    let replicas: Vec<_> = (0..n)
        .map(|i| Arc::new(HogwildBuffer::from_slice(&vec![i as f32 * 4.0; p])))
        .collect();
    let stop = Arc::new(AtomicBool::new(false));
    let mut shadows = Vec::new();
    let mut pullers = Vec::new();
    for (i, r) in replicas.iter().enumerate() {
        // workers pull toward trainer-specific optima (0, 4, 8)
        pullers.push(spawn_pullers(r.clone(), i as f32 * 4.0, stop.clone()));
        shadows.push(spawn_shadow(
            Box::new(EasgdSync::new(sync_ps.clone(), 0.3)),
            r.clone(),
            nodes[i],
            net.clone(),
            metrics.clone(),
            stop.clone(),
            Duration::from_micros(500),
            i,
        ));
    }
    std::thread::sleep(Duration::from_millis(300));
    stop.store(true, Relaxed);
    for h in shadows {
        h.join().unwrap().unwrap();
    }
    for h in pullers {
        h.join().unwrap();
    }
    // central copy must sit strictly inside the span of trainer targets —
    // the hub pulled everyone toward consensus while workers kept training
    let central = sync_ps.central.to_vec();
    let mean = central.iter().sum::<f32>() / p as f32;
    assert!(mean > 0.5 && mean < 7.5, "central mean {mean} not in consensus band");
    assert!(metrics.snapshot().syncs > 10);
    // every replica was pulled off its private optimum
    let r0 = replicas[0].to_vec();
    assert!(r0.iter().sum::<f32>() / p as f32 > 0.1);
}

#[test]
fn shadow_ma_with_stragglers_and_leavers() {
    let p = 32;
    let n = 3;
    let group = Arc::new(AllReduceGroup::new(n, p));
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    let stops: Vec<_> = (0..n).map(|_| Arc::new(AtomicBool::new(false))).collect();
    let mut shadows = Vec::new();
    let replicas: Vec<_> = (0..n)
        .map(|i| Arc::new(HogwildBuffer::from_slice(&vec![(i * 10) as f32; p])))
        .collect();
    for i in 0..n {
        shadows.push(spawn_shadow(
            Box::new(MaSync::new(group.clone(), 0.5, p)),
            replicas[i].clone(),
            nodes[i],
            net.clone(),
            metrics.clone(),
            stops[i].clone(),
            Duration::from_micros(300),
            i,
        ));
    }
    // trainer 0 "finishes its shard" early and leaves; the others continue
    std::thread::sleep(Duration::from_millis(50));
    stops[0].store(true, Relaxed);
    std::thread::sleep(Duration::from_millis(100));
    for s in &stops {
        s.store(true, Relaxed);
    }
    for h in shadows {
        h.join().unwrap().unwrap(); // no deadlock, no error
    }
    // remaining members kept converging toward each other
    let a = replicas[1].to_vec();
    let b = replicas[2].to_vec();
    let gap = shadowsync::tensor::ops::mean_abs_diff(&a, &b);
    assert!(gap < 2.0, "replicas 1,2 still {gap} apart");
    assert_eq!(group.active(), 0);
}

#[test]
fn shadow_bmuf_moves_global_toward_average() {
    let p = 16;
    let group = Arc::new(AllReduceGroup::new(2, p));
    let mut net = Network::new(None);
    let n0 = net.add_node(Role::Trainer);
    let n1 = net.add_node(Role::Trainer);
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    let r0 = Arc::new(HogwildBuffer::from_slice(&vec![2.0; p]));
    let r1 = Arc::new(HogwildBuffer::from_slice(&vec![6.0; p]));
    let stop = Arc::new(AtomicBool::new(false));
    let h0 = spawn_shadow(
        Box::new(BmufSync::new(group.clone(), 0.5, 1.0, 0.0, &vec![0.0; p])),
        r0.clone(),
        n0,
        net.clone(),
        metrics.clone(),
        stop.clone(),
        Duration::from_micros(300),
        0,
    );
    let h1 = spawn_shadow(
        Box::new(BmufSync::new(group.clone(), 0.5, 1.0, 0.0, &vec![0.0; p])),
        r1.clone(),
        n1,
        net.clone(),
        metrics.clone(),
        stop.clone(),
        Duration::from_micros(300),
        1,
    );
    std::thread::sleep(Duration::from_millis(200));
    stop.store(true, Relaxed);
    h0.join().unwrap().unwrap();
    h1.join().unwrap().unwrap();
    // both replicas converge toward the average (4.0)
    for r in [&r0, &r1] {
        let v = r.to_vec();
        let mean = v.iter().sum::<f32>() / p as f32;
        assert!((mean - 4.0).abs() < 1.0, "replica mean {mean} far from 4.0");
    }
    assert!(metrics.snapshot().syncs >= 4);
}

/// Drive `rounds` synchronized collective rounds of `strategy_for` across
/// `n` trainers and return (network, nodes, metrics) for traffic checks.
fn drive_collective_rounds<F>(
    n: usize,
    p: usize,
    rounds: u64,
    strategy_for: F,
) -> (Arc<Network>, Vec<shadowsync::net::NodeId>, Arc<Metrics>)
where
    F: Fn(usize) -> Box<dyn SyncStrategy> + Sync,
{
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    std::thread::scope(|s| {
        for (i, &node) in nodes.iter().enumerate() {
            let net = net.clone();
            let metrics = metrics.clone();
            let mut strategy = strategy_for(i);
            s.spawn(move || {
                let replica = HogwildBuffer::from_slice(&vec![i as f32; p]);
                let ctx = SyncCtx { local: &replica, trainer_node: node, net: &net, metrics: &metrics };
                for _ in 0..rounds {
                    strategy.sync_round(&ctx).unwrap();
                }
                strategy.leave();
            });
        }
    });
    (net, nodes, metrics)
}

/// Acceptance: after an MA run, trainer NIC counters carry the *measured*
/// chunked-ring traffic, matching `2·(n-1)/n · bytes` per round within one
/// chunk-segment of rounding per hop.
#[test]
fn ma_ring_traffic_lands_on_trainer_nics() {
    let (n, p, chunks, rounds) = (4usize, 10_000usize, 8usize, 25u64);
    let group = Arc::new(AllReduceGroup::new(n, p).with_chunks(chunks));
    let g = group.clone();
    let (net, nodes, metrics) =
        drive_collective_rounds(n, p, rounds, move |_| -> Box<dyn SyncStrategy> {
            Box::new(MaSync::new(g.clone(), 0.5, p))
        });
    let formula = group.ring_bytes_per_member(n) * rounds;
    assert!(formula > 0);
    // one element of rounding per chunk, per hop, per round
    let slack = rounds * 2 * (n as u64 - 1) * chunks as u64 * 4;
    let mut measured_total = 0u64;
    for &node in &nodes {
        let (tx, rx) = (net.tx(node), net.rx(node));
        assert!(
            tx.abs_diff(formula) <= slack,
            "tx {tx} vs ring formula {formula} (slack {slack})"
        );
        assert!(
            rx.abs_diff(formula) <= slack,
            "rx {rx} vs ring formula {formula} (slack {slack})"
        );
        measured_total += tx;
    }
    // the recorded sync-byte metric is exactly the measured wire traffic
    let snap = metrics.snapshot();
    assert_eq!(snap.sync_bytes, measured_total);
    assert_eq!(snap.syncs, n as u64 * rounds);
    // aggregate ring traffic is exact regardless of chunking
    assert_eq!(measured_total, 2 * (n as u64 - 1) * p as u64 * 4 * rounds);
}

/// Acceptance: delta-gated chunked EASGD pushes — recorded sync bytes
/// always equal the sync-PS NIC counters, and once the replicas converge
/// below the gate, rounds stop moving bytes entirely (both legs).
#[test]
fn delta_gated_easgd_metrics_agree_with_nic_counters() {
    let p = 96;
    let mut net = Network::new(None);
    let t = net.add_node(Role::Trainer);
    let group = Arc::new(
        SyncPsGroup::build(&vec![0.0; p], 3, &mut net).with_push_chunking(8, 1e-3),
    );
    let metrics = Metrics::new();
    let local = HogwildBuffer::from_slice(&vec![1.0; p]);
    let mut s = EasgdSync::new(group.clone(), 0.5);
    let ctx = SyncCtx { local: &local, trainer_node: t, net: &net, metrics: &metrics };
    for _ in 0..30 {
        s.sync_round(&ctx).unwrap();
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.syncs, 30);
    assert_eq!(
        net.role_bytes(Role::SyncPs),
        snap.sync_bytes,
        "metrics.sync_bytes must track the sync-PS NICs exactly"
    );
    // alpha = 0.5 with a single trainer meets central at the midpoint in
    // one round (gap -> 0), so every later round skips every chunk
    let st = group.elastic_sync_stats(&local, 0.5, t, &net);
    assert_eq!(st.bytes, 0);
    assert_eq!(st.chunks_pushed, 0);
    assert_eq!(st.chunks_skipped, (p / 8) as u64);
    let traffic = group.traffic();
    assert!(traffic.chunks_skipped > 0, "converged rounds must skip");
    assert!(traffic.push_fraction() < 1.0);
    // total bytes stayed strictly below 30 full rounds
    assert!(snap.sync_bytes < 30 * group.round_bytes());
}

/// Churn stress for the overlapped (double-buffered) engine: members leave
/// and rejoin at staggered points while rounds pipeline across the two
/// parity banks, and *every* generation's mean must stay bit-identical to a
/// single-threaded fold of its contributions in ring-position order — the
/// engine's fixed summation order survives deposit/reduce overlap and
/// membership churn.
#[test]
fn churn_with_overlapped_rounds_stays_bit_identical_to_position_order_reference() {
    let (n, p, chunks) = (6usize, 193usize, 5usize);
    let g = Arc::new(AllReduceGroup::new(n, p).with_chunks(chunks));
    assert_eq!(g.engine(), ReduceEngine::Overlapped);
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
    let net = Arc::new(net);
    let mut hs = Vec::new();
    for t in 0..n {
        let g = g.clone();
        let net = net.clone();
        let node = nodes[t];
        hs.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xC4A2 ^ t as u64);
            let my_rounds = 60 + (rng.next_u64() % 60) as usize;
            // each thread churns (leaves, then rejoins) once, at its own
            // staggered point, between two of its own rounds
            let churn_at = 5 + t * 9;
            let mut log = Vec::with_capacity(my_rounds);
            for r in 0..my_rounds {
                if r == churn_at {
                    // churn window: sit out until (bounded-wait) at least
                    // one round closed without us, then rejoin
                    let gen0 = g.completed_rounds();
                    g.leave();
                    for _ in 0..1_000_000 {
                        if g.completed_rounds() > gen0 {
                            break;
                        }
                        std::thread::yield_now();
                    }
                    g.join().expect("rejoin after leave");
                }
                // fractional values whose f32 sum is association-order
                // sensitive — any reordering would change the bits
                let v: Vec<f32> = (0..p)
                    .map(|_| (rng.next_u64() % 1_000_003) as f32 * 1e-3 - 500.0)
                    .collect();
                let mut buf = v.clone();
                let out = g.allreduce_mean(&mut buf, node, &net).unwrap();
                log.push((out.generation, out.position, out.contributors, v, buf));
            }
            g.leave();
            log
        }));
    }
    let mut by_gen: HashMap<u64, Vec<(usize, usize, Vec<f32>, Vec<f32>)>> = HashMap::new();
    for h in hs {
        for (gen, pos, parts, v, mean) in h.join().unwrap() {
            by_gen.entry(gen).or_default().push((pos, parts, v, mean));
        }
    }
    assert!(by_gen.len() >= 60, "expected 60+ generations, got {}", by_gen.len());
    let mut shrunk_rounds = 0;
    for (gen, mut entries) in by_gen {
        entries.sort_by_key(|e| e.0);
        if entries.len() < n {
            shrunk_rounds += 1; // closed while someone was churned out
        }
        // the reported contributor count is exact for every member
        for (pos, parts, _, _) in &entries {
            assert_eq!(*parts, entries.len(), "gen {gen} pos {pos}");
        }
        // bit-identical to the position-order fold
        let mut reference = entries[0].2.clone();
        for e in &entries[1..] {
            for (acc, &x) in reference.iter_mut().zip(&e.2) {
                *acc += x;
            }
        }
        let inv = 1.0 / entries.len() as f32;
        for acc in reference.iter_mut() {
            *acc *= inv;
        }
        for (pos, _, _, mean) in &entries {
            for (a, b) in mean.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "gen {gen} pos {pos}: {a} != {b}");
            }
        }
    }
    assert_eq!(g.active(), 0);
    // churn must actually have produced shrunken rounds for this test to
    // mean anything (6 staggered leave/rejoin windows over ~60 rounds)
    assert!(shrunk_rounds > 0, "no round ever closed during a churn window");
}

/// Acceptance: the adaptive quantile gate + dirty-epoch scan skips keep
/// `metrics.sync_bytes` exactly equal to the sync-PS NIC counters, and the
/// live skip-rate metric reflects the gate's decisions.
#[test]
fn adaptive_gate_with_dirty_epochs_tracks_nic_counters_exactly() {
    let p = 256;
    let chunk = 16;
    let mut net = Network::new(None);
    let t = net.add_node(Role::Trainer);
    let group = Arc::new(
        SyncPsGroup::build(&vec![0.0; p], 2, &mut net)
            .with_push_chunking(chunk, 0.0)
            .with_adaptive_gate(0.5),
    );
    let metrics = Metrics::new();
    let local = HogwildBuffer::from_slice(&vec![0.0; p]).with_dirty_epochs(chunk);
    let mut s = EasgdSync::new(group.clone(), 0.4);
    let ctx = SyncCtx { local: &local, trainer_node: t, net: &net, metrics: &metrics };
    let mut rng = Rng::new(0xD1A7);
    for round in 0..50 {
        // perturb a few random subranges between rounds (workers writing)
        for _ in 0..(round % 4) {
            let lo = (rng.next_u64() as usize) % (p - 8);
            let noise: Vec<f32> = (0..8).map(|_| rng.u01() - 0.5).collect();
            local.axpy_range(lo, 0.3, &noise);
        }
        s.sync_round(&ctx).unwrap();
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.syncs, 50);
    assert_eq!(
        net.role_bytes(Role::SyncPs),
        snap.sync_bytes,
        "metrics.sync_bytes must track the sync-PS NICs exactly under \
         adaptive gating + dirty-epoch skips"
    );
    let traffic = group.traffic();
    assert_eq!(traffic.bytes_moved, snap.sync_bytes);
    // the chunk counters flow identically through metrics and the snapshot
    assert_eq!(snap.sync_chunks_pushed, traffic.chunks_pushed);
    assert_eq!(snap.sync_chunks_skipped, traffic.chunks_skipped);
    assert_eq!(snap.sync_scan_skipped, traffic.chunks_scan_skipped);
    // the adaptive gate engaged (post-warmup rounds skip), and idle chunks
    // exercised the dirty-epoch scan fast path
    assert!(traffic.chunks_skipped > 0, "adaptive gate never skipped");
    assert!(traffic.chunks_scan_skipped > 0, "dirty epochs never skipped a scan");
    assert!(snap.sync_skip_rate() > 0.0);
}

/// Same acceptance check for BMUF, on a flat (single-chunk) ring.
#[test]
fn bmuf_ring_traffic_lands_on_trainer_nics() {
    let (n, p, rounds) = (3usize, 9_999usize, 10u64);
    let group = Arc::new(AllReduceGroup::new(n, p));
    let g = group.clone();
    let (net, nodes, _metrics) = drive_collective_rounds(n, p, rounds, move |_| -> Box<dyn SyncStrategy> {
        Box::new(BmufSync::new(g.clone(), 0.5, 1.0, 0.0, &vec![0.0; p]))
    });
    let formula = group.ring_bytes_per_member(n) * rounds;
    let slack = rounds * 2 * (n as u64 - 1) * 4; // flat: one segment's rounding
    for &node in &nodes {
        assert!(
            net.tx(node).abs_diff(formula) <= slack,
            "tx {} vs ring formula {formula}",
            net.tx(node)
        );
    }
}
