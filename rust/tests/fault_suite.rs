//! Chaos suite for the fault-injection fabric: real shadow pools + crash
//! watchdog + elastic rejoin under a seeded [`FaultPlan`], with the two
//! invariants the fabric promises under faults checked end-to-end —
//!
//! 1. **byte exactness**: `metrics.sync_bytes` equals the summed sync-PS
//!    NIC counters plus the ring tx, no matter which transfers a plan
//!    crashed or dropped (faulted legs count on *neither* side);
//! 2. **no membership leaks**: every collective group of the final epoch
//!    is fully vacated — by strategy `leave()`s, watchdog proxy-departs,
//!    or pending-epoch vacation — never doubly, never not at all.
//!
//! The first test is parameterized by environment so CI can run it as a
//! seed × plan matrix:
//!
//! ```text
//! SHADOWSYNC_FAULT_PLAN="crash:t1@sweep20" SHADOWSYNC_PROPTEST_SEED=7 \
//!     cargo test --release --test fault_suite
//! ```

use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::Arc;
use std::time::Duration;

use shadowsync::config::{RunConfig, SyncAlgo};
use shadowsync::metrics::Metrics;
use shadowsync::net::fault::FaultPlan;
use shadowsync::net::{Network, Role};
use shadowsync::sync::driver::{spawn_shadow_pool_adaptive, ShadowTask};
use shadowsync::sync::{
    build_group, build_strategy, AllReduceGroup, HealthController, PartitionPlan,
    RepartitionController, SyncPsGroup,
};
use shadowsync::tensor::HogwildBuffer;
use shadowsync::util::rng::Rng;

const LEN: usize = 4096;
const CHUNK: usize = 64;

/// Everything a chaos run leaves behind for assertions.
struct Chaos {
    rounds: u64,
    net: Arc<Network>,
    metrics: Arc<Metrics>,
    controller: Arc<RepartitionController>,
    health: Arc<HealthController>,
    nodes: Vec<shadowsync::net::NodeId>,
    /// roster size sampled just before stop (terminal exits depart the
    /// controller for everyone, so post-join `active()` is always 0)
    mid_active: usize,
    mid_departs: u64,
}

/// The full fabric under a fault plan: `n` trainers × `shadow_threads`
/// pool workers over a partitioned EASGD/MA fabric, a repartition + health
/// controller pair, the crash watchdog, and writer threads standing in for
/// training workers (they dirty the replica, stamp heartbeats, and honor
/// the plan's crash/stall windows exactly like `trainer::run_trainer`).
fn run_chaos(cfg: &RunConfig, faults: Arc<FaultPlan>, run: Duration) -> Chaos {
    let n = cfg.num_trainers;
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
    let w0 = vec![0.0f32; LEN];
    // mirror the coordinator's wiring: retry knobs from the config, and —
    // when a heartbeat watchdog is armed — a summed-backoff budget of half
    // the timeout, so retry sleeps can never starve the heartbeat
    let mut group = SyncPsGroup::build(&w0, 2, &mut net)
        .with_push_chunking(CHUNK, cfg.delta_threshold)
        .with_push_retry(cfg.push_retries, Duration::from_millis(cfg.push_backoff_ms));
    if cfg.heartbeat_timeout_ms > 0 {
        group =
            group.with_push_backoff_budget(Duration::from_millis(cfg.heartbeat_timeout_ms) / 2);
    }
    let sync_ps = Arc::new(group);
    let net = Arc::new(net.with_faults(faults.clone()));
    let plan = PartitionPlan::build(LEN, cfg).unwrap();
    let groups: Vec<Option<Arc<AllReduceGroup>>> = plan
        .partitions
        .iter()
        .map(|p| match p.algo {
            SyncAlgo::Ma | SyncAlgo::Bmuf => Some(build_group(cfg, p.index, p.range.len)),
            _ => None,
        })
        .collect();
    let controller = Arc::new(RepartitionController::new(
        cfg,
        LEN,
        Some(sync_ps.clone()),
        plan.clone(),
        groups.clone(),
    ));
    let health = Arc::new(HealthController::new(cfg, controller.clone()));
    let wd_stop = Arc::new(AtomicBool::new(false));
    let watchdog = health.spawn_watchdog(wd_stop.clone());
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));
    let mut pools = Vec::new();
    let mut writers = Vec::new();
    for (t, &node) in nodes.iter().enumerate() {
        let replica = Arc::new(
            HogwildBuffer::from_slice(&vec![t as f32 + 1.0; LEN]).with_dirty_epochs(CHUNK),
        );
        let tasks: Vec<ShadowTask> = plan
            .partitions
            .iter()
            .map(|p| ShadowTask {
                partition: p.index,
                range: p.range,
                strategy: build_strategy(
                    cfg,
                    p,
                    t,
                    &w0,
                    Some(sync_ps.clone()),
                    groups[p.index].clone(),
                )
                .unwrap(),
            })
            .collect();
        pools.push(spawn_shadow_pool_adaptive(
            tasks,
            replica.clone(),
            node,
            net.clone(),
            metrics.clone(),
            stop.clone(),
            Duration::ZERO,
            t,
            cfg.shadow_threads,
            Some(controller.clone()),
            Some(health.clone()),
        ));
        // training stand-in: dirty the replica and stamp heartbeats,
        // honoring crash windows (a crashed trainer goes silent — the
        // pool's dark loop keeps the sweep clock ticking, not us) and
        // stall windows (capped at 5ms/lap so the suite stays fast; a
        // capped straggler still beats, which is the point — stalls are
        // not crashes)
        let stop = stop.clone();
        let faults = faults.clone();
        let health = health.clone();
        writers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xFA07 ^ t as u64);
            while !stop.load(Relaxed) {
                if faults.crashed(t) {
                    std::thread::sleep(Duration::from_millis(1));
                    continue;
                }
                if let Some(d) = faults.lap_delay(t) {
                    std::thread::sleep(d.min(Duration::from_millis(5)));
                }
                let lo = (rng.next_u64() as usize) % (LEN - 32);
                let noise: Vec<f32> = (0..32).map(|_| rng.u01() - 0.5).collect();
                replica.axpy_range(lo, 0.3, &noise);
                health.note_lap(t);
                std::thread::sleep(Duration::from_micros(100));
            }
        }));
    }
    std::thread::sleep(run);
    let mid_departs = health.departs();
    let mid_active = controller.active();
    stop.store(true, Relaxed);
    let mut rounds = 0u64;
    for p in pools {
        rounds += p.join().unwrap().unwrap();
    }
    for w in writers {
        w.join().unwrap();
    }
    // like the coordinator: the watchdog outlives the pools, so a trainer
    // crashed right at stop is still proxy-departed, never deadlocked on
    wd_stop.store(true, Relaxed);
    watchdog.join().unwrap();
    Chaos { rounds, net, metrics, controller, health, nodes, mid_active, mid_departs }
}

/// The CI chaos matrix entry: run whatever `SHADOWSYNC_FAULT_PLAN` +
/// `SHADOWSYNC_PROPTEST_SEED` + `SHADOWSYNC_REDUCE_ENGINE` name (defaults:
/// a permanent single-trainer crash, seed 7, the overlapped engine)
/// through the full fabric and check both invariants.
#[test]
fn chaos_plan_preserves_byte_exactness_and_membership() {
    let spec = std::env::var("SHADOWSYNC_FAULT_PLAN")
        .unwrap_or_else(|_| "crash:t1@sweep20".into());
    let seed: u64 = std::env::var("SHADOWSYNC_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7);
    let engine = std::env::var("SHADOWSYNC_REDUCE_ENGINE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(RunConfig::default().reduce_engine);
    let faults = Arc::new(FaultPlan::parse(&spec, seed).expect("CI plan must parse"));
    let n = faults.trainers_referenced().max(2);
    // drop plans run an all-EASGD fabric so the push-retry path is what
    // the drops exercise; everything else gets the hybrid EASGD+MA fabric
    let drops = spec.contains("drop");
    let cfg = RunConfig {
        num_trainers: n,
        sync_partitions: 4,
        shadow_threads: 2,
        easgd_chunk_elems: CHUNK,
        delta_threshold: 1e-4,
        repartition_every: 40,
        algo: SyncAlgo::Easgd,
        algo_map: (!drops).then(|| "easgd:0-2,ma:3".parse().unwrap()),
        heartbeat_timeout_ms: 40,
        reduce_engine: engine,
        ..RunConfig::default()
    };
    let c = run_chaos(&cfg, faults.clone(), Duration::from_millis(400));

    let permanent: Vec<usize> = (0..n).filter(|&t| faults.crashes_permanently(t)).collect();
    if permanent.len() < n {
        assert!(c.rounds > 0, "survivors never completed a sync round");
    }
    for &t in &permanent {
        assert!(
            c.health.is_departed(t),
            "permanently crashed trainer {t} was never departed by the watchdog"
        );
    }
    assert!(
        c.mid_departs >= permanent.len() as u64,
        "watchdog caught {} crashes, plan schedules {} permanent ones",
        c.mid_departs,
        permanent.len()
    );
    assert!(
        c.mid_active <= n - permanent.len(),
        "roster still counts permanently crashed trainers"
    );
    if spec.contains("drop") {
        assert!(faults.dropped_bytes() > 0, "a drop plan that dropped nothing proved nothing");
    }
    // invariant 1: byte exactness under whatever the plan faulted
    let snap = c.metrics.snapshot();
    let trainer_tx: u64 = c.nodes.iter().map(|&nd| c.net.tx(nd)).sum();
    let ring_tx = trainer_tx - c.net.role_rx(Role::SyncPs);
    assert_eq!(
        snap.sync_bytes,
        c.net.role_bytes(Role::SyncPs) + ring_tx,
        "metrics.sync_bytes diverged from the NIC counters under plan `{spec}` (seed {seed})"
    );
    assert!(snap.syncs > 0);
    assert_eq!(snap.partition_syncs.len(), 4);
    for (i, &s) in snap.partition_syncs.iter().enumerate() {
        assert!(s > 0, "partition {i} starved under plan `{spec}`: {:?}", snap.partition_syncs);
    }
    // invariant 2: no membership leaks in the final epoch's groups
    for g in c.controller.current_epoch().groups.iter().flatten() {
        assert_eq!(g.active(), 0, "leaked collective membership under plan `{spec}`");
    }
}

/// The ISSUE's pinned scenario, deterministically: a trainer crashes while
/// a repartition generation is *pending* (published, not yet adopted). Its
/// slots in the pending epoch's groups must be vacated, the survivor
/// adopts without blocking on the ghost, the next rebuild sizes groups to
/// the real roster, and the crashed trainer rejoins cleanly afterward.
#[test]
fn crash_during_pending_repartition_vacates_the_generation() {
    let cfg = RunConfig {
        num_trainers: 2,
        sync_partitions: 2,
        shadow_threads: 1,
        easgd_chunk_elems: 8,
        algo: SyncAlgo::Ma,
        num_sync_ps: 0,
        heartbeat_timeout_ms: 50,
        ..RunConfig::default()
    };
    let len = 128;
    let plan = PartitionPlan::build(len, &cfg).unwrap();
    let groups: Vec<Option<Arc<AllReduceGroup>>> = plan
        .partitions
        .iter()
        .map(|p| match p.algo {
            SyncAlgo::Ma | SyncAlgo::Bmuf => Some(build_group(&cfg, p.index, p.range.len)),
            _ => None,
        })
        .collect();
    let ctrl = Arc::new(RepartitionController::new(&cfg, len, None, plan, groups));
    let health = HealthController::new(&cfg, ctrl.clone());
    let epoch0 = ctrl.current_epoch();
    // a generation goes pending: published, nobody has adopted it yet
    assert!(ctrl.force_rebuild());
    let pending = ctrl.current_epoch();
    assert_eq!(pending.gen, 1);
    for g in pending.groups.iter().flatten() {
        assert_eq!(g.active(), 2, "pending groups are pre-sized to the full roster");
    }
    // trainer 1 crashes NOW — before anyone adopted the pending epoch
    assert!(health.depart_trainer(1));
    assert_eq!(ctrl.active(), 1);
    assert_eq!(health.departs(), 1);
    // its adopted-epoch groups were proxy-left...
    for g in epoch0.groups.iter().flatten() {
        assert_eq!(g.active(), 1, "the crash must proxy-leave the adopted epoch's rings");
    }
    // ...and its slots in the PENDING generation were vacated too, so the
    // survivor's rounds on the new fabric never wait on the ghost
    for g in pending.groups.iter().flatten() {
        assert_eq!(g.active(), 1, "the pending generation must be vacated by the depart");
    }
    // the survivor adopts the (vacated) pending epoch normally
    let e1 = ctrl.adopt(0);
    health.note_adopt(0, &e1);
    assert_eq!(ctrl.repartitions(), 1);
    // with the ghost gone, the adoption gate opens on the survivor alone:
    // the next rebuild sizes fresh groups to the real roster
    assert!(ctrl.force_rebuild());
    let solo = ctrl.current_epoch();
    assert_eq!(solo.gen, 2);
    for g in solo.groups.iter().flatten() {
        assert_eq!(g.active(), 1, "post-crash rebuilds must size groups to the survivors");
    }
    let e2 = ctrl.adopt(1);
    health.note_adopt(0, &e2);
    // the crash window closes: elastic rejoin grows the roster back
    let e3 = ctrl.rejoin().expect("rejoin must succeed once the survivor adopted");
    health.mark_rejoined(1, &e3);
    assert!(!health.is_departed(1));
    assert_eq!(ctrl.active(), 2);
    assert_eq!(e3.gen, 3);
    for g in e3.groups.iter().flatten() {
        assert_eq!(g.active(), 2, "the rejoin epoch is sized for the grown roster");
    }
}

/// Transient crash end-to-end: the trainer goes dark mid-run, the watchdog
/// departs it, its window closes, and the pool rejoins elastically —
/// roster restored, byte accounting exact, no leaked memberships.
#[test]
fn transient_crash_departs_then_rejoins() {
    // crash at sweep 10 for 150 sweeps: the dark loop ticks the sweep
    // clock at ~1ms/lap, so the trainer is gone for ~150ms of a 500ms run
    // — long past the 25ms heartbeat timeout, with ample time to rejoin
    let faults = Arc::new(FaultPlan::parse("crash:t0@sweep10+150", 11).unwrap());
    let cfg = RunConfig {
        num_trainers: 2,
        sync_partitions: 2,
        shadow_threads: 2,
        easgd_chunk_elems: CHUNK,
        delta_threshold: 1e-4,
        algo: SyncAlgo::Easgd,
        algo_map: Some("easgd:0,ma:1".parse().unwrap()),
        heartbeat_timeout_ms: 25,
        ..RunConfig::default()
    };
    let c = run_chaos(&cfg, faults, Duration::from_millis(500));
    assert!(c.rounds > 0);
    assert_eq!(c.mid_departs, 1, "exactly one depart: the crash, caught once");
    assert_eq!(c.mid_active, 2, "the rejoin must restore the full roster");
    assert!(
        c.controller.repartitions() >= 1,
        "the rejoin publishes (and the survivor adopts) a fresh generation"
    );
    let snap = c.metrics.snapshot();
    let trainer_tx: u64 = c.nodes.iter().map(|&nd| c.net.tx(nd)).sum();
    let ring_tx = trainer_tx - c.net.role_rx(Role::SyncPs);
    assert_eq!(
        snap.sync_bytes,
        c.net.role_bytes(Role::SyncPs) + ring_tx,
        "byte accounting must stay exact across depart + rejoin"
    );
    for g in c.controller.current_epoch().groups.iter().flatten() {
        assert_eq!(g.active(), 0, "leaked collective membership across a rejoin");
    }
}

/// The embedding tier under a lossy fabric: `metrics.embedding_bytes`
/// must equal the embedding-PS NIC counters *exactly* while a seeded plan
/// drops half the transfers touching the trainer — a dropped up-leg
/// suppresses its down-leg, and neither ledger moves for a faulted leg.
/// Cache hits, prefetches, and a mid-run hot-key rebalance are all in the
/// mix (the rebalance's PS↔PS migrations don't touch the trainer, so the
/// drop plan never intercepts them — but they land on both ledgers too).
#[test]
fn embedding_drop_plan_keeps_byte_ledger_exact() {
    use shadowsync::config::{EmbeddingConfig, ModelMeta};
    use shadowsync::embedding::{EmbCache, EmbeddingSystem};

    let meta = ModelMeta::parse(
        r#"{
      "batch": 4, "bot_mlp": [16, 8], "emb_dim": 8,
      "name": "t", "num_dense": 4, "num_feats": 5, "num_interactions": 10,
      "num_params": 537, "num_tables": 4, "seed": 1, "top_mlp": [16]
    }"#,
    )
    .unwrap();
    let mut net = Network::new(None);
    let trainer = net.add_node(Role::Trainer);
    let emb = EmbeddingConfig { rows_per_table: 80, ..Default::default() };
    let sys = EmbeddingSystem::build(&meta, &emb, 3, &mut net, 9).unwrap();
    let faults = Arc::new(FaultPlan::parse("drop:t0@0.5", 31).unwrap());
    let net = net.with_faults(faults.clone());
    let m = Metrics::new();
    let cache = EmbCache::new(64);
    let (d, l, t_count, batch) = (sys.dim, sys.indices_per_feature, sys.num_tables(), 4);
    let mut rng = Rng::new(0xE0B);
    let mut out = vec![0f32; batch * t_count * d];
    let grad = vec![0.1f32; batch * t_count * d];
    for i in 0..40 {
        let idx: Vec<Vec<u32>> = (0..t_count)
            .map(|_| (0..batch * l).map(|_| rng.below(80) as u32).collect())
            .collect();
        let keys: Vec<(usize, u32)> = idx
            .iter()
            .enumerate()
            .flat_map(|(t, v)| v.iter().map(move |&r| (t, r)))
            .collect();
        sys.prefetch_rows(&cache, &keys, trainer, &net, &m);
        sys.lookup_batch_cached(&cache, &idx, batch, &mut out, trainer, &net, &m);
        sys.update_batch(&idx, batch, &grad, trainer, &net, &m);
        if i == 20 {
            sys.rebalance(&net, &m);
        }
    }
    assert!(faults.dropped_bytes() > 0, "a 50% drop plan must actually drop");
    assert_eq!(
        m.snapshot().embedding_bytes,
        net.role_bytes(Role::EmbeddingPs),
        "embedding byte accounting diverged from the NIC counters under drops"
    );
}

/// Regression for the retry/backoff bug: a push leg's *summed* doubling
/// backoff sleeps were unbounded — under a drop-heavy plan with generous
/// retry settings a single exhausted leg slept for tens of seconds, far
/// past any heartbeat timeout, wedging its shadow-pool thread (and at
/// shutdown, the whole run) inside `thread::sleep`. The backoff budget
/// caps the summed sleeps per leg at half `--heartbeat-timeout-ms`, so
/// sync degrades to skipped chunks instead, nobody is spuriously departed,
/// and the run winds down promptly.
#[test]
fn drop_heavy_retries_never_spuriously_depart_a_healthy_trainer() {
    // p=0.6 drops on t0, no crashes anywhere: every depart is spurious.
    // 12 retries × 10ms doubling backoff would sleep ~41s per exhausted
    // leg uncapped — three orders past the 60ms heartbeat timeout. With
    // the budget, no leg sleeps more than 30ms total.
    let faults = Arc::new(FaultPlan::parse("drop:t0@0.6", 13).unwrap());
    let cfg = RunConfig {
        num_trainers: 2,
        sync_partitions: 2,
        shadow_threads: 2,
        easgd_chunk_elems: CHUNK,
        algo: SyncAlgo::Easgd,
        push_retries: 12,
        push_backoff_ms: 10,
        heartbeat_timeout_ms: 60,
        ..RunConfig::default()
    };
    let started = std::time::Instant::now();
    let c = run_chaos(&cfg, faults.clone(), Duration::from_millis(400));
    assert!(
        started.elapsed() < Duration::from_secs(10),
        "uncapped backoff: a 400ms run spent {:?} draining retry sleeps",
        started.elapsed()
    );
    assert!(c.rounds > 0, "the fabric must keep syncing through the drops");
    assert!(faults.dropped_bytes() > 0, "a 60% drop rate must actually drop");
    assert_eq!(
        c.mid_departs, 0,
        "no trainer crashed — any depart here is spurious (retry sleeps outliving the watchdog)"
    );
    assert_eq!(c.mid_active, cfg.num_trainers, "the roster must stay whole");
    // and the retried/abandoned legs never bent the byte accounting
    let snap = c.metrics.snapshot();
    let trainer_tx: u64 = c.nodes.iter().map(|&nd| c.net.tx(nd)).sum();
    let ring_tx = trainer_tx - c.net.role_rx(Role::SyncPs);
    assert_eq!(snap.sync_bytes, c.net.role_bytes(Role::SyncPs) + ring_tx);
}
