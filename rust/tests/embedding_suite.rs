//! Embedding-tier property/coherence suite: the sharded PS tier, the
//! versioned row cache, and the lookahead pipeline, locked down end to end.
//!
//! The invariants (ISSUE acceptance, asserted bitwise where it matters):
//!
//! - cached/prefetched lookups are **bit-identical** to uncached pooling,
//!   including under concurrent Hogwild updates to disjoint rows;
//! - rendezvous placement moves only the minimal bucket set on PS
//!   retirement/revival, and revival converges back to the original
//!   placement;
//! - dedup'd lookahead batches pool to the same sums as naive per-batch
//!   lookups while moving strictly fewer bytes;
//! - `metrics.embedding_bytes` equals the embedding-PS NIC counters
//!   exactly under any interleaving of cached lookups, prefetches,
//!   updates, rebalances, and roster changes;
//! - a checkpoint written after a hot-key rebalance reloads bit-equal into
//!   a system with a different roster/bucketing.
//!
//! `SHADOWSYNC_EMB_CACHE` (CI stress axis) overrides the cache capacity in
//! the concurrency test — 0 degrades the cache to a pure pass-through,
//! which must *still* be bit-identical.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use shadowsync::config::{EmbeddingConfig, ModelMeta};
use shadowsync::data::Batch;
use shadowsync::embedding::{EmbCache, EmbeddingSystem, Lookahead};
use shadowsync::metrics::Metrics;
use shadowsync::net::{Network, NodeId, Role};
use shadowsync::util::proptest::check;

fn meta() -> ModelMeta {
    ModelMeta::parse(
        r#"{
      "batch": 4, "bot_mlp": [16, 8], "emb_dim": 8,
      "name": "t", "num_dense": 4, "num_feats": 5, "num_interactions": 10,
      "num_params": 537, "num_tables": 4, "seed": 1, "top_mlp": [16]
    }"#,
    )
    .unwrap()
}

fn system(num_ps: usize, rows: usize, seed: u64) -> (EmbeddingSystem, Network, NodeId, Metrics) {
    let mut net = Network::new(None);
    let trainer = net.add_node(Role::Trainer);
    let emb = EmbeddingConfig { rows_per_table: rows, ..Default::default() };
    let sys = EmbeddingSystem::build(&meta(), &emb, num_ps, &mut net, seed).unwrap();
    (sys, net, trainer, Metrics::new())
}

/// CI stress axis: cache capacity for the concurrency test (0 = cache
/// effectively off; correctness must not depend on it).
fn cache_capacity() -> usize {
    std::env::var("SHADOWSYNC_EMB_CACHE").ok().and_then(|s| s.parse().ok()).unwrap_or(1024)
}

#[test]
fn cached_lookups_are_bit_identical_under_concurrent_hogwild_updates() {
    // 64 rows over 2 PSs = 2 buckets of 32: updater threads hammer rows
    // [32, 64) (bucket 1) while the main thread pools rows [0, 32)
    // (bucket 0) — disjoint rows, so every looked-up signature is stable
    // and the cached result must equal the live tables bit for bit.
    let (sys, net, tr, m) = system(2, 64, 11);
    let (sys, net, m) = (Arc::new(sys), Arc::new(net), Arc::new(m));
    let cache = EmbCache::new(cache_capacity());
    let (d, l, t_count, batch) = (sys.dim, sys.indices_per_feature, sys.num_tables(), 4);

    let stop = Arc::new(AtomicBool::new(false));
    let updaters: Vec<_> = (0..2)
        .map(|u| {
            let (sys, net, m, stop) = (sys.clone(), net.clone(), m.clone(), stop.clone());
            std::thread::spawn(move || {
                let idx: Vec<Vec<u32>> = (0..t_count)
                    .map(|t| {
                        (0..batch * l).map(|k| (32 + (t * 13 + k * 5 + u) % 32) as u32).collect()
                    })
                    .collect();
                let grad = vec![0.1f32; batch * t_count * d];
                while !stop.load(Ordering::Relaxed) {
                    sys.update_batch(&idx, batch, &grad, tr, &net, &m);
                }
            })
        })
        .collect();

    let idx: Vec<Vec<u32>> = (0..t_count)
        .map(|t| (0..batch * l).map(|k| ((t * 31 + k * 7) % 32) as u32).collect())
        .collect();
    let mut plain = vec![0f32; batch * t_count * d];
    let mut cached = vec![0f32; batch * t_count * d];
    for _ in 0..50 {
        sys.lookup_batch(&idx, batch, &mut plain, tr, &net, &m);
        sys.lookup_batch_cached(&cache, &idx, batch, &mut cached, tr, &net, &m);
        for (p, c) in plain.iter().zip(&cached) {
            assert_eq!(
                p.to_bits(),
                c.to_bits(),
                "cached pooling diverged from the live tables under concurrent updates"
            );
        }
    }
    stop.store(true, Ordering::Relaxed);
    for u in updaters {
        u.join().unwrap();
    }
    // the byte ledger stayed exact through the concurrent churn
    assert_eq!(m.snapshot().embedding_bytes, net.role_bytes(Role::EmbeddingPs));
}

#[test]
fn roster_changes_move_only_the_minimal_bucket_set() {
    check("emb-roster-minimal", 15, |g| {
        let num_ps = g.usize_in(3, 5);
        let rows = g.usize_in(40, 400);
        let (sys, net, _tr, m) = system(num_ps, rows, g.rng.next_u64());
        let hosts: Vec<NodeId> = sys.shards().map(|s| s.ps_node()).collect();
        let idx = g.usize_in(0, num_ps - 1);
        let retired = sys.ps_nodes[idx];
        let v0 = sys.placement_version();
        sys.retire_ps(idx, &net, &m);
        for (s, &h0) in sys.shards().zip(&hosts) {
            if h0 == retired {
                assert_ne!(s.ps_node(), retired, "retired PS still hosts a bucket");
            } else {
                assert_eq!(s.ps_node(), h0, "a surviving PS's bucket moved on retire");
            }
        }
        assert!(sys.placement_version() > v0, "a roster change must bump the version");
        // revival pulls back exactly the buckets the revived token wins —
        // with no rebalance in between, that is the original rendezvous
        // placement, bucket for bucket
        sys.restore_ps(idx, &net, &m);
        for (s, &h0) in sys.shards().zip(&hosts) {
            assert_eq!(s.ps_node(), h0, "restore did not converge to the rendezvous placement");
        }
        assert_eq!(m.snapshot().embedding_bytes, net.role_bytes(Role::EmbeddingPs));
    });
}

/// A batch whose ids all land in the 8-row power-law head, varying with
/// `salt` so consecutive batches overlap heavily but are not identical.
fn hot_batch(m: &ModelMeta, emb: &EmbeddingConfig, salt: u32) -> Batch {
    let mut b = Batch::empty(m, emb);
    for (t, idx) in b.indices.iter_mut().enumerate() {
        for (k, v) in idx.iter_mut().enumerate() {
            *v = (t as u32 * 5 + k as u32 * 3 + salt) % 8;
        }
    }
    b
}

#[test]
fn lookahead_dedup_pools_the_same_sums_with_fewer_bytes() {
    let m = meta();
    let emb = EmbeddingConfig { rows_per_table: 64, ..Default::default() };
    let batches: Vec<Batch> = (0..6).map(|i| hot_batch(&m, &emb, i)).collect();
    let batch = m.batch;
    let out_len = batch * m.num_tables * m.emb_dim;

    // naive arm: every batch round-trips to the PSs
    let mut net_n = Network::new(None);
    let tr_n = net_n.add_node(Role::Trainer);
    let sys_n = EmbeddingSystem::build(&m, &emb, 2, &mut net_n, 21).unwrap();
    let m_n = Metrics::new();
    let mut naive_out = Vec::new();
    for b in &batches {
        let mut out = vec![0f32; out_len];
        sys_n.lookup_batch(&b.indices, batch, &mut out, tr_n, &net_n, &m_n);
        naive_out.push(out);
    }

    // lookahead arm: same seed (identical initial tables), batches flow
    // through a depth-2 window that prefetches the deduped id union
    let mut net_l = Network::new(None);
    let tr_l = net_l.add_node(Role::Trainer);
    let sys_l = EmbeddingSystem::build(&m, &emb, 2, &mut net_l, 21).unwrap();
    let m_l = Metrics::new();
    let cache = EmbCache::new(256);
    let (tx, rx) = channel();
    for b in &batches {
        tx.send(b.clone()).unwrap();
    }
    drop(tx);
    let mut la = Lookahead::new(Arc::new(Mutex::new(rx)), 2);
    let mut i = 0;
    while let Some(b) = la.next(&sys_l, &cache, tr_l, &net_l, &m_l) {
        let mut out = vec![0f32; out_len];
        sys_l.lookup_batch_cached(&cache, &b.indices, batch, &mut out, tr_l, &net_l, &m_l);
        for (x, y) in out.iter().zip(&naive_out[i]) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "lookahead batch {i} pooled different bits than the naive path"
            );
        }
        i += 1;
    }
    assert_eq!(i, batches.len(), "the window must drain every batch");
    assert!(la.prefetched() > 0, "the window never prefetched");

    // both ledgers exact, and the deduped pipeline moved strictly fewer
    // bytes than six naive round-trips over the same hot rows
    assert_eq!(m_n.snapshot().embedding_bytes, net_n.role_bytes(Role::EmbeddingPs));
    assert_eq!(m_l.snapshot().embedding_bytes, net_l.role_bytes(Role::EmbeddingPs));
    assert!(
        net_l.role_bytes(Role::EmbeddingPs) < net_n.role_bytes(Role::EmbeddingPs),
        "dedup'd lookahead moved {} bytes, naive {}",
        net_l.role_bytes(Role::EmbeddingPs),
        net_n.role_bytes(Role::EmbeddingPs)
    );
}

#[test]
fn byte_ledger_is_exact_under_random_cache_prefetch_and_migration_traffic() {
    check("emb-byte-exact", 10, |g| {
        let num_ps = g.usize_in(2, 4);
        let rows = g.usize_in(32, 200);
        let (sys, net, tr, m) = system(num_ps, rows, g.rng.next_u64());
        let cache = EmbCache::new(g.usize_in(0, 64));
        let (d, l, t_count, batch) = (sys.dim, sys.indices_per_feature, sys.num_tables(), 4);
        let mut out = vec![0f32; batch * t_count * d];
        let grad = vec![0.05f32; batch * t_count * d];
        for _ in 0..g.usize_in(5, 20) {
            let idx: Vec<Vec<u32>> = (0..t_count)
                .map(|_| (0..batch * l).map(|_| g.rng.below(rows as u64) as u32).collect())
                .collect();
            match g.usize_in(0, 5) {
                0 => sys.lookup_batch(&idx, batch, &mut out, tr, &net, &m),
                1 | 2 => sys.lookup_batch_cached(&cache, &idx, batch, &mut out, tr, &net, &m),
                3 => {
                    let keys: Vec<(usize, u32)> = idx
                        .iter()
                        .enumerate()
                        .flat_map(|(t, v)| v.iter().map(move |&r| (t, r)))
                        .collect();
                    sys.prefetch_rows(&cache, &keys, tr, &net, &m);
                }
                4 => sys.update_batch(&idx, batch, &grad, tr, &net, &m),
                _ => {
                    sys.rebalance(&net, &m);
                }
            }
        }
        sys.retire_ps(g.usize_in(0, num_ps - 1), &net, &m);
        assert_eq!(
            m.snapshot().embedding_bytes,
            net.role_bytes(Role::EmbeddingPs),
            "metrics and NIC ledgers diverged (cache capacity {})",
            cache.len()
        );
    });
}

#[test]
fn placement_changes_invalidate_cached_rows() {
    let (sys, net, tr, m) = system(3, 60, 5);
    let cache = EmbCache::new(256);
    let (d, l, t_count, batch) = (sys.dim, sys.indices_per_feature, sys.num_tables(), 4);
    let idx: Vec<Vec<u32>> = (0..t_count)
        .map(|t| (0..batch * l).map(|k| ((t * 7 + k) % 60) as u32).collect())
        .collect();
    let mut out = vec![0f32; batch * t_count * d];
    sys.lookup_batch_cached(&cache, &idx, batch, &mut out, tr, &net, &m); // warm
    assert!(!cache.is_empty());
    sys.lookup_batch_cached(&cache, &idx, batch, &mut out, tr, &net, &m);
    assert!(cache.stats().hits > 0, "a repeated lookup over an idle table must hit");

    let inv0 = cache.stats().invalidations;
    sys.retire_ps(0, &net, &m); // topology change: version bump
    let mut cached = vec![0f32; batch * t_count * d];
    sys.lookup_batch_cached(&cache, &idx, batch, &mut cached, tr, &net, &m);
    assert!(
        cache.stats().invalidations > inv0,
        "stale-version entries must be evicted, not served"
    );
    // the refetched pooling still equals the uncached truth bit for bit
    let mut plain = vec![0f32; batch * t_count * d];
    sys.lookup_batch(&idx, batch, &mut plain, tr, &net, &m);
    for (p, c) in plain.iter().zip(&cached) {
        assert_eq!(p.to_bits(), c.to_bits());
    }
    assert_eq!(m.snapshot().embedding_bytes, net.role_bytes(Role::EmbeddingPs));
}

#[test]
fn checkpoint_round_trip_after_hot_key_rebalance_is_bit_equal() {
    let (sys, net, tr, m) = system(3, 96, 17);
    let (d, l, t_count, batch) = (sys.dim, sys.indices_per_feature, sys.num_tables(), 4);
    // drift the weights off init and skew the hot-key stats onto the head
    let idx: Vec<Vec<u32>> =
        (0..t_count).map(|t| (0..batch * l).map(|k| ((t + k) % 16) as u32).collect()).collect();
    let mut out = vec![0f32; batch * t_count * d];
    let grad = vec![0.2f32; batch * t_count * d];
    for _ in 0..3 {
        sys.lookup_batch(&idx, batch, &mut out, tr, &net, &m);
        sys.update_batch(&idx, batch, &grad, tr, &net, &m);
    }
    sys.rebalance(&net, &m);

    let dir = std::env::temp_dir().join(format!("ss_emb_suite_ckpt_{}", std::process::id()));
    sys.save(&dir).unwrap();
    // reload into a system with a different roster (2 PSs -> different
    // bucketing) and a different init seed: rows must route through the
    // new placement and land bit-equal to the live tables
    let (sys2, _net2, _tr2, _m2) = system(2, 96, 99);
    sys2.load_into(&dir).unwrap();
    for t in 0..t_count {
        for r in 0..96u32 {
            let a = sys.shard_of(t, r).row(r);
            let b = sys2.shard_of(t, r).row(r);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "table {t} row {r} changed across reload");
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
