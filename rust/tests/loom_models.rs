//! Exhaustive model checking of the shadow-sync fabric's concurrency
//! protocols under the in-tree [`shadowsync::mc`] checker — a loom-style
//! DFS over every thread interleaving within a preemption bound, on top of
//! a PSO-class store-buffer memory model (relaxed stores really are
//! delayed, so a missing release fence is an *observable* bug here, not a
//! latent one).
//!
//! This suite only compiles under the model-checking cfg:
//!
//! ```text
//! RUSTFLAGS="--cfg shadowsync_loom" cargo test --release --test loom_models
//! ```
//!
//! Six models run the *real* fabric code (`sync/allreduce.rs`,
//! `sync/ps.rs`, `sync/repartition.rs`, `sync/health.rs`,
//! `tensor/mod.rs`) through `sync::prim`, which swaps
//! `std::sync`/`std::thread` for the modeled primitives under this cfg:
//!
//! 1. overlapped double-buffered deposit vs. a draining reduce (exact
//!    means across racing rounds — a stale helper folding the wrong
//!    parity bank would corrupt them);
//! 2. the epoch-tagged chunk-claim cursor under leave/join churn;
//! 3. dirty-epoch bump-after-write + scan-skip cache + central
//!    bump-after-push ("a scan skip never misses a settled write");
//! 4. the repartition adopt/depart handshake (at most one pending
//!    generation, no lost `leave()`);
//! 5. the heartbeat-depart claim protocol (watchdog ticks vs. a pool's
//!    terminal goodbye — the proxy-leave runs exactly once);
//! 6. the resume/depart TOCTOU closure (a tick that measured dark-window
//!    silence re-validates staleness under the lock a resume stamps
//!    through, so no schedule departs a resumed trainer);
//! 7. the shared-nothing engine's SPSC rings: the real `SpscRing` under a
//!    producer/consumer race (FIFO, exactly-once, backpressure) and the
//!    grant → fold → return delegation handshake over a ring pair, plus
//!    the real engine's two-member pipelined-round scenario.
//!
//! Three distilled *mutation* pairs close the loop on checker power: the
//! pre-epoch-tag claim cursor (the PR-1 generation race), a
//! `Relaxed`-weakened dirty bump, and a `Relaxed`-weakened SPSC tail
//! publication are each shown to FAIL model checking, while their fixed
//! twins — the accounting the fabric actually ships — pass exhaustively.
#![cfg(shadowsync_loom)]

use shadowsync::config::{RunConfig, SyncAlgo};
use shadowsync::mc::{model, model_finds_bug, Model};
use shadowsync::net::{Network, Role};
use shadowsync::sync::prim::{
    thread, Arc, AtomicU32, AtomicU64, AtomicUsize, Mutex,
    Ordering::{Acquire, Relaxed, Release, SeqCst},
};
use shadowsync::sync::ring::SpscRing;
use shadowsync::sync::{
    AllReduceGroup, DeltaScanCache, HealthController, ParamRange, PartitionPlan,
    ReduceEngine, RepartitionController, SyncPsGroup,
};
use shadowsync::tensor::HogwildBuffer;

// ---------------------------------------------------------------------------
// Model 1: overlapped double-buffered AllReduce, two racing rounds
// ---------------------------------------------------------------------------

/// Two members drive two back-to-back rounds through the overlapped
/// engine. Round `N+1` deposits are allowed to land while round `N`'s
/// reduce plan is still draining (opposite parity bank), so every
/// interleaving where a helper thread keeps folding across the round
/// boundary is explored: if the parity fence in the claim cursor ever let
/// a stale helper fold the wrong bank, some schedule would produce a mean
/// polluted by the other round's deposits and the exact asserts would
/// fire.
#[test]
fn overlapped_rounds_produce_exact_means() {
    let stats = Model::new().clamp_preemptions(2).check(|| {
        let mut net = Network::new(None);
        let node_a = net.add_node(Role::Trainer);
        let node_b = net.add_node(Role::Trainer);
        let net = Arc::new(net);
        let group = Arc::new(AllReduceGroup::new(2, 2));

        let member_b = {
            let group = Arc::clone(&group);
            let net = Arc::clone(&net);
            thread::spawn(move || {
                let mut buf = [3.0f32, 5.0];
                let r1 = group.allreduce_mean(&mut buf, node_b, &net).unwrap();
                assert_eq!((r1.generation, r1.contributors), (0, 2));
                assert_eq!(buf, [2.0, 4.0]);
                buf = [7.0, 11.0];
                let r2 = group.allreduce_mean(&mut buf, node_b, &net).unwrap();
                assert_eq!((r2.generation, r2.contributors), (1, 2));
                assert_eq!(buf, [6.0, 10.0]);
            })
        };

        let mut buf = [1.0f32, 3.0];
        let r1 = group.allreduce_mean(&mut buf, node_a, &net).unwrap();
        assert_eq!((r1.generation, r1.contributors), (0, 2));
        assert_eq!(buf, [2.0, 4.0]);
        buf = [5.0, 9.0];
        let r2 = group.allreduce_mean(&mut buf, node_a, &net).unwrap();
        assert_eq!((r2.generation, r2.contributors), (1, 2));
        assert_eq!(buf, [6.0, 10.0]);

        member_b.join().unwrap();
        assert_eq!(group.completed_rounds(), 2);
    });
    assert!(stats.executions > 1, "model never branched");
}

// ---------------------------------------------------------------------------
// Model 2: epoch-tagged claim cursor under membership churn
// ---------------------------------------------------------------------------

/// Three members complete a round, two leave, and the survivor runs a
/// singleton round — with every possible overlap between the leavers'
/// result reads, their `leave()` calls, and the survivor's next deposit.
/// The round-2 close races the round-1 reduce drain, so the epoch tag on
/// the claim cursor is what keeps a late helper from claiming (or
/// folding) chunks of the wrong generation; a lost `leave()` would
/// deadlock round 2, which the checker reports as a bug in that schedule.
#[test]
fn claim_cursor_survives_leave_churn() {
    // the fold order is ring-position order, so the mean is
    // bit-deterministic: (3+6+9) * (1/3) in f32, not an approximate 6.0
    let round1_mean = 18.0f32 * (1.0f32 / 3.0);
    let stats = Model::new().clamp_preemptions(2).check(move || {
        let mut net = Network::new(None);
        let nodes = [
            net.add_node(Role::Trainer),
            net.add_node(Role::Trainer),
            net.add_node(Role::Trainer),
        ];
        let net = Arc::new(net);
        let group = Arc::new(AllReduceGroup::new(3, 1));

        let leavers: Vec<_> = [(nodes[1], 6.0f32), (nodes[2], 9.0f32)]
            .into_iter()
            .map(|(node, v)| {
                let group = Arc::clone(&group);
                let net = Arc::clone(&net);
                thread::spawn(move || {
                    let mut buf = [v];
                    let r = group.allreduce_mean(&mut buf, node, &net).unwrap();
                    assert_eq!((r.generation, r.contributors), (0, 3));
                    assert_eq!(buf, [round1_mean]);
                    group.leave();
                })
            })
            .collect();

        let mut buf = [3.0f32];
        let r1 = group.allreduce_mean(&mut buf, nodes[0], &net).unwrap();
        assert_eq!((r1.generation, r1.contributors), (0, 3));
        assert_eq!(buf, [round1_mean]);
        // round 2 may start before either leaver has read round 1 (or
        // left); it must close the moment the membership drops to one
        buf = [7.0];
        let r2 = group.allreduce_mean(&mut buf, nodes[0], &net).unwrap();
        assert_eq!((r2.generation, r2.contributors), (1, 1));
        assert_eq!(buf, [7.0]);

        for h in leavers {
            h.join().unwrap();
        }
        assert_eq!(group.active(), 1);
        assert_eq!(group.completed_rounds(), 2);
    });
    assert!(stats.executions > 1, "model never branched");
}

// ---------------------------------------------------------------------------
// Model 3: dirty-epoch scan skip vs. a racing write and a racing peer push
// ---------------------------------------------------------------------------

/// The scan-skip invariant: a chunk may only reuse its cached gap while
/// neither the local replica (dirty-epoch signature) nor the central copy
/// (per-chunk version) changed — and both counters bump strictly *after*
/// their stores, so once a write has settled (here: `join()`), no later
/// round can skip over it. Mid-race rounds may legally reuse a scan for
/// one round (documented transient); the post-join round must not, and
/// the final values prove neither the worker write nor the peer push was
/// ever lost to a stale skip.
#[test]
fn scan_skip_never_misses_a_settled_write() {
    let stats = Model::new().clamp_preemptions(2).check(|| {
        let mut net = Network::new(None);
        let node_a = net.add_node(Role::Trainer);
        let node_b = net.add_node(Role::Trainer);
        let group =
            Arc::new(SyncPsGroup::build(&[0.0, 0.0], 1, &mut net).with_push_chunking(1, 0.01));
        let net = Arc::new(net);
        let local_a = Arc::new(HogwildBuffer::from_slice(&[0.0, 0.0]).with_dirty_epochs(1));
        let local_b = Arc::new(HogwildBuffer::from_slice(&[0.0, 2.0]));
        let mut cache = DeltaScanCache::new();

        // round 1, pre-race: converged, so both chunks scan cold and skip
        let r1 = group.elastic_sync_cached(&local_a, 0.5, node_a, &net, &mut cache);
        assert_eq!((r1.chunks_pushed, r1.chunks_skipped, r1.chunks_scan_skipped), (0, 2, 0));

        // a Hogwild worker writes chunk 0 (element store, then the Release
        // dirty bump — HogwildBuffer::set)
        let writer = {
            let local_a = Arc::clone(&local_a);
            thread::spawn(move || local_a.set(0, 4.0))
        };
        // a peer trainer pushes chunk 1 centrally (elastic move, then the
        // Release version bump); alpha=1 swaps local and central
        let peer = {
            let group = Arc::clone(&group);
            let net = Arc::clone(&net);
            let local_b = Arc::clone(&local_b);
            thread::spawn(move || {
                let mut scratch = DeltaScanCache::new();
                let range = ParamRange { offset: 1, len: 1 };
                let s = group
                    .elastic_sync_partition(&local_b, range, 1.0, node_b, &net, &mut scratch, None);
                assert_eq!(s.chunks_pushed, 1);
            })
        };
        // round 2 races both: any reuse here is the one-round transient
        group.elastic_sync_cached(&local_a, 0.5, node_a, &net, &mut cache);
        writer.join().unwrap();
        peer.join().unwrap();

        // round 3, post-join: both bumps happened-before this scan, so
        // neither chunk may reuse a stale entry...
        let r3 = group.elastic_sync_cached(&local_a, 0.5, node_a, &net, &mut cache);
        assert_eq!(r3.chunks_scan_skipped, 0);
        assert!(!cache.scan_skipped(0) && !cache.scan_skipped(1));
        // ...and by now each dirty chunk was pushed exactly once in *some*
        // round — these finals only hold if no schedule ever lost a write
        assert_eq!((group.central.get(0), group.central.get(1)), (2.0, 1.0));
        assert_eq!((local_a.get(0), local_a.get(1)), (2.0, 1.0));
        assert_eq!(local_b.get(1), 0.0);
    });
    assert!(stats.executions > 1, "model never branched");
}

// ---------------------------------------------------------------------------
// Model 4: repartition adopt/depart handshake
// ---------------------------------------------------------------------------

/// Two trainers sweep, adopt the generation-1 epoch, and race toward
/// generation 2 — except one departs while still on generation 1. In
/// every interleaving: at most one epoch is ever pending (the
/// `adopt(prev_gen)` one-behind assert runs inside the model), the
/// leaver's slots in a pending epoch's groups are vacated, and the
/// survivor's singleton rounds on the new fabric complete instead of
/// waiting on the ghost — a lost `leave()` surfaces as a modeled
/// deadlock.
#[test]
fn repartition_adopt_depart_handshake() {
    let stats = Model::new().clamp_preemptions(2).check(|| {
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 2,
            repartition_every: 1,
            easgd_chunk_elems: 8,
            algo: SyncAlgo::Ma,
            num_sync_ps: 0,
            ..RunConfig::default()
        };
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let _peer_node = net.add_node(Role::Trainer);
        let net = Arc::new(net);
        let plan = PartitionPlan::build(16, &cfg).unwrap();
        let groups = plan
            .partitions
            .iter()
            .map(|p| Some(Arc::new(AllReduceGroup::new(2, p.range.len))))
            .collect();
        let ctrl = Arc::new(RepartitionController::new(&cfg, 16, None, plan, groups));

        let survivor = {
            let ctrl = Arc::clone(&ctrl);
            let net = Arc::clone(&net);
            thread::spawn(move || {
                ctrl.record_sweep(&[1, 0]);
                while ctrl.generation() == 0 {
                    thread::yield_now();
                }
                let e1 = ctrl.adopt(0);
                assert_eq!(e1.gen, 1);
                ctrl.record_sweep(&[0, 1]);
                while ctrl.generation() == 1 {
                    thread::yield_now();
                }
                let e2 = ctrl.adopt(1);
                assert_eq!(e2.gen, 2);
                // liveness on the adopted fabric: whether the peer departed
                // before the rebuild (groups sized 1) or after (sized 2,
                // then vacated), singleton rounds must complete
                for (part, g) in e2.plan.partitions.iter().zip(&e2.groups) {
                    let g = g.as_ref().expect("MA partitions carry a ring group");
                    let mut buf = vec![1.5f32; part.range.len];
                    let r = g.allreduce_mean(&mut buf, node, &net).unwrap();
                    assert_eq!(r.contributors, 1);
                    assert!(buf.iter().all(|&x| x == 1.5));
                }
                ctrl.depart(2);
            })
        };
        let leaver = {
            let ctrl = Arc::clone(&ctrl);
            thread::spawn(move || {
                ctrl.record_sweep(&[1, 0]);
                while ctrl.generation() == 0 {
                    thread::yield_now();
                }
                let e1 = ctrl.adopt(0);
                assert_eq!(e1.gen, 1);
                ctrl.record_sweep(&[0, 1]);
                // depart while still on generation 1: if generation 2 is
                // already pending, our slots in its groups vacate here
                ctrl.depart(1);
            })
        };
        survivor.join().unwrap();
        leaver.join().unwrap();

        assert_eq!(ctrl.current_epoch().gen, 2);
        assert_eq!(ctrl.repartitions(), 2);
        for g in ctrl.current_epoch().groups.iter().flatten() {
            assert_eq!(g.active(), 1);
        }
    });
    assert!(stats.executions > 1, "model never branched");
}

// ---------------------------------------------------------------------------
// Models 5 & 6: the heartbeat-depart claim protocol
// ---------------------------------------------------------------------------

fn health_fixture() -> (
    Arc<RepartitionController>,
    Arc<HealthController>,
    Arc<shadowsync::sync::PlanEpoch>,
) {
    let cfg = RunConfig {
        num_trainers: 2,
        sync_partitions: 1,
        easgd_chunk_elems: 8,
        algo: SyncAlgo::Ma,
        num_sync_ps: 0,
        heartbeat_timeout_ms: 10,
        ..RunConfig::default()
    };
    let plan = PartitionPlan::build(16, &cfg).unwrap();
    let groups = plan
        .partitions
        .iter()
        .map(|p| Some(Arc::new(AllReduceGroup::new(2, p.range.len))))
        .collect();
    let ctrl = Arc::new(RepartitionController::new(&cfg, 16, None, plan, groups));
    let health = Arc::new(HealthController::new(&cfg, Arc::clone(&ctrl)));
    let e0 = ctrl.current_epoch();
    health.note_adopt(0, &e0);
    health.note_adopt(1, &e0);
    (ctrl, health, e0)
}

/// Trainer 1 goes silent; two watchdog ticks and the trainer's own pool
/// terminal path race to take it out of the roster. Every `departed`
/// transition happens under the health controller's state lock, so in
/// every interleaving the goodbye — proxy-leave plus controller depart —
/// has exactly one owner: the epoch's groups shrink by exactly one slot
/// (a double `leave()` would underflow the ring's membership), the
/// roster by exactly one trainer, and a rejoin restores both.
#[test]
fn heartbeat_depart_claims_are_exactly_once() {
    let stats = Model::new().clamp_preemptions(2).check(|| {
        let (ctrl, health, e0) = health_fixture();
        // trainer 0 beat recently; trainer 1 never beat and is stale
        health.beat_at_ms(0, 95);

        let ticks: Vec<_> = [100u64, 101]
            .into_iter()
            .map(|now| {
                let health = Arc::clone(&health);
                thread::spawn(move || health.check_at_ms(now))
            })
            .collect();
        let pool = {
            let health = Arc::clone(&health);
            let ctrl = Arc::clone(&ctrl);
            let e0 = Arc::clone(&e0);
            thread::spawn(move || {
                // the driver's terminal path: claim, then say goodbye
                if health.claim_exit(1) {
                    for g in e0.groups.iter().flatten() {
                        g.leave();
                    }
                    ctrl.depart(0);
                    1usize
                } else {
                    0
                }
            })
        };
        let ticked: usize = ticks.into_iter().map(|h| h.join().unwrap()).sum();
        let claimed = pool.join().unwrap();

        assert_eq!(ticked + claimed, 1, "the goodbye must have exactly one owner");
        assert_eq!(health.departs() as usize, ticked);
        assert!(health.is_departed(1));
        assert_eq!(ctrl.active(), 1);
        for g in e0.groups.iter().flatten() {
            assert_eq!(g.active(), 1, "trainer 1's slot must vacate exactly once");
        }
        // the roster recovers identically whichever claimant won
        let e1 = ctrl.rejoin().expect("survivor roster is idle");
        health.mark_rejoined(1, &e1);
        assert!(!health.is_departed(1));
        assert_eq!(ctrl.active(), 2);
        for g in e1.groups.iter().flatten() {
            assert_eq!(g.active(), 2);
        }
    });
    assert!(stats.executions > 1, "model never branched");
}

/// The resume/depart TOCTOU, closed. A watchdog tick measured trainer
/// 1's dark-window silence *before* taking the lock; the pool's resume
/// stamps a fresh heartbeat *under* that lock. Because the tick
/// re-validates staleness once it holds the lock, no schedule departs a
/// trainer that already resumed — and no resume slips past a depart that
/// already landed. Exactly one of the two wins in every interleaving.
#[test]
fn resume_and_depart_exclude_each_other() {
    let stats = Model::new().clamp_preemptions(2).check(|| {
        let (ctrl, health, e0) = health_fixture();
        // trainer 0 is fresh; trainer 1 last beat at t=50ms — stale at 100
        health.beat_at_ms(0, 95);
        health.beat_at_ms(1, 50);

        let tick = {
            let health = Arc::clone(&health);
            thread::spawn(move || health.check_at_ms(100))
        };
        let resume = {
            let health = Arc::clone(&health);
            // the pool's crash window closed just in time
            thread::spawn(move || health.resume_at_ms(1, 96))
        };
        let departed = tick.join().unwrap();
        let resumed = resume.join().unwrap();

        assert_eq!(departed == 1, !resumed, "each schedule picks exactly one winner");
        if resumed {
            assert!(!health.is_departed(1));
            assert_eq!(health.departs(), 0);
            assert_eq!(ctrl.active(), 2);
            for g in e0.groups.iter().flatten() {
                assert_eq!(g.active(), 2, "a resumed trainer keeps its slots");
            }
        } else {
            assert!(health.is_departed(1));
            assert_eq!(health.departs(), 1);
            assert_eq!(ctrl.active(), 1);
            for g in e0.groups.iter().flatten() {
                assert_eq!(g.active(), 1);
            }
            let e1 = ctrl.rejoin().expect("survivor roster is idle");
            health.mark_rejoined(1, &e1);
            assert_eq!(ctrl.active(), 2);
        }
    });
    assert!(stats.executions > 1, "model never branched");
}

// ---------------------------------------------------------------------------
// Mutation pair A: the PR-1 generation race, distilled
// ---------------------------------------------------------------------------
//
// Before the epoch-tagged cursor, the reduce used a plain chunk-index
// cursor that was reset to 0 at every round close, and "all chunks
// claimed" was treated as "round done". Two distinct corruptions hide in
// that accounting, both found below: a helper that claimed a chunk but
// hasn't folded yet starves the closing round's mean, and — the ABA — a
// helper holding a stale index observes the *reset* cursor back at its
// expected value, so its claim of round N's chunk succeeds against round
// N+1 and folds round-N data into round N+1's sum. The fixed twin carries
// the two ingredients the real engine ships (`pack_cursor` epoch tags +
// the `chunks_done` fold counter) and passes exhaustively; the churn
// model above pins the same guarantee on the real `AllReduceGroup`.

const ROUND1: [f32; 2] = [4.0, 2.0];
const ROUND2: [f32; 2] = [8.0, 6.0];

#[test]
fn untagged_claim_cursor_race_is_caught() {
    assert!(
        model_finds_bug(|| {
            let cursor = Arc::new(AtomicUsize::new(0));
            let sum = Arc::new(Mutex::new(0.0f32));
            let helper = {
                let cursor = Arc::clone(&cursor);
                let sum = Arc::clone(&sum);
                // one help_reduce-style claim attempt against round 1
                thread::spawn(move || {
                    let cur = cursor.load(SeqCst);
                    if cur < 2 && cursor.compare_exchange(cur, cur + 1, SeqCst, SeqCst).is_ok() {
                        *sum.lock().unwrap() += ROUND1[cur];
                    }
                })
            };
            for (round, src) in [ROUND1, ROUND2].into_iter().enumerate() {
                if round > 0 {
                    // old accounting: reset the plain-index cursor — the
                    // window the stale helper's ABA claim sneaks through
                    cursor.store(0, SeqCst);
                    *sum.lock().unwrap() = 0.0;
                }
                loop {
                    let cur = cursor.load(SeqCst);
                    if cur >= 2 {
                        break;
                    }
                    if cursor.compare_exchange(cur, cur + 1, SeqCst, SeqCst).is_ok() {
                        *sum.lock().unwrap() += src[cur];
                    }
                }
                // old accounting: "all claimed" == "round done"
                let mean = *sum.lock().unwrap() / 2.0;
                let want = (src[0] + src[1]) / 2.0;
                assert!((mean - want).abs() < 1e-6, "round {round} mean {mean} != {want}");
            }
            helper.join().unwrap();
        }),
        "the untagged cursor's generation race must be caught"
    );
}

/// Pack a claim cursor exactly like `sync::allreduce::pack_cursor`.
fn pack(round: u64, idx: usize) -> u64 {
    (round << 32) | idx as u64
}

#[test]
fn epoch_tagged_fold_counted_cursor_is_safe() {
    model(|| {
        let cursor = Arc::new(AtomicU64::new(pack(1, 0)));
        let folded = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(Mutex::new(0.0f32));
        let helper = {
            let cursor = Arc::clone(&cursor);
            let folded = Arc::clone(&folded);
            let sum = Arc::clone(&sum);
            thread::spawn(move || loop {
                let cur = cursor.load(SeqCst);
                if cur >> 32 != 1 {
                    break; // a different round owns the cursor; stand down
                }
                let idx = (cur & 0xFFFF_FFFF) as usize;
                if idx >= 2 {
                    break;
                }
                if cursor.compare_exchange(cur, cur + 1, SeqCst, SeqCst).is_err() {
                    continue;
                }
                *sum.lock().unwrap() += ROUND1[idx];
                folded.fetch_add(1, SeqCst);
            })
        };
        for (round, src) in [(1u64, ROUND1), (2, ROUND2)] {
            if round > 1 {
                cursor.store(pack(round, 0), SeqCst);
                *sum.lock().unwrap() = 0.0;
                // safe to reset: close-on-folded below means every round-1
                // fold (helper's included) completed before we got here
                folded.store(0, SeqCst);
            }
            loop {
                let cur = cursor.load(SeqCst);
                let idx = (cur & 0xFFFF_FFFF) as usize;
                if idx >= 2 {
                    break;
                }
                if cursor.compare_exchange(cur, cur + 1, SeqCst, SeqCst).is_ok() {
                    *sum.lock().unwrap() += src[idx];
                    folded.fetch_add(1, SeqCst);
                }
            }
            // fixed accounting: close on folds, not on claims
            while folded.load(SeqCst) < 2 {
                thread::yield_now();
            }
            let mean = *sum.lock().unwrap() / 2.0;
            let want = (src[0] + src[1]) / 2.0;
            assert!((mean - want).abs() < 1e-6, "round {round} mean {mean} != {want}");
        }
        helper.join().unwrap();
    });
}

// ---------------------------------------------------------------------------
// Mutation pair B: the dirty-epoch bump's Release ordering is load-bearing
// ---------------------------------------------------------------------------

/// `HogwildBuffer::set` distilled to one cell: a relaxed element store
/// followed by the dirty-epoch bump. A scanner that observes the bump
/// must observe the store behind it — that is the entire contract the
/// scan-skip cache leans on.
fn dirty_cell(bump: shadowsync::sync::prim::Ordering) {
    let data = Arc::new(AtomicU32::new(0.0f32.to_bits()));
    let epoch = Arc::new(AtomicU64::new(0));
    let writer = {
        let data = Arc::clone(&data);
        let epoch = Arc::clone(&epoch);
        thread::spawn(move || {
            data.store(4.0f32.to_bits(), Relaxed); // the element store
            epoch.fetch_add(1, bump); // DirtyEpochs::mark
        })
    };
    if epoch.load(Acquire) == 1 {
        assert_eq!(f32::from_bits(data.load(Relaxed)), 4.0, "bump visible but store lost");
    }
    writer.join().unwrap();
}

#[test]
fn relaxed_dirty_bump_is_caught() {
    // weakened mutant: under the store-buffer model a Relaxed RMW drains
    // only its own cell, so the element store can still be in flight when
    // the epoch bump lands — and the checker finds that schedule
    assert!(
        model_finds_bug(|| dirty_cell(Relaxed)),
        "a Relaxed dirty bump must be caught by the checker"
    );
}

#[test]
fn release_dirty_bump_is_safe() {
    // the shipped ordering: the Release bump publishes the store
    model(|| dirty_cell(Release));
}

// ---------------------------------------------------------------------------
// Model 7: the shared-nothing engine's SPSC rings
// ---------------------------------------------------------------------------

/// The real `SpscRing` under a producer/consumer race: three messages
/// through a capacity-2 ring, so the full/backpressure path (try_push
/// handing the message back) is explored alongside the publish/consume
/// protocol. Every schedule must deliver all three messages exactly once,
/// in order — a lost Release edge on either cursor would surface as a
/// duplicated or vanished message in some interleaving.
#[test]
fn spsc_ring_never_loses_or_duplicates_a_message() {
    let stats = Model::new().clamp_preemptions(2).check(|| {
        let ring: Arc<SpscRing<u32>> = Arc::new(SpscRing::new(2));
        let producer = {
            let ring = Arc::clone(&ring);
            thread::spawn(move || {
                for v in [10u32, 20, 30] {
                    let mut msg = v;
                    while let Err(back) = ring.try_push(msg) {
                        msg = back; // full: backpressure, retry
                        thread::yield_now();
                    }
                }
            })
        };
        let mut got = Vec::new();
        while got.len() < 3 {
            match ring.try_pop() {
                Some(v) => got.push(v),
                None => thread::yield_now(),
            }
        }
        producer.join().unwrap();
        assert_eq!(got, [10, 20, 30], "FIFO, exactly once");
        assert!(ring.try_pop().is_none());
    });
    assert!(stats.executions > 1, "model never branched");
}

/// The delegation handshake over a ring pair, exactly as the shared-nothing
/// owner runs it: two chunk-range grants travel to a borrower over one
/// ring, the borrower folds each stripe privately and sends it back over
/// the other, and the owner copies the returned stripes into the result at
/// their offsets. In every interleaving the assembled vector must hold
/// each element exactly once — a grant consumed twice, a stripe lost, or a
/// stripe landing at the wrong offset all corrupt the exact comparison.
#[test]
fn delegation_handshake_returns_every_granted_stripe() {
    let stats = Model::new().clamp_preemptions(2).check(|| {
        let grants: Arc<SpscRing<(usize, usize)>> = Arc::new(SpscRing::new(2));
        let returns: Arc<SpscRing<(usize, Vec<f32>)>> = Arc::new(SpscRing::new(2));
        let borrower = {
            let grants = Arc::clone(&grants);
            let returns = Arc::clone(&returns);
            thread::spawn(move || {
                let mut served = 0;
                while served < 2 {
                    match grants.try_pop() {
                        Some((lo, hi)) => {
                            let stripe: Vec<f32> = (lo..hi).map(|i| i as f32 * 0.5).collect();
                            let mut msg = (lo, stripe);
                            while let Err(back) = returns.try_push(msg) {
                                msg = back;
                                thread::yield_now();
                            }
                            served += 1;
                        }
                        None => thread::yield_now(),
                    }
                }
            })
        };
        // the owner: delegate [0,2) and [2,3), fold its own [3,4) range,
        // then collect the returned stripes at their offsets
        grants.try_push((0, 2)).unwrap();
        grants.try_push((2, 3)).unwrap();
        let mut out = vec![0.0f32; 4];
        out[3] = 1.5;
        let mut collected = 0;
        while collected < 2 {
            match returns.try_pop() {
                Some((lo, stripe)) => {
                    out[lo..lo + stripe.len()].copy_from_slice(&stripe);
                    collected += 1;
                }
                None => thread::yield_now(),
            }
        }
        borrower.join().unwrap();
        assert_eq!(out, [0.0, 0.5, 1.0, 1.5], "every stripe landed exactly once");
    });
    assert!(stats.executions > 1, "model never branched");
}

/// Model 1's pipelined two-round scenario through the *shared-nothing*
/// engine: deposits move over the SPSC rings, the first waiter owns the
/// fold, round 2's deposits may drain into the depth-2 rings while round
/// 1 folds, and results publish by epoch-stamped pointer swap. Every
/// interleaving must still produce the exact means of both rounds.
#[test]
fn shared_nothing_rounds_produce_exact_means() {
    let stats = Model::new().clamp_preemptions(2).check(|| {
        let mut net = Network::new(None);
        let node_a = net.add_node(Role::Trainer);
        let node_b = net.add_node(Role::Trainer);
        let net = Arc::new(net);
        let group = Arc::new(
            AllReduceGroup::new(2, 2)
                .with_chunks(2)
                .with_engine(ReduceEngine::SharedNothing),
        );

        let member_b = {
            let group = Arc::clone(&group);
            let net = Arc::clone(&net);
            thread::spawn(move || {
                let mut buf = [3.0f32, 5.0];
                let r1 = group.allreduce_mean(&mut buf, node_b, &net).unwrap();
                assert_eq!((r1.generation, r1.contributors), (0, 2));
                assert_eq!(buf, [2.0, 4.0]);
                buf = [7.0, 11.0];
                let r2 = group.allreduce_mean(&mut buf, node_b, &net).unwrap();
                assert_eq!((r2.generation, r2.contributors), (1, 2));
                assert_eq!(buf, [6.0, 10.0]);
            })
        };

        let mut buf = [1.0f32, 3.0];
        let r1 = group.allreduce_mean(&mut buf, node_a, &net).unwrap();
        assert_eq!((r1.generation, r1.contributors), (0, 2));
        assert_eq!(buf, [2.0, 4.0]);
        buf = [5.0, 9.0];
        let r2 = group.allreduce_mean(&mut buf, node_a, &net).unwrap();
        assert_eq!((r2.generation, r2.contributors), (1, 2));
        assert_eq!(buf, [6.0, 10.0]);

        member_b.join().unwrap();
        assert_eq!(group.completed_rounds(), 2);
        assert_eq!(group.published_rounds(), 2);
    });
    assert!(stats.executions > 1, "model never branched");
}

// ---------------------------------------------------------------------------
// Mutation pair C: the SPSC tail publication's Release ordering
// ---------------------------------------------------------------------------

/// `SpscRing::try_push` distilled to one slot, with the payload mirrored
/// as an atomic (the checker's store buffer tracks atomics, not
/// `UnsafeCell` contents): slot write, then the tail publication with the
/// ordering under test. A consumer that Acquire-observes the new tail
/// must observe the slot write behind it — the ring's entire contract.
fn spsc_publish(tail_order: shadowsync::sync::prim::Ordering) {
    let slot = Arc::new(AtomicU32::new(0));
    let tail = Arc::new(AtomicUsize::new(0));
    let producer = {
        let slot = Arc::clone(&slot);
        let tail = Arc::clone(&tail);
        thread::spawn(move || {
            slot.store(42, Relaxed); // the slot write
            tail.store(1, tail_order); // the publication
        })
    };
    if tail.load(Acquire) == 1 {
        assert_eq!(slot.load(Relaxed), 42, "tail visible but the slot write lost");
    }
    producer.join().unwrap();
}

#[test]
fn relaxed_spsc_tail_store_is_caught() {
    // weakened mutant: a Relaxed tail store can land while the slot write
    // is still buffered, so some schedule pops an unwritten slot
    assert!(
        model_finds_bug(|| spsc_publish(Relaxed)),
        "a Relaxed SPSC tail publication must be caught by the checker"
    );
}

#[test]
fn release_spsc_tail_store_is_safe() {
    // the shipped ordering: the Release tail store publishes the slot
    model(|| spsc_publish(Release));
}
