//! End-to-end integration: the full coordinator → trainers → embedding PS →
//! sync pipeline on the tiny preset, for every algorithm × mode.
//! Requires `make artifacts`.

use std::path::PathBuf;

use shadowsync::config::{RunConfig, SyncAlgo, SyncMode};
use shadowsync::coordinator;
use shadowsync::runtime::Runtime;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("tiny.meta.json").exists()
}

fn base_cfg() -> RunConfig {
    RunConfig {
        preset: "tiny".into(),
        artifacts_dir: artifacts_dir(),
        num_trainers: 2,
        worker_threads: 2,
        num_embedding_ps: 2,
        num_sync_ps: 1,
        train_examples: 16_384,
        eval_examples: 2_048,
        shadow_interval_ms: 2,
        embedding: shadowsync::config::EmbeddingConfig {
            rows_per_table: 500,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn run(cfg: RunConfig) -> coordinator::TrainOutcome {
    let rt = Runtime::cpu().unwrap();
    coordinator::run_timed(&cfg, &rt).unwrap()
}

#[test]
fn shadow_easgd_learns_and_syncs() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let out = run(base_cfg());
    assert_eq!(out.label, "S-EASGD");
    // every training example consumed exactly once (full batches)
    assert_eq!(out.metrics.examples, 16_384);
    // loss is meaningful and the model beats the base-rate predictor
    assert!(out.train_loss.is_finite() && out.train_loss > 0.0);
    assert!(out.eval.ne() < 1.0, "NE {} should beat base rate", out.eval.ne());
    // the shadow thread actually synced, in the background
    assert!(out.metrics.syncs > 0);
    assert!(out.sync_ps_bytes > 0);
    assert!(out.avg_sync_gap.is_finite());
    assert!(out.eps > 0.0);
}

#[test]
fn all_algorithms_and_modes_complete() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let combos: Vec<(SyncAlgo, SyncMode)> = vec![
        (SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }),
        (SyncAlgo::Ma, SyncMode::Shadow),
        (SyncAlgo::Ma, SyncMode::FixedRate { gap: 8 }),
        (SyncAlgo::Bmuf, SyncMode::Shadow),
        (SyncAlgo::Bmuf, SyncMode::FixedRate { gap: 8 }),
        (SyncAlgo::None, SyncMode::Shadow),
    ];
    for (algo, mode) in combos {
        let mut cfg = base_cfg();
        cfg.algo = algo;
        cfg.mode = mode;
        cfg.num_sync_ps = usize::from(algo == SyncAlgo::Easgd);
        cfg.train_examples = 2_048;
        cfg.eval_examples = 512;
        let out = coordinator::run_timed(&cfg, &rt)
            .unwrap_or_else(|e| panic!("{algo:?}/{mode:?} failed: {e}"));
        assert_eq!(out.metrics.examples, 2_048, "{algo:?}/{mode:?}");
        assert!(out.train_loss.is_finite(), "{algo:?}/{mode:?}");
        if algo != SyncAlgo::None {
            assert!(out.metrics.syncs > 0, "{algo:?}/{mode:?} never synced");
        }
    }
}

#[test]
fn shadow_sync_replicas_converge() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // After a pass with S-EASGD, replicas should sit near the central copy
    let rt = Runtime::cpu().unwrap();
    let cfg = base_cfg();
    let cluster = coordinator::build(&cfg, &rt).unwrap();
    coordinator::train(&cluster).unwrap();
    let central = cluster.sync_ps.as_ref().unwrap().central.to_vec();
    for t in &cluster.trainers {
        let replica = t.replica.to_vec();
        let gap = shadowsync::tensor::ops::mean_abs_diff(&replica, &central);
        let scale =
            shadowsync::tensor::ops::l2_norm(&central) / (central.len() as f32).sqrt();
        assert!(
            gap < 0.8 * scale.max(0.05),
            "trainer {} drifted: gap={gap} scale={scale}",
            t.id
        );
    }
}

#[test]
fn fixed_rate_gap_is_respected() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = base_cfg();
    cfg.mode = SyncMode::FixedRate { gap: 4 };
    cfg.train_examples = 2_048;
    let out = run(cfg);
    // FR-EASGD-4: every worker syncs every 4 of its own iterations, so the
    // Eq.2 average gap must be ~4 (tail iterations may not hit a boundary)
    assert!(
        (out.avg_sync_gap - 4.0).abs() < 1.0,
        "avg sync gap {} should be ≈4",
        out.avg_sync_gap
    );
    assert_eq!(out.label, "FR-EASGD-4");
}

#[test]
fn checkpoint_writes_model() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut cfg = base_cfg();
    cfg.train_examples = 512;
    cfg.eval_examples = 128;
    let cluster = coordinator::build(&cfg, &rt).unwrap();
    coordinator::train(&cluster).unwrap();
    let dir = std::env::temp_dir().join(format!("shadowsync-ckpt-{}", std::process::id()));
    coordinator::checkpoint(&cluster, &dir).unwrap();
    let w = std::fs::read(dir.join("w.bin")).unwrap();
    assert_eq!(w.len(), cluster.meta.num_params * 4);
    assert!(dir.join("MANIFEST.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn decaying_gap_mode_completes_and_syncs() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = base_cfg();
    cfg.mode = SyncMode::Decaying { start: 40, end: 2 };
    cfg.train_examples = 4_096;
    let out = run(cfg);
    assert_eq!(out.label, "FR-EASGD-40→2");
    assert_eq!(out.metrics.examples, 4_096);
    assert!(out.metrics.syncs > 0, "decaying mode never synced");
    // the annealed schedule averages strictly inside (end, start)
    assert!(out.avg_sync_gap > 2.0 && out.avg_sync_gap < 40.0, "gap {}", out.avg_sync_gap);
}

#[test]
fn checkpoint_roundtrip_is_bit_exact() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let mut cfg = base_cfg();
    cfg.train_examples = 1_024;
    cfg.eval_examples = 128;
    let cluster = coordinator::build(&cfg, &rt).unwrap();
    coordinator::train(&cluster).unwrap();
    let dir = std::env::temp_dir().join(format!("shadowsync-rt-{}", std::process::id()));
    coordinator::checkpoint(&cluster, &dir).unwrap();
    // reload w.bin: every f32 must be bit-equal to the live first replica
    // (training is quiescent after train(), so live == checkpointed)
    let w_file = std::fs::read(dir.join("w.bin")).unwrap();
    let live = cluster.trainers[0].replica.to_vec();
    assert_eq!(w_file.len(), live.len() * 4);
    for (i, v) in live.iter().enumerate() {
        let bytes: [u8; 4] = w_file[i * 4..i * 4 + 4].try_into().unwrap();
        assert_eq!(
            f32::from_le_bytes(bytes).to_bits(),
            v.to_bits(),
            "w.bin[{i}] diverged from the live replica"
        );
    }
    // reload every embedding shard file: bit-equal to the live tables
    let mut shard_files = 0;
    for shard in cluster.embeddings.shards() {
        let path = dir.join(format!("emb_t{}_r{}.bin", shard.table, shard.row_lo));
        let bytes = std::fs::read(&path).unwrap();
        let mut off = 0usize;
        for r in shard.row_lo..shard.row_hi {
            for v in shard.row(r) {
                let b: [u8; 4] = bytes[off..off + 4].try_into().unwrap();
                assert_eq!(
                    f32::from_le_bytes(b).to_bits(),
                    v.to_bits(),
                    "shard t{} row {r} diverged",
                    shard.table
                );
                off += 4;
            }
        }
        assert_eq!(off, bytes.len(), "shard file has trailing bytes");
        shard_files += 1;
    }
    assert!(shard_files > 0, "no embedding shards checkpointed");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn adaptive_repartition_run_completes_and_replans() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // measured-cost adaptive repartitioning end-to-end through the
    // coordinator: the run must replan at least once, keep every
    // partition syncing, and finish with sane quality numbers
    let mut cfg = base_cfg();
    cfg.sync_partitions = 4;
    cfg.shadow_threads = 2;
    cfg.easgd_chunk_elems = 64; // tiny preset: 537 dense params
    cfg.delta_skip_target = 0.25;
    cfg.repartition_every = 5;
    cfg.train_examples = 4_096;
    cfg.eval_examples = 512;
    cfg.validate().unwrap();
    let rt = Runtime::cpu().unwrap();
    let out = coordinator::run_timed(&cfg, &rt)
        .unwrap_or_else(|e| panic!("adaptive repartition run failed: {e}"));
    assert_eq!(out.metrics.examples, 4_096);
    assert!(out.train_loss.is_finite());
    assert!(out.metrics.syncs > 0, "repartitioned fabric never synced");
    assert!(out.repartitions >= 1, "the plan was never rebuilt");
    assert_eq!(out.partition_gaps.len(), 4, "gaps: {:?}", out.partition_gaps);
    for (i, g) in out.partition_gaps.iter().enumerate() {
        assert!(g.is_finite(), "partition {i} starved: {:?}", out.partition_gaps);
    }
}

#[test]
fn hybrid_algo_map_run_completes_with_per_partition_gaps() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // the paper's §3.2 hybrid scenario, end-to-end: 4 partitions, EASGD on
    // 0-1 (against the sync PSs), MA on 2-3 (per-partition rings), 2
    // shadow threads per trainer
    let mut cfg = base_cfg();
    cfg.sync_partitions = 4;
    cfg.shadow_threads = 2;
    cfg.algo_map = Some("easgd:0-1,ma:2-3".parse().unwrap());
    cfg.easgd_chunk_elems = 64; // tiny preset: 537 dense params
    cfg.train_examples = 4_096;
    cfg.eval_examples = 512;
    cfg.validate().unwrap();
    let rt = Runtime::cpu().unwrap();
    let out = coordinator::run_timed(&cfg, &rt)
        .unwrap_or_else(|e| panic!("hybrid run failed: {e}"));
    assert_eq!(out.metrics.examples, 4_096);
    assert!(out.train_loss.is_finite());
    assert!(out.metrics.syncs > 0, "hybrid fabric never synced");
    // every partition's shadow rounds were recorded, so every per-partition
    // gap is measurable (finite)
    assert_eq!(out.partition_gaps.len(), 4, "gaps: {:?}", out.partition_gaps);
    for (i, g) in out.partition_gaps.iter().enumerate() {
        assert!(g.is_finite(), "partition {i} never synced: {:?}", out.partition_gaps);
    }
    // both tiers moved bytes: the sync-PS tier (EASGD partitions) and the
    // trainer rings (MA partitions); metrics.sync_bytes covers exactly both
    assert!(out.sync_ps_bytes > 0, "EASGD partitions never pushed");
    // metrics.sync_bytes = EASGD legs (== the sync-PS role counters) plus
    // the MA partitions' ring tx, so it must strictly exceed the PS share
    assert!(
        out.metrics.sync_bytes > out.sync_ps_bytes,
        "ring bytes missing from metrics.sync_bytes ({} <= {})",
        out.metrics.sync_bytes,
        out.sync_ps_bytes
    );
}
