//! Cross-module property suite (no artifacts needed): invariants that span
//! subsystems, run through the in-repo property-test harness.

use shadowsync::config::{EmbOptimizer, RunConfig, SyncAlgo, SyncMode};
use shadowsync::metrics::{normalized_entropy, Metrics};
use shadowsync::sim::CostModel;
use shadowsync::tensor::HogwildBuffer;
use shadowsync::util::proptest::check;

#[test]
fn sim_eps_is_monotone_in_trainers_for_every_mode() {
    check("sim-monotone-trainers", 40, |g| {
        let cm = CostModel::paper_scale();
        let threads = g.usize_in(1, 48);
        let sync_ps = g.usize_in(1, 6);
        let algo = match g.usize_in(0, 2) {
            0 => SyncAlgo::Easgd,
            1 => SyncAlgo::Ma,
            _ => SyncAlgo::Bmuf,
        };
        let mode = match g.usize_in(0, 2) {
            0 => SyncMode::Shadow,
            1 => SyncMode::FixedRate { gap: g.usize_in(1, 120) as u32 },
            _ => SyncMode::Decaying {
                start: g.usize_in(10, 100) as u32,
                end: g.usize_in(1, 10) as u32,
            },
        };
        let mut prev = 0.0;
        for n in [1usize, 2, 4, 8, 16, 32] {
            let p = cm.simulate(n, threads, algo, mode, sync_ps);
            assert!(
                p.eps >= prev - 1e-6,
                "EPS decreased {prev} -> {} at n={n} ({algo:?} {mode:?})",
                p.eps
            );
            assert!(p.train_fraction > 0.0 && p.train_fraction <= 1.0);
            assert!((0.0..=1.0).contains(&p.sync_ps_util));
            prev = p.eps;
        }
    });
}

#[test]
fn sim_shadow_always_at_least_matches_foreground_eps() {
    check("shadow-dominates", 60, |g| {
        let cm = CostModel::paper_scale();
        let n = g.usize_in(1, 32);
        let threads = g.usize_in(1, 48);
        let gap = g.usize_in(1, 200) as u32;
        let sync_ps = g.usize_in(1, 6);
        for algo in [SyncAlgo::Easgd, SyncAlgo::Ma, SyncAlgo::Bmuf] {
            let shadow = cm.simulate(n, threads, algo, SyncMode::Shadow, sync_ps).eps;
            let fr = cm.simulate(n, threads, algo, SyncMode::FixedRate { gap }, sync_ps).eps;
            // the paper's core throughput claim, as a universal invariant
            assert!(shadow >= fr - 1e-6, "{algo:?}: shadow {shadow} < FR-{gap} {fr} at n={n}");
        }
    });
}

#[test]
fn elastic_sync_is_a_contraction_between_replicas() {
    check("easgd-contraction", 30, |g| {
        let p = g.usize_in(1, 128);
        let alpha = g.f32_in(0.05, 0.95);
        let a = HogwildBuffer::from_slice(&g.vec_normal(p, 2.0));
        let b = HogwildBuffer::from_slice(&g.vec_normal(p, 2.0));
        let gap0 = shadowsync::tensor::ops::mean_abs_diff(&a.to_vec(), &b.to_vec());
        // one full elastic round for each replica against a shared hub
        let mut net = shadowsync::net::Network::new(None);
        let t0 = net.add_node(shadowsync::net::Role::Trainer);
        let hub = shadowsync::sync::SyncPsGroup::build(&vec![0.0; p], 1, &mut net);
        for _ in 0..200 {
            hub.elastic_sync(&a, alpha, t0, &net);
            hub.elastic_sync(&b, alpha, t0, &net);
        }
        let gap1 = shadowsync::tensor::ops::mean_abs_diff(&a.to_vec(), &b.to_vec());
        assert!(gap1 < 0.05 * gap0.max(1e-3), "no consensus: {gap0} -> {gap1}");
    });
}

#[test]
fn embedding_optimizers_share_lookup_semantics() {
    // swapping the PS optimizer must never change what a *lookup* returns
    // before any update lands (init is optimizer-independent)
    use shadowsync::embedding::TableShard;
    use shadowsync::net::NodeId;
    check("emb-opt-lookup", 20, |g| {
        let rows = g.usize_in(4, 64) as u32;
        let dim = g.usize_in(1, 16);
        let seed = g.rng.next_u64();
        let mk = |opt| TableShard::with_optimizer(1, 0, rows, dim, NodeId(0), seed, opt);
        let a = mk(EmbOptimizer::Adagrad);
        let b = mk(EmbOptimizer::Adam { beta1: 0.9, beta2: 0.999 });
        let c = mk(EmbOptimizer::RmsProp { decay: 0.95 });
        let r = g.usize_in(0, rows as usize - 1) as u32;
        assert_eq!(a.row(r), b.row(r));
        assert_eq!(a.row(r), c.row(r));
    });
}

#[test]
fn ne_is_scale_free_and_one_at_base_rate() {
    check("ne-properties", 50, |g| {
        let p = g.f32_in(0.05, 0.95) as f64;
        let h = -(p * p.ln() + (1.0 - p) * (1.0 - p).ln());
        // base-rate predictor has NE exactly 1
        assert!((normalized_entropy(h, p) - 1.0).abs() < 1e-9);
        // better log-loss => smaller NE, monotonically
        let better = normalized_entropy(h * 0.7, p);
        let worse = normalized_entropy(h * 1.3, p);
        assert!(better < 1.0 && worse > 1.0);
    });
}

#[test]
fn metrics_totals_are_exact_under_many_threads() {
    use std::sync::Arc;
    let m = Arc::new(Metrics::new());
    let hs: Vec<_> = (0..8)
        .map(|_| {
            let m = m.clone();
            std::thread::spawn(move || {
                for _ in 0..2_000 {
                    m.record_batch(13, 0.25);
                    m.record_sync(7);
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    let s = m.snapshot();
    assert_eq!(s.examples, 8 * 2_000 * 13);
    assert_eq!(s.iterations, 8 * 2_000);
    assert_eq!(s.syncs, 8 * 2_000);
    assert_eq!(s.sync_bytes, 8 * 2_000 * 7);
    assert!((s.avg_loss - 0.25 / 13.0).abs() < 1e-12);
    assert!((m.avg_sync_gap() - 1.0).abs() < 1e-12);
}

#[test]
fn run_config_label_roundtrips_modes() {
    let mut cfg = RunConfig::default();
    cfg.mode = SyncMode::Decaying { start: 100, end: 5 };
    assert_eq!(cfg.label(), "FR-EASGD-100→5");
    cfg.algo = SyncAlgo::Bmuf;
    cfg.mode = SyncMode::Shadow;
    assert_eq!(cfg.label(), "S-BMUF");
}
