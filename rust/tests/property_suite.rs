//! Cross-module property suite (no artifacts needed): invariants that span
//! subsystems, run through the in-repo property-test harness.

use shadowsync::config::{EmbOptimizer, RunConfig, SyncAlgo, SyncMode};
use shadowsync::metrics::{normalized_entropy, Metrics};
use shadowsync::net::{Network, Role};
use shadowsync::sim::CostModel;
use shadowsync::sync::partition::{lpt_contiguous_ranges, lpt_contiguous_ranges_weighted};
use shadowsync::sync::{
    AllReduceGroup, DeltaGate, DeltaScanCache, ParamRange, ReduceEngine, SyncPsGroup, WireCodec,
};
use shadowsync::tensor::HogwildBuffer;
use shadowsync::util::proptest::check;
use shadowsync::util::rng::Rng;

#[test]
fn sim_eps_is_monotone_in_trainers_for_every_mode() {
    check("sim-monotone-trainers", 40, |g| {
        let cm = CostModel::paper_scale();
        let threads = g.usize_in(1, 48);
        let sync_ps = g.usize_in(1, 6);
        let algo = match g.usize_in(0, 2) {
            0 => SyncAlgo::Easgd,
            1 => SyncAlgo::Ma,
            _ => SyncAlgo::Bmuf,
        };
        let mode = match g.usize_in(0, 2) {
            0 => SyncMode::Shadow,
            1 => SyncMode::FixedRate { gap: g.usize_in(1, 120) as u32 },
            _ => SyncMode::Decaying {
                start: g.usize_in(10, 100) as u32,
                end: g.usize_in(1, 10) as u32,
            },
        };
        let mut prev = 0.0;
        for n in [1usize, 2, 4, 8, 16, 32] {
            let p = cm.simulate(n, threads, algo, mode, sync_ps);
            assert!(
                p.eps >= prev - 1e-6,
                "EPS decreased {prev} -> {} at n={n} ({algo:?} {mode:?})",
                p.eps
            );
            assert!(p.train_fraction > 0.0 && p.train_fraction <= 1.0);
            assert!((0.0..=1.0).contains(&p.sync_ps_util));
            prev = p.eps;
        }
    });
}

#[test]
fn sim_shadow_always_at_least_matches_foreground_eps() {
    check("shadow-dominates", 60, |g| {
        let cm = CostModel::paper_scale();
        let n = g.usize_in(1, 32);
        let threads = g.usize_in(1, 48);
        let gap = g.usize_in(1, 200) as u32;
        let sync_ps = g.usize_in(1, 6);
        for algo in [SyncAlgo::Easgd, SyncAlgo::Ma, SyncAlgo::Bmuf] {
            let shadow = cm.simulate(n, threads, algo, SyncMode::Shadow, sync_ps).eps;
            let fr = cm.simulate(n, threads, algo, SyncMode::FixedRate { gap }, sync_ps).eps;
            // the paper's core throughput claim, as a universal invariant
            assert!(shadow >= fr - 1e-6, "{algo:?}: shadow {shadow} < FR-{gap} {fr} at n={n}");
        }
    });
}

#[test]
fn elastic_sync_is_a_contraction_between_replicas() {
    check("easgd-contraction", 30, |g| {
        let p = g.usize_in(1, 128);
        let alpha = g.f32_in(0.05, 0.95);
        let a = HogwildBuffer::from_slice(&g.vec_normal(p, 2.0));
        let b = HogwildBuffer::from_slice(&g.vec_normal(p, 2.0));
        let gap0 = shadowsync::tensor::ops::mean_abs_diff(&a.to_vec(), &b.to_vec());
        // one full elastic round for each replica against a shared hub
        let mut net = shadowsync::net::Network::new(None);
        let t0 = net.add_node(shadowsync::net::Role::Trainer);
        let hub = shadowsync::sync::SyncPsGroup::build(&vec![0.0; p], 1, &mut net);
        for _ in 0..200 {
            hub.elastic_sync(&a, alpha, t0, &net);
            hub.elastic_sync(&b, alpha, t0, &net);
        }
        let gap1 = shadowsync::tensor::ops::mean_abs_diff(&a.to_vec(), &b.to_vec());
        assert!(gap1 < 0.05 * gap0.max(1e-3), "no consensus: {gap0} -> {gap1}");
    });
}

#[test]
fn embedding_optimizers_share_lookup_semantics() {
    // swapping the PS optimizer must never change what a *lookup* returns
    // before any update lands (init is optimizer-independent)
    use shadowsync::embedding::TableShard;
    use shadowsync::net::NodeId;
    check("emb-opt-lookup", 20, |g| {
        let rows = g.usize_in(4, 64) as u32;
        let dim = g.usize_in(1, 16);
        let seed = g.rng.next_u64();
        let mk = |opt| TableShard::with_optimizer(1, 0, rows, dim, NodeId(0), seed, opt);
        let a = mk(EmbOptimizer::Adagrad);
        let b = mk(EmbOptimizer::Adam { beta1: 0.9, beta2: 0.999 });
        let c = mk(EmbOptimizer::RmsProp { decay: 0.95 });
        let r = g.usize_in(0, rows as usize - 1) as u32;
        assert_eq!(a.row(r), b.row(r));
        assert_eq!(a.row(r), c.row(r));
    });
}

#[test]
fn ne_is_scale_free_and_one_at_base_rate() {
    check("ne-properties", 50, |g| {
        let p = g.f32_in(0.05, 0.95) as f64;
        let h = -(p * p.ln() + (1.0 - p) * (1.0 - p).ln());
        // base-rate predictor has NE exactly 1
        assert!((normalized_entropy(h, p) - 1.0).abs() < 1e-9);
        // better log-loss => smaller NE, monotonically
        let better = normalized_entropy(h * 0.7, p);
        let worse = normalized_entropy(h * 1.3, p);
        assert!(better < 1.0 && worse > 1.0);
    });
}

#[test]
fn adaptive_gate_skip_rate_converges_to_target() {
    // On synthetic gap distributions the adaptive quantile gate's observed
    // skip rate converges to --delta-skip-target: per round, each chunk's
    // max-gap is a fresh draw from a stationary continuous distribution, so
    // gating at the sketch's target quantile skips ~target of the chunks.
    check("adaptive-gate-convergence", 6, |g| {
        let target = g.f32_in(0.2, 0.8);
        let (chunk, chunks) = (16usize, 64usize);
        let p = chunk * chunks;
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let group = SyncPsGroup::build(&vec![0.0; p], 1, &mut net)
            .with_push_chunking(chunk, 0.0)
            .with_adaptive_gate(target);
        let (mut decisions, mut skips) = (0u64, 0u64);
        for round in 0..50 {
            // resample the local replica around the *current* central so
            // the per-chunk max-gap distribution stays stationary even as
            // pushes move w^PS: chunk gaps are iid uniform amplitudes
            let mut lv = group.central.to_vec();
            for c in 0..chunks {
                let amp = g.f32_in(1e-4, 1.0);
                for x in lv[c * chunk..(c + 1) * chunk].iter_mut() {
                    *x += amp;
                }
            }
            let local = HogwildBuffer::from_slice(&lv);
            let st = group.elastic_sync_stats(&local, 0.5, trainer, &net);
            if round >= 10 {
                // past warmup: the sliding window is fully populated
                decisions += st.chunks_pushed + st.chunks_skipped;
                skips += st.chunks_skipped;
            }
        }
        let rate = skips as f64 / decisions as f64;
        assert!(
            (rate - target as f64).abs() < 0.12,
            "case {}: observed skip rate {rate:.3} vs target {target:.3}",
            g.case
        );
    });
}

#[test]
fn dirty_epoch_scan_skip_never_hides_changed_elements() {
    // The dirty-epoch fast path may only reuse a chunk's cached scan when
    // *no element of that chunk changed since the scan was taken*: under
    // randomized writes, every scan-skipped chunk's contents must be
    // bit-identical to what they were at its last real scan. (Shard
    // boundaries at p=200 with 2 PSs misalign the push chunks against the
    // dirty-epoch grid, so the overlap mapping is exercised too.)
    check("dirty-epoch-scan-safety", 10, |g| {
        let p = 200usize;
        let chunk = 8usize;
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let group = SyncPsGroup::build(&g.vec_normal(p, 1.0), 2, &mut net)
            .with_push_chunking(chunk, 1e-3);
        let local = HogwildBuffer::from_slice(&g.vec_normal(p, 1.0)).with_dirty_epochs(chunk);
        let mut cache = DeltaScanCache::new();
        let ranges = group.push_chunk_ranges();
        // contents of each push chunk as of its last real scan
        let mut at_last_scan: Vec<Vec<f32>> = vec![Vec::new(); ranges.len()];
        let mut total_scan_skips = 0u64;
        for _ in 0..40 {
            // workers: a few random subrange writes between rounds
            for _ in 0..g.usize_in(0, 3) {
                let lo = g.usize_in(0, p - 4);
                let len = g.usize_in(1, 4);
                let noise = g.vec_normal(len, 0.01);
                local.axpy_range(lo, 1.0, &noise);
            }
            let before = local.to_vec();
            let st = group.elastic_sync_cached(&local, 0.4, trainer, &net, &mut cache);
            total_scan_skips += st.chunks_scan_skipped;
            for (k, &(lo, hi)) in ranges.iter().enumerate() {
                if cache.scan_skipped(k) {
                    assert_eq!(
                        &before[lo..hi],
                        &at_last_scan[k][..],
                        "chunk {k} [{lo},{hi}) scan-skipped despite changed elements"
                    );
                } else {
                    // a real scan happened this round: record the contents
                    // it observed (pre-push, == the pre-round snapshot,
                    // since the elastic move runs after the scan)
                    at_last_scan[k] = before[lo..hi].to_vec();
                }
            }
        }
        // replicas converge under the gate, so the fast path must have
        // fired for untouched chunks
        assert!(total_scan_skips > 0, "dirty-epoch fast path never engaged");
    });
}

#[test]
fn repartition_never_loses_or_double_counts_a_chunk() {
    // The cutover's structural safety net: for ANY measured write profile,
    // the weighted replan and the uniform plan it replaces both tile
    // [0, len) exactly — every element belongs to exactly one partition of
    // each plan, so no chunk is dropped or double-synced across a replan.
    check("repartition-tiling", 30, |g| {
        let p = g.usize_in(1, 8);
        let len = g.usize_in(p.max(2), 6_000);
        let granule = g.usize_in(1, 512);
        // random per-block write profile, including long zero stretches
        let blocks = len.div_ceil(granule.max(1));
        let weights: Vec<f64> = (0..blocks)
            .map(|_| if g.bool() { g.f32_in(0.0, 1_000.0) as f64 } else { 0.0 })
            .collect();
        let cost = |lo: usize, hi: usize| -> f64 {
            let mut c = (hi - lo) as f64;
            for (b, w) in weights.iter().enumerate() {
                let blo = b * granule;
                let bhi = ((b + 1) * granule).min(len);
                let overlap = hi.min(bhi).saturating_sub(lo.max(blo));
                c += w * overlap as f64 / (bhi - blo).max(1) as f64;
            }
            c
        };
        let uniform = lpt_contiguous_ranges(len, p, granule);
        let weighted = lpt_contiguous_ranges_weighted(len, p, granule, cost);
        for (name, plan) in [("uniform", &uniform), ("weighted", &weighted)] {
            assert_eq!(plan.len(), p, "{name}");
            assert_eq!(plan[0].lo(), 0, "{name}");
            assert_eq!(plan[p - 1].hi(), len, "{name}");
            for w in plan.windows(2) {
                assert_eq!(w[0].hi(), w[1].lo(), "{name} plan must be contiguous");
            }
            for r in plan.iter() {
                assert!(r.len > 0, "{name} produced an empty partition: {plan:?}");
            }
            // element-level coverage: exactly once each
            let mut owners = vec![0u32; len];
            for r in plan.iter() {
                for o in owners.iter_mut().take(r.hi()).skip(r.lo()) {
                    *o += 1;
                }
            }
            assert!(
                owners.iter().all(|&o| o == 1),
                "{name} plan lost or double-counted an element"
            );
        }
    });
}

#[test]
fn codec_rounds_keep_bytes_exact_and_residuals_bounded() {
    // The wire-codec invariants, as properties over random codecs, shapes,
    // gates, and a seeded drop plan:
    //   1. `metrics.sync_bytes`-style exactness — the stats' delivered
    //      bytes equal the sync-PS NIC counters, codec-compressed, with
    //      gated/dropped chunks on neither side;
    //   2. error-feedback residuals stay bounded (the encode loss is
    //      re-folded each round, never accumulated) and the replica still
    //      reaches consensus with the central copy through the lossy wire;
    //   3. fp32 drains the residual to exact zero.
    check("codec-bytes-and-residuals", 12, |g| {
        let codec = match g.usize_in(0, 3) {
            0 => WireCodec::Fp32,
            1 => WireCodec::Fp16,
            2 => WireCodec::Int8,
            _ => WireCodec::TopK(g.f32_in(0.1, 0.9)),
        };
        let chunk = 8usize;
        let p = chunk * g.usize_in(4, 12);
        let drop_p = if g.bool() { 0.1 } else { 0.0 };
        let plan = std::sync::Arc::new(
            shadowsync::net::fault::FaultPlan::parse(
                &format!("drop:t0@{drop_p}"),
                g.rng.next_u64(),
            )
            .unwrap(),
        );
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let group = SyncPsGroup::build(&vec![0.0; p], 2, &mut net)
            .with_push_chunking(chunk, 0.0)
            .with_push_retry(6, std::time::Duration::from_micros(1));
        let net = net.with_faults(plan);
        let amp = g.f32_in(0.5, 4.0);
        let local = HogwildBuffer::from_slice(&vec![amp; p]);
        let gate = DeltaGate::new(1e-5, 0.0);
        let mut cache = DeltaScanCache::new();
        let mut residual = vec![0.0f32; p];
        let range = ParamRange::full(p);
        let mut recorded = 0u64;
        for _ in 0..30 {
            let st = group.elastic_sync_partition_codec(
                &local,
                range,
                0.4,
                trainer,
                &net,
                &mut cache,
                Some(&gate),
                codec,
                Some(&mut residual),
            );
            recorded += st.bytes;
            // the residual never blows up: error feedback re-encodes the
            // loss, it doesn't stack it. Top-k rotates coordinates in and
            // out, so its residual can briefly hold a few rounds of value.
            let worst = residual.iter().fold(0.0f32, |m, r| m.max(r.abs()));
            assert!(
                worst.is_finite() && worst <= 16.0 * amp,
                "case {}: {codec} residual grew to {worst} (amp {amp})",
                g.case
            );
        }
        assert_eq!(
            recorded,
            net.role_bytes(Role::SyncPs),
            "case {}: {codec} recorded bytes diverged from the NIC counters (drop {drop_p})",
            g.case
        );
        if codec == WireCodec::Fp32 {
            assert!(
                residual.iter().all(|&r| r == 0.0),
                "fp32 must drain the residual to exact zero"
            );
        }
        // consensus through the lossy wire: the replica closed most of its
        // initial gap to the (0-initialized) central copy
        let lv = local.to_vec();
        let cv = group.central.to_vec();
        let gap = shadowsync::tensor::ops::mean_abs_diff(&lv, &cv);
        assert!(gap < 0.35 * amp, "case {}: {codec} stuck at gap {gap} (amp {amp})", g.case);
    });
}

#[test]
fn deterministic_reduce_engines_agree_bit_for_bit() {
    // For ANY (members, length, chunk count, values): the overlapped,
    // striped, and shared-nothing engines all produce means bit-identical
    // to a single-threaded fold of the round's contributions in
    // ring-position order. The mean depends only on the position -> value
    // mapping — never on deposit timing, reduce interleaving, delegation
    // splits, or which engine folds — so swapping the engine can never
    // change a training run's trajectory.
    check("reduce-engines-bit-identical", 6, |g| {
        let n = g.usize_in(2, 5);
        let p = g.usize_in(1, 257);
        let chunks = g.usize_in(1, 8).min(p);
        let rounds = 6usize;
        let seed = g.rng.next_u64();
        // association-order-sensitive fractional values, keyed per
        // (thread, round); the reference fold below reorders each round's
        // contributions by the ring positions the engine actually assigned
        let values = move |label: usize, round: usize| -> Vec<f32> {
            let mut rng = Rng::new(seed ^ ((label as u64) << 32) ^ round as u64);
            (0..p).map(|_| (rng.next_u64() % 1_000_003) as f32 * 1e-3 - 500.0).collect()
        };
        for engine in
            [ReduceEngine::Overlapped, ReduceEngine::Striped, ReduceEngine::SharedNothing]
        {
            let grp = std::sync::Arc::new(
                AllReduceGroup::new(n, p).with_chunks(chunks).with_engine(engine),
            );
            let mut net = Network::new(None);
            let nodes: Vec<_> = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
            let net = std::sync::Arc::new(net);
            let hs: Vec<_> = (0..n)
                .map(|t| {
                    let grp = grp.clone();
                    let net = net.clone();
                    let node = nodes[t];
                    std::thread::spawn(move || {
                        let mut log = Vec::with_capacity(rounds);
                        for r in 0..rounds {
                            let v = values(t, r);
                            let mut buf = v.clone();
                            let out = grp.allreduce_mean(&mut buf, node, &net).unwrap();
                            log.push((out.generation, out.position, out.contributors, v, buf));
                        }
                        grp.leave();
                        log
                    })
                })
                .collect();
            let mut by_gen: std::collections::HashMap<u64, Vec<(usize, Vec<f32>, Vec<f32>)>> =
                std::collections::HashMap::new();
            for h in hs {
                for (gen, pos, parts, v, mean) in h.join().unwrap() {
                    assert_eq!(parts, n, "case {}: {engine} gen {gen}: wrong count", g.case);
                    by_gen.entry(gen).or_default().push((pos, v, mean));
                }
            }
            assert_eq!(by_gen.len(), rounds, "case {}: {engine} round drift", g.case);
            for (gen, mut entries) in by_gen {
                entries.sort_by_key(|e| e.0);
                // single-threaded fold in ring-position order — the same
                // copy -> add -> scale association every engine commits to
                let mut reference = entries[0].1.clone();
                for e in &entries[1..] {
                    for (acc, &x) in reference.iter_mut().zip(&e.1) {
                        *acc += x;
                    }
                }
                let inv = 1.0 / n as f32;
                for acc in reference.iter_mut() {
                    *acc *= inv;
                }
                for (pos, _, mean) in &entries {
                    for (a, b) in mean.iter().zip(&reference) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "case {}: {engine} gen {gen} pos {pos} diverged from the \
                             position-order fold",
                            g.case
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn metrics_totals_are_exact_under_many_threads() {
    use std::sync::Arc;
    let m = Arc::new(Metrics::new());
    let hs: Vec<_> = (0..8)
        .map(|_| {
            let m = m.clone();
            std::thread::spawn(move || {
                for _ in 0..2_000 {
                    m.record_batch(13, 0.25);
                    m.record_sync(7);
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    let s = m.snapshot();
    assert_eq!(s.examples, 8 * 2_000 * 13);
    assert_eq!(s.iterations, 8 * 2_000);
    assert_eq!(s.syncs, 8 * 2_000);
    assert_eq!(s.sync_bytes, 8 * 2_000 * 7);
    assert!((s.avg_loss - 0.25 / 13.0).abs() < 1e-12);
    assert!((m.avg_sync_gap() - 1.0).abs() < 1e-12);
}

#[test]
fn run_config_label_roundtrips_modes() {
    let mut cfg = RunConfig::default();
    cfg.mode = SyncMode::Decaying { start: 100, end: 5 };
    assert_eq!(cfg.label(), "FR-EASGD-100→5");
    cfg.algo = SyncAlgo::Bmuf;
    cfg.mode = SyncMode::Shadow;
    assert_eq!(cfg.label(), "S-BMUF");
}
