//! End-to-end hot-path benchmarks (cargo bench — custom harness since
//! criterion isn't in the offline vendor set).
//!
//! These are the per-stage instruments for the §Perf pass: the worker-loop
//! stages (batch generation, embedding lookup, XLA train step, Hogwild
//! Adagrad apply, embedding update) and the full loop, per preset.
//! `BENCH_MS` overrides the per-benchmark budget (default 1500 ms).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use shadowsync::config::{EmbeddingConfig, ModelMeta};
use shadowsync::data::{Batch, TeacherModel};
use shadowsync::embedding::EmbeddingSystem;
use shadowsync::metrics::Metrics;
use shadowsync::net::{Network, Role};
use shadowsync::optim::HogwildAdagrad;
use shadowsync::runtime::Runtime;
use shadowsync::tensor::HogwildBuffer;
use shadowsync::util::bench::bench;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// SPSC ring microbenchmarks: single-threaded enqueue/dequeue (the pure
/// protocol cost, no contention by construction) and a cross-thread
/// delegation round-trip mirroring the shared-nothing engine's grant →
/// fold → return handshake over a pair of rings.
fn spsc_benches(budget: Duration) {
    use shadowsync::sync::ring::SpscRing;

    // raw enqueue + dequeue of an owned message, uncontended
    let ring: SpscRing<u64> = SpscRing::new(64);
    bench("spsc/enqueue_dequeue", budget, || {
        ring.try_push(7).unwrap();
        std::hint::black_box(ring.try_pop().unwrap());
    });

    // delegation round-trip: a "grant" (chunk range) travels to a borrower
    // thread over one ring; the borrower sends the folded stripe back over
    // another. One iteration = one full out-and-back, like one delegated
    // sub-partition in a shared-nothing round.
    const STRIPE: usize = 4096;
    let grants: Arc<SpscRing<(usize, usize)>> = Arc::new(SpscRing::new(2));
    let returns: Arc<SpscRing<Vec<f32>>> = Arc::new(SpscRing::new(2));
    let stop = Arc::new(AtomicBool::new(false));
    let borrower = {
        let (grants, returns, stop) = (grants.clone(), returns.clone(), stop.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match grants.try_pop() {
                    Some((lo, hi)) => {
                        let mut out = vec![0.5f32; hi - lo];
                        for x in &mut out {
                            *x *= 0.25; // stand-in for the fold's scale pass
                        }
                        let mut msg = out;
                        while let Err(back) = returns.try_push(msg) {
                            msg = back;
                            std::thread::yield_now();
                        }
                    }
                    None => std::hint::spin_loop(),
                }
            }
        })
    };
    bench("spsc/delegation_round_trip", budget, || {
        grants.try_push((0, STRIPE)).unwrap();
        let stripe = loop {
            if let Some(s) = returns.try_pop() {
                break s;
            }
            std::hint::spin_loop();
        };
        std::hint::black_box(stripe.len());
    });
    stop.store(true, Ordering::Relaxed);
    borrower.join().unwrap();
    println!();
}

fn main() {
    let budget = Duration::from_millis(
        std::env::var("BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(1500),
    );

    // SPSC ring hot path (no artifacts needed): raw enqueue/dequeue cost,
    // then the shared-nothing delegation round-trip — a grant message out,
    // a folded stripe back — which bounds how fine sub-partition delegation
    // can slice before message cost eats the parallelism.
    spsc_benches(budget);

    if !artifacts_dir().join("tiny.meta.json").exists() {
        eprintln!("run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();

    for preset in ["tiny", "model_a", "model_c"] {
        let meta = match ModelMeta::load(&artifacts_dir(), preset) {
            Ok(m) => m,
            Err(_) => continue,
        };
        let emb_cfg = EmbeddingConfig::default();
        let model = rt.load_model(&meta, &artifacts_dir()).unwrap();
        let mut net = Network::new(None);
        let metrics = Metrics::new();
        let trainer = net.add_node(Role::Trainer);
        let embeddings = EmbeddingSystem::build(&meta, &emb_cfg, 2, &mut net, 7).unwrap();
        let teacher = TeacherModel::new(&meta, &emb_cfg, 7);
        let mut batch = Batch::empty(&meta, &emb_cfg);
        let ids: Vec<u64> = (0..meta.batch as u64).collect();
        teacher.fill_batch(&mut batch, &ids);

        let replica = HogwildBuffer::from_slice(&model.w0);
        let opt = HogwildAdagrad::new(meta.num_params, 0.02, 1e-8);
        let mut io = model.new_io();

        let r = bench(&format!("{preset}/gen_batch"), budget, || {
            teacher.fill_batch(&mut batch, &ids);
            std::hint::black_box(&batch);
        });
        let gen_eps = r.throughput(meta.batch as f64);

        bench(&format!("{preset}/emb_lookup"), budget, || {
            embeddings.lookup_batch(
                &batch.indices,
                batch.size,
                &mut io.pooled_host,
                trainer,
                &net,
                &metrics,
            );
            std::hint::black_box(&io.pooled_host);
        });

        let r = bench(&format!("{preset}/xla_train_step"), budget, || {
            replica.read_into(&mut io.w_host);
            let loss = model.train_step(&mut io, &batch.dense, &batch.labels).unwrap();
            std::hint::black_box(loss);
        });
        let step_eps = r.throughput(meta.batch as f64);

        bench(&format!("{preset}/adagrad_apply"), budget, || {
            opt.apply(&replica, &io.grad_w);
        });

        bench(&format!("{preset}/emb_update"), budget, || {
            embeddings.update_batch(&batch.indices, batch.size, &io.grad_emb, trainer, &net, &metrics);
        });

        let r = bench(&format!("{preset}/full_worker_iteration"), budget, || {
            embeddings.lookup_batch(
                &batch.indices,
                batch.size,
                &mut io.pooled_host,
                trainer,
                &net,
                &metrics,
            );
            replica.read_into(&mut io.w_host);
            let loss = model.train_step(&mut io, &batch.dense, &batch.labels).unwrap();
            opt.apply(&replica, &io.grad_w);
            embeddings.update_batch(&batch.indices, batch.size, &io.grad_emb, trainer, &net, &metrics);
            std::hint::black_box(loss);
        });
        println!(
            "  -> {preset}: single-thread EPS {:.0} (xla-only {:.0}, gen {:.0})\n",
            r.throughput(meta.batch as f64),
            step_eps,
            gen_eps,
        );
    }
}
