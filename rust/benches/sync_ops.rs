//! Synchronization-primitive benchmarks: what a shadow round costs at
//! various parameter sizes, and how the AllReduce scales with membership.
//! These correspond to the sync columns of the paper's Fig. 5/6 and feed
//! the §Perf iteration log.

use std::sync::Arc;
use std::time::Duration;

use shadowsync::metrics::Metrics;
use shadowsync::net::{Network, Role};
use shadowsync::sync::{AllReduceGroup, SyncPsGroup};
use shadowsync::tensor::{ops, HogwildBuffer};
use shadowsync::util::bench::bench;

fn main() {
    let budget = Duration::from_millis(
        std::env::var("BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(1200),
    );

    // EASGD elastic round at dense-param sizes from tiny to paper-ish
    for p in [537usize, 9_009, 42_585, 1_000_000] {
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group = SyncPsGroup::build(&vec![0.1; p], 2, &mut net);
        let local = HogwildBuffer::from_slice(&vec![0.2; p]);
        let r = bench(&format!("easgd_round/P={p}"), budget, || {
            std::hint::black_box(group.elastic_sync(&local, 0.5, tnode, &net));
        });
        println!("  -> {:.1} M params/s\n", p as f64 / (r.mean_ns / 1e3) );
    }

    // Hogwild snapshot + interpolation primitives
    for p in [9_009usize, 1_000_000] {
        let buf = HogwildBuffer::from_slice(&vec![1.0; p]);
        let mut out = vec![0f32; p];
        bench(&format!("replica_snapshot/P={p}"), budget, || {
            buf.read_into(&mut out);
            std::hint::black_box(&out);
        });
        let target = vec![0.5f32; p];
        bench(&format!("lerp_toward/P={p}"), budget, || {
            buf.lerp_toward_slice(&target, 0.01);
        });
        let mut a = vec![1.0f32; p];
        let b = vec![2.0f32; p];
        bench(&format!("plain_lerp/P={p}"), budget, || {
            ops::lerp(&mut a, &b, 0.01);
            std::hint::black_box(&a);
        });
    }

    // AllReduce across real threads (the MA/BMUF shadow collective)
    for members in [2usize, 4] {
        let p = 42_585;
        let group = Arc::new(AllReduceGroup::new(members, p));
        let metrics = Arc::new(Metrics::new());
        let _ = &metrics;
        // peers loop until told to stop
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut peers = Vec::new();
        for _ in 1..members {
            let g = group.clone();
            let stop = stop.clone();
            peers.push(std::thread::spawn(move || {
                let mut v = vec![1.0f32; p];
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    if g.allreduce_mean(&mut v).is_err() {
                        break;
                    }
                }
                g.leave();
            }));
        }
        let mut mine = vec![2.0f32; p];
        bench(&format!("allreduce_mean/n={members}/P={p}"), budget, || {
            group.allreduce_mean(&mut mine).unwrap();
            std::hint::black_box(&mine);
        });
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        group.leave(); // unblock any pending round, then collect peers
        for h in peers {
            h.join().unwrap();
        }
    }
    println!("\nsync_ops done");
}
