//! Synchronization-primitive benchmarks: what a shadow round costs at
//! various parameter sizes, how the AllReduce scales with membership, and
//! what the lock-striped chunk-parallel reduction engine buys over the
//! single-mutex serial baseline. These correspond to the sync columns of
//! the paper's Fig. 5/6 and feed the §Perf iteration log.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use shadowsync::net::{Network, Role};
use shadowsync::sync::{AllReduceGroup, DeltaScanCache, ReduceEngine, SyncPsGroup};
use shadowsync::tensor::{ops, HogwildBuffer};
use shadowsync::util::bench::{bench, BenchResult};
use shadowsync::util::json::Json;

fn main() {
    let budget = Duration::from_millis(
        std::env::var("BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(1200),
    );
    // `--json`: machine-readable mode for the CI bench job — run only the
    // engine × members A/B matrix and write `BENCH_sync.json` next to the
    // manifest so the workflow can upload it as an artifact
    let json_mode = std::env::args().any(|a| a == "--json");
    if json_mode {
        let records = engine_members_matrix(budget);
        let path = "BENCH_sync.json";
        std::fs::write(path, render_bench_json(&records).to_string())
            .expect("writing BENCH_sync.json");
        println!("wrote {path} ({} records)", records.len());
        return;
    }

    // EASGD elastic round at dense-param sizes from tiny to paper-ish
    for p in [537usize, 9_009, 42_585, 1_000_000] {
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group = SyncPsGroup::build(&vec![0.1; p], 2, &mut net);
        let local = HogwildBuffer::from_slice(&vec![0.2; p]);
        let r = bench(&format!("easgd_round/P={p}"), budget, || {
            std::hint::black_box(group.elastic_sync(&local, 0.5, tnode, &net));
        });
        println!("  -> {:.1} M params/s\n", p as f64 / (r.mean_ns / 1e3));
    }

    // chunked pushes with a delta gate: converged replicas skip chunks, so
    // the scan is the whole cost and the wire moves (nearly) nothing
    for (delta, tag) in [(0.0f32, "off"), (1e-3, "on")] {
        let p = 1_000_000usize;
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group =
            SyncPsGroup::build(&vec![0.1; p], 2, &mut net).with_push_chunking(4096, delta);
        let local = HogwildBuffer::from_slice(&vec![0.1; p]); // already in sync
        let r = bench(&format!("easgd_round_delta_{tag}/P={p}"), budget, || {
            std::hint::black_box(group.elastic_sync_stats(&local, 0.5, tnode, &net));
        });
        let t = group.traffic();
        println!(
            "  -> {:.1} M params/s, push fraction {:.3}\n",
            p as f64 / (r.mean_ns / 1e3),
            t.push_fraction(),
        );
    }

    // The adaptive quantile gate pays one sketch insert per scanned chunk
    // plus one sorted-window quantile query per round on top of the scan.
    {
        let p = 1_000_000usize;
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group = SyncPsGroup::build(&vec![0.1; p], 2, &mut net)
            .with_push_chunking(4096, 0.0)
            .with_adaptive_gate(0.5);
        let local = HogwildBuffer::from_slice(&vec![0.1; p]);
        let r = bench(&format!("easgd_round_adaptive_gate/P={p}"), budget, || {
            std::hint::black_box(group.elastic_sync_stats(&local, 0.5, tnode, &net));
        });
        println!(
            "  -> {:.1} M params/s, skip fraction {:.3}\n",
            p as f64 / (r.mean_ns / 1e3),
            group.traffic().skip_fraction(),
        );
    }

    // Scan-vs-dirty-skip A/B: a converged, *idle* replica (the shadow
    // thread outpacing the workers). Without dirty epochs every round
    // re-reads all 1M elements just to decide "skip"; with them, the gate
    // decision reuses the cached scan and the round cost collapses to the
    // per-chunk bookkeeping.
    for (dirty, tag) in [(false, "full_scan"), (true, "dirty_skip")] {
        let p = 1_000_000usize;
        let chunk = 4096usize;
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group =
            SyncPsGroup::build(&vec![0.1; p], 2, &mut net).with_push_chunking(chunk, 1e-3);
        let mut local = HogwildBuffer::from_slice(&vec![0.1; p]);
        if dirty {
            local = local.with_dirty_epochs(chunk);
        }
        let mut cache = DeltaScanCache::new();
        let r = bench(&format!("easgd_gate_{tag}/P={p}"), budget, || {
            std::hint::black_box(group.elastic_sync_cached(&local, 0.5, tnode, &net, &mut cache));
        });
        let t = group.traffic();
        println!(
            "  -> {:.1} M params/s, scan-skip fraction {:.3}\n",
            p as f64 / (r.mean_ns / 1e3),
            t.scan_skip_fraction(),
        );
    }

    // Hogwild snapshot + interpolation primitives
    for p in [9_009usize, 1_000_000] {
        let buf = HogwildBuffer::from_slice(&vec![1.0; p]);
        let mut out = vec![0f32; p];
        bench(&format!("replica_snapshot/P={p}"), budget, || {
            buf.read_into(&mut out);
            std::hint::black_box(&out);
        });
        let target = vec![0.5f32; p];
        bench(&format!("lerp_toward/P={p}"), budget, || {
            buf.lerp_toward_slice(&target, 0.01);
        });
        let mut a = vec![1.0f32; p];
        let b = vec![2.0f32; p];
        bench(&format!("plain_lerp/P={p}"), budget, || {
            ops::lerp(&mut a, &b, 0.01);
            std::hint::black_box(&a);
        });
    }

    // AllReduce across real threads (the MA/BMUF shadow collective):
    // membership scaling at a mid-size vector, then flat (C=1) vs chunked
    // rings at 1M+ params — the schedule whose per-hop transfers flow
    // through the Network fabric.
    for (members, p, chunks) in [
        (2usize, 42_585usize, 1usize),
        (4, 42_585, 1),
        (4, 1_048_576, 1),  // flat ring, paper-ish dense size
        (4, 1_048_576, 8),  // chunked ring, same size
        (4, 1_048_576, 64), // fine-grained chunking
    ] {
        bench_allreduce(members, p, chunks, ReduceEngine::Striped, budget);
    }

    // The headline A/B: serial-mutex contribute (every member's full-vector
    // add serialized under one lock) vs the single-bank lock-striped engine
    // (deposits for round N+1 help round N drain first) vs the overlapped
    // double-buffered engine (off-parity deposits land immediately) vs the
    // shared-nothing engine (SPSC deposit rings + delegated sub-partition
    // folding), 1M params x {2, 4, 8, 16} members. Serial round time grows
    // ~linearly with members; striped stays ~flat; overlapped shaves the
    // drain-wait off striped; shared-nothing should pull ahead at 8/16
    // where deposit-bank contention starts to bite.
    engine_members_matrix(budget);
    println!("\nsync_ops done");
}

/// The engine × members A/B matrix (1M params, 16 chunks) — both the
/// human-readable headline run and the `--json` CI artifact come from here
/// so the two can never measure different configurations.
fn engine_members_matrix(budget: Duration) -> Vec<(ReduceEngine, usize, BenchResult)> {
    const P: usize = 1_048_576;
    const CHUNKS: usize = 16;
    println!(
        "\n== serial vs striped vs overlapped vs shared-nothing contribute \
         (1M params, 16 chunks) =="
    );
    let mut records = Vec::new();
    for members in [2usize, 4, 8, 16] {
        for engine in [
            ReduceEngine::SerialMutex,
            ReduceEngine::Striped,
            ReduceEngine::Overlapped,
            ReduceEngine::SharedNothing,
        ] {
            let r = bench_allreduce(members, P, CHUNKS, engine, budget);
            records.push((engine, members, r));
        }
    }
    records
}

/// `BENCH_sync.json`: `{"bench": ..., "params": P, "chunks": C,
/// "results": [{"engine", "members", "mean_ns", "p50_ns", ...}]}`.
fn render_bench_json(records: &[(ReduceEngine, usize, BenchResult)]) -> Json {
    let results: Vec<Json> = records
        .iter()
        .map(|(engine, members, r)| {
            let mut o = BTreeMap::new();
            o.insert("engine".to_string(), Json::Str(engine.to_string()));
            o.insert("members".to_string(), Json::Num(*members as f64));
            o.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
            o.insert("p50_ns".to_string(), Json::Num(r.p50_ns));
            o.insert("p95_ns".to_string(), Json::Num(r.p95_ns));
            o.insert("p99_ns".to_string(), Json::Num(r.p99_ns));
            o.insert("iters".to_string(), Json::Num(r.iters as f64));
            Json::Obj(o)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("allreduce_mean".to_string()));
    top.insert("params".to_string(), Json::Num(1_048_576.0));
    top.insert("chunks".to_string(), Json::Num(16.0));
    top.insert("results".to_string(), Json::Arr(results));
    Json::Obj(top)
}

/// One AllReduce configuration: `members` looping threads on a shared
/// chunked ring group, real per-hop traffic accounted on per-member NICs.
fn bench_allreduce(
    members: usize,
    p: usize,
    chunks: usize,
    engine: ReduceEngine,
    budget: Duration,
) -> BenchResult {
    let group = Arc::new(AllReduceGroup::new(members, p).with_chunks(chunks).with_engine(engine));
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..members).map(|_| net.add_node(Role::Trainer)).collect();
    let net = Arc::new(net);
    // peers loop until told to stop
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut peers = Vec::new();
    for node in nodes.iter().skip(1).copied() {
        let g = group.clone();
        let net = net.clone();
        let stop = stop.clone();
        peers.push(std::thread::spawn(move || {
            let mut v = vec![1.0f32; p];
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                if g.allreduce_mean(&mut v, node, &net).is_err() {
                    break;
                }
            }
            g.leave();
        }));
    }
    let mut mine = vec![2.0f32; p];
    let (tx0, rounds0) = (net.tx(nodes[0]), group.completed_rounds());
    let r = bench(
        &format!("allreduce_mean/{engine}/n={members}/P={p}/C={chunks}"),
        budget,
        || {
            group.allreduce_mean(&mut mine, nodes[0], &net).unwrap();
            std::hint::black_box(&mine);
        },
    );
    let rounds = (group.completed_rounds() - rounds0).max(1);
    println!(
        "  -> {:.1} M params/s, measured ring tx {} B/member/round (formula {})\n",
        p as f64 / (r.mean_ns / 1e3),
        (net.tx(nodes[0]) - tx0) / rounds,
        group.ring_bytes_per_member(members),
    );
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    group.leave(); // unblock any pending round, then collect peers
    for h in peers {
        h.join().unwrap();
    }
    r
}
