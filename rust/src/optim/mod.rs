//! Optimizers.
//!
//! The paper's setup: Adagrad on both the dense replicas (applied Hogwild
//! within a trainer) and the embedding tables (applied Hogwild on the
//! embedding PSs, auxiliary state collocated with the rows, §3.2), plus the
//! block-momentum update used by the BMUF global step.

use std::sync::Arc;

use crate::tensor::HogwildBuffer;

/// Dense Adagrad over a Hogwild-shared parameter vector.
///
/// Both the parameters and the squared-gradient accumulator live in shared
/// lock-free buffers; worker threads apply updates racily (the paper's
/// within-trainer Hogwild, which deliberately breaks the sparse-access
/// assumption of the original Hogwild paper).
pub struct HogwildAdagrad {
    pub lr: f32,
    pub eps: f32,
    accum: Arc<HogwildBuffer>,
}

impl HogwildAdagrad {
    pub fn new(num_params: usize, lr: f32, eps: f32) -> Self {
        Self { lr, eps, accum: Arc::new(HogwildBuffer::zeros(num_params)) }
    }

    /// Apply one gradient to the shared parameters: for every i,
    /// `G_i += g_i^2; w_i -= lr * g_i / (sqrt(G_i) + eps)`. Racy by design.
    pub fn apply(&self, params: &HogwildBuffer, grad: &[f32]) {
        use std::sync::atomic::Ordering::Relaxed;
        debug_assert_eq!(params.len(), grad.len());
        debug_assert_eq!(self.accum.len(), grad.len());
        // §Perf: zipped atomic slices — one bounds check per batch, not 4/elt
        let n = grad.len();
        let accum = self.accum.range(0, n);
        let ps = params.range(0, n);
        for ((&g, a), p) in grad.iter().zip(accum).zip(ps) {
            let acc = f32::from_bits(a.load(Relaxed)) + g * g;
            a.store(acc.to_bits(), Relaxed);
            let step = self.lr * g / (acc.sqrt() + self.eps);
            let v = f32::from_bits(p.load(Relaxed)) - step;
            p.store(v.to_bits(), Relaxed);
        }
        // writes went through the raw range view, so record them in the
        // replica's dirty epochs (no-op on untracked buffers)
        params.mark_dirty_range(0, n);
    }

    pub fn accum(&self) -> &HogwildBuffer {
        &self.accum
    }
}

/// Block-momentum state for the BMUF global step (Algorithm 4 comment line:
/// "can do momentum update, Nesterov acceleration etc.").
pub struct BlockMomentum {
    pub eta: f32,
    pub momentum: f32,
    velocity: Vec<f32>,
}

impl BlockMomentum {
    pub fn new(num_params: usize, eta: f32, momentum: f32) -> Self {
        Self { eta, momentum, velocity: vec![0.0; num_params] }
    }

    /// `v = mu*v + eta*desc; global += v`. Plain (non-shared) vectors: the
    /// BMUF global copy is private to one shadow thread.
    pub fn step(&mut self, global: &mut [f32], desc: &[f32]) {
        debug_assert_eq!(global.len(), desc.len());
        for ((v, g), &d) in self.velocity.iter_mut().zip(global.iter_mut()).zip(desc) {
            *v = self.momentum * *v + self.eta * d;
            *g += *v;
        }
    }

    /// The momentum state, for carrying across a strategy migration.
    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Reinstall carried momentum state (must match the partition size).
    pub fn set_velocity(&mut self, v: Vec<f32>) {
        debug_assert_eq!(v.len(), self.velocity.len(), "carried velocity must fit");
        if v.len() == self.velocity.len() {
            self.velocity = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn adagrad_descends_quadratic() {
        // minimize f(w) = 0.5*|w - target|^2 with grad = w - target
        let n = 16;
        let params = HogwildBuffer::from_slice(&vec![0.0; n]);
        let target = vec![3.0f32; n];
        let opt = HogwildAdagrad::new(n, 0.5, 1e-8);
        let mut grad = vec![0.0f32; n];
        for _ in 0..800 {
            for i in 0..n {
                grad[i] = params.get(i) - target[i];
            }
            opt.apply(&params, &grad);
        }
        for v in params.to_vec() {
            assert!((v - 3.0).abs() < 0.15, "v={v}");
        }
    }

    #[test]
    fn adagrad_step_shrinks_with_accumulation() {
        let params = HogwildBuffer::from_slice(&[0.0]);
        let opt = HogwildAdagrad::new(1, 0.1, 1e-8);
        opt.apply(&params, &[1.0]);
        let first = -params.get(0);
        opt.apply(&params, &[1.0]);
        let second = -params.get(0) - first;
        assert!(second < first, "second step {second} !< first {first}");
        assert!((first - 0.1).abs() < 1e-4); // lr * g / sqrt(g^2)
    }

    #[test]
    fn block_momentum_accumulates() {
        let mut bm = BlockMomentum::new(2, 1.0, 0.5);
        let mut global = vec![0.0f32; 2];
        bm.step(&mut global, &[1.0, 2.0]);
        assert_eq!(global, vec![1.0, 2.0]);
        bm.step(&mut global, &[1.0, 2.0]);
        // v = 0.5*1 + 1 = 1.5 -> global = 2.5
        assert_eq!(global, vec![2.5, 5.0]);
    }

    #[test]
    fn zero_momentum_is_plain_step() {
        check("bmuf-eta", 20, |g| {
            let n = g.usize_in(1, 16);
            let eta = g.f32_in(0.1, 2.0);
            let desc = g.vec_normal(n, 1.0);
            let mut bm = BlockMomentum::new(n, eta, 0.0);
            let mut global = vec![0.0f32; n];
            bm.step(&mut global, &desc);
            for (gi, di) in global.iter().zip(&desc) {
                assert!((gi - eta * di).abs() < 1e-5);
            }
        });
    }
}
