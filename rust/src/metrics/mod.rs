//! Training metrics: EPS, loss, normalized entropy, sync-gap (paper Eq. 2),
//! and network byte accounting.
//!
//! All counters are lock-free atomics so worker threads on the hot path pay
//! one `fetch_add` per batch; aggregation happens off-path.

use std::time::Instant;

use crate::sync::prim::{AtomicU64, Mutex, Ordering::Relaxed};

/// f64 accumulator over an AtomicU64 (CAS add on bits) — exact, unlike the
/// Hogwild parameter buffers.
#[derive(Debug, Default)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    pub fn add(&self, d: f64) {
        let mut cur = self.0.load(Relaxed);
        loop {
            let new = (f64::from_bits(cur) + d).to_bits();
            match self.0.compare_exchange_weak(cur, new, Relaxed, Relaxed) {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Relaxed))
    }
}

/// Shared run-wide counters.
#[derive(Debug, Default)]
pub struct Metrics {
    /// examples fully processed (fwd+bwd applied)
    pub examples: AtomicU64,
    /// worker-thread iterations (batches)
    pub iterations: AtomicU64,
    /// summed training loss (loss_sum outputs)
    pub loss_sum: AtomicF64,
    /// examples contributing to loss_sum
    pub loss_examples: AtomicU64,
    /// sync rounds completed (per Eq. 2's "num of EASGD syncs")
    pub syncs: AtomicU64,
    /// bytes moved for synchronization (sync PS or AllReduce traffic)
    pub sync_bytes: AtomicU64,
    /// delta-gated push chunks that moved over the wire
    pub sync_chunks_pushed: AtomicU64,
    /// delta-gated push chunks skipped (zero bytes, both legs)
    pub sync_chunks_skipped: AtomicU64,
    /// push chunks whose gap scan was skipped via dirty epochs
    pub sync_scan_skipped: AtomicU64,
    /// push-leg transfer retries issued against a faulted fabric (a chunk
    /// whose retries are exhausted lands in `sync_chunks_skipped`)
    pub sync_push_retries: AtomicU64,
    /// bytes moved for embedding lookups+updates
    pub embedding_bytes: AtomicU64,
    /// per-partition sync round counts of the partitioned shadow fabric
    /// (index = partition; empty until a shadow pool records a round).
    /// A mutex, not atomics: rounds are off the training hot path and the
    /// partition count is a run-time knob
    partition_syncs: Mutex<Vec<u64>>,
    /// per-partition sync bytes (index = partition), recorded by every
    /// strategy alongside `sync_bytes` — the measured byte shares that let
    /// `sim/` price heterogeneous plans and `--algo-map`s exactly
    partition_sync_bytes: Mutex<Vec<u64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, batch: usize, loss_sum: f64) {
        self.examples.fetch_add(batch as u64, Relaxed);
        self.iterations.fetch_add(1, Relaxed);
        self.loss_sum.add(loss_sum);
        self.loss_examples.fetch_add(batch as u64, Relaxed);
    }

    pub fn record_sync(&self, bytes: u64) {
        self.syncs.fetch_add(1, Relaxed);
        self.sync_bytes.fetch_add(bytes, Relaxed);
    }

    /// Record one round's delta-gate chunk outcomes (the live skip-rate
    /// columns of the experiment reports).
    pub fn record_sync_chunks(&self, pushed: u64, skipped: u64, scan_skipped: u64) {
        self.sync_chunks_pushed.fetch_add(pushed, Relaxed);
        self.sync_chunks_skipped.fetch_add(skipped, Relaxed);
        self.sync_scan_skipped.fetch_add(scan_skipped, Relaxed);
    }

    /// Record push-leg retries issued while degrading around a faulted
    /// fabric (see `SyncPsGroup::with_push_retry`).
    pub fn record_sync_retries(&self, retries: u64) {
        self.sync_push_retries.fetch_add(retries, Relaxed);
    }

    /// Record delivered embedding-tier wire bytes (lookups, updates,
    /// prefetches, hot-key shard migrations). The embedding tier's
    /// byte-exactness invariant is `embedding_bytes` == the embedding-PS
    /// NIC counters, so callers record exactly what `Network::try_transfer`
    /// delivered — dropped legs record nothing here, matching the zero NIC
    /// bytes they moved.
    pub fn record_embedding_bytes(&self, bytes: u64) {
        self.embedding_bytes.fetch_add(bytes, Relaxed);
    }

    /// Record one completed shadow round of `partition` (driven by the
    /// shadow pool; grows the table on first sight of a partition).
    pub fn record_partition_sync(&self, partition: usize) {
        let mut v = self.partition_syncs.lock().unwrap();
        if partition >= v.len() {
            v.resize(partition + 1, 0);
        }
        v[partition] += 1;
    }

    /// Record one sync round's measured bytes under its partition index
    /// (strategies call this alongside [`Metrics::record_sync`]; grows the
    /// table on first sight of a partition).
    pub fn record_partition_sync_bytes(&self, partition: usize, bytes: u64) {
        let mut v = self.partition_sync_bytes.lock().unwrap();
        if partition >= v.len() {
            v.resize(partition + 1, 0);
        }
        v[partition] += bytes;
    }

    /// Per-partition average sync gap (paper Eq. 2, per partition):
    /// trainer-level iterations per completed round of each partition.
    /// Empty when no shadow pool ran (foreground modes).
    pub fn partition_sync_gaps(&self) -> Vec<f64> {
        let iters = self.iterations.load(Relaxed) as f64;
        self.partition_syncs
            .lock()
            .unwrap()
            .iter()
            .map(|&s| if s == 0 { f64::INFINITY } else { iters / s as f64 })
            .collect()
    }

    /// Average training loss per example so far.
    pub fn avg_loss(&self) -> f64 {
        let n = self.loss_examples.load(Relaxed);
        if n == 0 {
            f64::NAN
        } else {
            self.loss_sum.get() / n as f64
        }
    }

    /// Paper Eq. 2: avg sync gap = iterations/sec ÷ syncs/sec — computed on
    /// totals (the run is one pass, so the ratio of totals is the average).
    pub fn avg_sync_gap(&self) -> f64 {
        let s = self.syncs.load(Relaxed);
        if s == 0 {
            f64::INFINITY
        } else {
            self.iterations.load(Relaxed) as f64 / s as f64
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            examples: self.examples.load(Relaxed),
            iterations: self.iterations.load(Relaxed),
            avg_loss: self.avg_loss(),
            syncs: self.syncs.load(Relaxed),
            sync_bytes: self.sync_bytes.load(Relaxed),
            sync_chunks_pushed: self.sync_chunks_pushed.load(Relaxed),
            sync_chunks_skipped: self.sync_chunks_skipped.load(Relaxed),
            sync_scan_skipped: self.sync_scan_skipped.load(Relaxed),
            sync_push_retries: self.sync_push_retries.load(Relaxed),
            embedding_bytes: self.embedding_bytes.load(Relaxed),
            partition_syncs: self.partition_syncs.lock().unwrap().clone(),
            partition_sync_bytes: self.partition_sync_bytes.lock().unwrap().clone(),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub examples: u64,
    pub iterations: u64,
    pub avg_loss: f64,
    pub syncs: u64,
    pub sync_bytes: u64,
    pub sync_chunks_pushed: u64,
    pub sync_chunks_skipped: u64,
    pub sync_scan_skipped: u64,
    /// push-leg retries issued against a faulted fabric
    pub sync_push_retries: u64,
    pub embedding_bytes: u64,
    /// per-partition sync round counts (empty when no shadow pool ran)
    pub partition_syncs: Vec<u64>,
    /// per-partition sync bytes (empty when nothing recorded per partition)
    pub partition_sync_bytes: Vec<u64>,
}

impl MetricsSnapshot {
    /// Live delta-gate skip rate: skipped / (pushed + skipped) chunks
    /// (0 when no chunked gated pushes ran).
    pub fn sync_skip_rate(&self) -> f64 {
        let total = self.sync_chunks_pushed + self.sync_chunks_skipped;
        if total == 0 {
            0.0
        } else {
            self.sync_chunks_skipped as f64 / total as f64
        }
    }

    /// Measured per-partition byte shares (normalized to sum to 1) — the
    /// cross-algorithm companion of
    /// `PsTrafficSnapshot::partition_byte_shares`: EASGD partitions report
    /// sync-PS push bytes, MA/BMUF partitions report ring tx bytes. Empty
    /// when nothing was recorded per partition.
    pub fn partition_byte_shares(&self) -> Vec<f64> {
        crate::util::byte_shares(&self.partition_sync_bytes)
    }
}

/// EPS meter: examples/sec over the whole run (paper Definition 1).
pub struct EpsMeter {
    start: Instant,
}

impl EpsMeter {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn eps(&self, examples: u64) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt == 0.0 {
            0.0
        } else {
            examples as f64 / dt
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Binary-entropy normalizer: normalized entropy = avg logloss / H(base_ctr)
/// (He et al. 2014, the metric family the paper reports).
pub fn normalized_entropy(avg_logloss: f64, base_ctr: f64) -> f64 {
    let p = base_ctr.clamp(1e-9, 1.0 - 1e-9);
    let h = -(p * p.ln() + (1.0 - p) * (1.0 - p).ln());
    avg_logloss / h
}

/// Evaluation aggregate: summed logloss + calibration inputs.
#[derive(Debug, Default, Clone, Copy)]
pub struct EvalAccum {
    pub loss_sum: f64,
    pub pred_sum: f64,
    pub label_sum: f64,
    pub examples: u64,
}

impl EvalAccum {
    pub fn add(&mut self, loss_sum: f64, pred_sum: f64, label_sum: f64, n: u64) {
        self.loss_sum += loss_sum;
        self.pred_sum += pred_sum;
        self.label_sum += label_sum;
        self.examples += n;
    }

    pub fn avg_loss(&self) -> f64 {
        self.loss_sum / self.examples.max(1) as f64
    }

    pub fn base_ctr(&self) -> f64 {
        self.label_sum / self.examples.max(1) as f64
    }

    /// predicted clicks / actual clicks — 1.0 is perfectly calibrated.
    pub fn calibration(&self) -> f64 {
        self.pred_sum / self.label_sum.max(1e-9)
    }

    pub fn ne(&self) -> f64 {
        normalized_entropy(self.avg_loss(), self.base_ctr())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn atomic_f64_exact_under_contention() {
        let a = Arc::new(AtomicF64::default());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let a = a.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        a.add(0.5);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(a.get(), 20_000.0);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(32, 22.4);
        m.record_batch(32, 20.8);
        let s = m.snapshot();
        assert_eq!(s.examples, 64);
        assert_eq!(s.iterations, 2);
        assert!((s.avg_loss - 43.2 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn sync_gap_eq2() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_batch(8, 1.0);
        }
        for _ in 0..20 {
            m.record_sync(64);
        }
        assert_eq!(m.avg_sync_gap(), 5.0);
        assert_eq!(m.snapshot().sync_bytes, 20 * 64);
        let empty = Metrics::new();
        assert!(empty.avg_sync_gap().is_infinite());
    }

    #[test]
    fn sync_chunk_counters_and_skip_rate() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().sync_skip_rate(), 0.0, "no gated pushes yet");
        m.record_sync_chunks(3, 1, 1);
        m.record_sync_chunks(0, 4, 4);
        let s = m.snapshot();
        assert_eq!(s.sync_chunks_pushed, 3);
        assert_eq!(s.sync_chunks_skipped, 5);
        assert_eq!(s.sync_scan_skipped, 5);
        assert!((s.sync_skip_rate() - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn embedding_bytes_accumulate() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().embedding_bytes, 0);
        m.record_embedding_bytes(96);
        m.record_embedding_bytes(32);
        assert_eq!(m.snapshot().embedding_bytes, 128);
    }

    #[test]
    fn partition_sync_counters_and_gaps() {
        let m = Metrics::new();
        assert!(m.partition_sync_gaps().is_empty(), "no partitions yet");
        for _ in 0..10 {
            m.record_batch(8, 1.0);
        }
        // partition 2 recorded first: the table grows to cover it
        m.record_partition_sync(2);
        m.record_partition_sync(0);
        m.record_partition_sync(0);
        let snap = m.snapshot();
        assert_eq!(snap.partition_syncs, vec![2, 0, 1]);
        let gaps = m.partition_sync_gaps();
        assert_eq!(gaps.len(), 3);
        assert_eq!(gaps[0], 5.0); // 10 iterations / 2 rounds
        assert!(gaps[1].is_infinite(), "partition with no rounds has no gap");
        assert_eq!(gaps[2], 10.0);
    }

    #[test]
    fn partition_byte_counters_and_shares() {
        let m = Metrics::new();
        assert!(m.snapshot().partition_byte_shares().is_empty(), "nothing recorded yet");
        m.record_partition_sync_bytes(2, 300);
        m.record_partition_sync_bytes(0, 100);
        m.record_partition_sync_bytes(2, 100);
        let snap = m.snapshot();
        assert_eq!(snap.partition_sync_bytes, vec![100, 0, 400]);
        let shares = snap.partition_byte_shares();
        assert!((shares[0] - 0.2).abs() < 1e-12);
        assert_eq!(shares[1], 0.0);
        assert!((shares[2] - 0.8).abs() < 1e-12);
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ne_of_base_rate_predictor_is_one() {
        // predicting exactly the base rate gives NE = 1.0
        let p: f64 = 0.3;
        let avg_ll = -(p * p.ln() + (1.0 - p) * (1.0 - p).ln());
        assert!((normalized_entropy(avg_ll, p) - 1.0).abs() < 1e-12);
        // a better-than-base model gives NE < 1
        assert!(normalized_entropy(avg_ll * 0.8, p) < 1.0);
    }

    #[test]
    fn eval_accum() {
        let mut e = EvalAccum::default();
        e.add(30.0, 28.0, 30.0, 100);
        e.add(30.0, 32.0, 30.0, 100);
        assert_eq!(e.avg_loss(), 0.3);
        assert_eq!(e.base_ctr(), 0.3);
        assert!((e.calibration() - 1.0).abs() < 1e-9);
    }
}
