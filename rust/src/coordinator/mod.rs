//! The master/coordinator: builds the cluster, assigns roles, runs the
//! one-pass training job, and evaluates the output model.
//!
//! Mirrors the paper's master (§3.1): it assigns worker roles
//! (trainers / embedding PSs / sync PSs), wires the reader service, sends
//! the "training plan" (here: the [`RunConfig`] + compiled artifacts), runs
//! the pass, then returns `h` (embedding tables) plus `w^(1)` — the first
//! trainer's replica — as the output model, exactly the paper's convention.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::config::{ModelMeta, RunConfig, SyncAlgo, SyncMode};
use crate::data::reader::{Reader, Shard};
use crate::data::TeacherModel;
use crate::embedding::{EmbCache, EmbeddingSystem};
use crate::metrics::{EpsMeter, EvalAccum, Metrics, MetricsSnapshot};
use crate::net::fault::FaultPlan;
use crate::net::{Network, Role};
use crate::runtime::{Model, Runtime};
use crate::sync::driver::{spawn_shadow_pool_adaptive, ShadowTask};
use crate::sync::prim::AtomicBool;
use crate::sync::ps::PsTrafficSnapshot;
use crate::sync::{
    AllReduceGroup, EasgdSync, HealthController, PartitionPlan, RepartitionController,
    SyncPsGroup,
};
use crate::trainer::{spawn_worker, ForegroundPlan, Trainer, WorkerEnv};

/// Everything a finished run reports (feeds the experiment tables).
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub label: String,
    pub num_trainers: usize,
    pub worker_threads: usize,
    /// average training loss over the pass (per example, log-loss)
    pub train_loss: f64,
    /// held-out evaluation aggregates (loss, NE, calibration)
    pub eval: EvalAccum,
    /// wall-clock examples/sec (paper Definition 1)
    pub eps: f64,
    pub wall_secs: f64,
    /// paper Eq. 2
    pub avg_sync_gap: f64,
    /// Eq. 2 per partition of the shadow fabric (empty when no shadow
    /// pool ran, e.g. fixed-rate modes)
    pub partition_gaps: Vec<f64>,
    pub metrics: MetricsSnapshot,
    /// bytes through the sync-PS tier (EASGD) or ring (MA/BMUF)
    pub sync_ps_bytes: u64,
    /// the sync-PS group's cumulative measured push traffic (EASGD runs
    /// only) — the outcome-level source the experiment harness feeds into
    /// the `sim/` cost model's measured push fraction and the skip-rate
    /// columns, instead of re-deriving it from summed metrics
    pub sync_traffic: Option<PsTrafficSnapshot>,
    /// adaptive repartitions performed during the run — replans some
    /// trainer actually cut over to (0 when `--repartition-every` is off
    /// or no published plan was ever adopted)
    pub repartitions: u64,
    /// crashed trainers the watchdog proxy-departed
    pub health_departs: u64,
    /// straggler demotions (rendezvous partitions → EASGD) published
    pub health_demotions: u64,
    /// recovery promotions (back to the configured algorithms) published
    pub health_promotions: u64,
    /// attempted-but-not-delivered bytes under the fault plan (never on
    /// the NIC counters — the attempted-vs-delivered split stays exact)
    pub dropped_bytes: u64,
    /// bytes through the embedding-PS tier (lookups, updates, prefetch,
    /// bucket migrations) — always equal to `metrics.embedding_bytes`
    pub embedding_bytes: u64,
    /// embedding-cache hits/misses summed over the trainers' caches
    /// (both 0 when `--emb-cache` is off)
    pub emb_cache_hits: u64,
    pub emb_cache_misses: u64,
    /// hot-bucket migrations the repartition controller drove on the
    /// embedding tier
    pub emb_migrations: u64,
    pub elp: u64,
}

impl TrainOutcome {
    /// Relative loss increase vs a baseline outcome (paper Table 3).
    pub fn rel_increase(new: f64, old: f64) -> f64 {
        (new - old) / old
    }
}

/// A built, not-yet-started cluster (exposed for tests and examples that
/// want to poke at the pieces).
pub struct Cluster {
    pub cfg: RunConfig,
    pub meta: ModelMeta,
    pub model: Arc<Model>,
    pub net: Arc<Network>,
    pub metrics: Arc<Metrics>,
    pub embeddings: Arc<EmbeddingSystem>,
    /// the partitioned fabric's layout (one full-range partition for P=1)
    pub plan: PartitionPlan,
    pub sync_ps: Option<Arc<SyncPsGroup>>,
    /// one ring fabric per decentralized partition, sized to its range
    /// (None for EASGD/none partitions); indexed by partition
    pub groups: Vec<Option<Arc<AllReduceGroup>>>,
    /// measured-cost adaptive repartitioning brain, shared by every
    /// trainer's shadow pool (None when neither `--repartition-every` nor
    /// the health machinery needs its epoch protocol)
    pub repartition: Option<Arc<RepartitionController>>,
    /// heartbeat/straggler brain (None unless `--heartbeat-timeout-ms` or
    /// `--health-adaptive` armed it)
    pub health: Option<Arc<HealthController>>,
    pub trainers: Vec<Trainer>,
    pub teacher: Arc<TeacherModel>,
    /// one embedding-row cache per trainer (`--emb-cache`; empty when off)
    pub emb_caches: Vec<Arc<EmbCache>>,
}

/// Build the cluster: roles, placement, artifacts — the master's plan.
pub fn build(cfg: &RunConfig, runtime: &Runtime) -> Result<Cluster> {
    cfg.validate()?;
    let meta = ModelMeta::load(&cfg.artifacts_dir, &cfg.preset)?;
    // knobs that only make sense against the model's actual parameter
    // count fail here with a parse-time-quality error, not a silent clamp
    cfg.validate_dims(meta.num_params)?;
    // worker→core placement is a process-global hint consulted at spawn
    crate::util::affinity::set_pinning(cfg.pin_cores);
    let model = runtime
        .load_model(&meta, &cfg.artifacts_dir)
        .with_context(|| format!("loading artifacts for preset {:?}", cfg.preset))?;

    let mut net = Network::new(if cfg.simulate_network {
        Some(crate::net::PAPER_NIC_BYTES_PER_SEC)
    } else {
        None
    });
    let trainer_nodes: Vec<_> =
        (0..cfg.num_trainers).map(|_| net.add_node(Role::Trainer)).collect();
    let embeddings = Arc::new(EmbeddingSystem::build(
        &meta,
        &cfg.embedding,
        cfg.num_embedding_ps,
        &mut net,
        cfg.data_seed ^ 0xE0B5,
    )?);
    // the partitioned fabric's layout: P contiguous LPT-balanced ranges,
    // each mapped to its algorithm (P = 1: one full-range partition)
    let plan = PartitionPlan::build(meta.num_params, cfg)?;
    // health-adaptive runs need the sync-PS tier even when no partition
    // starts on EASGD: it is both the demotion target and the rejoin
    // warm-start source
    let sync_ps = if plan.uses(SyncAlgo::Easgd) || cfg.health_adaptive {
        // chunked, delta-gated pushes: skipped chunks move zero bytes on
        // either leg, and recorded sync bytes are the measured traffic.
        // The group-level gate serves the legacy whole-vector API; the
        // strategies the fabric builds carry their own per-partition gates
        // when a heartbeat watchdog is armed, a push leg's summed backoff
        // sleeps must never outlast the timeout, or a drop-heavy fault plan
        // turns retry patience into a spurious proxy-depart
        let mut group = SyncPsGroup::build(&model.w0, cfg.num_sync_ps, &mut net)
            .with_push_chunking(cfg.easgd_chunk_elems, cfg.delta_threshold)
            .with_adaptive_gate(cfg.delta_skip_target)
            .with_push_retry(cfg.push_retries, Duration::from_millis(cfg.push_backoff_ms));
        if cfg.heartbeat_timeout_ms > 0 {
            group = group
                .with_push_backoff_budget(Duration::from_millis(cfg.heartbeat_timeout_ms) / 2);
        }
        Some(Arc::new(group))
    } else {
        None
    };
    // each decentralized partition gets its own chunked ring-AllReduce
    // fabric, sized to its range; every trainer's hops are driven through
    // (and attributed to) its own NIC
    let groups: Vec<Option<Arc<AllReduceGroup>>> = plan
        .partitions
        .iter()
        .map(|p| match p.algo {
            SyncAlgo::Ma | SyncAlgo::Bmuf => {
                Some(crate::sync::build_group(cfg, p.index, p.range.len))
            }
            _ => None,
        })
        .collect();
    // every node exists now: layer the seeded fault schedule (if any)
    // under the network, so transfers from here on can crash/drop/stall
    let net = match cfg.fault_plan.as_deref() {
        Some(spec) => {
            let fp = FaultPlan::parse(spec, cfg.data_seed)?;
            anyhow::ensure!(
                fp.trainers_referenced() <= cfg.num_trainers,
                "fault plan names trainer t{}, but the run has only {} trainers",
                fp.trainers_referenced() - 1,
                cfg.num_trainers
            );
            net.with_faults(Arc::new(fp))
        }
        None => net,
    };
    // adaptive repartitioning: one shared controller wrapping generation 0
    // (the plan + groups the trainers' initial strategies are built from).
    // The health machinery reuses the same epoch-gated cutover protocol for
    // its departs, demotions and rejoins, so arming it forces a controller
    // even when periodic repartitioning is off.
    let repartition = (matches!(cfg.mode, SyncMode::Shadow)
        && (cfg.repartition_every > 0 || cfg.heartbeat_timeout_ms > 0 || cfg.health_adaptive))
        .then(|| {
            Arc::new(RepartitionController::new(
                cfg,
                meta.num_params,
                sync_ps.clone(),
                plan.clone(),
                groups.clone(),
            ))
        });
    let health = match &repartition {
        Some(c) if cfg.heartbeat_timeout_ms > 0 || cfg.health_adaptive => {
            Some(Arc::new(HealthController::new(cfg, c.clone())))
        }
        _ => None,
    };
    let trainers: Vec<Trainer> = trainer_nodes
        .iter()
        .enumerate()
        .map(|(i, &node)| Trainer::new(i, node, &model.w0, cfg))
        .collect();
    let teacher = Arc::new(TeacherModel::new(&meta, &cfg.embedding, cfg.data_seed));
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    // the controller's dense replans drag the embedding tier along: hot
    // buckets rebalance in the same breath as hot dense ranges
    if let Some(c) = &repartition {
        c.attach_embeddings(embeddings.clone(), net.clone(), metrics.clone());
    }
    let emb_caches: Vec<Arc<EmbCache>> = if cfg.embedding.cache_rows > 0 {
        (0..cfg.num_trainers)
            .map(|_| Arc::new(EmbCache::new(cfg.embedding.cache_rows)))
            .collect()
    } else {
        Vec::new()
    };
    Ok(Cluster {
        cfg: cfg.clone(),
        meta,
        model,
        net,
        metrics,
        embeddings,
        plan,
        sync_ps,
        groups,
        repartition,
        health,
        trainers,
        teacher,
        emb_caches,
    })
}

/// Run the full one-pass training job and evaluate `w^(1)` + `h`.
pub fn run(cfg: &RunConfig, runtime: &Runtime) -> Result<TrainOutcome> {
    let cluster = build(cfg, runtime)?;
    train(&cluster)?;
    finish(cluster)
}

/// Drive the training pass on a built cluster.
pub fn train(cluster: &Cluster) -> Result<()> {
    let cfg = &cluster.cfg;
    let mut worker_handles = Vec::new();
    let mut shadow_handles = Vec::new();
    // the crash watchdog + straggler ticker outlives the shadow pools: it
    // must still be proxy-departing dead trainers while survivors drain
    // their last rendezvous rounds at shutdown
    let watchdog = cluster.health.as_ref().map(|h| {
        let stop = Arc::new(AtomicBool::new(false));
        (h.spawn_watchdog(stop.clone()), stop)
    });

    for trainer in &cluster.trainers {
        // reader service shard for this trainer
        let shard = Shard {
            trainer: trainer.id,
            num_trainers: cfg.num_trainers,
            total_examples: cfg.train_examples,
            batch: cluster.meta.batch,
        };
        let reader = Reader::spawn(
            &cluster.meta,
            &cfg.embedding,
            cluster.teacher.clone(),
            shard.clone(),
            cfg.reader_queue_depth,
            cfg.reader_rate_limit,
        );
        let queue = Arc::new(Mutex::new(reader.rx));

        // sync wiring per mode
        match cfg.mode {
            SyncMode::Shadow => {
                // one shadow task per non-trivial partition, serviced by
                // the trainer's shadow pool (`--shadow-threads`)
                let tasks = cluster
                    .plan
                    .partitions
                    .iter()
                    .filter(|p| p.algo != SyncAlgo::None)
                    .map(|p| {
                        Ok(ShadowTask {
                            partition: p.index,
                            range: p.range,
                            strategy: crate::sync::build_strategy(
                                cfg,
                                p,
                                trainer.id,
                                &cluster.model.w0,
                                cluster.sync_ps.clone(),
                                cluster.groups[p.index].clone(),
                            )?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                if !tasks.is_empty() {
                    shadow_handles.push(spawn_shadow_pool_adaptive(
                        tasks,
                        trainer.replica.clone(),
                        trainer.node,
                        cluster.net.clone(),
                        cluster.metrics.clone(),
                        trainer.stop_shadow.clone(),
                        Duration::from_millis(cfg.shadow_interval_ms),
                        trainer.id,
                        cfg.shadow_threads,
                        cluster.repartition.clone(),
                        cluster.health.clone(),
                    ));
                }
                for w in 0..cfg.worker_threads {
                    worker_handles.push(spawn_worker(
                        trainer,
                        w,
                        env(cluster, trainer.id),
                        queue.clone(),
                        ForegroundPlan::None,
                    ));
                }
            }
            SyncMode::Decaying { start, end } => {
                // the paper's §4.1.1 conjecture: only defined for EASGD
                let per_worker_total =
                    shard.num_batches() / cfg.worker_threads.max(1) as u64;
                for w in 0..cfg.worker_threads {
                    let plan = match cfg.algo {
                        SyncAlgo::Easgd => ForegroundPlan::DecayingEasgd {
                            strategy: foreground_easgd(cfg, cluster),
                            start,
                            end,
                            total: per_worker_total,
                        },
                        _ => ForegroundPlan::None,
                    };
                    worker_handles
                        .push(spawn_worker(trainer, w, env(cluster, trainer.id), queue.clone(), plan));
                }
            }
            SyncMode::FixedRate { gap } => {
                for w in 0..cfg.worker_threads {
                    let plan = match cfg.algo {
                        SyncAlgo::Easgd => ForegroundPlan::PerWorkerEasgd {
                            strategy: foreground_easgd(cfg, cluster),
                            gap,
                        },
                        SyncAlgo::Ma | SyncAlgo::Bmuf if w == 0 => {
                            // fixed-rate is whole-vector only (validated),
                            // so partition 0 spans the full replica
                            ForegroundPlan::TrainerCollective {
                                strategy: crate::sync::build_strategy(
                                    cfg,
                                    &cluster.plan.partitions[0],
                                    trainer.id,
                                    &cluster.model.w0,
                                    cluster.sync_ps.clone(),
                                    cluster.groups[0].clone(),
                                )?,
                                gap,
                            }
                        }
                        _ => ForegroundPlan::None,
                    };
                    worker_handles
                        .push(spawn_worker(trainer, w, env(cluster, trainer.id), queue.clone(), plan));
                }
            }
        }
    }

    // workers drain their shards; then shadows stop and leave their groups.
    // Errors are collected (not early-returned) so the watchdog is always
    // stopped and joined before train() exits.
    let mut first_err: Option<anyhow::Error> = None;
    for h in worker_handles {
        if let Err(e) = h.join().expect("worker panicked") {
            first_err.get_or_insert(e);
        }
    }
    for t in &cluster.trainers {
        crate::trainer::stop_shadow(t);
    }
    for h in shadow_handles {
        if let Err(e) = h.join().expect("shadow panicked") {
            first_err.get_or_insert(e);
        }
    }
    if let Some((handle, stop)) = watchdog {
        stop.store(true, std::sync::atomic::Ordering::Release);
        handle.join().expect("watchdog panicked");
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

fn env(cluster: &Cluster, trainer_id: usize) -> WorkerEnv {
    WorkerEnv {
        model: cluster.model.clone(),
        embeddings: cluster.embeddings.clone(),
        net: cluster.net.clone(),
        metrics: cluster.metrics.clone(),
        health: cluster.health.clone(),
        cache: cluster.emb_caches.get(trainer_id).cloned(),
        lookahead: cluster.cfg.embedding.lookahead,
    }
}

/// An `EasgdSync` for the foreground (fixed-rate / decaying) plans — the
/// same per-instance gate wiring as the shadow fabric's partition
/// strategies, via the one shared constructor.
fn foreground_easgd(cfg: &RunConfig, cluster: &Cluster) -> EasgdSync {
    crate::sync::easgd_from_cfg(cfg, 0, cluster.sync_ps.clone().expect("easgd sync ps"))
}

/// Evaluate `w^(1)` + `h` on the held-out range and assemble the outcome.
pub fn finish(cluster: Cluster) -> Result<TrainOutcome> {
    let cfg = &cluster.cfg;
    let eval = evaluate(&cluster, cfg.eval_examples)?;
    let m = cluster.metrics.snapshot();
    let partition_gaps = cluster.metrics.partition_sync_gaps();
    // Eq. 2 under the partitioned fabric: `metrics.syncs` counts partition
    // rounds, so the totals ratio would deflate the gap by ~P. When a
    // shadow pool ran, report the mean *per-partition* gap instead (P = 1
    // reduces to the classic totals ratio, same arithmetic); a starved
    // partition's infinite gap deliberately poisons the mean.
    let avg_sync_gap = if partition_gaps.is_empty() {
        cluster.metrics.avg_sync_gap()
    } else {
        partition_gaps.iter().sum::<f64>() / partition_gaps.len() as f64
    };
    Ok(TrainOutcome {
        label: cfg.label(),
        num_trainers: cfg.num_trainers,
        worker_threads: cfg.worker_threads,
        train_loss: m.avg_loss,
        eval,
        eps: 0.0,     // filled by run_timed
        wall_secs: 0.0,
        avg_sync_gap,
        partition_gaps,
        sync_ps_bytes: cluster.net.role_bytes(Role::SyncPs),
        sync_traffic: cluster.sync_ps.as_ref().map(|g| g.traffic()),
        repartitions: cluster.repartition.as_ref().map_or(0, |c| c.repartitions()),
        health_departs: cluster.health.as_ref().map_or(0, |h| h.departs()),
        health_demotions: cluster.health.as_ref().map_or(0, |h| h.demotions()),
        health_promotions: cluster.health.as_ref().map_or(0, |h| h.promotions()),
        dropped_bytes: cluster.net.faults().map_or(0, |f| f.dropped_bytes()),
        embedding_bytes: cluster.net.role_bytes(Role::EmbeddingPs),
        emb_cache_hits: cluster.emb_caches.iter().map(|c| c.stats().hits).sum(),
        emb_cache_misses: cluster.emb_caches.iter().map(|c| c.stats().misses).sum(),
        emb_migrations: cluster.repartition.as_ref().map_or(0, |c| c.embedding_migrations()),
        metrics: m,
        elp: cfg.elp(cluster.meta.batch),
    })
}

/// `run` + wall-clock EPS measurement around the training pass only.
pub fn run_timed(cfg: &RunConfig, runtime: &Runtime) -> Result<TrainOutcome> {
    let cluster = build(cfg, runtime)?;
    let meter = EpsMeter::start();
    train(&cluster)?;
    let wall = meter.elapsed_secs();
    let examples = cluster.metrics.snapshot().examples;
    let mut out = finish(cluster)?;
    out.eps = examples as f64 / wall.max(1e-9);
    out.wall_secs = wall;
    Ok(out)
}

/// One-pass evaluation of the output model (`w^(1)`, `h`) on the held-out
/// stream `[train_examples, train_examples + n)`.
pub fn evaluate(cluster: &Cluster, n: u64) -> Result<EvalAccum> {
    let meta = &cluster.meta;
    let cfg = &cluster.cfg;
    let mut accum = EvalAccum::default();
    let mut io = cluster.model.new_io();
    // the paper returns the first trainer's replica as the model
    cluster.trainers[0].replica.read_into(&mut io.w_host);
    let mut batch = crate::data::Batch::empty(meta, &cfg.embedding);
    let mut ids = vec![0u64; meta.batch];
    let batches = n / meta.batch as u64;
    let trainer_node = cluster.trainers[0].node;
    for b in 0..batches {
        for (r, id) in ids.iter_mut().enumerate() {
            *id = cfg.train_examples + b * meta.batch as u64 + r as u64;
        }
        cluster.teacher.fill_batch(&mut batch, &ids);
        cluster.embeddings.lookup_batch(
            &batch.indices,
            batch.size,
            &mut io.pooled_host,
            trainer_node,
            &cluster.net,
            &cluster.metrics,
        );
        let out = cluster.model.eval_step(&mut io, &batch.dense, &batch.labels)?;
        accum.add(
            out.loss_sum as f64,
            out.pred_sum as f64,
            out.label_sum as f64,
            meta.batch as u64,
        );
    }
    Ok(accum)
}

/// Write the output model (`w^(1)` + embedding shards) to a checkpoint dir.
pub fn checkpoint(cluster: &Cluster, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let w = cluster.trainers[0].replica.to_vec();
    let mut bytes = Vec::with_capacity(w.len() * 4);
    for v in &w {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(dir.join("w.bin"), &bytes)?;
    // embedding shards + MANIFEST.csv in the sharded tier's own layout
    // (round-trips through `EmbeddingSystem::load_into` bit-exactly, even
    // across hot-key rebalances)
    cluster.embeddings.save(dir)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_increase() {
        assert!((TrainOutcome::rel_increase(1.02, 1.0) - 0.02).abs() < 1e-12);
    }
}
