//! Deterministic fault injection for the in-process fabric.
//!
//! At scale the cluster is never healthy: trainers stall, links degrade,
//! nodes die. A [`FaultPlan`] is a seeded, declarative schedule of such
//! events, layered *under* [`super::Network`] so that every transfer
//! becomes fallible or delayable without any sync-layer code knowing which
//! plan (if any) is installed. The plan is parsed from the CLI
//! (`--fault-plan`), e.g.:
//!
//! ```text
//! crash:t2@sweep40,stall:t1@sweep10+8,slow-link:t0<->ps@2x,drop:t0@0.01
//! ```
//!
//! Entry grammar (comma-separated, `tN` = trainer index `N`):
//!
//! | Entry | Meaning |
//! |---|---|
//! | `crash:tN@sweepK` | trainer `N` dies permanently at its shadow sweep `K` |
//! | `crash:tN@sweepK+D` | down for `D` sweeps starting at `K`, then eligible to rejoin |
//! | `stall:tN@sweepK+D` | straggler: each shadow lap in `[K, K+D)` pays [`STALL_LAP_DELAY`] |
//! | `slow-link:tN<->ps@Fx` | the trainer↔sync-PS link runs `F`× slower |
//! | `drop:tN@P` | each transfer touching trainer `N` is dropped with probability `P` (seeded) |
//!
//! Time is measured in *shadow sweeps* of the affected trainer: the shadow
//! pool's lap thread calls [`FaultPlan::note_sweep`] once per lap —
//! including while crashed, so finite crash windows expire and the elastic
//! rejoin path can fire. This keeps plans deterministic per seed and
//! independent of wall-clock noise.
//!
//! Byte accounting is preserved for attempted-vs-delivered analysis: a
//! faulted transfer moves **zero** NIC bytes (neither `tx` nor `rx`) and
//! instead accrues to the plan's [`dropped bytes`](FaultPlan::dropped_bytes)
//! ledger, so `metrics.sync_bytes == sync-PS NIC + ring tx` stays exact
//! under retries and crashes.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

/// Why a transfer did not deliver (see [`super::Network::try_transfer`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultError {
    /// A transient, seeded drop (`drop:tN@P`): retrying may succeed.
    Dropped,
    /// An endpoint is inside a crash window (`crash:tN@sweepK[+D]`):
    /// retrying cannot help until the window ends.
    Unreachable,
}

impl std::fmt::Display for FaultError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultError::Dropped => write!(f, "transfer dropped (transient)"),
            FaultError::Unreachable => write!(f, "endpoint crashed (unreachable)"),
        }
    }
}

/// Delay injected per shadow lap while a `stall:` window is active. Fixed
/// rather than configurable: the experiments care about *relative* lap
/// inflation (the EWMA-vs-median ratio the health controller watches), not
/// the absolute magnitude.
pub const STALL_LAP_DELAY: Duration = Duration::from_millis(20);

#[derive(Debug, Clone, Copy)]
struct CrashWindow {
    trainer: usize,
    start: u64,
    /// `None` = permanent; `Some(d)` = down for `d` sweeps, then rejoin.
    down: Option<u64>,
}

#[derive(Debug, Clone, Copy)]
struct StallWindow {
    trainer: usize,
    start: u64,
    down: u64,
}

/// A parsed, seeded fault schedule. Shared (`Arc`) between the [`Network`]
/// (which consults it per transfer) and the shadow drivers / watchdog
/// (which advance sweep clocks and poll crash state).
///
/// [`Network`]: super::Network
#[derive(Debug)]
pub struct FaultPlan {
    crashes: Vec<CrashWindow>,
    stalls: Vec<StallWindow>,
    /// (trainer, factor) — trainer↔sync-PS link slowdown multipliers.
    slow_links: Vec<(usize, f64)>,
    /// (trainer, probability) — seeded transient drop rates.
    drops: Vec<(usize, f64)>,
    seed: u64,
    /// Per-trainer shadow-sweep clocks (index = trainer id).
    sweeps: Vec<AtomicU64>,
    /// Per-trainer transfer-attempt counters feeding the drop hash.
    attempts: Vec<AtomicU64>,
    /// Attempted-but-not-delivered bytes (the NIC counters never see these).
    dropped_bytes: AtomicU64,
    /// Faulted transfer count (drops + unreachable), for reports.
    dropped_transfers: AtomicU64,
}

impl FaultPlan {
    /// Parse a comma-separated fault spec (see the module docs for the
    /// grammar). `seed` drives the `drop:` entries' per-transfer coin flips.
    pub fn parse(spec: &str, seed: u64) -> Result<Self> {
        let mut crashes = Vec::new();
        let mut stalls = Vec::new();
        let mut slow_links = Vec::new();
        let mut drops = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, rest) = entry
                .split_once(':')
                .with_context(|| format!("fault entry `{entry}` missing `kind:` prefix"))?;
            match kind {
                "crash" => {
                    let (trainer, start, down) = parse_trainer_window(rest, entry)?;
                    crashes.push(CrashWindow { trainer, start, down });
                }
                "stall" => {
                    let (trainer, start, down) = parse_trainer_window(rest, entry)?;
                    let down = down.with_context(|| {
                        format!("stall entry `{entry}` needs a window, e.g. stall:t1@sweep10+8")
                    })?;
                    ensure!(down > 0, "stall entry `{entry}` has an empty window");
                    stalls.push(StallWindow { trainer, start, down });
                }
                "slow-link" => {
                    let (pair, factor) = rest.split_once('@').with_context(|| {
                        format!("slow-link entry `{entry}` missing `@Fx` factor")
                    })?;
                    let trainer = pair
                        .strip_suffix("<->ps")
                        .map(|t| parse_trainer(t, entry))
                        .with_context(|| {
                            format!("slow-link entry `{entry}` must name a `tN<->ps` link")
                        })??;
                    let factor: f64 = factor
                        .strip_suffix('x')
                        .with_context(|| format!("slow-link factor in `{entry}` must end in `x`"))?
                        .parse()
                        .with_context(|| format!("bad slow-link factor in `{entry}`"))?;
                    ensure!(factor >= 1.0, "slow-link factor in `{entry}` must be >= 1");
                    slow_links.push((trainer, factor));
                }
                "drop" => {
                    let (t, p) = rest
                        .split_once('@')
                        .with_context(|| format!("drop entry `{entry}` missing `@P` probability"))?;
                    let trainer = parse_trainer(t, entry)?;
                    let p: f64 = p
                        .parse()
                        .with_context(|| format!("bad drop probability in `{entry}`"))?;
                    ensure!((0.0..=1.0).contains(&p), "drop probability in `{entry}` not in [0,1]");
                    drops.push((trainer, p));
                }
                other => bail!("unknown fault kind `{other}` in `{entry}`"),
            }
        }
        // Reject plans that schedule contradictory states for one trainer:
        // two crash windows that overlap (which window owns the sweep?), or
        // a crash overlapping a stall (a dead trainer cannot also straggle).
        // Windows are half-open [start, start+d); a permanent crash is
        // [start, ∞). Back-to-back windows (one ends where the next starts)
        // are fine.
        let crash_end = |c: &CrashWindow| c.down.map(|d| c.start + d);
        let overlaps = |s0: u64, e0: Option<u64>, s1: u64, e1: Option<u64>| {
            e0.is_none_or(|e| s1 < e) && e1.is_none_or(|e| s0 < e)
        };
        for (i, a) in crashes.iter().enumerate() {
            for b in &crashes[i + 1..] {
                if a.trainer == b.trainer
                    && overlaps(a.start, crash_end(a), b.start, crash_end(b))
                {
                    bail!(
                        "conflicting fault plan: trainer t{} has two overlapping crash \
                         windows (sweep {}{} and sweep {}{}) — schedule them disjoint",
                        a.trainer,
                        a.start,
                        fmt_window(a.down),
                        b.start,
                        fmt_window(b.down),
                    );
                }
            }
            for s in &stalls {
                if a.trainer == s.trainer
                    && overlaps(a.start, crash_end(a), s.start, Some(s.start + s.down))
                {
                    bail!(
                        "conflicting fault plan: trainer t{} is both crashed (sweep {}{}) \
                         and stalled (sweep {}+{}) over the same sweeps — a crashed \
                         trainer cannot straggle",
                        a.trainer,
                        a.start,
                        fmt_window(a.down),
                        s.start,
                        s.down,
                    );
                }
            }
        }
        let max_t = crashes
            .iter()
            .map(|c| c.trainer)
            .chain(stalls.iter().map(|s| s.trainer))
            .chain(slow_links.iter().map(|(t, _)| *t))
            .chain(drops.iter().map(|(t, _)| *t))
            .max()
            .map(|m| m + 1)
            .unwrap_or(0);
        Ok(Self {
            crashes,
            stalls,
            slow_links,
            drops,
            seed,
            sweeps: (0..max_t).map(|_| AtomicU64::new(0)).collect(),
            attempts: (0..max_t).map(|_| AtomicU64::new(0)).collect(),
            dropped_bytes: AtomicU64::new(0),
            dropped_transfers: AtomicU64::new(0),
        })
    }

    /// Highest trainer index any entry names, plus one (0 for an empty plan)
    /// — config validation checks this against `--trainers`.
    pub fn trainers_referenced(&self) -> usize {
        self.sweeps.len()
    }

    /// Advance trainer `t`'s sweep clock by one lap; returns the new count.
    /// Called once per shadow lap by the pool's clock thread — including
    /// while `t` is crashed, so finite crash windows expire.
    pub fn note_sweep(&self, t: usize) -> u64 {
        match self.sweeps.get(t) {
            Some(s) => s.fetch_add(1, Relaxed) + 1,
            None => 0,
        }
    }

    /// Trainer `t`'s current sweep clock.
    pub fn sweep(&self, t: usize) -> u64 {
        self.sweeps.get(t).map(|s| s.load(Relaxed)).unwrap_or(0)
    }

    /// Is trainer `t` inside a crash window right now?
    pub fn crashed(&self, t: usize) -> bool {
        let s = self.sweep(t);
        self.crashes
            .iter()
            .any(|c| c.trainer == t && s >= c.start && c.down.is_none_or(|d| s < c.start + d))
    }

    /// Does trainer `t` have a *permanent* crash scheduled (no rejoin)?
    pub fn crashes_permanently(&self, t: usize) -> bool {
        self.crashes.iter().any(|c| c.trainer == t && c.down.is_none())
    }

    /// Does the plan schedule any crash window at all? Config validation
    /// uses this: a crash against rendezvous partitions needs a recovery
    /// mechanism (ring round timeout or heartbeat watchdog) or shutdown
    /// would deadlock on the dead trainer's unclosed rounds.
    pub fn has_crashes(&self) -> bool {
        !self.crashes.is_empty()
    }

    /// Per-lap straggler delay for trainer `t`, if a stall window is active.
    pub fn lap_delay(&self, t: usize) -> Option<Duration> {
        let s = self.sweep(t);
        self.stalls
            .iter()
            .any(|w| w.trainer == t && s >= w.start && s < w.start + w.down)
            .then_some(STALL_LAP_DELAY)
    }

    /// Slowdown multiplier for trainer `t`'s link to the sync PSs (1.0 when
    /// no `slow-link:` entry names `t`).
    pub fn slowdown(&self, t: usize) -> f64 {
        self.slow_links
            .iter()
            .filter(|(lt, _)| *lt == t)
            .map(|(_, f)| *f)
            .fold(1.0, f64::max)
    }

    /// Seeded per-transfer coin flip for trainer `t`'s `drop:` entries.
    /// Deterministic: the same seed and attempt sequence reproduce the same
    /// drops bit-for-bit.
    pub fn should_drop(&self, t: usize) -> bool {
        let p = self
            .drops
            .iter()
            .filter(|(dt, _)| *dt == t)
            .map(|(_, p)| *p)
            .fold(0.0, f64::max);
        if p <= 0.0 {
            return false;
        }
        let attempt = match self.attempts.get(t) {
            Some(a) => a.fetch_add(1, Relaxed),
            None => return false,
        };
        hash01(self.seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ attempt) < p
    }

    /// Record `bytes` as attempted but not delivered.
    pub fn note_dropped(&self, bytes: u64) {
        self.dropped_bytes.fetch_add(bytes, Relaxed);
        self.dropped_transfers.fetch_add(1, Relaxed);
    }

    /// Total attempted-but-not-delivered bytes.
    pub fn dropped_bytes(&self) -> u64 {
        self.dropped_bytes.load(Relaxed)
    }

    /// Total faulted transfers (transient drops + crashed endpoints).
    pub fn dropped_transfers(&self) -> u64 {
        self.dropped_transfers.load(Relaxed)
    }
}

fn parse_trainer(s: &str, entry: &str) -> Result<usize> {
    s.strip_prefix('t')
        .and_then(|n| n.parse().ok())
        .with_context(|| format!("expected trainer `tN` in `{entry}`, got `{s}`"))
}

/// Parse `tN@sweepK` or `tN@sweepK+D` into (trainer, start, window).
fn parse_trainer_window(rest: &str, entry: &str) -> Result<(usize, u64, Option<u64>)> {
    let (t, at) = rest
        .split_once('@')
        .with_context(|| format!("entry `{entry}` missing `@sweepK`"))?;
    let trainer = parse_trainer(t, entry)?;
    let at = at
        .strip_prefix("sweep")
        .with_context(|| format!("entry `{entry}` must anchor at `@sweepK`"))?;
    let (start, down) = match at.split_once('+') {
        Some((k, d)) => {
            let d: u64 =
                d.parse().with_context(|| format!("bad window length in `{entry}`"))?;
            (k, Some(d))
        }
        None => (at, None),
    };
    let start: u64 =
        start.parse().with_context(|| format!("bad sweep number in `{entry}`"))?;
    Ok((trainer, start, down))
}

/// Render a crash window length for conflict diagnostics.
fn fmt_window(down: Option<u64>) -> String {
    match down {
        Some(d) => format!("+{d}"),
        None => " (permanent)".to_string(),
    }
}

/// splitmix64 finalizer mapped to [0,1) — the plan's only randomness, so a
/// seed fully determines every drop decision.
fn hash01(x: u64) -> f64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_docstring_plan() {
        let p = FaultPlan::parse(
            "crash:t2@sweep40,stall:t1@sweep10+8,slow-link:t0<->ps@2x,drop:t0@0.01",
            7,
        )
        .unwrap();
        assert_eq!(p.trainers_referenced(), 3);
        assert_eq!(p.slowdown(0), 2.0);
        assert_eq!(p.slowdown(1), 1.0);
        assert!(p.crashes_permanently(2));
        assert!(!p.crashed(2), "crash only fires at sweep 40");
    }

    #[test]
    fn bad_specs_bail() {
        for bad in [
            "crash:t2",               // no @sweep
            "crash:x2@sweep4",        // no tN
            "stall:t1@sweep10",       // stall needs a window
            "stall:t1@sweep10+0",     // empty window
            "slow-link:t0@2x",        // no <->ps
            "slow-link:t0<->ps@0.5x", // speedup, not slowdown
            "slow-link:t0<->ps@2",    // missing x suffix
            "drop:t0@1.5",            // probability out of range
            "teleport:t0@sweep1",     // unknown kind
            "crash",                  // no colon
            // conflicting schedules for one trainer:
            "crash:t0@sweep1+5,crash:t0@sweep3+5",  // overlapping crash windows
            "crash:t1@sweep2,crash:t1@sweep10+2",   // permanent crash overlaps everything after
            "crash:t0@sweep5,crash:t0@sweep1+5",    // finite window runs into a permanent one
            "crash:t2@sweep1+8,stall:t2@sweep4+2",  // crashed trainer cannot also stall
            "stall:t0@sweep3+4,crash:t0@sweep6",    // ...in either entry order
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn disjoint_windows_per_trainer_are_fine() {
        // back-to-back half-open windows don't overlap, and entries naming
        // different trainers never conflict
        for ok in [
            "crash:t0@sweep1+2,crash:t0@sweep3+2",
            "crash:t0@sweep1+2,crash:t0@sweep10",
            "crash:t0@sweep1+3,stall:t0@sweep4+2",
            "crash:t0@sweep1+8,stall:t1@sweep4+2",
            "stall:t0@sweep1+2,stall:t0@sweep1+2", // stalls may stack freely
        ] {
            assert!(FaultPlan::parse(ok, 0).is_ok(), "`{ok}` should parse");
        }
    }

    #[test]
    fn empty_plan_is_inert() {
        let p = FaultPlan::parse("", 0).unwrap();
        assert_eq!(p.trainers_referenced(), 0);
        assert!(!p.crashed(0));
        assert!(!p.should_drop(0));
        assert_eq!(p.note_sweep(0), 0, "unreferenced trainers have no clock");
    }

    #[test]
    fn crash_window_opens_and_closes_on_the_sweep_clock() {
        let p = FaultPlan::parse("crash:t0@sweep3+2", 0).unwrap();
        assert!(!p.crashed(0));
        for _ in 0..3 {
            p.note_sweep(0);
        }
        assert!(p.crashed(0), "window [3,5) open at sweep 3");
        p.note_sweep(0);
        assert!(p.crashed(0), "still down at sweep 4");
        p.note_sweep(0);
        assert!(!p.crashed(0), "window closed at sweep 5 — rejoin eligible");
        assert!(!p.crashes_permanently(0));
    }

    #[test]
    fn permanent_crash_never_ends() {
        let p = FaultPlan::parse("crash:t1@sweep2", 0).unwrap();
        for _ in 0..100 {
            p.note_sweep(1);
        }
        assert!(p.crashed(1));
        assert!(p.crashes_permanently(1));
    }

    #[test]
    fn stall_delay_tracks_its_window() {
        let p = FaultPlan::parse("stall:t0@sweep1+2", 0).unwrap();
        assert_eq!(p.lap_delay(0), None);
        p.note_sweep(0);
        assert_eq!(p.lap_delay(0), Some(STALL_LAP_DELAY));
        p.note_sweep(0);
        assert_eq!(p.lap_delay(0), Some(STALL_LAP_DELAY));
        p.note_sweep(0);
        assert_eq!(p.lap_delay(0), None, "window [1,3) closed at sweep 3");
    }

    #[test]
    fn drops_are_seed_deterministic() {
        let a = FaultPlan::parse("drop:t0@0.5", 42).unwrap();
        let b = FaultPlan::parse("drop:t0@0.5", 42).unwrap();
        let sa: Vec<bool> = (0..64).map(|_| a.should_drop(0)).collect();
        let sb: Vec<bool> = (0..64).map(|_| b.should_drop(0)).collect();
        assert_eq!(sa, sb, "same seed, same drop sequence");
        assert!(sa.iter().any(|&d| d), "p=0.5 over 64 attempts drops something");
        assert!(sa.iter().any(|&d| !d), "...and delivers something");
        assert!(!a.should_drop(1), "entries are per-trainer");
    }

    #[test]
    fn dropped_ledger_accumulates() {
        let p = FaultPlan::parse("crash:t0@sweep0", 0).unwrap();
        assert!(p.crashed(0), "window starting at sweep 0 is open immediately");
        p.note_dropped(100);
        p.note_dropped(24);
        assert_eq!(p.dropped_bytes(), 124);
        assert_eq!(p.dropped_transfers(), 2);
    }
}
