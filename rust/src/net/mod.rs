//! Cluster "network": per-node byte accounting with optional simulated
//! bandwidth delay.
//!
//! In-process realization (DESIGN.md §3): trainers, embedding PSs and sync
//! PSs are actors inside one process, so the wire is a function call. What
//! the experiments need from the network layer is (a) *traffic accounting*
//! per node — the paper diagnoses the FR-EASGD-5 plateau by looking at sync
//! PS NIC saturation — and (b) optionally injecting transfer delay so small
//! real-mode runs can exhibit bandwidth effects. Throughput *modelling* at
//! paper scale happens in `sim/` instead.
//!
//! Every byte that crosses a tier boundary flows through
//! [`Network::transfer`]: embedding lookups/updates between trainers and
//! embedding PSs, EASGD elastic pushes against the sync-PS shards, and —
//! since the collective became a chunked ring fabric
//! ([`crate::sync::allreduce`]) — each MA/BMUF member's individual
//! reduce-scatter and all-gather hops toward its ring successor, and each
//! EASGD push chunk that survives the delta gate (skipped chunks suppress
//! both legs). The fig5/fig6 traffic columns therefore report *measured*
//! NIC counters for every role, not closed-form estimates; the `sim/` cost
//! model likewise prices collectives from the measured schedule
//! ([`crate::sync::traffic`]), with the textbook ring formula surviving
//! only as the cross-check reference
//! (`AllReduceGroup::ring_bytes_per_member`). Transfers are full-duplex:
//! `tx` accrues to the source NIC and `rx` to the destination NIC of the
//! same call.
//!
//! A [`FaultPlan`] ([`fault`]) can be layered underneath via
//! [`Network::with_faults`]: transfers then become fallible
//! ([`Network::try_transfer`]) — crashed endpoints are unreachable, drops
//! are seeded coin flips, slow links stretch the wire time — while the NIC
//! counters keep the attempted-vs-delivered split exact: faulted transfers
//! move zero NIC bytes and accrue to the plan's dropped-bytes ledger.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;
use std::time::Duration;

pub mod fault;

pub use fault::{FaultError, FaultPlan};

/// Node roles for per-role aggregation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Trainer,
    EmbeddingPs,
    SyncPs,
    Reader,
}

/// One node's NIC counters.
#[derive(Debug, Default)]
pub struct Nic {
    pub tx_bytes: AtomicU64,
    pub rx_bytes: AtomicU64,
}

/// The cluster fabric: one NIC per node plus an optional bandwidth model
/// and an optional fault plan.
pub struct Network {
    nodes: Vec<(Role, Nic)>,
    /// trainer id per node (trainer-role nodes are numbered in the order
    /// they were added — the same order the coordinator builds trainers).
    trainer_of: Vec<Option<usize>>,
    /// simulated per-NIC bandwidth in bytes/sec (None = only account)
    pub bandwidth: Option<f64>,
    /// installed fault schedule (None = the fabric is perfect)
    faults: Option<Arc<FaultPlan>>,
}

/// Handle for one endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeId(pub usize);

impl Network {
    pub fn new(bandwidth: Option<f64>) -> Self {
        Self { nodes: Vec::new(), trainer_of: Vec::new(), bandwidth, faults: None }
    }

    /// Install a fault plan: transfers become fallible per its schedule.
    /// Trainer identity for fault purposes follows the order trainer-role
    /// NICs were added (`t0` = first [`Role::Trainer`] node, ...).
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> Self {
        self.faults = Some(plan);
        self
    }

    /// The installed fault plan, if any.
    pub fn faults(&self) -> Option<&Arc<FaultPlan>> {
        self.faults.as_ref()
    }

    /// Attempted-but-not-delivered bytes (0 without a fault plan). These
    /// never appear in any NIC's `tx`/`rx`.
    pub fn dropped_bytes(&self) -> u64 {
        self.faults.as_ref().map(|f| f.dropped_bytes()).unwrap_or(0)
    }

    pub fn add_node(&mut self, role: Role) -> NodeId {
        let trainer = (role == Role::Trainer)
            .then(|| self.trainer_of.iter().flatten().count());
        self.nodes.push((role, Nic::default()));
        self.trainer_of.push(trainer);
        NodeId(self.nodes.len() - 1)
    }

    /// Record a transfer of `bytes` from `src` to `dst`, ignoring faults
    /// (a faulted transfer still moves zero NIC bytes — callers that cannot
    /// react simply proceed). Use [`Network::try_transfer`] to observe the
    /// outcome.
    pub fn transfer(&self, src: NodeId, dst: NodeId, bytes: u64) {
        let _ = self.try_transfer(src, dst, bytes);
    }

    /// Fallible transfer of `bytes` from `src` to `dst`: consults the fault
    /// plan (crashed endpoints, seeded drops), then accounts tx/rx and — if
    /// a bandwidth model is installed — blocks the calling thread for the
    /// wire time (stretched by any `slow-link:` factor on the endpoints).
    /// Transfers are full-duplex (tx and rx accounted separately). Faulted
    /// transfers move zero NIC bytes and accrue to the plan's dropped
    /// ledger instead. Self-transfers (`src == dst`) are a caller bug:
    /// rejected in debug builds, skipped (no accounting) in release.
    pub fn try_transfer(&self, src: NodeId, dst: NodeId, bytes: u64) -> Result<(), FaultError> {
        if src == dst {
            debug_assert_ne!(
                src.0, dst.0,
                "self-transfer: src == dst moves nothing over any wire"
            );
            return Ok(());
        }
        let mut slowdown = 1.0_f64;
        if let Some(f) = &self.faults {
            for (a, b) in [(src, dst), (dst, src)] {
                if let Some(t) = self.trainer_of[a.0] {
                    if f.crashed(t) {
                        f.note_dropped(bytes);
                        return Err(FaultError::Unreachable);
                    }
                    if f.should_drop(t) {
                        f.note_dropped(bytes);
                        return Err(FaultError::Dropped);
                    }
                    if self.nodes[b.0].0 == Role::SyncPs {
                        slowdown = slowdown.max(f.slowdown(t));
                    }
                }
            }
        }
        self.nodes[src.0].1.tx_bytes.fetch_add(bytes, Relaxed);
        self.nodes[dst.0].1.rx_bytes.fetch_add(bytes, Relaxed);
        // Wire time: the configured bandwidth stretched by the slow-link
        // factor; a slow link with no bandwidth model configured still
        // sleeps for the *degraded* share, priced off the paper's NIC.
        let effective_bw = match (self.bandwidth, slowdown > 1.0) {
            (Some(bw), _) => Some(bw / slowdown),
            (None, true) => Some(PAPER_NIC_BYTES_PER_SEC / slowdown),
            (None, false) => None,
        };
        if let Some(bw) = effective_bw {
            let secs = bytes as f64 / bw;
            if secs > 1e-6 {
                std::thread::sleep(Duration::from_secs_f64(secs));
            }
        }
        Ok(())
    }

    pub fn tx(&self, n: NodeId) -> u64 {
        self.nodes[n.0].1.tx_bytes.load(Relaxed)
    }

    pub fn rx(&self, n: NodeId) -> u64 {
        self.nodes[n.0].1.rx_bytes.load(Relaxed)
    }

    /// Total bytes through NICs of a given role (tx + rx).
    pub fn role_bytes(&self, role: Role) -> u64 {
        self.nodes
            .iter()
            .filter(|(r, _)| *r == role)
            .map(|(_, nic)| nic.tx_bytes.load(Relaxed) + nic.rx_bytes.load(Relaxed))
            .sum()
    }

    /// Transmitted bytes summed over every NIC of a role.
    pub fn role_tx(&self, role: Role) -> u64 {
        self.nodes
            .iter()
            .filter(|(r, _)| *r == role)
            .map(|(_, nic)| nic.tx_bytes.load(Relaxed))
            .sum()
    }

    /// Received bytes summed over every NIC of a role.
    pub fn role_rx(&self, role: Role) -> u64 {
        self.nodes
            .iter()
            .filter(|(r, _)| *r == role)
            .map(|(_, nic)| nic.rx_bytes.load(Relaxed))
            .sum()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// 25 Gbit Ethernet (the paper's testbed NIC), in bytes/sec.
pub const PAPER_NIC_BYTES_PER_SEC: f64 = 25.0e9 / 8.0;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn accounting_only_by_default() {
        let mut net = Network::new(None);
        let a = net.add_node(Role::Trainer);
        let b = net.add_node(Role::SyncPs);
        let net = Arc::new(net);
        net.transfer(a, b, 100);
        net.transfer(b, a, 40);
        assert_eq!(net.tx(a), 100);
        assert_eq!(net.rx(b), 100);
        assert_eq!(net.tx(b), 40);
        assert_eq!(net.role_bytes(Role::SyncPs), 140);
        assert_eq!(net.role_bytes(Role::Trainer), 140);
        assert_eq!(net.role_tx(Role::Trainer), 100);
        assert_eq!(net.role_rx(Role::Trainer), 40);
        assert_eq!(net.role_rx(Role::SyncPs), 100);
        assert_eq!(net.role_tx(Role::SyncPs), 40);
    }

    #[test]
    fn concurrent_transfers_sum_exactly() {
        let mut net = Network::new(None);
        let a = net.add_node(Role::Trainer);
        let b = net.add_node(Role::EmbeddingPs);
        let net = Arc::new(net);
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let net = net.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        net.transfer(a, b, 7);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(net.rx(b), 4 * 1000 * 7);
    }

    #[test]
    fn bandwidth_injects_delay() {
        let mut net = Network::new(Some(1e6)); // 1 MB/s
        let a = net.add_node(Role::Trainer);
        let b = net.add_node(Role::SyncPs);
        let t0 = std::time::Instant::now();
        net.transfer(a, b, 20_000); // 20ms at 1MB/s
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "self-transfer")]
    fn self_transfer_rejected_in_debug() {
        let mut net = Network::new(None);
        let a = net.add_node(Role::Trainer);
        net.transfer(a, a, 100);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn self_transfer_skips_accounting_in_release() {
        let mut net = Network::new(None);
        let a = net.add_node(Role::Trainer);
        net.transfer(a, a, 100);
        assert_eq!(net.tx(a), 0, "self-transfers move nothing");
        assert_eq!(net.rx(a), 0);
    }

    #[test]
    fn crashed_endpoint_feeds_the_dropped_ledger_not_the_nics() {
        let plan = Arc::new(FaultPlan::parse("crash:t0@sweep0", 0).unwrap());
        let mut net = Network::new(None);
        let t0 = net.add_node(Role::Trainer);
        let ps = net.add_node(Role::SyncPs);
        let net = net.with_faults(plan.clone());
        assert_eq!(net.try_transfer(t0, ps, 100), Err(FaultError::Unreachable));
        assert_eq!(net.try_transfer(ps, t0, 40), Err(FaultError::Unreachable));
        assert_eq!(net.tx(t0) + net.rx(t0), 0, "no NIC bytes while crashed");
        assert_eq!(net.role_bytes(Role::SyncPs), 0);
        assert_eq!(net.dropped_bytes(), 140, "attempted bytes land in the ledger");
        assert_eq!(plan.dropped_transfers(), 2);
    }

    #[test]
    fn transient_drops_split_attempted_from_delivered_exactly() {
        let plan = Arc::new(FaultPlan::parse("drop:t0@0.5", 0xC0FFEE).unwrap());
        let mut net = Network::new(None);
        let t0 = net.add_node(Role::Trainer);
        let ps = net.add_node(Role::SyncPs);
        let net = net.with_faults(plan);
        let mut delivered = 0u64;
        for _ in 0..200 {
            if net.try_transfer(t0, ps, 8).is_ok() {
                delivered += 8;
            }
        }
        assert_eq!(net.tx(t0), delivered, "NICs count only delivered bytes");
        assert_eq!(net.rx(ps), delivered);
        assert_eq!(net.dropped_bytes(), 200 * 8 - delivered);
        assert!(net.dropped_bytes() > 0, "p=0.5 over 200 transfers drops some");
        assert!(delivered > 0, "...and delivers some");
    }

    #[test]
    fn fault_free_trainers_are_untouched_by_the_plan() {
        let plan = Arc::new(FaultPlan::parse("crash:t0@sweep0", 0).unwrap());
        let mut net = Network::new(None);
        let _t0 = net.add_node(Role::Trainer);
        let t1 = net.add_node(Role::Trainer);
        let ps = net.add_node(Role::SyncPs);
        let net = net.with_faults(plan);
        assert_eq!(net.try_transfer(t1, ps, 64), Ok(()));
        assert_eq!(net.tx(t1), 64);
    }

    #[test]
    fn slow_link_stretches_wire_time() {
        let plan = Arc::new(FaultPlan::parse("slow-link:t0<->ps@10x", 0).unwrap());
        let mut net = Network::new(Some(1e6)); // 1 MB/s baseline
        let t0 = net.add_node(Role::Trainer);
        let ps = net.add_node(Role::SyncPs);
        let net = net.with_faults(plan);
        let start = std::time::Instant::now();
        net.transfer(t0, ps, 2_000); // 2ms at 1MB/s -> 20ms at 10x slowdown
        assert!(start.elapsed() >= Duration::from_millis(15));
    }
}
