//! Cluster "network": per-node byte accounting with optional simulated
//! bandwidth delay.
//!
//! In-process realization (DESIGN.md §3): trainers, embedding PSs and sync
//! PSs are actors inside one process, so the wire is a function call. What
//! the experiments need from the network layer is (a) *traffic accounting*
//! per node — the paper diagnoses the FR-EASGD-5 plateau by looking at sync
//! PS NIC saturation — and (b) optionally injecting transfer delay so small
//! real-mode runs can exhibit bandwidth effects. Throughput *modelling* at
//! paper scale happens in `sim/` instead.
//!
//! Every byte that crosses a tier boundary flows through
//! [`Network::transfer`]: embedding lookups/updates between trainers and
//! embedding PSs, EASGD elastic pushes against the sync-PS shards, and —
//! since the collective became a chunked ring fabric
//! ([`crate::sync::allreduce`]) — each MA/BMUF member's individual
//! reduce-scatter and all-gather hops toward its ring successor, and each
//! EASGD push chunk that survives the delta gate (skipped chunks suppress
//! both legs). The fig5/fig6 traffic columns therefore report *measured*
//! NIC counters for every role, not closed-form estimates; the `sim/` cost
//! model likewise prices collectives from the measured schedule
//! ([`crate::sync::traffic`]), with the textbook ring formula surviving
//! only as the cross-check reference
//! (`AllReduceGroup::ring_bytes_per_member`). Transfers are full-duplex:
//! `tx` accrues to the source NIC and `rx` to the destination NIC of the
//! same call.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

/// Node roles for per-role aggregation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Trainer,
    EmbeddingPs,
    SyncPs,
    Reader,
}

/// One node's NIC counters.
#[derive(Debug, Default)]
pub struct Nic {
    pub tx_bytes: AtomicU64,
    pub rx_bytes: AtomicU64,
}

/// The cluster fabric: one NIC per node plus an optional bandwidth model.
pub struct Network {
    nodes: Vec<(Role, Nic)>,
    /// simulated per-NIC bandwidth in bytes/sec (None = only account)
    pub bandwidth: Option<f64>,
}

/// Handle for one endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeId(pub usize);

impl Network {
    pub fn new(bandwidth: Option<f64>) -> Self {
        Self { nodes: Vec::new(), bandwidth }
    }

    pub fn add_node(&mut self, role: Role) -> NodeId {
        self.nodes.push((role, Nic::default()));
        NodeId(self.nodes.len() - 1)
    }

    /// Record a transfer of `bytes` from `src` to `dst`; if a bandwidth model
    /// is installed, block the calling thread for the wire time. Transfers
    /// are full-duplex (tx and rx accounted separately).
    pub fn transfer(&self, src: NodeId, dst: NodeId, bytes: u64) {
        self.nodes[src.0].1.tx_bytes.fetch_add(bytes, Relaxed);
        self.nodes[dst.0].1.rx_bytes.fetch_add(bytes, Relaxed);
        if let Some(bw) = self.bandwidth {
            let secs = bytes as f64 / bw;
            if secs > 1e-6 {
                std::thread::sleep(Duration::from_secs_f64(secs));
            }
        }
    }

    pub fn tx(&self, n: NodeId) -> u64 {
        self.nodes[n.0].1.tx_bytes.load(Relaxed)
    }

    pub fn rx(&self, n: NodeId) -> u64 {
        self.nodes[n.0].1.rx_bytes.load(Relaxed)
    }

    /// Total bytes through NICs of a given role (tx + rx).
    pub fn role_bytes(&self, role: Role) -> u64 {
        self.nodes
            .iter()
            .filter(|(r, _)| *r == role)
            .map(|(_, nic)| nic.tx_bytes.load(Relaxed) + nic.rx_bytes.load(Relaxed))
            .sum()
    }

    /// Transmitted bytes summed over every NIC of a role.
    pub fn role_tx(&self, role: Role) -> u64 {
        self.nodes
            .iter()
            .filter(|(r, _)| *r == role)
            .map(|(_, nic)| nic.tx_bytes.load(Relaxed))
            .sum()
    }

    /// Received bytes summed over every NIC of a role.
    pub fn role_rx(&self, role: Role) -> u64 {
        self.nodes
            .iter()
            .filter(|(r, _)| *r == role)
            .map(|(_, nic)| nic.rx_bytes.load(Relaxed))
            .sum()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

/// 25 Gbit Ethernet (the paper's testbed NIC), in bytes/sec.
pub const PAPER_NIC_BYTES_PER_SEC: f64 = 25.0e9 / 8.0;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn accounting_only_by_default() {
        let mut net = Network::new(None);
        let a = net.add_node(Role::Trainer);
        let b = net.add_node(Role::SyncPs);
        let net = Arc::new(net);
        net.transfer(a, b, 100);
        net.transfer(b, a, 40);
        assert_eq!(net.tx(a), 100);
        assert_eq!(net.rx(b), 100);
        assert_eq!(net.tx(b), 40);
        assert_eq!(net.role_bytes(Role::SyncPs), 140);
        assert_eq!(net.role_bytes(Role::Trainer), 140);
        assert_eq!(net.role_tx(Role::Trainer), 100);
        assert_eq!(net.role_rx(Role::Trainer), 40);
        assert_eq!(net.role_rx(Role::SyncPs), 100);
        assert_eq!(net.role_tx(Role::SyncPs), 40);
    }

    #[test]
    fn concurrent_transfers_sum_exactly() {
        let mut net = Network::new(None);
        let a = net.add_node(Role::Trainer);
        let b = net.add_node(Role::EmbeddingPs);
        let net = Arc::new(net);
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let net = net.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        net.transfer(a, b, 7);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(net.rx(b), 4 * 1000 * 7);
    }

    #[test]
    fn bandwidth_injects_delay() {
        let mut net = Network::new(Some(1e6)); // 1 MB/s
        let a = net.add_node(Role::Trainer);
        let b = net.add_node(Role::SyncPs);
        let t0 = std::time::Instant::now();
        net.transfer(a, b, 20_000); // 20ms at 1MB/s
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }
}
