//! Minimal JSON parser — enough for the artifact `*.meta.json` files and the
//! experiment result files this repo writes. Supports objects, arrays,
//! strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(anyhow!("expected number, got {self}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(anyhow!("expected array, got {self}")),
        }
    }

    /// Convenience: required numeric field of an object.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))?.as_usize()
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write!(f, "{:?}", s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{:?}:{v}", k)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            m.insert(k, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                _ => {
                    // copy raw utf-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_object() {
        let j = Json::parse(
            r#"{"batch": 32, "name": "tiny", "bot_mlp": [16, 8], "nested": {"x": -1.5e2}}"#,
        )
        .unwrap();
        assert_eq!(j.req_usize("batch").unwrap(), 32);
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "tiny");
        let arr = j.get("bot_mlp").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize().unwrap(), 8);
        assert_eq!(j.get("nested").unwrap().get("x").unwrap().as_f64().unwrap(), -150.0);
    }

    #[test]
    fn parses_strings_with_escapes() {
        let j = Json::parse(r#"["a\nb", "A", "\\"]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_str().unwrap(), "a\nb");
        assert_eq!(a[1].as_str().unwrap(), "A");
        assert_eq!(a[2].as_str().unwrap(), "\\");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,true,null],"b":"x"}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn parses_real_artifact_meta() {
        // mirror of what aot.py emits
        let src = r#"{
          "artifact_version": 1, "batch": 32, "bot_mlp": [16, 8], "emb_dim": 8,
          "name": "tiny", "num_dense": 4, "num_feats": 5, "num_interactions": 10,
          "num_params": 537, "num_tables": 4, "seed": 20200630,
          "top_in": 18, "top_mlp": [16]
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.req_usize("num_params").unwrap(), 537);
        assert_eq!(j.req_usize("seed").unwrap(), 20200630);
    }
}
