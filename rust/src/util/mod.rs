//! Small self-contained substrates: RNG, JSON, CLI parsing, bench + property
//! test harnesses.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (serde, clap,
//! criterion, proptest, rand) are implemented here at the scale this project
//! needs. Each submodule is tested in place.

pub mod affinity;
pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;

/// Normalize a per-bucket byte tally into fractional shares summing to 1.
/// Empty when the tally is empty or all zero — the single definition behind
/// `PsTrafficSnapshot::partition_byte_shares` and
/// `MetricsSnapshot::partition_byte_shares`, so the share semantics the
/// `sim/` cost model consumes can never diverge between the two sources.
pub fn byte_shares(bytes: &[u64]) -> Vec<f64> {
    let total: u64 = bytes.iter().sum();
    if total == 0 {
        return Vec::new();
    }
    bytes.iter().map(|&b| b as f64 / total as f64).collect()
}

/// Format a float with engineering-style thousands separators (for tables).
pub fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{:.0}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_count_bands() {
        assert_eq!(fmt_count(12.0), "12");
        assert_eq!(fmt_count(9_600.0), "9.6k");
        assert_eq!(fmt_count(96_000_000.0), "96.00M");
        assert_eq!(fmt_count(4.87e10), "48.70G");
    }
}
