//! Tiny argv parser: `--key value`, `--key=value`, `--flag`, positionals.
//! (clap is unavailable in the offline vendor set.)

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse an argv slice (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare -- is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow!("invalid value for --{key}: {s:?} ({e})")),
        }
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    /// Comma-separated list of T.
    pub fn parse_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.trim()
                        .parse::<T>()
                        .map_err(|e| anyhow!("invalid list item {p:?} for --{key}: {e}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = args("train extra --preset tiny --trainers=4 --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("preset"), Some("tiny"));
        assert_eq!(a.parse_or("trainers", 0usize).unwrap(), 4);
        assert!(a.has("verbose"));
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = args("--fast --n 3");
        assert_eq!(a.get("fast"), Some("true"));
        assert_eq!(a.parse_or("n", 0u32).unwrap(), 3);
    }

    #[test]
    fn lists_and_errors() {
        let a = args("--ks 5,10,30");
        assert_eq!(a.parse_list("ks", &[1usize]).unwrap(), vec![5, 10, 30]);
        assert!(a.parse_or("ks", 1usize).is_err());
        assert!(a.require("nope").is_err());
    }
}
