//! Deterministic, splittable pseudo-random generation.
//!
//! Two generators:
//! - [`splitmix64`] — the stateless mixer. Used for *counter-based* random
//!   streams: the synthetic dataset derives every feature of example `i`
//!   purely from `(seed, i, field)`, so any worker can materialize any
//!   example without coordination (the property the sharded reader and the
//!   one-pass partition rely on).
//! - [`Rng`] — a small xoshiro-style sequential generator for everything
//!   that just needs a stream (shuffles, property tests, init).
//!
//! `dense_init` reproduces `python/compile/model.py::init_params` bit-for-bit
//! so rust trainers and the JAX reference start from identical parameters.

/// The splitmix64 finalizer: a high-quality 64-bit mixer.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Combine a seed and up to two stream coordinates into one mixed word.
#[inline]
pub fn mix3(seed: u64, a: u64, b: u64) -> u64 {
    splitmix64(seed ^ splitmix64(a ^ splitmix64(b)))
}

/// Uniform f32 in [0, 1) from a mixed word (top 24 bits, like the python side).
#[inline]
pub fn u01(word: u64) -> f32 {
    (word >> 40) as f32 / (1u32 << 24) as f32
}

/// Standard normal via Box–Muller on two mixed words.
#[inline]
pub fn normal(w1: u64, w2: u64) -> f32 {
    let u1 = (u01(w1) + 1e-7).min(1.0 - 1e-7);
    let u2 = u01(w2);
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
}

/// Sequential PRNG (xorshift64* core) with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: splitmix64(seed).max(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    #[inline]
    pub fn u01(&mut self) -> f32 {
        u01(self.next_u64())
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform integer in [lo, hi].
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    pub fn normal(&mut self) -> f32 {
        let (a, b) = (self.next_u64(), self.next_u64());
        normal(a, b)
    }

    /// Fill a slice with iid N(0, sigma^2).
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = sigma * self.normal();
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Reproduce python `init_params`: He-uniform weights + zero biases over the
/// flat layout, derived from the vectorized splitmix64 counter stream.
pub fn dense_init(layer_dims: &[(usize, usize)], seed: u64) -> Vec<f32> {
    let num_params: usize = layer_dims.iter().map(|(i, o)| i * o + o).sum();
    let base = splitmix64(seed ^ 0x5EED_0FDA_7A);
    let mut out = vec![0f32; num_params];
    let mut off = 0usize;
    for &(n_in, n_out) in layer_dims {
        // f64 sqrt then cast, matching numpy's np.sqrt(6.0/n).astype(float32)
        let scale = (6.0f64 / n_in as f64).sqrt() as f32;
        for k in 0..n_in * n_out {
            let idx = (off + k) as u64;
            let u = u01(splitmix64(idx.wrapping_add(base)));
            out[off + k] = (u * 2.0 - 1.0) * scale;
        }
        off += n_in * n_out;
        off += n_out; // biases stay zero
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_vector() {
        // reference value from the python implementation
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn u01_in_range() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let u = r.u01();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let (mut s, mut s2) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn dense_init_shape_and_bounds() {
        let dims = [(4, 16), (16, 8)];
        let w = dense_init(&dims, 9);
        assert_eq!(w.len(), 4 * 16 + 16 + 16 * 8 + 8);
        // biases zero
        assert!(w[64..80].iter().all(|&x| x == 0.0));
        assert!(w[80 + 128..].iter().all(|&x| x == 0.0));
        let bound = (6.0f32 / 4.0).sqrt();
        assert!(w[..64].iter().all(|&x| x.abs() <= bound));
        assert!(w[..64].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn deterministic_streams() {
        assert_eq!(Rng::new(3).next_u64(), Rng::new(3).next_u64());
        assert_ne!(Rng::new(3).next_u64(), Rng::new(4).next_u64());
        assert_eq!(mix3(1, 2, 3), mix3(1, 2, 3));
        assert_ne!(mix3(1, 2, 3), mix3(1, 3, 2));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut v: Vec<u32> = (0..100).collect();
        Rng::new(5).shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
