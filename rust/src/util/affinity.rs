//! Best-effort CPU pinning for the shadow/reduce workers (`--pin-cores`).
//!
//! The shared-nothing reduce engine's whole premise is that a worker's
//! deposit banks and mean stripes stay resident in one core's cache;
//! letting the OS migrate the thread undoes that. `--pin-cores` asks for
//! a stable thread→core placement via `sched_setaffinity`, issued as a
//! raw syscall on x86_64 Linux (the crate carries no libc binding) and a
//! portable no-op everywhere else — pinning is a *hint*, never a
//! correctness requirement, so failure is reported, not fatal.
//!
//! The toggle is process-global: the pool spawn path
//! (`sync::driver::spawn_shadow_pool_adaptive`) is a public API with many
//! callers, so the config layer flips [`set_pinning`] once at startup and
//! workers consult it as they come up.

use std::sync::atomic::{AtomicBool, Ordering};

static PIN_ENABLED: AtomicBool = AtomicBool::new(false);

/// Enable or disable worker pinning process-wide (`--pin-cores`).
/// Flipped once at startup from `RunConfig::pin_cores`; workers read it
/// as they spawn, so toggling mid-run only affects later pools.
pub fn set_pinning(on: bool) {
    PIN_ENABLED.store(on, Ordering::SeqCst);
}

/// Whether `--pin-cores` is in effect for newly spawned workers.
pub fn pinning_enabled() -> bool {
    PIN_ENABLED.load(Ordering::SeqCst)
}

/// Pin the calling thread to `core` (modulo the mask width). Returns
/// `true` when the kernel accepted the mask, `false` on failure or on
/// platforms where pinning is a no-op — callers treat both the same way.
pub fn pin_current_thread(core: usize) -> bool {
    pin_impl(core)
}

#[cfg(all(target_os = "linux", target_arch = "x86_64", not(miri)))]
fn pin_impl(core: usize) -> bool {
    // a 1024-bit cpu mask, the kernel's default cpu_set_t width
    let mut mask = [0u64; 16];
    let core = core % (mask.len() * 64);
    mask[core / 64] = 1u64 << (core % 64);
    let ret: i64;
    // SAFETY: sched_setaffinity(pid=0 → calling thread, len, mask_ptr)
    // only reads `mask` from this stack frame, writes no user memory, and
    // reports failure through the return value; rcx/r11 are declared
    // clobbered because the syscall instruction overwrites them.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,
            in("rsi") std::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr() as usize,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64", not(miri))))]
fn pin_impl(_core: usize) -> bool {
    false // portable fallback: pinning is advisory, so "didn't" is fine
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toggle_is_observable() {
        set_pinning(true);
        assert!(pinning_enabled());
        set_pinning(false);
        assert!(!pinning_enabled());
    }

    #[cfg(all(target_os = "linux", target_arch = "x86_64", not(miri)))]
    #[test]
    fn pinning_to_core_zero_succeeds_on_linux() {
        // core 0 always exists; the call must not crash and should stick
        assert!(pin_current_thread(0), "sched_setaffinity(0) failed");
        // out-of-range cores wrap into the mask width rather than erroring
        let _ = pin_current_thread(usize::MAX);
    }
}
