//! Property-testing mini-harness (proptest is unavailable offline).
//!
//! `check(name, cases, |g| ...)` runs a closure over `cases` seeded
//! generators; a failure reports the reproducing seed. No shrinking — cases
//! are kept small enough to eyeball. The seed can be pinned via
//! `SHADOWSYNC_PROPTEST_SEED` for reproduction.

use super::rng::Rng;

/// A per-case generator handle.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as u64, hi as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.u01()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0f32; len];
        self.rng.fill_normal(&mut v, sigma);
        v
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

fn base_seed() -> u64 {
    std::env::var("SHADOWSYNC_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `body` for `cases` generated cases; panics with the failing seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut body: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Rng::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed on case {case} \
                 (rerun with SHADOWSYNC_PROPTEST_SEED={base})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("abs-nonneg", 50, |g| {
            let x = g.f32_in(-10.0, 10.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic]
    fn reports_failing_property() {
        check("always-small", 50, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 10, "x={x}");
        });
    }

    #[test]
    fn generators_within_bounds() {
        check("bounds", 100, |g| {
            let n = g.usize_in(1, 17);
            assert!((1..=17).contains(&n));
            let v = g.vec_f32(n, -2.0, 3.0);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|&x| (-2.0..=3.0).contains(&x)));
        });
    }
}
