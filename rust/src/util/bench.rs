//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-time per iteration with warmup, reports mean / p50 / p95 /
//! p99 and throughput. Used by `rust/benches/*.rs` (cargo bench with
//! `harness = false`) and by the perf pass in EXPERIMENTS.md §Perf.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// items/sec given `items` processed per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.mean_ns / 1e9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{:.0}ns", ns)
    }
}

/// Run `f` repeatedly for ~`budget` after ~budget/5 warmup; per-iteration
/// timing. Use `std::hint::black_box` inside `f` on inputs/outputs.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warmup + calibration
    let warm_until = Instant::now() + budget / 5;
    let mut warm_iters = 0u64;
    while Instant::now() < warm_until {
        f();
        warm_iters += 1;
    }
    let est = (budget.as_nanos() / 5).max(1) as f64 / warm_iters.max(1) as f64;
    // batch iterations so timer overhead stays <1% for fast bodies
    let batch = ((50.0 * 30.0 / est).ceil() as usize).clamp(1, 1000);

    let mut samples = Vec::new();
    let end = Instant::now() + budget * 4 / 5;
    while Instant::now() < end {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len().max(1);
    let pct = |p: f64| samples[((n as f64 * p) as usize).min(n - 1)];
    let res = BenchResult {
        name: name.to_string(),
        iters: n * batch,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
        p99_ns: pct(0.99),
    };
    println!(
        "{:40} mean {:>10}  p50 {:>10}  p95 {:>10}  p99 {:>10}  ({} iters)",
        res.name,
        fmt_ns(res.mean_ns),
        fmt_ns(res.p50_ns),
        fmt_ns(res.p95_ns),
        fmt_ns(res.p99_ns),
        res.iters
    );
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleepish_body() {
        let r = bench("spin50us", Duration::from_millis(200), || {
            let t = Instant::now();
            while t.elapsed() < Duration::from_micros(50) {}
        });
        assert!(r.mean_ns > 40_000.0 && r.mean_ns < 500_000.0, "mean={}", r.mean_ns);
        assert!(r.iters > 0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e6,
            p50_ns: 1e6,
            p95_ns: 1e6,
            p99_ns: 1e6,
        };
        assert!((r.throughput(100.0) - 100_000.0).abs() < 1.0);
    }
}
