//! `ablate-faults`: the robustness ablation — seeded fault plans injected
//! under real quality runs.
//!
//! Three questions, one section each:
//!
//! 1. **Stragglers** — does the health controller's demote-to-EASGD beat a
//!    static rendezvous (BMUF) fabric when one trainer runs 20 ms/lap slow?
//!    The static arm drags every ring round down to the straggler's pace;
//!    the adaptive arm demotes the stalled partitions to the centralized
//!    tier and each survivor syncs at its own rate.
//! 2. **Crashes** — does the heartbeat watchdog proxy-depart a crashed
//!    trainer so the survivors' rounds keep closing, and does the trainer
//!    rejoin elastically when its window ends? The run must complete with
//!    every shard drained.
//! 3. **Drops** — under a lossy fabric with bounded-backoff push retries,
//!    does `metrics.sync_bytes` stay *exactly* equal to the delivered
//!    sync-PS NIC traffic (attempted-but-dropped bytes live only in the
//!    fault plan's ledger)?
//!
//! The invariants are `ensure!`d, not just tabulated — CI's chaos job runs
//! this experiment with `--smoke` and fails on any regression.

use anyhow::{ensure, Result};

use crate::config::{RunConfig, SyncAlgo, SyncMode};
use crate::coordinator::TrainOutcome;
use crate::runtime::Runtime;
use crate::sim::CostModel;

use super::{fmt_loss, quality_cfg, run_quality, ExpOpts, Report};

const TRAIN_EXAMPLES: u64 = 90_000;
const SMOKE_EXAMPLES: u64 = 30_000;

/// A stall that outlives any run: the straggler never recovers, so the
/// static arm pays for it the whole way through.
const STALL_PLAN: &str = "stall:t2@sweep5+1000000";
/// Transient crash: trainer 1 goes dark mid-run and comes back, so the
/// same run shows both the proxy-depart and the elastic rejoin.
const CRASH_PLAN: &str = "crash:t1@sweep10+400";
/// 5% seeded drop probability on every transfer touching trainer 0 —
/// low enough that the default 3-retry budget virtually never exhausts,
/// high enough that hundreds of retries fire over a run.
const DROP_PLAN: &str = "drop:t0@0.05";

/// 3 trainers × 2 Hogwild threads, shadow mode, 1 ms sweep clock (fault
/// windows are anchored in shadow sweeps; a short run must reach and
/// outlive them).
fn base_cfg(opts: &ExpOpts, algo: SyncAlgo) -> RunConfig {
    let examples = if opts.smoke { SMOKE_EXAMPLES } else { TRAIN_EXAMPLES };
    let mut cfg = quality_cfg(opts, 3, 2, algo, SyncMode::Shadow, examples);
    cfg.shadow_interval_ms = 1;
    cfg
}

fn outcome_row(label: &str, o: &TrainOutcome) -> Vec<String> {
    vec![
        label.to_string(),
        fmt_loss(o.train_loss),
        fmt_loss(o.eval.ne()),
        format!("{:.0}", o.eps),
        format!("{:.2}", o.avg_sync_gap),
        o.metrics.syncs.to_string(),
        o.health_departs.to_string(),
        o.health_demotions.to_string(),
        o.health_promotions.to_string(),
    ]
}

const ROW_HEADERS: [&str; 9] = [
    "arm",
    "train loss",
    "eval NE",
    "EPS",
    "avg gap",
    "rounds",
    "departs",
    "demotions",
    "promotions",
];

pub fn run(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let mut r = Report::new(
        "Fault ablation: stragglers, crashes, drops",
        "robustness ablation (no direct paper figure; exercises the §3 fabric under §4-style runs)",
    );

    // ---- section 1: straggler vs adaptive algorithm switching ----
    r.para(&format!(
        "**Stragglers.** 2-partition BMUF fabric; `{STALL_PLAN}` stretches every lap of \
         trainer 2 by 20 ms. The static arm keeps the rendezvous ring and inherits the \
         straggler's pace; the adaptive arm (`--health-adaptive`) demotes stalled \
         partitions to EASGD against the sync-PS tier and promotes them back only if \
         the straggle clears (here: never)."
    ));

    let mut healthy = base_cfg(opts, SyncAlgo::Bmuf);
    healthy.sync_partitions = 2;
    healthy.shadow_threads = 2;
    let o_healthy = run_quality(&healthy, &rt)?;

    let mut stalled = healthy.clone();
    stalled.fault_plan = Some(STALL_PLAN.into());
    let o_static = run_quality(&stalled, &rt)?;

    let mut async_static = base_cfg(opts, SyncAlgo::Easgd);
    async_static.sync_partitions = 2;
    async_static.shadow_threads = 2;
    async_static.fault_plan = Some(STALL_PLAN.into());
    let o_async = run_quality(&async_static, &rt)?;

    let mut adaptive = stalled.clone();
    adaptive.health_adaptive = true;
    adaptive.health_stall_factor = 2.5;
    adaptive.num_sync_ps = 1;
    let o_adaptive = run_quality(&adaptive, &rt)?;

    ensure!(
        o_adaptive.health_demotions >= 1,
        "the health controller never demoted under a permanent 20 ms straggle \
         (demotions = {})",
        o_adaptive.health_demotions
    );
    for (label, o) in [
        ("healthy", &o_healthy),
        ("stall/static-sync", &o_static),
        ("stall/static-async", &o_async),
        ("stall/adaptive", &o_adaptive),
    ] {
        ensure!(
            o.train_loss.is_finite() && o.eval.ne().is_finite(),
            "{label} arm did not converge to finite losses"
        );
        ensure!(o.metrics.examples > 0, "{label} arm trained no examples");
    }

    r.table(
        &ROW_HEADERS,
        &[
            outcome_row("healthy / BMUF", &o_healthy),
            outcome_row("stall / static-sync (BMUF)", &o_static),
            outcome_row("stall / static-async (EASGD)", &o_async),
            outcome_row("stall / adaptive demote", &o_adaptive),
        ],
    );
    r.para(&format!(
        "Adaptive arm: {} demotion(s) published; rounds no longer gated on the \
         straggler's ring deposits ({} adaptive vs {} static-sync rounds).",
        o_adaptive.health_demotions, o_adaptive.metrics.syncs, o_static.metrics.syncs
    ));

    // paper-scale EPS under the same degradation, priced by the cost
    // model's straggler hook: a 4x-slow trainer paces every rendezvous
    // round (and, for stop-the-world modes, the whole barrier), while the
    // demoted centralized fabric only loses the straggler's own share
    let healthy_cm = CostModel::paper_scale().with_partitioned_shadow(2, 2);
    let degraded_cm =
        CostModel::paper_scale().with_partitioned_shadow(2, 2).with_straggler_factor(4.0);
    use SyncAlgo::{Bmuf, Easgd};
    let s_healthy = healthy_cm.simulate_hybrid_shadow(20, 24, &[Bmuf, Bmuf], 2);
    let s_static = degraded_cm.simulate_hybrid_shadow(20, 24, &[Bmuf, Bmuf], 2);
    let s_async = degraded_cm.simulate_hybrid_shadow(20, 24, &[Easgd, Easgd], 2);
    let s_fr = degraded_cm.simulate(20, 24, Bmuf, SyncMode::FixedRate { gap: 10 }, 0);
    ensure!(
        s_async.avg_sync_gap < s_static.avg_sync_gap,
        "paper-scale model must price the demoted fabric's gap under the static ring's"
    );
    r.para(
        "Paper scale (20 trainers × 24 threads, one 4×-slow straggler, cost model): \
         the adaptive demotion keeps background sync's EPS advantage *and* a \
         healthy-cluster sync gap, while the static ring's gap inflates with the \
         straggler and a stop-the-world ring drags the whole cluster down:",
    );
    r.table(
        &["fabric under 4x straggler", "EPS", "avg gap (iters)"],
        &[
            vec![
                "healthy BMUF ring (reference)".into(),
                format!("{:.0}", s_healthy.eps),
                format!("{:.1}", s_healthy.avg_sync_gap),
            ],
            vec![
                "static-sync: shadow BMUF ring".into(),
                format!("{:.0}", s_static.eps),
                format!("{:.1}", s_static.avg_sync_gap),
            ],
            vec![
                "adaptive: demoted to EASGD".into(),
                format!("{:.0}", s_async.eps),
                format!("{:.1}", s_async.avg_sync_gap),
            ],
            vec![
                "FR-BMUF-10 (stop-the-world)".into(),
                format!("{:.0}", s_fr.eps),
                format!("{:.1}", s_fr.avg_sync_gap),
            ],
        ],
    );

    // ---- section 2: crash, proxy-depart, elastic rejoin ----
    r.para(&format!(
        "**Crashes.** Single BMUF ring; `{CRASH_PLAN}` takes trainer 1 dark for 400 \
         sweep-clock ticks mid-run. The heartbeat watchdog (60 ms timeout) \
         proxy-departs it so survivors' rounds keep closing; when the window ends the \
         trainer warm-starts and rejoins, and its shard still drains completely."
    ));

    let mut crash = base_cfg(opts, SyncAlgo::Bmuf);
    crash.fault_plan = Some(CRASH_PLAN.into());
    crash.heartbeat_timeout_ms = 60;
    let o_crash = run_quality(&crash, &rt)?;

    ensure!(
        o_crash.health_departs >= 1,
        "the watchdog never departed the crashed trainer (departs = {})",
        o_crash.health_departs
    );
    ensure!(
        o_crash.train_loss.is_finite() && o_crash.metrics.examples > 0,
        "survivors did not converge across the crash window"
    );

    r.table(&ROW_HEADERS, &[outcome_row("crash / watchdog + rejoin", &o_crash)]);
    r.para(&format!(
        "{} proxy-depart(s); {} examples drained (the crashed trainer resumed its \
         shard after the window).",
        o_crash.health_departs, o_crash.metrics.examples
    ));

    // ---- section 3: drops, retries, byte exactness ----
    r.para(&format!(
        "**Drops.** Centralized EASGD fabric under `{DROP_PLAN}`: every transfer \
         touching trainer 0 is dropped with seeded probability 0.05 and the push \
         path retries with bounded exponential backoff. The accounting invariant is \
         exact equality — `metrics.sync_bytes` counts only delivered sync traffic, \
         matching the sync-PS NIC counters byte-for-byte; attempted-but-dropped \
         bytes appear only in the plan's ledger."
    ));

    let mut lossy = base_cfg(opts, SyncAlgo::Easgd);
    lossy.fault_plan = Some(DROP_PLAN.into());
    let o_drop = run_quality(&lossy, &rt)?;

    ensure!(
        o_drop.metrics.sync_bytes == o_drop.sync_ps_bytes,
        "byte exactness broken under drops + retries: metrics.sync_bytes = {} but \
         sync-PS NIC counters saw {}",
        o_drop.metrics.sync_bytes,
        o_drop.sync_ps_bytes
    );
    ensure!(o_drop.dropped_bytes > 0, "a 5% drop plan dropped nothing");
    ensure!(
        o_drop.metrics.sync_push_retries >= 1,
        "the push path never retried under a 5% drop plan"
    );

    r.table(
        &["arm", "sync bytes", "sync-PS NIC bytes", "dropped bytes", "push retries"],
        &[vec![
            "drop / retry".into(),
            o_drop.metrics.sync_bytes.to_string(),
            o_drop.sync_ps_bytes.to_string(),
            o_drop.dropped_bytes.to_string(),
            o_drop.metrics.sync_push_retries.to_string(),
        ]],
    );
    r.para("All invariants held (they are asserted, not just reported).");

    Ok(r.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_arm_configs_validate() {
        let opts = ExpOpts::default();
        let mut stalled = base_cfg(&opts, SyncAlgo::Bmuf);
        stalled.sync_partitions = 2;
        stalled.shadow_threads = 2;
        stalled.fault_plan = Some(STALL_PLAN.into());
        stalled.validate().unwrap();

        let mut adaptive = stalled.clone();
        adaptive.health_adaptive = true;
        adaptive.health_stall_factor = 2.5;
        adaptive.num_sync_ps = 1;
        adaptive.validate().unwrap();

        let mut crash = base_cfg(&opts, SyncAlgo::Bmuf);
        crash.fault_plan = Some(CRASH_PLAN.into());
        crash.heartbeat_timeout_ms = 60;
        crash.validate().unwrap();
        // a crash against a rendezvous fabric with no recovery path must
        // be rejected, not deadlock at shutdown
        crash.heartbeat_timeout_ms = 0;
        assert!(crash.validate().is_err());

        let mut lossy = base_cfg(&opts, SyncAlgo::Easgd);
        lossy.fault_plan = Some(DROP_PLAN.into());
        lossy.validate().unwrap();
    }
}
