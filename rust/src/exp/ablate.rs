//! Ablations beyond the paper's tables, for the design choices §3.3 calls
//! out in prose.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::config::{RunConfig, SyncAlgo, SyncMode};
use crate::metrics::Metrics;
use crate::net::{Network, Role};
use crate::runtime::Runtime;
use crate::sim::CostModel;
use crate::sync::driver::{spawn_shadow_pool_adaptive, ShadowTask};
use crate::sync::prim::AtomicBool;
use crate::sync::{
    build_strategy, AllReduceGroup, PartitionPlan, RepartitionController, SyncPsGroup,
    WireCodec,
};
use crate::tensor::HogwildBuffer;
use crate::util::rng::Rng;

use super::{fmt_loss, quality_cfg, run_quality, ExpOpts, Report};

const TRAIN_EXAMPLES: u64 = 200_000;

/// §3.3: "if we directly copy the averaged weight back, we will lose the
/// updates to the local replicas [made] when the background synchronization
/// is happening" — the asymmetric elastic pull is claimed essential.
/// α=1.0 under S-MA *is* the copy-back variant.
pub fn run_elastic(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let mut rows = Vec::new();
    for (label, alpha) in [("elastic pull (α=0.5)", 0.5f32), ("copy-back (α=1.0)", 1.0)] {
        let mut cfg = quality_cfg(opts, 4, 3, SyncAlgo::Ma, SyncMode::Shadow, TRAIN_EXAMPLES);
        cfg.alpha = alpha;
        cfg.shadow_interval_ms = 1;
        // paper-scale AllReduce wall time: the window during which Hogwild
        // workers make progress that copy-back would discard (in-process the
        // collective is near-instant, so we model the wire; DESIGN.md §3)
        cfg.collective_wire_ms = 25;
        let o = run_quality(&cfg, &rt)?;
        rows.push(vec![
            label.to_string(),
            fmt_loss(o.train_loss),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
            format!("{}", o.metrics.syncs),
        ]);
    }
    let mut r = Report::new(
        "Ablation: elastic pull vs copy-back under S-MA",
        "paper §3.3 (the asymmetric-interpolation modification)",
    );
    r.para(
        "4 trainers × 3 threads, S-MA, shadow free-running, 25 ms simulated \
         AllReduce wall time per round.",
    );
    r.table(&["variant", "train loss", "eval loss", "eval NE", "sync rounds"], &rows);
    r.para(
        "Expected: copy-back discards the Hogwild updates that landed during \
         each background AllReduce, degrading (or at best matching) quality — \
         supporting the paper's claim that the elastic pull is what makes \
         background MA safe.",
    );
    Ok(r.finish())
}

/// Throttling the shadow loop interpolates between FR-like infrequent sync
/// and the paper's free-running shadow; sweeps the implicit sync gap.
pub fn run_shadow_rate(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let mut rows = Vec::new();
    for interval_ms in [0u64, 2, 10, 50] {
        let mut cfg = quality_cfg(opts, 4, 3, SyncAlgo::Easgd, SyncMode::Shadow, TRAIN_EXAMPLES);
        cfg.shadow_interval_ms = interval_ms;
        let o = run_quality(&cfg, &rt)?;
        rows.push(vec![
            format!("{interval_ms} ms"),
            format!("{:.3}", o.avg_sync_gap),
            fmt_loss(o.train_loss),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
        ]);
    }
    let mut r = Report::new(
        "Ablation: shadow-loop pacing",
        "extension of paper §4.1 (sync-rate sensitivity, background edition)",
    );
    r.para(
        "4 trainers × 3 threads, S-EASGD, 1 sync PS; the shadow thread \
         sleeps `interval` between rounds.",
    );
    r.table(
        &["shadow interval", "avg sync gap (Eq. 2)", "train loss", "eval loss", "eval NE"],
        &rows,
    );
    r.para(
        "Expected: quality is robust over a wide pacing range (the paper's \
         free-running choice is convenient, not critical), degrading only \
         once the gap grows to FR-EASGD-100 territory.",
    );
    Ok(r.finish())
}

/// The partitioned shadow fabric (the paper's §3.2 "each partition synced
/// by its own background thread"), swept over (P partitions, S shadow
/// threads): real runs measure quality + the live delta-gate skip rate;
/// the paper-scale model prices EPS at 20×24 with the same (P, S).
pub fn run_partitions(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let sweep: [(usize, usize); 4] = [(1, 1), (2, 1), (4, 2), (4, 4)];
    let mut rows = Vec::new();
    for (p, s) in sweep {
        let mut cfg = quality_cfg(opts, 4, 3, SyncAlgo::Easgd, SyncMode::Shadow, TRAIN_EXAMPLES);
        cfg.sync_partitions = p;
        cfg.shadow_threads = s;
        // small chunks + the adaptive gate so every partition's private
        // sketch engages at this reduced scale
        cfg.easgd_chunk_elems = 512;
        cfg.delta_skip_target = 0.25;
        let o = run_quality(&cfg, &rt)?;
        let skip = o.sync_traffic.as_ref().map_or(0.0, |t| t.skip_fraction());
        let eps = CostModel::paper_scale()
            .with_partitioned_shadow(p, s)
            .simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2)
            .eps;
        // a partition that never synced must read as an alarm, not vanish:
        // an infinite gap (or a partition missing from the table entirely)
        // renders as ∞ instead of being filtered out of the max
        let worst_gap = o.partition_gaps.iter().cloned().fold(0.0f64, f64::max);
        let worst = if o.partition_gaps.len() < p || worst_gap.is_infinite() {
            "∞ (starved)".to_string()
        } else {
            format!("{worst_gap:.2}")
        };
        rows.push(vec![
            format!("P={p} S={s}"),
            format!("{eps:.0}"),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
            format!("{:.0}%", 100.0 * skip),
            worst,
            format!("{}", o.metrics.syncs),
        ]);
    }
    let mut r = Report::new(
        "Ablation: partitioned shadow fabric (P × S)",
        "paper §3.2 (partitioned dense parameters, one background thread per partition)",
    );
    r.para(
        "4 trainers × 3 threads, S-EASGD with the adaptive delta gate \
         (target 25%), 512-element push chunks; EPS from the paper-scale \
         model at 20×24 trainers/threads with the same (P, S).",
    );
    r.table(
        &[
            "fabric",
            "model EPS @20",
            "eval loss",
            "eval NE",
            "skip rate",
            "worst part gap",
            "sync rounds",
        ],
        &rows,
    );
    r.para(
        "Expected: quality holds across (P, S) while partition rounds \
         shrink; raising S multiplies sync frequency per partition (the \
         worst per-partition gap drops) without touching the training loop, \
         and the per-partition gates keep the skip rate near its target.",
    );
    Ok(r.finish())
}

/// Synthetic skewed-write workload scale (no artifacts needed: the dense
/// replica and sync fabric run bare, with writer threads standing in for
/// Hogwild workers).
const SKEW_LEN: usize = 65_536;
const SKEW_CHUNK: usize = 512;
const SKEW_P: usize = 4;
const SKEW_S: usize = 2;
const SKEW_TRAINERS: usize = 2;

/// One arm of the repartitioning ablation's synthetic workload.
struct SkewOutcome {
    gaps: Vec<f64>,
    rounds: u64,
    repartitions: u64,
    plan_sizes: Vec<usize>,
    shares: Vec<f64>,
    /// per-partition Eq.-2 gap the paper-scale model prices at 20×24 from
    /// the measured byte shares (the hot-partition-bound sweep)
    model_gap: f64,
}

impl SkewOutcome {
    fn worst_gap(&self) -> f64 {
        self.gaps.iter().cloned().fold(0.0, f64::max)
    }
}

/// Drive the skewed workload: writer threads hammer the hot first quarter
/// of the vector every lap (and rarely touch the cold tail) while shadow
/// pools sync the partitioned fabric — statically, or with measured-cost
/// adaptive repartitioning.
fn skewed_workload(adaptive: bool, millis: u64) -> Result<SkewOutcome> {
    let cfg = RunConfig {
        num_trainers: SKEW_TRAINERS,
        sync_partitions: SKEW_P,
        shadow_threads: SKEW_S,
        easgd_chunk_elems: SKEW_CHUNK,
        delta_threshold: 1e-3,
        repartition_every: if adaptive { 400 } else { 0 },
        ..RunConfig::default()
    };
    let mut net = Network::new(None);
    let nodes: Vec<_> = (0..SKEW_TRAINERS).map(|_| net.add_node(Role::Trainer)).collect();
    let w0 = vec![0.0f32; SKEW_LEN];
    let sync_ps = Arc::new(
        SyncPsGroup::build(&w0, 2, &mut net)
            .with_push_chunking(SKEW_CHUNK, cfg.delta_threshold),
    );
    let plan = PartitionPlan::build(SKEW_LEN, &cfg)?;
    let groups: Vec<Option<Arc<AllReduceGroup>>> = vec![None; SKEW_P];
    let controller = if adaptive {
        Some(Arc::new(RepartitionController::new(
            &cfg,
            SKEW_LEN,
            Some(sync_ps.clone()),
            plan.clone(),
            groups,
        )))
    } else {
        None
    };
    let net = Arc::new(net);
    let metrics = Arc::new(Metrics::new());
    let stop = Arc::new(AtomicBool::new(false));
    let mut pools = Vec::new();
    let mut writers = Vec::new();
    for (t, &node) in nodes.iter().enumerate() {
        let replica =
            Arc::new(HogwildBuffer::from_slice(&w0).with_dirty_epochs(SKEW_CHUNK));
        let tasks = plan
            .partitions
            .iter()
            .map(|part| {
                Ok(ShadowTask {
                    partition: part.index,
                    range: part.range,
                    strategy: build_strategy(
                        &cfg,
                        part,
                        t,
                        &w0,
                        Some(sync_ps.clone()),
                        None,
                    )?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        pools.push(spawn_shadow_pool_adaptive(
            tasks,
            replica.clone(),
            node,
            net.clone(),
            metrics.clone(),
            stop.clone(),
            Duration::ZERO,
            t,
            SKEW_S,
            controller.clone(),
            None,
        ));
        let stop = stop.clone();
        let metrics = metrics.clone();
        writers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x5EED ^ t as u64);
            let hot = SKEW_LEN / 4;
            let mut lap = 0u64;
            while !stop.load(Relaxed) {
                // the hot quarter is rewritten every iteration...
                let noise: Vec<f32> = (0..hot).map(|_| rng.u01() - 0.5).collect();
                replica.axpy_range(0, 0.2, &noise);
                // ...the cold tail only once in a while, in small touches
                if lap % 24 == 0 {
                    let lo = hot + (rng.next_u64() as usize) % (SKEW_LEN - hot - 64);
                    let cold: Vec<f32> = (0..64).map(|_| rng.u01() - 0.5).collect();
                    replica.axpy_range(lo, 0.2, &cold);
                }
                metrics.record_batch(1, 0.0);
                lap += 1;
                std::thread::yield_now();
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(millis));
    stop.store(true, Relaxed);
    let mut rounds = 0u64;
    for h in pools {
        rounds += h.join().expect("shadow pool panicked")?;
    }
    for w in writers {
        w.join().expect("writer panicked");
    }
    let gaps = metrics.partition_sync_gaps();
    let shares = sync_ps.traffic().partition_byte_shares();
    let plan_sizes = match &controller {
        Some(c) => {
            c.current_epoch().plan.partitions.iter().map(|p| p.range.len).collect()
        }
        None => plan.partitions.iter().map(|p| p.range.len).collect(),
    };
    let mut model = CostModel::paper_scale().with_partitioned_shadow(SKEW_P, SKEW_S);
    if !shares.is_empty() {
        model = model.with_partition_byte_shares(&shares);
    }
    let model_gap =
        model.simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2).avg_sync_gap;
    Ok(SkewOutcome {
        gaps,
        rounds,
        repartitions: controller.as_ref().map_or(0, |c| c.repartitions()),
        plan_sizes,
        shares,
        model_gap,
    })
}

/// ROADMAP's measured-cost repartitioning follow-on, ablated: static
/// uniform-cost plans vs adaptive repartitioning on a skewed-write
/// workload (synthetic, artifact-free), plus a real-training quality check
/// that the cutover machinery costs nothing when writes are uniform.
pub fn run_repartition(opts: &ExpOpts) -> Result<String> {
    let mut r = Report::new(
        "Ablation: measured-cost adaptive repartitioning",
        "ROADMAP follow-on to paper §3.2 (cost-balanced partitioned sync)",
    );

    // ---- part 1: synthetic skewed writes, static vs adaptive ----
    let millis = ((600.0 * opts.scale) as u64).clamp(150, 2_000);
    let arms = [
        ("static uniform-cost plan", skewed_workload(false, millis)?),
        ("adaptive repartitioning", skewed_workload(true, millis)?),
    ];
    let mut rows = Vec::new();
    for (label, o) in &arms {
        let worst = o.worst_gap();
        let worst_s = if worst.is_infinite() {
            "∞ (starved)".to_string()
        } else {
            format!("{worst:.2}")
        };
        let sizes: Vec<String> = o.plan_sizes.iter().map(|s| s.to_string()).collect();
        let shares: Vec<String> =
            o.shares.iter().map(|s| format!("{:.0}%", 100.0 * s)).collect();
        rows.push(vec![
            label.to_string(),
            sizes.join("/"),
            shares.join("/"),
            worst_s,
            format!("{:.2}", o.model_gap),
            o.rounds.to_string(),
            o.repartitions.to_string(),
        ]);
    }
    r.para(&format!(
        "Synthetic skewed workload: {SKEW_TRAINERS} trainers, {SKEW_LEN}-element \
         replicas, P={SKEW_P} S={SKEW_S}, fixed 1e-3 delta gate; writer threads \
         rewrite the hot first quarter every iteration and barely touch the \
         tail, {millis} ms free-running. \"model worst gap\" prices the \
         20×24 paper-scale per-partition Eq.-2 gap from each arm's measured \
         per-partition byte shares (a sweep is gated by its hottest \
         partition's round)."
    ));
    r.table(
        &[
            "plan",
            "partition sizes",
            "byte shares",
            "worst part gap",
            "model worst gap @20",
            "sync rounds",
            "repartitions",
        ],
        &rows,
    );
    r.para(
        "Expected: the static plan leaves the whole hot quarter in one \
         partition, whose slow rounds gate the worst per-partition gap; the \
         adaptive plan splits the hot region across partitions (sizes \
         shrink where the write rate is high) so its rounds shorten, the \
         byte shares even out, and both the measured and the model-priced \
         worst gap drop strictly below the static plan's.",
    );

    // ---- part 2: real training, repartitioning off vs on ----
    let rt = Runtime::cpu()?;
    let mut rows2 = Vec::new();
    for every in [0u64, 25] {
        let mut cfg =
            quality_cfg(opts, 4, 3, SyncAlgo::Easgd, SyncMode::Shadow, TRAIN_EXAMPLES);
        cfg.sync_partitions = 4;
        cfg.shadow_threads = 2;
        cfg.easgd_chunk_elems = 512;
        cfg.delta_skip_target = 0.25;
        cfg.repartition_every = every;
        let o = run_quality(&cfg, &rt)?;
        let worst = o.partition_gaps.iter().cloned().fold(0.0f64, f64::max);
        rows2.push(vec![
            if every == 0 { "static (off)".into() } else { format!("every {every} sweeps") },
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
            format!("{worst:.2}"),
            o.repartitions.to_string(),
        ]);
    }
    r.para(&format!(
        "Real training (model_a, 4 trainers × 3 threads, P=4 S=2, adaptive \
         gate target 25%, {} examples): dense writes are uniform here, so \
         adaptive replans stay near-uniform — quality must hold while the \
         cutover machinery exercises end-to-end.",
        ((TRAIN_EXAMPLES as f64) * opts.scale) as u64
    ));
    r.table(
        &["repartitioning", "eval loss", "eval NE", "worst part gap", "repartitions"],
        &rows2,
    );
    Ok(r.finish())
}

/// Wire-codec ablation: quantized (fp16/int8) and top-k-sparsified sync
/// traffic with per-trainer error feedback, vs the uncompressed fp32 wire.
/// NE should hold (fp16 within 1% of fp32) while the measured NIC bytes
/// drop with the wire format — and `metrics.sync_bytes` must equal the
/// sync-PS NIC counters bit-exactly under every codec.
pub fn run_codec(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let codecs = [
        WireCodec::Fp32,
        WireCodec::Fp16,
        WireCodec::Int8,
        WireCodec::TopK(0.25),
    ];
    let mut rows = Vec::new();
    let mut base: Option<(f64, u64)> = None; // fp32 (NE, sync bytes)
    for codec in codecs {
        let mut cfg = quality_cfg(opts, 4, 3, SyncAlgo::Easgd, SyncMode::Shadow, TRAIN_EXAMPLES);
        cfg.wire_codec = codec;
        let o = run_quality(&cfg, &rt)?;
        let ne = o.eval.ne();
        let bytes = o.metrics.sync_bytes;
        // the invariant the codec layer must not bend: recorded sync bytes
        // are exactly what the sync-PS NICs moved (EASGD-only run: no rings)
        let exact = bytes == o.sync_ps_bytes;
        let (base_ne, base_bytes) = *base.get_or_insert((ne, bytes));
        let ratio = if bytes > 0 { base_bytes as f64 / bytes as f64 } else { f64::INFINITY };
        rows.push(vec![
            codec.to_string(),
            format!("{ne:.4}"),
            format!("{:+.2}%", 100.0 * (ne - base_ne) / base_ne),
            format!("{bytes}"),
            format!("{ratio:.2}×"),
            if exact { "✓".into() } else { format!("✗ (NIC {})", o.sync_ps_bytes) },
        ]);
    }
    let mut r = Report::new(
        "Ablation: wire codecs for the sync fabric",
        "compressed background sync traffic with error feedback (extension of §3.2)",
    );
    r.para(
        "4 trainers × 3 threads, S-EASGD, 1 sync PS; each arm encodes both \
         push legs with the codec, with per-trainer error-feedback residuals \
         carrying the encode loss into the next round. \"compression\" is \
         measured fp32 NIC bytes over the arm's measured NIC bytes.",
    );
    r.table(
        &["codec", "eval NE", "ΔNE vs fp32", "sync bytes", "compression", "bytes exact"],
        &rows,
    );
    r.para(
        "Expected: fp16 halves the measured wire (≥ 40% drop) at an NE within \
         1% of fp32; int8 and top-k cut deeper with modest NE cost, the \
         error feedback keeping the loss bounded instead of accumulating; \
         and the byte-exactness column holds for every codec — compression \
         changes what the fabric moves, never how it is accounted.",
    );
    Ok(r.finish())
}

/// The paper's §4.1.1 conjecture, tested: "a time-varying sync gap would be
/// favorable for FR-EASGD under our setting" — loose syncing early (more
/// exploration), tight toward the end of the pass.
pub fn run_decay_gap(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let variants: [(&str, SyncMode); 4] = [
        ("FR-EASGD-5 (constant)", SyncMode::FixedRate { gap: 5 }),
        ("FR-EASGD-30 (constant)", SyncMode::FixedRate { gap: 30 }),
        ("FR-EASGD-100→5 (decaying)", SyncMode::Decaying { start: 100, end: 5 }),
        ("FR-EASGD-5→100 (inverted)", SyncMode::Decaying { start: 5, end: 100 }),
    ];
    let mut rows = Vec::new();
    for (label, mode) in variants {
        let cfg = quality_cfg(opts, 4, 3, SyncAlgo::Easgd, mode, TRAIN_EXAMPLES);
        let o = run_quality(&cfg, &rt)?;
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", o.avg_sync_gap),
            fmt_loss(o.train_loss),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
        ]);
    }
    let mut r = Report::new(
        "Extension: time-varying sync gap for FR-EASGD",
        "paper §4.1.1 closing conjecture",
    );
    r.para(
        "4 trainers × 3 threads, 1 sync PS; the decaying variants anneal \
         the per-worker gap linearly across the one-pass shard.",
    );
    r.table(
        &["variant", "measured avg gap", "train loss", "eval loss", "eval NE"],
        &rows,
    );
    r.para(
        "The paper conjectures (from FR-5 ≈ FR-100 eval at 20 trainers) that \
         small gaps help late and loose gaps help early; the decaying variant \
         tests exactly that against both constants and the inverted schedule.",
    );
    Ok(r.finish())
}
