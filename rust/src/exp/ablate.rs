//! Ablations beyond the paper's tables, for the design choices §3.3 calls
//! out in prose.

use anyhow::Result;

use crate::config::{SyncAlgo, SyncMode};
use crate::runtime::Runtime;
use crate::sim::CostModel;

use super::{fmt_loss, quality_cfg, run_quality, ExpOpts, Report};

const TRAIN_EXAMPLES: u64 = 200_000;

/// §3.3: "if we directly copy the averaged weight back, we will lose the
/// updates to the local replicas [made] when the background synchronization
/// is happening" — the asymmetric elastic pull is claimed essential.
/// α=1.0 under S-MA *is* the copy-back variant.
pub fn run_elastic(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let mut rows = Vec::new();
    for (label, alpha) in [("elastic pull (α=0.5)", 0.5f32), ("copy-back (α=1.0)", 1.0)] {
        let mut cfg = quality_cfg(opts, 4, 3, SyncAlgo::Ma, SyncMode::Shadow, TRAIN_EXAMPLES);
        cfg.alpha = alpha;
        cfg.shadow_interval_ms = 1;
        // paper-scale AllReduce wall time: the window during which Hogwild
        // workers make progress that copy-back would discard (in-process the
        // collective is near-instant, so we model the wire; DESIGN.md §3)
        cfg.collective_wire_ms = 25;
        let o = run_quality(&cfg, &rt)?;
        rows.push(vec![
            label.to_string(),
            fmt_loss(o.train_loss),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
            format!("{}", o.metrics.syncs),
        ]);
    }
    let mut r = Report::new(
        "Ablation: elastic pull vs copy-back under S-MA",
        "paper §3.3 (the asymmetric-interpolation modification)",
    );
    r.para(
        "4 trainers × 3 threads, S-MA, shadow free-running, 25 ms simulated \
         AllReduce wall time per round.",
    );
    r.table(&["variant", "train loss", "eval loss", "eval NE", "sync rounds"], &rows);
    r.para(
        "Expected: copy-back discards the Hogwild updates that landed during \
         each background AllReduce, degrading (or at best matching) quality — \
         supporting the paper's claim that the elastic pull is what makes \
         background MA safe.",
    );
    Ok(r.finish())
}

/// Throttling the shadow loop interpolates between FR-like infrequent sync
/// and the paper's free-running shadow; sweeps the implicit sync gap.
pub fn run_shadow_rate(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let mut rows = Vec::new();
    for interval_ms in [0u64, 2, 10, 50] {
        let mut cfg = quality_cfg(opts, 4, 3, SyncAlgo::Easgd, SyncMode::Shadow, TRAIN_EXAMPLES);
        cfg.shadow_interval_ms = interval_ms;
        let o = run_quality(&cfg, &rt)?;
        rows.push(vec![
            format!("{interval_ms} ms"),
            format!("{:.3}", o.avg_sync_gap),
            fmt_loss(o.train_loss),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
        ]);
    }
    let mut r = Report::new(
        "Ablation: shadow-loop pacing",
        "extension of paper §4.1 (sync-rate sensitivity, background edition)",
    );
    r.para(
        "4 trainers × 3 threads, S-EASGD, 1 sync PS; the shadow thread \
         sleeps `interval` between rounds.",
    );
    r.table(
        &["shadow interval", "avg sync gap (Eq. 2)", "train loss", "eval loss", "eval NE"],
        &rows,
    );
    r.para(
        "Expected: quality is robust over a wide pacing range (the paper's \
         free-running choice is convenient, not critical), degrading only \
         once the gap grows to FR-EASGD-100 territory.",
    );
    Ok(r.finish())
}

/// The partitioned shadow fabric (the paper's §3.2 "each partition synced
/// by its own background thread"), swept over (P partitions, S shadow
/// threads): real runs measure quality + the live delta-gate skip rate;
/// the paper-scale model prices EPS at 20×24 with the same (P, S).
pub fn run_partitions(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let sweep: [(usize, usize); 4] = [(1, 1), (2, 1), (4, 2), (4, 4)];
    let mut rows = Vec::new();
    for (p, s) in sweep {
        let mut cfg = quality_cfg(opts, 4, 3, SyncAlgo::Easgd, SyncMode::Shadow, TRAIN_EXAMPLES);
        cfg.sync_partitions = p;
        cfg.shadow_threads = s;
        // small chunks + the adaptive gate so every partition's private
        // sketch engages at this reduced scale
        cfg.easgd_chunk_elems = 512;
        cfg.delta_skip_target = 0.25;
        let o = run_quality(&cfg, &rt)?;
        let skip = o.sync_traffic.as_ref().map_or(0.0, |t| t.skip_fraction());
        let eps = CostModel::paper_scale()
            .with_partitioned_shadow(p, s)
            .simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2)
            .eps;
        // a partition that never synced must read as an alarm, not vanish:
        // an infinite gap (or a partition missing from the table entirely)
        // renders as ∞ instead of being filtered out of the max
        let worst_gap = o.partition_gaps.iter().cloned().fold(0.0f64, f64::max);
        let worst = if o.partition_gaps.len() < p || worst_gap.is_infinite() {
            "∞ (starved)".to_string()
        } else {
            format!("{worst_gap:.2}")
        };
        rows.push(vec![
            format!("P={p} S={s}"),
            format!("{eps:.0}"),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
            format!("{:.0}%", 100.0 * skip),
            worst,
            format!("{}", o.metrics.syncs),
        ]);
    }
    let mut r = Report::new(
        "Ablation: partitioned shadow fabric (P × S)",
        "paper §3.2 (partitioned dense parameters, one background thread per partition)",
    );
    r.para(
        "4 trainers × 3 threads, S-EASGD with the adaptive delta gate \
         (target 25%), 512-element push chunks; EPS from the paper-scale \
         model at 20×24 trainers/threads with the same (P, S).",
    );
    r.table(
        &[
            "fabric",
            "model EPS @20",
            "eval loss",
            "eval NE",
            "skip rate",
            "worst part gap",
            "sync rounds",
        ],
        &rows,
    );
    r.para(
        "Expected: quality holds across (P, S) while partition rounds \
         shrink; raising S multiplies sync frequency per partition (the \
         worst per-partition gap drops) without touching the training loop, \
         and the per-partition gates keep the skip rate near its target.",
    );
    Ok(r.finish())
}

/// The paper's §4.1.1 conjecture, tested: "a time-varying sync gap would be
/// favorable for FR-EASGD under our setting" — loose syncing early (more
/// exploration), tight toward the end of the pass.
pub fn run_decay_gap(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let variants: [(&str, SyncMode); 4] = [
        ("FR-EASGD-5 (constant)", SyncMode::FixedRate { gap: 5 }),
        ("FR-EASGD-30 (constant)", SyncMode::FixedRate { gap: 30 }),
        ("FR-EASGD-100→5 (decaying)", SyncMode::Decaying { start: 100, end: 5 }),
        ("FR-EASGD-5→100 (inverted)", SyncMode::Decaying { start: 5, end: 100 }),
    ];
    let mut rows = Vec::new();
    for (label, mode) in variants {
        let cfg = quality_cfg(opts, 4, 3, SyncAlgo::Easgd, mode, TRAIN_EXAMPLES);
        let o = run_quality(&cfg, &rt)?;
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", o.avg_sync_gap),
            fmt_loss(o.train_loss),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
        ]);
    }
    let mut r = Report::new(
        "Extension: time-varying sync gap for FR-EASGD",
        "paper §4.1.1 closing conjecture",
    );
    r.para(
        "4 trainers × 3 threads, 1 sync PS; the decaying variants anneal \
         the per-worker gap linearly across the one-pass shard.",
    );
    r.table(
        &["variant", "measured avg gap", "train loss", "eval loss", "eval NE"],
        &rows,
    );
    r.para(
        "The paper conjectures (from FR-5 ≈ FR-100 eval at 20 trainers) that \
         small gaps help late and loose gaps help early; the decaying variant \
         tests exactly that against both constants and the inverted schedule.",
    );
    Ok(r.finish())
}
