//! `ablate-embedding`: the sharded embedding tier ablation — row caching
//! and BagPipe-style lookahead prefetch under real quality runs.
//!
//! Three arms over the same stream: the seed path (every lookup round-trips
//! to the embedding PSs), the versioned row cache (`--emb-cache`), and the
//! cache fed by the lookahead pipeline (`--emb-lookahead`), which prefetches
//! the union of row ids for the next k batches and dedups duplicate keys
//! within the window.
//!
//! The invariants are `ensure!`d, not just tabulated:
//!
//! 1. **Byte exactness** — `metrics.embedding_bytes` equals the
//!    embedding-PS NIC counters byte-for-byte in every arm (cache hits and
//!    prefetches included);
//! 2. **Bytes saved** — the cached arm moves strictly fewer bytes than the
//!    seed path and its hit rate is nonzero;
//! 3. **Quality** — cached/prefetched lookups are bit-identical per batch
//!    (property-tested in `tests/embedding_suite.rs`), so eval NE stays
//!    within Hogwild noise of the seed arm.

use anyhow::{ensure, Result};

use crate::config::{RunConfig, SyncAlgo, SyncMode};
use crate::coordinator::TrainOutcome;
use crate::runtime::Runtime;
use crate::sim::CostModel;

use super::{fmt_loss, quality_cfg, run_quality, ExpOpts, Report};

const TRAIN_EXAMPLES: u64 = 90_000;
const SMOKE_EXAMPLES: u64 = 30_000;

/// Trainer-side row-cache capacity for the cached arms: large enough to
/// hold the power-law head of every table at the quality-run scale.
const CACHE_ROWS: usize = 4_096;
/// Lookahead window (batches) for the prefetched arm.
const LOOKAHEAD: usize = 3;

/// 3 trainers × 2 Hogwild threads, shadow EASGD — the same quality-run
/// shape as the other ablations; only the embedding knobs vary per arm.
fn base_cfg(opts: &ExpOpts) -> RunConfig {
    let examples = if opts.smoke { SMOKE_EXAMPLES } else { TRAIN_EXAMPLES };
    quality_cfg(opts, 3, 2, SyncAlgo::Easgd, SyncMode::Shadow, examples)
}

fn hit_rate(o: &TrainOutcome) -> f64 {
    let total = o.emb_cache_hits + o.emb_cache_misses;
    if total == 0 {
        0.0
    } else {
        o.emb_cache_hits as f64 / total as f64
    }
}

fn outcome_row(label: &str, o: &TrainOutcome) -> Vec<String> {
    vec![
        label.to_string(),
        fmt_loss(o.train_loss),
        fmt_loss(o.eval.ne()),
        format!("{:.0}", o.eps),
        o.embedding_bytes.to_string(),
        format!("{:.1}%", 100.0 * hit_rate(o)),
        o.emb_cache_hits.to_string(),
    ]
}

const ROW_HEADERS: [&str; 7] =
    ["arm", "train loss", "eval NE", "EPS", "emb bytes", "hit rate", "cache hits"];

pub fn run(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let mut r = Report::new(
        "Embedding ablation: sharded PS tier, row cache, lookahead prefetch",
        "embedding-tier ablation (no direct paper figure; exercises the §3.1–3.2 \
         model-parallel tier with BagPipe-style caching)",
    );

    r.para(&format!(
        "Three arms over the same one-pass stream (3 trainers × 2 Hogwild threads, \
         shadow EASGD): the seed path (every lookup round-trips to the rendezvous-\
         sharded embedding PSs), a {CACHE_ROWS}-row versioned cache per trainer, and \
         the cache fed by a {LOOKAHEAD}-batch lookahead window that prefetches the \
         deduped union of upcoming row ids. Cache entries invalidate on placement \
         changes and on Hogwild writes to the underlying row, so every arm computes \
         bit-identical pooled embeddings for a given batch."
    ));

    let seed_cfg = base_cfg(opts);
    let o_seed = run_quality(&seed_cfg, &rt)?;

    let mut cache_cfg = base_cfg(opts);
    cache_cfg.embedding.cache_rows = CACHE_ROWS;
    let o_cache = run_quality(&cache_cfg, &rt)?;

    let mut look_cfg = base_cfg(opts);
    look_cfg.embedding.cache_rows = CACHE_ROWS;
    look_cfg.embedding.lookahead = LOOKAHEAD;
    let o_look = run_quality(&look_cfg, &rt)?;

    for (label, o) in
        [("seed", &o_seed), ("cache", &o_cache), ("cache+lookahead", &o_look)]
    {
        ensure!(
            o.train_loss.is_finite() && o.eval.ne().is_finite(),
            "{label} arm did not converge to finite losses"
        );
        ensure!(o.metrics.examples > 0, "{label} arm trained no examples");
        ensure!(
            o.embedding_bytes == o.metrics.embedding_bytes,
            "{label} arm broke embedding byte exactness: NIC counters saw {} but \
             metrics recorded {}",
            o.embedding_bytes,
            o.metrics.embedding_bytes
        );
    }
    ensure!(o_seed.emb_cache_hits == 0, "the seed arm has no cache to hit");
    ensure!(
        o_cache.emb_cache_hits > 0,
        "a {CACHE_ROWS}-row cache never hit under a power-law stream"
    );
    ensure!(
        o_cache.embedding_bytes < o_seed.embedding_bytes,
        "cache hits must shed wire bytes: cached arm moved {} vs seed {}",
        o_cache.embedding_bytes,
        o_seed.embedding_bytes
    );
    ensure!(
        o_look.emb_cache_hits > 0,
        "the lookahead window prefetched nothing the consumer could hit"
    );
    let ne_drift = (o_cache.eval.ne() - o_seed.eval.ne()).abs() / o_seed.eval.ne().abs();
    ensure!(
        ne_drift < 0.1,
        "cached arm's NE drifted {:.1}% from the seed path (lookups are \
         bit-identical; only Hogwild noise may separate them)",
        100.0 * ne_drift
    );

    r.table(
        &ROW_HEADERS,
        &[
            outcome_row("seed (uncached)", &o_seed),
            outcome_row(&format!("cache {CACHE_ROWS}"), &o_cache),
            outcome_row(&format!("cache + lookahead {LOOKAHEAD}"), &o_look),
        ],
    );
    let saved = o_seed.embedding_bytes.saturating_sub(o_cache.embedding_bytes);
    r.para(&format!(
        "Cached arm: {:.1}% hit rate shed {} bytes ({:.1}% of the seed path's {}); \
         eval NE moved {:.2}% (Hogwild noise — per-batch lookups are bit-identical). \
         Lookahead arm: {:.1}% hit rate with the prefetch traffic itself on the same \
         byte ledger. All byte ledgers matched the embedding-PS NIC counters exactly.",
        100.0 * hit_rate(&o_cache),
        saved,
        100.0 * saved as f64 / (o_seed.embedding_bytes.max(1)) as f64,
        o_seed.embedding_bytes,
        100.0 * ne_drift,
        100.0 * hit_rate(&o_look),
    ));

    // paper-scale EPS under the measured traffic profile: the cost model's
    // embedding feed cap binds the trainer NIC when every lookup
    // round-trips, and the measured hit rate buys the headroom back
    let bytes_per_example =
        o_seed.embedding_bytes as f64 / o_seed.metrics.examples.max(1) as f64;
    // the quality testbed's rows are tiny; scale the per-example footprint
    // to the paper's table sizes (~1000x more rows, same power law) so the
    // feed cap is visible against a 25 Gbit NIC
    let paper_bytes = bytes_per_example * 1000.0;
    let measured_hit = hit_rate(&o_look);
    let dense = CostModel::paper_scale();
    let cold = CostModel::paper_scale().with_embedding_traffic(paper_bytes, 0.0);
    let warm = CostModel::paper_scale().with_embedding_traffic(paper_bytes, measured_hit);
    let s_dense = dense.simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
    let s_cold = cold.simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
    let s_warm = warm.simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
    ensure!(
        s_warm.eps >= s_cold.eps,
        "the cost model must not price a cache hit as extra wire time"
    );
    r.para(&format!(
        "Paper scale (20 trainers × 24 threads, cost model): at {:.0} embedding \
         bytes/example the uncached tier caps the trainer NIC; the measured \
         {:.1}% hit rate recovers EPS toward the dense-only ceiling:",
        paper_bytes,
        100.0 * measured_hit
    ));
    r.table(
        &["embedding tier", "EPS", "of dense-only"],
        &[
            vec!["dense-only ceiling".into(), format!("{:.0}", s_dense.eps), "100.0%".into()],
            vec![
                "uncached lookups".into(),
                format!("{:.0}", s_cold.eps),
                format!("{:.1}%", 100.0 * s_cold.eps / s_dense.eps),
            ],
            vec![
                format!("measured {:.1}% hit rate", 100.0 * measured_hit),
                format!("{:.0}", s_warm.eps),
                format!("{:.1}%", 100.0 * s_warm.eps / s_dense.eps),
            ],
        ],
    );
    r.para("All invariants held (they are asserted, not just reported).");

    Ok(r.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedding_arm_configs_validate() {
        let opts = ExpOpts::default();
        base_cfg(&opts).validate().unwrap();

        let mut cache = base_cfg(&opts);
        cache.embedding.cache_rows = CACHE_ROWS;
        cache.validate().unwrap();

        let mut look = base_cfg(&opts);
        look.embedding.cache_rows = CACHE_ROWS;
        look.embedding.lookahead = LOOKAHEAD;
        look.validate().unwrap();

        // lookahead without a cache to prefetch into is rejected
        let mut bad = base_cfg(&opts);
        bad.embedding.lookahead = LOOKAHEAD;
        assert!(bad.validate().is_err());
    }
}
