//! Figure 5 + Table 3: scalability of S-EASGD vs FR-EASGD.
//!
//! Panel 1 (EPS vs trainers) and panel 4 (the 4-sync-PS fix) come from the
//! calibrated paper-scale model (`sim`); panels 2–3 (train/eval loss vs
//! trainers, fixed total dataset) are measured by really training. Table 3
//! (relative loss increase vs the smallest-scale run) derives from the same
//! measured runs.

use anyhow::Result;

use crate::config::{SyncAlgo, SyncMode};
use crate::coordinator::TrainOutcome;
use crate::runtime::Runtime;
use crate::sim::CostModel;
use crate::sync::ps::PsTrafficSnapshot;

use super::{fmt_loss, fmt_pct, quality_cfg, run_quality, ExpOpts, Report};

/// fixed total dataset: more trainers -> less data per trainer (the paper's
/// core difficulty)
const TRAIN_EXAMPLES: u64 = 240_000;
/// real-mode trainer counts (stand-ins for the paper's 5/10/15/20)
pub const REAL_SCALES: [usize; 3] = [2, 4, 8];

struct Variant {
    label: &'static str,
    mode: SyncMode,
    sync_ps: usize,
}

const VARIANTS: [Variant; 3] = [
    Variant { label: "S-EASGD", mode: SyncMode::Shadow, sync_ps: 2 },
    Variant { label: "FR-EASGD-5", mode: SyncMode::FixedRate { gap: 5 }, sync_ps: 2 },
    Variant { label: "FR-EASGD-30", mode: SyncMode::FixedRate { gap: 30 }, sync_ps: 2 },
];

fn measure(opts: &ExpOpts) -> Result<Vec<(String, usize, TrainOutcome)>> {
    let rt = Runtime::cpu()?;
    let mut out = Vec::new();
    for v in &VARIANTS {
        for &n in &REAL_SCALES {
            let mut cfg =
                quality_cfg(opts, n, 3, SyncAlgo::Easgd, v.mode, TRAIN_EXAMPLES);
            cfg.num_sync_ps = v.sync_ps;
            let o = run_quality(&cfg, &rt)?;
            out.push((v.label.to_string(), n, o));
        }
    }
    Ok(out)
}

/// Build the paper-scale model priced from the measured runs: each run's
/// [`TrainOutcome::sync_traffic`] snapshot (the sync-PS group's own
/// cumulative push counters, full-round denominator included) is folded
/// into one aggregate, so the EPS panels cost what the sync fabric actually
/// moved — no re-derivation from summed metrics.
fn paper_model_from_measured(measured: &[(String, usize, TrainOutcome)]) -> CostModel {
    let mut agg = PsTrafficSnapshot::default();
    for (_, _, o) in measured {
        if let Some(t) = &o.sync_traffic {
            agg.absorb(t);
        }
    }
    // no-rounds aggregates leave the model at its full-push default
    CostModel::paper_scale().with_measured_easgd(&agg)
}

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut r = Report::new(
        "Figure 5: S-EASGD vs FR-EASGD scaling",
        "paper Figure 5 (Model-B on Dataset-2, 5–20 trainers, 2 sync PSs)",
    );

    // the real runs come first: their measured sync traffic prices the
    // paper-scale model used by the EPS panels
    let measured = measure(opts)?;
    let cm = paper_model_from_measured(&measured);

    // ---- panel 1: EPS vs trainers (paper-scale model) ----
    let mut rows = Vec::new();
    for n in (5..=20).filter(|n| n % 3 == 2 || *n == 5 || *n == 20) {
        let s = cm.simulate(n, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
        let f5 = cm.simulate(n, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }, 2);
        let f30 = cm.simulate(n, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 30 }, 2);
        rows.push(vec![
            n.to_string(),
            format!("{:.0}", s.eps),
            format!("{:.0}", f5.eps),
            format!("{:.0}", f30.eps),
            format!("{:.0}%", 100.0 * f5.sync_ps_util),
        ]);
    }
    r.para(&format!(
        "**Panel 1 — EPS vs #trainers** (paper-scale model, 24 threads, 2 \
         sync PSs; collectives priced from measured traffic — ring rounds \
         from the chunked schedule, EASGD rounds at the measured push \
         fraction {:.2} of the full 2·|w| round):",
        cm.easgd_push_fraction,
    ));
    r.table(
        &["trainers", "S-EASGD EPS", "FR-EASGD-5 EPS", "FR-EASGD-30 EPS", "FR-5 syncPS util"],
        &rows,
    );
    r.para(
        "Shape check: S-EASGD and FR-EASGD-30 grow linearly; FR-EASGD-5 \
         plateaus once the 2 sync-PS NICs saturate (util → 100%), the \
         paper's root-cause for its Fig. 5 stagnation.",
    );

    // ---- panel 4: 4 sync PSs fix FR-5 ----
    let mut rows4 = Vec::new();
    for n in [5, 10, 15, 20] {
        let f5_2 = cm.simulate(n, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }, 2);
        let f5_4 = cm.simulate(n, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }, 4);
        rows4.push(vec![
            n.to_string(),
            format!("{:.0}", f5_2.eps),
            format!("{:.0}", f5_4.eps),
        ]);
    }
    r.para("**Panel 4 — FR-EASGD-5 with 2 vs 4 sync PSs** (the paper's fix):");
    r.table(&["trainers", "2 sync PSs", "4 sync PSs"], &rows4);

    // ---- panels 2-3: measured loss vs scale ----
    let mut rows_loss = Vec::new();
    for (label, n, o) in &measured {
        // live delta-gate skip rate straight from the outcome's sync-PS
        // traffic snapshot (no gate configured -> nothing ever skips)
        let skip = match &o.sync_traffic {
            Some(t) => format!("{:.0}%", 100.0 * t.skip_fraction()),
            None => "-".to_string(),
        };
        rows_loss.push(vec![
            label.clone(),
            n.to_string(),
            fmt_loss(o.train_loss),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.2}", o.avg_sync_gap),
            skip,
        ]);
    }
    r.para(&format!(
        "**Panels 2–3 — measured losses** (real runs, fixed total dataset of \
         {} examples split across trainers; scaled stand-in: {:?} trainers; \
         \"skip rate\" is the live delta-gate column from each run's \
         sync-PS traffic snapshot):",
        ((TRAIN_EXAMPLES as f64) * opts.scale) as u64,
        REAL_SCALES,
    ));
    r.table(
        &["algorithm", "trainers", "train loss", "eval loss", "avg sync gap", "skip rate"],
        &rows_loss,
    );
    r.para(
        "Shape check: losses gently increase with scale for S-EASGD and \
         FR-EASGD-30; S-EASGD's eval loss stays lowest-or-tied across scales.",
    );
    Ok(r.finish())
}

/// Table 3: relative loss increase vs the smallest-scale run.
pub fn run_table3(opts: &ExpOpts) -> Result<String> {
    let measured = measure(opts)?;
    let mut r = Report::new(
        "Table 3: relative loss increase vs smallest scale",
        "paper Table 3 (10/20 trainers vs 5; here 4/8 trainers vs 2)",
    );
    let mut rows = Vec::new();
    for v in &VARIANTS {
        let base = measured
            .iter()
            .find(|(l, n, _)| l == v.label && *n == REAL_SCALES[0])
            .expect("baseline run");
        for &n in &REAL_SCALES[1..] {
            let o = &measured.iter().find(|(l, m, _)| l == v.label && *m == n).unwrap().2;
            rows.push(vec![
                v.label.to_string(),
                format!("{n} vs {}", REAL_SCALES[0]),
                fmt_pct(TrainOutcome::rel_increase(o.train_loss, base.2.train_loss)),
                fmt_pct(TrainOutcome::rel_increase(o.eval.avg_loss(), base.2.eval.avg_loss())),
            ]);
        }
    }
    r.table(&["algorithm", "scale", "train Δ", "eval Δ"], &rows);
    r.para(
        "Shape check (paper): S-EASGD shows the mildest relative eval-loss \
         increase as training scales out.",
    );
    Ok(r.finish())
}
