//! Figure 7: comparison *within* the ShadowSync family — S-EASGD vs S-BMUF
//! (standard and aggressive α) vs S-MA.
//!
//! Paper setup: Model-B on Dataset-2, 5–20 trainers, 2 sync PSs for
//! S-EASGD, same hyper-parameters otherwise; BMUF additionally tested with
//! a larger elastic α because its global step is more conservative than MA's.

use anyhow::Result;

use crate::config::{SyncAlgo, SyncMode};
use crate::runtime::Runtime;

use super::{fmt_loss, quality_cfg, run_quality, ExpOpts, Report};

const TRAIN_EXAMPLES: u64 = 240_000;
const SCALES: [usize; 3] = [2, 4, 8];

pub fn run(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let variants: [(&str, SyncAlgo, f32); 4] = [
        ("S-EASGD", SyncAlgo::Easgd, 0.5),
        ("S-BMUF (α=0.5)", SyncAlgo::Bmuf, 0.5),
        ("S-BMUF (α=0.9)", SyncAlgo::Bmuf, 0.9),
        ("S-MA", SyncAlgo::Ma, 0.5),
    ];
    let mut rows = Vec::new();
    for (label, algo, alpha) in variants {
        for &n in &SCALES {
            let mut cfg = quality_cfg(opts, n, 3, algo, SyncMode::Shadow, TRAIN_EXAMPLES);
            cfg.alpha = alpha;
            if algo == SyncAlgo::Easgd {
                cfg.num_sync_ps = 2;
            }
            let o = run_quality(&cfg, &rt)?;
            rows.push(vec![
                label.to_string(),
                n.to_string(),
                fmt_loss(o.train_loss),
                fmt_loss(o.eval.avg_loss()),
                format!("{:.4}", o.eval.ne()),
            ]);
        }
    }
    let mut r = Report::new(
        "Figure 7: S-EASGD vs S-BMUF vs S-MA",
        "paper Figure 7 (Model-B on Dataset-2, 2 sync PSs for S-EASGD)",
    );
    r.para(&format!(
        "One pass over {} examples; the decentralized variants need no sync \
         PSs at all (the compute-budget argument of §4.3).",
        ((TRAIN_EXAMPLES as f64) * opts.scale) as u64,
    ));
    r.table(&["algorithm", "trainers", "train loss", "eval loss", "eval NE"], &rows);
    r.para(
        "Shape check (paper): S-EASGD trains best; raising α improves \
         S-BMUF; eval is mixed with no single leader — decentralized \
         ShadowSync is a viable budget option.",
    );
    Ok(r.finish())
}
