//! Table 2: model quality of S-EASGD vs FR-EASGD-{5,10,30,100}.
//!
//! Paper setup: Model-A on Dataset-1 (48.7B examples), (a) 11 trainers /
//! 12 embedding PSs / 1 sync PS, (b) 20 trainers / 29 / 6. Scaled stand-in:
//! `model_a` on the synthetic stream, (a) 4 trainers × 3 threads / 1 sync
//! PS, (b) 8 trainers × 3 threads / 2 sync PSs, same one-pass discipline.

use anyhow::Result;

use crate::config::{SyncAlgo, SyncMode};
use crate::runtime::Runtime;

use super::{fmt_loss, quality_cfg, run_quality, ExpOpts, Report};

const TRAIN_EXAMPLES: u64 = 240_000;
const GAPS: [u32; 4] = [5, 10, 30, 100];

fn run_panel(opts: &ExpOpts, trainers: usize, sync_ps: usize, panel: &str) -> Result<String> {
    let rt = Runtime::cpu()?;
    let mut rows = Vec::new();

    let mut cfg = quality_cfg(opts, trainers, 3, SyncAlgo::Easgd, SyncMode::Shadow, TRAIN_EXAMPLES);
    cfg.num_sync_ps = sync_ps;
    let s = run_quality(&cfg, &rt)?;
    rows.push(vec![
        "S-EASGD".to_string(),
        format!("{:.2}", s.avg_sync_gap),
        fmt_loss(s.train_loss),
        fmt_loss(s.eval.avg_loss()),
        format!("{:.4}", s.eval.ne()),
    ]);

    for gap in GAPS {
        let mut cfg = quality_cfg(
            opts,
            trainers,
            3,
            SyncAlgo::Easgd,
            SyncMode::FixedRate { gap },
            TRAIN_EXAMPLES,
        );
        cfg.num_sync_ps = sync_ps;
        let o = run_quality(&cfg, &rt)?;
        rows.push(vec![
            format!("FR-EASGD-{gap}"),
            format!("{gap}"),
            fmt_loss(o.train_loss),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
        ]);
    }

    let mut r = Report::new(
        &format!("Table 2({panel}): S-EASGD vs FR-EASGD model quality"),
        &format!("paper Table 2({panel}) — {trainers} trainers (scaled stand-in)"),
    );
    r.para(&format!(
        "{} trainers × 3 Hogwild threads, {} sync PS(s), one pass over {} \
         synthetic examples (paper: Model-A on Dataset-1).",
        trainers,
        sync_ps,
        ((TRAIN_EXAMPLES as f64) * opts.scale) as u64,
    ));
    r.table(&["algorithm", "sync gap", "train loss", "eval loss", "eval NE"], &rows);
    r.para(
        "Expected shape (paper): S-EASGD's measured average gap lands in the \
         small-gap regime and its losses are on par with or better than the \
         best fixed-rate setting; FR eval loss degrades as the gap grows.",
    );
    Ok(r.finish())
}

pub fn run_a(opts: &ExpOpts) -> Result<String> {
    run_panel(opts, 4, 1, "a")
}

pub fn run_b(opts: &ExpOpts) -> Result<String> {
    run_panel(opts, 8, 2, "b")
}
