//! Calibration: measure this box's real per-batch costs and relate them to
//! the paper-scale cost model the EPS figures use.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{EmbeddingConfig, ModelMeta};
use crate::data::TeacherModel;
use crate::runtime::Runtime;
use crate::sim::CostModel;
use crate::sync::traffic::RingTraffic;

use super::{ExpOpts, Report};

pub fn run(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let mut r = Report::new(
        "Calibration: measured step costs vs paper-scale model",
        "DESIGN.md §3 (substitution audit)",
    );

    let mut rows = Vec::new();
    for preset in ["tiny", "model_a", "model_b", "model_c"] {
        let meta = match ModelMeta::load(&opts.artifacts_dir, preset) {
            Ok(m) => m,
            Err(_) => continue, // preset not compiled
        };
        let model = rt.load_model(&meta, &opts.artifacts_dir)?;
        let emb = EmbeddingConfig::default();
        let teacher = TeacherModel::new(&meta, &emb, 7);
        let mut batch = crate::data::Batch::empty(&meta, &emb);
        let ids: Vec<u64> = (0..meta.batch as u64).collect();
        teacher.fill_batch(&mut batch, &ids);
        let mut io = model.new_io();

        // warmup + timed loop
        for _ in 0..3 {
            model.train_step(&mut io, &batch.dense, &batch.labels)?;
        }
        let t0 = Instant::now();
        let mut steps = 0u32;
        while t0.elapsed() < Duration::from_millis(600) {
            model.train_step(&mut io, &batch.dense, &batch.labels)?;
            steps += 1;
        }
        let per_batch = t0.elapsed().as_secs_f64() / steps as f64;
        rows.push(vec![
            preset.to_string(),
            meta.batch.to_string(),
            meta.num_params.to_string(),
            format!("{:.2} ms", 1e3 * per_batch),
            format!("{:.0}", meta.batch as f64 / per_batch),
        ]);
    }
    r.para("**Measured on this box** (single thread, XLA CPU, train fwd+bwd):");
    r.table(&["preset", "batch", "P", "per-batch", "EPS/thread"], &rows);

    let cm = CostModel::paper_scale();
    r.para(&format!(
        "**Paper-scale model constants**: batch {} at {:.0} ms/batch/thread, \
         memory-bandwidth knee at {:.0} threads (p={:.0}), NIC {:.2} GB/s, \
         |w| = {:.0} MB, collective latency floor {:.1} ms. These reproduce \
         the paper's observed saturation points (FR-EASGD-5 clip ≈ 12–14 \
         trainers on 2 sync PSs; EPS flat past 24 threads).",
        cm.batch,
        1e3 * cm.batch_secs,
        cm.mem_knee_threads,
        cm.mem_knee_power,
        cm.nic_bytes_per_sec / 1e9,
        cm.w_bytes / 1e6,
        1e3 * cm.round_latency,
    ));

    // provenance of the collective pricing: the model consumes the
    // *measured* chunked-ring schedule, not the closed-form estimate
    let elems = (cm.w_bytes / 4.0).round() as usize;
    let mut ring_rows = Vec::new();
    for n in [2usize, 5, 10, 20] {
        let measured = RingTraffic::measure(elems, cm.ring_chunks, n);
        let closed = 2 * (elems as u64 * 4) * (n as u64 - 1) / n as u64;
        ring_rows.push(vec![
            n.to_string(),
            format!("{:.3} MB", measured.max_member_bytes() as f64 / 1e6),
            format!("{:.3} MB", closed as f64 / 1e6),
            format!("{:+} B", measured.max_member_bytes() as i64 - closed as i64),
        ]);
    }
    r.para(&format!(
        "**Measured ring schedule at paper scale** ({} chunks): the EPS \
         model prices MA/BMUF collectives from the slowest member's bytes \
         under the exact chunked reduce-scatter/all-gather schedule; the \
         textbook 2·(n-1)/n formula is kept only as the cross-check column.",
        cm.ring_chunks,
    ));
    r.table(&["members", "measured max/member", "closed form", "rounding Δ"], &ring_rows);
    Ok(r.finish())
}
