//! Figure 8: Hogwild worker threads vs single thread (the §4.4
//! justification of 24 threads).
//!
//! Left panel (quality vs thread count): measured — Hogwild staleness is a
//! *semantic* effect, so it reproduces at reduced scale. Right panel (EPS vs
//! thread count): paper-scale model — the memory-bandwidth saturation knee
//! at ~24 threads is hardware physics this box cannot exhibit.
//!
//! Paper setup: Model-C on Dataset-3, S-EASGD, 5 and 10 trainers,
//! threads ∈ {1, 12, 24, 32, 64}.

use anyhow::Result;

use crate::config::{SyncAlgo, SyncMode};
use crate::runtime::Runtime;
use crate::sim::CostModel;

use super::{fmt_loss, quality_cfg, run_quality, ExpOpts, Report};

const TRAIN_EXAMPLES: u64 = 200_000;
/// scaled stand-ins for the paper's {1, 12, 24, 32, 64}
const REAL_THREADS: [usize; 4] = [1, 2, 4, 8];

pub fn run(opts: &ExpOpts) -> Result<String> {
    let mut r = Report::new(
        "Figure 8: Hogwild threads vs single thread",
        "paper Figure 8 (Model-C on Dataset-3, S-EASGD)",
    );

    // ---- left panel: measured quality vs threads ----
    let rt = Runtime::cpu()?;
    let mut rows = Vec::new();
    for &m in &REAL_THREADS {
        let cfg = quality_cfg(opts, 2, m, SyncAlgo::Easgd, SyncMode::Shadow, TRAIN_EXAMPLES);
        let o = run_quality(&cfg, &rt)?;
        rows.push(vec![
            m.to_string(),
            fmt_loss(o.train_loss),
            fmt_loss(o.eval.avg_loss()),
            format!("{:.4}", o.eval.ne()),
        ]);
    }
    r.para(&format!(
        "**Left — measured quality vs Hogwild threads** (2 trainers, one pass \
         over {} examples; thread counts {:?} stand in for the paper's \
         1–64 — staleness grows with concurrent updaters either way):",
        ((TRAIN_EXAMPLES as f64) * opts.scale) as u64,
        REAL_THREADS,
    ));
    r.table(&["threads", "train loss", "eval loss", "eval NE"], &rows);
    r.para("Shape check: a mild quality degradation as thread count rises.");

    // ---- right panel: paper-scale EPS vs threads ----
    let cm = CostModel::paper_scale();
    let mut rows_eps = Vec::new();
    for m in [1usize, 12, 24, 32, 64] {
        let e5 = cm.simulate(5, m, SyncAlgo::Easgd, SyncMode::Shadow, 1);
        let e10 = cm.simulate(10, m, SyncAlgo::Easgd, SyncMode::Shadow, 1);
        rows_eps.push(vec![
            m.to_string(),
            format!("{:.0}", e5.eps),
            format!("{:.0}", e10.eps),
            format!("{:.1}", cm.effective_threads(m)),
        ]);
    }
    r.para(
        "**Right — EPS vs threads** (paper-scale model; the effective-threads \
         column is the memory-bandwidth contention model, ~saturated at 24 \
         threads exactly as the paper measured ~70% bandwidth utilization):",
    );
    r.table(&["threads", "EPS (5 trainers)", "EPS (10 trainers)", "effective threads"], &rows_eps);
    r.para(
        "Shape check: EPS nearly stops growing at ≥24 threads while the \
         quality cost of Hogwild stays mild — the paper's justification for \
         running 24.",
    );
    Ok(r.finish())
}
