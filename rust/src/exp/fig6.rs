//! Figure 6: ShadowSync vs fixed-rate for the decentralized algorithms
//! (BMUF, MA): (a) measured model quality, (b) EPS scaling.
//!
//! Paper setup: Model-B on Dataset-2 at 5/10/15/20 trainers; FR rate set to
//! 1 sync/min to match the measured S-BMUF/S-MA background rates. Scaled
//! stand-in: FR gap chosen to match the measured S-* sync gap the same way.

use anyhow::Result;

use crate::config::{SyncAlgo, SyncMode};
use crate::runtime::Runtime;
use crate::sim::CostModel;

use super::{fmt_loss, quality_cfg, ExpOpts, Report};

const TRAIN_EXAMPLES: u64 = 240_000;
const SCALES: [usize; 3] = [2, 4, 8];
/// FR gap matched to the shadow loop's observed cadence (paper: 1/min)
const FR_GAP: u32 = 30;

pub fn run_quality(opts: &ExpOpts) -> Result<String> {
    let rt = Runtime::cpu()?;
    let mut rows = Vec::new();
    for algo in [SyncAlgo::Bmuf, SyncAlgo::Ma] {
        for mode in [SyncMode::Shadow, SyncMode::FixedRate { gap: FR_GAP }] {
            for &n in &SCALES {
                let cfg = quality_cfg(opts, n, 3, algo, mode, TRAIN_EXAMPLES);
                let o = super::run_quality(&cfg, &rt)?;
                rows.push(vec![
                    cfg.label(),
                    n.to_string(),
                    fmt_loss(o.train_loss),
                    fmt_loss(o.eval.avg_loss()),
                    format!("{:.2}", o.avg_sync_gap),
                ]);
            }
        }
    }
    let mut r = Report::new(
        "Figure 6(a): BMUF & MA, ShadowSync vs fixed-rate (quality)",
        "paper Figure 6(a) (Model-B on Dataset-2)",
    );
    r.para(&format!(
        "One pass over {} examples, 3 Hogwild threads/trainer; FR gap {} \
         (matched to the shadow cadence, as the paper matched 1/min).",
        ((TRAIN_EXAMPLES as f64) * opts.scale) as u64,
        FR_GAP,
    ));
    r.table(&["algorithm", "trainers", "train loss", "eval loss", "avg sync gap"], &rows);
    r.para(
        "Shape check (paper): the ShadowSync variants are comparable to or \
         better than their fixed-rate counterparts at every scale.",
    );
    Ok(r.finish())
}

pub fn run_eps(_opts: &ExpOpts) -> Result<String> {
    let cm = CostModel::paper_scale();
    let mut rows = Vec::new();
    for n in [5, 10, 15, 20] {
        let mk = |algo, mode| cm.simulate(n, 24, algo, mode, 0).eps;
        rows.push(vec![
            n.to_string(),
            format!("{:.0}", mk(SyncAlgo::Bmuf, SyncMode::Shadow)),
            format!("{:.0}", mk(SyncAlgo::Bmuf, SyncMode::FixedRate { gap: 120 })),
            format!("{:.0}", mk(SyncAlgo::Ma, SyncMode::Shadow)),
            format!("{:.0}", mk(SyncAlgo::Ma, SyncMode::FixedRate { gap: 120 })),
        ]);
    }
    let mut r = Report::new(
        "Figure 6(b): BMUF & MA EPS scaling",
        "paper Figure 6(b) (all variants scale linearly)",
    );
    r.para("Paper-scale model, 24 threads; FR collective every 120 iterations (≈1/min).");
    r.table(&["trainers", "S-BMUF", "FR-BMUF", "S-MA", "FR-MA"], &rows);
    r.para(
        "Shape check: synchronization is not a bottleneck here — every \
         variant scales linearly (the AllReduce touches one thread per \
         trainer at a low rate).",
    );
    Ok(r.finish())
}
