//! Table 1: Example-Level-Parallelism comparison vs prior art.
//!
//! ELP = batch × Hogwild threads × replicas (paper Definition 2). The prior
//! rows are the configurations the papers themselves report; the ShadowSync
//! row is computed from this system's paper-scale configuration, and a
//! second row shows the largest configuration this repo actually ran.

use anyhow::Result;

use crate::config::RunConfig;

use super::{ExpOpts, Report};

struct Row {
    algo: &'static str,
    batch: Option<u64>,
    hog: u64,
    rep: u64,
}

const PRIOR: [Row; 7] = [
    Row { algo: "EASGD [24]", batch: Some(128), hog: 1, rep: 16 },
    Row { algo: "DC-ASGD [26]", batch: Some(128), hog: 16, rep: 1 },
    Row { algo: "BMUF [5]", batch: None, hog: 1, rep: 64 },
    Row { algo: "DownpourSGD [7]", batch: None, hog: 1, rep: 200 },
    Row { algo: "ADPSGD [16]", batch: Some(128), hog: 1, rep: 128 },
    Row { algo: "LARS [23]", batch: Some(32_000), hog: 1, rep: 1 },
    Row { algo: "SGP [1]", batch: Some(256), hog: 1, rep: 256 },
];

pub fn run(_opts: &ExpOpts) -> Result<String> {
    let mut rows = Vec::new();
    // ShadowSync at the paper's configuration
    let paper_cfg = RunConfig { num_trainers: 20, worker_threads: 24, ..Default::default() };
    rows.push(vec![
        "ShadowSync (paper cfg)".to_string(),
        "200".to_string(),
        "24".to_string(),
        "20".to_string(),
        paper_cfg.elp(200).to_string(),
    ]);
    for r in PRIOR {
        let b = r.batch.map_or("N.A.".to_string(), |b| b.to_string());
        let elp = r.batch.map_or(format!("{} × B", r.rep), |b| (b * r.hog * r.rep).to_string());
        rows.push(vec![r.algo.to_string(), b, r.hog.to_string(), r.rep.to_string(), elp]);
    }
    let mut rep = Report::new(
        "Table 1: ELP comparison",
        "paper Table 1 (ELP = batch × #Hogwild × #replicas)",
    );
    rep.table(&["algorithm", "batch", "#Hog.", "#Rep.", "ELP"], &rows);
    rep.para(
        "ShadowSync's two-level data parallelism (Hogwild within a trainer × \
         replication across trainers) yields 96,000 ELP at 20 trainers — the \
         highest among the compared systems (SGP: 65,536).",
    );
    Ok(rep.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_row_is_96000() {
        let report = run(&ExpOpts::default()).unwrap();
        assert!(report.contains("96000"));
        assert!(report.contains("SGP"));
    }
}
