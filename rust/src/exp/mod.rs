//! The experiment harness: one module per table/figure of the paper's §4,
//! plus ablations. `shadowsync exp --id <id>` regenerates the artifact.
//!
//! Two kinds of numbers (DESIGN.md §5):
//! - **quality** (losses, sync gaps): measured by really training on the
//!   synthetic one-pass stream at reduced scale — same structure as the
//!   paper's runs (n trainers × m Hogwild threads, embedding PSs, sync
//!   PSs/AllReduce, shadow or fixed-rate sync);
//! - **throughput** (EPS curves): produced by the calibrated steady-state
//!   model in [`crate::sim`] at the paper's full scale (20×24 threads on
//!   25 Gbit), since one core cannot exhibit cluster physics in vivo.
//!
//! Shapes — orderings, crossovers, saturation points — are the reproduction
//! target, not absolute values (the substrate is synthetic; see DESIGN.md).

pub mod ablate;
pub mod calibrate;
pub mod embedding;
pub mod faults;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod table1;
pub mod table2;

use std::fmt::Write as _;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::config::{EmbeddingConfig, RunConfig, SyncAlgo, SyncMode};
use crate::coordinator::TrainOutcome;
use crate::runtime::Runtime;

/// Options shared by all experiments.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    pub artifacts_dir: PathBuf,
    /// where reports land (one markdown file per experiment)
    pub out_dir: PathBuf,
    /// multiplies dataset sizes (1.0 = defaults; 0.2 = smoke)
    pub scale: f64,
    /// seed for the synthetic stream
    pub seed: u64,
    /// `--smoke`: shrink wall-clock-bound experiments (shorter fault
    /// windows, fewer arms) so CI can afford them
    pub smoke: bool,
}

impl Default for ExpOpts {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("results"),
            scale: 1.0,
            seed: 20200630,
            smoke: false,
        }
    }
}

pub const ALL_IDS: &[&str] = &[
    "table1",
    "table2a",
    "table2b",
    "fig5",
    "table3",
    "fig6a",
    "fig6b",
    "fig7",
    "fig8",
    "ablate-elastic",
    "ablate-shadow-rate",
    "ablate-decay-gap",
    "ablate-partitions",
    "ablate-repartition",
    "ablate-faults",
    "ablate-codec",
    "ablate-embedding",
    "calibrate",
];

/// Run one experiment by id; returns (and persists) the report text.
pub fn run(id: &str, opts: &ExpOpts) -> Result<String> {
    let report = match id {
        "table1" => table1::run(opts)?,
        "table2a" => table2::run_a(opts)?,
        "table2b" => table2::run_b(opts)?,
        "fig5" => fig5::run(opts)?,
        "table3" => fig5::run_table3(opts)?,
        "fig6a" => fig6::run_quality(opts)?,
        "fig6b" => fig6::run_eps(opts)?,
        "fig7" => fig7::run(opts)?,
        "fig8" => fig8::run(opts)?,
        "ablate-elastic" => ablate::run_elastic(opts)?,
        "ablate-shadow-rate" => ablate::run_shadow_rate(opts)?,
        "ablate-decay-gap" => ablate::run_decay_gap(opts)?,
        "ablate-partitions" => ablate::run_partitions(opts)?,
        "ablate-repartition" => ablate::run_repartition(opts)?,
        "ablate-faults" => faults::run(opts)?,
        "ablate-codec" => ablate::run_codec(opts)?,
        "ablate-embedding" => embedding::run(opts)?,
        "calibrate" => calibrate::run(opts)?,
        _ => bail!("unknown experiment {id:?}; known: {}", ALL_IDS.join(", ")),
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    let path = opts.out_dir.join(format!("{id}.md"));
    std::fs::write(&path, &report)?;
    println!("{report}");
    println!("(written to {})", path.display());
    Ok(report)
}

/// Markdown report builder shared by the experiment modules.
///
/// # Examples
///
/// ```
/// use shadowsync::exp::Report;
///
/// let mut report = Report::new("Figure 0", "paper Figure 0");
/// report.para("One calibrated point:");
/// report.table(&["trainers", "EPS"], &[vec!["20".into(), "96000".into()]]);
/// let text = report.finish();
/// assert!(text.contains("# Figure 0"));
/// assert!(text.contains("| 20 | 96000 |"));
/// ```
#[derive(Default)]
pub struct Report {
    buf: String,
}

impl Report {
    pub fn new(title: &str, paper_ref: &str) -> Self {
        let mut r = Report::default();
        let _ = writeln!(r.buf, "# {title}\n\nPaper artifact: {paper_ref}\n");
        r
    }

    pub fn para(&mut self, text: &str) {
        let _ = writeln!(self.buf, "{text}\n");
    }

    pub fn table(&mut self, headers: &[&str], rows: &[Vec<String>]) {
        let _ = writeln!(self.buf, "| {} |", headers.join(" | "));
        let _ = writeln!(
            self.buf,
            "|{}|",
            headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in rows {
            let _ = writeln!(self.buf, "| {} |", row.join(" | "));
        }
        let _ = writeln!(self.buf);
    }

    pub fn finish(self) -> String {
        self.buf
    }
}

/// The scaled-down stand-in for the paper's quality runs: `model_a`, a few
/// trainers × a few Hogwild threads. Dataset sizes scale with `opts.scale`.
pub fn quality_cfg(
    opts: &ExpOpts,
    trainers: usize,
    threads: usize,
    algo: SyncAlgo,
    mode: SyncMode,
    train_examples: u64,
) -> RunConfig {
    RunConfig {
        preset: "model_a".into(),
        artifacts_dir: opts.artifacts_dir.clone(),
        num_trainers: trainers,
        worker_threads: threads,
        num_embedding_ps: trainers.max(2),
        num_sync_ps: if algo == SyncAlgo::Easgd { 1 } else { 0 },
        algo,
        mode,
        train_examples: ((train_examples as f64) * opts.scale) as u64,
        eval_examples: ((train_examples as f64) * opts.scale * 0.2) as u64,
        data_seed: opts.seed,
        embedding: EmbeddingConfig { rows_per_table: 2_000, ..Default::default() },
        // pace the shadow loop so measured sync gaps land in the paper's
        // regime (~1–15 iterations/round) at this testbed's batch rate
        shadow_interval_ms: 25,
        ..Default::default()
    }
}

/// Run one quality config and return its outcome (shared runtime).
pub fn run_quality(cfg: &RunConfig, rt: &Runtime) -> Result<TrainOutcome> {
    crate::coordinator::run_timed(cfg, rt)
}

pub fn fmt_loss(x: f64) -> String {
    format!("{x:.5}")
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:+.3}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_markdown_table() {
        let mut r = Report::new("T", "Table 9");
        r.table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        let s = r.finish();
        assert!(s.contains("# T"));
        assert!(s.contains("| a | b |"));
        assert!(s.contains("| 1 | 2 |"));
    }

    #[test]
    fn unknown_id_is_rejected() {
        let opts = ExpOpts { out_dir: std::env::temp_dir(), ..Default::default() };
        assert!(run("nope", &opts).is_err());
    }

    #[test]
    fn quality_cfg_scales_dataset() {
        let opts = ExpOpts { scale: 0.5, ..Default::default() };
        let cfg = quality_cfg(&opts, 4, 3, SyncAlgo::Easgd, SyncMode::Shadow, 100_000);
        assert_eq!(cfg.train_examples, 50_000);
        assert_eq!(cfg.num_sync_ps, 1);
        let cfg2 = quality_cfg(&opts, 4, 3, SyncAlgo::Ma, SyncMode::Shadow, 100_000);
        assert_eq!(cfg2.num_sync_ps, 0);
        cfg.validate().unwrap();
        cfg2.validate().unwrap();
    }
}
