//! `shadowsync` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   train        run one distributed-training job (flags below)
//!   exp          regenerate a paper table/figure: --id table2a|fig5|... |all
//!   elp          print the ELP of a configuration (paper Definition 2)
//!   sim          query the paper-scale throughput model directly
//!   list         list presets and experiments
//!
//! Partitioned shadow fabric (shadow mode only):
//!   --sync-partitions <P>        cut the dense vector into P contiguous
//!                                LPT-balanced partitions, each synced by
//!                                its own background strategy (default 1)
//!   --shadow-threads <S>         shadow threads per trainer servicing the
//!                                partitions (S ≤ P; default 1)
//!   --algo-map <map>             per-partition algorithms, e.g.
//!                                easgd:0-1,ma:2-3 (unmapped partitions
//!                                run --algo)
//!   --repartition-every <N>      measured-cost adaptive repartitioning:
//!                                rebuild the plan every N shadow sweeps
//!                                from the measured per-range write rates
//!                                (hot partitions shrink, cold ones grow)
//!                                with a live cutover; 0 = static plan
//!   --wire-codec <codec|map>     compress sync traffic on the wire:
//!                                fp32 (default), fp16, int8, or topk:R
//!                                (keep the R fraction of largest-|x|
//!                                coordinates); lossy codecs carry
//!                                per-trainer error-feedback residuals.
//!                                A per-partition map composes with
//!                                --algo-map, e.g. int8:0-1,topk:0.1:2-3
//!
//! In-process reduce engine (MA/BMUF collectives):
//!   --reduce-engine <e>          overlapped (default) | striped | serial |
//!                                shared-nothing (thread-per-core SPSC
//!                                deposit rings, delegated sub-partition
//!                                folding, depth-2 stripe pipelining)
//!   --ring-depth <D>             shared-nothing deposit-ring depth
//!                                (default 2: round g+1's deposits land
//!                                while round g folds; 1 = serialize
//!                                rounds via backpressure)
//!   --pin-cores                  pin shadow/reduce workers to cores
//!                                (best-effort sched_setaffinity on x86_64
//!                                Linux, no-op elsewhere)
//!
//! Delta gating (EASGD pushes against the sync PSs):
//!   --sync-chunk <elems>         elements per push chunk (0 = whole shard)
//!   --delta-threshold <abs>      fixed gate: skip chunks whose max
//!                                |local − central| is at or below this
//!   --delta-skip-target <frac>   adaptive gate: target the given skip
//!                                *rate* instead — the gate tracks the
//!                                observed per-chunk gap distribution's
//!                                quantile (overrides the fixed threshold
//!                                once its sketch warms up)
//!   --no-dirty-scan              disable dirty-epoch scan reuse (by
//!                                default, trainer replicas track per-chunk
//!                                write epochs whenever a gate is on, and a
//!                                chunk untouched since its last scan
//!                                reuses that scan instead of re-reading
//!                                every element)
//!
//! Sharded embedding tier (lookups/updates against the embedding PSs):
//!   --emb-cache <rows>           trainer-side versioned row cache capacity
//!                                (entries invalidate on placement changes
//!                                and Hogwild writes; 0 = no cache)
//!   --emb-lookahead <k>          BagPipe-style lookahead: prefetch the
//!                                deduped union of row ids for the next k
//!                                batches into the row cache (needs
//!                                --emb-cache; 0 = off)
//!   --emb-buckets <B>            row-range buckets per table placed by
//!                                rendezvous hashing over the PS nodes
//!                                (0 = auto: one per PS, capped at 4)
//!
//! Fault injection and health (shadow mode only):
//!   --fault-plan <spec>          seeded fault schedule, e.g.
//!                                crash:t2@sweep40,stall:t1@sweep10+8,
//!                                slow-link:t0<->ps@2x,drop:t0@0.01
//!   --push-retries <N>           retries per EASGD push leg on a faulted
//!                                transfer (exhausted chunks are skipped)
//!   --push-backoff-ms <ms>       initial retry backoff, doubling per try
//!   --allreduce-timeout-ms <ms>  ring round timeout: evict (leave) members
//!                                that fail to deposit in time (0 = off)
//!   --heartbeat-timeout-ms <ms>  watchdog: depart trainers whose shadow
//!                                pool stops heartbeating (0 = off)
//!   --health-adaptive            demote straggling rendezvous partitions
//!                                to EASGD, promote back when healthy
//!   --health-stall-factor <f>    straggler = EWMA lap > f × cluster median
//!
//! Examples:
//!   shadowsync train --preset model_a --trainers 4 --threads 3 \
//!       --algo easgd --mode shadow --examples 200000 \
//!       --sync-chunk 4096 --delta-skip-target 0.5
//!   shadowsync train --algo ma --chunks 16 --reduce-engine overlapped
//!   shadowsync exp --id table2a
//!   shadowsync sim --trainers 5,10,20 --algo easgd --mode fixed --gap 5 --sync-ps 2

use std::path::PathBuf;

use anyhow::{bail, Result};

use shadowsync::config::{RunConfig, SyncAlgo, SyncMode};
use shadowsync::coordinator;
use shadowsync::exp::{self, ExpOpts};
use shadowsync::runtime::Runtime;
use shadowsync::sim::CostModel;
use shadowsync::sync::ReduceEngine;
use shadowsync::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("exp") => cmd_exp(&args),
        Some("elp") => cmd_elp(&args),
        Some("sim") => cmd_sim(&args),
        Some("list") | None => cmd_list(),
        Some(other) => bail!("unknown subcommand {other:?} (train|exp|elp|sim|list)"),
    }
}

fn parse_mode(args: &Args) -> Result<SyncMode> {
    match args.get_or("mode", "shadow") {
        "shadow" => Ok(SyncMode::Shadow),
        "fixed" | "fr" => Ok(SyncMode::FixedRate { gap: args.parse_or("gap", 30u32)? }),
        "decay" => Ok(SyncMode::Decaying {
            start: args.parse_or("gap-start", 100u32)?,
            end: args.parse_or("gap-end", 5u32)?,
        }),
        m => bail!("unknown --mode {m:?} (shadow|fixed|decay)"),
    }
}

fn run_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig {
        preset: args.get_or("preset", "tiny").to_string(),
        artifacts_dir: PathBuf::from(args.get_or("artifacts", "artifacts")),
        num_trainers: args.parse_or("trainers", 2usize)?,
        worker_threads: args.parse_or("threads", 2usize)?,
        num_embedding_ps: args.parse_or("embedding-ps", 2usize)?,
        num_sync_ps: args.parse_or("sync-ps", 1usize)?,
        algo: args.get_or("algo", "easgd").parse()?,
        mode: parse_mode(args)?,
        alpha: args.parse_or("alpha", 0.5f32)?,
        bmuf_eta: args.parse_or("bmuf-eta", 1.0f32)?,
        bmuf_momentum: args.parse_or("bmuf-momentum", 0.0f32)?,
        learning_rate: args.parse_or("lr", 0.02f32)?,
        train_examples: args.parse_or("examples", 100_000u64)?,
        eval_examples: args.parse_or("eval-examples", 20_000u64)?,
        data_seed: args.parse_or("seed", 1u64)?,
        shadow_interval_ms: args.parse_or("shadow-interval-ms", 0u64)?,
        sync_partitions: args.parse_or("sync-partitions", 1usize)?,
        shadow_threads: args.parse_or("shadow-threads", 1usize)?,
        repartition_every: args.parse_or("repartition-every", 0u64)?,
        allreduce_chunks: args.parse_or("chunks", 8usize)?,
        reduce_engine: args.parse_or("reduce-engine", ReduceEngine::Overlapped)?,
        reduce_ring_depth: args.parse_or("ring-depth", 2usize)?,
        pin_cores: args.has("pin-cores"),
        easgd_chunk_elems: args.parse_or("sync-chunk", 4096usize)?,
        delta_threshold: args.parse_or("delta-threshold", 0.0f32)?,
        delta_skip_target: args.parse_or("delta-skip-target", 0.0f32)?,
        dirty_epoch_scan: !args.has("no-dirty-scan"),
        fault_plan: args.get("fault-plan").map(str::to_string),
        push_retries: args.parse_or("push-retries", 3u32)?,
        push_backoff_ms: args.parse_or("push-backoff-ms", 1u64)?,
        allreduce_timeout_ms: args.parse_or("allreduce-timeout-ms", 0u64)?,
        heartbeat_timeout_ms: args.parse_or("heartbeat-timeout-ms", 0u64)?,
        health_adaptive: args.has("health-adaptive"),
        health_stall_factor: args.parse_or("health-stall-factor", 4.0f64)?,
        ..Default::default()
    };
    cfg.embedding.rows_per_table = args.parse_or("rows", cfg.embedding.rows_per_table)?;
    cfg.embedding.optimizer = args.parse_or("emb-opt", cfg.embedding.optimizer)?;
    cfg.embedding.cache_rows = args.parse_or("emb-cache", cfg.embedding.cache_rows)?;
    cfg.embedding.lookahead = args.parse_or("emb-lookahead", cfg.embedding.lookahead)?;
    cfg.embedding.buckets_per_table =
        args.parse_or("emb-buckets", cfg.embedding.buckets_per_table)?;
    if let Some(r) = args.get("reader-rate") {
        cfg.reader_rate_limit = Some(r.parse()?);
    }
    if let Some(m) = args.get("algo-map") {
        cfg.algo_map = Some(m.parse()?);
    }
    if let Some(c) = args.get("wire-codec") {
        shadowsync::config::apply_wire_codec_flag(&mut cfg, c)?;
    }
    // the sync-PS tier exists iff some (possibly algo-mapped) partition
    // runs the centralized algorithm — or the health controller may demote
    // one to it mid-run
    if !cfg.any_easgd() && !cfg.health_adaptive {
        cfg.num_sync_ps = 0;
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    println!(
        "{}: preset={} trainers={} threads={} embedding_ps={} sync_ps={}",
        cfg.label(),
        cfg.preset,
        cfg.num_trainers,
        cfg.worker_threads,
        cfg.num_embedding_ps,
        cfg.num_sync_ps
    );
    let rt = Runtime::cpu()?;
    if let Some(dir) = args.get("checkpoint") {
        // build → train → checkpoint → evaluate, keeping the cluster alive
        let cluster = coordinator::build(&cfg, &rt)?;
        let meter = std::time::Instant::now();
        coordinator::train(&cluster)?;
        let wall = meter.elapsed().as_secs_f64();
        coordinator::checkpoint(&cluster, &PathBuf::from(dir))?;
        println!("checkpoint written to {dir}");
        let examples = cluster.metrics.snapshot().examples;
        let mut out = coordinator::finish(cluster)?;
        out.eps = examples as f64 / wall.max(1e-9);
        out.wall_secs = wall;
        print_outcome(&out);
        return Ok(());
    }
    let out = coordinator::run_timed(&cfg, &rt)?;
    print_outcome(&out);
    Ok(())
}

fn print_outcome(out: &coordinator::TrainOutcome) {
    println!("examples      {}", out.metrics.examples);
    println!("train loss    {:.5}", out.train_loss);
    println!("eval loss     {:.5}", out.eval.avg_loss());
    println!("eval NE       {:.5}", out.eval.ne());
    println!("calibration   {:.4}", out.eval.calibration());
    println!("EPS           {:.0}", out.eps);
    println!("wall secs     {:.2}", out.wall_secs);
    println!("avg sync gap  {:.3}", out.avg_sync_gap);
    if out.partition_gaps.len() > 1 {
        let gaps: Vec<String> =
            out.partition_gaps.iter().map(|g| format!("{g:.2}")).collect();
        println!("part gaps     [{}]", gaps.join(", "));
    }
    println!("sync rounds   {}", out.metrics.syncs);
    println!("sync bytes    {}", out.metrics.sync_bytes);
    println!("emb bytes     {}", out.embedding_bytes);
    if out.emb_cache_hits + out.emb_cache_misses > 0 {
        let total = (out.emb_cache_hits + out.emb_cache_misses) as f64;
        println!("emb cache     {:.1}% hit rate", 100.0 * out.emb_cache_hits as f64 / total);
    }
    if out.emb_migrations > 0 {
        println!("emb moves     {}", out.emb_migrations);
    }
    if out.repartitions > 0 {
        println!("repartitions  {}", out.repartitions);
    }
    if let Some(t) = &out.sync_traffic {
        println!("skip rate     {:.1}%", 100.0 * t.skip_fraction());
        println!("scan skips    {:.1}%", 100.0 * t.scan_skip_fraction());
    }
    println!("ELP           {}", out.elp);
}

fn cmd_exp(args: &Args) -> Result<()> {
    let opts = ExpOpts {
        artifacts_dir: PathBuf::from(args.get_or("artifacts", "artifacts")),
        out_dir: PathBuf::from(args.get_or("out", "results")),
        scale: args.parse_or("scale", 1.0f64)?,
        seed: args.parse_or("seed", 20200630u64)?,
        smoke: args.has("smoke"),
    };
    let id = args.get_or("id", "all");
    if id == "all" {
        for id in exp::ALL_IDS {
            println!("\n=== experiment {id} ===");
            exp::run(id, &opts)?;
        }
    } else {
        exp::run(id, &opts)?;
    }
    Ok(())
}

fn cmd_elp(args: &Args) -> Result<()> {
    let trainers = args.parse_or("trainers", 20usize)?;
    let threads = args.parse_or("threads", 24usize)?;
    let batch = args.parse_or("batch", 200usize)?;
    let cfg = RunConfig { num_trainers: trainers, worker_threads: threads, ..Default::default() };
    println!(
        "ELP = batch({batch}) × hogwild({threads}) × replicas({trainers}) = {}",
        cfg.elp(batch)
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let cm = CostModel::paper_scale();
    let algo: SyncAlgo = args.get_or("algo", "easgd").parse()?;
    let mode = parse_mode(args)?;
    let sync_ps = args.parse_or("sync-ps", 2usize)?;
    let threads = args.parse_or("threads", 24usize)?;
    println!("paper-scale model: {algo} {mode:?} sync_ps={sync_ps} threads={threads}");
    println!(
        "{:>9} {:>12} {:>14} {:>12} {:>10}",
        "trainers", "EPS", "avg sync gap", "syncPS util", "train frac"
    );
    for n in args.parse_list("trainers", &[5usize, 10, 15, 20])? {
        let p = cm.simulate(n, threads, algo, mode, sync_ps);
        println!(
            "{:>9} {:>12.0} {:>14.2} {:>11.0}% {:>10.3}",
            n,
            p.eps,
            p.avg_sync_gap,
            100.0 * p.sync_ps_util,
            p.train_fraction
        );
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("presets: tiny, model_a, model_b, model_c (see python/compile/presets.py)");
    println!("experiments: {}", exp::ALL_IDS.join(", "));
    println!("subcommands: train, exp, elp, sim, list  (see --help text in main.rs)");
    println!(
        "delta gating: --delta-threshold <abs> (fixed gate), \
         --delta-skip-target <frac> (adaptive quantile gate), \
         --no-dirty-scan (disable dirty-epoch scan reuse)"
    );
    println!(
        "partitioned fabric: --sync-partitions <P>, --shadow-threads <S>, \
         --algo-map easgd:0-1,ma:2-3, --repartition-every <N sweeps> \
         (shadow mode only)"
    );
    println!(
        "reduce engines: --reduce-engine overlapped|striped|serial|shared-nothing, \
         --ring-depth <D> (shared-nothing deposit-ring depth, default 2), \
         --pin-cores (best-effort worker→core affinity)"
    );
    println!(
        "wire codecs: --wire-codec fp32|fp16|int8|topk:R (uniform) or a \
         per-partition map like int8:0-1,topk:0.1:2-3 (composes with \
         --algo-map; lossy codecs use error feedback)"
    );
    println!(
        "fault injection: --fault-plan crash:t2@sweep40,stall:t1@sweep10+8,... \
         --push-retries <N>, --allreduce-timeout-ms <ms>, \
         --heartbeat-timeout-ms <ms>, --health-adaptive (shadow mode only)"
    );
    println!(
        "embedding tier: --emb-cache <rows> (versioned row cache), \
         --emb-lookahead <k> (prefetch the next k batches' row ids), \
         --emb-buckets <B> (row-range buckets per table, 0 = auto)"
    );
    Ok(())
}
