//! # ShadowSync
//!
//! A full reproduction of *“ShadowSync: Performing Synchronization in the
//! Background for Highly Scalable Distributed Training”* (Zheng et al.,
//! 2020) as a rust distributed-training coordinator over AOT-compiled
//! JAX/Pallas compute (PJRT CPU).
//!
//! Architecture (DESIGN.md):
//! - **L3 (this crate)** — trainers with Hogwild worker threads, embedding
//!   parameter servers, optional sync parameter servers, and per-trainer
//!   **shadow threads** that synchronize dense-parameter replicas in the
//!   background (S-EASGD / S-MA / S-BMUF) or in the foreground at a fixed
//!   rate (FR-*), a reader service, bin-packing placement, metrics, a
//!   cluster-scale throughput simulator, and the paper's experiment harness.
//! - **L2/L1 (python, build-time only)** — the DLRM forward/backward with
//!   Pallas kernels, lowered to HLO text consumed by [`runtime`].

pub mod config;
pub mod coordinator;
pub mod data;
pub mod embedding;
pub mod exp;
pub mod mc;
pub mod metrics;
pub mod net;
pub mod optim;
pub mod placement;
pub mod runtime;
pub mod sim;
pub mod sync;
pub mod tensor;
pub mod trainer;
pub mod util;
