//! The steady-state cost model behind the EPS-scaling figures.
//!
//! Collectives are priced from *measured* traffic: MA/BMUF ring rounds use
//! the exact chunked reduce-scatter/all-gather schedule exported by
//! [`crate::sync::traffic`] (chunk rounding included) rather than the
//! closed-form `2·(n-1)/n` textbook estimate, and EASGD rounds are scaled
//! by the measured push fraction of the chunked/delta-gated sync-PS tier
//! (`SyncPsGroup::traffic`, fed in by the experiment harness).
//!
//! The partitioned shadow fabric is priced per partition. By default every
//! partition costs `1/P` of the vector; feeding measured per-partition
//! byte shares ([`CostModel::with_partition_byte_shares`], from
//! `PsTrafficSnapshot::partition_byte_shares` or
//! `MetricsSnapshot::partition_byte_shares`) prices heterogeneous plans —
//! including mixed `--algo-map` fabrics via
//! [`CostModel::simulate_hybrid_shadow`] — from what each partition
//! actually moved, not `round_bytes / P`.
//!
//! # Examples
//!
//! ```
//! use shadowsync::config::{SyncAlgo, SyncMode};
//! use shadowsync::sim::CostModel;
//!
//! let model = CostModel::paper_scale();
//! let point = model.simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
//! assert_eq!(point.train_fraction, 1.0, "shadow sync never throttles training");
//! assert!(point.eps > 0.0);
//! ```

use crate::config::{SyncAlgo, SyncMode};
use crate::sync::ps::PsTrafficSnapshot;
use crate::sync::traffic::{RingTraffic, WireCodec};

/// Calibrated constants describing one testbed.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// CPU seconds per batch per worker thread, uncontended
    pub batch_secs: f64,
    /// memory-bandwidth knee: effective parallelism saturates around this
    /// many threads (paper §4.4: ~50% bw at 12 threads, saturated at 24)
    pub mem_knee_threads: f64,
    /// knee sharpness (higher = harder saturation)
    pub mem_knee_power: f64,
    /// NIC bandwidth, bytes/sec, full duplex per direction (25 Gbit)
    pub nic_bytes_per_sec: f64,
    /// dense parameter bytes |w| moved per sync direction
    pub w_bytes: f64,
    /// examples per batch
    pub batch: usize,
    /// per-collective latency floor (RPC/barrier overhead), seconds
    pub round_latency: f64,
    /// reader service ceiling in examples/sec (None = amply provisioned)
    pub reader_eps_cap: Option<f64>,
    /// chunk count of the ring schedule whose *measured* per-member bytes
    /// price the MA/BMUF collectives (mirrors `RunConfig::allreduce_chunks`)
    pub ring_chunks: usize,
    /// measured fraction of the full `2·|w|` EASGD round the delta-gated
    /// chunked pushes actually move (1.0 = no skips; feed from
    /// `SyncPsGroup::traffic` / `metrics.sync_bytes`)
    pub easgd_push_fraction: f64,
    /// contiguous sync partitions `P` of the partitioned shadow fabric
    /// (1 = the monolithic whole-vector fabric; shadow modes only)
    pub sync_partitions: usize,
    /// shadow threads `S` per trainer servicing the partitions (`S ≤ P`);
    /// concurrent partition rounds share the trainer NIC
    pub shadow_threads: usize,
    /// measured per-partition cost shares (normalized, one entry per
    /// partition). Empty = uniform `1/P` — the static-plan assumption;
    /// feed [`CostModel::with_partition_byte_shares`] to price
    /// heterogeneous (adaptively repartitioned / algo-mapped) fabrics
    /// from what each partition actually moved
    pub partition_shares: Vec<f64>,
    /// one straggling trainer's lap-time inflation factor (1.0 = healthy
    /// cluster). Rendezvous (MA/BMUF) rounds are paced by the straggler's
    /// deposits so their round time inflates by this factor; centralized
    /// (EASGD) sync and the healthy trainers' training never wait on it —
    /// only the straggler's own contribution shrinks. This is the pricing
    /// behind `exp ablate-faults`' static-vs-adaptive EPS comparison.
    pub straggler_factor: f64,
    /// wire codec the ring schedule's hops are priced under (mirrors
    /// `RunConfig::wire_codec`; `Fp32` = the uncompressed legacy pricing).
    /// EASGD compression needs no knob here: it flows in through the
    /// measured push fraction / partition byte shares, which already see
    /// codec-reduced bytes.
    pub ring_codec: WireCodec,
    /// embedding-tier bytes per example over the trainer NIC (ids up,
    /// pooled rows down, gradients back), before caching. `0.0` = the
    /// embedding tier is not priced (the dense-only legacy figures).
    pub emb_bytes_per_example: f64,
    /// measured trainer-side row-cache hit rate in `[0, 1]`; the hit
    /// fraction of `emb_bytes_per_example` is served locally and never
    /// touches the NIC
    pub emb_cache_hit_rate: f64,
}

/// One simulated operating point.
#[derive(Debug, Clone)]
pub struct SimPoint {
    pub trainers: usize,
    pub threads: usize,
    pub eps: f64,
    /// paper Eq. 2, in trainer-level iterations per sync round
    pub avg_sync_gap: f64,
    /// sync-tier NIC utilization in [0, 1]
    pub sync_ps_util: f64,
    /// fraction of wall time a worker thread spends training (1.0 for shadow)
    pub train_fraction: f64,
}

impl CostModel {
    /// The paper's testbed: 20-core 2 GHz Xeon trainers (24 worker threads),
    /// 25 Gbit Ethernet, batch 200. `w_bytes` ≈ 32 MB reproduces the paper's
    /// observed FR-EASGD-5 saturation near 14 trainers on 2 sync PSs.
    pub fn paper_scale() -> Self {
        Self {
            batch_secs: 0.5,
            mem_knee_threads: 24.0,
            mem_knee_power: 5.0,
            nic_bytes_per_sec: 25.0e9 / 8.0,
            w_bytes: 32.0e6,
            batch: 200,
            round_latency: 2e-3,
            reader_eps_cap: None,
            ring_chunks: 8,
            easgd_push_fraction: 1.0,
            sync_partitions: 1,
            shadow_threads: 1,
            partition_shares: Vec::new(),
            straggler_factor: 1.0,
            ring_codec: WireCodec::Fp32,
            emb_bytes_per_example: 0.0,
            emb_cache_hit_rate: 0.0,
        }
    }

    /// Price ring collectives under `codec` — hop bytes come from the same
    /// `codec_segment_bytes` schedule the live fabric meters, so compressed
    /// wire formats shrink the priced collective exactly as they shrink the
    /// measured NIC counters. `Fp32` is bit-identical to the legacy pricing.
    pub fn with_ring_codec(mut self, codec: WireCodec) -> Self {
        self.ring_codec = codec;
        self
    }

    /// Price the sharded embedding tier: each example moves
    /// `bytes_per_example` over the trainer NIC (sparse ids up, pooled
    /// rows down, gradients back), of which the measured cache `hit_rate`
    /// fraction is served from the trainer-local row cache. The lookahead
    /// pipeline prefetches ahead of the consumer, so embedding traffic
    /// overlaps compute: the trainer is bound by the *slower* of its
    /// compute rate and the NIC feed rate, not their sum.
    /// `bytes_per_example = 0` (the default) leaves every figure
    /// bit-identical to the dense-only pricing.
    pub fn with_embedding_traffic(mut self, bytes_per_example: f64, hit_rate: f64) -> Self {
        self.emb_bytes_per_example =
            if bytes_per_example.is_finite() { bytes_per_example.max(0.0) } else { 0.0 };
        self.emb_cache_hit_rate =
            if hit_rate.is_finite() { hit_rate.clamp(0.0, 1.0) } else { 0.0 };
        self
    }

    /// Price the partitioned shadow fabric: `p` contiguous partitions
    /// synced by `s` shadow threads per trainer (`s` is clamped to
    /// `[1, p]`). `p = s = 1` reproduces the monolithic pricing exactly.
    pub fn with_partitioned_shadow(mut self, p: usize, s: usize) -> Self {
        self.sync_partitions = p.max(1);
        self.shadow_threads = s.clamp(1, self.sync_partitions);
        self
    }

    /// Price the shadow fabric from *measured* per-partition byte shares
    /// (one entry per partition; normalized here). Non-positive or
    /// non-finite entries count as zero cost; an all-zero profile is
    /// ignored and the uniform `1/P` assumption stays. Sets the partition
    /// count to the profile's length.
    pub fn with_partition_byte_shares(mut self, shares: &[f64]) -> Self {
        let total: f64 = shares.iter().filter(|s| s.is_finite() && **s > 0.0).sum();
        if !shares.is_empty() && total > 0.0 {
            self.partition_shares = shares
                .iter()
                .map(|s| if s.is_finite() && *s > 0.0 { s / total } else { 0.0 })
                .collect();
            self.sync_partitions = self.partition_shares.len();
            self.shadow_threads = self.shadow_threads.clamp(1, self.sync_partitions);
        }
        self
    }

    /// Price EASGD rounds from measured sync-PS traffic (delta-gated
    /// chunked pushes move fewer bytes than the full-vector round). Uses
    /// the scale-free *byte* fraction, so uneven chunk sizes can't skew it,
    /// floored at 1% of a full round: a fully-converged delta-gated run can
    /// measure ~0 bytes/round, and pricing sync as literally free would
    /// erase the FR-EASGD saturation shape the figures exist to show.
    pub fn with_measured_easgd(mut self, t: &PsTrafficSnapshot) -> Self {
        if t.rounds > 0 {
            self.easgd_push_fraction = t.byte_fraction().max(0.01);
        }
        self
    }

    /// Price EASGD rounds at a directly supplied measured push fraction
    /// (measured round bytes ÷ full `2·|w|` round bytes).
    pub fn with_easgd_push_fraction(mut self, fraction: f64) -> Self {
        self.easgd_push_fraction = fraction.clamp(0.0, 1.0);
        self
    }

    /// Price a degraded cluster in which one trainer's laps run `f`×
    /// slow (floored at 1; non-finite = healthy). Rendezvous rounds are
    /// gated by the straggler's pace; stop-the-world (fixed-rate) ring
    /// modes drag the whole barrier down to it; centralized sync and the
    /// healthy trainers' shadow-mode training are untouched.
    pub fn with_straggler_factor(mut self, f: f64) -> Self {
        self.straggler_factor = if f.is_finite() { f.max(1.0) } else { 1.0 };
        self
    }

    /// Trainer-equivalents of compute once the straggler runs `1/f` as
    /// fast: `n - 1 + 1/f` (the healthy peers never wait on it outside a
    /// barrier).
    fn straggled_trainers(&self, n: f64) -> f64 {
        if n < 1.0 {
            return n;
        }
        n - 1.0 + 1.0 / self.straggler_factor
    }

    /// Effective parallel threads after memory-bandwidth contention:
    /// smooth knee `m / (1 + (m/c)^p)^(1/p)` — linear for small m,
    /// asymptoting to c.
    pub fn effective_threads(&self, m: usize) -> f64 {
        let m = m as f64;
        let c = self.mem_knee_threads;
        let p = self.mem_knee_power;
        m / (1.0 + (m / c).powf(p)).powf(1.0 / p)
    }

    /// Batches/sec the trainer NIC can feed with pooled embeddings after
    /// the cache absorbs its hit fraction (`f64::INFINITY` when the tier
    /// is unpriced or fully cached — `.min()` with it is a no-op, keeping
    /// the dense-only pricing bit-identical).
    fn emb_feed_cap(&self) -> f64 {
        let bytes_per_batch =
            self.batch as f64 * self.emb_bytes_per_example * (1.0 - self.emb_cache_hit_rate);
        if bytes_per_batch <= 0.0 {
            f64::INFINITY
        } else {
            self.nic_bytes_per_sec / bytes_per_batch
        }
    }

    /// Unconstrained batches/sec of one trainer running m worker threads,
    /// bounded by the embedding feed cap when the tier is priced.
    fn trainer_rate(&self, m: usize) -> f64 {
        (self.effective_threads(m) / self.batch_secs).min(self.emb_feed_cap())
    }

    /// Simulate one operating point.
    ///
    /// `sync_ps` is the number of sync PSs (EASGD only; ignored for
    /// decentralized algorithms).
    pub fn simulate(
        &self,
        trainers: usize,
        threads: usize,
        algo: SyncAlgo,
        mode: SyncMode,
        sync_ps: usize,
    ) -> SimPoint {
        let n = trainers as f64;
        let m = threads as f64;
        let r_trainer = self.trainer_rate(threads); // batches/s, unconstrained
        // per-thread effective batch seconds under memory contention
        let t_batch_eff = m / r_trainer;
        let sync_cap = sync_ps.max(1) as f64 * self.nic_bytes_per_sec;
        // up + down, scaled by the measured fraction the delta-gated
        // chunked pushes actually move
        let round_bytes = 2.0 * self.w_bytes * self.easgd_push_fraction;

        // a decaying gap behaves like its harmonic-mean fixed rate for
        // steady-state throughput purposes
        let mode = match mode {
            SyncMode::Decaying { start, end } => SyncMode::FixedRate {
                gap: (2.0 * start as f64 * end as f64 / (start + end).max(1) as f64)
                    .round()
                    .max(1.0) as u32,
            },
            m => m,
        };
        let (mut iter_rate_total, gap, util, train_frac);
        match (algo, mode) {
            (SyncAlgo::None, _) => {
                iter_rate_total = self.straggled_trainers(n) * r_trainer;
                gap = f64::INFINITY;
                util = 0.0;
                train_frac = 1.0;
            }
            (SyncAlgo::Easgd, SyncMode::FixedRate { gap: k }) => {
                // every worker thread syncs inline every k of its own
                // iterations; congestion inflates the sync time until
                // demand fits the sync-tier capacity (fluid fixed point)
                let k = k as f64;
                let t_sync0 = round_bytes / self.nic_bytes_per_sec + self.round_latency;
                let mut t_sync = t_sync0;
                for _ in 0..200 {
                    let per_thread = 1.0 / (t_batch_eff + t_sync / k);
                    let demand = n * m * per_thread * round_bytes / k;
                    let over = demand / sync_cap;
                    if over <= 1.0 {
                        break;
                    }
                    t_sync *= over.min(1.5);
                }
                let per_thread = 1.0 / (t_batch_eff + t_sync / k);
                // the straggler's threads contribute 1/f of a healthy
                // trainer's share; nobody else waits on it (no barrier)
                iter_rate_total = self.straggled_trainers(n) * m * per_thread;
                let demand = iter_rate_total * round_bytes / k;
                util = (demand / sync_cap).min(1.0);
                gap = k;
                train_frac = t_batch_eff / (t_batch_eff + t_sync / k);
            }
            (_, SyncMode::Decaying { .. }) => unreachable!("normalized above"),
            (_, SyncMode::Shadow) => {
                // background sync never throttles training; the sweep is
                // priced per partition (uniform 1/P by default, measured
                // shares when fed) and shared by the S pool threads
                iter_rate_total = self.straggled_trainers(n) * r_trainer;
                let algos = vec![algo; self.sync_partitions.max(1)];
                let (sweep, ps_round_bytes) = self.shadow_sweep(trainers, &algos, sync_ps);
                // reader cap may slow iterations (affects the measured gap)
                let capped_iter_total = self.apply_reader_cap(iter_rate_total);
                gap = (capped_iter_total / n) * sweep;
                util = if ps_round_bytes > 0.0 {
                    (n * ps_round_bytes / sweep / sync_cap).min(1.0)
                } else {
                    0.0
                };
                train_frac = 1.0;
            }
            (SyncAlgo::Ma | SyncAlgo::Bmuf, SyncMode::FixedRate { gap: k }) => {
                // stop-the-world ring collective every k trainer
                // iterations: the barrier drags every member down to the
                // straggler's lap pace
                let k = k as f64;
                let t_round = self.ring_secs(trainers) + self.round_latency;
                let t_k_iters = k / r_trainer * self.straggler_factor;
                iter_rate_total = n * k / (t_k_iters + t_round);
                gap = k;
                util = 0.0;
                train_frac = t_k_iters / (t_k_iters + t_round);
            }
        }
        iter_rate_total = self.apply_reader_cap(iter_rate_total);
        SimPoint {
            trainers,
            threads,
            eps: iter_rate_total * self.batch as f64,
            avg_sync_gap: gap,
            sync_ps_util: util,
            train_fraction: train_frac,
        }
    }

    /// Wall time of one ring collective: the slowest member's *measured*
    /// wire bytes under the chunked reduce-scatter/all-gather schedule
    /// (exported by `sync::traffic`, chunk rounding included) over its NIC.
    /// This replaces the closed-form `2·w·(n-1)/(n·bw)` estimate — the two
    /// agree to within chunk rounding, but the simulator now prices what
    /// the fabric actually does.
    fn ring_secs(&self, trainers: usize) -> f64 {
        if trainers <= 1 {
            return 0.0;
        }
        let elems = (self.w_bytes / 4.0).round() as usize;
        let measured =
            RingTraffic::measure_codec(self.ring_codec, elems, self.ring_chunks, trainers);
        measured.max_member_bytes() as f64 / self.nic_bytes_per_sec
    }

    /// [`CostModel::ring_secs`] over an explicit element count (one
    /// partition's slice), at full NIC rate — the shadow sweep scales it
    /// by the NIC share when `S` rings run concurrently.
    fn ring_elems_secs(&self, elems: usize, trainers: usize) -> f64 {
        if trainers <= 1 {
            return 0.0;
        }
        let measured =
            RingTraffic::measure_codec(self.ring_codec, elems, self.ring_chunks, trainers);
        measured.max_member_bytes() as f64 / self.nic_bytes_per_sec
    }

    /// Wall time of one shadow *sweep* per pool thread (every partition
    /// completes one round per sweep) plus the sync-PS bytes one trainer's
    /// full sweep demands. `algos[i]` is partition `i`'s algorithm;
    /// partition costs come from the measured shares when fed
    /// ([`CostModel::with_partition_byte_shares`]) and the uniform `1/P`
    /// split otherwise. EASGD partitions contend for the sync-PS tier
    /// (`n` trainers sweep concurrently); ring partitions are
    /// trainer-to-trainer, and the `S` concurrent threads share the
    /// trainer NIC in both cases.
    ///
    /// The sweep is the summed round time divided across the `S` threads,
    /// floored by the slowest single partition round — one round runs on
    /// one thread, so an imbalanced plan is gated by its hottest partition
    /// no matter how many threads idle beside it. That floor is why
    /// measured-cost repartitioning (which equalizes round costs) lowers
    /// the priced worst-partition gap while leaving total bytes unchanged.
    fn shadow_sweep(&self, trainers: usize, algos: &[SyncAlgo], sync_ps: usize) -> (f64, f64) {
        let n = trainers as f64;
        let p = algos.len().max(1);
        let s = self.shadow_threads.clamp(1, p) as f64;
        let sync_cap = sync_ps.max(1) as f64 * self.nic_bytes_per_sec;
        let round_bytes = 2.0 * self.w_bytes * self.easgd_push_fraction;
        let elems = (self.w_bytes / 4.0).round() as usize;
        let mut sum = 0.0;
        let mut slowest = 0.0f64;
        let mut ps_bytes = 0.0;
        for (i, algo) in algos.iter().enumerate() {
            let t = match algo {
                SyncAlgo::Easgd => {
                    let b = match self.partition_shares.get(i) {
                        Some(&share) => round_bytes * share,
                        None => round_bytes / p as f64,
                    };
                    ps_bytes += b;
                    (b * s / self.nic_bytes_per_sec).max(n * b / sync_cap)
                        + self.round_latency
                }
                SyncAlgo::Ma | SyncAlgo::Bmuf => {
                    let part_elems = match self.partition_shares.get(i) {
                        Some(&share) => ((elems as f64 * share).round() as usize).max(1),
                        None => crate::sync::traffic::part_len(elems, p, i).max(1),
                    };
                    // rendezvous rounds close at the straggler's deposit
                    // pace — centralized partitions below never wait on it
                    (self.ring_elems_secs(part_elems, trainers) * s + self.round_latency)
                        * self.straggler_factor
                }
                SyncAlgo::None => 0.0,
            };
            sum += t;
            slowest = slowest.max(t);
        }
        ((sum / s).max(slowest), ps_bytes)
    }

    /// Price a heterogeneous `--algo-map` shadow fabric: `algos[i]` is
    /// partition `i`'s algorithm, partition costs come from the measured
    /// byte shares when fed. Training throughput is untouched (shadow);
    /// the per-partition Eq.-2 gap and sync-PS utilization reflect the
    /// mixed sweep.
    pub fn simulate_hybrid_shadow(
        &self,
        trainers: usize,
        threads: usize,
        algos: &[SyncAlgo],
        sync_ps: usize,
    ) -> SimPoint {
        let n = trainers as f64;
        let iter_rate_total =
            self.apply_reader_cap(self.straggled_trainers(n) * self.trainer_rate(threads));
        let (sweep, ps_round_bytes) = self.shadow_sweep(trainers, algos, sync_ps);
        let sync_cap = sync_ps.max(1) as f64 * self.nic_bytes_per_sec;
        let util = if ps_round_bytes > 0.0 && sweep > 0.0 {
            (n * ps_round_bytes / sweep / sync_cap).min(1.0)
        } else {
            0.0
        };
        SimPoint {
            trainers,
            threads,
            eps: iter_rate_total * self.batch as f64,
            avg_sync_gap: if sweep > 0.0 {
                (iter_rate_total / n) * sweep
            } else {
                f64::INFINITY
            },
            sync_ps_util: util,
            train_fraction: 1.0,
        }
    }

    fn apply_reader_cap(&self, iter_rate_total: f64) -> f64 {
        match self.reader_eps_cap {
            Some(cap) => iter_rate_total.min(cap / self.batch as f64),
            None => iter_rate_total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_threads_shape() {
        let m = CostModel::paper_scale();
        // near-linear at low counts
        assert!((m.effective_threads(6) - 6.0).abs() < 0.05);
        // paper: ~50% memory bw at 12 threads -> barely impeded
        assert!(m.effective_threads(12) > 11.5);
        // saturating beyond the knee
        assert!(m.effective_threads(64) < 27.0);
        // monotone
        let mut prev = 0.0;
        for t in 1..=64 {
            let e = m.effective_threads(t);
            assert!(e >= prev);
            prev = e;
        }
    }

    #[test]
    fn fr_easgd_clip_is_capacity_consistent() {
        let m = CostModel::paper_scale();
        let p = m.simulate(20, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }, 2);
        // at the clip, sync tier runs at (near) full utilization and
        // training fraction visibly degrades
        assert!(p.sync_ps_util > 0.95, "util {}", p.sync_ps_util);
        assert!(p.train_fraction < 0.9, "train_frac {}", p.train_fraction);
    }

    #[test]
    fn shadow_never_degrades_train_fraction() {
        let m = CostModel::paper_scale();
        for n in [5, 10, 20] {
            let p = m.simulate(n, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
            assert_eq!(p.train_fraction, 1.0);
        }
    }

    #[test]
    fn ring_cost_grows_sublinearly() {
        let m = CostModel::paper_scale();
        assert_eq!(m.ring_secs(1), 0.0);
        assert!(m.ring_secs(20) < 2.0 * m.w_bytes / m.nic_bytes_per_sec);
        assert!(m.ring_secs(20) > m.ring_secs(5));
    }

    #[test]
    fn measured_ring_pricing_agrees_with_closed_form_within_rounding() {
        // the simulator now prices collectives from the measured chunked
        // schedule; at paper scale the chunk rounding is sub-0.1%, so the
        // figures keep the paper's qualitative shapes
        let m = CostModel::paper_scale();
        for n in [2usize, 5, 10, 20] {
            let closed = 2.0 * m.w_bytes * (n as f64 - 1.0) / (n as f64 * m.nic_bytes_per_sec);
            let measured = m.ring_secs(n);
            assert!(
                (measured - closed).abs() <= closed * 1e-3,
                "n={n}: measured {measured} vs closed form {closed}"
            );
        }
    }

    #[test]
    fn codec_ring_pricing_shrinks_with_the_wire_format() {
        // fp16 halves the ring's wall time (2-byte elements vs 4); the
        // default fp32 codec must be bit-identical to the legacy pricing
        let m = CostModel::paper_scale();
        let fp16 = CostModel::paper_scale().with_ring_codec(WireCodec::Fp16);
        for n in [2usize, 5, 20] {
            let base = m.ring_secs(n);
            assert_eq!(
                CostModel::paper_scale().with_ring_codec(WireCodec::Fp32).ring_secs(n),
                base,
                "explicit fp32 must not perturb the default pricing"
            );
            let half = fp16.ring_secs(n);
            assert!(
                (half - base / 2.0).abs() <= base * 1e-3,
                "n={n}: fp16 ring {half} should be ~half of fp32 {base}"
            );
        }
        // a sweep priced under int8 is cheaper than fp32 end-to-end
        let int8 = CostModel::paper_scale().with_ring_codec(WireCodec::Int8);
        let a = int8.simulate(8, 24, SyncAlgo::Ma, SyncMode::Shadow, 2);
        let b = m.simulate(8, 24, SyncAlgo::Ma, SyncMode::Shadow, 2);
        assert!(a.avg_sync_gap <= b.avg_sync_gap, "cheaper rings sync at least as often");
    }

    #[test]
    fn partitioned_shadow_pricing_is_monolithic_at_p1_and_scales_with_threads() {
        for algo in [SyncAlgo::Easgd, SyncAlgo::Ma] {
            // P = S = 1 is exactly the monolithic pricing (same code path,
            // same arithmetic)
            let base = CostModel::paper_scale().simulate(10, 24, algo, SyncMode::Shadow, 2);
            let p1 = CostModel::paper_scale()
                .with_partitioned_shadow(1, 1)
                .simulate(10, 24, algo, SyncMode::Shadow, 2);
            assert_eq!(p1.eps, base.eps, "{algo:?}");
            assert_eq!(p1.avg_sync_gap, base.avg_sync_gap, "{algo:?}");
            // more shadow threads sweep the partitions faster: the
            // per-partition gap shrinks (by the saved round latencies at
            // least), and training throughput is never touched
            let p4s1 = CostModel::paper_scale()
                .with_partitioned_shadow(4, 1)
                .simulate(10, 24, algo, SyncMode::Shadow, 2);
            let p4s4 = CostModel::paper_scale()
                .with_partitioned_shadow(4, 4)
                .simulate(10, 24, algo, SyncMode::Shadow, 2);
            assert!(
                p4s4.avg_sync_gap < p4s1.avg_sync_gap,
                "{algo:?}: S=4 gap {} !< S=1 gap {}",
                p4s4.avg_sync_gap,
                p4s1.avg_sync_gap
            );
            assert_eq!(p4s4.train_fraction, 1.0, "shadow never throttles training");
            assert_eq!(p4s4.eps, base.eps, "partitioning must not change shadow EPS");
        }
        // s is clamped into [1, p]
        let m = CostModel::paper_scale().with_partitioned_shadow(2, 9);
        assert_eq!(m.shadow_threads, 2);
    }

    #[test]
    fn measured_push_fraction_scales_easgd_pricing() {
        // moving 4x fewer bytes (delta-gated pushes) relieves the FR-5
        // sync-tier clip the paper diagnoses at 20 trainers on 2 sync PSs
        let base = CostModel::paper_scale();
        let gated = CostModel::paper_scale().with_easgd_push_fraction(0.25);
        let pb = base.simulate(20, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }, 2);
        let pg = gated.simulate(20, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }, 2);
        assert!(pg.eps > pb.eps * 1.5, "gated {} vs base {}", pg.eps, pb.eps);
        assert!(pg.sync_ps_util <= pb.sync_ps_util + 1e-9);
        // the snapshot-driven setter consumes the measured BYTE fraction
        // (4000 B/round over a 16 kB full round = 0.25), not the chunk
        // count (10/40 would coincide here, but bytes are authoritative
        // when chunk sizes are uneven)
        let snap = PsTrafficSnapshot {
            rounds: 10,
            bytes_moved: 40_000,
            chunks_pushed: 10,
            chunks_skipped: 30,
            full_round_bytes: 16_000,
            ..PsTrafficSnapshot::default()
        };
        let m2 = CostModel::paper_scale().with_measured_easgd(&snap);
        assert!((m2.easgd_push_fraction - 0.25).abs() < 1e-12);
        // no measured rounds -> keep the full-push default
        let empty = PsTrafficSnapshot {
            full_round_bytes: 16_000,
            ..PsTrafficSnapshot::default()
        };
        let m3 = CostModel::paper_scale().with_measured_easgd(&empty);
        assert!((m3.easgd_push_fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn measured_partition_shares_reshape_the_sweep() {
        // a skewed measured profile vs the uniform assumption, same P and S
        let uniform = CostModel::paper_scale().with_partitioned_shadow(4, 2);
        let skewed = CostModel::paper_scale()
            .with_partitioned_shadow(4, 2)
            .with_partition_byte_shares(&[0.85, 0.05, 0.05, 0.05]);
        assert_eq!(skewed.sync_partitions, 4);
        assert!((skewed.partition_shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let pu = uniform.simulate(10, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
        let ps = skewed.simulate(10, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
        // shadow throughput is untouched either way; the measured shares
        // reshape the sweep: total bytes are equal, but one partition
        // round runs on one thread, so the 85%-hot plan is gated by its
        // hottest partition and prices a strictly larger sweep (gap) than
        // the balanced plan — the effect adaptive repartitioning removes
        assert_eq!(pu.eps, ps.eps);
        assert!(pu.avg_sync_gap > 0.0);
        assert!(
            ps.avg_sync_gap > pu.avg_sync_gap * 1.2,
            "skewed sweep must be gated by its hot partition: \
             uniform {} vs skewed {}",
            pu.avg_sync_gap,
            ps.avg_sync_gap
        );
        // degenerate profiles are ignored, keeping the uniform assumption
        let bad = CostModel::paper_scale()
            .with_partitioned_shadow(4, 2)
            .with_partition_byte_shares(&[0.0, f64::NAN, -1.0, 0.0]);
        assert!(bad.partition_shares.is_empty());
        let pb = bad.simulate(10, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
        assert_eq!(pb.avg_sync_gap, pu.avg_sync_gap);
    }

    #[test]
    fn straggler_pricing_penalizes_rendezvous_not_centralized() {
        use crate::config::SyncAlgo::{Bmuf, Easgd};
        let healthy = CostModel::paper_scale().with_partitioned_shadow(2, 2);
        let degraded = CostModel::paper_scale()
            .with_partitioned_shadow(2, 2)
            .with_straggler_factor(4.0);
        // factor 1 (and garbage factors) are the healthy model exactly
        let noop = CostModel::paper_scale().with_straggler_factor(0.2);
        assert_eq!(noop.straggler_factor, 1.0);
        assert_eq!(
            CostModel::paper_scale().with_straggler_factor(f64::NAN).straggler_factor,
            1.0
        );

        // shadow-mode training only loses the straggler's own share...
        let hb = healthy.simulate(10, 24, Bmuf, SyncMode::Shadow, 0);
        let db = degraded.simulate(10, 24, Bmuf, SyncMode::Shadow, 0);
        assert!(db.eps > hb.eps * 0.9, "shadow EPS {} vs healthy {}", db.eps, hb.eps);
        // ...but a static rendezvous fabric's sync gap inflates ~4x
        assert!(
            db.avg_sync_gap > hb.avg_sync_gap * 3.0,
            "straggled ring gap {} vs healthy {}",
            db.avg_sync_gap,
            hb.avg_sync_gap
        );
        // the adaptive demotion (rings -> EASGD) keeps the gap near the
        // healthy centralized fabric's: this is the EPS/gap argument the
        // fault ablation reports at paper scale
        let de = degraded.simulate_hybrid_shadow(10, 24, &[Easgd, Easgd], 4);
        let he = healthy.simulate_hybrid_shadow(10, 24, &[Easgd, Easgd], 4);
        assert!(de.avg_sync_gap <= he.avg_sync_gap * 1.01);
        let dstatic = degraded.simulate_hybrid_shadow(10, 24, &[Bmuf, Bmuf], 0);
        assert!(
            dstatic.avg_sync_gap > de.avg_sync_gap * 2.0,
            "static ring {} !>> demoted {}",
            dstatic.avg_sync_gap,
            de.avg_sync_gap
        );

        // stop-the-world ring modes pay the barrier: the whole cluster
        // drops toward the straggler's pace
        let hfr = healthy.simulate(10, 24, Bmuf, SyncMode::FixedRate { gap: 10 }, 0);
        let dfr = degraded.simulate(10, 24, Bmuf, SyncMode::FixedRate { gap: 10 }, 0);
        assert!(dfr.eps < hfr.eps * 0.5, "FR ring EPS {} vs healthy {}", dfr.eps, hfr.eps);
    }

    #[test]
    fn embedding_feed_cap_prices_cache_hits_as_recovered_eps() {
        let base = CostModel::paper_scale();
        let pb = base.simulate(10, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
        // an unpriced tier, and a fully-cached one, are bit-identical to
        // the dense-only figures
        let zero = CostModel::paper_scale().with_embedding_traffic(0.0, 0.0);
        assert_eq!(zero.simulate(10, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2).eps, pb.eps);
        let full = CostModel::paper_scale().with_embedding_traffic(1.0e6, 1.0);
        assert_eq!(full.simulate(10, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2).eps, pb.eps);
        // heavy uncached traffic binds the trainer NIC: 200 ex x 1 MB over
        // 3.125 GB/s is ~15.6 batches/s, well under the ~42 compute allows
        let cold = CostModel::paper_scale().with_embedding_traffic(1.0e6, 0.0);
        let pc = cold.simulate(10, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
        assert!(pc.eps < pb.eps * 0.5, "cold tier {} !<< dense {}", pc.eps, pb.eps);
        // a measured 50% hit rate halves the wire bytes and claws EPS back
        let warm = CostModel::paper_scale().with_embedding_traffic(1.0e6, 0.5);
        let pw = warm.simulate(10, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2);
        assert!(pw.eps > pc.eps && pw.eps < pb.eps, "cold {} warm {} dense {}", pc.eps, pw.eps, pb.eps);
        // garbage knobs degrade to the unpriced tier
        let junk = CostModel::paper_scale().with_embedding_traffic(f64::NAN, f64::INFINITY);
        assert_eq!(junk.emb_bytes_per_example, 0.0);
        assert_eq!(junk.emb_cache_hit_rate, 0.0);
    }

    #[test]
    fn hybrid_algo_map_pricing_mixes_ps_and_ring_costs() {
        use crate::config::SyncAlgo::{Bmuf, Easgd, Ma, None as NoAlgo};
        let m = CostModel::paper_scale().with_partitioned_shadow(4, 2);
        let hybrid = m.simulate_hybrid_shadow(10, 24, &[Easgd, Easgd, Ma, Bmuf], 2);
        assert_eq!(hybrid.train_fraction, 1.0, "shadow never throttles training");
        assert!(hybrid.avg_sync_gap.is_finite() && hybrid.avg_sync_gap > 0.0);
        // EASGD partitions demand sync-PS bandwidth, rings do not
        assert!(hybrid.sync_ps_util > 0.0);
        let rings_only = m.simulate_hybrid_shadow(10, 24, &[Ma, Ma, Bmuf, Bmuf], 2);
        assert_eq!(rings_only.sync_ps_util, 0.0);
        // an all-EASGD map through the hybrid entry point matches simulate()
        let all_easgd = m.simulate_hybrid_shadow(10, 24, &[Easgd; 4], 2);
        let direct = m.simulate(10, 24, Easgd, SyncMode::Shadow, 2);
        assert_eq!(all_easgd.avg_sync_gap, direct.avg_sync_gap);
        assert_eq!(all_easgd.eps, direct.eps);
        // all-None partitions never sync: the gap is infinite
        let idle = m.simulate_hybrid_shadow(10, 24, &[NoAlgo; 4], 2);
        assert!(idle.avg_sync_gap.is_infinite());
        // measured shares shift cost between the PS tier and the rings
        let skewed = CostModel::paper_scale()
            .with_partitioned_shadow(4, 2)
            .with_partition_byte_shares(&[0.7, 0.1, 0.1, 0.1]);
        let sk = skewed.simulate_hybrid_shadow(10, 24, &[Easgd, Easgd, Ma, Bmuf], 2);
        assert!(
            (sk.avg_sync_gap - hybrid.avg_sync_gap).abs() > 1e-9,
            "measured shares must reprice the hybrid sweep"
        );
    }
}
