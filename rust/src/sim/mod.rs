//! Cluster-scale throughput model (the EPS side of the paper's figures).
//!
//! This box has one core; 20 trainers × 24 worker threads cannot exhibit
//! the paper's throughput physics in vivo. The quality experiments run the
//! *real* system at reduced scale; the EPS-scaling curves (Fig. 5, 6b, 8)
//! come from this steady-state model of the paper's testbed, built from the
//! two saturation mechanisms the paper identifies explicitly:
//!
//! 1. **Trainer memory bandwidth** (§4.4): the interaction layers are
//!    memory-bound; ~50% utilization at 12 worker threads, saturated by 24.
//!    Modelled as a smooth-knee effective-parallelism curve.
//! 2. **Sync-PS NIC saturation** (§4.1.2): FR-EASGD syncs from *every
//!    worker thread* inline, so sync traffic scales with `n·m/k` and the
//!    sync-PS NICs clip it; because the sync is foreground, clipping
//!    throttles training itself. Shadow syncing uses leftover bandwidth and
//!    instead lets the *sync gap* grow.
//!
//! Parameters are calibrated per `CostModel::paper_scale` to the paper's
//! testbed (20-core Xeon, 25 Gbit NICs, batch 200, 24 threads); the
//! small-scale constants (per-batch compute) are measured from this repo's
//! real runs by `exp::calibrate`.
//!
//! Collective pricing is *measured*, not closed-form: ring rounds cost the
//! slowest member's wire bytes under the exact chunked schedule the fabric
//! runs ([`crate::sync::traffic::RingTraffic`], chunk rounding included),
//! and EASGD rounds scale with the measured push fraction of the
//! delta-gated chunked sync-PS pushes (`SyncPsGroup::traffic`).

pub mod model;

pub use model::{CostModel, SimPoint};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SyncAlgo, SyncMode};

    fn m() -> CostModel {
        CostModel::paper_scale()
    }

    #[test]
    fn shadow_easgd_scales_linearly() {
        let pts: Vec<SimPoint> = (5..=20)
            .map(|n| m().simulate(n, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2))
            .collect();
        for w in pts.windows(2) {
            let r = w[1].eps / w[0].eps;
            let n_ratio = w[1].trainers as f64 / w[0].trainers as f64;
            assert!((r - n_ratio).abs() < 0.02, "not linear: {r} vs {n_ratio}");
        }
    }

    #[test]
    fn fr_easgd_5_plateaus_but_fr_30_does_not() {
        let eps =
            |n, k| m().simulate(n, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: k }, 2).eps;
        // FR-5 saturates the 2 sync PSs somewhere in the mid-teens
        let e14 = eps(14, 5);
        let e20 = eps(20, 5);
        assert!(e20 < e14 * 1.15, "FR-5 should plateau: {e14} -> {e20}");
        // FR-30 keeps scaling
        let f14 = eps(14, 30);
        let f20 = eps(20, 30);
        assert!(f20 > f14 * 1.35, "FR-30 should keep scaling: {f14} -> {f20}");
        // shadow beats FR-5 at scale
        let s20 = m().simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2).eps;
        assert!(s20 > e20 * 1.3);
    }

    #[test]
    fn four_sync_ps_fixes_fr5_plateau() {
        let eps2 = m().simulate(20, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }, 2).eps;
        let eps4 = m().simulate(20, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }, 4).eps;
        assert!(eps4 > eps2 * 1.5, "doubling sync PSs should relieve the clip");
        // and with 4 PSs the 5→20 curve is near-linear again (paper Fig 5 last panel)
        let e5 = m().simulate(5, 24, SyncAlgo::Easgd, SyncMode::FixedRate { gap: 5 }, 4).eps;
        assert!(eps4 / e5 > 3.3, "ratio {}", eps4 / e5);
    }

    #[test]
    fn shadow_gap_grows_with_trainers_when_ps_bound() {
        // paper: 15→20 trainers gave gaps 8.60 … 12.48 with 2 sync PSs
        let g15 = m().simulate(15, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2).avg_sync_gap;
        let g20 = m().simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 2).avg_sync_gap;
        assert!(g20 > g15, "gap should grow: {g15} -> {g20}");
        assert!(g15 > 2.0 && g20 < 40.0, "gaps implausible: {g15}, {g20}");
    }

    #[test]
    fn hogwild_threads_saturate_after_24() {
        // paper Fig 8 right: EPS almost stops growing at >= 24 threads
        let eps = |t| m().simulate(5, t, SyncAlgo::Easgd, SyncMode::Shadow, 1).eps;
        assert!(eps(24) / eps(12) > 1.4, "12->24 should still grow");
        assert!(eps(32) / eps(24) < 1.12, "24->32 should be nearly flat");
        assert!(eps(64) / eps(32) < 1.05, "32->64 flat");
    }

    #[test]
    fn decentralized_algos_scale_linearly_shadow_and_fr() {
        for algo in [SyncAlgo::Ma, SyncAlgo::Bmuf] {
            for mode in [SyncMode::Shadow, SyncMode::FixedRate { gap: 60 }] {
                let e5 = m().simulate(5, 24, algo, mode, 0).eps;
                let e20 = m().simulate(20, 24, algo, mode, 0).eps;
                assert!(e20 / e5 > 3.4, "{algo:?}/{mode:?} ratio {}", e20 / e5);
            }
        }
    }

    #[test]
    fn reader_cap_binds() {
        let mut cm = m();
        cm.reader_eps_cap = Some(50_000.0);
        let p = cm.simulate(20, 24, SyncAlgo::Easgd, SyncMode::Shadow, 6);
        assert!(p.eps <= 50_000.0 * 1.001);
        // reader-bound training slows, so the shadow gap collapses toward ~1
        assert!(p.avg_sync_gap < 3.0, "gap {}", p.avg_sync_gap);
    }
}
