//! PJRT backend: load AOT artifacts (HLO text) and execute them via the
//! `xla` crate, with zero Python anywhere near the request path.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`, following /opt/xla-example/load_hlo. HLO
//! *text* is the interchange format (the bundled xla_extension 0.5.1
//! rejects jax≥0.5's 64-bit-id protos).
//!
//! Worker threads keep a [`StepIo`] each: input literals are allocated once
//! and refilled with `copy_raw_from` every step, so the steady-state step
//! does no literal allocation.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use crate::config::ModelMeta;

use super::EvalOut;

/// `PjRtLoadedExecutable` wrapper that is shareable across worker threads.
///
/// SAFETY: the xla crate omits Send/Sync because the struct holds raw
/// pointers, but PJRT executables are immutable after compilation and
/// `PjRtLoadedExecutable::Execute` is thread-safe (the CPU client runs a
/// thread pool underneath). The integration test
/// `concurrent_execution_is_correct` exercises this from many threads.
pub struct Executable(PjRtLoadedExecutable);

// SAFETY: the executable is immutable after compilation and `Execute` is
// thread-safe in the CPU plugin (see the struct-level contract above).
unsafe impl Send for Executable {}
// SAFETY: as for `Send` — shared references only reach the thread-safe
// `Execute` entry point.
unsafe impl Sync for Executable {}

impl Executable {
    pub fn execute(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        let bufs = self.0.execute::<&Literal>(args).map_err(|e| anyhow!("execute: {e}"))?;
        let lit = bufs[0][0].to_literal_sync().map_err(|e| anyhow!("fetch: {e}"))?;
        // aot.py lowers with return_tuple=True: always a tuple literal.
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e}"))
    }
}

/// The PJRT client (one per process).
pub struct Runtime {
    client: PjRtClient,
}

// SAFETY: same argument as Executable; the client is only used to compile
// and to host buffers, both thread-safe in the CPU plugin.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, path: &Path) -> Result<Executable> {
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {path:?}: {e}"))?;
        Ok(Executable(exe))
    }

    /// Load one model preset: train + eval executables + initial params.
    pub fn load_model(&self, meta: &ModelMeta, artifacts_dir: &Path) -> Result<Arc<Model>> {
        let train = self.compile_file(&meta.train_hlo(artifacts_dir))?;
        let eval = self.compile_file(&meta.eval_hlo(artifacts_dir))?;
        let w0 = super::read_w0(meta, artifacts_dir)?;
        Ok(Arc::new(Model { meta: meta.clone(), train, eval, w0 }))
    }
}

/// One compiled model preset.
pub struct Model {
    pub meta: ModelMeta,
    pub train: Executable,
    pub eval: Executable,
    pub w0: Vec<f32>,
}

/// Per-thread reusable input literals + host-side output buffers.
pub struct StepIo {
    w_lit: Literal,
    dense_lit: Literal,
    pooled_lit: Literal,
    labels_lit: Literal,
    /// parameter snapshot the caller fills before `train_step`
    pub w_host: Vec<f32>,
    /// pooled embeddings [B, T, D] the caller fills before stepping
    pub pooled_host: Vec<f32>,
    /// outputs of the last `train_step`
    pub grad_w: Vec<f32>,
    pub grad_emb: Vec<f32>,
}

// SAFETY: Literal is a raw-pointer wrapper; a StepIo is owned by exactly one
// worker thread at a time (moved into the thread at spawn).
unsafe impl Send for StepIo {}

impl Model {
    pub fn new_io(&self) -> StepIo {
        let m = &self.meta;
        let f32s = |n: usize| vec![0f32; n];
        let mk = |dims: &[usize]| {
            Literal::create_from_shape(ElementType::F32.primitive_type(), dims)
        };
        StepIo {
            w_lit: mk(&[m.num_params]),
            dense_lit: mk(&[m.batch, m.num_dense]),
            pooled_lit: mk(&[m.batch, m.num_tables, m.emb_dim]),
            labels_lit: mk(&[m.batch]),
            w_host: self.w0.clone(),
            pooled_host: f32s(m.batch * m.num_tables * m.emb_dim),
            grad_w: f32s(m.num_params),
            grad_emb: f32s(m.batch * m.num_tables * m.emb_dim),
        }
    }

    fn fill_inputs(&self, io: &mut StepIo, dense: &[f32], labels: &[f32]) -> Result<()> {
        let m = &self.meta;
        debug_assert_eq!(dense.len(), m.batch * m.num_dense);
        debug_assert_eq!(labels.len(), m.batch);
        debug_assert_eq!(io.w_host.len(), m.num_params);
        io.w_lit.copy_raw_from(&io.w_host).map_err(|e| anyhow!("w: {e}"))?;
        io.dense_lit.copy_raw_from(dense).map_err(|e| anyhow!("dense: {e}"))?;
        io.pooled_lit.copy_raw_from(&io.pooled_host).map_err(|e| anyhow!("pooled: {e}"))?;
        io.labels_lit.copy_raw_from(labels).map_err(|e| anyhow!("labels: {e}"))?;
        Ok(())
    }

    /// Forward+backward on one batch. Caller fills `io.w_host` (parameter
    /// snapshot) and `io.pooled_host`; returns loss_sum and leaves gradients
    /// in `io.grad_w` / `io.grad_emb`.
    pub fn train_step(&self, io: &mut StepIo, dense: &[f32], labels: &[f32]) -> Result<f32> {
        self.fill_inputs(io, dense, labels)?;
        let args = [&io.w_lit, &io.dense_lit, &io.pooled_lit, &io.labels_lit];
        let parts = self.train.execute(&args)?;
        if parts.len() != 3 {
            bail!("train artifact returned {} outputs, want 3", parts.len());
        }
        let loss: f32 = parts[0].get_first_element().map_err(|e| anyhow!("loss: {e}"))?;
        parts[1].copy_raw_to(&mut io.grad_w).map_err(|e| anyhow!("grad_w: {e}"))?;
        parts[2].copy_raw_to(&mut io.grad_emb).map_err(|e| anyhow!("grad_emb: {e}"))?;
        Ok(loss)
    }

    /// Eval pass on one batch (no gradients).
    pub fn eval_step(&self, io: &mut StepIo, dense: &[f32], labels: &[f32]) -> Result<EvalOut> {
        self.fill_inputs(io, dense, labels)?;
        let args = [&io.w_lit, &io.dense_lit, &io.pooled_lit, &io.labels_lit];
        let parts = self.eval.execute(&args)?;
        if parts.len() != 3 {
            bail!("eval artifact returned {} outputs, want 3", parts.len());
        }
        Ok(EvalOut {
            loss_sum: parts[0].get_first_element().map_err(|e| anyhow!("{e}"))?,
            pred_sum: parts[1].get_first_element().map_err(|e| anyhow!("{e}"))?,
            label_sum: parts[2].get_first_element().map_err(|e| anyhow!("{e}"))?,
        })
    }
}
