//! Model runtime: load AOT artifacts and execute them on the hot path.
//!
//! Two interchangeable backends behind one API (`Runtime`, `Model`,
//! `StepIo`, `EvalOut`):
//!
//! - **`pjrt` feature on** (`pjrt.rs`): the real thing — HLO text is
//!   parsed and compiled through the vendored `xla` crate and every
//!   train/eval step runs on PJRT CPU. Zero Python anywhere near the
//!   request path.
//! - **`pjrt` feature off** (`stub.rs`, the default): a dependency-free
//!   stand-in with the identical surface. `load_model` still reads and
//!   validates `w0`, so every coordinator/sync/placement/net code path —
//!   and all tests that don't execute compiled steps — builds and runs
//!   without the XLA toolchain; `train_step`/`eval_step` return a clear
//!   error instead.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelMeta;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Model, Runtime, StepIo};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, Model, Runtime, StepIo};

/// Aggregates returned by one eval batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalOut {
    pub loss_sum: f32,
    pub pred_sum: f32,
    pub label_sum: f32,
}

/// Read and size-check the initial dense parameters `w0` for a preset.
pub(crate) fn read_w0(meta: &ModelMeta, artifacts_dir: &Path) -> Result<Vec<f32>> {
    let w0_path = meta.w0_bin(artifacts_dir);
    let bytes = std::fs::read(&w0_path).with_context(|| format!("reading {w0_path:?}"))?;
    if bytes.len() != meta.num_params * 4 {
        bail!("w0 size mismatch: {} bytes for P={} params", bytes.len(), meta.num_params);
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    // Tests needing real artifacts live in rust/tests/runtime_integration.rs
    // (they require `make artifacts`); only pure logic is tested here.
    use super::*;

    #[test]
    fn eval_out_is_plain_data() {
        let e = EvalOut { loss_sum: 1.0, pred_sum: 2.0, label_sum: 3.0 };
        let f = e;
        assert_eq!(e, f);
    }
}
