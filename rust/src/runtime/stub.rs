//! Dependency-free runtime stand-in (built when the `pjrt` feature is off).
//!
//! Mirrors the PJRT backend's public surface exactly. `load_model` still
//! reads and validates the preset's `w0`, so cluster construction, sync,
//! placement, and network accounting all work without the XLA toolchain;
//! only actually *executing* a compiled step is refused, with an error that
//! says how to get the real backend.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::ModelMeta;

use super::EvalOut;

const NO_PJRT: &str = "shadowsync was built without the `pjrt` feature; \
rebuild with `cargo build --features pjrt` (requires the vendored `xla` \
crate) to execute compiled artifacts";

/// Placeholder for the compiled-executable handle of the PJRT backend.
pub struct Executable;

/// The (stub) runtime — constructing it always succeeds.
pub struct Runtime;

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    pub fn platform(&self) -> String {
        "stub (built without pjrt)".to_string()
    }

    /// Load one model preset's metadata + initial params (no compilation).
    pub fn load_model(&self, meta: &ModelMeta, artifacts_dir: &Path) -> Result<Arc<Model>> {
        let w0 = super::read_w0(meta, artifacts_dir)?;
        Ok(Arc::new(Model { meta: meta.clone(), w0 }))
    }
}

/// One loaded model preset (parameters only — no executables).
pub struct Model {
    pub meta: ModelMeta,
    pub w0: Vec<f32>,
}

/// Host-side step buffers, identical to the PJRT backend's public fields.
pub struct StepIo {
    /// parameter snapshot the caller fills before `train_step`
    pub w_host: Vec<f32>,
    /// pooled embeddings [B, T, D] the caller fills before stepping
    pub pooled_host: Vec<f32>,
    /// outputs of the last `train_step`
    pub grad_w: Vec<f32>,
    pub grad_emb: Vec<f32>,
}

impl Model {
    pub fn new_io(&self) -> StepIo {
        let m = &self.meta;
        let f32s = |n: usize| vec![0f32; n];
        StepIo {
            w_host: self.w0.clone(),
            pooled_host: f32s(m.batch * m.num_tables * m.emb_dim),
            grad_w: f32s(m.num_params),
            grad_emb: f32s(m.batch * m.num_tables * m.emb_dim),
        }
    }

    pub fn train_step(&self, _io: &mut StepIo, _dense: &[f32], _labels: &[f32]) -> Result<f32> {
        bail!(NO_PJRT)
    }

    pub fn eval_step(&self, _io: &mut StepIo, _dense: &[f32], _labels: &[f32]) -> Result<EvalOut> {
        bail!(NO_PJRT)
    }
}
