//! Cost-balanced placement of parameter shards onto servers.
//!
//! The paper (§3.1) profiles embedding-lookup cost per table and solves a
//! bin-packing problem to spread load evenly across the embedding PSs (and
//! the same for sync-PS parameter shards). We implement the classic LPT
//! (longest-processing-time-first) greedy: sort items by cost descending,
//! always assign to the least-loaded bin — 4/3-optimal for makespan.
//!
//! The sharded embedding tier adds *rendezvous* (highest-random-weight)
//! hashing ([`rendezvous_pick`]): every key independently scores every
//! live server token and picks the argmax. Unlike modular hashing, when a
//! token joins only the keys whose new score wins move (to the new token,
//! from everywhere), and when a token leaves only its own keys move
//! (redistributed over the survivors) — the minimal-movement property the
//! embedding cache's placement-version invalidation relies on.

use crate::util::rng::mix3;

/// An item to place: id + profiled cost (e.g. expected lookups/sec × rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Item {
    pub id: usize,
    pub cost: f64,
}

/// Result: `assignment[item.id] = bin`, plus per-bin load.
#[derive(Debug, Clone)]
pub struct Placement {
    pub assignment: Vec<usize>,
    pub bin_load: Vec<f64>,
}

impl Placement {
    pub fn max_load(&self) -> f64 {
        self.bin_load.iter().cloned().fold(0.0, f64::max)
    }

    pub fn min_load(&self) -> f64 {
        self.bin_load.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// max/mean load ratio — 1.0 is perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        let mean = self.bin_load.iter().sum::<f64>() / self.bin_load.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            self.max_load() / mean
        }
    }
}

/// LPT greedy bin packing of `items` onto `bins` bins.
pub fn lpt(items: &[Item], bins: usize) -> Placement {
    assert!(bins > 0, "need at least one bin");
    let max_id = items.iter().map(|i| i.id).max().map_or(0, |m| m + 1);
    let mut assignment = vec![usize::MAX; max_id];
    let mut bin_load = vec![0f64; bins];
    let mut order: Vec<&Item> = items.iter().collect();
    // total_cmp: a NaN cost (e.g. a degenerate 0/0 profile ratio) must
    // never panic the planner — NaNs sort deterministically instead
    order.sort_by(|a, b| b.cost.total_cmp(&a.cost).then(a.id.cmp(&b.id)));
    for it in order {
        let (best, _) = bin_load
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.total_cmp(b))
            .unwrap();
        assignment[it.id] = best;
        bin_load[best] += it.cost;
    }
    Placement { assignment, bin_load }
}

/// Rendezvous (highest-random-weight) pick: score every token against the
/// key with the repo's deterministic mixer and return the *index into
/// `tokens`* of the winner. Scores depend only on `(seed, key, token)`, so
/// adding a token moves exactly the keys the new token wins, and removing
/// one moves exactly the removed token's keys — nothing else reshuffles.
///
/// Ties (astronomically unlikely under a 64-bit mix, but the planner must
/// be total) break toward the smaller token value, which is itself
/// deterministic across any reordering of `tokens`.
pub fn rendezvous_pick(seed: u64, key: u64, tokens: &[u64]) -> usize {
    assert!(!tokens.is_empty(), "rendezvous over an empty token set");
    let mut best = 0usize;
    let mut best_score = (mix3(seed, key, tokens[0]), !tokens[0]);
    for (i, &tok) in tokens.iter().enumerate().skip(1) {
        let score = (mix3(seed, key, tok), !tok);
        if score > best_score {
            best = i;
            best_score = score;
        }
    }
    best
}

/// Split a parameter vector of `len` into `shards` near-equal contiguous
/// ranges `[lo, hi)` — used to spread `w^PS` across sync PSs.
pub fn equal_ranges(len: usize, shards: usize) -> Vec<(usize, usize)> {
    assert!(shards > 0);
    let base = len / shards;
    let extra = len % shards;
    let mut out = Vec::with_capacity(shards);
    let mut lo = 0;
    for s in 0..shards {
        let sz = base + usize::from(s < extra);
        out.push((lo, lo + sz));
        lo += sz;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn lpt_balances_simple() {
        let items: Vec<Item> = [7.0, 5.0, 4.0, 3.0, 1.0]
            .iter()
            .enumerate()
            .map(|(id, &c)| Item { id, cost: c })
            .collect();
        let p = lpt(&items, 2);
        // LPT: 7 | 5,4 -> 7+3 | 9+1 -> loads {10, 10}
        assert_eq!(p.max_load(), 10.0);
        assert_eq!(p.min_load(), 10.0);
        assert!(p.assignment.iter().all(|&b| b < 2));
    }

    #[test]
    fn lpt_invariants() {
        check("lpt", 40, |g| {
            let n_items = g.usize_in(0, 40);
            let bins = g.usize_in(1, 8);
            let items: Vec<Item> = (0..n_items)
                .map(|id| Item { id, cost: g.f32_in(0.1, 10.0) as f64 })
                .collect();
            let p = lpt(&items, bins);
            // every item assigned to a valid bin
            for it in &items {
                assert!(p.assignment[it.id] < bins);
            }
            // loads add up
            let total: f64 = items.iter().map(|i| i.cost).sum();
            assert!((p.bin_load.iter().sum::<f64>() - total).abs() < 1e-9 * (1.0 + total));
            // LPT guarantee: makespan <= 4/3 OPT + largest; OPT >= total/bins
            if n_items > 0 {
                let largest = items.iter().map(|i| i.cost).fold(0.0, f64::max);
                assert!(p.max_load() <= (4.0 / 3.0) * (total / bins as f64) + largest + 1e-9);
            }
        });
    }

    #[test]
    fn degenerate_costs_never_panic() {
        // all-zero cost table (an unprofiled cluster) plus a NaN cost (a
        // 0/0 profile ratio): the planner must still assign every item
        let items = vec![
            Item { id: 0, cost: 0.0 },
            Item { id: 1, cost: f64::NAN },
            Item { id: 2, cost: 0.0 },
            Item { id: 3, cost: 5.0 },
        ];
        let p = lpt(&items, 3);
        for it in &items {
            assert!(p.assignment[it.id] < 3, "item {} unassigned", it.id);
        }
        // the finite work still lands somewhere with finite load
        assert!(p.bin_load.iter().any(|l| *l == 5.0));

        let zeros: Vec<Item> = (0..6).map(|id| Item { id, cost: 0.0 }).collect();
        let pz = lpt(&zeros, 2);
        assert!(pz.assignment.iter().all(|&b| b < 2));
        assert_eq!(pz.max_load(), 0.0);
        assert_eq!(pz.imbalance(), 1.0);
    }

    #[test]
    fn rendezvous_is_deterministic_and_total() {
        let tokens = [3u64, 11, 42, 7];
        for key in 0..200u64 {
            let a = rendezvous_pick(9, key, &tokens);
            let b = rendezvous_pick(9, key, &tokens);
            assert_eq!(a, b);
            assert!(a < tokens.len());
        }
        // the pick follows the token, not its position: any permutation of
        // the token set selects the same winning *token value*
        let perm = [42u64, 7, 3, 11];
        for key in 0..200u64 {
            let w1 = tokens[rendezvous_pick(9, key, &tokens)];
            let w2 = perm[rendezvous_pick(9, key, &perm)];
            assert_eq!(w1, w2, "key {key}");
        }
    }

    #[test]
    fn rendezvous_add_moves_keys_only_to_the_new_token() {
        check("rendezvous-add", 25, |g| {
            let n = g.usize_in(1, 6);
            let seed = g.rng.next_u64();
            let tokens: Vec<u64> = (0..n as u64).map(|i| i * 7 + 1).collect();
            let mut grown = tokens.clone();
            let newcomer = 1000 + g.rng.below(1000);
            grown.push(newcomer);
            let mut moved = 0usize;
            for key in 0..500u64 {
                let before = tokens[rendezvous_pick(seed, key, &tokens)];
                let after = grown[rendezvous_pick(seed, key, &grown)];
                if before != after {
                    assert_eq!(
                        after, newcomer,
                        "key {key} moved between two surviving tokens"
                    );
                    moved += 1;
                }
            }
            // the newcomer wins roughly 1/(n+1) of the keyspace
            assert!(moved < 500, "the new token must not capture everything");
        });
    }

    #[test]
    fn rendezvous_remove_moves_only_the_departed_tokens_keys() {
        check("rendezvous-remove", 25, |g| {
            let n = g.usize_in(2, 7);
            let seed = g.rng.next_u64();
            let tokens: Vec<u64> = (0..n as u64).map(|i| i * 13 + 5).collect();
            let gone = tokens[g.usize_in(0, n - 1)];
            let survivors: Vec<u64> =
                tokens.iter().copied().filter(|&t| t != gone).collect();
            for key in 0..500u64 {
                let before = tokens[rendezvous_pick(seed, key, &tokens)];
                let after = survivors[rendezvous_pick(seed, key, &survivors)];
                if before != gone {
                    assert_eq!(before, after, "key {key} moved although its token survived");
                }
            }
        });
    }

    #[test]
    fn rendezvous_spreads_keys_across_tokens() {
        let tokens: Vec<u64> = (0..4u64).collect();
        let mut counts = [0usize; 4];
        for key in 0..4000u64 {
            counts[rendezvous_pick(0xE0B, key, &tokens)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (500..=1500).contains(&c),
                "token {i} owns {c}/4000 keys — far from uniform"
            );
        }
    }

    #[test]
    fn equal_ranges_partition() {
        check("ranges", 40, |g| {
            let len = g.usize_in(0, 1000);
            let shards = g.usize_in(1, 9);
            let rs = equal_ranges(len, shards);
            assert_eq!(rs.len(), shards);
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[shards - 1].1, len);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0); // contiguous
            }
            let sizes: Vec<usize> = rs.iter().map(|(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1); // near-equal
        });
    }
}
