//! Self-tests for the bounded model checker. These run under the normal test
//! config (the `mc` module is always compiled), so tier-1 CI validates the
//! engine that the `--cfg shadowsync_loom` protocol models rely on.

use std::collections::HashSet;
use std::sync::atomic::Ordering::{Acquire, Relaxed, Release, SeqCst};
use std::sync::Arc;
use std::sync::Mutex as StdMutex;

use super::thread;
use super::{model, model_finds_bug, AtomicU64, Condvar, Model, Mutex};

/// Both schedules of a store/load pair are explored: the reader observes the
/// old *and* the new value across executions.
#[test]
fn explores_both_orders() {
    let seen: Arc<StdMutex<HashSet<u64>>> = Arc::new(StdMutex::new(HashSet::new()));
    let seen2 = Arc::clone(&seen);
    let stats = Model::new().preemptions(4).check(move || {
        let x = Arc::new(AtomicU64::new(0));
        let x2 = Arc::clone(&x);
        let t = thread::spawn(move || x2.store(1, SeqCst));
        let observed = x.load(SeqCst);
        t.join().unwrap();
        seen2.lock().unwrap().insert(observed);
    });
    assert!(stats.executions >= 2, "expected multiple executions");
    let seen = seen.lock().unwrap();
    assert!(seen.contains(&0) && seen.contains(&1), "saw {seen:?}");
}

/// A load/store increment pair is racy; the model must find the lost update.
#[test]
fn finds_lost_update() {
    assert!(model_finds_bug(|| {
        let x = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let x = Arc::clone(&x);
            handles.push(thread::spawn(move || {
                let v = x.load(SeqCst);
                x.store(v + 1, SeqCst);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(x.load(SeqCst), 2, "lost update");
    }));
}

/// The same increment via an atomic RMW can never lose an update.
#[test]
fn rmw_increment_is_sound() {
    model(|| {
        let x = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let x = Arc::clone(&x);
            handles.push(thread::spawn(move || {
                x.fetch_add(1, SeqCst);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(x.load(SeqCst), 2);
    });
}

/// Message passing with a `Release` flag store: whenever the flag is
/// observed, the payload written before it must be visible too.
#[test]
fn message_passing_release_holds() {
    model(|| {
        let data = Arc::new(AtomicU64::new(0));
        let flag = Arc::new(AtomicU64::new(0));
        let (d2, f2) = (Arc::clone(&data), Arc::clone(&flag));
        let t = thread::spawn(move || {
            d2.store(1, Relaxed);
            f2.store(1, Release);
        });
        if flag.load(Acquire) == 1 {
            assert_eq!(data.load(Acquire), 1, "flag visible before payload");
        }
        t.join().unwrap();
    });
}

/// Weakening the flag store to `Relaxed` lets the store buffer publish the
/// flag before the payload — the model must catch it. This is the engine-level
/// twin of the protocol mutation checks in `tests/loom_models.rs`.
#[test]
fn message_passing_relaxed_caught() {
    assert!(model_finds_bug(|| {
        let data = Arc::new(AtomicU64::new(0));
        let flag = Arc::new(AtomicU64::new(0));
        let (d2, f2) = (Arc::clone(&data), Arc::clone(&flag));
        let t = thread::spawn(move || {
            d2.store(1, Relaxed);
            f2.store(1, Relaxed);
        });
        if flag.load(Acquire) == 1 {
            assert_eq!(data.load(Acquire), 1, "flag visible before payload");
        }
        t.join().unwrap();
    }));
}

/// A `Relaxed` RMW preserves per-location coherence with the thread's own
/// earlier buffered store (but publishes nothing else).
#[test]
fn relaxed_rmw_is_self_coherent() {
    model(|| {
        let x = Arc::new(AtomicU64::new(0));
        let x2 = Arc::clone(&x);
        let t = thread::spawn(move || {
            x2.store(5, Relaxed);
            x2.fetch_add(1, Relaxed);
        });
        t.join().unwrap();
        assert_eq!(x.load(Acquire), 6);
    });
}

/// Non-atomic data behind the modeled mutex is never corrupted.
#[test]
fn mutex_provides_exclusion() {
    model(|| {
        let m = Arc::new(Mutex::new(0u64));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let m = Arc::clone(&m);
            handles.push(thread::spawn(move || {
                let mut g = m.lock().unwrap();
                let v = *g;
                thread::yield_now();
                *g = v + 1;
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*m.lock().unwrap(), 2);
    });
}

/// Classic AB-BA lock inversion: the model must report the deadlock.
#[test]
fn detects_abba_deadlock() {
    assert!(model_finds_bug(|| {
        let a = Arc::new(Mutex::new(()));
        let b = Arc::new(Mutex::new(()));
        let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
        let t = thread::spawn(move || {
            let _ga = a2.lock().unwrap();
            let _gb = b2.lock().unwrap();
        });
        {
            let _gb = b.lock().unwrap();
            let _ga = a.lock().unwrap();
        }
        t.join().unwrap();
    }));
}

/// Condvar handoff terminates under every schedule (no lost wakeups when the
/// predicate is checked under the mutex).
#[test]
fn condvar_handoff_terminates() {
    model(|| {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let t = thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut g = m.lock().unwrap();
            *g = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut g = m.lock().unwrap();
        while !*g {
            g = cv.wait(g).unwrap();
        }
        drop(g);
        t.join().unwrap();
    });
}

/// A spin loop that yields makes progress under the preemption bound instead
/// of livelocking or blowing the step budget.
#[test]
fn yielding_spin_terminates() {
    model(|| {
        let flag = Arc::new(AtomicU64::new(0));
        let f2 = Arc::clone(&flag);
        let t = thread::spawn(move || f2.store(1, SeqCst));
        while flag.load(SeqCst) == 0 {
            thread::yield_now();
        }
        t.join().unwrap();
    });
}

/// `join` has acquire semantics: the child's buffered stores are visible
/// after it is reaped.
#[test]
fn join_publishes_child_stores() {
    model(|| {
        let x = Arc::new(AtomicU64::new(0));
        let x2 = Arc::clone(&x);
        let t = thread::spawn(move || x2.store(7, Relaxed));
        t.join().unwrap();
        assert_eq!(x.load(Relaxed), 7);
    });
}
