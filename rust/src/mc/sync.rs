//! Modeled `Mutex`, `Condvar`, and `RwLock`.
//!
//! Lock acquisition, release, and condvar wait/notify are schedule points;
//! every release publishes the thread's store buffer (release semantics).
//! Blocked threads are re-attempted, not queued: a release wakes every waiter
//! and the scheduler explores all acquisition orders. Guards expose the
//! protected data through an `UnsafeCell`; exclusivity is enforced by the
//! modeled lock state, and poisoning is never reported (a modeled panic aborts
//! the whole execution instead).

use std::cell::UnsafeCell;
use std::marker::PhantomData;
use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, TryLockError, TryLockResult};

use super::{op, Blocked, IdCell, Step};

/// Modeled counterpart of `std::sync::Mutex`.
pub struct Mutex<T> {
    id: IdCell,
    data: UnsafeCell<T>,
}

// SAFETY: the modeled lock grants at most one live guard at a time (the
// scheduler serializes every lock/unlock under the engine lock), so access to
// the `UnsafeCell` contents is exclusive exactly as for `std::sync::Mutex`.
unsafe impl<T: Send> Send for Mutex<T> {}
// SAFETY: see above — `&Mutex<T>` only hands out data access through the
// modeled lock, mirroring `std::sync::Mutex`'s `Sync` bound.
unsafe impl<T: Send> Sync for Mutex<T> {}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

impl<T> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("mc::Mutex")
    }
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Self {
        Self {
            id: IdCell::new(),
            data: UnsafeCell::new(t),
        }
    }

    fn mid(&self, st: &mut super::ExecState) -> usize {
        self.id.resolve(st, |st| st.register_mutex())
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        op(|st, tid| {
            let mid = self.mid(st);
            if st.mutexes[mid].held_by.is_none() {
                st.mutexes[mid].held_by = Some(tid);
                Step::Done(())
            } else {
                Step::Block(Blocked::Mutex(mid))
            }
        });
        Ok(MutexGuard {
            mutex: self,
            _not_send: PhantomData,
        })
    }

    pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
        let acquired = op(|st, tid| {
            let mid = self.mid(st);
            if st.mutexes[mid].held_by.is_none() {
                st.mutexes[mid].held_by = Some(tid);
                Step::Done(true)
            } else {
                Step::Done(false)
            }
        });
        if acquired {
            Ok(MutexGuard {
                mutex: self,
                _not_send: PhantomData,
            })
        } else {
            Err(TryLockError::WouldBlock)
        }
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        Ok(self.data.get_mut())
    }

    pub fn into_inner(self) -> LockResult<T> {
        Ok(self.data.into_inner())
    }
}

/// Guard for [`Mutex`]; dropping it is the unlock schedule point.
pub struct MutexGuard<'a, T> {
    mutex: &'a Mutex<T>,
    /// Like `std::sync::MutexGuard`, not `Send`: the unlock must happen on
    /// the acquiring modeled thread.
    _not_send: PhantomData<*mut T>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        // SAFETY: this guard proves the modeled lock is held by the current
        // thread, so no other thread can obtain a reference concurrently.
        unsafe { &*self.mutex.data.get() }
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: as for `deref` — the modeled lock is held, making this the
        // only live reference to the contents.
        unsafe { &mut *self.mutex.data.get() }
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        op(|st, tid| {
            let mid = self.mutex.mid(st);
            debug_assert_eq!(st.mutexes[mid].held_by, Some(tid), "unlock by non-owner");
            st.mutexes[mid].held_by = None;
            st.flush_all(tid);
            st.wake(|b| b == Blocked::Mutex(mid));
            Step::Done(())
        })
    }
}

/// Modeled counterpart of `std::sync::WaitTimeoutResult`: the model has no
/// clock, so [`Condvar::wait_timeout`] never times out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    /// Always `false` under the model checker (waits only end by notify).
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Modeled counterpart of `std::sync::Condvar`. No spurious wakeups are
/// generated (a sound under-approximation; all call sites re-check their
/// predicate in a loop regardless).
#[derive(Default)]
pub struct Condvar {
    id: IdCell,
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("mc::Condvar")
    }
}

impl Condvar {
    pub const fn new() -> Self {
        Self { id: IdCell::new() }
    }

    fn cid(&self, st: &mut super::ExecState) -> usize {
        self.id.resolve(st, |st| st.register_condvar())
    }

    /// Atomically release the guard's mutex and park until notified, then
    /// reacquire. The release publishes the store buffer.
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let mutex = guard.mutex;
        // The release happens inside the wait op below, not via Drop.
        std::mem::forget(guard);
        let mut parked = false;
        op(|st, tid| {
            let cid = self.cid(st);
            if parked {
                return Step::Done(());
            }
            parked = true;
            let mid = mutex.mid(st);
            debug_assert_eq!(st.mutexes[mid].held_by, Some(tid), "wait without lock");
            st.mutexes[mid].held_by = None;
            st.flush_all(tid);
            st.wake(|b| b == Blocked::Mutex(mid));
            Step::Block(Blocked::Condvar(cid))
        });
        mutex.lock()
    }

    /// Modeled `wait_timeout`: the model has no clock, so this is exactly
    /// [`Condvar::wait`] and the returned [`WaitTimeoutResult`] never
    /// reports a timeout. That is a sound under-approximation for the
    /// fabric's timeout paths (round-timeout eviction, watchdog departs):
    /// they only *add* transitions that the untimed model also reaches via
    /// an explicit `leave()`/`depart()` call, and every call site re-checks
    /// its predicate in a loop regardless.
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        _dur: std::time::Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match self.wait(guard) {
            Ok(g) => Ok((g, WaitTimeoutResult(false))),
            Err(_) => unreachable!("mc mutexes are never poisoned"),
        }
    }

    pub fn notify_all(&self) {
        op(|st, _tid| {
            let cid = self.cid(st);
            st.wake(|b| b == Blocked::Condvar(cid));
            Step::Done(())
        })
    }

    pub fn notify_one(&self) {
        op(|st, _tid| {
            let cid = self.cid(st);
            st.wake_one(|b| b == Blocked::Condvar(cid));
            Step::Done(())
        })
    }
}

/// Modeled counterpart of `std::sync::RwLock`.
pub struct RwLock<T> {
    id: IdCell,
    data: UnsafeCell<T>,
}

// SAFETY: readers hold shared access and the single writer holds exclusive
// access, enforced by the modeled reader/writer counts — the same contract
// that makes `std::sync::RwLock<T: Send + Sync>` Sync.
unsafe impl<T: Send> Send for RwLock<T> {}
// SAFETY: see above.
unsafe impl<T: Send + Sync> Sync for RwLock<T> {}

impl<T> std::fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("mc::RwLock")
    }
}

impl<T: Default> Default for RwLock<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

impl<T> RwLock<T> {
    pub const fn new(t: T) -> Self {
        Self {
            id: IdCell::new(),
            data: UnsafeCell::new(t),
        }
    }

    fn rid(&self, st: &mut super::ExecState) -> usize {
        self.id.resolve(st, |st| st.register_rwlock())
    }

    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        op(|st, _tid| {
            let rid = self.rid(st);
            if st.rwlocks[rid].writer.is_none() {
                st.rwlocks[rid].readers += 1;
                Step::Done(())
            } else {
                Step::Block(Blocked::RwLock(rid))
            }
        });
        Ok(RwLockReadGuard {
            lock: self,
            _not_send: PhantomData,
        })
    }

    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        op(|st, tid| {
            let rid = self.rid(st);
            if st.rwlocks[rid].writer.is_none() && st.rwlocks[rid].readers == 0 {
                st.rwlocks[rid].writer = Some(tid);
                Step::Done(())
            } else {
                Step::Block(Blocked::RwLock(rid))
            }
        });
        Ok(RwLockWriteGuard {
            lock: self,
            _not_send: PhantomData,
        })
    }
}

/// Shared guard for [`RwLock`].
pub struct RwLockReadGuard<'a, T> {
    lock: &'a RwLock<T>,
    _not_send: PhantomData<*mut T>,
}

impl<T> Deref for RwLockReadGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        // SAFETY: a live read guard excludes writers, so shared access to the
        // contents is sound.
        unsafe { &*self.lock.data.get() }
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        op(|st, tid| {
            let rid = self.lock.rid(st);
            debug_assert!(st.rwlocks[rid].readers > 0);
            st.rwlocks[rid].readers -= 1;
            st.flush_all(tid);
            st.wake(|b| b == Blocked::RwLock(rid));
            Step::Done(())
        })
    }
}

/// Exclusive guard for [`RwLock`].
pub struct RwLockWriteGuard<'a, T> {
    lock: &'a RwLock<T>,
    _not_send: PhantomData<*mut T>,
}

impl<T> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        // SAFETY: a live write guard excludes all other readers and writers.
        unsafe { &*self.lock.data.get() }
    }
}

impl<T> DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: as for `deref` — exclusive access is held.
        unsafe { &mut *self.lock.data.get() }
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        op(|st, tid| {
            let rid = self.lock.rid(st);
            debug_assert_eq!(st.rwlocks[rid].writer, Some(tid));
            st.rwlocks[rid].writer = None;
            st.flush_all(tid);
            st.wake(|b| b == Blocked::RwLock(rid));
            Step::Done(())
        })
    }
}
