//! A dependency-free bounded model checker for the shadow-sync concurrency
//! primitives — the engine behind the `--cfg shadowsync_loom` test config.
//!
//! # Why this exists
//!
//! The repo's correctness claims (bit-identical means under churn, exact byte
//! accounting, deadlock-free repartition cutover) rest on hand-rolled lock-free
//! protocols. Seeded stress tests *sample* schedules; this module *enumerates*
//! them. The build environment is fully offline (see `util`), so instead of the
//! `loom` crate this is a small in-tree checker with a loom-shaped API:
//! [`model`] runs a closure under every distinguishable interleaving (up to a
//! preemption bound), and [`model_finds_bug`] asserts that at least one
//! interleaving panics — used by the mutation checks that deliberately weaken a
//! fence and prove the model would have caught it.
//!
//! # Execution model
//!
//! Each modeled thread is a real OS thread, but a central scheduler grants
//! exactly one of them a turn at a time. Every primitive operation (atomic
//! access, mutex lock/unlock, condvar wait/notify, spawn/join/exit) is a
//! *schedule point*: the thread parks until the scheduler picks it. The
//! scheduler records its decision sequence and explores alternatives by
//! depth-first replay: rerun the prefix, branch at the deepest decision with an
//! unexplored alternative.
//!
//! # Memory model: PSO store buffers
//!
//! `Relaxed` stores do not become globally visible immediately. Each
//! `(thread, atomic)` pair has a single pending-store slot (a later `Relaxed`
//! store by the same thread overwrites it). The owner reads its own pending
//! value; other threads read the last flushed value. Pending stores flush:
//!
//! * individually, as explicit scheduler decisions (modeling an arbitrary
//!   store-buffer drain — this is what makes store-store reordering
//!   observable);
//! * all at once, on any `Release`/`SeqCst` store, non-`Relaxed` RMW, mutex or
//!   rwlock unlock, condvar wait, spawn, or thread exit (release semantics);
//! * for the *same atomic only*, on a `Relaxed` RMW (per-location coherence —
//!   crucially this does **not** publish earlier stores to other locations,
//!   which is exactly why weakening a bump-after-write from `Release` to
//!   `Relaxed` becomes an observable model failure).
//!
//! The model is a sound *under-approximation* of C11: every execution it
//! explores is a legal execution of the real program (so a reported failure is
//! a real bug), but it does not model load-side staleness beyond store
//! buffers, treats `SeqCst` as `Release`+`Acquire`, models
//! `compare_exchange_weak` as strong, and does not generate spurious condvar
//! wakeups. See `docs/CONCURRENCY.md` for the full fidelity notes.
//!
//! # Bounds
//!
//! Exploration is bounded by a preemption budget (`LOOM_MAX_PREEMPTIONS`, the
//! same knob loom uses; default 2), a per-run execution cap
//! (`SHADOWSYNC_MC_MAX_EXECS`), and a per-execution step cap
//! (`SHADOWSYNC_MC_MAX_STEPS`). Store-buffer flushes never count against the
//! preemption budget. [`thread::yield_now`](crate::mc::thread::yield_now)
//! resets the "preferred thread" so spin loops that yield cannot livelock the
//! bounded scheduler.

use std::cell::RefCell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64 as StdAtomicU64, Ordering as StdOrdering};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex};

pub mod atomic;
pub mod sync;
pub mod thread;

pub use atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};
pub use sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Global execution counter; used to lazily (re-)bind primitive objects to the
/// per-execution state tables (an object created in one execution and reused
/// in the next re-registers with its initial value).
static EXEC_EPOCH: StdAtomicU64 = StdAtomicU64::new(1);

/// Why a thread is not schedulable.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Blocked {
    /// Waiting to acquire the mutex with this id.
    Mutex(usize),
    /// Parked on the condvar with this id (until a notify).
    Condvar(usize),
    /// Waiting to acquire the rwlock with this id.
    RwLock(usize),
    /// Waiting for the thread with this id to finish.
    Join(usize),
    /// Yielded (`yield_now`/`sleep`): not schedulable again until some other
    /// thread is stepped — loom's rule, which keeps spin loops that yield
    /// from generating unbounded interleavings under DFS.
    Yield,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum ThreadState {
    /// Executing non-primitive code; the scheduler waits for it to quiesce.
    Running,
    /// Parked at a schedule point, eligible to be stepped.
    AtPoint,
    /// Parked, not eligible until another thread's action wakes it.
    Blocked(Blocked),
    Finished,
}

/// One modeled atomic cell. Values are widened to `u64`.
pub(crate) struct Atom {
    pub value: u64,
    /// Pending `Relaxed` stores: at most one `(thread, value)` slot per thread.
    pub pending: Vec<(usize, u64)>,
}

#[derive(Default)]
pub(crate) struct MutexSt {
    pub held_by: Option<usize>,
}

#[derive(Default)]
pub(crate) struct RwSt {
    pub readers: usize,
    pub writer: Option<usize>,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Action {
    /// Make thread `tid`'s pending store on atom `aid` globally visible.
    Flush { tid: usize, aid: usize },
    /// Grant thread `tid` its next primitive operation.
    Step(usize),
}

#[derive(Clone, Debug)]
pub(crate) enum Abort {
    /// A modeled-program defect: an assertion/panic in user code or a deadlock.
    Bug(String),
    /// An engine/bounds problem: replay divergence or a blown step budget.
    Fatal(String),
}

pub(crate) struct ExecState {
    pub epoch: u64,
    current: Option<usize>,
    threads: Vec<ThreadState>,
    names: Vec<String>,
    os_handles: Vec<Option<std::thread::JoinHandle<()>>>,
    pub atoms: Vec<Atom>,
    pub mutexes: Vec<MutexSt>,
    pub rwlocks: Vec<RwSt>,
    pub condvars: usize,
    schedule: Vec<usize>,
    alt_counts: Vec<usize>,
    pos: usize,
    last_thread: Option<usize>,
    preemptions: usize,
    max_preemptions: usize,
    steps: u64,
    max_steps: u64,
    abort: Option<Abort>,
}

impl ExecState {
    pub fn register_atom(&mut self, init: u64) -> usize {
        self.atoms.push(Atom { value: init, pending: Vec::new() });
        self.atoms.len() - 1
    }

    pub fn register_mutex(&mut self) -> usize {
        self.mutexes.push(MutexSt::default());
        self.mutexes.len() - 1
    }

    pub fn register_rwlock(&mut self) -> usize {
        self.rwlocks.push(RwSt::default());
        self.rwlocks.len() - 1
    }

    pub fn register_condvar(&mut self) -> usize {
        self.condvars += 1;
        self.condvars - 1
    }

    fn register_thread(&mut self, name: String) -> usize {
        self.threads.push(ThreadState::Running);
        self.names.push(name);
        self.os_handles.push(None);
        self.threads.len() - 1
    }

    pub fn thread_finished(&self, tid: usize) -> bool {
        self.threads[tid] == ThreadState::Finished
    }

    /// Wake every thread whose blocked reason satisfies `pred` (they become
    /// schedulable again and will re-attempt their operation when stepped).
    pub fn wake(&mut self, pred: impl Fn(Blocked) -> bool) {
        for st in &mut self.threads {
            if let ThreadState::Blocked(b) = *st {
                if pred(b) {
                    *st = ThreadState::AtPoint;
                }
            }
        }
    }

    /// Wake the lowest-tid thread whose blocked reason satisfies `pred`.
    pub fn wake_one(&mut self, pred: impl Fn(Blocked) -> bool) {
        for st in &mut self.threads {
            if let ThreadState::Blocked(b) = *st {
                if pred(b) {
                    *st = ThreadState::AtPoint;
                    return;
                }
            }
        }
    }

    /// Drain thread `tid`'s store buffer: every pending store becomes globally
    /// visible. Release semantics — everything the thread wrote before this
    /// point is published together.
    pub fn flush_all(&mut self, tid: usize) {
        for atom in &mut self.atoms {
            if let Some(i) = atom.pending.iter().position(|&(t, _)| t == tid) {
                atom.value = atom.pending.remove(i).1;
            }
        }
    }

    /// Flush thread `tid`'s pending store on one atom only (per-location
    /// coherence, as forced by a `Relaxed` RMW on that atom).
    pub fn flush_own(&mut self, tid: usize, aid: usize) {
        let atom = &mut self.atoms[aid];
        if let Some(i) = atom.pending.iter().position(|&(t, _)| t == tid) {
            atom.value = atom.pending.remove(i).1;
        }
    }

    /// Value of `aid` as seen by `tid`: its own pending store if any, else the
    /// last globally flushed value.
    pub fn atom_load(&self, aid: usize, tid: usize) -> u64 {
        let atom = &self.atoms[aid];
        match atom.pending.iter().find(|&&(t, _)| t == tid) {
            Some(&(_, v)) => v,
            None => atom.value,
        }
    }

    pub fn atom_store(&mut self, aid: usize, tid: usize, v: u64, ord: StdOrdering) {
        if ord == StdOrdering::Relaxed {
            let atom = &mut self.atoms[aid];
            match atom.pending.iter_mut().find(|p| p.0 == tid) {
                Some(slot) => slot.1 = v,
                None => atom.pending.push((tid, v)),
            }
        } else {
            self.flush_all(tid);
            self.atoms[aid].value = v;
        }
    }

    /// Atomic read-modify-write; returns the previous value.
    pub fn atom_rmw(
        &mut self,
        aid: usize,
        tid: usize,
        ord: StdOrdering,
        f: impl FnOnce(u64) -> u64,
    ) -> u64 {
        if ord == StdOrdering::Relaxed {
            self.flush_own(tid, aid);
        } else {
            self.flush_all(tid);
        }
        let old = self.atoms[aid].value;
        self.atoms[aid].value = f(old);
        old
    }

    pub fn atom_cas(
        &mut self,
        aid: usize,
        tid: usize,
        expect: u64,
        new: u64,
        ord: StdOrdering,
    ) -> Result<u64, u64> {
        if ord == StdOrdering::Relaxed {
            self.flush_own(tid, aid);
        } else {
            self.flush_all(tid);
        }
        let old = self.atoms[aid].value;
        if old == expect {
            self.atoms[aid].value = new;
            Ok(old)
        } else {
            Err(old)
        }
    }

    /// Forget which thread ran last, so the next scheduling decision is not a
    /// preemption no matter which thread is picked. Called by `yield_now`.
    pub fn clear_preferred(&mut self) {
        self.last_thread = None;
    }

    fn enumerate(&self) -> Vec<Action> {
        let mut acts = Vec::new();
        for (aid, atom) in self.atoms.iter().enumerate() {
            for &(tid, _) in &atom.pending {
                acts.push(Action::Flush { tid, aid });
            }
        }
        for (tid, st) in self.threads.iter().enumerate() {
            if *st == ThreadState::AtPoint {
                acts.push(Action::Step(tid));
            }
        }
        // Preemption bounding: once the budget is spent, the previously
        // running thread (if still steppable) must keep going. Flushes model
        // hardware, not the scheduler, and stay available.
        if let Some(p) = self.last_thread {
            let spent = self.preemptions >= self.max_preemptions;
            if spent && self.threads[p] == ThreadState::AtPoint {
                acts.retain(|a| !matches!(a, Action::Step(t) if *t != p));
            }
        }
        acts
    }
}

pub(crate) struct Exec {
    inner: StdMutex<ExecState>,
    cv: StdCondvar,
}

thread_local! {
    static CTX: RefCell<Option<(Arc<Exec>, usize)>> = const { RefCell::new(None) };
}

fn ctx() -> (Arc<Exec>, usize) {
    CTX.with(|c| {
        c.borrow()
            .clone()
            .expect("shadowsync mc primitive used outside mc::model (or from an unmanaged thread)")
    })
}

pub(crate) fn in_model() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

/// Sentinel panic payload used to unwind modeled threads when the execution
/// aborts; never reported as the bug itself.
struct McAbort;

pub(crate) enum Step<R> {
    Done(R),
    Block(Blocked),
}

/// Run one primitive operation at a schedule point. `f` may be re-entered: if
/// it returns [`Step::Block`], the thread parks until another thread's action
/// wakes it, then `f` runs again on the thread's next granted turn.
pub(crate) fn op<R>(mut f: impl FnMut(&mut ExecState, usize) -> Step<R>) -> R {
    let (exec, tid) = ctx();
    let mut g = exec.inner.lock().unwrap();
    g.threads[tid] = ThreadState::AtPoint;
    exec.cv.notify_all();
    loop {
        while g.current != Some(tid) && g.abort.is_none() {
            g = exec.cv.wait(g).unwrap();
        }
        if g.abort.is_some() {
            drop(g);
            panic::resume_unwind(Box::new(McAbort));
        }
        g.current = None;
        match f(&mut g, tid) {
            Step::Done(r) => {
                g.threads[tid] = ThreadState::Running;
                exec.cv.notify_all();
                return r;
            }
            Step::Block(b) => {
                g.threads[tid] = ThreadState::Blocked(b);
                exec.cv.notify_all();
            }
        }
    }
}

fn payload_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "thread panicked (non-string payload)".to_string()
    }
}

/// Terminal schedule point of a modeled thread: publish its stores, record the
/// outcome, mark it finished, and wake its joiners. Unlike [`op`] the thread
/// ends `Finished`, and an abort observed while waiting finishes it silently.
pub(crate) fn finish_thread(
    exec: &Arc<Exec>,
    tid: usize,
    panic_payload: Option<&(dyn std::any::Any + Send)>,
) {
    let mut g = exec.inner.lock().unwrap();
    g.threads[tid] = ThreadState::AtPoint;
    exec.cv.notify_all();
    while g.current != Some(tid) && g.abort.is_none() {
        g = exec.cv.wait(g).unwrap();
    }
    if g.abort.is_none() {
        g.current = None;
        if let Some(e) = panic_payload {
            if e.downcast_ref::<McAbort>().is_none() {
                let name = g.names[tid].clone();
                g.abort = Some(Abort::Bug(format!(
                    "thread '{name}' panicked: {}",
                    payload_msg(e)
                )));
            }
        }
        g.flush_all(tid);
    }
    g.threads[tid] = ThreadState::Finished;
    g.wake(|b| b == Blocked::Join(tid));
    exec.cv.notify_all();
}

/// Spawn a modeled thread. Registration is a schedule point for the parent and
/// publishes the parent's store buffer (spawn has release semantics).
pub(crate) fn spawn_managed<T: Send + 'static>(
    name: Option<String>,
    f: impl FnOnce() -> T + Send + 'static,
) -> thread::JoinHandle<T> {
    let (exec, _parent) = ctx();
    let display = name.clone().unwrap_or_else(|| "<unnamed>".to_string());
    let child = op(move |st, tid| {
        st.flush_all(tid);
        Step::Done(st.register_thread(display.clone()))
    });
    let slot = Arc::new(StdMutex::new(None));
    let slot2 = Arc::clone(&slot);
    let exec2 = Arc::clone(&exec);
    let mut builder = std::thread::Builder::new();
    if let Some(n) = name {
        builder = builder.name(n);
    }
    let os = builder
        .spawn(move || {
            CTX.with(|c| *c.borrow_mut() = Some((Arc::clone(&exec2), child)));
            let res = panic::catch_unwind(AssertUnwindSafe(f));
            match res {
                Ok(v) => {
                    *slot2.lock().unwrap() = Some(Ok(v));
                    finish_thread(&exec2, child, None);
                }
                Err(e) => {
                    finish_thread(&exec2, child, Some(e.as_ref()));
                    *slot2.lock().unwrap() = Some(Err(e));
                }
            }
        })
        .expect("mc: failed to spawn backing OS thread");
    exec.inner.lock().unwrap().os_handles[child] = Some(os);
    thread::JoinHandle::new(child, slot)
}

struct ExecOutcome {
    schedule: Vec<usize>,
    alt_counts: Vec<usize>,
    abort: Option<Abort>,
}

/// Exploration statistics returned by [`Model::check`].
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// Number of complete executions explored.
    pub executions: u64,
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Exploration configuration; see the module docs for the bounds.
#[derive(Clone, Copy, Debug)]
pub struct Model {
    max_preemptions: usize,
    max_execs: u64,
    max_steps: u64,
}

impl Default for Model {
    fn default() -> Self {
        Self::new()
    }
}

impl Model {
    /// Bounds from the environment: `LOOM_MAX_PREEMPTIONS` (default 2),
    /// `SHADOWSYNC_MC_MAX_EXECS` (default 500 000 per model),
    /// `SHADOWSYNC_MC_MAX_STEPS` (default 100 000 per execution).
    pub fn new() -> Self {
        Self {
            max_preemptions: env_u64("LOOM_MAX_PREEMPTIONS", 2) as usize,
            max_execs: env_u64("SHADOWSYNC_MC_MAX_EXECS", 500_000),
            max_steps: env_u64("SHADOWSYNC_MC_MAX_STEPS", 100_000),
        }
    }

    /// Set the preemption budget exactly (overrides the environment).
    pub fn preemptions(mut self, n: usize) -> Self {
        self.max_preemptions = n;
        self
    }

    /// Clamp the preemption budget to at most `n` (heavy models stay
    /// tractable even when the environment asks for a deeper search).
    pub fn clamp_preemptions(mut self, n: usize) -> Self {
        self.max_preemptions = self.max_preemptions.min(n);
        self
    }

    fn run_one(&self, f: Arc<dyn Fn() + Send + Sync>, schedule: Vec<usize>) -> ExecOutcome {
        let epoch = EXEC_EPOCH.fetch_add(1, StdOrdering::Relaxed);
        let exec = Arc::new(Exec {
            inner: StdMutex::new(ExecState {
                epoch,
                current: None,
                threads: Vec::new(),
                names: Vec::new(),
                os_handles: Vec::new(),
                atoms: Vec::new(),
                mutexes: Vec::new(),
                rwlocks: Vec::new(),
                condvars: 0,
                schedule,
                alt_counts: Vec::new(),
                pos: 0,
                last_thread: None,
                preemptions: 0,
                max_preemptions: self.max_preemptions,
                steps: 0,
                max_steps: self.max_steps,
                abort: None,
            }),
            cv: StdCondvar::new(),
        });

        // Thread 0 is the model closure itself; the scheduler runs here on the
        // caller's thread.
        let root = {
            let mut g = exec.inner.lock().unwrap();
            g.register_thread("model-root".to_string())
        };
        let exec2 = Arc::clone(&exec);
        let os = std::thread::Builder::new()
            .name("mc-root".to_string())
            .spawn(move || {
                CTX.with(|c| *c.borrow_mut() = Some((Arc::clone(&exec2), root)));
                let res = panic::catch_unwind(AssertUnwindSafe(|| f()));
                finish_thread(&exec2, root, res.err().as_deref());
            })
            .expect("mc: failed to spawn model root thread");
        exec.inner.lock().unwrap().os_handles[root] = Some(os);

        // Scheduler loop.
        {
            let mut g = exec.inner.lock().unwrap();
            loop {
                while g.abort.is_none()
                    && (g.current.is_some()
                        || g.threads.iter().any(|t| *t == ThreadState::Running))
                {
                    g = exec.cv.wait(g).unwrap();
                }
                if g.abort.is_some() {
                    break;
                }
                if g.threads.iter().all(|t| *t == ThreadState::Finished) {
                    break;
                }
                let mut acts = g.enumerate();
                if acts.is_empty()
                    && g.threads.iter().any(|t| *t == ThreadState::Blocked(Blocked::Yield))
                {
                    // Every other thread is stuck; yielded threads get to
                    // re-check their condition (matches real scheduling,
                    // where a yield never blocks forever).
                    g.wake(|b| b == Blocked::Yield);
                    acts = g.enumerate();
                }
                if acts.is_empty() {
                    let states: Vec<String> = g
                        .threads
                        .iter()
                        .zip(&g.names)
                        .map(|(st, n)| format!("{n}: {st:?}"))
                        .collect();
                    g.abort = Some(Abort::Bug(format!("deadlock: [{}]", states.join(", "))));
                    break;
                }
                let idx = if g.pos < g.schedule.len() {
                    let i = g.schedule[g.pos];
                    if i >= acts.len() {
                        g.abort = Some(Abort::Fatal(format!(
                            "replay divergence at step {}: index {} of {} actions \
                             (model closure is nondeterministic?)",
                            g.pos,
                            i,
                            acts.len()
                        )));
                        break;
                    }
                    i
                } else {
                    g.schedule.push(0);
                    0
                };
                g.alt_counts.push(acts.len());
                g.pos += 1;
                g.steps += 1;
                if g.steps > g.max_steps {
                    g.abort = Some(Abort::Fatal(format!(
                        "step budget ({}) exceeded — livelocked spin loop or model too \
                         large; shrink the model or raise SHADOWSYNC_MC_MAX_STEPS",
                        g.max_steps
                    )));
                    break;
                }
                match acts[idx] {
                    Action::Flush { tid, aid } => g.flush_own(tid, aid),
                    Action::Step(t) => {
                        if let Some(p) = g.last_thread {
                            if p != t && g.threads[p] == ThreadState::AtPoint {
                                g.preemptions += 1;
                            }
                        }
                        // Stepping any thread un-parks yielded peers: "some
                        // other thread has run since the yield".
                        g.wake(|b| b == Blocked::Yield);
                        g.last_thread = Some(t);
                        g.current = Some(t);
                        exec.cv.notify_all();
                    }
                }
            }
            exec.cv.notify_all();
        }

        // Unwind and reap every backing OS thread before reading the outcome.
        let handles: Vec<_> = {
            let mut g = exec.inner.lock().unwrap();
            g.os_handles.iter_mut().map(|h| h.take()).collect()
        };
        for h in handles.into_iter().flatten() {
            let _ = h.join();
        }

        let g = exec.inner.lock().unwrap();
        ExecOutcome {
            schedule: g.schedule.clone(),
            alt_counts: g.alt_counts.clone(),
            abort: g.abort.clone(),
        }
    }

    fn explore(&self, f: impl Fn() + Send + Sync + 'static) -> Result<Stats, (Abort, u64)> {
        let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
        assert!(
            !in_model(),
            "mc::model may not be nested inside another model"
        );
        let mut prefix: Vec<usize> = Vec::new();
        let mut execs: u64 = 0;
        loop {
            execs += 1;
            if execs > self.max_execs {
                panic!(
                    "mc: execution budget ({}) exhausted before the state space was \
                     covered; shrink the model or raise SHADOWSYNC_MC_MAX_EXECS",
                    self.max_execs
                );
            }
            let out = self.run_one(Arc::clone(&f), prefix.clone());
            if let Some(a) = out.abort {
                if let Abort::Fatal(msg) = &a {
                    panic!("mc: {msg}\nschedule: {:?}", out.schedule);
                }
                return Err((a, execs));
            }
            let mut branch = None;
            for i in (0..out.schedule.len()).rev() {
                if out.schedule[i] + 1 < out.alt_counts[i] {
                    branch = Some(i);
                    break;
                }
            }
            match branch {
                Some(i) => {
                    prefix = out.schedule[..i].to_vec();
                    prefix.push(out.schedule[i] + 1);
                }
                None => return Ok(Stats { executions: execs }),
            }
        }
    }

    /// Exhaustively check `f` under every schedule within the bounds; panics
    /// with the failing schedule if any interleaving panics or deadlocks.
    pub fn check(&self, f: impl Fn() + Send + Sync + 'static) -> Stats {
        match self.explore(f) {
            Ok(stats) => stats,
            Err((Abort::Bug(msg), execs)) => {
                panic!("mc model failed (execution #{execs}): {msg}")
            }
            Err((Abort::Fatal(msg), _)) => panic!("mc: {msg}"),
        }
    }

    /// Like [`Model::check`] but returns `true` when some interleaving fails
    /// (panic or deadlock) instead of panicking. Used by mutation checks to
    /// prove a deliberately weakened ordering is caught.
    pub fn check_finds_bug(&self, f: impl Fn() + Send + Sync + 'static) -> bool {
        self.explore(f).is_err()
    }
}

/// Check `f` under every interleaving with the default [`Model`] bounds.
pub fn model(f: impl Fn() + Send + Sync + 'static) {
    Model::new().check(f);
}

/// `true` when some interleaving of `f` panics or deadlocks (default bounds).
pub fn model_finds_bug(f: impl Fn() + Send + Sync + 'static) -> bool {
    Model::new().check_finds_bug(f)
}

/// Per-object lazy binding into the per-execution state tables. Packs
/// `(execution epoch, index + 1)` into one word; rebinding in a later
/// execution resets the object to its initial state, matching loom's rule
/// that modeled objects are created inside the model closure.
pub(crate) struct IdCell(StdAtomicU64);

impl Default for IdCell {
    fn default() -> Self {
        Self::new()
    }
}

impl IdCell {
    pub const fn new() -> Self {
        IdCell(StdAtomicU64::new(0))
    }

    /// Resolve this object's index in the current execution, registering it on
    /// first touch. Callers hold the engine lock (`op` closures), so the
    /// load/store pair is race-free.
    pub fn resolve(
        &self,
        st: &mut ExecState,
        register: impl FnOnce(&mut ExecState) -> usize,
    ) -> usize {
        let packed = self.0.load(StdOrdering::Relaxed);
        let (ep, idx1) = (packed >> 32, packed & 0xFFFF_FFFF);
        if ep == (st.epoch & 0xFFFF_FFFF) && idx1 != 0 {
            return (idx1 - 1) as usize;
        }
        let idx = register(st);
        self.0.store(
            ((st.epoch & 0xFFFF_FFFF) << 32) | (idx as u64 + 1),
            StdOrdering::Relaxed,
        );
        idx
    }
}

#[cfg(test)]
mod tests;
