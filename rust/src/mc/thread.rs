//! Modeled `std::thread` subset: spawn/join, `Builder`, `yield_now`, `sleep`.
//!
//! Spawned closures run on real OS threads but only make progress when the
//! scheduler grants them a turn. `join` is a blocking schedule point with
//! acquire semantics (the child's exit publishes its store buffer). `sleep`
//! has no modeled duration — it is just a schedule point, like `yield_now`.

use std::sync::{Arc, Mutex as StdMutex};
use std::time::Duration;

use super::{op, spawn_managed, Blocked, Step};

type ResultSlot<T> = Arc<StdMutex<Option<std::thread::Result<T>>>>;

/// Modeled counterpart of `std::thread::JoinHandle`.
pub struct JoinHandle<T> {
    tid: usize,
    slot: ResultSlot<T>,
}

impl<T> JoinHandle<T> {
    pub(crate) fn new(tid: usize, slot: ResultSlot<T>) -> Self {
        Self { tid, slot }
    }

    /// Park until the thread finishes; returns its result exactly as
    /// `std::thread::JoinHandle::join` does (Err on a panicked child, though
    /// in a model a child panic aborts the whole execution first).
    pub fn join(self) -> std::thread::Result<T> {
        let tid = self.tid;
        op(move |st, _me| {
            if st.thread_finished(tid) {
                Step::Done(())
            } else {
                Step::Block(Blocked::Join(tid))
            }
        });
        self.slot
            .lock()
            .unwrap()
            .take()
            .expect("mc join: thread finished without storing a result")
    }

    pub fn is_finished(&self) -> bool {
        let tid = self.tid;
        op(move |st, _me| Step::Done(st.thread_finished(tid)))
    }
}

/// Modeled counterpart of `std::thread::Builder`.
#[derive(Default)]
pub struct Builder {
    name: Option<String>,
}

impl Builder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn name(mut self, name: String) -> Self {
        self.name = Some(name);
        self
    }

    /// Never fails in the model (OS spawn errors abort the run instead).
    pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Ok(spawn_managed(self.name, f))
    }
}

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    spawn_managed(None, f)
}

/// Yield to the scheduler: the thread parks until some other thread has been
/// stepped (loom's rule). This both keeps yielding spin loops from generating
/// unbounded interleavings and prevents livelock under the preemption bound.
pub fn yield_now() {
    let mut parked = false;
    op(move |st, _tid| {
        if parked {
            return Step::Done(());
        }
        parked = true;
        st.clear_preferred();
        Step::Block(Blocked::Yield)
    })
}

/// Modeled as [`yield_now`]; durations do not exist under the model.
pub fn sleep(_dur: Duration) {
    yield_now()
}
