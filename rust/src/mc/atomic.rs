//! Modeled atomic types with PSO store-buffer semantics.
//!
//! Drop-in (subset) replacements for `std::sync::atomic::{AtomicBool,
//! AtomicU32, AtomicU64, AtomicUsize}`, swapped in by `sync::prim` under
//! `cfg(shadowsync_loom)`. Every operation is a schedule point. Values are
//! widened to `u64` internally. Orderings are interpreted as described in the
//! [`mc`](crate::mc) module docs: `Relaxed` stores sit in a per-thread store
//! buffer until flushed; everything else publishes the whole buffer.

use std::sync::atomic::Ordering;

use super::{op, IdCell, Step};

macro_rules! modeled_int_atomic {
    ($name:ident, $ty:ty) => {
        /// Modeled counterpart of the std atomic of the same name.
        pub struct $name {
            id: IdCell,
            init: u64,
        }

        impl Default for $name {
            fn default() -> Self {
                Self::new(0)
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                // Reading the value would require a model context; don't.
                f.write_str(concat!("mc::", stringify!($name)))
            }
        }

        // The identity casts for the `u64` instantiation are macro noise.
        #[allow(clippy::unnecessary_cast)]
        impl $name {
            pub const fn new(v: $ty) -> Self {
                Self {
                    id: IdCell::new(),
                    init: v as u64,
                }
            }

            fn aid(&self, st: &mut super::ExecState) -> usize {
                let init = self.init;
                self.id.resolve(st, |st| st.register_atom(init))
            }

            pub fn load(&self, _ord: Ordering) -> $ty {
                op(|st, tid| {
                    let aid = self.aid(st);
                    Step::Done(st.atom_load(aid, tid))
                }) as $ty
            }

            pub fn store(&self, v: $ty, ord: Ordering) {
                op(|st, tid| {
                    let aid = self.aid(st);
                    st.atom_store(aid, tid, v as u64, ord);
                    Step::Done(())
                })
            }

            pub fn fetch_add(&self, v: $ty, ord: Ordering) -> $ty {
                op(|st, tid| {
                    let aid = self.aid(st);
                    Step::Done(st.atom_rmw(aid, tid, ord, |cur| {
                        (cur as $ty).wrapping_add(v) as u64
                    }))
                }) as $ty
            }

            pub fn fetch_max(&self, v: $ty, ord: Ordering) -> $ty {
                op(|st, tid| {
                    let aid = self.aid(st);
                    Step::Done(st.atom_rmw(aid, tid, ord, |cur| (cur as $ty).max(v) as u64))
                }) as $ty
            }

            pub fn swap(&self, v: $ty, ord: Ordering) -> $ty {
                op(|st, tid| {
                    let aid = self.aid(st);
                    Step::Done(st.atom_rmw(aid, tid, ord, |_| v as u64))
                }) as $ty
            }

            /// The success ordering drives the store-buffer flush; modeled as
            /// always-strong (see module docs).
            pub fn compare_exchange(
                &self,
                current: $ty,
                new: $ty,
                success: Ordering,
                _failure: Ordering,
            ) -> Result<$ty, $ty> {
                op(|st, tid| {
                    let aid = self.aid(st);
                    Step::Done(st.atom_cas(aid, tid, current as u64, new as u64, success))
                })
                .map(|v| v as $ty)
                .map_err(|v| v as $ty)
            }

            /// Modeled as [`Self::compare_exchange`] (never fails spuriously;
            /// a sound under-approximation — retry loops only see a subset of
            /// real behaviors, all of which are legal).
            pub fn compare_exchange_weak(
                &self,
                current: $ty,
                new: $ty,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$ty, $ty> {
                self.compare_exchange(current, new, success, failure)
            }
        }
    };
}

modeled_int_atomic!(AtomicU32, u32);
modeled_int_atomic!(AtomicU64, u64);
modeled_int_atomic!(AtomicUsize, usize);

/// Modeled counterpart of `std::sync::atomic::AtomicBool`.
pub struct AtomicBool {
    id: IdCell,
    init: u64,
}

impl Default for AtomicBool {
    fn default() -> Self {
        Self::new(false)
    }
}

impl std::fmt::Debug for AtomicBool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("mc::AtomicBool")
    }
}

impl AtomicBool {
    pub const fn new(v: bool) -> Self {
        Self {
            id: IdCell::new(),
            init: v as u64,
        }
    }

    fn aid(&self, st: &mut super::ExecState) -> usize {
        let init = self.init;
        self.id.resolve(st, |st| st.register_atom(init))
    }

    pub fn load(&self, _ord: Ordering) -> bool {
        op(|st, tid| {
            let aid = self.aid(st);
            Step::Done(st.atom_load(aid, tid))
        }) != 0
    }

    pub fn store(&self, v: bool, ord: Ordering) {
        op(|st, tid| {
            let aid = self.aid(st);
            st.atom_store(aid, tid, v as u64, ord);
            Step::Done(())
        })
    }

    pub fn swap(&self, v: bool, ord: Ordering) -> bool {
        op(|st, tid| {
            let aid = self.aid(st);
            Step::Done(st.atom_rmw(aid, tid, ord, |_| v as u64))
        }) != 0
    }

    pub fn compare_exchange(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        _failure: Ordering,
    ) -> Result<bool, bool> {
        op(|st, tid| {
            let aid = self.aid(st);
            Step::Done(st.atom_cas(aid, tid, current as u64, new as u64, success))
        })
        .map(|v| v != 0)
        .map_err(|v| v != 0)
    }

    pub fn compare_exchange_weak(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        self.compare_exchange(current, new, success, failure)
    }
}
