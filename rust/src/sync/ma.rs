//! Model averaging synchronization (paper Algorithm 3; Zinkevich et al.).
//!
//! Decentralized: snapshot the local partition, AllReduce-mean it with the
//! other trainers over this partition's own ring fabric, then elastically
//! pull the partition toward the average. The elastic pull (rather than
//! the original MA's copy-back) is the paper's key modification: during a
//! background AllReduce the Hogwild workers keep training, and a copy-back
//! would discard that progress. Under the partitioned fabric the group is
//! sized to the partition (`SyncCtx::range`), so hybrid plans can run MA
//! on some partitions while EASGD owns others.

use anyhow::Result;

use super::prim::{thread, Arc};
use super::traffic::WireCodec;
use super::{AllReduceGroup, SyncCtx, SyncStrategy};
use crate::tensor::ops;

pub struct MaSync {
    group: Arc<AllReduceGroup>,
    pub alpha: f32,
    /// `w^global` scratch (Algorithm 3 line 5)
    global: Vec<f32>,
    /// simulated collective wall time (models the paper's "time-consuming
    /// AllReduce" window during which Hogwild workers keep training)
    round_delay: std::time::Duration,
    /// wire codec applied to this trainer's *contribution* before the
    /// collective (the group's hop accounting carries the same codec)
    codec: WireCodec,
    /// per-trainer error-feedback residual for lossy codecs, one slot per
    /// partition element
    residual: Vec<f32>,
    left: bool,
}

impl MaSync {
    pub fn new(group: Arc<AllReduceGroup>, alpha: f32, num_params: usize) -> Self {
        Self {
            group,
            alpha,
            global: vec![0.0; num_params],
            round_delay: std::time::Duration::ZERO,
            codec: WireCodec::Fp32,
            residual: Vec::new(),
            left: false,
        }
    }

    /// Model a collective that takes `d` of wall time (paper-scale wire).
    pub fn with_round_delay(mut self, d: std::time::Duration) -> Self {
        self.round_delay = d;
        self
    }

    /// Compress this trainer's contribution with `codec` before each
    /// collective, with error feedback — whatever the encode loses rides
    /// into the next round. Normally set to the owning group's codec.
    pub fn with_codec(mut self, codec: WireCodec) -> Self {
        self.codec = codec;
        if codec != WireCodec::Fp32 {
            self.residual = vec![0.0; self.global.len()];
        }
        self
    }

    /// Direct copy-back variant (original MA), used by the
    /// `ablate-elastic` experiment to show why the elastic pull matters.
    pub fn set_copy_back(&mut self) {
        self.alpha = 1.0;
    }
}

impl SyncStrategy for MaSync {
    fn sync_round(&mut self, ctx: &SyncCtx<'_>) -> Result<f32> {
        debug_assert_eq!(
            self.global.len(),
            ctx.range.len,
            "MA group must be sized to its partition"
        );
        // w_global <- copy of the local partition
        ctx.local.read_range_into(ctx.range.lo(), &mut self.global);
        // lossy codecs: the wire carries the encoded contribution — peers
        // reduce what they'd decode, and the encode error feeds back
        if self.codec != WireCodec::Fp32 {
            self.codec.encode_with_feedback(&mut self.global, &mut self.residual);
        }
        // w_global <- AllReduce(w_global) / n; workers keep training during
        // this window — exactly what copy-back (alpha=1) would throw away
        if !self.round_delay.is_zero() {
            thread::sleep(self.round_delay);
        }
        let round = self.group.allreduce_mean(&mut self.global, ctx.trainer_node, ctx.net)?;
        let gap = ops::mean_abs_diff(
            &self.global,
            &ctx.local.to_vec_range(ctx.range.lo(), ctx.range.hi()),
        );
        // w_i <- (1-alpha) w_i + alpha w_global  (elastic, not copy-back)
        ctx.local.lerp_range_toward_slice(ctx.range.lo(), &self.global, self.alpha);
        // ring traffic was driven hop-by-hop through ctx.net by the
        // collective itself; record the measured bytes this member moved
        ctx.metrics.record_sync(round.bytes_tx);
        ctx.metrics.record_partition_sync_bytes(ctx.partition, round.bytes_tx);
        Ok(gap)
    }

    fn leave(&mut self) {
        if !self.left {
            self.group.leave();
            self.left = true;
        }
    }

    fn rendezvous(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "ma"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;
    use crate::net::{Network, NodeId, Role};
    use crate::tensor::HogwildBuffer;

    fn harness(n: usize, p: usize) -> (Arc<AllReduceGroup>, Network, Vec<NodeId>) {
        let group = Arc::new(AllReduceGroup::new(n, p));
        let mut net = Network::new(None);
        let nodes = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
        (group, net, nodes)
    }

    #[test]
    fn two_trainers_average_elastically() {
        let (group, net, nodes) = harness(2, 4);
        let locals: Vec<_> = [2.0f32, 6.0]
            .iter()
            .map(|&v| Arc::new(HogwildBuffer::from_slice(&vec![v; 4])))
            .collect();
        let metrics = Metrics::new();
        std::thread::scope(|s| {
            for (i, local) in locals.iter().enumerate() {
                let group = group.clone();
                let net = &net;
                let metrics = &metrics;
                let node = nodes[i];
                s.spawn(move || {
                    let mut ma = MaSync::new(group, 0.5, 4);
                    let ctx = SyncCtx::full(local, node, net, metrics);
                    ma.sync_round(&ctx).unwrap();
                });
            }
        });
        // average = 4; each local moves halfway toward it
        assert!(locals[0].to_vec().iter().all(|&x| (x - 3.0).abs() < 1e-6));
        assert!(locals[1].to_vec().iter().all(|&x| (x - 5.0).abs() < 1e-6));
        assert_eq!(metrics.snapshot().syncs, 2);
    }

    #[test]
    fn copy_back_overwrites() {
        let (group, net, nodes) = harness(1, 2);
        let local = HogwildBuffer::from_slice(&[1.0, 3.0]);
        let metrics = Metrics::new();
        let mut ma = MaSync::new(group, 0.5, 2);
        ma.set_copy_back();
        let ctx = SyncCtx::full(&local, nodes[0], &net, &metrics);
        ma.sync_round(&ctx).unwrap();
        // singleton group: average == self, so copy-back is identity here
        assert_eq!(local.to_vec(), vec![1.0, 3.0]);
    }

    #[test]
    fn range_scoped_round_averages_only_its_partition() {
        use crate::sync::ParamRange;
        // partition [2, 6) of an 8-element replica, singleton ring: the
        // round must read/average/pull exactly that slice
        let (group, net, nodes) = harness(1, 4);
        let local = HogwildBuffer::from_slice(&[9.0, 9.0, 1.0, 2.0, 3.0, 4.0, 9.0, 9.0]);
        let metrics = Metrics::new();
        let mut ma = MaSync::new(group, 0.5, 4);
        let range = ParamRange { offset: 2, len: 4 };
        let ctx = SyncCtx {
            local: &local,
            range,
            partition: 1,
            trainer_node: nodes[0],
            net: &net,
            metrics: &metrics,
        };
        let gap = ma.sync_round(&ctx).unwrap();
        // singleton: average == own slice, so gap is 0 and nothing moves
        assert_eq!(gap, 0.0);
        assert_eq!(local.to_vec(), vec![9.0, 9.0, 1.0, 2.0, 3.0, 4.0, 9.0, 9.0]);
        assert!(ma.rendezvous());
    }

    #[test]
    fn leave_is_idempotent() {
        let (group, _, _) = harness(2, 2);
        let mut ma = MaSync::new(group.clone(), 0.5, 2);
        ma.leave();
        ma.leave();
        assert_eq!(group.active(), 1);
    }
}
