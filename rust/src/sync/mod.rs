//! Parameter synchronization — the paper's contribution.
//!
//! A [`SyncStrategy`] performs one synchronization *round* for one trainer's
//! dense-parameter replica. The same strategies run in two modes
//! ([`crate::config::SyncMode`]):
//!
//! - **Shadow** (the paper's proposal): a dedicated per-trainer *shadow
//!   thread* loops rounds continuously in the background, never stalling
//!   the Hogwild worker threads ([`driver::spawn_shadow`]).
//! - **Fixed-rate** (the baselines): the sync is executed in the foreground
//!   of the training loop every `k` iterations ([`driver::Foreground`]) —
//!   inline in each worker thread for centralized EASGD (which is why its
//!   sync-PS traffic is `m×` the shadow variant's), or stop-the-world per
//!   trainer for the AllReduce-based MA/BMUF.
//!
//! Three algorithms are provided (paper Algorithms 2–4): EASGD (centralized,
//! against sync PSs via chunked pushes with an optional delta gate —
//! [`ps::SyncPsGroup`] skips chunks that barely moved, both wire legs of a
//! skipped chunk are suppressed, the gate can adapt itself to a target skip
//! rate via a streaming quantile sketch, and dirty-epoch-tracked replicas
//! skip even the gap *scan* for untouched chunks), MA and BMUF
//! (decentralized, over the lock-striped, double-buffered chunk-parallel
//! ring-AllReduce fabric in [`allreduce`], whose parity-banked deposit
//! slots let round `N+1` contributions land while round `N` still reduces,
//! and whose per-hop transfers flow through [`Network`] so ring traffic is
//! measured per trainer NIC rather than asserted from a formula; the
//! [`traffic`] module exports that measured schedule to `sim/`). All three
//! use the *asymmetric elastic interpolation* the paper highlights as its
//! key modification: after a round, the local replica moves α of the way
//! toward the global/central model instead of being overwritten, so Hogwild
//! progress made during the (background) round isn't thrown away.

pub mod allreduce;
pub mod bmuf;
pub mod driver;
pub mod easgd;
pub mod ma;
pub mod ps;
pub mod traffic;

use anyhow::Result;

use crate::metrics::Metrics;
use crate::net::{Network, NodeId};
use crate::tensor::HogwildBuffer;

/// Everything a sync round needs from its trainer.
pub struct SyncCtx<'a> {
    /// this trainer's dense replica `w^(i)` (Hogwild-shared with workers)
    pub local: &'a HogwildBuffer,
    pub trainer_node: NodeId,
    pub net: &'a Network,
    pub metrics: &'a Metrics,
}

/// One synchronization algorithm instance, owned by whichever thread drives
/// it (shadow thread or foreground hook).
pub trait SyncStrategy: Send {
    /// Execute one synchronization round. Returns the mean |local-global|
    /// gap observed (a convergence-health signal), when meaningful.
    fn sync_round(&mut self, ctx: &SyncCtx<'_>) -> Result<f32>;

    /// Called when this trainer permanently stops syncing (end of its data
    /// shard) so decentralized groups can shrink their membership.
    fn leave(&mut self) {}

    fn name(&self) -> &'static str;
}

pub use allreduce::{AllReduceGroup, ReduceEngine, RoundOutcome};
pub use bmuf::BmufSync;
pub use easgd::EasgdSync;
pub use ma::MaSync;
pub use ps::{DeltaScanCache, PushStats, QuantileSketch, SyncPsGroup};

/// Build the shared chunked ring-AllReduce fabric for the decentralized
/// algorithms (MA, BMUF): one group over all trainers, split into
/// `cfg.allreduce_chunks` chunks so wire traffic is driven — and accounted
/// per trainer NIC — through the explicit reduce-scatter + all-gather
/// schedule, with the in-process reduction engine selected by
/// `cfg.reduce_engine` (see [`allreduce`]).
pub fn build_group(
    cfg: &crate::config::RunConfig,
    num_params: usize,
) -> std::sync::Arc<AllReduceGroup> {
    std::sync::Arc::new(
        AllReduceGroup::new(cfg.num_trainers, num_params)
            .with_chunks(cfg.allreduce_chunks)
            .with_engine(cfg.reduce_engine),
    )
}

/// Build the strategy instance for trainer `rank` from a run config.
pub fn build_strategy(
    cfg: &crate::config::RunConfig,
    num_params: usize,
    rank: usize,
    w0: &[f32],
    sync_ps: Option<std::sync::Arc<SyncPsGroup>>,
    group: Option<std::sync::Arc<AllReduceGroup>>,
) -> Result<Box<dyn SyncStrategy>> {
    use crate::config::SyncAlgo;
    let _ = rank; // ranks are implicit in-process; kept for API parity
    Ok(match cfg.algo {
        SyncAlgo::Easgd => Box::new(EasgdSync::new(
            sync_ps.expect("EASGD needs sync PSs"),
            cfg.alpha,
        )),
        SyncAlgo::Ma => Box::new(
            MaSync::new(group.expect("MA needs an AllReduce group"), cfg.alpha, num_params)
                .with_round_delay(std::time::Duration::from_millis(cfg.collective_wire_ms)),
        ),
        SyncAlgo::Bmuf => Box::new(BmufSync::new(
            group.expect("BMUF needs an AllReduce group"),
            cfg.alpha,
            cfg.bmuf_eta,
            cfg.bmuf_momentum,
            w0,
        )),
        SyncAlgo::None => Box::new(NoSync),
    })
}

/// The "independent sub-models" baseline: no synchronization at all.
pub struct NoSync;

impl SyncStrategy for NoSync {
    fn sync_round(&mut self, _ctx: &SyncCtx<'_>) -> Result<f32> {
        Ok(0.0)
    }

    fn name(&self) -> &'static str {
        "none"
    }
}
