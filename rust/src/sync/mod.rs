//! Parameter synchronization — the paper's contribution, as a
//! **partitioned shadow-sync fabric**.
//!
//! The dense replica is cut into `P` contiguous, LPT-balanced partitions
//! ([`partition::PartitionPlan`], `--sync-partitions`; `P = 1` is the
//! monolithic whole-vector fabric — bit for bit, except that adaptive
//! delta gates are now per-strategy by design, see [`partition`]). A
//! [`SyncStrategy`]
//! performs one synchronization *round* for **one partition** of one
//! trainer's replica: [`SyncCtx`] carries a [`ParamRange`] view
//! (offset/len into the [`HogwildBuffer`]) and the strategy never touches
//! parameters outside it. Each partition can run a *different* algorithm
//! (`--algo-map easgd:0-1,ma:2-3`) — the paper's §3.2 hybrid scenario:
//! EASGD partitions push to [`ps::SyncPsGroup`] sub-ranges while MA/BMUF
//! partitions reduce over their own per-partition [`AllReduceGroup`]s.
//!
//! The same strategies run in two modes ([`crate::config::SyncMode`]):
//!
//! - **Shadow** (the paper's proposal): a per-trainer *shadow pool*
//!   ([`driver::spawn_shadow_pool`], `--shadow-threads S`, `S ≤ P`) loops
//!   partition rounds continuously in the background, never stalling the
//!   Hogwild worker threads. Rendezvous strategies (MA/BMUF) are pinned to
//!   pool threads in identical order on every trainer; centralized
//!   strategies are serviced by a work-stealing round-robin, so sync
//!   frequency per partition scales with `S`.
//! - **Fixed-rate** (the baselines): the sync is executed in the
//!   foreground of the training loop every `k` iterations, over the whole
//!   vector — inline in each worker thread for centralized EASGD (which is
//!   why its sync-PS traffic is `m×` the shadow variant's), or
//!   stop-the-world per trainer for the AllReduce-based MA/BMUF
//!   ([`driver::Gate`]).
//!
//! Three algorithms are provided (paper Algorithms 2–4): EASGD
//! (centralized, against sync PSs via chunked pushes with an optional
//! delta gate — [`ps::SyncPsGroup`] skips chunks that barely moved, both
//! wire legs of a skipped chunk are suppressed, and each strategy instance
//! owns its *own* [`ps::DeltaGate`] — a per-trainer × per-partition
//! [`ps::QuantileSketch`] plus [`ps::DeltaScanCache`], so heterogeneous
//! replicas gate independently; central-side per-chunk version counters
//! invalidate a trainer's cached scan the moment *another* trainer pushes
//! that chunk), MA and BMUF (decentralized, over the lock-striped,
//! double-buffered chunk-parallel ring-AllReduce fabric in [`allreduce`],
//! whose per-hop transfers flow through [`Network`] so ring traffic is
//! measured per trainer NIC; the [`traffic`] module exports that measured
//! schedule to `sim/`, which also prices shadow rounds per partition). All
//! three use the *asymmetric elastic interpolation* the paper highlights
//! as its key modification: after a round, the local partition moves α of
//! the way toward the global/central model instead of being overwritten,
//! so Hogwild progress made during the (background) round isn't thrown
//! away.
//!
//! The fabric is **self-tuning** when `--repartition-every N` is set: a
//! shared [`repartition::RepartitionController`] accumulates measured
//! per-range write rates (dirty-epoch bump counts) and per-partition push
//! bytes, and every `N` shadow sweeps republishes the plan with a
//! cost-balanced cut — hot partitions shrink, cold ones grow — with a safe
//! live cutover in [`driver`] (quiesce at a sweep boundary, retire + leave
//! the old strategies, carry [`RepartitionCarry`] gate state across by
//! global chunk ordinal, adopt the re-sized per-partition groups).

pub mod allreduce;
pub mod bmuf;
pub mod driver;
pub mod easgd;
pub mod health;
pub mod ma;
pub mod partition;
pub mod prim;
pub mod ps;
pub mod repartition;
pub mod ring;
pub mod traffic;

use anyhow::Result;

use self::prim::Arc;
use crate::metrics::Metrics;
use crate::net::{Network, NodeId};
use crate::tensor::HogwildBuffer;

/// Everything a sync round needs from its trainer, scoped to one
/// partition of the replica.
pub struct SyncCtx<'a> {
    /// this trainer's dense replica `w^(i)` (Hogwild-shared with workers)
    pub local: &'a HogwildBuffer,
    /// the partition of the replica this round operates on
    pub range: ParamRange,
    /// index of that partition in the trainer's [`PartitionPlan`]
    /// (the per-partition metrics key)
    pub partition: usize,
    pub trainer_node: NodeId,
    pub net: &'a Network,
    pub metrics: &'a Metrics,
}

impl<'a> SyncCtx<'a> {
    /// A whole-replica context: partition 0 spanning everything. The
    /// foreground drivers and single-partition plans use exactly this.
    pub fn full(
        local: &'a HogwildBuffer,
        trainer_node: NodeId,
        net: &'a Network,
        metrics: &'a Metrics,
    ) -> Self {
        Self {
            range: ParamRange::full(local.len()),
            partition: 0,
            local,
            trainer_node,
            net,
            metrics,
        }
    }
}

/// One synchronization algorithm instance, owned by whichever thread
/// drives it (shadow pool thread or foreground hook) and bound to one
/// partition of one trainer's replica.
pub trait SyncStrategy: Send {
    /// Execute one synchronization round over `ctx.range`. Returns the
    /// mean |local-global| gap observed on the partition (a
    /// convergence-health signal), when meaningful.
    fn sync_round(&mut self, ctx: &SyncCtx<'_>) -> Result<f32>;

    /// Called when this trainer permanently stops syncing (end of its data
    /// shard) so decentralized groups can shrink their membership.
    fn leave(&mut self) {}

    /// Does a round rendezvous with the other trainers (block until every
    /// active member of a collective contributes)? The shadow pool pins
    /// rendezvous strategies to fixed threads — in identical order on
    /// every trainer — so the cross-trainer round order stays acyclic;
    /// non-rendezvous strategies are work-stolen freely.
    fn rendezvous(&self) -> bool {
        false
    }

    /// Detach whatever per-strategy state should survive an adaptive
    /// repartition cutover (see [`repartition`]). EASGD strategies hand
    /// over their delta-gate sketch and dirty-scan cache; stateless and
    /// collective strategies return `None` and are rebuilt fresh.
    fn take_repartition_carry(&mut self) -> Option<RepartitionCarry> {
        None
    }

    /// Install state carried out of the retiring strategy of the same
    /// partition index by [`SyncStrategy::take_repartition_carry`].
    fn install_repartition_carry(&mut self, _carry: RepartitionCarry) {}

    fn name(&self) -> &'static str;
}

/// Gate state an EASGD strategy hands across a repartition cutover: its
/// private [`DeltaGate`] (warmed quantile sketch) and [`DeltaScanCache`].
/// Cache entries are keyed by *global* push-chunk ordinal, so an entry
/// stays valid for any chunk whose dirty signature and central version
/// survived the cutover — wherever the new plan puts the chunk — and a
/// chunk the carrying partition never scanned simply misses and re-scans.
pub struct RepartitionCarry {
    pub cache: DeltaScanCache,
    pub gate: Option<DeltaGate>,
    /// BMUF momentum + private `w^global`, carried across a health-driven
    /// demote→promote cycle: the retiring [`BmufSync`] emits it, the interim
    /// EASGD strategy parks and re-emits it untouched, and the promoted
    /// [`BmufSync`] rehydrates it (forced rebuilds keep ranges fixed, so the
    /// carried vectors still fit their partition).
    pub bmuf: Option<bmuf::BmufCarry>,
}

pub use allreduce::{AllReduceGroup, ReduceEngine, RoundOutcome};
pub use bmuf::{BmufCarry, BmufSync};
pub use easgd::EasgdSync;
pub use health::HealthController;
pub use ma::MaSync;
pub use partition::{ParamRange, Partition, PartitionPlan};
pub use ps::{DeltaGate, DeltaScanCache, PushStats, QuantileSketch, SyncPsGroup};
pub use repartition::{PlanEpoch, RepartitionController};
pub use traffic::WireCodec;

/// Build one chunked ring-AllReduce fabric over all trainers for a
/// `num_params`-element partition (MA, BMUF): wire traffic is driven — and
/// accounted per trainer NIC — through the explicit reduce-scatter +
/// all-gather schedule, with the in-process reduction engine selected by
/// `cfg.reduce_engine` (see [`allreduce`]). The partitioned fabric builds
/// one group per decentralized partition, each sized to its range and
/// carrying that partition's wire codec (`cfg.partition_codec(partition)`),
/// so every hop of the ring moves codec-sized messages.
pub fn build_group(
    cfg: &crate::config::RunConfig,
    partition: usize,
    num_params: usize,
) -> Arc<AllReduceGroup> {
    build_group_sized(cfg, partition, cfg.num_trainers, num_params)
}

/// [`build_group`] for an explicit member count — repartition / rejoin
/// epochs size their rings to the trainers still active, not the configured
/// roster. The one place `--allreduce-timeout-ms` and the ring's wire codec
/// are wired, so every ring — initial, repartitioned, or rejoin-built —
/// degrades and compresses the same way.
pub fn build_group_sized(
    cfg: &crate::config::RunConfig,
    partition: usize,
    members: usize,
    num_params: usize,
) -> Arc<AllReduceGroup> {
    let mut g = AllReduceGroup::new(members, num_params)
        .with_chunks(cfg.allreduce_chunks)
        .with_engine(cfg.reduce_engine)
        .with_ring_depth(cfg.reduce_ring_depth)
        .with_codec(cfg.partition_codec(partition));
    if cfg.allreduce_timeout_ms > 0 {
        g = g.with_round_timeout(std::time::Duration::from_millis(cfg.allreduce_timeout_ms));
    }
    Arc::new(g)
}

/// The single place the config→gate (and config→codec) wiring lives: an
/// [`EasgdSync`] carrying its own per-instance [`DeltaGate`] whenever the
/// run is delta-gated, syncing with `cfg.partition_codec(partition)` on the
/// wire. Used for every EASGD strategy — shadow partitions and the
/// foreground per-worker plans alike — so a new gating mode or codec wired
/// here reaches them all.
pub fn easgd_from_cfg(
    cfg: &crate::config::RunConfig,
    partition: usize,
    sync_ps: Arc<SyncPsGroup>,
) -> EasgdSync {
    let mut s = EasgdSync::new(sync_ps, cfg.alpha).with_codec(cfg.partition_codec(partition));
    if cfg.delta_gated() {
        s = s.with_gate(DeltaGate::new(cfg.delta_threshold, cfg.delta_skip_target));
    }
    s
}

/// Build the strategy instance for one partition of trainer `rank`'s
/// replica. `w0` is the *full* initial dense vector (BMUF slices out its
/// partition); `group` is this partition's ring fabric (decentralized
/// algorithms only). EASGD strategies get their own per-partition
/// [`DeltaGate`] whenever the run is delta-gated, so every trainer ×
/// partition gates on its own sketch.
pub fn build_strategy(
    cfg: &crate::config::RunConfig,
    part: &Partition,
    rank: usize,
    w0: &[f32],
    sync_ps: Option<Arc<SyncPsGroup>>,
    group: Option<Arc<AllReduceGroup>>,
) -> Result<Box<dyn SyncStrategy>> {
    use crate::config::SyncAlgo;
    let _ = rank; // ranks are implicit in-process; kept for API parity
    let codec = cfg.partition_codec(part.index);
    Ok(match part.algo {
        SyncAlgo::Easgd => {
            Box::new(easgd_from_cfg(cfg, part.index, sync_ps.expect("EASGD needs sync PSs")))
        }
        SyncAlgo::Ma => Box::new(
            MaSync::new(group.expect("MA needs an AllReduce group"), cfg.alpha, part.range.len)
                .with_round_delay(std::time::Duration::from_millis(cfg.collective_wire_ms))
                .with_codec(codec),
        ),
        SyncAlgo::Bmuf => Box::new(
            BmufSync::new(
                group.expect("BMUF needs an AllReduce group"),
                cfg.alpha,
                cfg.bmuf_eta,
                cfg.bmuf_momentum,
                &w0[part.range.lo()..part.range.hi()],
            )
            .with_codec(codec),
        ),
        SyncAlgo::None => Box::new(NoSync),
    })
}

/// The "independent sub-models" baseline: no synchronization at all.
pub struct NoSync;

impl SyncStrategy for NoSync {
    fn sync_round(&mut self, _ctx: &SyncCtx<'_>) -> Result<f32> {
        Ok(0.0)
    }

    fn name(&self) -> &'static str {
        "none"
    }
}
