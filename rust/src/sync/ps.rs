//! Sync parameter servers: the centralized home of `w^PS` for EASGD.
//!
//! The central parameter vector is sharded into near-equal contiguous
//! ranges, one per sync PS (the paper load-balances these with the same
//! profiling + bin-packing as the embedding shards; ranges of a homogeneous
//! dense vector are already perfectly balanced, which is what LPT would
//! produce). Trainers sync shard-by-shard so traffic is attributed to the
//! right PS NIC — the saturation of exactly these NICs is what causes the
//! paper's FR-EASGD-5 EPS plateau (Fig. 5).
//!
//! ## Chunked, delta-gated pushes
//!
//! Each shard is pushed in chunks of [`SyncPsGroup`]'s `chunk_elems`
//! elements (0 = whole-shard pushes). With a positive `delta_threshold`, a
//! chunk whose max |local − central| is at or below the threshold is
//! *skipped entirely*: neither the trainer→PS push leg nor the PS→trainer
//! reply leg touches [`Network::transfer`], so NIC counters and
//! `metrics.sync_bytes` both see only the bytes actually moved. The
//! returned [`PushStats`] carry the measured bytes of the round, and the
//! group keeps cumulative counters ([`SyncPsGroup::traffic`]) that the
//! experiment harness feeds into the `sim/` cost model as its measured
//! EASGD push fraction.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::net::{Network, NodeId, Role};
use crate::placement::equal_ranges;
use crate::tensor::HogwildBuffer;

/// One shard: parameter range `[lo, hi)` hosted on `node`.
#[derive(Debug)]
pub struct SyncShard {
    pub lo: usize,
    pub hi: usize,
    pub node: NodeId,
}

/// What one elastic round measured and moved.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PushStats {
    /// Mean |local − central| over the *whole* vector before the move
    /// (skipped chunks contribute their scanned gap).
    pub gap: f32,
    /// Bytes actually moved through the network, both legs summed — what
    /// `metrics.sync_bytes` should record.
    pub bytes: u64,
    pub chunks_pushed: u64,
    pub chunks_skipped: u64,
}

/// Cumulative measured push traffic of a sync-PS group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PsTrafficSnapshot {
    pub rounds: u64,
    pub bytes_moved: u64,
    pub chunks_pushed: u64,
    pub chunks_skipped: u64,
    /// Bytes a full no-skip round would move (`SyncPsGroup::round_bytes`) —
    /// the denominator that turns `bytes_moved` into a scale-free fraction.
    pub full_round_bytes: u64,
}

impl PsTrafficSnapshot {
    /// Measured bytes of an average round (both legs).
    pub fn avg_round_bytes(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.bytes_moved as f64 / self.rounds as f64
        }
    }

    /// Measured *byte* fraction of the full round an average round moved —
    /// the scale-free input the `sim/` cost model uses to price delta-gated
    /// EASGD rounds (robust to uneven chunk sizes, unlike a chunk count).
    pub fn byte_fraction(&self) -> f64 {
        if self.rounds == 0 || self.full_round_bytes == 0 {
            1.0
        } else {
            (self.avg_round_bytes() / self.full_round_bytes as f64).clamp(0.0, 1.0)
        }
    }

    /// Fraction of chunks that actually moved (a skip-rate diagnostic; use
    /// [`PsTrafficSnapshot::byte_fraction`] for traffic pricing).
    pub fn push_fraction(&self) -> f64 {
        let total = self.chunks_pushed + self.chunks_skipped;
        if total == 0 {
            1.0
        } else {
            self.chunks_pushed as f64 / total as f64
        }
    }
}

/// The sync-PS tier: the central `w^PS` plus its sharding.
pub struct SyncPsGroup {
    /// central parameters, Hogwild-shared across all trainers' syncs
    pub central: HogwildBuffer,
    pub shards: Vec<SyncShard>,
    /// elements per push chunk (0 = whole-shard pushes)
    chunk_elems: usize,
    /// skip chunks whose max |local − central| is at or below this
    delta_threshold: f32,
    rounds: AtomicU64,
    bytes_moved: AtomicU64,
    chunks_pushed: AtomicU64,
    chunks_skipped: AtomicU64,
}

impl SyncPsGroup {
    /// Initialize `w^PS ← w0` across `num_ps` servers (Algorithm 1 line 3),
    /// whole-shard pushes, no delta gate.
    pub fn build(w0: &[f32], num_ps: usize, net: &mut Network) -> Self {
        let shards = equal_ranges(w0.len(), num_ps.max(1))
            .into_iter()
            .map(|(lo, hi)| SyncShard { lo, hi, node: net.add_node(Role::SyncPs) })
            .collect();
        Self {
            central: HogwildBuffer::from_slice(w0),
            shards,
            chunk_elems: 0,
            delta_threshold: 0.0,
            rounds: AtomicU64::new(0),
            bytes_moved: AtomicU64::new(0),
            chunks_pushed: AtomicU64::new(0),
            chunks_skipped: AtomicU64::new(0),
        }
    }

    /// Configure chunked pushes (`chunk_elems` elements per chunk, 0 =
    /// whole shard) with a delta gate (`delta_threshold` max-|Δ| skip
    /// level, 0 = push everything).
    pub fn with_push_chunking(mut self, chunk_elems: usize, delta_threshold: f32) -> Self {
        self.chunk_elems = chunk_elems;
        self.delta_threshold = delta_threshold.max(0.0);
        self
    }

    /// One EASGD elastic round for `local` against every shard:
    /// `w^PS ← (1-α) w^PS + α w^(i)`; `w^(i) ← (1-α) w^(i) + α w^PS`
    /// (Algorithm 2), executed chunk-by-chunk with measured traffic
    /// accounting. Returns mean |local - central| before the move.
    pub fn elastic_sync(
        &self,
        local: &HogwildBuffer,
        alpha: f32,
        trainer: NodeId,
        net: &Network,
    ) -> f32 {
        self.elastic_sync_stats(local, alpha, trainer, net).gap
    }

    /// `elastic_sync` returning the round's full measured [`PushStats`].
    pub fn elastic_sync_stats(
        &self,
        local: &HogwildBuffer,
        alpha: f32,
        trainer: NodeId,
        net: &Network,
    ) -> PushStats {
        debug_assert_eq!(local.len(), self.central.len());
        let mut gap_weighted = 0f64;
        let mut bytes = 0u64;
        let mut pushed = 0u64;
        let mut skipped = 0u64;
        for s in &self.shards {
            let step = if self.chunk_elems == 0 { (s.hi - s.lo).max(1) } else { self.chunk_elems };
            let mut lo = s.lo;
            while lo < s.hi {
                let hi = (lo + step).min(s.hi);
                if self.delta_threshold > 0.0 {
                    // delta gate: one racy scan (Hogwild semantics); a
                    // chunk that barely moved is skipped entirely — the
                    // reply leg is suppressed along with the push leg
                    let (max_abs, sum_abs) = Self::chunk_gap(local, &self.central, lo, hi);
                    if max_abs <= self.delta_threshold {
                        skipped += 1;
                        gap_weighted += sum_abs;
                        lo = hi;
                        continue;
                    }
                }
                let chunk_bytes = ((hi - lo) * 4) as u64;
                // trainer pushes the chunk, PS answers with the moved chunk
                net.transfer(trainer, s.node, chunk_bytes);
                let gap = HogwildBuffer::elastic_pair(local, &self.central, lo, hi, alpha);
                net.transfer(s.node, trainer, chunk_bytes);
                gap_weighted += gap as f64 * (hi - lo) as f64;
                bytes += 2 * chunk_bytes;
                pushed += 1;
                lo = hi;
            }
        }
        self.rounds.fetch_add(1, Relaxed);
        self.bytes_moved.fetch_add(bytes, Relaxed);
        self.chunks_pushed.fetch_add(pushed, Relaxed);
        self.chunks_skipped.fetch_add(skipped, Relaxed);
        PushStats {
            gap: (gap_weighted / self.central.len().max(1) as f64) as f32,
            bytes,
            chunks_pushed: pushed,
            chunks_skipped: skipped,
        }
    }

    /// Max and summed |local − central| over `[lo, hi)` (racy snapshot).
    fn chunk_gap(
        local: &HogwildBuffer,
        central: &HogwildBuffer,
        lo: usize,
        hi: usize,
    ) -> (f32, f64) {
        let mut max_abs = 0f32;
        let mut sum_abs = 0f64;
        for i in lo..hi {
            let d = (local.get(i) - central.get(i)).abs();
            if d > max_abs {
                max_abs = d;
            }
            sum_abs += d as f64;
        }
        (max_abs, sum_abs)
    }

    /// Cumulative measured push traffic since construction.
    pub fn traffic(&self) -> PsTrafficSnapshot {
        PsTrafficSnapshot {
            rounds: self.rounds.load(Relaxed),
            bytes_moved: self.bytes_moved.load(Relaxed),
            chunks_pushed: self.chunks_pushed.load(Relaxed),
            chunks_skipped: self.chunks_skipped.load(Relaxed),
            full_round_bytes: self.round_bytes(),
        }
    }

    /// Bytes a *full* round moves through the sync-PS tier (both
    /// directions) — the no-skip reference; measured rounds report their
    /// actual bytes via [`PushStats`] / [`SyncPsGroup::traffic`].
    pub fn round_bytes(&self) -> u64 {
        2 * 4 * self.central.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Network;

    #[test]
    fn build_initializes_central_to_w0() {
        let mut net = Network::new(None);
        let w0 = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let g = SyncPsGroup::build(&w0, 2, &mut net);
        assert_eq!(g.central.to_vec(), w0);
        assert_eq!(g.shards.len(), 2);
        assert_eq!(g.shards[0].lo, 0);
        assert_eq!(g.shards[1].hi, 5);
    }

    #[test]
    fn elastic_sync_contracts_toward_each_other() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 16], 3, &mut net);
        let local = HogwildBuffer::from_slice(&vec![8.0; 16]);
        let gap = g.elastic_sync(&local, 0.5, trainer, &net);
        assert!((gap - 8.0).abs() < 1e-5);
        // alpha=0.5: both meet at 4.0
        assert!(local.to_vec().iter().all(|&x| (x - 4.0).abs() < 1e-5));
        assert!(g.central.to_vec().iter().all(|&x| (x - 4.0).abs() < 1e-5));
    }

    #[test]
    fn repeated_sync_converges_replicas_through_hub() {
        // two replicas never talk directly; they converge via w^PS
        let mut net = Network::new(None);
        let t0 = net.add_node(Role::Trainer);
        let t1 = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 8], 1, &mut net);
        let a = HogwildBuffer::from_slice(&vec![1.0; 8]);
        let b = HogwildBuffer::from_slice(&vec![-1.0; 8]);
        for _ in 0..100 {
            g.elastic_sync(&a, 0.3, t0, &net);
            g.elastic_sync(&b, 0.3, t1, &net);
        }
        let (av, bv) = (a.to_vec(), b.to_vec());
        for (x, y) in av.iter().zip(&bv) {
            assert!((x - y).abs() < 1e-3, "replicas did not converge: {x} vs {y}");
        }
    }

    #[test]
    fn traffic_lands_on_sync_ps_nics() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 100], 4, &mut net);
        let local = HogwildBuffer::from_slice(&vec![1.0; 100]);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(net.role_bytes(Role::SyncPs), g.round_bytes());
        assert_eq!(g.round_bytes(), 800);
        assert_eq!(st.bytes, 800);
        assert_eq!(st.chunks_skipped, 0);
    }

    #[test]
    fn chunked_pushes_move_the_same_total_bytes() {
        // chunk tiling preserves byte totals exactly (no delta gate)
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 103], 3, &mut net).with_push_chunking(7, 0.0);
        let local = HogwildBuffer::from_slice(&vec![1.0; 103]);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(st.bytes, g.round_bytes());
        assert_eq!(net.role_bytes(Role::SyncPs), g.round_bytes());
        // ceil(35/7) + ceil(34/7) * 2 chunks
        assert_eq!(st.chunks_pushed, 5 + 5 + 5);
        assert_eq!(st.chunks_skipped, 0);
        let t = g.traffic();
        assert_eq!(t.rounds, 1);
        assert_eq!(t.bytes_moved, st.bytes);
        assert!((t.push_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn delta_gate_skips_unchanged_chunks_both_legs() {
        // local == central over the second shard: every chunk there is
        // skipped, and its PS NIC moves zero bytes in BOTH directions (the
        // reply leg is suppressed along with the push)
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let w0 = vec![0.0f32; 64];
        let g = SyncPsGroup::build(&w0, 2, &mut net).with_push_chunking(8, 1e-6);
        // shard 0 = [0, 32), shard 1 = [32, 64)
        let mut local_v = vec![0.0f32; 64];
        for x in local_v.iter_mut().take(32) {
            *x = 2.0; // only shard 0 diverges
        }
        let local = HogwildBuffer::from_slice(&local_v);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        // shard 0: 4 chunks of 8 elems pushed, both legs = 2 * 32 * 4 bytes
        assert_eq!(st.chunks_pushed, 4);
        assert_eq!(st.chunks_skipped, 4);
        assert_eq!(st.bytes, 2 * 32 * 4);
        let quiet = g.shards[1].node;
        assert_eq!(net.tx(quiet), 0, "skipped chunks must suppress the reply leg");
        assert_eq!(net.rx(quiet), 0, "skipped chunks must suppress the push leg");
        let busy = g.shards[0].node;
        assert_eq!(net.rx(busy), 32 * 4);
        assert_eq!(net.tx(busy), 32 * 4);
        // skipped ranges were not elastically moved
        assert!(local.to_vec()[32..].iter().all(|&x| x == 0.0));
        assert!(g.central.to_vec()[..32].iter().all(|&x| (x - 1.0).abs() < 1e-6));
        // the reported gap still covers the whole vector (here: 2.0 over
        // half the elements -> 1.0 mean)
        assert!((st.gap - 1.0).abs() < 1e-5);
        let t = g.traffic();
        assert!((t.push_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(t.avg_round_bytes(), (2 * 32 * 4) as f64);
    }

    #[test]
    fn pushed_chunks_move_exactly_chunk_sized_bytes() {
        // non-skipped chunks must account chunk size exactly, per leg
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 10], 1, &mut net).with_push_chunking(4, 1e-3);
        // diverge only [4, 8): exactly the second chunk of the one shard
        let mut lv = vec![0.0f32; 10];
        for x in lv.iter_mut().skip(4).take(4) {
            *x = 1.0;
        }
        let local = HogwildBuffer::from_slice(&lv);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(st.chunks_pushed, 1);
        assert_eq!(st.chunks_skipped, 2);
        assert_eq!(st.bytes, 2 * 4 * 4); // one 4-elem chunk, both legs
        assert_eq!(net.tx(trainer), 4 * 4);
        assert_eq!(net.rx(trainer), 4 * 4);
        // chunks tile 10 as [4, 4, 2], so the chunk-count and byte
        // fractions differ — pricing must use bytes (32 of the 80-byte
        // full round), not the 1-in-3 chunk count
        let t = g.traffic();
        assert!((t.push_fraction() - 1.0 / 3.0).abs() < 1e-12);
        assert!((t.byte_fraction() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn zero_threshold_never_skips() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 32], 2, &mut net).with_push_chunking(8, 0.0);
        let local = HogwildBuffer::from_slice(&vec![0.0; 32]); // identical!
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(st.chunks_skipped, 0);
        assert_eq!(st.bytes, g.round_bytes());
    }
}
