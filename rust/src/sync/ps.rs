//! Sync parameter servers: the centralized home of `w^PS` for EASGD.
//!
//! The central parameter vector is sharded into near-equal contiguous
//! ranges, one per sync PS (the paper load-balances these with the same
//! profiling + bin-packing as the embedding shards; ranges of a homogeneous
//! dense vector are already perfectly balanced, which is what LPT would
//! produce). Trainers sync shard-by-shard so traffic is attributed to the
//! right PS NIC — the saturation of exactly these NICs is what causes the
//! paper's FR-EASGD-5 EPS plateau (Fig. 5).

use crate::net::{Network, NodeId, Role};
use crate::placement::equal_ranges;
use crate::tensor::HogwildBuffer;

/// One shard: parameter range `[lo, hi)` hosted on `node`.
#[derive(Debug)]
pub struct SyncShard {
    pub lo: usize,
    pub hi: usize,
    pub node: NodeId,
}

/// The sync-PS tier: the central `w^PS` plus its sharding.
pub struct SyncPsGroup {
    /// central parameters, Hogwild-shared across all trainers' syncs
    pub central: HogwildBuffer,
    pub shards: Vec<SyncShard>,
}

impl SyncPsGroup {
    /// Initialize `w^PS ← w0` across `num_ps` servers (Algorithm 1 line 3).
    pub fn build(w0: &[f32], num_ps: usize, net: &mut Network) -> Self {
        let shards = equal_ranges(w0.len(), num_ps.max(1))
            .into_iter()
            .map(|(lo, hi)| SyncShard { lo, hi, node: net.add_node(Role::SyncPs) })
            .collect();
        Self { central: HogwildBuffer::from_slice(w0), shards }
    }

    /// One EASGD elastic round for `local` against every shard:
    /// `w^PS ← (1-α) w^PS + α w^(i)`; `w^(i) ← (1-α) w^(i) + α w^PS`
    /// (Algorithm 2), executed per shard with traffic accounting.
    /// Returns mean |local - central| before the move.
    pub fn elastic_sync(
        &self,
        local: &HogwildBuffer,
        alpha: f32,
        trainer: NodeId,
        net: &Network,
    ) -> f32 {
        debug_assert_eq!(local.len(), self.central.len());
        let mut gap_weighted = 0f64;
        for s in &self.shards {
            let bytes = ((s.hi - s.lo) * 4) as u64;
            // trainer pushes its range, PS answers with the moved range
            net.transfer(trainer, s.node, bytes);
            let gap = HogwildBuffer::elastic_pair(local, &self.central, s.lo, s.hi, alpha);
            net.transfer(s.node, trainer, bytes);
            gap_weighted += gap as f64 * (s.hi - s.lo) as f64;
        }
        (gap_weighted / self.central.len().max(1) as f64) as f32
    }

    /// Bytes a full round moves through the sync-PS tier (both directions).
    pub fn round_bytes(&self) -> u64 {
        2 * 4 * self.central.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Network;

    #[test]
    fn build_initializes_central_to_w0() {
        let mut net = Network::new(None);
        let w0 = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let g = SyncPsGroup::build(&w0, 2, &mut net);
        assert_eq!(g.central.to_vec(), w0);
        assert_eq!(g.shards.len(), 2);
        assert_eq!(g.shards[0].lo, 0);
        assert_eq!(g.shards[1].hi, 5);
    }

    #[test]
    fn elastic_sync_contracts_toward_each_other() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 16], 3, &mut net);
        let local = HogwildBuffer::from_slice(&vec![8.0; 16]);
        let gap = g.elastic_sync(&local, 0.5, trainer, &net);
        assert!((gap - 8.0).abs() < 1e-5);
        // alpha=0.5: both meet at 4.0
        assert!(local.to_vec().iter().all(|&x| (x - 4.0).abs() < 1e-5));
        assert!(g.central.to_vec().iter().all(|&x| (x - 4.0).abs() < 1e-5));
    }

    #[test]
    fn repeated_sync_converges_replicas_through_hub() {
        // two replicas never talk directly; they converge via w^PS
        let mut net = Network::new(None);
        let t0 = net.add_node(Role::Trainer);
        let t1 = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 8], 1, &mut net);
        let a = HogwildBuffer::from_slice(&vec![1.0; 8]);
        let b = HogwildBuffer::from_slice(&vec![-1.0; 8]);
        for _ in 0..100 {
            g.elastic_sync(&a, 0.3, t0, &net);
            g.elastic_sync(&b, 0.3, t1, &net);
        }
        let (av, bv) = (a.to_vec(), b.to_vec());
        for (x, y) in av.iter().zip(&bv) {
            assert!((x - y).abs() < 1e-3, "replicas did not converge: {x} vs {y}");
        }
    }

    #[test]
    fn traffic_lands_on_sync_ps_nics() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 100], 4, &mut net);
        let local = HogwildBuffer::from_slice(&vec![1.0; 100]);
        g.elastic_sync(&local, 0.5, trainer, &net);
        assert_eq!(net.role_bytes(Role::SyncPs), g.round_bytes());
        assert_eq!(g.round_bytes(), 800);
    }
}
