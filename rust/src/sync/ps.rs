//! Sync parameter servers: the centralized home of `w^PS` for EASGD.
//!
//! The central parameter vector is sharded into near-equal contiguous
//! ranges, one per sync PS (the paper load-balances these with the same
//! profiling + bin-packing as the embedding shards; ranges of a homogeneous
//! dense vector are already perfectly balanced, which is what LPT would
//! produce). Trainers sync shard-by-shard so traffic is attributed to the
//! right PS NIC — the saturation of exactly these NICs is what causes the
//! paper's FR-EASGD-5 EPS plateau (Fig. 5).
//!
//! ## Chunked, delta-gated pushes
//!
//! Each shard is pushed in chunks of [`SyncPsGroup`]'s `chunk_elems`
//! elements (0 = whole-shard pushes). With a positive `delta_threshold`, a
//! chunk whose max |local − central| is at or below the threshold is
//! *skipped entirely*: neither the trainer→PS push leg nor the PS→trainer
//! reply leg touches [`Network::transfer`], so NIC counters and
//! `metrics.sync_bytes` both see only the bytes actually moved. The
//! returned [`PushStats`] carry the measured bytes of the round, and the
//! group keeps cumulative counters ([`SyncPsGroup::traffic`]) that the
//! experiment harness feeds into the `sim/` cost model as its measured
//! EASGD push fraction.
//!
//! ## The adaptive quantile gate
//!
//! A single global threshold has to be tuned per model and per phase of
//! training — too low and nothing skips, too high and the replicas decouple.
//! A [`DeltaGate`] instead targets a *skip rate*: every scanned chunk's
//! max-gap feeds a lock-free sliding-window [`QuantileSketch`], and each
//! round gates at the window's `delta_skip_target`-quantile, so the
//! observed skip rate tracks the target as the gap distribution drifts
//! across training (until the sketch warms up, the fixed `delta_threshold`
//! — possibly 0, i.e. push everything — applies). The group carries one
//! gate for the legacy whole-vector API
//! ([`SyncPsGroup::with_adaptive_gate`]); the partitioned fabric gives
//! every EASGD strategy — per trainer, per partition — its *own* gate, so
//! heterogeneous replicas and partitions gate independently.
//!
//! ## Range-scoped partition rounds and central version counters
//!
//! The partitioned fabric syncs each [`super::ParamRange`] partition on its
//! own ([`SyncPsGroup::elastic_sync_partition`]): only the push chunks
//! overlapping the range move (chunks are clipped at partition
//! boundaries), and both the scan cache and the gate belong to the calling
//! strategy. Each round's measured bytes are additionally recorded under
//! the partition's index ([`SyncPsGroup::note_partition_round`], exported
//! through [`PsTrafficSnapshot::per_partition`]) so the `sim/` cost model
//! and the adaptive repartitioner see per-partition byte fractions instead
//! of assuming `round_bytes / P`. Cache ordinals stay keyed by *global* chunk ordinal, and the
//! central vector keeps a per-chunk **version counter** that every elastic
//! push bumps — so a chunk *another trainer* pushed no longer matches this
//! trainer's cached `(signature, version)` pair and is re-scanned next
//! round. That closes the dirty-epoch drift gap (a scan-skipped chunk
//! silently missing central-side movement) with the same
//! one-round-bounded staleness class as the racy scan itself.
//!
//! ## Dirty-epoch scan skips
//!
//! The gate's scan reads every element even when nothing moved. When the
//! trainer's replica tracks per-chunk write epochs
//! ([`HogwildBuffer::with_dirty_epochs`]), a per-trainer [`DeltaScanCache`]
//! remembers each chunk's scan result keyed by its dirty signature: a chunk
//! untouched since its last scan reuses the cached gap without reading a
//! single element ([`SyncPsGroup::elastic_sync_cached`]). A pushed chunk is
//! rewritten by the elastic move, so its cache entry is invalidated and the
//! next round re-scans it — a scan-skipped chunk is therefore never one
//! whose (quiescent) elements changed since the last push; the property
//! suite proves this on randomized write patterns, and a write still
//! racing the signature read can defer its re-scan by at most one round
//! (see the [`crate::tensor::DirtyEpochs`] precision caveat — the same
//! transient-staleness class as the racy scan itself).

use std::time::Duration;

use super::prim::{
    thread, AtomicU32, AtomicU64, AtomicUsize, Mutex,
    Ordering::{Acquire, Relaxed, Release},
};

use super::partition::ParamRange;
use super::traffic::WireCodec;
use crate::net::{FaultError, Network, NodeId, Role};
use crate::placement::equal_ranges;
use crate::tensor::HogwildBuffer;

/// One shard: parameter range `[lo, hi)` hosted on `node`.
#[derive(Debug)]
pub struct SyncShard {
    pub lo: usize,
    pub hi: usize,
    pub node: NodeId,
}

/// What one elastic round measured and moved.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PushStats {
    /// Mean |local − central| over the *whole* vector before the move
    /// (skipped chunks contribute their scanned gap; scan-skipped chunks
    /// contribute their cached gap from the last real scan).
    pub gap: f32,
    /// Bytes actually moved through the network, both legs summed — what
    /// `metrics.sync_bytes` should record.
    pub bytes: u64,
    pub chunks_pushed: u64,
    pub chunks_skipped: u64,
    /// Chunks whose gate decision reused a cached scan because the
    /// trainer's dirty epochs showed no write since (a subset of
    /// `chunks_pushed + chunks_skipped`).
    pub chunks_scan_skipped: u64,
    /// Push-leg transfer retries issued against a faulted fabric (0 on a
    /// healthy one). A chunk whose retries are exhausted counts under
    /// `chunks_skipped` and moves zero further bytes.
    pub push_retries: u64,
}

/// Lock-free sliding-window sketch of a scalar stream, queried for
/// quantiles. `record` is one atomic store + one counter bump; `quantile`
/// snapshots and sorts the window (a few hundred floats — called once per
/// sync round, off the training hot path). Old samples are overwritten ring-
/// buffer style, so the estimate follows a drifting distribution.
///
/// # Examples
///
/// ```
/// use shadowsync::sync::QuantileSketch;
///
/// let sketch = QuantileSketch::new(64);
/// assert_eq!(sketch.quantile(0.5), None, "no answers before warmup");
/// for x in 0..64 {
///     sketch.record(x as f32);
/// }
/// assert_eq!(sketch.quantile(0.5), Some(31.0));
/// ```
#[derive(Debug)]
pub struct QuantileSketch {
    window: Vec<AtomicU32>,
    cursor: AtomicUsize,
    filled: AtomicUsize,
}

/// Samples required before the sketch answers quantile queries.
const SKETCH_WARMUP: usize = 16;

impl QuantileSketch {
    pub fn new(window: usize) -> Self {
        let window = window.max(SKETCH_WARMUP);
        let mut w = Vec::with_capacity(window);
        w.resize_with(window, || AtomicU32::new(0));
        Self { window: w, cursor: AtomicUsize::new(0), filled: AtomicUsize::new(0) }
    }

    pub fn record(&self, x: f32) {
        if !x.is_finite() {
            return;
        }
        let i = self.cursor.fetch_add(1, Relaxed) % self.window.len();
        self.window[i].store(x.to_bits(), Relaxed);
        if self.filled.load(Relaxed) < self.window.len() {
            // may overshoot under races; clamped in `samples`. The Release
            // bump publishes the slot store above, so a reader that observes
            // `filled >= n` via `samples` also observes at least `n` real
            // slot writes (never the zeroed initial values).
            self.filled.fetch_add(1, Release);
        }
    }

    /// Valid samples currently in the window (Acquire: pairs with the
    /// Release bump in [`Self::record`]).
    pub fn samples(&self) -> usize {
        self.filled.load(Acquire).min(self.window.len())
    }

    /// The `q`-quantile of the current window, chosen so that (for a
    /// continuous distribution) about a `q` fraction of fresh samples fall
    /// at or below it. `None` until the warmup fill is reached.
    pub fn quantile(&self, q: f32) -> Option<f32> {
        let n = self.samples();
        if n < SKETCH_WARMUP {
            return None;
        }
        let mut v: Vec<f32> = self.window[..n]
            .iter()
            .map(|a| f32::from_bits(a.load(Relaxed)))
            .collect();
        v.sort_by(f32::total_cmp);
        let idx = ((n as f64 * q as f64).ceil() as usize).clamp(1, n) - 1;
        Some(v[idx])
    }
}

/// Per-trainer cache for the dirty-epoch scan fast path: one entry per push
/// chunk (in shard/chunk iteration order), holding the last scanned gap and
/// the replica's dirty signature at scan time. Owned by the sync strategy
/// (one per trainer/worker), never shared — the [`SyncPsGroup`] itself is
/// shared across trainers.
#[derive(Debug, Default)]
pub struct DeltaScanCache {
    entries: Vec<CacheEntry>,
}

#[derive(Debug, Default, Clone, Copy)]
struct CacheEntry {
    sig: u64,
    /// central-side chunk version at scan time; a mismatch means another
    /// trainer pushed this chunk since, so the cached gap is stale
    central_ver: u64,
    max_abs: f32,
    sum_abs: f64,
    valid: bool,
    /// did the most recent round reuse this entry instead of scanning?
    reused: bool,
}

impl DeltaScanCache {
    pub fn new() -> Self {
        Self::default()
    }

    fn entry(&mut self, k: usize) -> &mut CacheEntry {
        if k >= self.entries.len() {
            self.entries.resize(k + 1, CacheEntry::default());
        }
        &mut self.entries[k]
    }

    /// Did the most recent round skip the scan of push chunk `k` (test
    /// observability for the dirty-epoch safety property)?
    pub fn scan_skipped(&self, k: usize) -> bool {
        self.entries.get(k).map(|e| e.reused).unwrap_or(false)
    }
}

/// Measured traffic of one partition's EASGD rounds — the per-partition
/// resolution of [`PsTrafficSnapshot`]. `full_round_bytes` is what a
/// no-skip round over the partition's *current* range would move (both
/// legs), so `bytes_moved / rounds / full_round_bytes` is that partition's
/// measured byte fraction.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PartitionTraffic {
    pub rounds: u64,
    pub bytes_moved: u64,
    pub full_round_bytes: u64,
}

impl PartitionTraffic {
    /// Measured bytes of this partition's average round (both legs).
    pub fn avg_round_bytes(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.bytes_moved as f64 / self.rounds as f64
        }
    }
}

/// Cumulative measured push traffic of a sync-PS group.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct PsTrafficSnapshot {
    pub rounds: u64,
    pub bytes_moved: u64,
    pub chunks_pushed: u64,
    pub chunks_skipped: u64,
    /// Chunks whose scan was skipped via dirty epochs (cached gap reused).
    pub chunks_scan_skipped: u64,
    /// Bytes a full no-skip round would move (`SyncPsGroup::round_bytes`) —
    /// the denominator that turns `bytes_moved` into a scale-free fraction.
    pub full_round_bytes: u64,
    /// Per-partition resolution (index = partition in the fabric's plan;
    /// empty until a partition-scoped round is recorded). Feeds the `sim/`
    /// cost model's measured per-partition byte shares so heterogeneous
    /// plans and `--algo-map`s are priced exactly, not at `round_bytes/P`.
    pub per_partition: Vec<PartitionTraffic>,
}

impl PsTrafficSnapshot {
    /// Fold another group's (or run's) counters into this snapshot —
    /// used by the experiment harness to aggregate the measured traffic of
    /// several runs before pricing the cost model.
    pub fn absorb(&mut self, other: &PsTrafficSnapshot) {
        self.rounds += other.rounds;
        self.bytes_moved += other.bytes_moved;
        self.chunks_pushed += other.chunks_pushed;
        self.chunks_skipped += other.chunks_skipped;
        self.chunks_scan_skipped += other.chunks_scan_skipped;
        if self.full_round_bytes == 0 {
            self.full_round_bytes = other.full_round_bytes;
        }
        if self.per_partition.len() < other.per_partition.len() {
            self.per_partition.resize(other.per_partition.len(), PartitionTraffic::default());
        }
        for (mine, theirs) in self.per_partition.iter_mut().zip(&other.per_partition) {
            mine.rounds += theirs.rounds;
            mine.bytes_moved += theirs.bytes_moved;
            if mine.full_round_bytes == 0 {
                mine.full_round_bytes = theirs.full_round_bytes;
            }
        }
    }

    /// Measured per-partition byte shares, normalized to sum to 1 — the
    /// input `sim/` uses to price a heterogeneous fabric exactly. Empty
    /// when no partition-scoped bytes were recorded.
    pub fn partition_byte_shares(&self) -> Vec<f64> {
        let bytes: Vec<u64> = self.per_partition.iter().map(|p| p.bytes_moved).collect();
        crate::util::byte_shares(&bytes)
    }

    /// Measured bytes of an average round (both legs).
    pub fn avg_round_bytes(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.bytes_moved as f64 / self.rounds as f64
        }
    }

    /// Measured *byte* fraction of the full round an average round moved —
    /// the scale-free input the `sim/` cost model uses to price delta-gated
    /// EASGD rounds (robust to uneven chunk sizes, unlike a chunk count).
    pub fn byte_fraction(&self) -> f64 {
        if self.rounds == 0 || self.full_round_bytes == 0 {
            1.0
        } else {
            (self.avg_round_bytes() / self.full_round_bytes as f64).clamp(0.0, 1.0)
        }
    }

    /// Fraction of chunks that actually moved (a skip-rate diagnostic; use
    /// [`PsTrafficSnapshot::byte_fraction`] for traffic pricing).
    pub fn push_fraction(&self) -> f64 {
        let total = self.chunks_pushed + self.chunks_skipped;
        if total == 0 {
            1.0
        } else {
            self.chunks_pushed as f64 / total as f64
        }
    }

    /// The live delta-gate skip rate: fraction of gated chunks that moved
    /// zero bytes — what the adaptive gate steers toward its target.
    pub fn skip_fraction(&self) -> f64 {
        1.0 - self.push_fraction()
    }

    /// Fraction of gated chunks whose *scan* was skipped via dirty epochs.
    pub fn scan_skip_fraction(&self) -> f64 {
        let total = self.chunks_pushed + self.chunks_skipped;
        if total == 0 {
            0.0
        } else {
            self.chunks_scan_skipped as f64 / total as f64
        }
    }
}

/// Sliding-window size of the adaptive gate's gap sketch.
const GATE_SKETCH_WINDOW: usize = 512;

/// One delta-gate instance: a fixed max-|Δ| threshold plus an optional
/// adaptive quantile sketch targeting a skip *rate*. The [`SyncPsGroup`]
/// carries a group-level gate for the legacy whole-vector API; the
/// partitioned fabric hands each EASGD strategy (per trainer × per
/// partition) its own gate, closing the "per-trainer/per-shard sketch"
/// follow-on: heterogeneous replicas gate on their own gap distributions.
#[derive(Debug)]
pub struct DeltaGate {
    /// skip chunks whose max |local − central| is at or below this
    delta_threshold: f32,
    /// adaptive mode: target fraction of gated chunks to skip (0 = fixed
    /// threshold mode)
    skip_target: f32,
    /// per-chunk max-gap distribution feeding the adaptive gate
    sketch: Option<QuantileSketch>,
}

impl DeltaGate {
    /// A gate with fixed threshold `delta_threshold` (0 = never skip on
    /// the fixed path) and adaptive skip target `skip_target` (0 = fixed
    /// mode; positive values allocate the sliding-window sketch).
    pub fn new(delta_threshold: f32, skip_target: f32) -> Self {
        let skip_target = skip_target.clamp(0.0, 1.0);
        Self {
            delta_threshold: delta_threshold.max(0.0),
            skip_target,
            sketch: (skip_target > 0.0).then(|| QuantileSketch::new(GATE_SKETCH_WINDOW)),
        }
    }

    /// The no-op gate: nothing ever skips.
    pub fn disabled() -> Self {
        Self::new(0.0, 0.0)
    }

    /// Is any gating (fixed or adaptive) configured? Mirrors
    /// `RunConfig::delta_gated` (strategies are built from that config);
    /// keep the two predicates in lockstep when adding a gating mode, or
    /// trainer replicas stop tracking dirty epochs while the gate still
    /// scans.
    pub fn enabled(&self) -> bool {
        self.delta_threshold > 0.0 || self.skip_target > 0.0
    }

    /// The max-|Δ| threshold this round gates at. Adaptive mode reads the
    /// sketch's target quantile (falling back to the fixed threshold — or
    /// "never skip" — until warmup); fixed mode uses `delta_threshold`.
    /// Negative means no chunk can skip (gaps are always >= 0).
    fn round_gate(&self) -> f32 {
        let fixed = if self.delta_threshold > 0.0 { self.delta_threshold } else { -1.0 };
        match &self.sketch {
            Some(sk) => sk.quantile(self.skip_target).unwrap_or(fixed),
            None => fixed,
        }
    }

    /// Feed one per-chunk max-gap observation to the adaptive sketch.
    fn record(&self, gap: f32) {
        if let Some(sk) = &self.sketch {
            sk.record(gap);
        }
    }

    /// Test observability: samples currently in the adaptive sketch.
    pub fn sketch_samples(&self) -> usize {
        self.sketch.as_ref().map_or(0, |sk| sk.samples())
    }
}

/// The sync-PS tier: the central `w^PS` plus its sharding.
pub struct SyncPsGroup {
    /// central parameters, Hogwild-shared across all trainers' syncs
    pub central: HogwildBuffer,
    pub shards: Vec<SyncShard>,
    /// elements per push chunk (0 = whole-shard pushes)
    chunk_elems: usize,
    /// group-level gate for the legacy whole-vector API; strategies built
    /// by the partitioned fabric pass their own per-partition gate instead
    gate: DeltaGate,
    /// central-side per-chunk version counters (global push-chunk
    /// ordinals): every elastic push bumps its chunk, so one trainer's
    /// push invalidates every other trainer's cached scan of that chunk
    chunk_versions: Vec<AtomicU64>,
    rounds: AtomicU64,
    bytes_moved: AtomicU64,
    chunks_pushed: AtomicU64,
    chunks_skipped: AtomicU64,
    chunks_scan_skipped: AtomicU64,
    /// retries per push leg when a transfer faults (see
    /// [`SyncPsGroup::with_push_retry`]); the default matches
    /// `RunConfig::push_retries`
    push_retries: u32,
    /// initial backoff between retries, doubling per attempt
    push_backoff: Duration,
    /// hard cap on the *summed* backoff sleeps of one push leg (see
    /// [`SyncPsGroup::with_push_backoff_budget`]); None = unbounded
    push_backoff_budget: Option<Duration>,
    /// per-partition round/byte counters (index = partition in the
    /// fabric's plan), recorded by the strategies after each round — a
    /// mutex, not atomics: rounds are off the training hot path and the
    /// partition count is a run-time knob
    partition_traffic: Mutex<Vec<PartitionTraffic>>,
}

impl SyncPsGroup {
    /// Initialize `w^PS ← w0` across `num_ps` servers (Algorithm 1 line 3),
    /// whole-shard pushes, no delta gate.
    pub fn build(w0: &[f32], num_ps: usize, net: &mut Network) -> Self {
        let shards = equal_ranges(w0.len(), num_ps.max(1))
            .into_iter()
            .map(|(lo, hi)| SyncShard { lo, hi, node: net.add_node(Role::SyncPs) })
            .collect();
        let mut g = Self {
            central: HogwildBuffer::from_slice(w0),
            shards,
            chunk_elems: 0,
            gate: DeltaGate::disabled(),
            chunk_versions: Vec::new(),
            rounds: AtomicU64::new(0),
            bytes_moved: AtomicU64::new(0),
            chunks_pushed: AtomicU64::new(0),
            chunks_skipped: AtomicU64::new(0),
            chunks_scan_skipped: AtomicU64::new(0),
            push_retries: 3,
            push_backoff: Duration::from_millis(1),
            push_backoff_budget: None,
            partition_traffic: Mutex::new(Vec::new()),
        };
        g.reset_chunk_versions();
        g
    }

    /// Configure chunked pushes (`chunk_elems` elements per chunk, 0 =
    /// whole shard) with a delta gate (`delta_threshold` max-|Δ| skip
    /// level, 0 = push everything). Builder-phase only: resizes the
    /// central version table to the new chunk count.
    pub fn with_push_chunking(mut self, chunk_elems: usize, delta_threshold: f32) -> Self {
        self.chunk_elems = chunk_elems;
        self.gate = DeltaGate::new(delta_threshold, self.gate.skip_target);
        self.reset_chunk_versions();
        self
    }

    /// Enable the adaptive quantile gate on the group-level gate: per
    /// round, skip the chunks whose max-gap falls in the lowest
    /// `skip_target` fraction of the recently observed gap distribution. 0
    /// disables (fixed-threshold mode); while the sketch warms up, the
    /// fixed `delta_threshold` applies. Strategies with their own
    /// [`DeltaGate`] bypass this gate entirely.
    pub fn with_adaptive_gate(mut self, skip_target: f32) -> Self {
        self.gate = DeltaGate::new(self.gate.delta_threshold, skip_target);
        self
    }

    /// Configure degradation around a faulted fabric: each push leg whose
    /// transfer faults transiently is retried up to `retries` times with
    /// exponential backoff starting at `backoff` (crashed endpoints are
    /// not retried — the backoff cannot outlast a crash window). A chunk
    /// whose retries are exhausted is *skipped with retry*: it feeds the
    /// existing skip metrics and moves zero further bytes, so
    /// `metrics.sync_bytes` stays exactly equal to the delivered NIC
    /// traffic. On a healthy fabric this builder is inert.
    pub fn with_push_retry(mut self, retries: u32, backoff: Duration) -> Self {
        self.push_retries = retries;
        self.push_backoff = backoff;
        self
    }

    /// Cap the *summed* doubling backoff sleeps of any single push leg at
    /// `budget`. Without the cap, a large `--push-backoff-ms` against a
    /// drop-heavy fabric lets a perfectly healthy trainer sleep through its
    /// own heartbeat window mid-leg and get proxy-departed by the
    /// `HealthController` watchdog — the retry loop must never out-sleep
    /// the watchdog's patience. The coordinator wires this to a fraction of
    /// `--heartbeat-timeout-ms` whenever the watchdog is armed.
    pub fn with_push_backoff_budget(mut self, budget: Duration) -> Self {
        self.push_backoff_budget = Some(budget);
        self
    }

    /// Deliver one push leg, retrying transient faults with bounded
    /// exponential backoff. The summed sleeps never exceed the configured
    /// backoff budget: each sleep is clipped to the budget's remainder and
    /// the leg gives up once the budget is spent. Returns
    /// `(delivered, retries_issued)`.
    fn push_leg_with_retry(
        &self,
        net: &Network,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
    ) -> (bool, u64) {
        let mut retries = 0u64;
        let mut backoff = self.push_backoff;
        let mut slept = Duration::ZERO;
        loop {
            match net.try_transfer(src, dst, bytes) {
                Ok(()) => return (true, retries),
                // a crashed endpoint stays crashed for whole sweep windows:
                // backing off cannot help, give the chunk up immediately
                Err(FaultError::Unreachable) => return (false, retries),
                Err(FaultError::Dropped) => {
                    if retries >= self.push_retries as u64 {
                        return (false, retries);
                    }
                    let mut sleep = backoff;
                    if let Some(budget) = self.push_backoff_budget {
                        let remaining = budget.saturating_sub(slept);
                        if remaining.is_zero() {
                            // another doubling would sleep past the
                            // heartbeat watchdog's patience: give the chunk
                            // up (next round retries it from scratch)
                            return (false, retries);
                        }
                        sleep = sleep.min(remaining);
                    }
                    retries += 1;
                    slept += sleep;
                    thread::sleep(sleep);
                    backoff = backoff.saturating_mul(2);
                }
            }
        }
    }

    /// One zeroed version counter per global push chunk (builder phase).
    fn reset_chunk_versions(&mut self) {
        let n = self.push_chunks().count();
        let mut v = Vec::with_capacity(n);
        v.resize_with(n, || AtomicU64::new(0));
        self.chunk_versions = v;
    }

    /// Central-side version of push chunk `k` (bumped on every push of
    /// that chunk, by any trainer). Test observability.
    pub fn chunk_version(&self, k: usize) -> u64 {
        self.chunk_versions[k].load(Acquire)
    }

    /// One EASGD elastic round for `local` against every shard:
    /// `w^PS ← (1-α) w^PS + α w^(i)`; `w^(i) ← (1-α) w^(i) + α w^PS`
    /// (Algorithm 2), executed chunk-by-chunk with measured traffic
    /// accounting. Returns mean |local - central| before the move.
    pub fn elastic_sync(
        &self,
        local: &HogwildBuffer,
        alpha: f32,
        trainer: NodeId,
        net: &Network,
    ) -> f32 {
        self.elastic_sync_stats(local, alpha, trainer, net).gap
    }

    /// `elastic_sync` returning the round's full measured [`PushStats`].
    pub fn elastic_sync_stats(
        &self,
        local: &HogwildBuffer,
        alpha: f32,
        trainer: NodeId,
        net: &Network,
    ) -> PushStats {
        self.elastic_sync_impl(
            local,
            alpha,
            trainer,
            net,
            None,
            None,
            0,
            self.central.len(),
            WireCodec::Fp32,
            None,
        )
    }

    /// `elastic_sync_stats` with a per-trainer [`DeltaScanCache`]: when the
    /// local replica tracks dirty epochs, chunks untouched since their last
    /// scan reuse the cached gap without reading a single element.
    pub fn elastic_sync_cached(
        &self,
        local: &HogwildBuffer,
        alpha: f32,
        trainer: NodeId,
        net: &Network,
        cache: &mut DeltaScanCache,
    ) -> PushStats {
        self.elastic_sync_impl(
            local,
            alpha,
            trainer,
            net,
            Some(cache),
            None,
            0,
            self.central.len(),
            WireCodec::Fp32,
            None,
        )
    }

    /// Range-scoped elastic round for one partition of the replica: only
    /// the push chunks overlapping `range` are gated and pushed (clipped
    /// at partition boundaries), `gate` — when given — replaces the
    /// group-level gate with the caller's own per-partition instance, and
    /// `cache` ordinals stay keyed by global chunk ordinal so the cache
    /// survives any partition geometry. A full-range call with the group
    /// gate is bit-identical to [`SyncPsGroup::elastic_sync_cached`].
    #[allow(clippy::too_many_arguments)]
    pub fn elastic_sync_partition(
        &self,
        local: &HogwildBuffer,
        range: ParamRange,
        alpha: f32,
        trainer: NodeId,
        net: &Network,
        cache: &mut DeltaScanCache,
        gate: Option<&DeltaGate>,
    ) -> PushStats {
        self.elastic_sync_impl(
            local,
            alpha,
            trainer,
            net,
            Some(cache),
            gate,
            range.lo(),
            range.hi().min(self.central.len()),
            WireCodec::Fp32,
            None,
        )
    }

    /// [`SyncPsGroup::elastic_sync_partition`] with a wire codec on both
    /// legs. Pushed chunks move `codec.wire_bytes(chunk_elems)` per leg —
    /// the compressed size flows straight into [`Network`] transfers and
    /// [`PushStats::bytes`], so NIC counters and `metrics.sync_bytes` see
    /// codec-reduced traffic through the existing single source of truth.
    /// `residual` is the caller's per-trainer × per-partition error-feedback
    /// buffer, indexed relative to `range.lo()` and exactly `range.len`
    /// long; lossy codecs fold it into each push and store what the encode
    /// lost back ([`WireCodec::encode_with_feedback`]). The reply leg
    /// transcodes the moved central chunk without feedback — residual
    /// ownership is per trainer, push leg only. Under [`WireCodec::Fp32`]
    /// this is bit-identical to [`SyncPsGroup::elastic_sync_partition`].
    #[allow(clippy::too_many_arguments)]
    pub fn elastic_sync_partition_codec(
        &self,
        local: &HogwildBuffer,
        range: ParamRange,
        alpha: f32,
        trainer: NodeId,
        net: &Network,
        cache: &mut DeltaScanCache,
        gate: Option<&DeltaGate>,
        codec: WireCodec,
        residual: Option<&mut [f32]>,
    ) -> PushStats {
        self.elastic_sync_impl(
            local,
            alpha,
            trainer,
            net,
            Some(cache),
            gate,
            range.lo(),
            range.hi().min(self.central.len()),
            codec,
            residual,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn elastic_sync_impl(
        &self,
        local: &HogwildBuffer,
        alpha: f32,
        trainer: NodeId,
        net: &Network,
        mut cache: Option<&mut DeltaScanCache>,
        gate_override: Option<&DeltaGate>,
        lo: usize,
        hi: usize,
        codec: WireCodec,
        mut residual: Option<&mut [f32]>,
    ) -> PushStats {
        debug_assert_eq!(local.len(), self.central.len());
        debug_assert!(lo <= hi && hi <= self.central.len());
        let gate_state = gate_override.unwrap_or(&self.gate);
        let gate_on = gate_state.enabled();
        let gate = if gate_on { gate_state.round_gate() } else { -1.0 };
        let mut gap_weighted = 0f64;
        let mut bytes = 0u64;
        let mut pushed = 0u64;
        let mut skipped = 0u64;
        let mut scan_skipped = 0u64;
        let mut retries = 0u64;
        // the shared walk keeps [`DeltaScanCache`] ordinals `k` in lockstep
        // with `push_chunk_ranges` by construction
        for (k, clo, chi, node) in self.push_chunks_scoped(lo, hi) {
            if gate_on {
                // version read precedes the scan: if a peer's push lands
                // during our scan, the next round's version check fails
                // and forces the conservative re-scan
                let ver = self.chunk_versions[k].load(Acquire);
                // dirty-epoch fast path: if the replica records no write
                // to [clo, chi) since this chunk's last scan — and no
                // peer pushed the chunk centrally since — reuse that
                // scan; otherwise do the racy scan (Hogwild semantics)
                // and feed the fresh max-gap to the adaptive sketch
                let sig = cache.as_ref().and_then(|_| local.dirty_signature(clo, chi));
                let (max_abs, sum_abs) = match (&mut cache, sig) {
                    (Some(c), Some(sig)) => {
                        let e = c.entry(k);
                        if e.valid && e.sig == sig && e.central_ver == ver {
                            e.reused = true;
                            scan_skipped += 1;
                            // the cached gap is still this round's gap
                            // observation — feed it to the sketch, or the
                            // adaptive gate would see only the rescanned
                            // (dirtier, higher-gap) subpopulation and the
                            // skip rate would drift above its target
                            gate_state.record(e.max_abs);
                            (e.max_abs, e.sum_abs)
                        } else {
                            let (m, sum) = Self::chunk_gap(local, &self.central, clo, chi);
                            *e = CacheEntry {
                                sig,
                                central_ver: ver,
                                max_abs: m,
                                sum_abs: sum,
                                valid: true,
                                reused: false,
                            };
                            gate_state.record(m);
                            (m, sum)
                        }
                    }
                    (c, _) => {
                        if let Some(c) = c {
                            // replica untracked: keep the per-round
                            // reuse flags truthful for observers
                            let e = c.entry(k);
                            e.valid = false;
                            e.reused = false;
                        }
                        let (m, sum) = Self::chunk_gap(local, &self.central, clo, chi);
                        gate_state.record(m);
                        (m, sum)
                    }
                };
                if max_abs <= gate {
                    // a chunk that barely moved is skipped entirely —
                    // the reply leg is suppressed along with the push
                    skipped += 1;
                    gap_weighted += sum_abs;
                    continue;
                }
                // the elastic move below rewrites the chunk, so the
                // cached scan is stale the moment we push
                if let Some(c) = &mut cache {
                    c.entry(k).valid = false;
                }
            }
            let chunk_bytes = codec.wire_bytes(chi - clo);
            // trainer pushes the chunk, PS answers with the moved chunk;
            // either leg may fault under an installed fault plan
            let (leg1_ok, leg1_retries) =
                self.push_leg_with_retry(net, trainer, node, chunk_bytes);
            retries += leg1_retries;
            if !leg1_ok {
                // skipped-with-retry: the elastic move never ran, central
                // is untouched, zero bytes crossed any wire — the chunk
                // lands in the existing skip metrics and the next round
                // retries it from scratch
                skipped += 1;
                continue;
            }
            let gap = if codec == WireCodec::Fp32 {
                HogwildBuffer::elastic_pair(local, &self.central, clo, chi, alpha)
            } else {
                let res = residual.as_deref_mut().map(|r| &mut r[clo - lo..chi - lo]);
                self.elastic_pair_codec(local, clo, chi, alpha, codec, res)
            };
            let (leg2_ok, leg2_retries) =
                self.push_leg_with_retry(net, node, trainer, chunk_bytes);
            retries += leg2_retries;
            // bump-after-move (Release): the moment a peer observes the new
            // version, the elastic stores behind it are visible too, so its
            // re-scan sees the drift this push introduced. The bump happens
            // even when the reply leg faulted: the elastic move already
            // rewrote central, so peers' cached scans *are* stale
            self.chunk_versions[k].fetch_add(1, Release);
            gap_weighted += gap as f64 * (chi - clo) as f64;
            // count only delivered legs: a faulted reply moved one leg of
            // wire traffic, and `metrics.sync_bytes` must stay exactly
            // equal to the NIC counters
            bytes += if leg2_ok { 2 * chunk_bytes } else { chunk_bytes };
            pushed += 1;
        }
        self.rounds.fetch_add(1, Relaxed);
        self.bytes_moved.fetch_add(bytes, Relaxed);
        self.chunks_pushed.fetch_add(pushed, Relaxed);
        self.chunks_skipped.fetch_add(skipped, Relaxed);
        self.chunks_scan_skipped.fetch_add(scan_skipped, Relaxed);
        PushStats {
            gap: (gap_weighted / (hi - lo).max(1) as f64) as f32,
            bytes,
            chunks_pushed: pushed,
            chunks_skipped: skipped,
            chunks_scan_skipped: scan_skipped,
            push_retries: retries,
        }
    }

    /// The codec-path elastic move for one pushed chunk `[lo, hi)` — the
    /// lossy counterpart of [`HogwildBuffer::elastic_pair`]. Both directions
    /// see what actually crossed the wire: central absorbs the
    /// error-feedback-encoded *decoded* local payload, and the local replica
    /// moves toward the transcoded (no-feedback) moved central. All loads
    /// and stores are Relaxed Hogwild snapshots, the same racy-by-design
    /// class as `elastic_pair`. Returns mean |local − central| before the
    /// move, matching the fp32 path's gap semantics.
    fn elastic_pair_codec(
        &self,
        local: &HogwildBuffer,
        lo: usize,
        hi: usize,
        alpha: f32,
        codec: WireCodec,
        residual: Option<&mut [f32]>,
    ) -> f32 {
        let n = hi - lo;
        let mut payload = vec![0f32; n];
        local.read_range_into(lo, &mut payload);
        let central = self.central.range(lo, hi);
        let mut gap = 0f64;
        for (p, a) in payload.iter().zip(central.iter()) {
            gap += (p - f32::from_bits(a.load(Relaxed))).abs() as f64;
        }
        // push leg: what the PS decodes from the trainer's message
        match residual {
            Some(r) => codec.encode_with_feedback(&mut payload, r),
            None => codec.transcode(&mut payload),
        }
        // central absorbs the decoded payload: w^PS += α (dec − w^PS)
        let mut reply = Vec::with_capacity(n);
        for (p, a) in payload.iter().zip(central.iter()) {
            let c = f32::from_bits(a.load(Relaxed));
            let moved = c + alpha * (p - c);
            a.store(moved.to_bits(), Relaxed);
            reply.push(moved);
        }
        self.central.mark_dirty_range(lo, hi);
        // reply leg: the PS transcodes the moved chunk back (no feedback —
        // residuals belong to the pushing trainer, push leg only)
        codec.transcode(&mut reply);
        // local moves toward the decoded central
        for (r, a) in reply.iter().zip(local.range(lo, hi).iter()) {
            let l = f32::from_bits(a.load(Relaxed));
            a.store((l + alpha * (r - l)).to_bits(), Relaxed);
        }
        local.mark_dirty_range(lo, hi);
        if n > 0 { (gap / n as f64) as f32 } else { 0.0 }
    }

    /// Max and summed |local − central| over `[lo, hi)` (racy snapshot).
    fn chunk_gap(
        local: &HogwildBuffer,
        central: &HogwildBuffer,
        lo: usize,
        hi: usize,
    ) -> (f32, f64) {
        let mut max_abs = 0f32;
        let mut sum_abs = 0f64;
        for i in lo..hi {
            let d = (local.get(i) - central.get(i)).abs();
            if d > max_abs {
                max_abs = d;
            }
            sum_abs += d as f64;
        }
        (max_abs, sum_abs)
    }

    /// Record one partition-scoped round's measured traffic under its
    /// partition index (called by the EASGD strategies after each round;
    /// `full_bytes` is the no-skip cost of the partition's current range,
    /// `2 × 4 × range.len`). Grows the table on first sight.
    pub fn note_partition_round(&self, partition: usize, stats: &PushStats, full_bytes: u64) {
        let mut v = self.partition_traffic.lock().unwrap();
        if partition >= v.len() {
            v.resize(partition + 1, PartitionTraffic::default());
        }
        let e = &mut v[partition];
        e.rounds += 1;
        e.bytes_moved += stats.bytes;
        e.full_round_bytes = full_bytes;
    }

    /// Cumulative measured push traffic since construction.
    pub fn traffic(&self) -> PsTrafficSnapshot {
        PsTrafficSnapshot {
            rounds: self.rounds.load(Relaxed),
            bytes_moved: self.bytes_moved.load(Relaxed),
            chunks_pushed: self.chunks_pushed.load(Relaxed),
            chunks_skipped: self.chunks_skipped.load(Relaxed),
            chunks_scan_skipped: self.chunks_scan_skipped.load(Relaxed),
            full_round_bytes: self.round_bytes(),
            per_partition: self.partition_traffic.lock().unwrap().clone(),
        }
    }

    /// The single source of truth for the push-chunk walk: `(lo, hi, shard
    /// node)` of every chunk, in round order, allocation-free (the sync
    /// loop runs it every shadow round). Both the sync loop and the public
    /// [`SyncPsGroup::push_chunk_ranges`] derive from this, so
    /// [`DeltaScanCache`] ordinals can never drift between them.
    fn push_chunks(&self) -> impl Iterator<Item = (usize, usize, NodeId)> + '_ {
        self.shards.iter().flat_map(move |s| {
            let step = if self.chunk_elems == 0 { (s.hi - s.lo).max(1) } else { self.chunk_elems };
            (s.lo..s.hi)
                .step_by(step)
                .map(move |lo| (lo, (lo + step).min(s.hi), s.node))
        })
    }

    /// The scoped walk of [`SyncPsGroup::push_chunks`]: every push chunk
    /// overlapping `[lo, hi)`, as `(global ordinal, clipped lo, clipped
    /// hi, shard node)`. Partitions that don't align to chunk boundaries
    /// own exactly their clipped slice; the global ordinal keys both the
    /// [`DeltaScanCache`] and the central version table, so adjacent
    /// partitions sharing a clipped chunk invalidate each other
    /// conservatively.
    fn push_chunks_scoped(
        &self,
        lo: usize,
        hi: usize,
    ) -> impl Iterator<Item = (usize, usize, usize, NodeId)> + '_ {
        self.push_chunks().enumerate().filter_map(move |(k, (clo, chi, node))| {
            let l = clo.max(lo);
            let h = chi.min(hi);
            (l < h).then_some((k, l, h, node))
        })
    }

    /// The `[lo, hi)` ranges of every push chunk, in the order one elastic
    /// round visits them (== [`DeltaScanCache`] ordinals).
    pub fn push_chunk_ranges(&self) -> Vec<(usize, usize)> {
        self.push_chunks().map(|(lo, hi, _)| (lo, hi)).collect()
    }

    /// The max-|Δ| threshold the *next* round of the group-level gate
    /// would gate at (diagnostic; adaptive mode tracks the sketch, so this
    /// moves between rounds).
    pub fn current_gate(&self) -> f32 {
        if self.gate.enabled() {
            self.gate.round_gate()
        } else {
            -1.0
        }
    }

    /// Bytes a *full* round moves through the sync-PS tier (both
    /// directions) — the no-skip reference; measured rounds report their
    /// actual bytes via [`PushStats`] / [`SyncPsGroup::traffic`].
    pub fn round_bytes(&self) -> u64 {
        2 * 4 * self.central.len() as u64
    }

    /// Bytes a *full* no-skip round over `range` would move under `codec`
    /// (both legs), walking the same clipped push chunks the round itself
    /// walks — the per-partition byte-fraction denominator the EASGD
    /// strategies feed to [`SyncPsGroup::note_partition_round`]. Under
    /// [`WireCodec::Fp32`] this is exactly `2 × 4 × range.len` (chunks
    /// tile), so fp32 runs keep the historical denominator bit for bit.
    pub fn round_bytes_codec_scoped(&self, codec: WireCodec, range: ParamRange) -> u64 {
        let lo = range.lo();
        let hi = range.hi().min(self.central.len());
        self.push_chunks_scoped(lo, hi)
            .map(|(_, l, h, _)| 2 * codec.wire_bytes(h - l))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Network;

    #[test]
    fn build_initializes_central_to_w0() {
        let mut net = Network::new(None);
        let w0 = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let g = SyncPsGroup::build(&w0, 2, &mut net);
        assert_eq!(g.central.to_vec(), w0);
        assert_eq!(g.shards.len(), 2);
        assert_eq!(g.shards[0].lo, 0);
        assert_eq!(g.shards[1].hi, 5);
    }

    #[test]
    fn elastic_sync_contracts_toward_each_other() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 16], 3, &mut net);
        let local = HogwildBuffer::from_slice(&vec![8.0; 16]);
        let gap = g.elastic_sync(&local, 0.5, trainer, &net);
        assert!((gap - 8.0).abs() < 1e-5);
        // alpha=0.5: both meet at 4.0
        assert!(local.to_vec().iter().all(|&x| (x - 4.0).abs() < 1e-5));
        assert!(g.central.to_vec().iter().all(|&x| (x - 4.0).abs() < 1e-5));
    }

    #[test]
    fn repeated_sync_converges_replicas_through_hub() {
        // two replicas never talk directly; they converge via w^PS
        let mut net = Network::new(None);
        let t0 = net.add_node(Role::Trainer);
        let t1 = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 8], 1, &mut net);
        let a = HogwildBuffer::from_slice(&vec![1.0; 8]);
        let b = HogwildBuffer::from_slice(&vec![-1.0; 8]);
        for _ in 0..100 {
            g.elastic_sync(&a, 0.3, t0, &net);
            g.elastic_sync(&b, 0.3, t1, &net);
        }
        let (av, bv) = (a.to_vec(), b.to_vec());
        for (x, y) in av.iter().zip(&bv) {
            assert!((x - y).abs() < 1e-3, "replicas did not converge: {x} vs {y}");
        }
    }

    #[test]
    fn traffic_lands_on_sync_ps_nics() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 100], 4, &mut net);
        let local = HogwildBuffer::from_slice(&vec![1.0; 100]);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(net.role_bytes(Role::SyncPs), g.round_bytes());
        assert_eq!(g.round_bytes(), 800);
        assert_eq!(st.bytes, 800);
        assert_eq!(st.chunks_skipped, 0);
    }

    #[test]
    fn chunked_pushes_move_the_same_total_bytes() {
        // chunk tiling preserves byte totals exactly (no delta gate)
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 103], 3, &mut net).with_push_chunking(7, 0.0);
        let local = HogwildBuffer::from_slice(&vec![1.0; 103]);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(st.bytes, g.round_bytes());
        assert_eq!(net.role_bytes(Role::SyncPs), g.round_bytes());
        // ceil(35/7) + ceil(34/7) * 2 chunks
        assert_eq!(st.chunks_pushed, 5 + 5 + 5);
        assert_eq!(st.chunks_skipped, 0);
        let t = g.traffic();
        assert_eq!(t.rounds, 1);
        assert_eq!(t.bytes_moved, st.bytes);
        assert!((t.push_fraction() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn delta_gate_skips_unchanged_chunks_both_legs() {
        // local == central over the second shard: every chunk there is
        // skipped, and its PS NIC moves zero bytes in BOTH directions (the
        // reply leg is suppressed along with the push)
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let w0 = vec![0.0f32; 64];
        let g = SyncPsGroup::build(&w0, 2, &mut net).with_push_chunking(8, 1e-6);
        // shard 0 = [0, 32), shard 1 = [32, 64)
        let mut local_v = vec![0.0f32; 64];
        for x in local_v.iter_mut().take(32) {
            *x = 2.0; // only shard 0 diverges
        }
        let local = HogwildBuffer::from_slice(&local_v);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        // shard 0: 4 chunks of 8 elems pushed, both legs = 2 * 32 * 4 bytes
        assert_eq!(st.chunks_pushed, 4);
        assert_eq!(st.chunks_skipped, 4);
        assert_eq!(st.bytes, 2 * 32 * 4);
        let quiet = g.shards[1].node;
        assert_eq!(net.tx(quiet), 0, "skipped chunks must suppress the reply leg");
        assert_eq!(net.rx(quiet), 0, "skipped chunks must suppress the push leg");
        let busy = g.shards[0].node;
        assert_eq!(net.rx(busy), 32 * 4);
        assert_eq!(net.tx(busy), 32 * 4);
        // skipped ranges were not elastically moved
        assert!(local.to_vec()[32..].iter().all(|&x| x == 0.0));
        assert!(g.central.to_vec()[..32].iter().all(|&x| (x - 1.0).abs() < 1e-6));
        // the reported gap still covers the whole vector (here: 2.0 over
        // half the elements -> 1.0 mean)
        assert!((st.gap - 1.0).abs() < 1e-5);
        let t = g.traffic();
        assert!((t.push_fraction() - 0.5).abs() < 1e-12);
        assert_eq!(t.avg_round_bytes(), (2 * 32 * 4) as f64);
    }

    #[test]
    fn pushed_chunks_move_exactly_chunk_sized_bytes() {
        // non-skipped chunks must account chunk size exactly, per leg
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 10], 1, &mut net).with_push_chunking(4, 1e-3);
        // diverge only [4, 8): exactly the second chunk of the one shard
        let mut lv = vec![0.0f32; 10];
        for x in lv.iter_mut().skip(4).take(4) {
            *x = 1.0;
        }
        let local = HogwildBuffer::from_slice(&lv);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(st.chunks_pushed, 1);
        assert_eq!(st.chunks_skipped, 2);
        assert_eq!(st.bytes, 2 * 4 * 4); // one 4-elem chunk, both legs
        assert_eq!(net.tx(trainer), 4 * 4);
        assert_eq!(net.rx(trainer), 4 * 4);
        // chunks tile 10 as [4, 4, 2], so the chunk-count and byte
        // fractions differ — pricing must use bytes (32 of the 80-byte
        // full round), not the 1-in-3 chunk count
        let t = g.traffic();
        assert!((t.push_fraction() - 1.0 / 3.0).abs() < 1e-12);
        assert!((t.byte_fraction() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn zero_threshold_never_skips() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 32], 2, &mut net).with_push_chunking(8, 0.0);
        let local = HogwildBuffer::from_slice(&vec![0.0; 32]); // identical!
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(st.chunks_skipped, 0);
        assert_eq!(st.bytes, g.round_bytes());
    }

    #[test]
    fn quantile_sketch_tracks_known_distribution() {
        let sk = QuantileSketch::new(64);
        assert_eq!(sk.quantile(0.5), None, "no answers before warmup");
        for i in 0..64 {
            sk.record(i as f32); // 0..63 uniform
        }
        assert_eq!(sk.samples(), 64);
        // ceil(0.5*64)-1 = 31; exactly 32/64 samples are <= 31
        assert_eq!(sk.quantile(0.5), Some(31.0));
        assert_eq!(sk.quantile(0.25), Some(15.0));
        // the window slides: overwrite with a shifted distribution
        for i in 0..64 {
            sk.record(1000.0 + i as f32);
        }
        assert_eq!(sk.quantile(0.5), Some(1031.0));
        // non-finite samples are dropped, not poisoning total_cmp order
        sk.record(f32::NAN);
        assert_eq!(sk.quantile(1.0), Some(1063.0));
    }

    #[test]
    fn adaptive_gate_skips_lowest_gap_chunks_after_warmup() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        // 64 chunks of 4 elems on one shard, target: skip half
        let p = 256;
        let g = SyncPsGroup::build(&vec![0.0; p], 1, &mut net)
            .with_push_chunking(4, 0.0)
            .with_adaptive_gate(0.5);
        // chunk c has constant gap c+1 (no zero gap, strictly increasing)
        let mk_local = |central: &HogwildBuffer| {
            let mut lv = central.to_vec();
            for (c, w) in lv.chunks_mut(4).enumerate() {
                for x in w.iter_mut() {
                    *x += (c + 1) as f32;
                }
            }
            HogwildBuffer::from_slice(&lv)
        };
        // round 1: sketch empty + no fixed threshold -> nothing skips
        let st = g.elastic_sync_stats(&mk_local(&g.central), 0.5, trainer, &net);
        assert_eq!(st.chunks_skipped, 0);
        assert_eq!(st.chunks_pushed, 64);
        // round 2: the sketch saw gaps 1..=64, median 32 -> chunks 1..=32 skip
        let st = g.elastic_sync_stats(&mk_local(&g.central), 0.5, trainer, &net);
        assert_eq!(st.chunks_skipped, 32);
        assert_eq!(st.chunks_pushed, 32);
        // skipped chunks moved zero bytes on both legs
        assert_eq!(st.bytes, 2 * 32 * 4 * 4);
        assert!((g.traffic().skip_fraction() - 0.25).abs() < 1e-12); // 32 of 128
        assert!(g.current_gate() > 0.0);
    }

    #[test]
    fn scan_cache_reuses_untouched_chunks_and_rescans_pushed_ones() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let p = 64;
        let g = SyncPsGroup::build(&vec![0.0; p], 2, &mut net).with_push_chunking(8, 1e-3);
        // dirty tracking at push-chunk granularity on the trainer replica
        let mut lv = vec![0.0f32; p];
        for x in lv.iter_mut().take(8) {
            *x = 4.0; // only chunk 0 diverges
        }
        let local = HogwildBuffer::from_slice(&lv).with_dirty_epochs(8);
        let mut cache = DeltaScanCache::new();
        // round 1: everything scanned (cold cache)
        let st = g.elastic_sync_cached(&local, 0.5, trainer, &net, &mut cache);
        assert_eq!(st.chunks_scan_skipped, 0);
        assert_eq!(st.chunks_pushed, 1);
        assert_eq!(st.chunks_skipped, 7);
        // round 2: the 7 clean chunks were untouched -> scans reused; the
        // pushed chunk was rewritten by the elastic move -> re-scanned
        let st = g.elastic_sync_cached(&local, 0.5, trainer, &net, &mut cache);
        assert_eq!(st.chunks_scan_skipped, 7);
        assert!(!cache.scan_skipped(0), "pushed chunk must be re-scanned");
        for k in 1..8 {
            assert!(cache.scan_skipped(k), "untouched chunk {k} must reuse its scan");
        }
        // touching one clean chunk forces exactly its re-scan
        local.set(17, 0.5); // chunk 2
        let st = g.elastic_sync_cached(&local, 0.5, trainer, &net, &mut cache);
        assert!(!cache.scan_skipped(2));
        assert!(st.chunks_scan_skipped < 8);
        // byte accounting still matches NIC counters exactly
        let nic: u64 = g.shards.iter().map(|s| net.rx(s.node) + net.tx(s.node)).sum();
        assert_eq!(nic, g.traffic().bytes_moved);
    }

    #[test]
    fn scan_reuse_still_feeds_the_adaptive_sketch() {
        // A reused (scan-skipped) chunk's cached gap still counts as this
        // round's gap observation. If reuse bypassed the sketch, the gate
        // would only ever see the rescanned (dirtier) subpopulation and the
        // skip rate would drift above the target under the default
        // dirty-epoch + adaptive-gate combination.
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let p = 64;
        let g = SyncPsGroup::build(&vec![0.0; p], 1, &mut net)
            .with_push_chunking(8, 0.0)
            .with_adaptive_gate(0.5);
        let local = HogwildBuffer::from_slice(&vec![0.0; p]).with_dirty_epochs(8);
        let mut cache = DeltaScanCache::new();
        // r1, r2: warmup gate pushes everything (entries invalidated each
        // round); r3: gate reaches 0.0, all 8 chunks re-scan then skip
        for _ in 0..3 {
            g.elastic_sync_cached(&local, 0.5, trainer, &net, &mut cache);
        }
        let before = g.gate.sketch_samples();
        // r4: every chunk untouched since its r3 scan -> all reused, and
        // every reuse still lands one observation in the sketch
        let st = g.elastic_sync_cached(&local, 0.5, trainer, &net, &mut cache);
        assert_eq!(st.chunks_scan_skipped, 8);
        assert_eq!(st.chunks_skipped, 8);
        assert_eq!(g.gate.sketch_samples(), before + 8);
    }

    #[test]
    fn cached_sync_without_dirty_tracking_always_scans() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 32], 1, &mut net).with_push_chunking(8, 1e-3);
        let local = HogwildBuffer::from_slice(&vec![0.0; 32]); // untracked
        let mut cache = DeltaScanCache::new();
        for _ in 0..3 {
            let st = g.elastic_sync_cached(&local, 0.5, trainer, &net, &mut cache);
            assert_eq!(st.chunks_scan_skipped, 0);
            assert_eq!(st.chunks_skipped, 4);
        }
    }

    #[test]
    fn peer_push_invalidates_cached_scan_via_central_versions() {
        // ROADMAP drift gap, closed: a chunk ANOTHER trainer pushed must
        // not stay scan-skipped here just because our replica is untouched
        let mut net = Network::new(None);
        let ta = net.add_node(Role::Trainer);
        let tb = net.add_node(Role::Trainer);
        let p = 32;
        let g = SyncPsGroup::build(&vec![0.0; p], 1, &mut net).with_push_chunking(8, 1e-3);
        // trainer A converges exactly, with dirty tracking + scan cache
        let a = HogwildBuffer::from_slice(&vec![0.0; p]).with_dirty_epochs(8);
        let mut ca = DeltaScanCache::new();
        let st = g.elastic_sync_cached(&a, 0.5, ta, &net, &mut ca);
        assert_eq!(st.chunks_skipped, 4);
        // round 2: nothing changed anywhere -> every scan reused
        let st = g.elastic_sync_cached(&a, 0.5, ta, &net, &mut ca);
        assert_eq!(st.chunks_scan_skipped, 4);
        // trainer B pushes chunk 0 (its replica diverged there)
        let mut bv = vec![0.0f32; p];
        for x in bv.iter_mut().take(8) {
            *x = 2.0;
        }
        let v0 = g.chunk_version(0);
        let st = g.elastic_sync_stats(&HogwildBuffer::from_slice(&bv), 0.5, tb, &net);
        assert_eq!(st.chunks_pushed, 1);
        assert_eq!(g.chunk_version(0), v0 + 1, "a push must bump its chunk version");
        // round 3: A's replica is still untouched, but chunk 0's central
        // moved — the version counter forces exactly that chunk to
        // re-scan, and the fresh scan sees (and re-syncs) B's drift
        let st = g.elastic_sync_cached(&a, 0.5, ta, &net, &mut ca);
        assert_eq!(st.chunks_scan_skipped, 3, "chunk 0 must re-scan after B's push");
        assert!(!ca.scan_skipped(0));
        assert_eq!(st.chunks_pushed, 1, "the drift B introduced must be re-synced");
    }

    #[test]
    fn partition_scoped_sync_touches_only_its_range() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let p = 64;
        let g = SyncPsGroup::build(&vec![0.0; p], 2, &mut net).with_push_chunking(8, 0.0);
        let local = HogwildBuffer::from_slice(&vec![2.0; p]);
        let mut cache = DeltaScanCache::new();
        // sync only the second quarter [16, 32)
        let range = ParamRange { offset: 16, len: 16 };
        let st = g.elastic_sync_partition(&local, range, 0.5, trainer, &net, &mut cache, None);
        assert_eq!(st.chunks_pushed, 2);
        assert_eq!(st.bytes, 2 * 16 * 4);
        assert!((st.gap - 2.0).abs() < 1e-6, "gap is over the partition, not the vector");
        // only the partition moved, on both sides
        let lv = local.to_vec();
        let cv = g.central.to_vec();
        assert!(lv[..16].iter().all(|&x| x == 2.0));
        assert!(lv[16..32].iter().all(|&x| (x - 1.0).abs() < 1e-6));
        assert!(lv[32..].iter().all(|&x| x == 2.0));
        assert!(cv[..16].iter().all(|&x| x == 0.0));
        assert!(cv[16..32].iter().all(|&x| (x - 1.0).abs() < 1e-6));
        assert!(cv[32..].iter().all(|&x| x == 0.0));
        // NIC counters carry exactly the partition's bytes
        assert_eq!(net.role_bytes(Role::SyncPs), st.bytes);
    }

    #[test]
    fn partition_boundaries_clip_push_chunks() {
        // chunk size 8, partition [4, 12): two clipped half-chunks (global
        // ordinals 0 and 1) move 4 elements each
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let g = SyncPsGroup::build(&vec![0.0; 16], 1, &mut net).with_push_chunking(8, 0.0);
        let local = HogwildBuffer::from_slice(&vec![1.0; 16]);
        let mut cache = DeltaScanCache::new();
        let range = ParamRange { offset: 4, len: 8 };
        let st = g.elastic_sync_partition(&local, range, 0.5, trainer, &net, &mut cache, None);
        assert_eq!(st.chunks_pushed, 2);
        assert_eq!(st.bytes, 2 * 8 * 4);
        let lv = local.to_vec();
        assert!(lv[..4].iter().all(|&x| x == 1.0));
        assert!(lv[4..12].iter().all(|&x| (x - 0.5).abs() < 1e-6));
        assert!(lv[12..].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn per_partition_gates_sketch_independently() {
        // two strategies' gates over disjoint partitions: each sketch only
        // sees its own partition's gap observations
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let p = 64;
        let g = SyncPsGroup::build(&vec![0.0; p], 1, &mut net).with_push_chunking(8, 0.0);
        let local = HogwildBuffer::from_slice(&vec![1.0; p]);
        let gate_a = DeltaGate::new(0.0, 0.5);
        let gate_b = DeltaGate::new(0.0, 0.5);
        let (mut ca, mut cb) = (DeltaScanCache::new(), DeltaScanCache::new());
        let ra = ParamRange { offset: 0, len: 32 };
        let rb = ParamRange { offset: 32, len: 32 };
        g.elastic_sync_partition(&local, ra, 0.5, trainer, &net, &mut ca, Some(&gate_a));
        assert_eq!(gate_a.sketch_samples(), 4, "4 chunks observed in partition A");
        assert_eq!(gate_b.sketch_samples(), 0, "partition B's gate saw nothing");
        g.elastic_sync_partition(&local, rb, 0.5, trainer, &net, &mut cb, Some(&gate_b));
        assert_eq!(gate_b.sketch_samples(), 4);
        // the group-level gate was bypassed entirely
        assert_eq!(g.gate.sketch_samples(), 0);
    }

    #[test]
    fn snapshot_absorb_merges_counters() {
        let a = PsTrafficSnapshot {
            rounds: 2,
            bytes_moved: 100,
            chunks_pushed: 3,
            chunks_skipped: 1,
            chunks_scan_skipped: 1,
            full_round_bytes: 80,
            per_partition: vec![
                PartitionTraffic { rounds: 1, bytes_moved: 60, full_round_bytes: 64 },
                PartitionTraffic { rounds: 1, bytes_moved: 40, full_round_bytes: 16 },
            ],
        };
        let mut m = PsTrafficSnapshot::default();
        m.absorb(&a);
        m.absorb(&a);
        assert_eq!(m.rounds, 4);
        assert_eq!(m.bytes_moved, 200);
        assert_eq!(m.full_round_bytes, 80);
        assert!((m.skip_fraction() - 0.25).abs() < 1e-12);
        assert!((m.scan_skip_fraction() - 0.25).abs() < 1e-12);
        // per-partition counters merge element-wise
        assert_eq!(m.per_partition.len(), 2);
        assert_eq!(m.per_partition[0].rounds, 2);
        assert_eq!(m.per_partition[0].bytes_moved, 120);
        assert_eq!(m.per_partition[1].full_round_bytes, 16);
        let shares = m.partition_byte_shares();
        assert!((shares[0] - 0.6).abs() < 1e-12);
        assert!((shares[1] - 0.4).abs() < 1e-12);
        // no partition bytes -> no shares
        assert!(PsTrafficSnapshot::default().partition_byte_shares().is_empty());
    }

    #[test]
    fn partition_rounds_record_per_partition_traffic() {
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let p = 64;
        let g = SyncPsGroup::build(&vec![0.0; p], 1, &mut net).with_push_chunking(8, 0.0);
        let local = HogwildBuffer::from_slice(&vec![2.0; p]);
        let mut cache = DeltaScanCache::new();
        // partition 1 covers [32, 64): two rounds recorded under index 1
        let range = ParamRange { offset: 32, len: 32 };
        for _ in 0..2 {
            let st = g.elastic_sync_partition(&local, range, 0.5, trainer, &net, &mut cache, None);
            g.note_partition_round(1, &st, 2 * 4 * range.len as u64);
        }
        let t = g.traffic();
        assert_eq!(t.per_partition.len(), 2, "table grows to cover partition 1");
        assert_eq!(t.per_partition[0], PartitionTraffic::default());
        assert_eq!(t.per_partition[1].rounds, 2);
        assert_eq!(t.per_partition[1].full_round_bytes, 2 * 4 * 32);
        // round 1 pushed everything, round 2 pushed the elastic residue
        assert!(t.per_partition[1].bytes_moved >= 2 * 4 * 32);
        assert!(t.per_partition[1].avg_round_bytes() > 0.0);
        let shares = t.partition_byte_shares();
        assert_eq!(shares[0], 0.0);
        assert!((shares[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn push_retries_ride_out_transient_drops() {
        use crate::net::FaultPlan;
        use crate::sync::prim::Arc;
        let plan = Arc::new(FaultPlan::parse("drop:t0@0.5", 0xBEEF).unwrap());
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let p = 64;
        let g = SyncPsGroup::build(&vec![0.0; p], 1, &mut net)
            .with_push_chunking(8, 0.0)
            // p=0.5 with 60 retries: every leg delivers with near certainty
            .with_push_retry(60, Duration::from_micros(1));
        let net = net.with_faults(plan);
        let local = HogwildBuffer::from_slice(&vec![2.0; p]);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(st.chunks_pushed, 8, "every chunk delivered after retries");
        assert_eq!(st.chunks_skipped, 0);
        assert!(st.push_retries > 0, "p=0.5 must have needed retries");
        assert_eq!(st.bytes, g.round_bytes());
        // the exactness invariant under faults: stats bytes == NIC bytes,
        // and dropped attempts live only in the plan's ledger
        assert_eq!(st.bytes, net.role_bytes(Role::SyncPs));
        assert!(net.dropped_bytes() > 0);
    }

    #[test]
    fn exhausted_retries_skip_chunks_and_keep_bytes_exact() {
        use crate::net::FaultPlan;
        use crate::sync::prim::Arc;
        let plan = Arc::new(FaultPlan::parse("crash:t0@sweep0", 0).unwrap());
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let w0 = vec![1.0f32; 16];
        let g = SyncPsGroup::build(&w0, 1, &mut net)
            .with_push_chunking(8, 0.0)
            .with_push_retry(3, Duration::from_micros(1));
        let net = net.with_faults(plan);
        let local = HogwildBuffer::from_slice(&vec![5.0; 16]);
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        assert_eq!(st.chunks_pushed, 0, "a crashed trainer delivers nothing");
        assert_eq!(st.chunks_skipped, 2, "exhausted chunks feed the skip metrics");
        assert_eq!(st.bytes, 0);
        assert_eq!(net.role_bytes(Role::SyncPs), 0, "zero NIC bytes moved");
        assert_eq!(g.central.to_vec(), w0, "central untouched by failed pushes");
        assert_eq!(local.to_vec(), vec![5.0; 16], "replica untouched too");
        assert!(net.dropped_bytes() > 0, "attempts land in the dropped ledger");
    }

    #[test]
    fn backoff_budget_caps_the_summed_sleeps_per_leg() {
        use crate::net::FaultPlan;
        use crate::sync::prim::Arc;
        // everything drops: every leg exhausts. Uncapped, 30 retries at
        // 1ms doubling would sleep ~12 days per leg; the 5ms budget must
        // bound each leg's summed sleeps (and the whole round) instead.
        let plan = Arc::new(FaultPlan::parse("drop:t0@1.0", 0).unwrap());
        let mut net = Network::new(None);
        let trainer = net.add_node(Role::Trainer);
        let w0 = vec![1.0f32; 16];
        let g = SyncPsGroup::build(&w0, 1, &mut net)
            .with_push_chunking(8, 0.0)
            .with_push_retry(30, Duration::from_millis(1))
            .with_push_backoff_budget(Duration::from_millis(5));
        let net = net.with_faults(plan);
        let local = HogwildBuffer::from_slice(&vec![5.0; 16]);
        let started = std::time::Instant::now();
        let st = g.elastic_sync_stats(&local, 0.5, trainer, &net);
        // 2 chunks × ≤5ms of budgeted sleep, with slack for a slow CI box
        assert!(
            started.elapsed() < Duration::from_secs(2),
            "budget failed to cap the leg: slept {:?}",
            started.elapsed()
        );
        assert_eq!(st.chunks_pushed, 0);
        assert_eq!(st.chunks_skipped, 2, "budget-exhausted chunks are skipped, not failed");
        assert_eq!(st.bytes, 0);
        assert_eq!(net.role_bytes(Role::SyncPs), 0);
        assert!(
            st.push_retries < 2 * 30,
            "the budget must cut retries short, not just clip sleeps: {}",
            st.push_retries
        );
    }
}
