//! Partitioning the dense vector for the partitioned shadow-sync fabric.
//!
//! The paper's framework (§3.2) partitions the dense parameters and gives
//! each partition its own background synchronization thread, "possibly with
//! a different algorithm per partition". This module is that layout: a
//! [`PartitionPlan`] cuts the flat parameter vector into `P` contiguous,
//! LPT-balanced [`ParamRange`]s and resolves which [`SyncAlgo`] owns each
//! one (`--algo-map`). `P = 1` reproduces the monolithic single-strategy
//! fabric bit for bit — with one *deliberate* exception: adaptive delta
//! gating now runs on per-strategy sketches (per trainer × partition)
//! instead of one sketch shared by every trainer, so multi-trainer
//! adaptive runs gate independently by design (the ROADMAP
//! per-trainer/per-shard follow-on). Fixed-threshold and ungated runs are
//! exactly equivalent, regression-tested in `tests/sync_integration.rs`.
//!
//! Two cut rules are provided:
//!
//! - [`lpt_contiguous_ranges`] packs *uniform*-cost blocks — the static
//!   plan every run starts on, and the only plan when adaptive
//!   repartitioning is off (`--repartition-every 0`), so golden P=1 /
//!   static-P runs are untouched by this module's growth.
//! - [`lpt_contiguous_ranges_weighted`] balances *measured* per-block
//!   costs (dirty-epoch write rates accumulated by
//!   [`super::repartition::RepartitionController`]): hot blocks make their
//!   partition shrink, cold blocks make it grow, so every partition's
//!   sync round costs about the same. Contiguity makes raw LPT
//!   reassembly unsound for non-uniform costs (bin *counts* no longer
//!   imply bin *costs*), so the weighted rule is the contiguous analogue:
//!   a greedy left-to-right cut targeting the LPT makespan
//!   `total_cost / P`, feasibility-clamped so every partition keeps at
//!   least one block.
//!
//! # Examples
//!
//! ```
//! use shadowsync::sync::partition::lpt_contiguous_ranges_weighted;
//!
//! // First half of the vector is written 9x as often as the second half:
//! // the cost-balanced cut gives the hot half more (smaller) partitions.
//! let ranges = lpt_contiguous_ranges_weighted(1024, 4, 64, |lo, _hi| {
//!     if lo < 512 { 9.0 } else { 1.0 }
//! });
//! assert_eq!(ranges.len(), 4);
//! assert_eq!(ranges[0].lo(), 0);
//! assert_eq!(ranges[3].hi(), 1024);
//! assert!(ranges[0].len < ranges[3].len, "hot partitions shrink");
//! ```

use anyhow::{bail, Result};

use crate::config::{RunConfig, SyncAlgo};
use crate::placement::{lpt, Item};

/// A contiguous view into the flat dense-parameter vector:
/// `[offset, offset + len)`. [`crate::sync::SyncCtx`] carries one of these
/// so a [`crate::sync::SyncStrategy`] operates on its partition only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParamRange {
    pub offset: usize,
    pub len: usize,
}

impl ParamRange {
    /// The whole-vector range — single-partition plans and the foreground
    /// drivers use exactly this.
    pub fn full(len: usize) -> Self {
        Self { offset: 0, len }
    }

    /// First element of the range.
    pub fn lo(&self) -> usize {
        self.offset
    }

    /// One past the last element of the range.
    pub fn hi(&self) -> usize {
        self.offset + self.len
    }
}

/// One entry of a [`PartitionPlan`]: a contiguous range plus the
/// synchronization algorithm that owns it.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Index of this partition in its plan (metrics key, `--algo-map` key).
    pub index: usize,
    pub range: ParamRange,
    pub algo: SyncAlgo,
}

/// The partitioned fabric's layout: `P` contiguous LPT-balanced ranges
/// covering the dense vector, each mapped to a sync algorithm.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    pub partitions: Vec<Partition>,
}

impl PartitionPlan {
    /// The trivial plan: one partition spanning everything (the monolithic
    /// pre-partitioning behaviour — bit for bit for fixed-threshold and
    /// ungated runs; see the module doc for the adaptive-gate exception).
    pub fn single(num_params: usize, algo: SyncAlgo) -> Self {
        Self {
            partitions: vec![Partition { index: 0, range: ParamRange::full(num_params), algo }],
        }
    }

    /// Build the plan for a run: `cfg.sync_partitions` contiguous ranges
    /// packed by [`lpt_contiguous_ranges`] at the EASGD push-chunk granule
    /// (so partitions align to push chunks whenever the vector is large
    /// enough), each resolved through [`RunConfig::partition_algo`].
    pub fn build(num_params: usize, cfg: &RunConfig) -> Result<Self> {
        let p = cfg.sync_partitions.max(1);
        if p > num_params {
            bail!("--sync-partitions {p} exceeds the {num_params} dense parameters");
        }
        if p == 1 && cfg.algo_map.is_none() {
            return Ok(Self::single(num_params, cfg.algo));
        }
        let partitions = lpt_contiguous_ranges(num_params, p, cfg.easgd_chunk_elems.max(1))
            .into_iter()
            .enumerate()
            .map(|(index, range)| Partition { index, range, algo: cfg.partition_algo(index) })
            .collect();
        Ok(Self { partitions })
    }

    /// Assemble a plan from pre-cut ranges (the adaptive repartitioner's
    /// entry point): partition `i` keeps `cfg.partition_algo(i)` — the
    /// `--algo-map` keys on the partition *index*, which is stable across
    /// repartitions — only the ranges move.
    pub fn from_ranges(ranges: Vec<ParamRange>, cfg: &RunConfig) -> Self {
        let partitions = ranges
            .into_iter()
            .enumerate()
            .map(|(index, range)| Partition { index, range, algo: cfg.partition_algo(index) })
            .collect();
        Self { partitions }
    }

    pub fn len(&self) -> usize {
        self.partitions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.partitions.is_empty()
    }

    /// Does any partition run `algo`?
    pub fn uses(&self, algo: SyncAlgo) -> bool {
        self.partitions.iter().any(|p| p.algo == algo)
    }

    /// Does any partition run a decentralized AllReduce algorithm?
    pub fn uses_collective(&self) -> bool {
        self.uses(SyncAlgo::Ma) || self.uses(SyncAlgo::Bmuf)
    }
}

/// Cut `[0, len)` into `p` contiguous ranges balanced by LPT: uniform-cost
/// blocks of up to `granule` elements are packed into `p` bins by
/// [`crate::placement::lpt`] — the same bin packing the paper's master uses
/// for PS shard placement — and partition `i` takes the `i`-th contiguous
/// run of blocks with the block count LPT gave bin `i`. Contiguity is what
/// makes a partition a [`ParamRange`] view of the replica; LPT supplies
/// balanced counts and keeps the cut compatible with non-uniform per-block
/// costs (e.g. profiled per-range write rates) later.
///
/// The granule is clamped so at least `p` blocks exist; every returned
/// range is non-empty and the ranges tile `[0, len)` exactly.
pub fn lpt_contiguous_ranges(len: usize, p: usize, granule: usize) -> Vec<ParamRange> {
    assert!(p >= 1 && len >= p, "need at least one element per partition");
    let granule = granule.clamp(1, (len / p).max(1));
    let blocks = len.div_ceil(granule);
    let items: Vec<Item> = (0..blocks)
        .map(|id| Item { id, cost: granule.min(len - id * granule) as f64 })
        .collect();
    let placement = lpt(&items, p);
    let mut counts = vec![0usize; p];
    for &bin in &placement.assignment {
        counts[bin] += 1;
    }
    let mut out = Vec::with_capacity(p);
    let mut lo = 0usize;
    for &c in &counts {
        let hi = (lo + c * granule).min(len);
        out.push(ParamRange { offset: lo, len: hi - lo });
        lo = hi;
    }
    debug_assert_eq!(out.last().map(|r| r.hi()), Some(len));
    out
}

/// Cut `[0, len)` into `p` contiguous ranges balanced by *measured* block
/// costs: blocks of up to `granule` elements are priced by `cost(lo, hi)`
/// (non-finite or negative costs count as 0; an all-zero profile falls back
/// to uniform element counts), and a greedy left-to-right cut closes each
/// partition once its accumulated cost reaches the LPT makespan target
/// `remaining_cost / remaining_partitions` (midpoint rule: a block joins
/// the open partition only while half of it still fits under the target).
///
/// Contiguity is what raw LPT cannot give for non-uniform costs — packing
/// blocks into bins by cost order and then re-reading bin *counts* as
/// contiguous runs divorces each run from the cost its bin balanced — so
/// this is the contiguous analogue the adaptive repartitioner uses: hot
/// (high write rate) regions end up split across more, smaller partitions
/// and cold regions merge into fewer, larger ones.
///
/// The same structural guarantees as [`lpt_contiguous_ranges`] hold: every
/// returned range is non-empty, boundaries are block-aligned (except the
/// tail), and the `p` ranges tile `[0, len)` exactly — no element is lost
/// or double-counted across a replan.
pub fn lpt_contiguous_ranges_weighted<F>(
    len: usize,
    p: usize,
    granule: usize,
    cost: F,
) -> Vec<ParamRange>
where
    F: Fn(usize, usize) -> f64,
{
    assert!(p >= 1 && len >= p, "need at least one element per partition");
    let granule = granule.clamp(1, (len / p).max(1));
    let blocks = len.div_ceil(granule);
    let mut costs: Vec<f64> = (0..blocks)
        .map(|b| {
            let lo = b * granule;
            let hi = (lo + granule).min(len);
            let c = cost(lo, hi);
            if c.is_finite() && c > 0.0 {
                c
            } else {
                0.0
            }
        })
        .collect();
    let mut total: f64 = costs.iter().sum();
    if total <= 0.0 {
        // degenerate profile (nothing measured): balance element counts
        for (b, c) in costs.iter_mut().enumerate() {
            *c = granule.min(len - b * granule) as f64;
        }
        total = costs.iter().sum();
    }
    let mut out = Vec::with_capacity(p);
    let mut next = 0usize; // next unassigned block
    let mut lo = 0usize;
    let mut remaining = total;
    for bin in 0..p {
        let bins_left = p - bin;
        let take = if bins_left == 1 {
            blocks - next // the last partition absorbs the tail
        } else {
            // leave at least one block for every remaining partition
            let max_take = blocks - next - (bins_left - 1);
            let target = remaining / bins_left as f64;
            let mut acc = 0.0;
            let mut take = 0usize;
            while take < max_take {
                let c = costs[next + take];
                if take > 0 && acc + 0.5 * c > target {
                    break;
                }
                acc += c;
                take += 1;
            }
            take
        };
        remaining -= costs[next..next + take].iter().sum::<f64>();
        next += take;
        let hi = (lo + take * granule).min(len);
        out.push(ParamRange { offset: lo, len: hi - lo });
        lo = hi;
    }
    debug_assert_eq!(out.last().map(|r| r.hi()), Some(len));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn param_range_views() {
        let r = ParamRange::full(10);
        assert_eq!((r.lo(), r.hi(), r.len), (0, 10, 10));
        let r = ParamRange { offset: 4, len: 3 };
        assert_eq!((r.lo(), r.hi()), (4, 7));
    }

    #[test]
    fn single_plan_covers_everything() {
        let plan = PartitionPlan::single(537, SyncAlgo::Easgd);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.partitions[0].range, ParamRange::full(537));
        assert!(plan.uses(SyncAlgo::Easgd));
        assert!(!plan.uses_collective());
    }

    #[test]
    fn ranges_tile_exactly_and_balance() {
        check("lpt-contiguous", 40, |g| {
            let p = g.usize_in(1, 8);
            let len = g.usize_in(p, 5_000);
            let granule = g.usize_in(1, 700);
            let rs = lpt_contiguous_ranges(len, p, granule);
            assert_eq!(rs.len(), p);
            assert_eq!(rs[0].lo(), 0);
            assert_eq!(rs[p - 1].hi(), len);
            for w in rs.windows(2) {
                assert_eq!(w[0].hi(), w[1].lo(), "ranges must be contiguous");
            }
            for r in &rs {
                assert!(r.len > 0, "empty partition in {rs:?}");
            }
            // LPT balance at block granularity: spread <= one granule
            let g_eff = granule.clamp(1, (len / p).max(1));
            let sizes: Vec<usize> = rs.iter().map(|r| r.len).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(
                mx - mn <= 2 * g_eff,
                "imbalance {mx}-{mn} over granule {g_eff}: {sizes:?}"
            );
        });
    }

    #[test]
    fn granule_aligns_partition_boundaries() {
        let rs = lpt_contiguous_ranges(1024, 4, 64);
        for r in &rs[..3] {
            assert_eq!(r.hi() % 64, 0, "boundary {r:?} not chunk-aligned");
        }
        assert_eq!(rs[3].hi(), 1024);
    }

    #[test]
    fn plan_build_resolves_algo_map() {
        let cfg = RunConfig {
            sync_partitions: 4,
            shadow_threads: 2,
            algo_map: Some("easgd:0-1,ma:2-3".parse().unwrap()),
            easgd_chunk_elems: 8,
            ..RunConfig::default()
        };
        let plan = PartitionPlan::build(64, &cfg).unwrap();
        assert_eq!(plan.len(), 4);
        assert_eq!(plan.partitions[0].algo, SyncAlgo::Easgd);
        assert_eq!(plan.partitions[1].algo, SyncAlgo::Easgd);
        assert_eq!(plan.partitions[2].algo, SyncAlgo::Ma);
        assert_eq!(plan.partitions[3].algo, SyncAlgo::Ma);
        assert!(plan.uses_collective());
        assert!(plan.uses(SyncAlgo::Easgd));
    }

    #[test]
    fn weighted_ranges_tile_exactly_for_any_profile() {
        check("lpt-weighted", 40, |g| {
            let p = g.usize_in(1, 8);
            let len = g.usize_in(p.max(2), 5_000);
            let granule = g.usize_in(1, 700);
            // hot head: the first ~quarter of the vector costs 20x
            let hot_hi = len / 4;
            let rs = lpt_contiguous_ranges_weighted(len, p, granule, |lo, _hi| {
                if lo < hot_hi {
                    20.0
                } else {
                    1.0
                }
            });
            assert_eq!(rs.len(), p);
            assert_eq!(rs[0].lo(), 0);
            assert_eq!(rs[p - 1].hi(), len);
            for w in rs.windows(2) {
                assert_eq!(w[0].hi(), w[1].lo(), "ranges must be contiguous");
            }
            for r in &rs {
                assert!(r.len > 0, "empty partition in {rs:?}");
            }
        });
    }

    #[test]
    fn weighted_cut_splits_the_hot_region_across_partitions() {
        // 16 blocks of 64; the first 4 blocks carry almost all the cost:
        // cost-balancing splits them across partitions while the cold tail
        // merges into one big partition
        let rs = lpt_contiguous_ranges_weighted(1024, 4, 64, |lo, _hi| {
            if lo < 256 {
                100.0
            } else {
                1.0
            }
        });
        assert_eq!(rs.len(), 4);
        assert_eq!(rs.last().unwrap().hi(), 1024);
        // the hot head is covered by more than one partition...
        assert!(rs[0].hi() < 256, "hot region not split: {rs:?}");
        // ...and the cold tail's partition is the largest by far
        let uniform = 1024 / 4;
        assert!(rs[0].len < uniform, "hot partition did not shrink: {rs:?}");
        assert!(rs[3].len > uniform, "cold partition did not grow: {rs:?}");
    }

    #[test]
    fn weighted_cut_degenerate_costs_fall_back_to_uniform() {
        // zero / NaN cost profiles must still produce a sane balanced plan
        for bad in [0.0f64, f64::NAN, -3.0] {
            let rs = lpt_contiguous_ranges_weighted(1000, 4, 10, |_, _| bad);
            assert_eq!(rs.len(), 4);
            assert_eq!(rs[3].hi(), 1000);
            let sizes: Vec<usize> = rs.iter().map(|r| r.len).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 20, "uniform fallback unbalanced: {sizes:?}");
        }
    }

    #[test]
    fn from_ranges_keeps_index_stable_algo_mapping() {
        let cfg = RunConfig {
            sync_partitions: 4,
            shadow_threads: 2,
            algo_map: Some("easgd:0-1,ma:2-3".parse().unwrap()),
            ..RunConfig::default()
        };
        let ranges = lpt_contiguous_ranges_weighted(64, 4, 8, |lo, _| {
            if lo < 16 {
                10.0
            } else {
                1.0
            }
        });
        let plan = PartitionPlan::from_ranges(ranges, &cfg);
        assert_eq!(plan.len(), 4);
        // the algo map keys on index, so a replan never migrates algorithms
        assert_eq!(plan.partitions[0].algo, SyncAlgo::Easgd);
        assert_eq!(plan.partitions[1].algo, SyncAlgo::Easgd);
        assert_eq!(plan.partitions[2].algo, SyncAlgo::Ma);
        assert_eq!(plan.partitions[3].algo, SyncAlgo::Ma);
    }

    #[test]
    fn plan_build_rejects_more_partitions_than_params() {
        let cfg = RunConfig {
            sync_partitions: 10,
            shadow_threads: 1,
            ..RunConfig::default()
        };
        assert!(PartitionPlan::build(5, &cfg).is_err());
    }

    #[test]
    fn p1_plan_is_the_single_plan() {
        let cfg = RunConfig::default();
        let plan = PartitionPlan::build(537, &cfg).unwrap();
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.partitions[0].range, ParamRange::full(537));
        assert_eq!(plan.partitions[0].algo, cfg.algo);
    }
}
