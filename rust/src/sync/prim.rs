//! The concurrency-primitive facade for the shadow-sync fabric.
//!
//! Every concurrent module in `sync/` and `tensor/` imports its atomics,
//! locks, condvars, and thread entry points from here instead of from
//! `std::sync`/`std::thread` (enforced by `cargo run -p xtask -- lint`).
//! Normally these are straight re-exports of `std`; under
//! `RUSTFLAGS="--cfg shadowsync_loom"` they swap to the bounded model
//! checker in [`crate::mc`], so `tests/loom_models.rs` can exhaustively
//! explore schedules of the real protocol code — not a copy of it.
//!
//! Two deliberate exceptions:
//!
//! * [`Arc`] is always `std::sync::Arc`. It carries no protocol state —
//!   only reference counts — and modeling it would add schedule points
//!   without adding behaviors (loom itself models `Arc` only to catch
//!   leak/drop races, which the protocol models here do not exercise).
//! * [`Ordering`] is always the `std` enum; the model checker interprets
//!   it (see the `mc` module docs for exactly how each ordering maps onto
//!   the PSO store-buffer semantics).
//!
//! Everything else must come from this module. When adding a new primitive
//! to the fabric, extend the facade (and `mc`) rather than importing `std`
//! directly — the lint will hold you to it.

/// `std::sync::Arc` in both configs (refcount only, never protocol state).
pub use std::sync::Arc;
/// The std orderings in both configs; the model checker interprets them.
pub use std::sync::atomic::Ordering;

#[cfg(not(shadowsync_loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize};
#[cfg(not(shadowsync_loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

#[cfg(not(shadowsync_loom))]
pub use std::thread;

#[cfg(shadowsync_loom)]
pub use crate::mc::{
    AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Condvar, Mutex, MutexGuard, RwLock,
    RwLockReadGuard, RwLockWriteGuard,
};

#[cfg(shadowsync_loom)]
pub use crate::mc::thread;
