//! Chunked ring-AllReduce fabric for the decentralized algorithms (MA, BMUF).
//!
//! Semantics match a ring all-reduce over the trainers: every active member
//! contributes a vector, everyone receives the element-wise mean. Because
//! training is one-pass, trainers finish their shards at different times;
//! members therefore [`AllReduceGroup::leave`] the group when done and
//! rounds complete over the *remaining* membership (a real collective over
//! dynamic process groups behaves the same way after a resize).
//!
//! ## The chunked schedule
//!
//! The parameter vector is split into `C` chunks
//! ([`AllReduceGroup::with_chunks`], `RunConfig::allreduce_chunks`). Each
//! chunk is reduced through an explicit reduce-scatter + all-gather ring
//! schedule over the round's `n` contributors: a chunk of length `L` is cut
//! into `n` near-equal segments, and every member sends one segment per hop
//! to its ring successor for `n-1` reduce-scatter hops followed by `n-1`
//! all-gather hops. All chunks move together on each hop (the pipelined
//! order a multi-threaded chunk-parallel reduction would use), so a member
//! performs `2·(n-1)` wire transfers per round regardless of `C`.
//!
//! ## Measured-traffic accounting
//!
//! Every per-hop transfer is driven through [`Network::transfer`], so NIC
//! counters (and the optional bandwidth-delay model) see the *actual* ring
//! traffic of every round instead of a closed-form estimate: per member and
//! round the measured bytes land within one chunk-segment of rounding of
//! the textbook `2·(n-1)/n · bytes` ring formula
//! ([`AllReduceGroup::ring_bytes_per_member`], kept as the reference used
//! by the paper-scale throughput model in `sim/`). Because each member
//! drives its own hops, traffic is attributed to that member's own NIC.
//!
//! ## Correct overlap with dynamic membership
//!
//! Results are *version-stamped per generation*: a completed round is
//! parked (mean, ring membership, exact contributor count) until every one
//! of its waiters has copied it out, so a fast round `N+1` — or `N+2`, after
//! mid-round [`AllReduceGroup::leave`]s — can never clobber round `N`'s mean
//! before slow round-`N` waiters observe it, and every joiner is told the
//! exact contributor count of *its own* round. Retired round buffers are
//! recycled through a pool, so the steady state allocates nothing.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{ensure, Result};

use crate::net::{Network, NodeId};

/// What one completed collective round reports to each contributor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundOutcome {
    /// Monotonic round index within the group.
    pub generation: u64,
    /// Exact number of vectors that entered this round's mean.
    pub contributors: usize,
    /// Bytes this member pushed onto the wire for this round (its
    /// reduce-scatter + all-gather hops, as accounted through `Network`).
    pub bytes_tx: u64,
}

/// A finalized round, parked until all its waiters have copied the mean.
struct Round {
    generation: u64,
    mean: Vec<f32>,
    /// Contributor NICs in join order — the ring of this round.
    ring: Vec<NodeId>,
    /// Waiters that still have to copy `mean` out.
    readers_left: usize,
}

struct State {
    active: usize,
    joined: usize,
    /// NICs of the current round's contributors, in join order.
    contributors: Vec<NodeId>,
    sum: Vec<f32>,
    generation: u64,
    /// Completed rounds not yet copied out by all their waiters.
    done: VecDeque<Round>,
    /// Recycled `mean`/`ring` buffers (steady state allocates nothing).
    mean_pool: Vec<Vec<f32>>,
    ring_pool: Vec<Vec<NodeId>>,
}

/// A dynamic-membership mean-AllReduce group over a chunked ring schedule.
pub struct AllReduceGroup {
    state: Mutex<State>,
    cv: Condvar,
    /// Vector length every contribution must match.
    pub len: usize,
    /// Chunk count `C` of the ring schedule (1 = flat single-chunk rings).
    pub chunks: usize,
}

impl AllReduceGroup {
    /// `members` trainers, vectors of length `len`, flat (single-chunk).
    pub fn new(members: usize, len: usize) -> Self {
        Self {
            state: Mutex::new(State {
                active: members,
                joined: 0,
                contributors: Vec::with_capacity(members),
                sum: vec![0.0; len],
                generation: 0,
                done: VecDeque::new(),
                mean_pool: Vec::new(),
                ring_pool: Vec::new(),
            }),
            cv: Condvar::new(),
            len,
            chunks: 1,
        }
    }

    /// Split the vector into `chunks` chunks for the ring schedule.
    pub fn with_chunks(mut self, chunks: usize) -> Self {
        self.chunks = chunks.max(1);
        self
    }

    /// `len / parts` with the remainder spread over the leading parts —
    /// the same split rule as `placement::equal_ranges`.
    fn part_len(len: usize, parts: usize, idx: usize) -> usize {
        len / parts + usize::from(idx < len % parts)
    }

    /// Close the pending round: stamp the mean + ring + exact contributor
    /// count with the current generation and park it for its waiters.
    /// `finalizer_copies` is true when the caller is the final joiner (it
    /// copies the mean inline and never waits).
    fn finalize(st: &mut State, finalizer_copies: bool) {
        let n = st.joined;
        debug_assert!(n > 0, "finalize of an empty round");
        let len = st.sum.len();
        let fresh = match st.mean_pool.pop() {
            Some(mut v) => {
                v.fill(0.0);
                v
            }
            None => vec![0.0; len],
        };
        let mut mean = std::mem::replace(&mut st.sum, fresh);
        let inv = 1.0 / n as f32;
        for m in &mut mean {
            *m *= inv;
        }
        let empty_ring = st.ring_pool.pop().unwrap_or_default();
        let ring = std::mem::replace(&mut st.contributors, empty_ring);
        st.done.push_back(Round {
            generation: st.generation,
            mean,
            ring,
            readers_left: if finalizer_copies { n - 1 } else { n },
        });
        st.joined = 0;
        st.generation += 1;
    }

    /// Retire fully-read rounds and recycle their buffers.
    fn gc(st: &mut State) {
        let mut i = 0;
        while i < st.done.len() {
            if st.done[i].readers_left == 0 {
                let r = st.done.remove(i).expect("index in bounds");
                st.mean_pool.push(r.mean);
                let mut ring = r.ring;
                ring.clear();
                st.ring_pool.push(ring);
            } else {
                i += 1;
            }
        }
    }

    /// Contribute `data` as the member whose NIC is `me`, block until the
    /// round completes, and replace `data` with the mean over this round's
    /// contributors. Drives this member's ring hops through `net` and
    /// returns the round's generation, exact contributor count, and the
    /// bytes this member moved.
    pub fn allreduce_mean(
        &self,
        data: &mut [f32],
        me: NodeId,
        net: &Network,
    ) -> Result<RoundOutcome> {
        self.allreduce_mean_inner(data, me, net, None)
    }

    /// `allreduce_mean` with an optional artificial delay between being
    /// woken and copying the result out — test-only hook that forces the
    /// "slow waiter vs. fast next round" interleaving deterministically.
    fn allreduce_mean_inner(
        &self,
        data: &mut [f32],
        me: NodeId,
        net: &Network,
        wake_delay: Option<Duration>,
    ) -> Result<RoundOutcome> {
        ensure!(data.len() == self.len, "allreduce length mismatch");
        let mut st = self.state.lock().unwrap();
        ensure!(st.active > 0, "allreduce on an empty group");
        for (s, &d) in st.sum.iter_mut().zip(data.iter()) {
            *s += d;
        }
        let my_pos = st.contributors.len();
        st.contributors.push(me);
        st.joined += 1;
        let my_gen = st.generation;
        if st.joined == st.active {
            Self::finalize(&mut st, true);
            let round = st.done.back().expect("round just finalized");
            data.copy_from_slice(&round.mean);
            let n = round.ring.len();
            let succ = round.ring[(my_pos + 1) % n];
            Self::gc(&mut st);
            drop(st);
            self.cv.notify_all();
            let bytes_tx = self.account_ring(me, succ, my_pos, n, net);
            return Ok(RoundOutcome { generation: my_gen, contributors: n, bytes_tx });
        }
        while st.generation == my_gen {
            st = self.cv.wait(st).unwrap();
        }
        if let Some(d) = wake_delay {
            drop(st);
            std::thread::sleep(d);
            st = self.state.lock().unwrap();
        }
        // The version stamp makes this lookup safe under overlap: our round
        // is parked until every waiter (us included) has copied it out.
        let idx = st
            .done
            .iter()
            .position(|r| r.generation == my_gen)
            .expect("round result retired before all waiters copied it");
        let round = &mut st.done[idx];
        data.copy_from_slice(&round.mean);
        round.readers_left -= 1;
        let n = round.ring.len();
        let succ = round.ring[(my_pos + 1) % n];
        Self::gc(&mut st);
        drop(st);
        let bytes_tx = self.account_ring(me, succ, my_pos, n, net);
        Ok(RoundOutcome { generation: my_gen, contributors: n, bytes_tx })
    }

    /// Drive this member's hops of the chunked ring schedule through the
    /// network: `n-1` reduce-scatter hops then `n-1` all-gather hops, each
    /// moving one segment of every chunk to the ring successor. Returns the
    /// bytes sent.
    fn account_ring(
        &self,
        me: NodeId,
        succ: NodeId,
        my_pos: usize,
        n: usize,
        net: &Network,
    ) -> u64 {
        if n < 2 {
            return 0;
        }
        let seg_bytes = |seg: usize| -> u64 {
            let mut elems = 0u64;
            for c in 0..self.chunks {
                let chunk_len = Self::part_len(self.len, self.chunks, c);
                elems += Self::part_len(chunk_len, n, seg) as u64;
            }
            4 * elems
        };
        let mut tx = 0u64;
        // reduce-scatter hop s: position p sends segment (p - s) mod n
        for s in 0..n - 1 {
            let bytes = seg_bytes((my_pos + n - s) % n);
            net.transfer(me, succ, bytes);
            tx += bytes;
        }
        // all-gather hop s: position p sends segment (p + 1 - s) mod n
        for s in 0..n - 1 {
            let bytes = seg_bytes((my_pos + 1 + n - s) % n);
            net.transfer(me, succ, bytes);
            tx += bytes;
        }
        tx
    }

    /// Permanently remove one member. If everyone else is already waiting,
    /// the pending round completes without the leaver.
    pub fn leave(&self) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.active > 0);
        st.active -= 1;
        if st.active > 0 && st.joined == st.active {
            Self::finalize(&mut st, false);
            drop(st);
            self.cv.notify_all();
        }
    }

    pub fn active(&self) -> usize {
        self.state.lock().unwrap().active
    }

    /// Members currently blocked in (or summed into) the pending round.
    pub fn pending(&self) -> usize {
        self.state.lock().unwrap().joined
    }

    /// Rounds completed so far (the next round's generation stamp).
    pub fn completed_rounds(&self) -> u64 {
        self.state.lock().unwrap().generation
    }

    /// Closed-form ring bytes each member moves per direction per round —
    /// the reference the measured per-hop traffic is checked against, and
    /// what the paper-scale throughput model in `sim/` uses.
    pub fn ring_bytes_per_member(&self, participants: usize) -> u64 {
        if participants <= 1 {
            return 0;
        }
        let vec_bytes = (self.len * 4) as u64;
        2 * vec_bytes * (participants as u64 - 1) / participants as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Role;
    use crate::util::rng::Rng;
    use std::collections::HashMap;
    use std::sync::Arc;

    fn net_with(n: usize) -> (Arc<Network>, Vec<NodeId>) {
        let mut net = Network::new(None);
        let nodes = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
        (Arc::new(net), nodes)
    }

    #[test]
    fn mean_matches_sequential_sum() {
        let n = 4;
        let g = Arc::new(AllReduceGroup::new(n, 8));
        let (net, nodes) = net_with(n);
        let mut hs = Vec::new();
        for r in 0..n {
            let g = g.clone();
            let net = net.clone();
            let node = nodes[r];
            hs.push(std::thread::spawn(move || {
                let mut v = vec![(r + 1) as f32; 8];
                let out = g.allreduce_mean(&mut v, node, &net).unwrap();
                (v, out)
            }));
        }
        for h in hs {
            let (v, out) = h.join().unwrap();
            // mean of 1,2,3,4 = 2.5
            assert!(v.iter().all(|&x| (x - 2.5).abs() < 1e-6), "{v:?}");
            assert_eq!(out.contributors, 4);
            assert_eq!(out.generation, 0);
        }
    }

    #[test]
    fn repeated_rounds_stay_consistent() {
        let n = 3;
        let g = Arc::new(AllReduceGroup::new(n, 4).with_chunks(2));
        let (net, nodes) = net_with(n);
        let mut hs = Vec::new();
        for r in 0..n {
            let g = g.clone();
            let net = net.clone();
            let node = nodes[r];
            hs.push(std::thread::spawn(move || {
                let mut acc = Vec::new();
                for round in 0..50 {
                    let mut v = vec![(r * 50 + round) as f32; 4];
                    g.allreduce_mean(&mut v, node, &net).unwrap();
                    acc.push(v[0]);
                }
                acc
            }));
        }
        let results: Vec<Vec<f32>> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        for round in 0..50 {
            let want = (0..n).map(|r| (r * 50 + round) as f32).sum::<f32>() / n as f32;
            for res in &results {
                assert!((res[round] - want).abs() < 1e-4);
            }
        }
        assert_eq!(g.completed_rounds(), 50);
    }

    #[test]
    fn leaver_unblocks_pending_round() {
        let g = Arc::new(AllReduceGroup::new(3, 2));
        let (net, nodes) = net_with(3);
        let g2 = g.clone();
        let (net2, node0) = (net.clone(), nodes[0]);
        let waiter = std::thread::spawn(move || {
            let mut v = vec![6.0, 6.0];
            let out = g2.allreduce_mean(&mut v, node0, &net2).unwrap();
            (v, out)
        });
        let g3 = g.clone();
        let (net3, node1) = (net.clone(), nodes[1]);
        let waiter2 = std::thread::spawn(move || {
            let mut v = vec![2.0, 2.0];
            let out = g3.allreduce_mean(&mut v, node1, &net3).unwrap();
            (v, out)
        });
        // give the waiters time to block, then the third member leaves
        std::thread::sleep(std::time::Duration::from_millis(50));
        g.leave();
        let (v, out) = waiter.join().unwrap();
        let (v2, out2) = waiter2.join().unwrap();
        // round completed over the two contributors: mean = 4
        assert_eq!(v, vec![4.0, 4.0]);
        assert_eq!(v2, vec![4.0, 4.0]);
        // both waiters learn the exact contributor count of their round
        assert_eq!(out.contributors, 2);
        assert_eq!(out2.contributors, 2);
        assert_eq!(g.active(), 2);
    }

    #[test]
    fn singleton_group_is_identity() {
        let g = AllReduceGroup::new(1, 3);
        let (net, nodes) = net_with(1);
        let mut v = vec![1.0, 2.0, 3.0];
        let out = g.allreduce_mean(&mut v, nodes[0], &net).unwrap();
        assert_eq!(out.contributors, 1);
        assert_eq!(out.bytes_tx, 0);
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
        assert_eq!(g.ring_bytes_per_member(1), 0);
        assert_eq!(net.tx(nodes[0]), 0);
    }

    #[test]
    fn ring_cost_formula() {
        let g = AllReduceGroup::new(4, 100);
        // 2 * 400 bytes * 3/4 = 600
        assert_eq!(g.ring_bytes_per_member(4), 600);
    }

    #[test]
    fn length_mismatch_rejected() {
        let g = AllReduceGroup::new(1, 3);
        let (net, nodes) = net_with(1);
        let mut v = vec![0.0; 2];
        assert!(g.allreduce_mean(&mut v, nodes[0], &net).is_err());
    }

    #[test]
    fn measured_traffic_matches_ring_formula() {
        // n | len: the per-member measured bytes equal the formula exactly
        let n = 4;
        let g = Arc::new(AllReduceGroup::new(n, 100));
        let (net, nodes) = net_with(n);
        let mut hs = Vec::new();
        for &node in &nodes {
            let g = g.clone();
            let net = net.clone();
            hs.push(std::thread::spawn(move || {
                let mut v = vec![1.0f32; 100];
                g.allreduce_mean(&mut v, node, &net).unwrap()
            }));
        }
        for h in hs {
            let out = h.join().unwrap();
            assert_eq!(out.bytes_tx, 600); // == ring_bytes_per_member(4)
        }
        for &node in &nodes {
            assert_eq!(net.tx(node), 600);
            assert_eq!(net.rx(node), 600);
        }
    }

    #[test]
    fn chunked_traffic_sums_to_exact_aggregate() {
        // Whatever the chunking, total ring traffic over all members is
        // exactly 2(n-1) * vec_bytes, and each member is within one
        // chunk-segment of the per-member formula.
        for &(n, len, chunks) in &[(3usize, 101usize, 1usize), (4, 1_037, 8), (5, 997, 64)] {
            let g = Arc::new(AllReduceGroup::new(n, len).with_chunks(chunks));
            let (net, nodes) = net_with(n);
            let mut hs = Vec::new();
            for &node in &nodes {
                let g = g.clone();
                let net = net.clone();
                hs.push(std::thread::spawn(move || {
                    let mut v = vec![1.0f32; len];
                    g.allreduce_mean(&mut v, node, &net).unwrap()
                }));
            }
            let mut total = 0u64;
            for h in hs {
                let out = h.join().unwrap();
                total += out.bytes_tx;
                let formula = g.ring_bytes_per_member(n);
                let slack = 4 * 2 * chunks as u64; // one element per chunk, both phases
                assert!(
                    out.bytes_tx.abs_diff(formula) <= slack,
                    "n={n} len={len} C={chunks}: measured {} vs formula {formula}",
                    out.bytes_tx
                );
            }
            assert_eq!(total, 2 * (n as u64 - 1) * len as u64 * 4);
            let nic_total: u64 = nodes.iter().map(|&nd| net.tx(nd)).sum();
            assert_eq!(nic_total, total);
        }
    }

    #[test]
    fn contributor_count_is_exact_after_membership_shrinks() {
        // Regression: the old code reported `active.max(1)` at wake time,
        // which is wrong once membership changed after the round closed.
        let g = Arc::new(AllReduceGroup::new(2, 2));
        let (net, nodes) = net_with(2);
        let g2 = g.clone();
        let net2 = net.clone();
        let node0 = nodes[0];
        let slow = std::thread::spawn(move || {
            let mut v = vec![1.0, 1.0];
            g2.allreduce_mean_inner(
                &mut v,
                node0,
                &net2,
                Some(Duration::from_millis(200)),
            )
            .unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut v = vec![3.0, 3.0];
        let out = g.allreduce_mean(&mut v, nodes[1], &net).unwrap();
        assert_eq!(out.contributors, 2);
        g.leave(); // membership shrinks to 1 before the slow waiter wakes up
        let slow_out = slow.join().unwrap();
        assert_eq!(slow_out.contributors, 2, "waiter must see its round's count");
        assert_eq!(slow_out.generation, out.generation);
    }

    #[test]
    fn overlapping_round_cannot_clobber_unread_result() {
        // Regression for the generation race: force round N+1 to finalize
        // (via mid-round leaves) while a round-N waiter has not yet copied
        // its mean out. With the version-stamped result store the slow
        // waiter still reads round N's mean and contributor count.
        //
        // Membership 5 = threads A (slow-wake), B, C + two phantom members
        // held by the test thread, which only ever `leave`s.
        let g = Arc::new(AllReduceGroup::new(5, 2));
        let (net, nodes) = net_with(5);
        let ga = g.clone();
        let neta = net.clone();
        let node_a = nodes[0];
        let a = std::thread::spawn(move || {
            let mut v = vec![3.0, 3.0];
            let out = ga
                .allreduce_mean_inner(&mut v, node_a, &neta, Some(Duration::from_millis(400)))
                .unwrap();
            (v, out)
        });
        let mut fast = Vec::new();
        for (i, val) in [(1usize, 6.0f32), (2, 9.0)] {
            let g = g.clone();
            let net = net.clone();
            let node = nodes[i];
            let second = if i == 1 { 10.0 } else { 20.0 };
            fast.push(std::thread::spawn(move || {
                let mut v = vec![val; 2];
                let r0 = g.allreduce_mean(&mut v, node, &net).unwrap();
                let first_mean = v[0];
                let mut w = vec![second; 2];
                let r1 = g.allreduce_mean(&mut w, node, &net).unwrap();
                (first_mean, r0, w[0], r1)
            }));
        }
        // wait for A, B, C to be summed into round 0, then shrink 5 -> 3 so
        // round 0 completes while A dawdles before copying
        while g.pending() < 3 {
            std::thread::yield_now();
        }
        g.leave();
        g.leave();
        // B and C wake, copy round 0, and start round 1; shrink 3 -> 2 so
        // round 1 completes too — before A has read round 0
        while g.pending() < 2 {
            std::thread::yield_now();
        }
        // retire one more membership (A never rejoins after round 0) so the
        // {B, C} round can close while A still hasn't copied round 0 out
        g.leave();
        let (a_mean, a_out) = {
            let (v, out) = a.join().unwrap();
            (v[0], out)
        };
        // round 0 = mean(3, 6, 9) over {A, B, C}
        assert_eq!(a_mean, 6.0);
        assert_eq!(a_out.contributors, 3);
        assert_eq!(a_out.generation, 0);
        for h in fast {
            let (m0, r0, m1, r1) = h.join().unwrap();
            assert_eq!(m0, 6.0);
            assert_eq!(r0.contributors, 3);
            assert_eq!(r0.generation, 0);
            // round 1 = mean(10, 20) over {B, C} — finalized while A slept
            assert_eq!(m1, 15.0);
            assert_eq!(r1.contributors, 2);
            assert_eq!(r1.generation, 1);
        }
    }

    #[test]
    fn dynamic_membership_stress_every_mean_is_exact() {
        // N threads run 100s of rounds while members leave at random
        // points; every returned mean must equal the sequential reference
        // over that round's surviving contributor set, and every returned
        // contributor count must be exact.
        let n = 8;
        let p = 4;
        let g = Arc::new(AllReduceGroup::new(n, p).with_chunks(3));
        let (net, nodes) = net_with(n);
        let mut hs = Vec::new();
        for t in 0..n {
            let g = g.clone();
            let net = net.clone();
            let node = nodes[t];
            hs.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xA11E ^ t as u64);
                // members leave at staggered, pseudo-random round counts
                let my_rounds = 100 + (rng.next_u64() % 150) as usize;
                let mut log = Vec::with_capacity(my_rounds);
                for r in 0..my_rounds {
                    let contrib = (t * 1_000 + r) as f32;
                    let mut v = vec![contrib; p];
                    let out = g.allreduce_mean(&mut v, node, &net).unwrap();
                    assert!(v.iter().all(|&x| x == v[0]), "mean not uniform");
                    log.push((out.generation, contrib, v[0], out.contributors));
                }
                g.leave();
                log
            }));
        }
        let mut by_gen: HashMap<u64, Vec<(f32, f32, usize)>> = HashMap::new();
        for h in hs {
            for (gen, contrib, mean, parts) in h.join().unwrap() {
                by_gen.entry(gen).or_default().push((contrib, mean, parts));
            }
        }
        assert!(by_gen.len() >= 100, "expected 100s of rounds, got {}", by_gen.len());
        for (gen, entries) in &by_gen {
            let count = entries.len();
            let want = entries.iter().map(|e| e.0).sum::<f32>() / count as f32;
            for &(_, mean, parts) in entries {
                assert_eq!(
                    parts, count,
                    "gen {gen}: reported {parts} contributors, actual {count}"
                );
                assert!(
                    (mean - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "gen {gen}: mean {mean} != reference {want}"
                );
            }
        }
        assert_eq!(g.active(), 0);
    }
}
