//! In-process AllReduce for the decentralized algorithms (MA, BMUF).
//!
//! Semantics match a ring all-reduce over the trainers: every active member
//! contributes a vector, everyone receives the element-wise mean. Because
//! training is one-pass, trainers finish their shards at different times;
//! members therefore [`AllReduceGroup::leave`] the group when done and
//! rounds complete over the *remaining* membership (a real collective over
//! dynamic process groups behaves the same way after a resize).
//!
//! Wire-cost accounting uses the ring formula: each member moves
//! `2·(n-1)/n · bytes` in each direction per round.

use std::sync::{Condvar, Mutex};

use anyhow::{ensure, Result};

struct State {
    active: usize,
    joined: usize,
    sum: Vec<f32>,
    result: Vec<f32>,
    generation: u64,
}

/// A dynamic-membership mean-AllReduce group.
pub struct AllReduceGroup {
    state: Mutex<State>,
    cv: Condvar,
    pub len: usize,
}

impl AllReduceGroup {
    /// `members` trainers, vectors of length `len`.
    pub fn new(members: usize, len: usize) -> Self {
        Self {
            state: Mutex::new(State {
                active: members,
                joined: 0,
                sum: vec![0.0; len],
                result: vec![0.0; len],
                generation: 0,
            }),
            cv: Condvar::new(),
            len,
        }
    }

    fn finalize(st: &mut State) {
        let n = st.joined as f32;
        for (r, s) in st.result.iter_mut().zip(&st.sum) {
            *r = s / n;
        }
        st.sum.fill(0.0);
        st.joined = 0;
        st.generation += 1;
    }

    /// Contribute `data`, block until the round completes, and replace
    /// `data` with the mean over this round's contributors. Returns the
    /// number of contributors (for wire-cost accounting).
    pub fn allreduce_mean(&self, data: &mut [f32]) -> Result<usize> {
        ensure!(data.len() == self.len, "allreduce length mismatch");
        let mut st = self.state.lock().unwrap();
        ensure!(st.active > 0, "allreduce on an empty group");
        for (s, &d) in st.sum.iter_mut().zip(data.iter()) {
            *s += d;
        }
        st.joined += 1;
        let my_gen = st.generation;
        if st.joined == st.active {
            let n = st.joined;
            Self::finalize(&mut st);
            data.copy_from_slice(&st.result);
            self.cv.notify_all();
            return Ok(n);
        }
        while st.generation == my_gen {
            st = self.cv.wait(st).unwrap();
        }
        data.copy_from_slice(&st.result);
        // contributors of the completed round = active at completion + any
        // leavers mid-round; report current active + 0 conservatively:
        Ok(st.active.max(1))
    }

    /// Permanently remove one member. If everyone else is already waiting,
    /// the pending round completes without the leaver.
    pub fn leave(&self) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.active > 0);
        st.active -= 1;
        if st.active > 0 && st.joined == st.active {
            Self::finalize(&mut st);
            self.cv.notify_all();
        }
    }

    pub fn active(&self) -> usize {
        self.state.lock().unwrap().active
    }

    /// Ring all-reduce bytes each member moves per direction per round.
    pub fn ring_bytes_per_member(&self, participants: usize) -> u64 {
        if participants <= 1 {
            return 0;
        }
        let vec_bytes = (self.len * 4) as u64;
        2 * vec_bytes * (participants as u64 - 1) / participants as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mean_matches_sequential_sum() {
        let n = 4;
        let g = Arc::new(AllReduceGroup::new(n, 8));
        let mut hs = Vec::new();
        for r in 0..n {
            let g = g.clone();
            hs.push(std::thread::spawn(move || {
                let mut v = vec![(r + 1) as f32; 8];
                let parts = g.allreduce_mean(&mut v).unwrap();
                (v, parts)
            }));
        }
        for h in hs {
            let (v, _) = h.join().unwrap();
            // mean of 1,2,3,4 = 2.5
            assert!(v.iter().all(|&x| (x - 2.5).abs() < 1e-6), "{v:?}");
        }
    }

    #[test]
    fn repeated_rounds_stay_consistent() {
        let n = 3;
        let g = Arc::new(AllReduceGroup::new(n, 4));
        let mut hs = Vec::new();
        for r in 0..n {
            let g = g.clone();
            hs.push(std::thread::spawn(move || {
                let mut acc = Vec::new();
                for round in 0..50 {
                    let mut v = vec![(r * 50 + round) as f32; 4];
                    g.allreduce_mean(&mut v).unwrap();
                    acc.push(v[0]);
                }
                acc
            }));
        }
        let results: Vec<Vec<f32>> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        for round in 0..50 {
            let want = (0..n).map(|r| (r * 50 + round) as f32).sum::<f32>() / n as f32;
            for res in &results {
                assert!((res[round] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn leaver_unblocks_pending_round() {
        let g = Arc::new(AllReduceGroup::new(3, 2));
        let g2 = g.clone();
        let waiter = std::thread::spawn(move || {
            let mut v = vec![6.0, 6.0];
            g2.allreduce_mean(&mut v).unwrap();
            v
        });
        let g3 = g.clone();
        let waiter2 = std::thread::spawn(move || {
            let mut v = vec![2.0, 2.0];
            g3.allreduce_mean(&mut v).unwrap();
            v
        });
        // give the waiters time to block, then the third member leaves
        std::thread::sleep(std::time::Duration::from_millis(50));
        g.leave();
        let v = waiter.join().unwrap();
        let v2 = waiter2.join().unwrap();
        // round completed over the two contributors: mean = 4
        assert_eq!(v, vec![4.0, 4.0]);
        assert_eq!(v2, vec![4.0, 4.0]);
        assert_eq!(g.active(), 2);
    }

    #[test]
    fn singleton_group_is_identity() {
        let g = AllReduceGroup::new(1, 3);
        let mut v = vec![1.0, 2.0, 3.0];
        let parts = g.allreduce_mean(&mut v).unwrap();
        assert_eq!(parts, 1);
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
        assert_eq!(g.ring_bytes_per_member(1), 0);
    }

    #[test]
    fn ring_cost_formula() {
        let g = AllReduceGroup::new(4, 100);
        // 2 * 400 bytes * 3/4 = 600
        assert_eq!(g.ring_bytes_per_member(4), 600);
    }

    #[test]
    fn length_mismatch_rejected() {
        let g = AllReduceGroup::new(1, 3);
        let mut v = vec![0.0; 2];
        assert!(g.allreduce_mean(&mut v).is_err());
    }
}
