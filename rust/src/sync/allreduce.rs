//! Lock-striped, chunk-parallel ring-AllReduce fabric for the decentralized
//! algorithms (MA, BMUF).
//!
//! Semantics match a ring all-reduce over the trainers: every active member
//! contributes a vector, everyone receives the element-wise mean. Because
//! training is one-pass, trainers finish their shards at different times;
//! members therefore [`AllReduceGroup::leave`] the group when done and
//! rounds complete over the *remaining* membership (a real collective over
//! dynamic process groups behaves the same way after a resize).
//!
//! ## The striped reduction engine
//!
//! The old engine funneled every member's element-wise sum through one
//! `Mutex<State>`: `n` concurrent contributors serialized on a single lock
//! for `n` full-vector adds per round. The default engine
//! ([`ReduceEngine::Striped`]) keeps only O(1) round/membership bookkeeping
//! under the small control lock and splits the arithmetic two ways:
//!
//! 1. **Deposit** — each contributor copies its vector into a private,
//!    per-ring-position *slot* buffer (its own lock, never contended), so
//!    all `n` deposits run fully in parallel.
//! 2. **Chunk-parallel reduce** — once the round closes, the vector's `C`
//!    chunks become a work list: every thread parked in the round claims
//!    chunks off an epoch-tagged atomic cursor and reduces *disjoint*
//!    chunks into per-chunk mean stripes (one lock per stripe, exclusive by
//!    construction). `n` members reduce `C` chunks cooperatively instead of
//!    queueing on one mutex, so the contribute path scales with cores.
//!
//! The per-chunk sum always folds slots in **ring-position order**, so the
//! reduction has a fixed chunk-wise summation order: concurrent rounds
//! produce bit-identical means to a single-threaded position-order
//! reference, regardless of thread interleaving (verified by the
//! concurrency regression tests). [`ReduceEngine::SerialMutex`] keeps the
//! old single-lock arrival-order engine as the benchmark baseline
//! (`benches/sync_ops.rs` compares the engines at 1M params).
//!
//! ## Double-buffered deposit banks (overlapped engine)
//!
//! With a single slot bank, a round-`N+1` deposit must wait (and help)
//! until round `N`'s reduce has drained out of the slot buffers — the
//! deposit would otherwise overwrite a slot the reducers are still folding.
//! The default engine ([`ReduceEngine::Overlapped`]) double-buffers the
//! deposit slots with **per-generation parity**: round `g` deposits land in
//! bank `g & 1` while the in-flight reduce plan (always generation `g - 1`,
//! the round just closed) folds the opposite-parity bank, so deposits never
//! block on a draining reduction. The epoch-tagged chunk-claim cursor's
//! generation tag carries the deposit bank's parity as its lowest bit
//! (bit 32 of the packed word), so a stale helper can never fold the wrong
//! bank. Round *closes* still serialize on
//! the previous reduce (the mean stripes are shared, depth-1 overlap): when
//! a round finishes deposits while the previous plan is draining, the
//! reducer that parks the previous round closes it immediately.
//! [`ReduceEngine::Striped`] keeps the single-bank engine for A/B benches.
//!
//! ## The shared-nothing engine
//!
//! [`ReduceEngine::SharedNothing`] removes even the cooperative sharing the
//! striped engines keep (contended chunk-claim cursor, shared stripe
//! locks): every deposit *moves* through a bounded per-position SPSC ring
//! ([`super::ring::SpscRing`], backpressure instead of blocking), one
//! waiter claims the closed round and folds it **exclusively** — no other
//! shard ever touches the deposits or the mean — and the result is
//! published by an epoch-stamped pointer swap (the parked `Round` plus a
//! `Release`-stored publication stamp). Two carried ROADMAP items fall out
//! of the same ownership discipline:
//!
//! * **Sub-partition work stealing by delegation** — the round owner lends
//!   waiters contiguous chunk ranges as *grant* messages over their rings
//!   (a read-only handle on the round's deposits plus a `[lo, hi)` chunk
//!   range); the borrower folds its range privately and returns the
//!   reduced stripe over its own ring. Ownership moves over messages;
//!   nothing is ever mutated by two shards.
//! * **Depth-2 stripe pipelining** — the deposit rings are
//!   [`AllReduceGroup::with_ring_depth`] deep (default 2), so round
//!   `g+1`'s deposits drain into the rings while round `g` folds; a
//!   depositor only waits when the ring still holds `ring_depth` older
//!   rounds at its position.
//!
//! Folds use the same per-chunk, ring-position-order summation as the
//! striped engines, so all four engines (bar the arrival-order serial
//! baseline) produce bit-identical means. Pair with `--pin-cores`
//! (`crate::util::affinity`) to keep each worker's deposits and stripes
//! resident in one core's cache.
//!
//! ## The chunked wire schedule
//!
//! The parameter vector is split into `C` chunks
//! ([`AllReduceGroup::with_chunks`], `RunConfig::allreduce_chunks`). Each
//! chunk is reduced through an explicit reduce-scatter + all-gather ring
//! schedule over the round's `n` contributors (schedule math shared with
//! [`super::traffic`]): a chunk of length `L` is cut into `n` near-equal
//! segments, and every member sends one segment per hop to its ring
//! successor for `n-1` reduce-scatter hops followed by `n-1` all-gather
//! hops. Every per-hop transfer is driven through [`Network::try_transfer`]
//! and only delivered hops are recorded, so NIC counters and recorded sync
//! bytes both see the *actual* ring traffic of every round; the textbook
//! `2·(n-1)/n · bytes` formula survives only as the cross-check reference
//! ([`AllReduceGroup::ring_bytes_per_member`]) — the paper-scale throughput
//! model in `sim/` now prices collectives from the measured schedule
//! ([`super::traffic::RingTraffic`]), not the closed form.
//!
//! ## Correct overlap with dynamic membership
//!
//! Results are *version-stamped per generation*: a completed round is
//! parked (mean, ring membership, exact contributor count) until every one
//! of its waiters has copied it out, so a fast round `N+1` — or `N+2`, after
//! mid-round [`AllReduceGroup::leave`]s — can never clobber round `N`'s mean
//! before slow round-`N` waiters observe it, and every joiner is told the
//! exact contributor count of *its own* round. Deposits for round `N+1`
//! wait (and help) until round `N`'s reduce has drained out of the slot
//! buffers. Retired round buffers are recycled through a pool, so the
//! steady state allocates nothing.

use std::collections::VecDeque;
use std::time::Duration;

use super::prim::{
    thread, Arc, AtomicU64, AtomicUsize, Condvar, Mutex, MutexGuard,
    Ordering::{Acquire, Release, SeqCst},
};

use anyhow::{bail, ensure, Result};

use crate::net::{Network, NodeId};

use super::ring::SpscRing;
use super::traffic;

/// Which in-process reduction engine a group runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceEngine {
    /// Legacy baseline: every contributor adds its full vector into one
    /// shared sum under the control lock (arrival-order association).
    SerialMutex,
    /// Parallel per-position deposits + cooperative chunk-parallel
    /// reduction over per-chunk stripes (position-order association,
    /// deterministic bits), single deposit bank: round `N+1` deposits help
    /// round `N`'s reduce drain before landing.
    Striped,
    /// Default: the striped engine with double-buffered, parity-indexed
    /// deposit banks — round `N+1` deposits land in the off-parity bank
    /// while round `N` is still being folded, so deposits never block on a
    /// draining reduction.
    Overlapped,
    /// Shared-nothing: deposits *move* through bounded per-position SPSC
    /// rings to a single round owner that folds the round exclusively
    /// (position-order association, deterministic bits), delegating
    /// contiguous chunk ranges to waiters over the same rings; results are
    /// published by epoch-stamped pointer swap. No cross-shard locks or
    /// contended cursors on the hot path.
    SharedNothing,
}

impl std::str::FromStr for ReduceEngine {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "overlapped" | "double" | "double-buffered" => Self::Overlapped,
            "striped" => Self::Striped,
            "serial" | "serial-mutex" => Self::SerialMutex,
            "shared-nothing" | "shared_nothing" | "sn" => Self::SharedNothing,
            _ => bail!("unknown reduce engine {s:?} (overlapped|striped|serial|shared-nothing)"),
        })
    }
}

impl std::fmt::Display for ReduceEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::SerialMutex => write!(f, "serial"),
            Self::Striped => write!(f, "striped"),
            Self::Overlapped => write!(f, "overlapped"),
            Self::SharedNothing => write!(f, "shared-nothing"),
        }
    }
}

impl ReduceEngine {
    /// Number of deposit slot banks the engine keeps (parity-indexed).
    fn banks(self) -> usize {
        match self {
            Self::Overlapped => 2,
            _ => 1,
        }
    }
}

/// What one completed collective round reports to each contributor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundOutcome {
    /// Monotonic round index within the group.
    pub generation: u64,
    /// Exact number of vectors that entered this round's mean.
    pub contributors: usize,
    /// This member's ring position within its round (also its fixed place
    /// in the deterministic summation order).
    pub position: usize,
    /// Bytes this member actually delivered for this round (its
    /// reduce-scatter + all-gather hops, as accounted through `Network`;
    /// hops faulted by a crash window or seeded drop are excluded, keeping
    /// recorded sync bytes identical to the NIC counters).
    pub bytes_tx: u64,
}

/// A finalized round, parked until all its waiters have copied the mean.
struct Round {
    generation: u64,
    mean: Vec<f32>,
    /// Contributor NICs in join order — the ring of this round.
    ring: Vec<NodeId>,
    /// Waiters that still have to copy `mean` out.
    readers_left: usize,
}

/// A closed round whose chunk-parallel reduction is still in flight
/// (striped engine only). The chunk cursor and completion count live in
/// [`StripedState`] so helpers can claim work without the control lock.
struct ReducePlan {
    generation: u64,
    /// Contributors of the closing round (== slots to fold per chunk).
    n: usize,
    /// Contributor NICs in join order, carried into the parked `Round`.
    ring: Vec<NodeId>,
    /// Shared-nothing engine: whether a waiter has claimed this plan as
    /// the round's exclusive owner. The striped engines leave it `false`
    /// (their claim mechanism is the chunk cursor, not ownership).
    owned: bool,
}

/// Round/membership bookkeeping — the *small* control lock. All O(len)
/// arithmetic happens outside it in the striped engine.
struct Control {
    active: usize,
    /// Contributors that have fully deposited their vector this round.
    deposited: usize,
    /// NICs of the current round's contributors, in join order.
    contributors: Vec<NodeId>,
    /// Serial engine only: the single shared sum (empty when striped).
    sum: Vec<f32>,
    generation: u64,
    /// The closed round currently being reduced (striped engine only).
    plan: Option<ReducePlan>,
    /// Completed rounds not yet copied out by all their waiters.
    done: VecDeque<Round>,
    /// Recycled `mean`/`ring` buffers (steady state allocates nothing).
    mean_pool: Vec<Vec<f32>>,
    ring_pool: Vec<Vec<NodeId>>,
}

/// The striped engine's lock-striped buffers, outside the control lock.
struct StripedState {
    /// Deposit slot banks, indexed by round parity (`generation % banks`):
    /// one bank for the plain striped engine, two for the overlapped
    /// engine. Each bank holds one buffer per ring position, written by
    /// exactly one contributor per round, so slot locks are never
    /// contended.
    banks: Vec<Vec<Mutex<Vec<f32>>>>,
    /// One mean stripe per chunk; the cursor hands each chunk to exactly
    /// one reducer, so each stripe lock is exclusive by construction.
    /// Stripes are shared across parities, which is why round *closes*
    /// (plan openings) still serialize even when deposits overlap.
    stripes: Vec<Mutex<Vec<f32>>>,
    /// Epoch-tagged claim cursor: `(generation & 0xFFFF_FFFF) << 32 | next
    /// chunk index`. The tag's low bit is the deposit-bank parity, so a
    /// tag mismatch stops a stale helper from claiming chunks — or folding
    /// the wrong slot bank — of a different round's reduce.
    cursor: AtomicU64,
    /// Chunks fully reduced in the active plan; the thread that finishes
    /// the last chunk parks the round.
    chunks_done: AtomicUsize,
}

impl StripedState {
    fn new(len: usize, chunks: usize, capacity: usize, banks: usize) -> Self {
        Self {
            banks: (0..banks)
                .map(|_| (0..capacity).map(|_| Mutex::new(vec![0.0; len])).collect())
                .collect(),
            stripes: (0..chunks)
                .map(|c| Mutex::new(vec![0.0; traffic::part_len(len, chunks, c)]))
                .collect(),
            cursor: AtomicU64::new(u64::MAX),
            chunks_done: AtomicUsize::new(0),
        }
    }

    /// The slot bank round `generation` deposits into (and reduces from).
    fn bank_of(&self, generation: u64) -> usize {
        (generation % self.banks.len() as u64) as usize
    }

    /// Slot capacity per bank (== initial group membership).
    fn capacity(&self) -> usize {
        self.banks[0].len()
    }
}

/// Pack the claim cursor: 32 bits of generation tag over 32 bits of
/// next-chunk index. The tag's lowest bit (bit 32 of the packed word) *is*
/// the deposit-bank parity — `generation % 2` selects the bank — so an
/// epoch mismatch also fences a stale helper from folding the wrong bank.
fn pack_cursor(generation: u64, idx: usize) -> u64 {
    ((generation & 0xFFFF_FFFF) << 32) | idx as u64
}

/// How many chunk ranges a shared-nothing round owner will delegate to
/// waiting members, besides the range it always folds itself.
const SN_DELEGATE_MAX: usize = 3;

/// One member's contribution in flight to its round's owner over the
/// position's deposit ring (shared-nothing engine). Epoch-stamped so the
/// owner can assert ring discipline under depth-2 pipelining (the ring may
/// hold deposits of two consecutive rounds at once).
struct SnDeposit {
    generation: u64,
    data: Vec<f32>,
}

/// A sub-partition delegation: the round owner lends a waiter a contiguous
/// chunk range plus a read-only handle on the round's deposits. This is
/// ownership *delegation*, not work stealing — the borrower never touches
/// shared mutable state; it folds privately and returns the reduced stripe
/// over its own return ring.
struct SnGrant {
    generation: u64,
    /// Chunk range `[lo_chunk, hi_chunk)` the borrower folds.
    lo_chunk: usize,
    hi_chunk: usize,
    /// Contributors in the round (deposits to fold per chunk).
    n: usize,
    /// The round's deposits, position-ordered, shared read-only.
    deposits: Arc<Vec<Vec<f32>>>,
}

/// The reduced mean stripe for a delegated chunk range, returned to the
/// round owner over the borrower's return ring.
struct SnReturn {
    lo_chunk: usize,
    /// The contiguous element range covering `[lo_chunk, hi_chunk)`.
    data: Vec<f32>,
}

/// The shared-nothing engine's per-position rings and counters. Nothing
/// here is ever mutated by two shards at once: deposits, grants, and
/// returned stripes all *move* through SPSC rings, and the round owner is
/// the only shard folding the (undelegated) chunks of its round.
struct SnState {
    /// One deposit ring per ring position: producer = the contributor at
    /// that position (successive rounds' producers are serialized by the
    /// control lock), consumer = the round owner. The configured depth
    /// (default 2) *is* the stripe pipelining: round `g+1`'s deposits
    /// drain in while round `g` folds.
    deposit: Vec<SpscRing<SnDeposit>>,
    /// Delegation grants, owner → the position's round-`g` waiter. Pushed
    /// and polled under the control lock, so a grant is never lost to a
    /// sleeping waiter.
    grants: Vec<SpscRing<SnGrant>>,
    /// Reduced stripes coming back, the position's waiter → owner.
    returns: Vec<SpscRing<SnReturn>>,
    /// Chunk ranges granted to / returned by borrowers. Observability
    /// counters (equal whenever the fabric is quiescent); never `Relaxed`.
    delegated: AtomicUsize,
    returned: AtomicUsize,
    /// Epoch stamp of the publication pointer swap: `generation + 1` of
    /// the latest parked round, stored `Release` at park.
    published: AtomicU64,
}

impl SnState {
    fn new(capacity: usize, depth: usize) -> Self {
        Self {
            deposit: (0..capacity).map(|_| SpscRing::new(depth)).collect(),
            // at most one grant (and one return) is outstanding per
            // position per round; 2 leaves slack for the next round's
            // grant landing before a slow gc
            grants: (0..capacity).map(|_| SpscRing::new(2)).collect(),
            returns: (0..capacity).map(|_| SpscRing::new(2)).collect(),
            delegated: AtomicUsize::new(0),
            returned: AtomicUsize::new(0),
            published: AtomicU64::new(0),
        }
    }
}

/// A dynamic-membership mean-AllReduce group over a chunked ring schedule.
pub struct AllReduceGroup {
    state: Mutex<Control>,
    cv: Condvar,
    /// Striped engine buffers (None for the serial baseline).
    striped: Option<StripedState>,
    /// Shared-nothing engine rings (None under the other engines).
    sn: Option<SnState>,
    /// Per-position deposit-ring depth for the shared-nothing engine:
    /// 2 (the default) is depth-2 stripe pipelining — round `g+1`'s
    /// deposits queue behind round `g`'s while `g` folds.
    ring_depth: usize,
    engine: ReduceEngine,
    /// Initial membership — the slot capacity of the striped engine.
    capacity: usize,
    /// Test-only: artificial stall injected into every chunk reduction so
    /// tests can deterministically observe deposits overlapping a draining
    /// reduce. `None` (the default) costs one branch per chunk.
    reduce_stall: Option<Duration>,
    /// Round timeout: members blocked on a round longer than this evict
    /// the missing members (an implicit [`AllReduceGroup::leave`] per
    /// absentee) so survivors re-form. `None` = wait forever.
    round_timeout: Option<Duration>,
    /// Vector length every contribution must match.
    pub len: usize,
    /// Chunk count `C` of the ring schedule (1 = flat single-chunk rings).
    pub chunks: usize,
    /// Wire codec every ring hop's bytes are priced with (fp32 = identity).
    pub codec: traffic::WireCodec,
}

impl AllReduceGroup {
    /// `members` trainers, vectors of length `len`, flat (single-chunk),
    /// overlapped (double-buffered striped) reduction engine.
    pub fn new(members: usize, len: usize) -> Self {
        let mut g = Self {
            state: Mutex::new(Control {
                active: members,
                deposited: 0,
                contributors: Vec::with_capacity(members),
                sum: Vec::new(),
                generation: 0,
                plan: None,
                done: VecDeque::new(),
                mean_pool: Vec::new(),
                ring_pool: Vec::new(),
            }),
            cv: Condvar::new(),
            striped: None,
            sn: None,
            ring_depth: 2,
            engine: ReduceEngine::Overlapped,
            capacity: members,
            reduce_stall: None,
            round_timeout: None,
            len,
            chunks: 1,
            codec: traffic::WireCodec::Fp32,
        };
        g.rebuild_engine();
        g
    }

    /// Split the vector into `chunks` chunks for the ring schedule (and the
    /// striped engine's reduction work list).
    ///
    /// Degenerate chunk counts are a caller bug, not something to clamp
    /// silently: `RunConfig::validate` / `RunConfig::validate_dims` reject
    /// bad `--chunks` values at parse time with a real error message, so a
    /// violation here means a code path skipped validation.
    pub fn with_chunks(mut self, chunks: usize) -> Self {
        assert!(chunks >= 1, "chunk count must be >= 1 (1 = flat collective)");
        assert!(
            chunks as u64 <= u32::MAX as u64,
            "chunk count must fit the 32-bit claim cursor (got {chunks})"
        );
        self.chunks = chunks;
        self.rebuild_engine();
        self
    }

    /// Depth of the shared-nothing engine's per-position SPSC deposit
    /// rings (min 1, rounded up to a power of two). Depth 2 — the default
    /// — is the depth-2 stripe pipeline: round `g+1`'s deposits drain into
    /// the rings while round `g` folds; depth 1 serializes rounds at the
    /// deposit (backpressure), deeper rings only buy slack against
    /// stragglers since round `g+2` cannot close before `g` parks.
    pub fn with_ring_depth(mut self, depth: usize) -> Self {
        self.ring_depth = depth.max(1);
        self.rebuild_engine();
        self
    }

    /// Select the in-process reduction engine.
    pub fn with_engine(mut self, engine: ReduceEngine) -> Self {
        self.engine = engine;
        self.rebuild_engine();
        self
    }

    pub fn engine(&self) -> ReduceEngine {
        self.engine
    }

    /// Price every ring hop with `codec` — what the member NICs then see.
    /// The in-process reduction itself stays exact; codec loss is applied
    /// by the strategies to their *contributions* (with error feedback)
    /// before depositing, which is where a real compressed collective loses
    /// precision too.
    pub fn with_codec(mut self, codec: traffic::WireCodec) -> Self {
        self.codec = codec;
        self
    }

    /// Test-only hook: sleep `stall` inside every chunk reduction, so tests
    /// can prove a round-`N+1` deposit completes while round `N`'s reduce
    /// is still draining.
    pub fn with_reduce_stall(mut self, stall: Duration) -> Self {
        self.reduce_stall = Some(stall);
        self
    }

    /// Bound every blocking wait inside a round by `timeout`: when it
    /// expires, members that have not even *started* depositing are
    /// treated as crashed and evicted — membership shrinks to the members
    /// actually present, exactly as if each absentee had called
    /// [`AllReduceGroup::leave`] — so survivors close the round and keep
    /// bit-deterministic means over the actual contributor list. A member
    /// mid-deposit is never evicted (it is in `contributors` already). An
    /// evicted member that was merely slow rejoins the accounting
    /// implicitly: its late deposit lands in the next round, whose close
    /// waits for `deposited >= active` with it included in `contributors`.
    pub fn with_round_timeout(mut self, timeout: Duration) -> Self {
        self.round_timeout = Some(timeout);
        self
    }

    /// (Re)build the engine-specific buffers. Builder-phase only. The slot
    /// banks (`banks × capacity × len`, the expensive part) are reused
    /// across builder calls; only the per-chunk stripes are rebuilt when
    /// the chunk count changes.
    fn rebuild_engine(&mut self) {
        let st = self.state.get_mut().unwrap();
        match self.engine {
            ReduceEngine::SerialMutex => {
                if st.sum.len() != self.len {
                    st.sum = vec![0.0; self.len];
                }
                self.striped = None;
                self.sn = None;
            }
            ReduceEngine::SharedNothing => {
                st.sum = Vec::new();
                self.striped = None;
                self.sn = Some(SnState::new(self.capacity, self.ring_depth));
            }
            ReduceEngine::Striped | ReduceEngine::Overlapped => {
                st.sum = Vec::new();
                self.sn = None;
                let nbanks = self.engine.banks();
                match self.striped.take() {
                    Some(mut ss)
                        if ss.banks.len() == nbanks && ss.capacity() == self.capacity =>
                    {
                        if ss.stripes.len() != self.chunks {
                            ss.stripes = (0..self.chunks)
                                .map(|c| {
                                    Mutex::new(vec![
                                        0.0;
                                        traffic::part_len(self.len, self.chunks, c)
                                    ])
                                })
                                .collect();
                        }
                        self.striped = Some(ss);
                    }
                    _ => {
                        self.striped = Some(StripedState::new(
                            self.len,
                            self.chunks,
                            self.capacity,
                            nbanks,
                        ));
                    }
                }
            }
        }
    }

    /// Block on the round condvar, bounded by the round timeout when one is
    /// configured. On expiry, evict the members that never showed up for
    /// the pending round (see [`AllReduceGroup::with_round_timeout`]), then
    /// return to the caller's predicate loop.
    fn wait_round<'a>(&'a self, st: MutexGuard<'a, Control>) -> MutexGuard<'a, Control> {
        match self.round_timeout {
            None => self.cv.wait(st).unwrap(),
            Some(timeout) => {
                let (mut st, res) = self.cv.wait_timeout(st, timeout).unwrap();
                if res.timed_out() {
                    self.evict_absentees(&mut st);
                }
                st
            }
        }
    }

    /// Round-timeout eviction: shrink `active` down to the members that
    /// have at least *started* depositing into the pending round — each
    /// absentee is treated exactly as if it had called
    /// [`AllReduceGroup::leave`] — and close the round if that completes
    /// it. The mean stays bit-deterministic: it is always computed over
    /// the actual `contributors` list, never over `active`.
    fn evict_absentees(&self, st: &mut Control) {
        let present = st.contributors.len().max(1);
        if st.active <= present {
            // nobody is missing: the wait was for a draining reduce or a
            // mid-deposit member, both of which make progress on their own
            return;
        }
        st.active = present;
        if Self::round_complete(st) {
            self.close_round(st);
            // waiters blocked on this round (us included — the caller
            // re-checks its predicate) must observe the close
            self.cv.notify_all();
        }
    }

    /// Is the pending round ready to close? Every registered contributor
    /// has fully deposited, the remaining membership is covered, and no
    /// earlier round is still reducing out of the slot buffers.
    fn round_complete(st: &Control) -> bool {
        st.plan.is_none()
            && st.deposited > 0
            && st.deposited == st.contributors.len()
            && st.deposited >= st.active
    }

    /// Close the pending round. Serial engine: scale the shared sum and
    /// park the result immediately. Striped engine: open a reduce plan —
    /// the waiters themselves fold the slots chunk-by-chunk and the last
    /// chunk's reducer parks the result.
    fn close_round(&self, st: &mut Control) {
        let n = st.contributors.len();
        debug_assert!(n > 0, "closing an empty round");
        let empty = st.ring_pool.pop().unwrap_or_default();
        let ring = std::mem::replace(&mut st.contributors, empty);
        let generation = st.generation;
        st.generation += 1;
        st.deposited = 0;
        match self.engine {
            ReduceEngine::SerialMutex => {
                let fresh = match st.mean_pool.pop() {
                    Some(mut v) => {
                        v.fill(0.0);
                        v
                    }
                    None => vec![0.0; self.len],
                };
                let mut mean = std::mem::replace(&mut st.sum, fresh);
                let inv = 1.0 / n as f32;
                for m in &mut mean {
                    *m *= inv;
                }
                st.done.push_back(Round { generation, mean, ring, readers_left: n });
            }
            ReduceEngine::SharedNothing => {
                // every contributor's deposit is already queued in its
                // position's ring; the first waiter to observe this plan
                // claims ownership and folds the round exclusively
                st.plan = Some(ReducePlan { generation, n, ring, owned: false });
            }
            ReduceEngine::Striped | ReduceEngine::Overlapped => {
                let ss = self.striped.as_ref().expect("striped engine state");
                ss.chunks_done.store(0, SeqCst);
                ss.cursor.store(pack_cursor(generation, 0), SeqCst);
                st.plan = Some(ReducePlan { generation, n, ring, owned: false });
            }
        }
    }

    /// Claim and reduce chunks of the active plan for round `generation`
    /// over `n` slots. Returns whether any chunk was claimed; the reducer
    /// of the final chunk parks the round.
    fn help_reduce(&self, generation: u64, n: usize) -> bool {
        let ss = self.striped.as_ref().expect("reduce plan requires the striped engine");
        let epoch = pack_cursor(generation, 0);
        let mut claimed = false;
        loop {
            let cur = ss.cursor.load(SeqCst);
            if cur & !0xFFFF_FFFFu64 != epoch {
                break; // a different round owns the cursor now; stand down
            }
            let idx = (cur & 0xFFFF_FFFF) as usize;
            if idx >= self.chunks {
                break; // every chunk already claimed
            }
            if ss.cursor.compare_exchange(cur, cur + 1, SeqCst, SeqCst).is_err() {
                continue; // raced another claimer; reload
            }
            self.reduce_chunk(ss, idx, n, generation);
            claimed = true;
            if ss.chunks_done.fetch_add(1, SeqCst) + 1 == self.chunks {
                self.park_reduced(generation);
            }
        }
        claimed
    }

    /// Fold slots `0..n` of chunk `c` — read from the slot bank of round
    /// `generation`'s parity — into its mean stripe, always in ring-
    /// position order — the fixed chunk-wise summation order that makes the
    /// concurrent reduction bit-deterministic.
    fn reduce_chunk(&self, ss: &StripedState, c: usize, n: usize, generation: u64) {
        if let Some(stall) = self.reduce_stall {
            thread::sleep(stall);
        }
        let lo = traffic::part_offset(self.len, self.chunks, c);
        let clen = traffic::part_len(self.len, self.chunks, c);
        let bank = &ss.banks[ss.bank_of(generation)];
        let mut stripe = ss.stripes[c].lock().unwrap();
        debug_assert_eq!(stripe.len(), clen);
        for (pos, slot_mx) in bank.iter().take(n).enumerate() {
            let slot = slot_mx.lock().unwrap();
            let src = &slot[lo..lo + clen];
            if pos == 0 {
                stripe.copy_from_slice(src);
            } else {
                for (acc, &x) in stripe.iter_mut().zip(src) {
                    *acc += x;
                }
            }
        }
        let inv = 1.0 / n as f32;
        for acc in stripe.iter_mut() {
            *acc *= inv;
        }
    }

    /// All chunks of the plan for `generation` are reduced: assemble the
    /// stripes into a parked `Round` and wake every waiter.
    fn park_reduced(&self, generation: u64) {
        let ss = self.striped.as_ref().expect("striped engine state");
        let mut st = self.state.lock().unwrap();
        let plan = st.plan.take().expect("park without an active reduce plan");
        debug_assert_eq!(plan.generation, generation);
        let mut mean = st.mean_pool.pop().unwrap_or_else(|| vec![0.0; self.len]);
        let mut off = 0;
        for stripe_mx in &ss.stripes {
            let stripe = stripe_mx.lock().unwrap();
            mean[off..off + stripe.len()].copy_from_slice(&stripe[..]);
            off += stripe.len();
        }
        debug_assert_eq!(off, self.len);
        st.done.push_back(Round {
            generation: plan.generation,
            mean,
            ring: plan.ring,
            readers_left: plan.n,
        });
        // overlapped engine: a round that finished its deposits while this
        // reduce was draining could not close then (the stripes were busy);
        // close it now that the plan slot is free
        if Self::round_complete(&st) {
            self.close_round(&mut st);
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Shared-nothing: fold chunks `[lo_chunk, hi_chunk)` of the
    /// position-ordered `deposits` into `out`, where `out` starts at
    /// element offset `base` of the full vector (0 for the owner's
    /// full-length mean, the range's offset for a borrower's stripe).
    /// Same per-chunk copy → add → scale association as
    /// [`AllReduceGroup::reduce_chunk`], so every shard — owner or
    /// borrower — produces bit-identical stripes.
    fn sn_fold_chunks(
        &self,
        deposits: &[Vec<f32>],
        out: &mut [f32],
        base: usize,
        lo_chunk: usize,
        hi_chunk: usize,
        n: usize,
    ) {
        for c in lo_chunk..hi_chunk {
            if let Some(stall) = self.reduce_stall {
                thread::sleep(stall);
            }
            let lo = traffic::part_offset(self.len, self.chunks, c);
            let clen = traffic::part_len(self.len, self.chunks, c);
            let dst = &mut out[lo - base..lo - base + clen];
            for (pos, dep) in deposits.iter().take(n).enumerate() {
                let src = &dep[lo..lo + clen];
                if pos == 0 {
                    dst.copy_from_slice(src);
                } else {
                    for (acc, &x) in dst.iter_mut().zip(src) {
                        *acc += x;
                    }
                }
            }
            let inv = 1.0 / n as f32;
            for acc in dst.iter_mut() {
                *acc *= inv;
            }
        }
    }

    /// Shared-nothing: fold a delegated chunk range and send the reduced
    /// stripe back over this position's return ring. Runs without any lock
    /// — the grant carries everything the borrower needs, and the stripe
    /// goes back as an owned message, never shared mutation.
    fn sn_serve_grant(&self, my_pos: usize, grant: SnGrant) {
        let sn = self.sn.as_ref().expect("shared-nothing engine state");
        let SnGrant { generation: _, lo_chunk, hi_chunk, n, deposits } = grant;
        let off = traffic::part_offset(self.len, self.chunks, lo_chunk);
        let end = if hi_chunk == self.chunks {
            self.len
        } else {
            traffic::part_offset(self.len, self.chunks, hi_chunk)
        };
        let mut out = vec![0.0f32; end - off];
        self.sn_fold_chunks(&deposits, &mut out, off, lo_chunk, hi_chunk, n);
        // drop our deposit handle before publishing the stripe so the
        // owner's buffer-recycling `Arc::try_unwrap` can usually succeed
        drop(deposits);
        let mut msg = SnReturn { lo_chunk, data: out };
        while let Err(back) = sn.returns[my_pos].try_push(msg) {
            msg = back;
            thread::yield_now();
        }
        sn.returned.fetch_add(1, SeqCst);
    }

    /// Shared-nothing: fold round `pg` (`pn` contributors) as its claimed
    /// exclusive owner and publish the result. Called with the control
    /// lock held (the plan was just marked `owned`); returns holding it
    /// again. `my_gen`/`my_pos` identify the caller's own pending round so
    /// the owner never grants a range to its own position.
    fn sn_own_round<'a>(
        &'a self,
        mut st: MutexGuard<'a, Control>,
        pg: u64,
        pn: usize,
        my_gen: u64,
        my_pos: usize,
    ) -> MutexGuard<'a, Control> {
        let sn = self.sn.as_ref().expect("shared-nothing engine state");
        // Drain exactly one epoch-stamped deposit per position — all
        // present, because every contributor pushed before bumping
        // `deposited` under this lock. Pops are O(1) buffer moves; doing
        // them under the lock also serializes successive rounds' owners on
        // the rings (the consumer half of the SPSC handoff).
        let mut deposits = Vec::with_capacity(pn);
        for ring in sn.deposit.iter().take(pn) {
            let d = ring.try_pop().expect("closed round is missing a deposit");
            debug_assert_eq!(d.generation, pg, "ring held a deposit from the wrong round");
            deposits.push(d.data);
        }
        let mut mean = st.mean_pool.pop().unwrap_or_else(|| vec![0.0; self.len]);
        // Sub-partition work stealing by delegation: hand contiguous chunk
        // ranges to this round's waiters over their grant rings. Grants are
        // pushed under the control lock, and waiters poll their grant ring
        // under the same lock before sleeping, so no grant can be lost.
        let mut helpers: Vec<usize> =
            (0..pn).filter(|&p| my_gen != pg || p != my_pos).collect();
        let parts =
            helpers.len().min(SN_DELEGATE_MAX).min(self.chunks.saturating_sub(1)) + 1;
        helpers.truncate(parts - 1);
        let chunk_range = |j: usize| {
            let lo = traffic::part_offset(self.chunks, parts, j);
            (lo, lo + traffic::part_len(self.chunks, parts, j))
        };
        let mut own = vec![chunk_range(0)];
        let mut granted: Vec<(usize, usize)> = Vec::new();
        if parts > 1 {
            let shared = Arc::new(deposits);
            for (i, &p) in helpers.iter().enumerate() {
                let (lo, hi) = chunk_range(i + 1);
                let grant = SnGrant {
                    generation: pg,
                    lo_chunk: lo,
                    hi_chunk: hi,
                    n: pn,
                    deposits: shared.clone(),
                };
                match sn.grants[p].try_push(grant) {
                    Ok(()) => granted.push((p, lo)),
                    // a full grant ring means that waiter is still a whole
                    // round behind: fold the range ourselves instead
                    Err(_) => own.push((lo, hi)),
                }
            }
            sn.delegated.fetch_add(granted.len(), SeqCst);
            drop(st);
            // grantees may be asleep on the round condvar
            self.cv.notify_all();
            for &(lo, hi) in &own {
                self.sn_fold_chunks(&shared, &mut mean, 0, lo, hi, pn);
            }
            // collect the borrowed ranges back; spin-yield rather than
            // sleep — the borrowers are this round's waiters, guaranteed
            // to pass their grant poll before they can exit the round
            for &(p, lo) in &granted {
                let ret = loop {
                    if let Some(r) = sn.returns[p].try_pop() {
                        break r;
                    }
                    thread::yield_now();
                };
                debug_assert_eq!(ret.lo_chunk, lo, "stripe came back for the wrong range");
                let off = traffic::part_offset(self.len, self.chunks, lo);
                mean[off..off + ret.data.len()].copy_from_slice(&ret.data);
            }
            st = self.state.lock().unwrap();
            // recycle the deposit buffers; a borrower still holding its
            // clone for another beat only means these buffers skip the
            // pool this round
            if let Ok(bufs) = Arc::try_unwrap(shared) {
                st.mean_pool.extend(bufs);
            }
        } else {
            drop(st);
            let (lo, hi) = own[0];
            self.sn_fold_chunks(&deposits, &mut mean, 0, lo, hi, pn);
            st = self.state.lock().unwrap();
            st.mean_pool.extend(deposits);
        }
        // Publish by epoch-stamped pointer swap: park the round under the
        // generation stamp its waiters look up, then stamp `published`.
        let plan = st.plan.take().expect("owner parked without a plan");
        debug_assert!(plan.owned, "parked a plan nobody claimed");
        debug_assert_eq!(plan.generation, pg);
        st.done.push_back(Round { generation: pg, mean, ring: plan.ring, readers_left: plan.n });
        sn.published.store(pg + 1, Release);
        // depth-2 pipelining handoff: the next round's deposits drained
        // into the rings while this one folded — close it now that the
        // plan slot is free
        if Self::round_complete(&st) {
            self.close_round(&mut st);
        }
        drop(st);
        self.cv.notify_all();
        self.state.lock().unwrap()
    }

    /// Retire fully-read rounds and recycle their buffers.
    fn gc(st: &mut Control) {
        let mut i = 0;
        while i < st.done.len() {
            if st.done[i].readers_left == 0 {
                let r = st.done.remove(i).expect("index in bounds");
                st.mean_pool.push(r.mean);
                let mut ring = r.ring;
                ring.clear();
                st.ring_pool.push(ring);
            } else {
                i += 1;
            }
        }
    }

    /// Contribute `data` as the member whose NIC is `me`, block until the
    /// round completes, and replace `data` with the mean over this round's
    /// contributors. Drives this member's ring hops through `net` and
    /// returns the round's generation, exact contributor count, ring
    /// position, and the bytes this member moved.
    pub fn allreduce_mean(
        &self,
        data: &mut [f32],
        me: NodeId,
        net: &Network,
    ) -> Result<RoundOutcome> {
        self.allreduce_mean_inner(data, me, net, None)
    }

    /// `allreduce_mean` with an optional artificial delay between being
    /// woken and copying the result out — test-only hook that forces the
    /// "slow waiter vs. fast next round" interleaving deterministically.
    fn allreduce_mean_inner(
        &self,
        data: &mut [f32],
        me: NodeId,
        net: &Network,
        wake_delay: Option<Duration>,
    ) -> Result<RoundOutcome> {
        ensure!(data.len() == self.len, "allreduce length mismatch");
        let mut st = self.state.lock().unwrap();
        ensure!(st.active > 0, "allreduce on an empty group");
        ensure!(
            st.contributors.len() < self.capacity,
            "more concurrent contributors than group members"
        );
        let my_gen = st.generation;
        let my_pos = st.contributors.len();
        st.contributors.push(me);
        match self.engine {
            ReduceEngine::SerialMutex => {
                // the legacy hot path: O(len) arithmetic under the lock
                for (s, &d) in st.sum.iter_mut().zip(data.iter()) {
                    *s += d;
                }
            }
            ReduceEngine::SharedNothing => {
                let sn = self.sn.as_ref().expect("shared-nothing engine state");
                // O(len) copy outside the lock, into a pooled buffer the
                // round owner will recycle after the fold
                let mut buf = st.mean_pool.pop().unwrap_or_else(|| vec![0.0; self.len]);
                drop(st);
                buf.copy_from_slice(data);
                let mut msg = SnDeposit { generation: my_gen, data: buf };
                st = self.state.lock().unwrap();
                // The push itself is an O(1) buffer move. Doing it under
                // the control lock serializes successive rounds' producers
                // on this position's ring (the producer half of the SPSC
                // handoff) and makes the full-ring retry race-free: owners
                // drain deposits under this same lock, so a drain can
                // never slip between a failed push and the wait below.
                loop {
                    match sn.deposit[my_pos].try_push(msg) {
                        Ok(()) => break,
                        Err(back) => {
                            // Backpressure, not blocking: the ring still
                            // holds `ring_depth` older rounds' deposits at
                            // this position. Sleep on the round condvar
                            // until an owner drains one, then retry.
                            msg = back;
                            st = self.wait_round(st);
                        }
                    }
                }
            }
            ReduceEngine::Striped | ReduceEngine::Overlapped => {
                let ss = self.striped.as_ref().expect("striped engine state");
                // Single-bank striped engine: the previous round may still
                // be reducing out of the (only) slot bank, so help it drain
                // before overwriting our slot. Overlapped engine: the open
                // round's parity bank is never the bank the in-flight plan
                // folds (the plan is always the previous generation, the
                // opposite parity), so the conflict check fails and the
                // deposit proceeds immediately — deposits never block on a
                // draining reduction.
                loop {
                    let conflicting = st
                        .plan
                        .as_ref()
                        .filter(|p| ss.bank_of(p.generation) == ss.bank_of(my_gen))
                        .map(|p| (p.generation, p.n));
                    match conflicting {
                        None => break,
                        Some((pg, pn)) => {
                            drop(st);
                            let claimed = self.help_reduce(pg, pn);
                            st = self.state.lock().unwrap();
                            if !claimed && st.plan.is_some() {
                                st = self.wait_round(st);
                            }
                        }
                    }
                }
                drop(st);
                let bank = &ss.banks[ss.bank_of(my_gen)];
                bank[my_pos].lock().unwrap().copy_from_slice(data);
                st = self.state.lock().unwrap();
            }
        }
        st.deposited += 1;
        let mut closed = false;
        if Self::round_complete(&st) {
            self.close_round(&mut st);
            closed = true;
        }
        drop(st);
        if closed {
            self.cv.notify_all();
        }

        // wait for our round's result, cooperatively reducing whatever
        // round is currently closing while we do
        let mut delay = wake_delay;
        let mut st = self.state.lock().unwrap();
        let (n, succ) = loop {
            if self.engine == ReduceEngine::SharedNothing {
                // claim an unowned plan: this waiter becomes the round's
                // exclusive owner and folds it (delegating sub-ranges)
                let mut claim = None;
                if let Some(p) = st.plan.as_mut() {
                    if !p.owned {
                        p.owned = true;
                        claim = Some((p.generation, p.n));
                    }
                }
                if let Some((pg, pn)) = claim {
                    st = self.sn_own_round(st, pg, pn, my_gen, my_pos);
                    continue;
                }
                // serve a delegated chunk range. Only the waiter of the
                // plan's *own* round may consume the grant ring at its
                // position — one consumer per position per round, which is
                // what keeps the ring single-consumer.
                if st.plan.as_ref().map(|p| p.generation) == Some(my_gen) {
                    let sn = self.sn.as_ref().expect("shared-nothing engine state");
                    if let Some(grant) = sn.grants[my_pos].try_pop() {
                        drop(st);
                        self.sn_serve_grant(my_pos, grant);
                        st = self.state.lock().unwrap();
                        continue;
                    }
                }
            } else if let Some((pg, pn)) = st.plan.as_ref().map(|p| (p.generation, p.n)) {
                drop(st);
                let claimed = self.help_reduce(pg, pn);
                st = self.state.lock().unwrap();
                if claimed {
                    continue;
                }
            }
            // The version stamp makes this lookup safe under overlap: our
            // round is parked until every waiter (us included) copies it.
            if let Some(idx) = st.done.iter().position(|r| r.generation == my_gen) {
                if let Some(d) = delay.take() {
                    drop(st);
                    thread::sleep(d);
                    st = self.state.lock().unwrap();
                    continue;
                }
                let round = &mut st.done[idx];
                data.copy_from_slice(&round.mean);
                round.readers_left -= 1;
                let n = round.ring.len();
                let succ = round.ring[(my_pos + 1) % n];
                Self::gc(&mut st);
                break (n, succ);
            }
            st = self.wait_round(st);
        };
        drop(st);
        let bytes_tx = self.account_ring(me, succ, my_pos, n, net);
        Ok(RoundOutcome { generation: my_gen, contributors: n, position: my_pos, bytes_tx })
    }

    /// Drive this member's hops of the chunked ring schedule through the
    /// network: `n-1` reduce-scatter hops then `n-1` all-gather hops, each
    /// moving one segment of every chunk to the ring successor (schedule
    /// math shared with [`super::traffic`]). Returns the bytes *delivered*:
    /// a hop faulted by the run's [`FaultPlan`] (this member's crash window
    /// opening mid-round, or a seeded drop) moves zero NIC bytes and is
    /// excluded, so `metrics.sync_bytes` — fed from this return value —
    /// stays exactly equal to the NIC counters under faults. The ring
    /// successor is always a *depositor* of this round (evicted or crashed
    /// members never appear in `Round::ring`), so the undelivered cases are
    /// all on this member's own side.
    ///
    /// [`FaultPlan`]: crate::net::fault::FaultPlan
    fn account_ring(
        &self,
        me: NodeId,
        succ: NodeId,
        my_pos: usize,
        n: usize,
        net: &Network,
    ) -> u64 {
        if n < 2 {
            return 0;
        }
        let mut tx = 0u64;
        for hop in 0..n - 1 {
            let seg = traffic::reduce_scatter_segment(my_pos, n, hop);
            let bytes = traffic::codec_segment_bytes(self.codec, self.len, self.chunks, n, seg);
            // degenerate shapes (len < n) produce zero-length segments: a
            // hop that carries nothing must never touch the network — no
            // NIC bytes, no fault-plan drop accounting for a phantom
            // transfer
            if bytes > 0 && net.try_transfer(me, succ, bytes).is_ok() {
                tx += bytes;
            }
        }
        for hop in 0..n - 1 {
            let seg = traffic::all_gather_segment(my_pos, n, hop);
            let bytes = traffic::codec_segment_bytes(self.codec, self.len, self.chunks, n, seg);
            if bytes > 0 && net.try_transfer(me, succ, bytes).is_ok() {
                tx += bytes;
            }
        }
        tx
    }

    /// Permanently remove one member. If everyone else has already
    /// deposited, the pending round completes without the leaver.
    pub fn leave(&self) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.active > 0);
        st.active = st.active.saturating_sub(1);
        let mut closed = false;
        if Self::round_complete(&st) {
            self.close_round(&mut st);
            closed = true;
        }
        drop(st);
        if closed {
            self.cv.notify_all();
        }
    }

    /// (Re)admit one member — e.g. a trainer rejoining after churn. The
    /// joiner is expected to contribute to the next round (the pending
    /// round now waits for one more deposit). Errors when the group is
    /// already at its slot capacity.
    pub fn join(&self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        ensure!(st.active < self.capacity, "group is at capacity ({})", self.capacity);
        st.active += 1;
        Ok(())
    }

    pub fn active(&self) -> usize {
        self.state.lock().unwrap().active
    }

    /// Generation of the round whose chunk-parallel reduction is currently
    /// in flight (None when no reduce plan is active). Test observability
    /// for deposit/reduce overlap.
    pub fn reducing(&self) -> Option<u64> {
        self.state.lock().unwrap().plan.as_ref().map(|p| p.generation)
    }

    /// Members fully deposited into the pending round.
    pub fn pending(&self) -> usize {
        self.state.lock().unwrap().deposited
    }

    /// Rounds closed so far (the next round's generation stamp).
    pub fn completed_rounds(&self) -> u64 {
        self.state.lock().unwrap().generation
    }

    /// Shared-nothing engine: the epoch stamp of the publication pointer
    /// swap — `generation + 1` of the latest round parked by its owner.
    /// 0 before the first publish, and always 0 under the other engines.
    pub fn published_rounds(&self) -> u64 {
        self.sn.as_ref().map_or(0, |s| s.published.load(Acquire))
    }

    /// Shared-nothing engine: cumulative `(granted, returned)` chunk-range
    /// delegations over the group's lifetime. Every borrowed range comes
    /// back with its stripe, so the two are equal whenever the fabric is
    /// quiescent. `(0, 0)` under the other engines.
    pub fn delegations(&self) -> (usize, usize) {
        self.sn
            .as_ref()
            .map_or((0, 0), |s| (s.delegated.load(SeqCst), s.returned.load(SeqCst)))
    }

    /// Closed-form ring bytes each member moves per direction per round —
    /// the cross-check reference for the measured per-hop traffic (the
    /// `sim/` cost model consumes the measured schedule via
    /// [`super::traffic::RingTraffic`] instead).
    pub fn ring_bytes_per_member(&self, participants: usize) -> u64 {
        if participants <= 1 {
            return 0;
        }
        let vec_bytes = (self.len * 4) as u64;
        2 * vec_bytes * (participants as u64 - 1) / participants as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Role;
    use crate::util::rng::Rng;
    use std::collections::HashMap;
    use std::sync::Arc;

    fn net_with(n: usize) -> (Arc<Network>, Vec<NodeId>) {
        let mut net = Network::new(None);
        let nodes = (0..n).map(|_| net.add_node(Role::Trainer)).collect();
        (Arc::new(net), nodes)
    }

    const ALL_ENGINES: [ReduceEngine; 4] = [
        ReduceEngine::Overlapped,
        ReduceEngine::Striped,
        ReduceEngine::SerialMutex,
        ReduceEngine::SharedNothing,
    ];

    /// The engines with a fixed position-order summation (everything but
    /// the arrival-order serial baseline) — the bit-determinism set.
    const DETERMINISTIC_ENGINES: [ReduceEngine; 3] =
        [ReduceEngine::Overlapped, ReduceEngine::Striped, ReduceEngine::SharedNothing];

    #[test]
    fn mean_matches_sequential_sum() {
        for engine in ALL_ENGINES {
            let n = 4;
            let g = Arc::new(AllReduceGroup::new(n, 8).with_engine(engine));
            let (net, nodes) = net_with(n);
            let mut hs = Vec::new();
            for r in 0..n {
                let g = g.clone();
                let net = net.clone();
                let node = nodes[r];
                hs.push(std::thread::spawn(move || {
                    let mut v = vec![(r + 1) as f32; 8];
                    let out = g.allreduce_mean(&mut v, node, &net).unwrap();
                    (v, out)
                }));
            }
            for h in hs {
                let (v, out) = h.join().unwrap();
                // mean of 1,2,3,4 = 2.5
                assert!(v.iter().all(|&x| (x - 2.5).abs() < 1e-6), "{engine}: {v:?}");
                assert_eq!(out.contributors, 4);
                assert_eq!(out.generation, 0);
                assert!(out.position < 4);
            }
        }
    }

    #[test]
    fn repeated_rounds_stay_consistent() {
        for engine in ALL_ENGINES {
            let n = 3;
            let g = Arc::new(AllReduceGroup::new(n, 4).with_chunks(2).with_engine(engine));
            let (net, nodes) = net_with(n);
            let mut hs = Vec::new();
            for r in 0..n {
                let g = g.clone();
                let net = net.clone();
                let node = nodes[r];
                hs.push(std::thread::spawn(move || {
                    let mut acc = Vec::new();
                    for round in 0..50 {
                        let mut v = vec![(r * 50 + round) as f32; 4];
                        g.allreduce_mean(&mut v, node, &net).unwrap();
                        acc.push(v[0]);
                    }
                    acc
                }));
            }
            let results: Vec<Vec<f32>> = hs.into_iter().map(|h| h.join().unwrap()).collect();
            for round in 0..50 {
                let want = (0..n).map(|r| (r * 50 + round) as f32).sum::<f32>() / n as f32;
                for res in &results {
                    assert!((res[round] - want).abs() < 1e-4, "{engine}");
                }
            }
            assert_eq!(g.completed_rounds(), 50);
        }
    }

    #[test]
    fn leaver_unblocks_pending_round() {
        for engine in ALL_ENGINES {
            let g = Arc::new(AllReduceGroup::new(3, 2).with_engine(engine));
            let (net, nodes) = net_with(3);
            let g2 = g.clone();
            let (net2, node0) = (net.clone(), nodes[0]);
            let waiter = std::thread::spawn(move || {
                let mut v = vec![6.0, 6.0];
                let out = g2.allreduce_mean(&mut v, node0, &net2).unwrap();
                (v, out)
            });
            let g3 = g.clone();
            let (net3, node1) = (net.clone(), nodes[1]);
            let waiter2 = std::thread::spawn(move || {
                let mut v = vec![2.0, 2.0];
                let out = g3.allreduce_mean(&mut v, node1, &net3).unwrap();
                (v, out)
            });
            // give the waiters time to block, then the third member leaves
            while g.pending() < 2 {
                std::thread::yield_now();
            }
            g.leave();
            let (v, out) = waiter.join().unwrap();
            let (v2, out2) = waiter2.join().unwrap();
            // round completed over the two contributors: mean = 4
            assert_eq!(v, vec![4.0, 4.0]);
            assert_eq!(v2, vec![4.0, 4.0]);
            // both waiters learn the exact contributor count of their round
            assert_eq!(out.contributors, 2);
            assert_eq!(out2.contributors, 2);
            assert_eq!(g.active(), 2);
        }
    }

    #[test]
    fn singleton_group_is_identity() {
        for engine in ALL_ENGINES {
            let g = AllReduceGroup::new(1, 3).with_engine(engine);
            let (net, nodes) = net_with(1);
            let mut v = vec![1.0, 2.0, 3.0];
            let out = g.allreduce_mean(&mut v, nodes[0], &net).unwrap();
            assert_eq!(out.contributors, 1);
            assert_eq!(out.position, 0);
            assert_eq!(out.bytes_tx, 0);
            assert_eq!(v, vec![1.0, 2.0, 3.0]);
            assert_eq!(g.ring_bytes_per_member(1), 0);
            assert_eq!(net.tx(nodes[0]), 0);
        }
    }

    #[test]
    fn ring_cost_formula() {
        let g = AllReduceGroup::new(4, 100);
        // 2 * 400 bytes * 3/4 = 600
        assert_eq!(g.ring_bytes_per_member(4), 600);
    }

    #[test]
    fn length_mismatch_rejected() {
        let g = AllReduceGroup::new(1, 3);
        let (net, nodes) = net_with(1);
        let mut v = vec![0.0; 2];
        assert!(g.allreduce_mean(&mut v, nodes[0], &net).is_err());
    }

    #[test]
    fn measured_traffic_matches_ring_formula() {
        // n | len: the per-member measured bytes equal the formula exactly
        let n = 4;
        let g = Arc::new(AllReduceGroup::new(n, 100));
        let (net, nodes) = net_with(n);
        let mut hs = Vec::new();
        for &node in &nodes {
            let g = g.clone();
            let net = net.clone();
            hs.push(std::thread::spawn(move || {
                let mut v = vec![1.0f32; 100];
                g.allreduce_mean(&mut v, node, &net).unwrap()
            }));
        }
        for h in hs {
            let out = h.join().unwrap();
            assert_eq!(out.bytes_tx, 600); // == ring_bytes_per_member(4)
        }
        for &node in &nodes {
            assert_eq!(net.tx(node), 600);
            assert_eq!(net.rx(node), 600);
        }
    }

    #[test]
    fn chunked_traffic_sums_to_exact_aggregate() {
        // Whatever the chunking, total ring traffic over all members is
        // exactly 2(n-1) * vec_bytes, and each member is within one
        // chunk-segment of the per-member formula.
        for &(n, len, chunks) in &[(3usize, 101usize, 1usize), (4, 1_037, 8), (5, 997, 64)] {
            let g = Arc::new(AllReduceGroup::new(n, len).with_chunks(chunks));
            let (net, nodes) = net_with(n);
            let mut hs = Vec::new();
            for &node in &nodes {
                let g = g.clone();
                let net = net.clone();
                hs.push(std::thread::spawn(move || {
                    let mut v = vec![1.0f32; len];
                    g.allreduce_mean(&mut v, node, &net).unwrap()
                }));
            }
            let mut total = 0u64;
            for h in hs {
                let out = h.join().unwrap();
                total += out.bytes_tx;
                let formula = g.ring_bytes_per_member(n);
                let slack = 4 * 2 * chunks as u64; // one element per chunk, both phases
                assert!(
                    out.bytes_tx.abs_diff(formula) <= slack,
                    "n={n} len={len} C={chunks}: measured {} vs formula {formula}",
                    out.bytes_tx
                );
            }
            assert_eq!(total, 2 * (n as u64 - 1) * len as u64 * 4);
            let nic_total: u64 = nodes.iter().map(|&nd| net.tx(nd)).sum();
            assert_eq!(nic_total, total);
        }
    }

    #[test]
    fn contributor_count_is_exact_after_membership_shrinks() {
        // Regression: the old code reported `active.max(1)` at wake time,
        // which is wrong once membership changed after the round closed.
        let g = Arc::new(AllReduceGroup::new(2, 2));
        let (net, nodes) = net_with(2);
        let g2 = g.clone();
        let net2 = net.clone();
        let node0 = nodes[0];
        let slow = std::thread::spawn(move || {
            let mut v = vec![1.0, 1.0];
            g2.allreduce_mean_inner(
                &mut v,
                node0,
                &net2,
                Some(Duration::from_millis(200)),
            )
            .unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        let mut v = vec![3.0, 3.0];
        let out = g.allreduce_mean(&mut v, nodes[1], &net).unwrap();
        assert_eq!(out.contributors, 2);
        g.leave(); // membership shrinks to 1 before the slow waiter wakes up
        let slow_out = slow.join().unwrap();
        assert_eq!(slow_out.contributors, 2, "waiter must see its round's count");
        assert_eq!(slow_out.generation, out.generation);
    }

    #[test]
    fn overlapping_round_cannot_clobber_unread_result() {
        // Regression for the generation race: force round N+1 to finalize
        // (via mid-round leaves) while a round-N waiter has not yet copied
        // its mean out. With the version-stamped result store the slow
        // waiter still reads round N's mean and contributor count.
        //
        // Membership 5 = threads A (slow-wake), B, C + two phantom members
        // held by the test thread, which only ever `leave`s.
        let g = Arc::new(AllReduceGroup::new(5, 2));
        let (net, nodes) = net_with(5);
        let ga = g.clone();
        let neta = net.clone();
        let node_a = nodes[0];
        let a = std::thread::spawn(move || {
            let mut v = vec![3.0, 3.0];
            let out = ga
                .allreduce_mean_inner(&mut v, node_a, &neta, Some(Duration::from_millis(400)))
                .unwrap();
            (v, out)
        });
        let mut fast = Vec::new();
        for (i, val) in [(1usize, 6.0f32), (2, 9.0)] {
            let g = g.clone();
            let net = net.clone();
            let node = nodes[i];
            let second = if i == 1 { 10.0 } else { 20.0 };
            fast.push(std::thread::spawn(move || {
                let mut v = vec![val; 2];
                let r0 = g.allreduce_mean(&mut v, node, &net).unwrap();
                let first_mean = v[0];
                let mut w = vec![second; 2];
                let r1 = g.allreduce_mean(&mut w, node, &net).unwrap();
                (first_mean, r0, w[0], r1)
            }));
        }
        // wait for A, B, C to be deposited into round 0, then shrink 5 -> 3
        // so round 0 completes while A dawdles before copying
        while g.pending() < 3 {
            std::thread::yield_now();
        }
        g.leave();
        g.leave();
        // B and C wake, copy round 0, and start round 1; shrink 3 -> 2 so
        // round 1 completes too — before A has read round 0
        while g.pending() < 2 {
            std::thread::yield_now();
        }
        // retire one more membership (A never rejoins after round 0) so the
        // {B, C} round can close while A still hasn't copied round 0 out
        g.leave();
        let (a_mean, a_out) = {
            let (v, out) = a.join().unwrap();
            (v[0], out)
        };
        // round 0 = mean(3, 6, 9) over {A, B, C}
        assert_eq!(a_mean, 6.0);
        assert_eq!(a_out.contributors, 3);
        assert_eq!(a_out.generation, 0);
        for h in fast {
            let (m0, r0, m1, r1) = h.join().unwrap();
            assert_eq!(m0, 6.0);
            assert_eq!(r0.contributors, 3);
            assert_eq!(r0.generation, 0);
            // round 1 = mean(10, 20) over {B, C} — finalized while A slept
            assert_eq!(m1, 15.0);
            assert_eq!(r1.contributors, 2);
            assert_eq!(r1.generation, 1);
        }
    }

    #[test]
    fn means_bit_identical_to_position_order_reference() {
        // Satellite regression: n threads contributing *simultaneously*
        // through each deterministic engine must produce bit-identical
        // means to a single-threaded reference that sums in the engine's
        // fixed (position-major) chunk-wise order — for every round, under
        // real thread interleaving. The shared-nothing engine runs the
        // same reference: ownership delegation must not change a bit.
        for engine in DETERMINISTIC_ENGINES {
            let (n, p, chunks, rounds) = (4usize, 257usize, 5usize, 25usize);
            let g = Arc::new(AllReduceGroup::new(n, p).with_chunks(chunks).with_engine(engine));
            let (net, nodes) = net_with(n);
            let mut hs = Vec::new();
            for t in 0..n {
                let g = g.clone();
                let net = net.clone();
                let node = nodes[t];
                hs.push(std::thread::spawn(move || {
                    let mut rng = Rng::new(0xD37E ^ t as u64);
                    let mut log = Vec::with_capacity(rounds);
                    for _ in 0..rounds {
                        // fractional values whose f32 sum is association-
                        // order sensitive — reordering would change bits
                        let v: Vec<f32> = (0..p)
                            .map(|_| (rng.next_u64() % 1_000_003) as f32 * 1e-3 - 500.0)
                            .collect();
                        let mut buf = v.clone();
                        let out = g.allreduce_mean(&mut buf, node, &net).unwrap();
                        log.push((out.generation, out.position, v, buf));
                    }
                    log
                }));
            }
            let mut by_gen: HashMap<u64, Vec<(usize, Vec<f32>, Vec<f32>)>> = HashMap::new();
            for h in hs {
                for (gen, pos, v, mean) in h.join().unwrap() {
                    by_gen.entry(gen).or_default().push((pos, v, mean));
                }
            }
            assert_eq!(by_gen.len(), rounds, "{engine}");
            for (gen, mut entries) in by_gen {
                entries.sort_by_key(|e| e.0);
                assert_eq!(entries.len(), n, "{engine} gen {gen}");
                let mut reference = entries[0].1.clone();
                for e in &entries[1..] {
                    for (r, &x) in reference.iter_mut().zip(&e.1) {
                        *r += x;
                    }
                }
                let inv = 1.0 / n as f32;
                for r in reference.iter_mut() {
                    *r *= inv;
                }
                for (pos, _, mean) in &entries {
                    for (a, b) in mean.iter().zip(&reference) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{engine} gen {gen} pos {pos}: {a} != reference {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dynamic_membership_stress_every_mean_is_exact() {
        // N threads run 100s of rounds through the overlapped engine while
        // members leave at random points; every returned mean must equal
        // the sequential reference over that round's surviving contributor
        // set, and every returned contributor count must be exact.
        let n = 8;
        let p = 4;
        let g = Arc::new(AllReduceGroup::new(n, p).with_chunks(3));
        assert_eq!(g.engine(), ReduceEngine::Overlapped);
        let (net, nodes) = net_with(n);
        let mut hs = Vec::new();
        for t in 0..n {
            let g = g.clone();
            let net = net.clone();
            let node = nodes[t];
            hs.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xA11E ^ t as u64);
                // members leave at staggered, pseudo-random round counts
                let my_rounds = 100 + (rng.next_u64() % 150) as usize;
                let mut log = Vec::with_capacity(my_rounds);
                for r in 0..my_rounds {
                    let contrib = (t * 1_000 + r) as f32;
                    let mut v = vec![contrib; p];
                    let out = g.allreduce_mean(&mut v, node, &net).unwrap();
                    assert!(v.iter().all(|&x| x == v[0]), "mean not uniform");
                    log.push((out.generation, contrib, v[0], out.contributors));
                }
                g.leave();
                log
            }));
        }
        let mut by_gen: HashMap<u64, Vec<(f32, f32, usize)>> = HashMap::new();
        for h in hs {
            for (gen, contrib, mean, parts) in h.join().unwrap() {
                by_gen.entry(gen).or_default().push((contrib, mean, parts));
            }
        }
        assert!(by_gen.len() >= 100, "expected 100s of rounds, got {}", by_gen.len());
        for (gen, entries) in &by_gen {
            let count = entries.len();
            let want = entries.iter().map(|e| e.0).sum::<f32>() / count as f32;
            for &(_, mean, parts) in entries {
                assert_eq!(
                    parts, count,
                    "gen {gen}: reported {parts} contributors, actual {count}"
                );
                assert!(
                    (mean - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "gen {gen}: mean {mean} != reference {want}"
                );
            }
        }
        assert_eq!(g.active(), 0);
    }

    #[test]
    fn engine_parses_and_displays() {
        assert_eq!("striped".parse::<ReduceEngine>().unwrap(), ReduceEngine::Striped);
        assert_eq!("serial".parse::<ReduceEngine>().unwrap(), ReduceEngine::SerialMutex);
        assert_eq!("SERIAL-MUTEX".parse::<ReduceEngine>().unwrap(), ReduceEngine::SerialMutex);
        assert_eq!("overlapped".parse::<ReduceEngine>().unwrap(), ReduceEngine::Overlapped);
        assert_eq!("double-buffered".parse::<ReduceEngine>().unwrap(), ReduceEngine::Overlapped);
        assert_eq!(
            "shared-nothing".parse::<ReduceEngine>().unwrap(),
            ReduceEngine::SharedNothing
        );
        assert_eq!(
            "Shared_Nothing".parse::<ReduceEngine>().unwrap(),
            ReduceEngine::SharedNothing
        );
        assert_eq!("sn".parse::<ReduceEngine>().unwrap(), ReduceEngine::SharedNothing);
        let err = "quantum".parse::<ReduceEngine>().unwrap_err().to_string();
        assert!(err.contains("shared-nothing"), "error must list every engine: {err}");
        assert_eq!(ReduceEngine::Striped.to_string(), "striped");
        assert_eq!(ReduceEngine::SerialMutex.to_string(), "serial");
        assert_eq!(ReduceEngine::Overlapped.to_string(), "overlapped");
        assert_eq!(ReduceEngine::SharedNothing.to_string(), "shared-nothing");
    }

    #[test]
    fn shared_nothing_publishes_epoch_stamped_rounds() {
        // the publication stamp advances by pointer swap at every park:
        // after k rounds it reads exactly k (generation + 1 of the last)
        let g = AllReduceGroup::new(1, 16).with_engine(ReduceEngine::SharedNothing);
        let (net, nodes) = net_with(1);
        assert_eq!(g.published_rounds(), 0);
        for k in 1..=5u64 {
            let mut v = vec![k as f32; 16];
            let out = g.allreduce_mean(&mut v, nodes[0], &net).unwrap();
            assert_eq!(out.generation, k - 1);
            assert_eq!(v, vec![k as f32; 16], "singleton round must be identity");
            assert_eq!(g.published_rounds(), k);
        }
        assert_eq!(g.completed_rounds(), 5);
        // the other engines never touch the stamp
        let g = AllReduceGroup::new(1, 4);
        let (net, nodes) = net_with(1);
        let mut v = vec![1.0; 4];
        g.allreduce_mean(&mut v, nodes[0], &net).unwrap();
        assert_eq!(g.published_rounds(), 0);
    }

    #[test]
    fn shared_nothing_delegates_and_returns_every_chunk_range() {
        // with 4 members and 8 chunks every round grants SN_DELEGATE_MAX
        // ranges; once quiescent, granted == returned (every borrowed
        // range came back with its stripe) and the means are exact
        let (n, p, chunks, rounds) = (4usize, 512usize, 8usize, 40usize);
        let g = Arc::new(
            AllReduceGroup::new(n, p)
                .with_chunks(chunks)
                .with_engine(ReduceEngine::SharedNothing),
        );
        let (net, nodes) = net_with(n);
        let mut hs = Vec::new();
        for t in 0..n {
            let g = g.clone();
            let net = net.clone();
            let node = nodes[t];
            hs.push(std::thread::spawn(move || {
                for r in 0..rounds {
                    let mut v = vec![(t * rounds + r) as f32; p];
                    let out = g.allreduce_mean(&mut v, node, &net).unwrap();
                    assert_eq!(out.contributors, n);
                    let want =
                        (0..n).map(|u| (u * rounds + r) as f32).sum::<f32>() / n as f32;
                    assert!(v.iter().all(|&x| x == want), "round {r}: {} != {want}", v[0]);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let (granted, returned) = g.delegations();
        assert_eq!(granted, returned, "a borrowed range never came back");
        assert_eq!(
            granted,
            rounds * SN_DELEGATE_MAX,
            "4 members x 8 chunks must delegate {SN_DELEGATE_MAX} ranges per round"
        );
        assert_eq!(g.published_rounds(), rounds as u64);
    }

    #[test]
    fn shared_nothing_ring_depth_one_still_exact_under_backpressure() {
        // depth 1 disables the pipelining: a round-g+1 deposit finds its
        // ring full until the owner drains round g, exercising the
        // backpressure wait path on every round; results stay exact
        let (n, rounds) = (3usize, 60usize);
        let g = Arc::new(
            AllReduceGroup::new(n, 32)
                .with_chunks(4)
                .with_engine(ReduceEngine::SharedNothing)
                .with_ring_depth(1),
        );
        let (net, nodes) = net_with(n);
        let mut hs = Vec::new();
        for t in 0..n {
            let g = g.clone();
            let net = net.clone();
            let node = nodes[t];
            hs.push(std::thread::spawn(move || {
                for r in 0..rounds {
                    let mut v = vec![(t + r) as f32; 32];
                    g.allreduce_mean(&mut v, node, &net).unwrap();
                    let want = (0..n).map(|u| (u + r) as f32).sum::<f32>() / n as f32;
                    assert!(v.iter().all(|&x| x == want), "round {r}");
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(g.completed_rounds(), rounds as u64);
    }

    #[test]
    #[should_panic(expected = "chunk count must be >= 1")]
    fn zero_chunks_panics_instead_of_silently_clamping() {
        // the silent `.max(1)` clamp is gone: degenerate --chunks values
        // are rejected at config parse time, and a builder violation is a
        // loud caller bug
        let _ = AllReduceGroup::new(2, 8).with_chunks(0);
    }

    #[test]
    fn cursor_tag_carries_bank_parity() {
        // the generation tag's low bit (bit 32 of the packed word) is the
        // deposit-bank parity, so consecutive generations always differ in
        // tag and a stale helper can never fold the wrong deposit bank
        let a = pack_cursor(6, 0);
        let b = pack_cursor(7, 0);
        assert_ne!(a & !0xFFFF_FFFFu64, b & !0xFFFF_FFFFu64);
        assert_eq!((a >> 32) & 1, 0);
        assert_eq!((b >> 32) & 1, 1);
        // the chunk index occupies the low 32 bits untouched
        assert_eq!(pack_cursor(7, 42) & 0xFFFF_FFFF, 42);
        assert_eq!(pack_cursor(7, 42) & !0xFFFF_FFFFu64, b & !0xFFFF_FFFFu64);
    }

    #[test]
    fn deposit_completes_while_previous_reduce_is_stalled() {
        // Acceptance: with the overlapped engine, a round-1 deposit lands in
        // the off-parity bank while round 0's chunk reduction is artificially
        // stalled. (The single-bank striped engine would block the deposit
        // until the drain finished, so `reducing()` would be None by the
        // time `pending()` reaches 1 and this test would fail.)
        let g = Arc::new(
            AllReduceGroup::new(3, 64)
                .with_chunks(4)
                .with_reduce_stall(Duration::from_millis(150)),
        );
        let (net, nodes) = net_with(3);
        let mut waiters = Vec::new();
        for (i, val) in [(0usize, 1.0f32), (1, 5.0)] {
            let g = g.clone();
            let net = net.clone();
            let node = nodes[i];
            waiters.push(std::thread::spawn(move || {
                let mut v = vec![val; 64];
                let out = g.allreduce_mean(&mut v, node, &net).unwrap();
                (v, out)
            }));
        }
        while g.pending() < 2 {
            std::thread::yield_now();
        }
        // the third member leaves: round 0 closes over {A, B} and its
        // (stalled) reduce plan opens
        g.leave();
        // a fresh contributor deposits into round 1 while round 0 drains
        let gd = g.clone();
        let netd = net.clone();
        let node_d = nodes[2];
        let depositor = std::thread::spawn(move || {
            let mut v = vec![9.0f32; 64];
            let out = gd.allreduce_mean(&mut v, node_d, &netd).unwrap();
            (v, out)
        });
        while g.pending() < 1 {
            std::thread::yield_now();
        }
        // the round-1 deposit completed while round 0 is still reducing —
        // the stall (4 chunks x 150ms over 2 helpers >= 300ms) makes this
        // deterministic
        assert_eq!(
            g.reducing(),
            Some(0),
            "round-1 deposit must land while round 0's reduce is in flight"
        );
        // shrink so round 1 can close over the lone depositor; the close is
        // deferred until round 0's reducer parks and hands off
        g.leave();
        for h in waiters {
            let (v, out) = h.join().unwrap();
            assert_eq!(v, vec![3.0; 64]); // mean(1, 5)
            assert_eq!(out.generation, 0);
            assert_eq!(out.contributors, 2);
        }
        let (vd, outd) = depositor.join().unwrap();
        assert_eq!(vd, vec![9.0; 64]); // singleton round: identity
        assert_eq!(outd.generation, 1);
        assert_eq!(outd.contributors, 1);
        assert_eq!(g.active(), 1);
        assert_eq!(g.reducing(), None);
    }

    #[test]
    fn leave_then_join_restores_membership() {
        let g = Arc::new(AllReduceGroup::new(2, 4));
        let (net, nodes) = net_with(2);
        g.leave();
        assert_eq!(g.active(), 1);
        // a singleton round completes alone
        let mut v = vec![2.0; 4];
        let out = g.allreduce_mean(&mut v, nodes[0], &net).unwrap();
        assert_eq!(out.contributors, 1);
        // rejoin: rounds wait for both members again
        g.join().unwrap();
        assert_eq!(g.active(), 2);
        assert!(g.join().is_err(), "join past capacity must be rejected");
        let g2 = g.clone();
        let net2 = net.clone();
        let node1 = nodes[1];
        let peer = std::thread::spawn(move || {
            let mut w = vec![4.0; 4];
            g2.allreduce_mean(&mut w, node1, &net2).unwrap();
            w
        });
        let mut v = vec![2.0; 4];
        let out = g.allreduce_mean(&mut v, nodes[0], &net).unwrap();
        assert_eq!(out.contributors, 2);
        assert_eq!(v, vec![3.0; 4]);
        assert_eq!(peer.join().unwrap(), vec![3.0; 4]);
    }
}
