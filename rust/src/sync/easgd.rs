//! EASGD synchronization (paper Algorithm 2; Zhang et al. 2015).
//!
//! Centralized: the trainer's replica and the central `w^PS` on the sync-PS
//! tier move toward each other by the elastic parameter α. The update is
//! deliberately *asymmetric* — neither side is overwritten — because both
//! the PS (in sync with other trainers) and the Hogwild workers (which kept
//! training during the round) have information worth keeping. Pushes are
//! chunked and optionally delta-gated (skipped chunks move zero bytes on
//! either leg); the recorded sync bytes are the measured traffic of each
//! round, not the full-vector formula.
//!
//! Under the partitioned fabric each `EasgdSync` instance is bound to one
//! partition of one trainer's replica ([`SyncCtx::range`]) and owns its own
//! [`DeltaGate`] (quantile sketch) plus [`DeltaScanCache`] — the
//! per-trainer/per-shard gating the monolithic group-level gate couldn't
//! express.

use anyhow::Result;

use super::prim::Arc;
use super::{
    ps::{DeltaGate, DeltaScanCache, SyncPsGroup},
    traffic::WireCodec,
    RepartitionCarry, SyncCtx, SyncStrategy,
};

pub struct EasgdSync {
    group: Arc<SyncPsGroup>,
    pub alpha: f32,
    /// per-strategy dirty-epoch scan cache (no-op when the replica doesn't
    /// track dirty epochs), keyed by global push-chunk ordinal
    cache: DeltaScanCache,
    /// this strategy's own delta gate (per trainer × partition); `None`
    /// falls back to the group-level gate
    gate: Option<DeltaGate>,
    /// wire codec for both push legs (fp32 = the identity fabric)
    codec: WireCodec,
    /// per-trainer × per-partition error-feedback residual for lossy
    /// codecs, indexed relative to the partition's `range.lo()`. Lazily
    /// sized on the first round; a repartition cutover rebuilds strategies
    /// and drops the residual with them — the un-flushed remainder is
    /// bounded by one round's codec error, the same staleness class as a
    /// skipped chunk
    residual: Vec<f32>,
    /// BMUF state parked while this partition is health-demoted to EASGD,
    /// held untouched and re-emitted so a later promotion rehydrates it
    bmuf_parked: Option<super::bmuf::BmufCarry>,
}

impl EasgdSync {
    pub fn new(group: Arc<SyncPsGroup>, alpha: f32) -> Self {
        Self {
            group,
            alpha,
            cache: DeltaScanCache::new(),
            gate: None,
            codec: WireCodec::Fp32,
            residual: Vec::new(),
            bmuf_parked: None,
        }
    }

    /// Give this strategy its own [`DeltaGate`] — its private quantile
    /// sketch — instead of the group-level one.
    pub fn with_gate(mut self, gate: DeltaGate) -> Self {
        self.gate = Some(gate);
        self
    }

    /// Sync this partition with `codec` on the wire (both push legs).
    /// Lossy codecs allocate this strategy's error-feedback residual on
    /// first use.
    pub fn with_codec(mut self, codec: WireCodec) -> Self {
        self.codec = codec;
        self
    }
}

impl SyncStrategy for EasgdSync {
    fn sync_round(&mut self, ctx: &SyncCtx<'_>) -> Result<f32> {
        let residual = if self.codec == WireCodec::Fp32 {
            None
        } else {
            if self.residual.len() != ctx.range.len {
                self.residual = vec![0.0; ctx.range.len];
            }
            Some(self.residual.as_mut_slice())
        };
        let stats = self.group.elastic_sync_partition_codec(
            ctx.local,
            ctx.range,
            self.alpha,
            ctx.trainer_node,
            ctx.net,
            &mut self.cache,
            self.gate.as_ref(),
            self.codec,
            residual,
        );
        // record the bytes this round *actually* moved (delta-gated chunks
        // may skip), so metrics.sync_bytes always agrees with NIC counters;
        // chunk counters feed the live skip-rate column of the exp reports
        ctx.metrics.record_sync(stats.bytes);
        ctx.metrics.record_sync_chunks(
            stats.chunks_pushed,
            stats.chunks_skipped,
            stats.chunks_scan_skipped,
        );
        // per-partition resolution: the measured byte shares feed the sim
        // cost model and the adaptive repartitioner
        ctx.metrics.record_partition_sync_bytes(ctx.partition, stats.bytes);
        self.group.note_partition_round(
            ctx.partition,
            &stats,
            self.group.round_bytes_codec_scoped(self.codec, ctx.range),
        );
        Ok(stats.gap)
    }

    fn take_repartition_carry(&mut self) -> Option<RepartitionCarry> {
        Some(RepartitionCarry {
            cache: std::mem::take(&mut self.cache),
            gate: self.gate.take(),
            bmuf: self.bmuf_parked.take(),
        })
    }

    fn install_repartition_carry(&mut self, carry: RepartitionCarry) {
        self.cache = carry.cache;
        if carry.gate.is_some() {
            // keep the warmed sketch instead of the freshly built gate; an
            // ungated carry (legacy group-gate strategies) changes nothing
            self.gate = carry.gate;
        }
        if carry.bmuf.is_some() {
            // a demoted BMUF partition: park the momentum for the promotion
            self.bmuf_parked = carry.bmuf;
        }
    }

    fn name(&self) -> &'static str {
        "easgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;
    use crate::net::{Network, Role};
    use crate::sync::ParamRange;
    use crate::tensor::HogwildBuffer;

    #[test]
    fn round_counts_and_moves() {
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group = Arc::new(SyncPsGroup::build(&vec![0.0; 10], 2, &mut net));
        let metrics = Metrics::new();
        let local = HogwildBuffer::from_slice(&vec![2.0; 10]);
        let mut s = EasgdSync::new(group.clone(), 0.5);
        let ctx = SyncCtx::full(&local, tnode, &net, &metrics);
        let gap = s.sync_round(&ctx).unwrap();
        assert!((gap - 2.0).abs() < 1e-6);
        assert_eq!(metrics.snapshot().syncs, 1);
        assert_eq!(metrics.snapshot().sync_bytes, 80);
        assert!(local.to_vec().iter().all(|&x| (x - 1.0).abs() < 1e-6));
        assert!(group.central.to_vec().iter().all(|&x| (x - 1.0).abs() < 1e-6));
    }

    #[test]
    fn delta_gated_rounds_record_measured_bytes() {
        // with a delta gate, metrics.sync_bytes must equal the bytes that
        // actually crossed the sync-PS NICs — not the full-round formula
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group = Arc::new(
            SyncPsGroup::build(&vec![0.0; 16], 2, &mut net).with_push_chunking(4, 1e-6),
        );
        let metrics = Metrics::new();
        // only [0, 4) diverges: one chunk pushed, three skipped
        let mut lv = vec![0.0f32; 16];
        for x in lv.iter_mut().take(4) {
            *x = 2.0;
        }
        let local = HogwildBuffer::from_slice(&lv);
        let mut s = EasgdSync::new(group.clone(), 0.5);
        let ctx = SyncCtx::full(&local, tnode, &net, &metrics);
        s.sync_round(&ctx).unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.syncs, 1);
        assert_eq!(snap.sync_bytes, 2 * 4 * 4); // one 4-elem chunk, both legs
        assert!(snap.sync_bytes < group.round_bytes());
        assert_eq!(net.role_bytes(Role::SyncPs), snap.sync_bytes);
        assert_eq!(group.traffic().chunks_skipped, 3);
        // the chunk counters surface as live metrics for the skip-rate column
        assert_eq!(snap.sync_chunks_pushed, 1);
        assert_eq!(snap.sync_chunks_skipped, 3);
        assert!((snap.sync_skip_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn dirty_tracked_replica_skips_scans_across_rounds() {
        // a shadow loop over an idle (untouched) replica stops scanning
        // entirely after the first converged round
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group = Arc::new(
            SyncPsGroup::build(&vec![1.0; 32], 1, &mut net).with_push_chunking(8, 1e-6),
        );
        let metrics = Metrics::new();
        let local = HogwildBuffer::from_slice(&vec![1.0; 32]).with_dirty_epochs(8);
        let mut s = EasgdSync::new(group.clone(), 0.5);
        let ctx = SyncCtx::full(&local, tnode, &net, &metrics);
        for _ in 0..5 {
            s.sync_round(&ctx).unwrap();
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.sync_bytes, 0, "identical replicas move nothing");
        // round 1 scanned all 4 chunks cold; rounds 2-5 reused every scan
        assert_eq!(snap.sync_chunks_skipped, 5 * 4);
        assert_eq!(snap.sync_scan_skipped, 4 * 4);
        assert_eq!(net.role_bytes(Role::SyncPs), 0);
    }

    #[test]
    fn range_scoped_strategy_with_own_gate_syncs_its_partition_only() {
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let p = 64;
        let group = Arc::new(
            SyncPsGroup::build(&vec![0.0; p], 2, &mut net).with_push_chunking(8, 0.0),
        );
        let metrics = Metrics::new();
        let local = HogwildBuffer::from_slice(&vec![4.0; p]).with_dirty_epochs(8);
        let mut s = EasgdSync::new(group.clone(), 0.5).with_gate(DeltaGate::new(1e-3, 0.0));
        let range = ParamRange { offset: 32, len: 16 };
        let ctx = SyncCtx {
            local: &local,
            range,
            partition: 1,
            trainer_node: tnode,
            net: &net,
            metrics: &metrics,
        };
        let gap = s.sync_round(&ctx).unwrap();
        assert!((gap - 4.0).abs() < 1e-6);
        // only the partition's two chunks moved
        let snap = metrics.snapshot();
        assert_eq!(snap.sync_bytes, 2 * 16 * 4);
        assert_eq!(snap.sync_chunks_pushed, 2);
        let lv = local.to_vec();
        assert!(lv[..32].iter().all(|&x| x == 4.0));
        assert!(lv[32..48].iter().all(|&x| (x - 2.0).abs() < 1e-6));
        assert!(lv[48..].iter().all(|&x| x == 4.0));
        // a second round: the partition converged below this strategy's
        // own fixed gate, so it skips both chunks (and reuses the scans)
        s.sync_round(&ctx).unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.sync_bytes, 2 * 16 * 4, "converged partition moves nothing more");
        assert_eq!(snap.sync_chunks_skipped, 2);
        // both rounds were recorded at per-partition resolution
        assert_eq!(snap.partition_sync_bytes, vec![0, 2 * 16 * 4]);
        let t = group.traffic();
        assert_eq!(t.per_partition.len(), 2);
        assert_eq!(t.per_partition[1].rounds, 2);
        assert_eq!(t.per_partition[1].bytes_moved, 2 * 16 * 4);
        assert_eq!(t.per_partition[1].full_round_bytes, 2 * 4 * 16);
    }

    #[test]
    fn repartition_carry_moves_gate_and_cache_across_strategies() {
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let p = 64;
        let group = Arc::new(
            SyncPsGroup::build(&vec![0.0; p], 1, &mut net).with_push_chunking(8, 0.0),
        );
        let metrics = Metrics::new();
        let local = HogwildBuffer::from_slice(&vec![1.0; p]).with_dirty_epochs(8);
        let mut old = EasgdSync::new(group.clone(), 0.5).with_gate(DeltaGate::new(0.0, 0.5));
        let range = ParamRange::full(p);
        let ctx = SyncCtx {
            local: &local,
            range,
            partition: 0,
            trainer_node: tnode,
            net: &net,
            metrics: &metrics,
        };
        // warm the sketch and the scan cache over a few rounds
        for _ in 0..4 {
            old.sync_round(&ctx).unwrap();
        }
        let carry = old.take_repartition_carry().expect("EASGD must carry gate state");
        let warmed = carry.gate.as_ref().expect("gated strategy carries its gate");
        assert!(warmed.sketch_samples() > 0, "carried sketch must be warm");
        let samples = warmed.sketch_samples();
        // a fresh strategy (as the cutover builds) inherits the state
        let mut new = EasgdSync::new(group, 0.5).with_gate(DeltaGate::new(0.0, 0.5));
        new.install_repartition_carry(carry);
        // the installed gate is the warmed one, not the fresh empty sketch
        let round_observations = p / 8;
        new.sync_round(&ctx).unwrap();
        let reinstalled = new.take_repartition_carry().unwrap();
        assert_eq!(
            reinstalled.gate.unwrap().sketch_samples(),
            samples + round_observations,
            "warmed sketch must keep accumulating where it left off"
        );
    }
}
