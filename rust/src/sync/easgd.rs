//! EASGD synchronization (paper Algorithm 2; Zhang et al. 2015).
//!
//! Centralized: the trainer's replica and the central `w^PS` on the sync-PS
//! tier move toward each other by the elastic parameter α. The update is
//! deliberately *asymmetric* — neither side is overwritten — because both
//! the PS (in sync with other trainers) and the Hogwild workers (which kept
//! training during the round) have information worth keeping.

use std::sync::Arc;

use anyhow::Result;

use super::{ps::SyncPsGroup, SyncCtx, SyncStrategy};

pub struct EasgdSync {
    group: Arc<SyncPsGroup>,
    pub alpha: f32,
}

impl EasgdSync {
    pub fn new(group: Arc<SyncPsGroup>, alpha: f32) -> Self {
        Self { group, alpha }
    }
}

impl SyncStrategy for EasgdSync {
    fn sync_round(&mut self, ctx: &SyncCtx<'_>) -> Result<f32> {
        let gap = self.group.elastic_sync(ctx.local, self.alpha, ctx.trainer_node, ctx.net);
        ctx.metrics.record_sync(self.group.round_bytes());
        Ok(gap)
    }

    fn name(&self) -> &'static str {
        "easgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;
    use crate::net::{Network, Role};
    use crate::tensor::HogwildBuffer;

    #[test]
    fn round_counts_and_moves() {
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group = Arc::new(SyncPsGroup::build(&vec![0.0; 10], 2, &mut net));
        let metrics = Metrics::new();
        let local = HogwildBuffer::from_slice(&vec![2.0; 10]);
        let mut s = EasgdSync::new(group.clone(), 0.5);
        let ctx = SyncCtx { local: &local, trainer_node: tnode, net: &net, metrics: &metrics };
        let gap = s.sync_round(&ctx).unwrap();
        assert!((gap - 2.0).abs() < 1e-6);
        assert_eq!(metrics.snapshot().syncs, 1);
        assert_eq!(metrics.snapshot().sync_bytes, 80);
        assert!(local.to_vec().iter().all(|&x| (x - 1.0).abs() < 1e-6));
        assert!(group.central.to_vec().iter().all(|&x| (x - 1.0).abs() < 1e-6));
    }
}
