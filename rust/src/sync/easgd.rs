//! EASGD synchronization (paper Algorithm 2; Zhang et al. 2015).
//!
//! Centralized: the trainer's replica and the central `w^PS` on the sync-PS
//! tier move toward each other by the elastic parameter α. The update is
//! deliberately *asymmetric* — neither side is overwritten — because both
//! the PS (in sync with other trainers) and the Hogwild workers (which kept
//! training during the round) have information worth keeping. Pushes are
//! chunked and optionally delta-gated by the [`SyncPsGroup`] (skipped
//! chunks move zero bytes on either leg); the recorded sync bytes are the
//! measured traffic of each round, not the full-vector formula.

use std::sync::Arc;

use anyhow::Result;

use super::{ps::SyncPsGroup, SyncCtx, SyncStrategy};

pub struct EasgdSync {
    group: Arc<SyncPsGroup>,
    pub alpha: f32,
}

impl EasgdSync {
    pub fn new(group: Arc<SyncPsGroup>, alpha: f32) -> Self {
        Self { group, alpha }
    }
}

impl SyncStrategy for EasgdSync {
    fn sync_round(&mut self, ctx: &SyncCtx<'_>) -> Result<f32> {
        let stats =
            self.group
                .elastic_sync_stats(ctx.local, self.alpha, ctx.trainer_node, ctx.net);
        // record the bytes this round *actually* moved (delta-gated chunks
        // may skip), so metrics.sync_bytes always agrees with NIC counters
        ctx.metrics.record_sync(stats.bytes);
        Ok(stats.gap)
    }

    fn name(&self) -> &'static str {
        "easgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;
    use crate::net::{Network, Role};
    use crate::tensor::HogwildBuffer;

    #[test]
    fn round_counts_and_moves() {
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group = Arc::new(SyncPsGroup::build(&vec![0.0; 10], 2, &mut net));
        let metrics = Metrics::new();
        let local = HogwildBuffer::from_slice(&vec![2.0; 10]);
        let mut s = EasgdSync::new(group.clone(), 0.5);
        let ctx = SyncCtx { local: &local, trainer_node: tnode, net: &net, metrics: &metrics };
        let gap = s.sync_round(&ctx).unwrap();
        assert!((gap - 2.0).abs() < 1e-6);
        assert_eq!(metrics.snapshot().syncs, 1);
        assert_eq!(metrics.snapshot().sync_bytes, 80);
        assert!(local.to_vec().iter().all(|&x| (x - 1.0).abs() < 1e-6));
        assert!(group.central.to_vec().iter().all(|&x| (x - 1.0).abs() < 1e-6));
    }

    #[test]
    fn delta_gated_rounds_record_measured_bytes() {
        // with a delta gate, metrics.sync_bytes must equal the bytes that
        // actually crossed the sync-PS NICs — not the full-round formula
        let mut net = Network::new(None);
        let tnode = net.add_node(Role::Trainer);
        let group = Arc::new(
            SyncPsGroup::build(&vec![0.0; 16], 2, &mut net).with_push_chunking(4, 1e-6),
        );
        let metrics = Metrics::new();
        // only [0, 4) diverges: one chunk pushed, three skipped
        let mut lv = vec![0.0f32; 16];
        for x in lv.iter_mut().take(4) {
            *x = 2.0;
        }
        let local = HogwildBuffer::from_slice(&lv);
        let mut s = EasgdSync::new(group.clone(), 0.5);
        let ctx = SyncCtx { local: &local, trainer_node: tnode, net: &net, metrics: &metrics };
        s.sync_round(&ctx).unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.syncs, 1);
        assert_eq!(snap.sync_bytes, 2 * 4 * 4); // one 4-elem chunk, both legs
        assert!(snap.sync_bytes < group.round_bytes());
        assert_eq!(net.role_bytes(Role::SyncPs), snap.sync_bytes);
        assert_eq!(group.traffic().chunks_skipped, 3);
    }
}
