//! Measured sync-traffic export: the wire bytes the fabric *actually* moves.
//!
//! The chunked ring schedule in [`crate::sync::allreduce`] cuts the
//! parameter vector into `C` chunks and every chunk into `n` near-equal
//! segments; a member at ring position `p` sends one segment of every chunk
//! per hop, for `n-1` reduce-scatter hops followed by `n-1` all-gather hops.
//! This module holds the *single source of truth* for that schedule's
//! per-hop byte math: the live collective drives each hop through
//! [`crate::net::Network::transfer`] using [`segment_bytes`], and the
//! paper-scale throughput model in [`crate::sim`] prices collectives from
//! [`RingTraffic::measure`] — the same numbers, chunk rounding included —
//! instead of the closed-form `2·(n-1)/n · bytes` textbook estimate (which
//! survives only as a cross-check reference,
//! `AllReduceGroup::ring_bytes_per_member`).
//!
//! It is also home to [`WireCodec`], the lossy wire formats the fabric can
//! put on those hops (and on EASGD push legs): fp16 / int8 quantization and
//! top-k sparsification, each with an exact wire-size rule so the measured
//! NIC counters, `metrics.sync_bytes`, and the sim pricing all see the
//! compressed sizes through the same chokepoints that already carry the
//! fp32 sizes. Lossy codecs pair with per-trainer error-feedback residuals
//! ([`WireCodec::encode_with_feedback`]): whatever a codec rounds away or
//! drops is carried into the next round's payload instead of being lost.

/// `len / parts` with the remainder spread over the leading parts — the
/// same split rule as `placement::equal_ranges`.
#[inline]
pub fn part_len(len: usize, parts: usize, idx: usize) -> usize {
    len / parts + usize::from(idx < len % parts)
}

/// Offset of part `idx` under the [`part_len`] split rule.
#[inline]
pub fn part_offset(len: usize, parts: usize, idx: usize) -> usize {
    idx * (len / parts) + idx.min(len % parts)
}

/// A lossy (or identity) wire format for sync payloads.
///
/// Every variant defines two things and nothing else: what a message of
/// `e` f32 elements costs on the wire ([`wire_bytes`](Self::wire_bytes)),
/// and what the receiver decodes ([`transcode`](Self::transcode)). The
/// fabric's byte accounting calls the former at the exact points where it
/// used to hard-code `4 * elems`, so the signature invariant
/// `metrics.sync_bytes == sync-PS + ring NIC counters` holds under every
/// codec without any parallel bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum WireCodec {
    /// Identity: 4 bytes/element, lossless. The default, and bit-identical
    /// to the pre-codec fabric.
    #[default]
    Fp32,
    /// IEEE binary16 quantization: 2 bytes/element, round-to-nearest-even.
    Fp16,
    /// Symmetric int8 quantization: 1 byte/element plus a 4-byte per-message
    /// max-abs scale.
    Int8,
    /// Top-k sparsification: keep the `ceil(ratio · elems)` largest-|x|
    /// coordinates (clamped to `[1, elems]`), 8 bytes per kept coordinate
    /// (u32 index + f32 value). Unsent coordinates decode to zero — the
    /// error-feedback residual is what keeps them from being lost.
    TopK(f32),
}

impl WireCodec {
    /// Number of coordinates a top-k message keeps for `elems` elements at
    /// `ratio`: `ceil(elems · ratio)` clamped to `[1, elems]`.
    pub fn topk_k(elems: usize, ratio: f32) -> usize {
        if elems == 0 {
            return 0;
        }
        ((elems as f64 * ratio as f64).ceil() as usize).clamp(1, elems)
    }

    /// Wire bytes of one message carrying `elems` f32 elements under this
    /// codec. An empty message costs nothing under every codec — degenerate
    /// ring segments (`len < n`) must never be priced as transfers, and the
    /// int8 scale / top-k floor only apply to non-empty payloads.
    pub fn wire_bytes(&self, elems: usize) -> u64 {
        if elems == 0 {
            return 0;
        }
        match *self {
            WireCodec::Fp32 => 4 * elems as u64,
            WireCodec::Fp16 => 2 * elems as u64,
            WireCodec::Int8 => elems as u64 + 4,
            WireCodec::TopK(ratio) => 8 * Self::topk_k(elems, ratio) as u64,
        }
    }

    /// Encode-then-decode in place: after this call `data` holds exactly
    /// what the receiver reconstructs from the wire message.
    pub fn transcode(&self, data: &mut [f32]) {
        match *self {
            WireCodec::Fp32 => {}
            WireCodec::Fp16 => {
                for x in data.iter_mut() {
                    *x = f16_bits_to_f32(f32_to_f16_bits(*x));
                }
            }
            WireCodec::Int8 => {
                let max_abs = data.iter().fold(0f32, |m, &x| m.max(x.abs()));
                if max_abs == 0.0 {
                    return;
                }
                let scale = max_abs / 127.0;
                for x in data.iter_mut() {
                    let q = (*x / scale).round().clamp(-127.0, 127.0);
                    *x = q * scale;
                }
            }
            WireCodec::TopK(ratio) => {
                let k = Self::topk_k(data.len(), ratio);
                if k >= data.len() {
                    return;
                }
                let mut order: Vec<usize> = (0..data.len()).collect();
                order.select_nth_unstable_by(k, |&a, &b| {
                    data[b]
                        .abs()
                        .partial_cmp(&data[a].abs())
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
                for &i in &order[k..] {
                    data[i] = 0.0;
                }
            }
        }
    }

    /// Error-feedback encode: fold the residual from previous rounds into
    /// the payload, transcode, and store what the codec lost back into the
    /// residual. Postcondition per element: `decoded + residual == intended`
    /// where `intended = payload_in + residual_in` — so nothing a lossy
    /// codec rounds away or drops ever leaves the pipeline, it just arrives
    /// later. Under [`WireCodec::Fp32`] the residual drains to zero.
    ///
    /// The residual buffer is owned by the sender (one per trainer ×
    /// partition) and must be as long as `buf`.
    pub fn encode_with_feedback(&self, buf: &mut [f32], residual: &mut [f32]) {
        debug_assert_eq!(buf.len(), residual.len());
        for (b, r) in buf.iter_mut().zip(residual.iter_mut()) {
            *b += *r;
            *r = *b;
        }
        self.transcode(buf);
        for (b, r) in buf.iter().zip(residual.iter_mut()) {
            *r -= *b;
        }
    }
}

impl std::fmt::Display for WireCodec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            WireCodec::Fp32 => write!(f, "fp32"),
            WireCodec::Fp16 => write!(f, "fp16"),
            WireCodec::Int8 => write!(f, "int8"),
            WireCodec::TopK(r) => write!(f, "topk:{r}"),
        }
    }
}

impl std::str::FromStr for WireCodec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim();
        match s {
            "fp32" => Ok(WireCodec::Fp32),
            "fp16" => Ok(WireCodec::Fp16),
            "int8" => Ok(WireCodec::Int8),
            _ => match s.strip_prefix("topk:") {
                Some(r) => {
                    let ratio: f32 = r
                        .parse()
                        .map_err(|_| format!("bad top-k ratio {r:?} (want a number in (0, 1])"))?;
                    if !(ratio > 0.0 && ratio <= 1.0) {
                        return Err(format!("top-k ratio must be in (0, 1], got {ratio}"));
                    }
                    Ok(WireCodec::TopK(ratio))
                }
                None => Err(format!(
                    "unknown wire codec {s:?}; expected fp32|fp16|int8|topk:R"
                )),
            },
        }
    }
}

/// f32 → IEEE binary16 bits, round-to-nearest-even (no half-float crate in
/// the image, so the conversion lives here).
fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / nan (keep nan's payload bit so it stays a nan)
        return sign | 0x7c00 | u16::from(man != 0) << 9;
    }
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow → inf
    }
    if e >= -14 {
        // normal half: drop 13 mantissa bits with round-to-nearest-even
        let mut m = man >> 13;
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7c00;
            }
        }
        return sign | ((he as u16) << 10) | m as u16;
    }
    if e < -25 {
        return sign; // underflow to (signed) zero
    }
    // subnormal half
    let full = man | 0x0080_0000;
    let shift = (-14 - e) as u32 + 13;
    let mut m = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && (m & 1) == 1) {
        m += 1; // may carry into the normal range — the bit layout is contiguous
    }
    sign | m as u16
}

/// IEEE binary16 bits → f32 (exact).
fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal half → normal f32
            let mut e = 1i32;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((e - 15 + 127) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Bytes of ring segment `seg` summed over all `chunks` chunks of a
/// `len`-element f32 vector split across `n` ring members: each chunk of
/// length `L` contributes `part_len(L, n, seg)` elements.
pub fn segment_bytes(len: usize, chunks: usize, n: usize, seg: usize) -> u64 {
    codec_segment_bytes(WireCodec::Fp32, len, chunks, n, seg)
}

/// [`segment_bytes`] under an arbitrary wire codec. Each chunk's piece is
/// one wire message (per-message overhead like the int8 scale applies per
/// chunk piece); zero-length pieces — `len < n` shapes — cost nothing.
pub fn codec_segment_bytes(
    codec: WireCodec,
    len: usize,
    chunks: usize,
    n: usize,
    seg: usize,
) -> u64 {
    let mut bytes = 0u64;
    for c in 0..chunks {
        let chunk_len = part_len(len, chunks, c);
        bytes += codec.wire_bytes(part_len(chunk_len, n, seg));
    }
    bytes
}

/// The segment a member at ring position `pos` sends on reduce-scatter hop
/// `hop` (`0..n-1`).
#[inline]
pub fn reduce_scatter_segment(pos: usize, n: usize, hop: usize) -> usize {
    (pos + n - hop) % n
}

/// The segment a member at ring position `pos` sends on all-gather hop
/// `hop` (`0..n-1`).
#[inline]
pub fn all_gather_segment(pos: usize, n: usize, hop: usize) -> usize {
    (pos + 1 + n - hop) % n
}

/// Total bytes the member at ring position `pos` transmits over one full
/// round (both phases) of the chunked schedule.
pub fn member_round_tx_bytes(len: usize, chunks: usize, n: usize, pos: usize) -> u64 {
    codec_member_round_tx_bytes(WireCodec::Fp32, len, chunks, n, pos)
}

/// [`member_round_tx_bytes`] under an arbitrary wire codec.
pub fn codec_member_round_tx_bytes(
    codec: WireCodec,
    len: usize,
    chunks: usize,
    n: usize,
    pos: usize,
) -> u64 {
    if n < 2 {
        return 0;
    }
    let mut tx = 0u64;
    for hop in 0..n - 1 {
        tx += codec_segment_bytes(codec, len, chunks, n, reduce_scatter_segment(pos, n, hop));
        tx += codec_segment_bytes(codec, len, chunks, n, all_gather_segment(pos, n, hop));
    }
    tx
}

/// Measured per-member traffic of one ring round — what each NIC would
/// transmit, computed from the exact schedule rather than the closed form.
#[derive(Debug, Clone)]
pub struct RingTraffic {
    /// tx bytes per ring position, one entry per member
    pub per_member_tx: Vec<u64>,
}

impl RingTraffic {
    /// Walk the schedule for a `len`-element vector in `chunks` chunks over
    /// `n` members and collect every member's per-round tx bytes.
    pub fn measure(len: usize, chunks: usize, n: usize) -> Self {
        Self::measure_codec(WireCodec::Fp32, len, chunks, n)
    }

    /// [`RingTraffic::measure`] under an arbitrary wire codec — the sim
    /// prices compressed rings from exactly this.
    pub fn measure_codec(codec: WireCodec, len: usize, chunks: usize, n: usize) -> Self {
        let chunks = chunks.max(1);
        let per_member_tx = (0..n)
            .map(|pos| codec_member_round_tx_bytes(codec, len, chunks, n, pos))
            .collect();
        Self { per_member_tx }
    }

    /// The slowest member's bytes — what gates the round's wall time on a
    /// full-duplex fabric where every member drives its own hops.
    pub fn max_member_bytes(&self) -> u64 {
        self.per_member_tx.iter().copied().max().unwrap_or(0)
    }

    /// Aggregate bytes over all members and both phases.
    pub fn total_bytes(&self) -> u64 {
        self.per_member_tx.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn part_len_and_offset_tile_exactly() {
        for &(len, parts) in &[(10usize, 3usize), (7, 7), (5, 8), (1_037, 8), (0, 4)] {
            let mut off = 0;
            for i in 0..parts {
                assert_eq!(part_offset(len, parts, i), off, "len={len} parts={parts} i={i}");
                off += part_len(len, parts, i);
            }
            assert_eq!(off, len);
        }
    }

    #[test]
    fn aggregate_ring_traffic_is_exact() {
        // summed over members, every hop moves the whole vector once per
        // phase: total == 2·(n-1)·len·4 regardless of chunking
        for &(len, chunks, n) in &[(101usize, 1usize, 3usize), (1_037, 8, 4), (997, 64, 5)] {
            let t = RingTraffic::measure(len, chunks, n);
            assert_eq!(t.total_bytes(), 2 * (n as u64 - 1) * len as u64 * 4);
            assert_eq!(t.per_member_tx.len(), n);
        }
    }

    #[test]
    fn per_member_traffic_stays_within_chunk_rounding_of_closed_form() {
        for &(len, chunks, n) in &[(1_000_000usize, 8usize, 20usize), (997, 64, 5)] {
            let closed = 2 * (len as u64 * 4) * (n as u64 - 1) / n as u64;
            let t = RingTraffic::measure(len, chunks, n);
            // one element per chunk per hop of slack, both phases
            let slack = 4 * 2 * (n as u64 - 1) * chunks as u64;
            for (pos, &tx) in t.per_member_tx.iter().enumerate() {
                assert!(
                    tx.abs_diff(closed) <= slack,
                    "pos {pos}: measured {tx} vs closed form {closed} (slack {slack})"
                );
            }
        }
    }

    #[test]
    fn divisible_case_matches_closed_form_exactly() {
        // n | len and chunks | len: no rounding anywhere
        let t = RingTraffic::measure(100, 1, 4);
        assert_eq!(t.max_member_bytes(), 600); // 2 * 400 * 3/4
        for &tx in &t.per_member_tx {
            assert_eq!(tx, 600);
        }
    }

    #[test]
    fn singleton_ring_moves_nothing() {
        let t = RingTraffic::measure(1_000, 8, 1);
        assert_eq!(t.total_bytes(), 0);
        assert_eq!(t.max_member_bytes(), 0);
    }

    // ---- degenerate shapes (satellite bugfix) ----------------------------

    #[test]
    fn measure_tiles_exactly_when_len_shorter_than_ring() {
        // 3 elements across 8 members: five of the eight segments are empty.
        // The tiling invariant must still hold and empty segments must cost
        // exactly zero under every codec.
        for codec in [WireCodec::Fp32, WireCodec::Fp16, WireCodec::Int8, WireCodec::TopK(0.25)] {
            let (len, chunks, n) = (3usize, 1usize, 8usize);
            let mut elems = 0usize;
            for seg in 0..n {
                let piece = part_len(len, n, seg);
                elems += piece;
                let priced = codec_segment_bytes(codec, len, chunks, n, seg);
                if piece == 0 {
                    assert_eq!(priced, 0, "{codec}: empty segment {seg} priced as a transfer");
                } else {
                    assert!(priced > 0, "{codec}: non-empty segment {seg} priced zero");
                }
            }
            assert_eq!(elems, len);
            let t = RingTraffic::measure_codec(codec, len, chunks, n);
            let per_elem_total: u64 = (0..n)
                .map(|seg| codec_segment_bytes(codec, len, chunks, n, seg))
                .sum();
            assert_eq!(t.total_bytes(), 2 * (n as u64 - 1) * per_elem_total);
        }
        // fp32 keeps the closed-form aggregate even in the degenerate shape
        let t = RingTraffic::measure(3, 1, 8);
        assert_eq!(t.total_bytes(), 2 * 7 * 3 * 4);
    }

    #[test]
    fn measure_tiles_exactly_when_chunks_exceed_len() {
        // 5 elements in 8 chunks over 4 members: three chunks are empty and
        // every non-empty chunk is shorter than the ring.
        let (len, chunks, n) = (5usize, 8usize, 4usize);
        let t = RingTraffic::measure(len, chunks, n);
        assert_eq!(t.total_bytes(), 2 * (n as u64 - 1) * len as u64 * 4);
        // codec path: int8 charges its 4-byte scale only for non-empty
        // chunk pieces, so the total stays below the fp32 total here
        let t8 = RingTraffic::measure_codec(WireCodec::Int8, len, chunks, n);
        assert!(t8.total_bytes() > 0);
        for seg in 0..n {
            let mut expect = 0u64;
            for c in 0..chunks {
                let piece = part_len(part_len(len, chunks, c), n, seg);
                expect += if piece == 0 { 0 } else { piece as u64 + 4 };
            }
            assert_eq!(codec_segment_bytes(WireCodec::Int8, len, chunks, n, seg), expect);
        }
    }

    #[test]
    fn zero_length_vector_moves_nothing_under_every_codec() {
        for codec in [WireCodec::Fp32, WireCodec::Fp16, WireCodec::Int8, WireCodec::TopK(0.5)] {
            assert_eq!(codec.wire_bytes(0), 0, "{codec}");
            let t = RingTraffic::measure_codec(codec, 0, 8, 4);
            assert_eq!(t.total_bytes(), 0, "{codec}");
        }
    }

    // ---- codec wire sizes ------------------------------------------------

    #[test]
    fn codec_wire_sizes_match_their_formats() {
        assert_eq!(WireCodec::Fp32.wire_bytes(100), 400);
        assert_eq!(WireCodec::Fp16.wire_bytes(100), 200);
        assert_eq!(WireCodec::Int8.wire_bytes(100), 104); // payload + scale
        assert_eq!(WireCodec::TopK(0.1).wire_bytes(100), 80); // 10 coords × 8 B
        assert_eq!(WireCodec::TopK(0.001).wire_bytes(100), 8); // k floors at 1
        assert_eq!(WireCodec::TopK(1.0).wire_bytes(100), 800); // dense top-k
    }

    #[test]
    fn fp32_codec_paths_are_bit_identical_to_legacy() {
        for &(len, chunks, n) in &[(101usize, 1usize, 3usize), (1_037, 8, 4), (3, 1, 8)] {
            for seg in 0..n {
                assert_eq!(
                    codec_segment_bytes(WireCodec::Fp32, len, chunks, n, seg),
                    segment_bytes(len, chunks, n, seg)
                );
            }
            let a = RingTraffic::measure(len, chunks, n);
            let b = RingTraffic::measure_codec(WireCodec::Fp32, len, chunks, n);
            assert_eq!(a.per_member_tx, b.per_member_tx);
        }
    }

    #[test]
    fn codec_parse_and_display_round_trip() {
        for s in ["fp32", "fp16", "int8", "topk:0.25"] {
            let c: WireCodec = s.parse().unwrap();
            assert_eq!(c.to_string(), s);
        }
        assert!("fp8".parse::<WireCodec>().is_err());
        assert!("topk:0".parse::<WireCodec>().is_err());
        assert!("topk:1.5".parse::<WireCodec>().is_err());
        assert!("topk:x".parse::<WireCodec>().is_err());
    }

    // ---- transcode fidelity ----------------------------------------------

    #[test]
    fn fp16_transcode_is_exact_on_representable_values_and_bounded_elsewhere() {
        let mut exact = vec![0.0f32, 1.0, -1.0, 0.5, -2.0, 1024.0, 0.25, -0.125];
        let orig = exact.clone();
        WireCodec::Fp16.transcode(&mut exact);
        assert_eq!(exact, orig);

        let mut vals: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let orig = vals.clone();
        WireCodec::Fp16.transcode(&mut vals);
        for (a, b) in vals.iter().zip(orig.iter()) {
            // half has 11 significand bits: relative error ≤ 2^-11
            assert!((a - b).abs() <= b.abs() * (1.0 / 2048.0) + 1e-7, "{b} -> {a}");
        }
    }

    #[test]
    fn fp16_handles_extremes() {
        let mut v = vec![1e9f32, -1e9, 1e-9, f32::NAN];
        WireCodec::Fp16.transcode(&mut v);
        assert_eq!(v[0], f32::INFINITY); // overflow saturates to inf
        assert_eq!(v[1], f32::NEG_INFINITY);
        assert_eq!(v[2], 0.0); // underflows half's subnormal range
        assert!(v[3].is_nan());
    }

    #[test]
    fn int8_transcode_error_is_within_half_a_quantum() {
        let mut vals: Vec<f32> = (0..257).map(|i| (i as f32 * 0.11).cos() * 5.0).collect();
        let orig = vals.clone();
        let max_abs = orig.iter().fold(0f32, |m, &x| m.max(x.abs()));
        WireCodec::Int8.transcode(&mut vals);
        let quantum = max_abs / 127.0;
        for (a, b) in vals.iter().zip(orig.iter()) {
            assert!((a - b).abs() <= quantum / 2.0 + 1e-6, "{b} -> {a}");
        }
        // all-zero payload stays all-zero (no divide-by-zero scale)
        let mut zeros = vec![0.0f32; 16];
        WireCodec::Int8.transcode(&mut zeros);
        assert!(zeros.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn topk_keeps_exactly_the_largest_coordinates() {
        let mut v = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 4.0, 0.0, -2.5];
        WireCodec::TopK(0.5).transcode(&mut v); // k = 4
        assert_eq!(v, vec![0.0, -5.0, 0.0, 3.0, 0.0, 4.0, 0.0, -2.5]);
        // ratio 1.0 is the identity
        let mut w = vec![1.0f32, -2.0, 3.0];
        WireCodec::TopK(1.0).transcode(&mut w);
        assert_eq!(w, vec![1.0, -2.0, 3.0]);
    }

    // ---- error feedback --------------------------------------------------

    #[test]
    fn error_feedback_conserves_mass_per_round() {
        // decoded + residual_out == payload_in + residual_in, elementwise
        for codec in [WireCodec::Fp16, WireCodec::Int8, WireCodec::TopK(0.25)] {
            let payload: Vec<f32> = (0..64).map(|i| (i as f32 * 0.71).sin() * 2.0).collect();
            let res_in: Vec<f32> = (0..64).map(|i| (i as f32 * 0.31).cos() * 0.01).collect();
            let mut buf = payload.clone();
            let mut residual = res_in.clone();
            codec.encode_with_feedback(&mut buf, &mut residual);
            for i in 0..64 {
                let intended = payload[i] + res_in[i];
                assert!(
                    (buf[i] + residual[i] - intended).abs() <= 1e-5,
                    "{codec}: {} + {} != {}",
                    buf[i],
                    residual[i],
                    intended
                );
            }
        }
    }

    #[test]
    fn error_feedback_drains_residual_under_fp32() {
        let mut buf = vec![1.0f32, -2.0, 3.0];
        let mut residual = vec![0.5f32, 0.5, -0.5];
        WireCodec::Fp32.encode_with_feedback(&mut buf, &mut residual);
        assert_eq!(buf, vec![1.5, -1.5, 2.5]);
        assert!(residual.iter().all(|&r| r == 0.0));
    }

    #[test]
    fn error_feedback_decayed_drift_over_repeated_rounds() {
        // Feeding the same vector through a lossy codec with feedback, the
        // cumulative decoded sum tracks the cumulative intended sum: the
        // per-round drift does not accumulate.
        for codec in [WireCodec::Fp16, WireCodec::Int8, WireCodec::TopK(0.25)] {
            let payload: Vec<f32> = (0..128).map(|i| (i as f32 * 0.53).sin()).collect();
            let mut residual = vec![0.0f32; 128];
            let mut cum_decoded = vec![0.0f64; 128];
            let rounds = 50usize;
            for _ in 0..rounds {
                let mut buf = payload.clone();
                codec.encode_with_feedback(&mut buf, &mut residual);
                for (c, &b) in cum_decoded.iter_mut().zip(buf.iter()) {
                    *c += b as f64;
                }
            }
            // total decoded == total intended − final residual, so the mean
            // drift is bounded by max|residual| / rounds → decays with rounds
            let max_res = residual.iter().fold(0f32, |m, &r| m.max(r.abs()));
            for (i, &c) in cum_decoded.iter().enumerate() {
                let intended = payload[i] as f64 * rounds as f64;
                let drift = (c - intended).abs() / rounds as f64;
                assert!(
                    drift <= (max_res as f64 + 1e-3) / rounds as f64 + 1e-6,
                    "{codec}: coord {i} drift {drift}"
                );
            }
        }
    }
}
