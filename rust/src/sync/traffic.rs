//! Measured sync-traffic export: the wire bytes the fabric *actually* moves.
//!
//! The chunked ring schedule in [`crate::sync::allreduce`] cuts the
//! parameter vector into `C` chunks and every chunk into `n` near-equal
//! segments; a member at ring position `p` sends one segment of every chunk
//! per hop, for `n-1` reduce-scatter hops followed by `n-1` all-gather hops.
//! This module holds the *single source of truth* for that schedule's
//! per-hop byte math: the live collective drives each hop through
//! [`crate::net::Network::transfer`] using [`segment_bytes`], and the
//! paper-scale throughput model in [`crate::sim`] prices collectives from
//! [`RingTraffic::measure`] — the same numbers, chunk rounding included —
//! instead of the closed-form `2·(n-1)/n · bytes` textbook estimate (which
//! survives only as a cross-check reference,
//! `AllReduceGroup::ring_bytes_per_member`).

/// `len / parts` with the remainder spread over the leading parts — the
/// same split rule as `placement::equal_ranges`.
#[inline]
pub fn part_len(len: usize, parts: usize, idx: usize) -> usize {
    len / parts + usize::from(idx < len % parts)
}

/// Offset of part `idx` under the [`part_len`] split rule.
#[inline]
pub fn part_offset(len: usize, parts: usize, idx: usize) -> usize {
    idx * (len / parts) + idx.min(len % parts)
}

/// Bytes of ring segment `seg` summed over all `chunks` chunks of a
/// `len`-element f32 vector split across `n` ring members: each chunk of
/// length `L` contributes `part_len(L, n, seg)` elements.
pub fn segment_bytes(len: usize, chunks: usize, n: usize, seg: usize) -> u64 {
    let mut elems = 0u64;
    for c in 0..chunks {
        let chunk_len = part_len(len, chunks, c);
        elems += part_len(chunk_len, n, seg) as u64;
    }
    4 * elems
}

/// The segment a member at ring position `pos` sends on reduce-scatter hop
/// `hop` (`0..n-1`).
#[inline]
pub fn reduce_scatter_segment(pos: usize, n: usize, hop: usize) -> usize {
    (pos + n - hop) % n
}

/// The segment a member at ring position `pos` sends on all-gather hop
/// `hop` (`0..n-1`).
#[inline]
pub fn all_gather_segment(pos: usize, n: usize, hop: usize) -> usize {
    (pos + 1 + n - hop) % n
}

/// Total bytes the member at ring position `pos` transmits over one full
/// round (both phases) of the chunked schedule.
pub fn member_round_tx_bytes(len: usize, chunks: usize, n: usize, pos: usize) -> u64 {
    if n < 2 {
        return 0;
    }
    let mut tx = 0u64;
    for hop in 0..n - 1 {
        tx += segment_bytes(len, chunks, n, reduce_scatter_segment(pos, n, hop));
        tx += segment_bytes(len, chunks, n, all_gather_segment(pos, n, hop));
    }
    tx
}

/// Measured per-member traffic of one ring round — what each NIC would
/// transmit, computed from the exact schedule rather than the closed form.
#[derive(Debug, Clone)]
pub struct RingTraffic {
    /// tx bytes per ring position, one entry per member
    pub per_member_tx: Vec<u64>,
}

impl RingTraffic {
    /// Walk the schedule for a `len`-element vector in `chunks` chunks over
    /// `n` members and collect every member's per-round tx bytes.
    pub fn measure(len: usize, chunks: usize, n: usize) -> Self {
        let chunks = chunks.max(1);
        let per_member_tx = (0..n)
            .map(|pos| member_round_tx_bytes(len, chunks, n, pos))
            .collect();
        Self { per_member_tx }
    }

    /// The slowest member's bytes — what gates the round's wall time on a
    /// full-duplex fabric where every member drives its own hops.
    pub fn max_member_bytes(&self) -> u64 {
        self.per_member_tx.iter().copied().max().unwrap_or(0)
    }

    /// Aggregate bytes over all members and both phases.
    pub fn total_bytes(&self) -> u64 {
        self.per_member_tx.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn part_len_and_offset_tile_exactly() {
        for &(len, parts) in &[(10usize, 3usize), (7, 7), (5, 8), (1_037, 8), (0, 4)] {
            let mut off = 0;
            for i in 0..parts {
                assert_eq!(part_offset(len, parts, i), off, "len={len} parts={parts} i={i}");
                off += part_len(len, parts, i);
            }
            assert_eq!(off, len);
        }
    }

    #[test]
    fn aggregate_ring_traffic_is_exact() {
        // summed over members, every hop moves the whole vector once per
        // phase: total == 2·(n-1)·len·4 regardless of chunking
        for &(len, chunks, n) in &[(101usize, 1usize, 3usize), (1_037, 8, 4), (997, 64, 5)] {
            let t = RingTraffic::measure(len, chunks, n);
            assert_eq!(t.total_bytes(), 2 * (n as u64 - 1) * len as u64 * 4);
            assert_eq!(t.per_member_tx.len(), n);
        }
    }

    #[test]
    fn per_member_traffic_stays_within_chunk_rounding_of_closed_form() {
        for &(len, chunks, n) in &[(1_000_000usize, 8usize, 20usize), (997, 64, 5)] {
            let closed = 2 * (len as u64 * 4) * (n as u64 - 1) / n as u64;
            let t = RingTraffic::measure(len, chunks, n);
            // one element per chunk per hop of slack, both phases
            let slack = 4 * 2 * (n as u64 - 1) * chunks as u64;
            for (pos, &tx) in t.per_member_tx.iter().enumerate() {
                assert!(
                    tx.abs_diff(closed) <= slack,
                    "pos {pos}: measured {tx} vs closed form {closed} (slack {slack})"
                );
            }
        }
    }

    #[test]
    fn divisible_case_matches_closed_form_exactly() {
        // n | len and chunks | len: no rounding anywhere
        let t = RingTraffic::measure(100, 1, 4);
        assert_eq!(t.max_member_bytes(), 600); // 2 * 400 * 3/4
        for &tx in &t.per_member_tx {
            assert_eq!(tx, 600);
        }
    }

    #[test]
    fn singleton_ring_moves_nothing() {
        let t = RingTraffic::measure(1_000, 8, 1);
        assert_eq!(t.total_bytes(), 0);
        assert_eq!(t.max_member_bytes(), 0);
    }
}
