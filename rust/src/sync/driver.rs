//! Sync drivers: the per-trainer shadow pool (background) and the
//! foreground fixed-rate hooks.
//!
//! **Shadow pool** (the paper's framework, Algorithm 1 lines 10–12 +
//! §3.2's partitioned threads): `S` background threads per trainer loop
//! partition sync rounds while worker threads train — the synchronization
//! is "neither part of the backward pass nor happens every k iterations".
//! [`spawn_shadow_pool`] services a [`ShadowTask`] per partition:
//!
//! - **Rendezvous strategies** (MA/BMUF — a round blocks until every
//!   active trainer contributes to the partition's collective) are pinned
//!   to pool threads statically, in plan order, identically on every
//!   trainer. Each chain is then an independent cross-trainer sequence
//!   with a total order, exactly like a single pre-partitioning shadow
//!   thread: the minimal blocked round of a chain always has every peer
//!   either deposited or departed-and-left, so rounds keep closing — and
//!   a chain thread `leave()`s *its* partitions the moment it exits,
//!   unblocking peers mid-round at shutdown. Work-stealing rendezvous
//!   rounds across threads would break that total order and can deadlock
//!   (thread A blocked on partition 0 waiting for B, B blocked on
//!   partition 1 waiting for A), which is why stealing is reserved for:
//! - **Centralized strategies** (EASGD/none — rounds never block on other
//!   trainers): one shared pool serviced by every thread via a
//!   work-stealing round-robin (a shared ticket cursor; a thread finding
//!   its ticketed partition busy walks forward to the next free one), so
//!   sync frequency per partition scales with `S`.
//!
//! Every completed round is recorded per partition
//! ([`crate::metrics::Metrics::record_partition_sync`]), making the
//! avg-sync-gap metric (paper Eq. 2) per-partition. An optional interval
//! throttles each pool thread (the `ablate-shadow-rate` experiment sweeps
//! it; 0 = free-running as in the paper).
//!
//! **Foreground fixed-rate**: the baselines, whole-vector only. For EASGD
//! every worker thread syncs inline every `gap` of its own iterations
//! (this is what makes FR-EASGD's sync-PS traffic `m×` larger). For
//! AllReduce algorithms the trainer's designated syncer (worker 0) runs
//! the collective every `gap` trainer-level iterations while a write-lock
//! [`Gate`] stops that trainer's other workers — synchronization literally
//! interrupts training.
//!
//! **Repartition cutover** ([`spawn_shadow_pool_adaptive`]): when a
//! [`RepartitionController`] publishes a new generation, each trainer's
//! pool cuts over *independently*, at its own sweep boundary — no global
//! barrier. Safety rests on two facts. First, a pool thread that exits
//! always `leave()`s its rendezvous strategies, so a peer still blocked in
//! an old-generation round sees the membership shrink and its round
//! closes: a trainer on the old plan can always finish its sweep, which is
//! why the mixed state (some trainers cut, some not) cannot deadlock —
//! the acyclic-round-order argument for chains extends across the cutover
//! because departure, not arrival, is what closes rounds. Second, the
//! controller publishes at most one pending generation (a rebuild waits
//! until every active trainer adopted the current one), so adoption never
//! skips an epoch and a trainer that stops early can vacate exactly the
//! one pending epoch it never joined ([`RepartitionController::depart`]).
//!
//! # Examples
//!
//! ```
//! use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
//! use std::sync::Arc;
//! use std::time::Duration;
//! use shadowsync::metrics::Metrics;
//! use shadowsync::net::{Network, Role};
//! use shadowsync::sync::driver::spawn_shadow;
//! use shadowsync::sync::NoSync;
//! use shadowsync::tensor::HogwildBuffer;
//!
//! let mut net = Network::new(None);
//! let node = net.add_node(Role::Trainer);
//! let stop = Arc::new(AtomicBool::new(false));
//! let shadow = spawn_shadow(
//!     Box::new(NoSync),
//!     Arc::new(HogwildBuffer::zeros(8)),
//!     node,
//!     Arc::new(net),
//!     Arc::new(Metrics::new()),
//!     stop.clone(),
//!     Duration::ZERO,
//!     0,
//! );
//! stop.store(true, Relaxed);
//! shadow.join().unwrap().unwrap();
//! ```

use std::time::Duration;

use anyhow::Result;

use crate::metrics::Metrics;
use crate::net::{Network, NodeId};
use crate::tensor::HogwildBuffer;

use super::prim::thread::{self, JoinHandle};
use super::prim::{
    Arc, AtomicBool, AtomicU64, AtomicUsize, Mutex, Ordering::Relaxed, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};
use super::repartition::RepartitionController;
use super::{ParamRange, RepartitionCarry, SyncStrategy};

/// Shared flag a trainer raises when its shard is exhausted.
pub type StopFlag = Arc<AtomicBool>;

/// One partition's sync work inside a trainer's shadow pool: the strategy
/// instance plus the replica range it owns.
pub struct ShadowTask {
    /// partition index in the trainer's plan (the per-partition metrics key)
    pub partition: usize,
    pub range: ParamRange,
    pub strategy: Box<dyn SyncStrategy>,
}

/// The work-stealing pool of non-rendezvous tasks shared by a trainer's
/// shadow threads. Each slot's mutex is held only for the duration of one
/// sync round; `try_lock` failures mean "someone is already syncing this
/// partition — steal the next one".
struct StealPool {
    tasks: Vec<Mutex<ShadowTask>>,
    ticket: AtomicUsize,
}

/// Spawn a single whole-replica shadow thread for one trainer — the
/// monolithic special case of [`spawn_shadow_pool`] (one task spanning the
/// full vector, one thread). Kept as the simple entry point for tests,
/// examples, and custom strategies.
#[allow(clippy::too_many_arguments)]
pub fn spawn_shadow(
    strategy: Box<dyn SyncStrategy>,
    local: Arc<HogwildBuffer>,
    trainer_node: NodeId,
    net: Arc<Network>,
    metrics: Arc<Metrics>,
    stop: StopFlag,
    interval: Duration,
    trainer_id: usize,
) -> JoinHandle<Result<u64>> {
    let range = ParamRange::full(local.len());
    spawn_shadow_pool(
        vec![ShadowTask { partition: 0, range, strategy }],
        local,
        trainer_node,
        net,
        metrics,
        stop,
        interval,
        trainer_id,
        1,
    )
}

/// Spawn one trainer's shadow pool: `threads` background threads (clamped
/// to `[1, tasks.len()]`) servicing one [`ShadowTask`] per partition until
/// `stop` is raised, then every strategy `leave()`s so decentralized
/// groups shrink. Returns a single join handle (the pool controller); its
/// value is the total number of partition rounds the pool ran.
#[allow(clippy::too_many_arguments)]
pub fn spawn_shadow_pool(
    tasks: Vec<ShadowTask>,
    local: Arc<HogwildBuffer>,
    trainer_node: NodeId,
    net: Arc<Network>,
    metrics: Arc<Metrics>,
    stop: StopFlag,
    interval: Duration,
    trainer_id: usize,
    threads: usize,
) -> JoinHandle<Result<u64>> {
    spawn_shadow_pool_adaptive(
        tasks,
        local,
        trainer_node,
        net,
        metrics,
        stop,
        interval,
        trainer_id,
        threads,
        None,
    )
}

/// [`spawn_shadow_pool`] with measured-cost adaptive repartitioning: when
/// `controller` is given, the pool runs *epochs*. Each epoch services the
/// current [`super::repartition::PlanEpoch`]'s tasks exactly like the
/// static pool; once the controller publishes a new generation, every pool
/// thread exits at its next sweep boundary (a blocked rendezvous round is
/// unblocked by faster peers leaving, the same mechanism as shutdown), the
/// retiring strategies `leave()` their old groups, EASGD gate state is
/// carried across by partition index (cache ordinals are global, so
/// entries stay valid wherever their chunks now live), and the pool
/// re-spawns over the new ranges. With `controller = None` this is exactly
/// the static pool.
#[allow(clippy::too_many_arguments)]
pub fn spawn_shadow_pool_adaptive(
    tasks: Vec<ShadowTask>,
    local: Arc<HogwildBuffer>,
    trainer_node: NodeId,
    net: Arc<Network>,
    metrics: Arc<Metrics>,
    stop: StopFlag,
    interval: Duration,
    trainer_id: usize,
    threads: usize,
    controller: Option<Arc<RepartitionController>>,
) -> JoinHandle<Result<u64>> {
    thread::Builder::new()
        .name(format!("shadow-{trainer_id}"))
        .spawn(move || {
            let mut tasks = tasks;
            let mut my_gen = controller.as_ref().map_or(0, |c| c.generation());
            let mut total_rounds = 0u64;
            loop {
                let threads_now = threads.clamp(1, tasks.len().max(1));
                // rendezvous strategies are pinned to chains in plan order
                // — every trainer builds the exact same chains, which is
                // what keeps the cross-trainer round order acyclic (see the
                // module doc); everything else goes into the shared
                // work-stealing pool
                let mut chains: Vec<Vec<ShadowTask>> =
                    (0..threads_now).map(|_| Vec::new()).collect();
                let mut steal_tasks = Vec::new();
                let mut next_chain = 0usize;
                for t in tasks {
                    if t.strategy.rendezvous() {
                        chains[next_chain % threads_now].push(t);
                        next_chain += 1;
                    } else {
                        steal_tasks.push(Mutex::new(t));
                    }
                }
                let pool =
                    Arc::new(StealPool { tasks: steal_tasks, ticket: AtomicUsize::new(0) });
                let mut workers = Vec::new();
                for (k, chain) in chains.into_iter().enumerate() {
                    let local = local.clone();
                    let net = net.clone();
                    let metrics = metrics.clone();
                    let stop = stop.clone();
                    let pool = pool.clone();
                    let repart = controller.as_ref().map(|c| (c.clone(), my_gen));
                    workers.push(
                        thread::Builder::new()
                            .name(format!("shadow-{trainer_id}.{k}"))
                            .spawn(move || {
                                pool_thread(
                                    chain,
                                    &pool,
                                    &local,
                                    trainer_node,
                                    &net,
                                    &metrics,
                                    &stop,
                                    interval,
                                    repart,
                                    k == 0,
                                )
                            })
                            .expect("spawn shadow pool thread"),
                    );
                }
                let mut first_err = None;
                let mut recovered: Vec<ShadowTask> = Vec::new();
                for w in workers {
                    let exit = w.join().expect("shadow pool thread panicked");
                    total_rounds += exit.rounds;
                    recovered.extend(exit.chain);
                    first_err = first_err.or(exit.err);
                }
                // all pool threads are gone: recover (and retire) the
                // stolen strategies too
                let pool =
                    Arc::try_unwrap(pool).ok().expect("pool threads still hold the steal pool");
                for slot in pool.tasks {
                    let mut t = slot.into_inner().unwrap();
                    t.strategy.leave();
                    recovered.push(t);
                }
                let recut = first_err.is_none()
                    && !stop.load(Relaxed)
                    && controller.as_ref().is_some_and(|c| c.generation() != my_gen);
                if !recut {
                    if let Some(c) = &controller {
                        // vacate any pending epoch this trainer never
                        // adopted, so adopters don't wait on a ghost
                        c.depart(my_gen);
                    }
                    return match first_err {
                        Some(e) => Err(e),
                        None => Ok(total_rounds),
                    };
                }
                // cutover: the pool is quiesced between rounds and the old
                // strategies have left their groups — adopt the new epoch
                // and rebuild the tasks over its ranges
                let c = controller.as_ref().unwrap();
                let epoch = c.adopt(my_gen);
                my_gen = epoch.gen;
                let mut carry: Vec<Option<RepartitionCarry>> =
                    (0..epoch.plan.len()).map(|_| None).collect();
                for t in &mut recovered {
                    if t.partition < carry.len() {
                        carry[t.partition] = t.strategy.take_repartition_carry();
                    }
                }
                let seed = local.to_vec();
                tasks = match c.build_tasks(trainer_id, &epoch, &seed, carry) {
                    Ok(t) => t,
                    Err(e) => {
                        c.depart(my_gen);
                        return Err(e);
                    }
                };
            }
        })
        .expect("spawn shadow thread")
}

/// What one pool thread hands back when it exits: the partition rounds it
/// ran, its rendezvous chain (strategies already `leave()`d, carry state
/// intact), and the first strategy error it hit, if any.
struct PoolThreadExit {
    rounds: u64,
    chain: Vec<ShadowTask>,
    err: Option<anyhow::Error>,
}

/// One pool thread: per lap, run the next round of the owned rendezvous
/// chain (cyclic order) and steal one non-rendezvous round. Thread 0 of an
/// adaptive pool additionally records one *sweep* per lap with the
/// replica's dirty-epoch write delta; every thread checks the controller's
/// generation once per lap and exits at the sweep boundary when a new plan
/// is pending (the cutover's quiesce point).
#[allow(clippy::too_many_arguments)]
fn pool_thread(
    mut chain: Vec<ShadowTask>,
    pool: &StealPool,
    local: &HogwildBuffer,
    trainer_node: NodeId,
    net: &Network,
    metrics: &Metrics,
    stop: &AtomicBool,
    interval: Duration,
    repart: Option<(Arc<RepartitionController>, u64)>,
    record_sweeps: bool,
) -> PoolThreadExit {
    let mut rounds = 0u64;
    let mut chain_idx = 0usize;
    let mut err = None;
    let mut last_epochs: Vec<u64> = Vec::new();
    'run: while !stop.load(Relaxed) {
        let mut worked = false;
        if !chain.is_empty() {
            let t = &mut chain[chain_idx % chain.len()];
            chain_idx += 1;
            let ctx = super::SyncCtx {
                local,
                range: t.range,
                partition: t.partition,
                trainer_node,
                net,
                metrics,
            };
            match t.strategy.sync_round(&ctx) {
                Ok(_) => {
                    metrics.record_partition_sync(t.partition);
                    rounds += 1;
                    worked = true;
                }
                Err(e) => {
                    err = Some(e);
                    break 'run;
                }
            }
        }
        if !pool.tasks.is_empty() {
            // work-stealing round-robin: start at the shared ticket and
            // walk forward past partitions another thread is busy syncing
            let start = pool.ticket.fetch_add(1, Relaxed);
            for off in 0..pool.tasks.len() {
                let slot = &pool.tasks[(start.wrapping_add(off)) % pool.tasks.len()];
                let Ok(mut t) = slot.try_lock() else { continue };
                let ctx = super::SyncCtx {
                    local,
                    range: t.range,
                    partition: t.partition,
                    trainer_node,
                    net,
                    metrics,
                };
                match t.strategy.sync_round(&ctx) {
                    Ok(_) => {
                        metrics.record_partition_sync(t.partition);
                        rounds += 1;
                        worked = true;
                    }
                    Err(e) => {
                        err = Some(e);
                    }
                }
                break;
            }
            if err.is_some() {
                break 'run;
            }
        }
        if !worked {
            thread::yield_now();
        }
        if !interval.is_zero() {
            thread::sleep(interval);
        }
        if let Some((c, adopted_gen)) = &repart {
            if record_sweeps {
                // feed the measured write rates: dirty-epoch bumps since
                // this thread's previous sweep (empty when untracked; the
                // first observation only primes the baseline — re-adding
                // cumulative counts after every cutover would multiply the
                // profile by its own history)
                let delta = match local.dirty_chunk_epochs() {
                    Some(now) => {
                        let delta = if last_epochs.len() == now.len() {
                            now.iter()
                                .zip(&last_epochs)
                                .map(|(n, l)| n.wrapping_sub(*l))
                                .collect()
                        } else {
                            Vec::new()
                        };
                        last_epochs = now;
                        delta
                    }
                    None => Vec::new(),
                };
                c.record_sweep(&delta);
            }
            if c.generation() != *adopted_gen {
                break 'run; // quiesce for the cutover
            }
        }
    }
    // leaving the owned chain is what unblocks peer trainers mid-round —
    // at shutdown and at a repartition cutover alike
    for t in &mut chain {
        t.strategy.leave();
    }
    PoolThreadExit { rounds, chain, err }
}

/// Foreground gate: workers hold a read lock while training; a fixed-rate
/// AllReduce syncer takes the write lock, stopping the trainer's world.
#[derive(Default)]
pub struct Gate {
    lock: RwLock<()>,
}

impl Gate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Workers wrap each iteration in this.
    pub fn working(&self) -> RwLockReadGuard<'_, ()> {
        self.lock.read().unwrap()
    }

    /// The foreground syncer wraps the collective in this.
    pub fn stop_the_world(&self) -> RwLockWriteGuard<'_, ()> {
        self.lock.write().unwrap()
    }
}

/// Per-trainer shared iteration counter driving fixed-rate scheduling.
#[derive(Default)]
pub struct IterCounter(AtomicU64);

impl IterCounter {
    pub fn bump(&self) -> u64 {
        self.0.fetch_add(1, Relaxed) + 1
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Role;
    use crate::sync::{NoSync, SyncCtx, SyncStrategy};

    struct CountingSync {
        rounds: Arc<AtomicU64>,
        left: Arc<AtomicBool>,
    }

    impl SyncStrategy for CountingSync {
        fn sync_round(&mut self, _ctx: &SyncCtx<'_>) -> Result<f32> {
            self.rounds.fetch_add(1, Relaxed);
            Ok(0.0)
        }
        fn leave(&mut self) {
            self.left.store(true, Relaxed);
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    #[test]
    fn shadow_thread_runs_until_stopped_then_leaves() {
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow(
            Box::new(CountingSync { rounds: rounds.clone(), left: left.clone() }),
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop.clone(),
            Duration::from_millis(1),
            0,
        );
        while rounds.load(Relaxed) < 5 {
            std::thread::yield_now();
        }
        stop.store(true, Relaxed);
        let n = h.join().unwrap().unwrap();
        assert!(n >= 5);
        assert!(left.load(Relaxed));
    }

    #[test]
    fn shadow_free_runs_without_interval() {
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow(
            Box::new(CountingSync { rounds: rounds.clone(), left }),
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop.clone(),
            Duration::ZERO,
            1,
        );
        std::thread::sleep(Duration::from_millis(20));
        stop.store(true, Relaxed);
        let n = h.join().unwrap().unwrap();
        assert!(n > 100, "free-running shadow only did {n} rounds");
    }

    #[test]
    fn shadow_pool_services_every_partition_and_records_gaps() {
        // 4 partitions, 2 threads: every partition keeps getting rounds,
        // each round lands in its partition's metrics counter, and every
        // strategy leaves at shutdown
        let p = 4usize;
        let counters: Vec<_> = (0..p).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let lefts: Vec<_> = (0..p).map(|_| Arc::new(AtomicBool::new(false))).collect();
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let tasks: Vec<ShadowTask> = (0..p)
            .map(|i| ShadowTask {
                partition: i,
                range: ParamRange { offset: i * 4, len: 4 },
                strategy: Box::new(CountingSync {
                    rounds: counters[i].clone(),
                    left: lefts[i].clone(),
                }),
            })
            .collect();
        let h = spawn_shadow_pool(
            tasks,
            Arc::new(HogwildBuffer::zeros(16)),
            node,
            Arc::new(net),
            metrics.clone(),
            stop.clone(),
            Duration::ZERO,
            0,
            2,
        );
        while counters.iter().any(|c| c.load(Relaxed) < 5) {
            std::thread::yield_now();
        }
        stop.store(true, Relaxed);
        let total = h.join().unwrap().unwrap();
        let per_partition: Vec<u64> = counters.iter().map(|c| c.load(Relaxed)).collect();
        assert!(per_partition.iter().all(|&c| c >= 5), "starved partition: {per_partition:?}");
        assert_eq!(total, per_partition.iter().sum::<u64>());
        // the pool's rounds flow into the per-partition metrics counters
        let snap = metrics.snapshot();
        assert_eq!(snap.partition_syncs.len(), p);
        assert_eq!(snap.partition_syncs, per_partition);
        assert!(lefts.iter().all(|l| l.load(Relaxed)), "a strategy never left");
    }

    #[test]
    fn pool_threads_clamp_to_task_count() {
        // more threads than tasks: the pool clamps instead of spinning
        // idle threads
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow_pool(
            vec![ShadowTask {
                partition: 0,
                range: ParamRange::full(4),
                strategy: Box::new(CountingSync { rounds: rounds.clone(), left: left.clone() }),
            }],
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop.clone(),
            Duration::from_millis(1),
            7,
            8,
        );
        while rounds.load(Relaxed) < 3 {
            std::thread::yield_now();
        }
        stop.store(true, Relaxed);
        assert!(h.join().unwrap().unwrap() >= 3);
        assert!(left.load(Relaxed));
    }

    #[test]
    fn gate_blocks_workers_during_sync() {
        let gate = Arc::new(Gate::new());
        let in_crit = Arc::new(AtomicU64::new(0));
        let g = gate.clone();
        let ic = in_crit.clone();
        let w = gate.stop_the_world();
        let worker = std::thread::spawn(move || {
            let _guard = g.working();
            ic.store(1, Relaxed);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(in_crit.load(Relaxed), 0, "worker entered during stop-the-world");
        drop(w);
        worker.join().unwrap();
        assert_eq!(in_crit.load(Relaxed), 1);
    }

    #[test]
    fn iter_counter() {
        let c = IterCounter::default();
        assert_eq!(c.bump(), 1);
        assert_eq!(c.bump(), 2);
        assert_eq!(c.get(), 2);
    }

    #[test]
    fn nosync_is_noop() {
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let metrics = Metrics::new();
        let local = HogwildBuffer::from_slice(&[1.0]);
        let ctx = SyncCtx::full(&local, node, &net, &metrics);
        assert_eq!(NoSync.sync_round(&ctx).unwrap(), 0.0);
        assert_eq!(metrics.snapshot().syncs, 0);
    }
}
