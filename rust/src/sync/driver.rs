//! Sync drivers: the per-trainer shadow pool (background) and the
//! foreground fixed-rate hooks.
//!
//! **Shadow pool** (the paper's framework, Algorithm 1 lines 10–12 +
//! §3.2's partitioned threads): `S` background threads per trainer loop
//! partition sync rounds while worker threads train — the synchronization
//! is "neither part of the backward pass nor happens every k iterations".
//! [`spawn_shadow_pool`] services a [`ShadowTask`] per partition:
//!
//! - **Rendezvous strategies** (MA/BMUF — a round blocks until every
//!   active trainer contributes to the partition's collective) are pinned
//!   to pool threads statically, in plan order, identically on every
//!   trainer. Each chain is then an independent cross-trainer sequence
//!   with a total order, exactly like a single pre-partitioning shadow
//!   thread: the minimal blocked round of a chain always has every peer
//!   either deposited or departed-and-left, so rounds keep closing — and
//!   a chain thread `leave()`s *its* partitions the moment it exits,
//!   unblocking peers mid-round at shutdown. Work-stealing rendezvous
//!   rounds across threads would break that total order and can deadlock
//!   (thread A blocked on partition 0 waiting for B, B blocked on
//!   partition 1 waiting for A), which is why stealing is reserved for:
//! - **Centralized strategies** (EASGD/none — rounds never block on other
//!   trainers): one shared pool serviced by every thread via a
//!   work-stealing round-robin (a shared ticket cursor; a thread finding
//!   its ticketed partition busy walks forward to the next free one), so
//!   sync frequency per partition scales with `S`.
//!
//! Every completed round is recorded per partition
//! ([`crate::metrics::Metrics::record_partition_sync`]), making the
//! avg-sync-gap metric (paper Eq. 2) per-partition. An optional interval
//! throttles each pool thread (the `ablate-shadow-rate` experiment sweeps
//! it; 0 = free-running as in the paper).
//!
//! **Foreground fixed-rate**: the baselines, whole-vector only. For EASGD
//! every worker thread syncs inline every `gap` of its own iterations
//! (this is what makes FR-EASGD's sync-PS traffic `m×` larger). For
//! AllReduce algorithms the trainer's designated syncer (worker 0) runs
//! the collective every `gap` trainer-level iterations while a write-lock
//! [`Gate`] stops that trainer's other workers — synchronization literally
//! interrupts training.
//!
//! **Persistent workers and epochs** ([`spawn_shadow_pool_adaptive`]): a
//! pool's OS threads are spawned **once** and live for the whole run.
//! Layout changes — adaptive repartitions, health demotions/promotions,
//! crash rejoins — are *installs*: the pool controller publishes a new
//! task set into the shared [`PoolCore`] and the workers pick it up off a
//! condvar, so a cutover swaps task vectors in place instead of tearing
//! down and respawning `S` threads per epoch (the respawn cost used to be
//! the main cutover overhead at high `--shadow-threads`).
//!
//! **Repartition cutover**: when a [`RepartitionController`] publishes a
//! new generation, each trainer's pool cuts over *independently*, at its
//! own sweep boundary — no global barrier. Safety rests on two facts.
//! First, a pool worker that quiesces cleanly always `leave()`s its
//! rendezvous strategies, so a peer still blocked in an old-generation
//! round sees the membership shrink and its round closes: a trainer on
//! the old plan can always finish its sweep, which is why the mixed state
//! (some trainers cut, some not) cannot deadlock — the acyclic-round-order
//! argument for chains extends across the cutover because departure, not
//! arrival, is what closes rounds. Second, the controller publishes at
//! most one pending generation (a rebuild waits until every active
//! trainer adopted the current one), so adoption never skips an epoch and
//! a trainer that stops early can vacate exactly the one pending epoch it
//! never joined ([`RepartitionController::depart`]).
//!
//! **Fault semantics**: when the run's [`Network`] carries a
//! [`FaultPlan`], the pool's lead worker (thread 0) advances the
//! trainer's sweep clock once per lap and every worker checks the crash
//! window at its lap boundary. A crash is a *dirty* quiesce: strategies
//! do **not** leave their groups (a dead process doesn't say goodbye) —
//! peers recover via the allreduce round timeout's eviction or the
//! health watchdog's proxy-depart. The pool controller keeps the sweep
//! clock ticking while the pool is dark so the window can expire, then
//! either resumes in place (nobody departed us; adopt first if the plan
//! moved while we were dark), or — if the watchdog departed the trainer —
//! re-enters through [`RepartitionController::rejoin`], warm-starting the
//! replica from the sync-PS central model. Stall windows stretch every
//! lap by the plan's delay, shadow laps and training iterations alike.
//!
//! The pool never *checks* the departed flag and then acts on the answer —
//! that would race the watchdog. It **claims**: terminal paths go through
//! [`HealthController::claim_exit`] (flag flipped under the watchdog's
//! lock, so the goodbye runs exactly once, here or by proxy, never both)
//! and the crash-resume path goes through [`HealthController::try_resume`]
//! (fresh heartbeat stamped under the same lock, so a tick that measured
//! the dark window's silence can no longer depart a trainer that already
//! resumed). `tests/loom_models.rs` model-checks this handshake.
//!
//! # Examples
//!
//! ```
//! use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
//! use std::sync::Arc;
//! use std::time::Duration;
//! use shadowsync::metrics::Metrics;
//! use shadowsync::net::{Network, Role};
//! use shadowsync::sync::driver::spawn_shadow;
//! use shadowsync::sync::NoSync;
//! use shadowsync::tensor::HogwildBuffer;
//!
//! let mut net = Network::new(None);
//! let node = net.add_node(Role::Trainer);
//! let stop = Arc::new(AtomicBool::new(false));
//! let shadow = spawn_shadow(
//!     Box::new(NoSync),
//!     Arc::new(HogwildBuffer::zeros(8)),
//!     node,
//!     Arc::new(net),
//!     Arc::new(Metrics::new()),
//!     stop.clone(),
//!     Duration::ZERO,
//!     0,
//! );
//! stop.store(true, Relaxed);
//! shadow.join().unwrap().unwrap();
//! ```

use std::time::Duration;

use anyhow::Result;

use crate::metrics::Metrics;
use crate::net::fault::FaultPlan;
use crate::net::{Network, NodeId};
use crate::tensor::HogwildBuffer;

use super::health::HealthController;
use super::prim::thread::{self, JoinHandle};
use super::prim::{
    Arc, AtomicBool, AtomicU64, AtomicUsize, Condvar, Mutex, Ordering::Relaxed, RwLock,
    RwLockReadGuard, RwLockWriteGuard,
};
use super::repartition::RepartitionController;
use super::{ParamRange, RepartitionCarry, SyncStrategy};

/// Shared flag a trainer raises when its shard is exhausted.
pub type StopFlag = Arc<AtomicBool>;

/// One partition's sync work inside a trainer's shadow pool: the strategy
/// instance plus the replica range it owns.
pub struct ShadowTask {
    /// partition index in the trainer's plan (the per-partition metrics key)
    pub partition: usize,
    pub range: ParamRange,
    pub strategy: Box<dyn SyncStrategy>,
}

/// The work-stealing pool of non-rendezvous tasks shared by a trainer's
/// shadow threads. Each slot's mutex is held only for the duration of one
/// sync round; `try_lock` failures mean "someone is already syncing this
/// partition — steal the next one".
struct StealPool {
    tasks: Vec<Mutex<ShadowTask>>,
    ticket: AtomicUsize,
}

/// Spawn a single whole-replica shadow thread for one trainer — the
/// monolithic special case of [`spawn_shadow_pool`] (one task spanning the
/// full vector, one thread). Kept as the simple entry point for tests,
/// examples, and custom strategies.
#[allow(clippy::too_many_arguments)]
pub fn spawn_shadow(
    strategy: Box<dyn SyncStrategy>,
    local: Arc<HogwildBuffer>,
    trainer_node: NodeId,
    net: Arc<Network>,
    metrics: Arc<Metrics>,
    stop: StopFlag,
    interval: Duration,
    trainer_id: usize,
) -> JoinHandle<Result<u64>> {
    let range = ParamRange::full(local.len());
    spawn_shadow_pool(
        vec![ShadowTask { partition: 0, range, strategy }],
        local,
        trainer_node,
        net,
        metrics,
        stop,
        interval,
        trainer_id,
        1,
    )
}

/// Spawn one trainer's shadow pool: `threads` background threads (clamped
/// to `[1, tasks.len()]`) servicing one [`ShadowTask`] per partition until
/// `stop` is raised, then every strategy `leave()`s so decentralized
/// groups shrink. Returns a single join handle (the pool controller); its
/// value is the total number of partition rounds the pool ran.
#[allow(clippy::too_many_arguments)]
pub fn spawn_shadow_pool(
    tasks: Vec<ShadowTask>,
    local: Arc<HogwildBuffer>,
    trainer_node: NodeId,
    net: Arc<Network>,
    metrics: Arc<Metrics>,
    stop: StopFlag,
    interval: Duration,
    trainer_id: usize,
    threads: usize,
) -> JoinHandle<Result<u64>> {
    spawn_shadow_pool_adaptive(
        tasks,
        local,
        trainer_node,
        net,
        metrics,
        stop,
        interval,
        trainer_id,
        threads,
        None,
        None,
    )
}

/// Immutable context shared by a pool's controller and workers.
struct PoolCtx {
    local: Arc<HogwildBuffer>,
    trainer_node: NodeId,
    net: Arc<Network>,
    metrics: Arc<Metrics>,
    stop: StopFlag,
    interval: Duration,
    trainer_id: usize,
    ctrl: Option<Arc<RepartitionController>>,
    faults: Option<Arc<FaultPlan>>,
}

/// The install/quiesce rendezvous between a pool's controller and its
/// persistent workers. The controller publishes a task set (an *install*);
/// each worker takes its chain + a steal handle, runs laps until a quiesce
/// reason (stop, generation change, crash window, strategy error), parks
/// its chain back, and waits for the next install.
struct PoolCore {
    state: Mutex<CoreState>,
    cv: Condvar,
}

struct CoreState {
    /// monotonically increasing install counter; workers wake when it moves
    install: u64,
    /// the controller generation this install was built against
    install_gen: u64,
    /// per-worker rendezvous chains of the current install (taken on wake)
    chains: Vec<Option<Vec<ShadowTask>>>,
    /// the current install's shared work-stealing pool
    steal: Option<Arc<StealPool>>,
    /// chains handed back by quiesced workers
    parked: Vec<Option<Vec<ShadowTask>>>,
    /// workers parked since the current install
    quiesced: usize,
    /// partition rounds accumulated across all installs
    rounds: u64,
    /// first strategy error any worker hit
    first_err: Option<anyhow::Error>,
    /// some worker quiesced because the trainer's crash window opened
    /// (dirty exit: its strategies did NOT leave their groups)
    crashed: bool,
    /// terminal: workers exit their outer loop
    shutdown: bool,
}

/// What one worker's lap loop hands back when it quiesces.
struct LapExit {
    rounds: u64,
    err: Option<anyhow::Error>,
    crashed: bool,
}

/// [`spawn_shadow_pool`] with measured-cost adaptive repartitioning and
/// fault/health handling: when `controller` is given the pool runs
/// *epochs* — each services the current [`super::repartition::PlanEpoch`]
/// exactly like the static pool; once the controller publishes a new
/// generation every worker quiesces at its next sweep boundary (a blocked
/// rendezvous round is unblocked by faster peers leaving, the same
/// mechanism as shutdown), the retiring strategies `leave()` their old
/// groups, per-partition [`RepartitionCarry`] state is carried across
/// (cache ordinals are global, so entries stay valid wherever their
/// chunks now live), and the controller installs tasks over the new
/// ranges into the *same* worker threads. With `controller = None` this
/// is exactly the static pool. `health`, when given, supplies the
/// departed/rejoin handshake with the crash watchdog (see the module
/// docs).
#[allow(clippy::too_many_arguments)]
pub fn spawn_shadow_pool_adaptive(
    tasks: Vec<ShadowTask>,
    local: Arc<HogwildBuffer>,
    trainer_node: NodeId,
    net: Arc<Network>,
    metrics: Arc<Metrics>,
    stop: StopFlag,
    interval: Duration,
    trainer_id: usize,
    threads: usize,
    controller: Option<Arc<RepartitionController>>,
    health: Option<Arc<HealthController>>,
) -> JoinHandle<Result<u64>> {
    thread::Builder::new()
        .name(format!("shadow-{trainer_id}"))
        .spawn(move || {
            // worker count is fixed for the lifetime of the pool: installs
            // swap task vectors, never threads
            let nworkers = threads.clamp(1, tasks.len().max(1));
            let faults = net.faults().cloned();
            let ctx = Arc::new(PoolCtx {
                local,
                trainer_node,
                net,
                metrics,
                stop,
                interval,
                trainer_id,
                ctrl: controller,
                faults,
            });
            let core = Arc::new(PoolCore {
                state: Mutex::new(CoreState {
                    install: 0,
                    install_gen: 0,
                    chains: (0..nworkers).map(|_| None).collect(),
                    steal: None,
                    parked: (0..nworkers).map(|_| None).collect(),
                    quiesced: 0,
                    rounds: 0,
                    first_err: None,
                    crashed: false,
                    shutdown: false,
                }),
                cv: Condvar::new(),
            });
            let mut workers = Vec::with_capacity(nworkers);
            for k in 0..nworkers {
                let core = core.clone();
                let ctx = ctx.clone();
                workers.push(
                    thread::Builder::new()
                        .name(format!("shadow-{trainer_id}.{k}"))
                        .spawn(move || {
                            // --pin-cores: best-effort worker→core affinity,
                            // spread so co-located trainers don't stack on
                            // the same cores; never a correctness dependency
                            if crate::util::affinity::pinning_enabled() {
                                crate::util::affinity::pin_current_thread(
                                    trainer_id * nworkers + k,
                                );
                            }
                            worker_loop(k, &core, &ctx)
                        })
                        .expect("spawn shadow pool worker"),
                );
            }
            let mut my_gen = ctx.ctrl.as_ref().map_or(0, |c| c.generation());
            install_epoch(&core, tasks, nworkers, my_gen);
            loop {
                let (mut recovered, err, crashed) = wait_quiesced(&core, nworkers);
                // terminal paths claim the exit against the watchdog: true
                // means we own the goodbye; false means a proxy-depart
                // already left our groups and vacated our slots
                let claim_exit =
                    || health.as_ref().map_or(true, |h| h.claim_exit(trainer_id));
                if let Some(e) = err {
                    if claim_exit() {
                        leave_all(&mut recovered);
                        if let Some(c) = &ctx.ctrl {
                            c.depart(my_gen);
                        }
                    }
                    let _ = shutdown_workers(&core, workers);
                    return Err(e);
                }
                if crashed && !ctx.stop.load(Relaxed) {
                    let f = ctx.faults.as_ref().expect("crash quiesce implies a fault plan");
                    if f.crashes_permanently(trainer_id) {
                        // dead for good: a crashed process says no goodbyes —
                        // no leave, no depart. The watchdog's proxy-depart
                        // (or ring eviction) removes us from survivors' view.
                        return Ok(shutdown_workers(&core, workers));
                    }
                    // dark: keep the trainer's sweep clock ticking so the
                    // crash window can expire
                    while f.crashed(trainer_id) && !ctx.stop.load(Relaxed) {
                        f.note_sweep(trainer_id);
                        thread::sleep(Duration::from_millis(1));
                    }
                    if !ctx.stop.load(Relaxed) {
                        // try_resume stamps a fresh heartbeat under the
                        // watchdog's own lock, so a tick that measured our
                        // dark-window silence can no longer depart us
                        let resumed =
                            health.as_ref().map_or(true, |h| h.try_resume(trainer_id));
                        if !resumed {
                            // the watchdog took us out while we were dark:
                            // elastic rejoin. The dead strategies are dropped
                            // WITHOUT leave() — the watchdog already left
                            // their groups on our behalf.
                            drop(recovered);
                            let (c, h) = (
                                ctx.ctrl.as_ref().expect("departed implies a controller"),
                                health.as_ref().expect("departed implies health"),
                            );
                            let mut epoch = None;
                            while !ctx.stop.load(Relaxed) {
                                match c.rejoin() {
                                    Some(e) => {
                                        epoch = Some(e);
                                        break;
                                    }
                                    // survivors are mid-cutover: retry once
                                    // the pending epoch is fully adopted
                                    None => thread::sleep(Duration::from_millis(1)),
                                }
                            }
                            let Some(epoch) = epoch else {
                                return Ok(shutdown_workers(&core, workers));
                            };
                            // warm-start the replica from the central model:
                            // the survivors kept pushing while we were dark,
                            // so central is the freshest consistent state
                            if let Some(ps) = c.sync_ps() {
                                ctx.local.write_from(&ps.central.to_vec());
                            }
                            let seed = ctx.local.to_vec();
                            match c.build_tasks(trainer_id, &epoch, &seed, Vec::new()) {
                                Ok(tasks) => {
                                    h.mark_rejoined(trainer_id, &epoch);
                                    my_gen = epoch.gen;
                                    install_epoch(&core, tasks, nworkers, my_gen);
                                    continue;
                                }
                                Err(e) => {
                                    let _ = shutdown_workers(&core, workers);
                                    return Err(e);
                                }
                            }
                        }
                        // a short window nobody noticed: resume in place. If
                        // the plan moved while we were dark, cut over first
                        // (we are alive again, so now we say goodbye
                        // properly); the cutover block below handles it.
                        if !ctx.ctrl.as_ref().is_some_and(|c| c.generation() != my_gen) {
                            install_epoch(&core, recovered, nworkers, my_gen);
                            continue;
                        }
                    }
                }
                let recut = !ctx.stop.load(Relaxed)
                    && ctx.ctrl.as_ref().is_some_and(|c| c.generation() != my_gen);
                if !recut {
                    if claim_exit() {
                        // clean quiesces already left their chains; this
                        // retires the stolen strategies (and is idempotent
                        // on the chains) and covers a crash-at-stop
                        leave_all(&mut recovered);
                        if let Some(c) = &ctx.ctrl {
                            // vacate any pending epoch we never adopted, so
                            // adopters don't wait on a ghost
                            c.depart(my_gen);
                        }
                    }
                    return Ok(shutdown_workers(&core, workers));
                }
                // cutover: the pool is quiesced between rounds — retire the
                // old strategies, adopt the new epoch, rebuild the tasks
                // over its ranges, and install them into the same workers
                leave_all(&mut recovered);
                let c = ctx.ctrl.as_ref().unwrap();
                let epoch = c.adopt(my_gen);
                my_gen = epoch.gen;
                let mut carry: Vec<Option<RepartitionCarry>> =
                    (0..epoch.plan.len()).map(|_| None).collect();
                for t in &mut recovered {
                    if t.partition < carry.len() {
                        carry[t.partition] = t.strategy.take_repartition_carry();
                    }
                }
                let seed = ctx.local.to_vec();
                let tasks = match c.build_tasks(trainer_id, &epoch, &seed, carry) {
                    Ok(t) => t,
                    Err(e) => {
                        c.depart(my_gen);
                        let _ = shutdown_workers(&core, workers);
                        return Err(e);
                    }
                };
                if let Some(h) = &health {
                    h.note_adopt(trainer_id, &epoch);
                }
                install_epoch(&core, tasks, nworkers, my_gen);
            }
        })
        .expect("spawn shadow thread")
}

/// Distribute a task set to the persistent workers and wake them:
/// rendezvous strategies round-robin onto chains in plan order — every
/// trainer builds the exact same chains, which is what keeps the
/// cross-trainer round order acyclic (see the module doc) — everything
/// else goes into the shared work-stealing pool.
fn install_epoch(core: &PoolCore, tasks: Vec<ShadowTask>, nworkers: usize, install_gen: u64) {
    let mut chains: Vec<Vec<ShadowTask>> = (0..nworkers).map(|_| Vec::new()).collect();
    let mut steal_tasks = Vec::new();
    let mut next_chain = 0usize;
    for t in tasks {
        if t.strategy.rendezvous() {
            chains[next_chain % nworkers].push(t);
            next_chain += 1;
        } else {
            steal_tasks.push(Mutex::new(t));
        }
    }
    let mut st = core.state.lock().unwrap();
    st.chains = chains.into_iter().map(Some).collect();
    st.steal = Some(Arc::new(StealPool { tasks: steal_tasks, ticket: AtomicUsize::new(0) }));
    st.parked = (0..nworkers).map(|_| None).collect();
    st.quiesced = 0;
    st.crashed = false;
    st.install += 1;
    st.install_gen = install_gen;
    core.cv.notify_all();
}

/// Block until every worker parked, then collect every strategy of the
/// retired install (chains and stolen tasks alike) plus the quiesce
/// verdict: the first error, and whether the exit was a crash.
fn wait_quiesced(
    core: &PoolCore,
    nworkers: usize,
) -> (Vec<ShadowTask>, Option<anyhow::Error>, bool) {
    let mut st = core.state.lock().unwrap();
    while st.quiesced < nworkers {
        st = core.cv.wait(st).unwrap();
    }
    let mut recovered = Vec::new();
    for slot in st.parked.iter_mut() {
        recovered.extend(slot.take().unwrap_or_default());
    }
    let steal = st.steal.take().expect("an installed epoch has a steal pool");
    let err = st.first_err.take();
    let crashed = st.crashed;
    drop(st);
    // every worker dropped its handle before parking, so the pool is ours
    let pool = Arc::try_unwrap(steal).ok().expect("workers still hold the steal pool");
    for slot in pool.tasks {
        recovered.push(slot.into_inner().unwrap());
    }
    (recovered, err, crashed)
}

/// Terminal: wake the workers into their exit path, join them, and return
/// the pool's total round count.
fn shutdown_workers(core: &PoolCore, workers: Vec<JoinHandle<()>>) -> u64 {
    {
        let mut st = core.state.lock().unwrap();
        st.shutdown = true;
        core.cv.notify_all();
    }
    for w in workers {
        w.join().expect("shadow pool worker panicked");
    }
    let st = core.state.lock().unwrap();
    st.rounds
}

/// Retire strategies: idempotent for chains that already left on their
/// clean quiesce, a no-op for centralized strategies, and the real
/// goodbye for a crash-at-stop chain.
fn leave_all(tasks: &mut [ShadowTask]) {
    for t in tasks.iter_mut() {
        t.strategy.leave();
    }
}

/// One persistent worker: wait for an install (or shutdown), run laps
/// until a quiesce reason, park the chain back, repeat. The dirty-epoch
/// baseline (`last_epochs`) lives across installs, so sweep write-deltas
/// stay continuous through cutovers.
fn worker_loop(k: usize, core: &PoolCore, ctx: &PoolCtx) {
    let mut seen = 0u64;
    let mut last_epochs: Vec<u64> = Vec::new();
    loop {
        let (mut chain, steal, my_gen) = {
            let mut st = core.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.install > seen {
                    seen = st.install;
                    let chain = st.chains[k].take().unwrap_or_default();
                    let steal = st.steal.clone().expect("an install publishes a steal pool");
                    break (chain, steal, st.install_gen);
                }
                st = core.cv.wait(st).unwrap();
            }
        };
        let exit = run_laps(&mut chain, &steal, ctx, k == 0, my_gen, &mut last_epochs);
        // the controller try-unwraps the steal pool once every worker has
        // parked: our clone must be gone first
        drop(steal);
        let mut st = core.state.lock().unwrap();
        st.rounds += exit.rounds;
        if let Some(e) = exit.err {
            st.first_err.get_or_insert(e);
        }
        if exit.crashed {
            st.crashed = true;
        }
        st.parked[k] = Some(chain);
        st.quiesced += 1;
        core.cv.notify_all();
    }
}

/// The lap loop of one worker for one install: per lap, run the next
/// round of the owned rendezvous chain (cyclic order) and steal one
/// non-rendezvous round. The lead worker (thread 0) additionally advances
/// the fault plan's sweep clock and records one repartition *sweep* per
/// lap with the replica's dirty-epoch write delta; every worker checks
/// the crash window and the controller's generation once per lap and
/// quiesces at the boundary. Clean exits `leave()` the chain — a crash
/// does not (dirty exit; see the module docs).
fn run_laps(
    chain: &mut [ShadowTask],
    pool: &StealPool,
    ctx: &PoolCtx,
    lead: bool,
    my_gen: u64,
    last_epochs: &mut Vec<u64>,
) -> LapExit {
    let mut rounds = 0u64;
    let mut chain_idx = 0usize;
    let mut err = None;
    let mut crashed = false;
    'run: while !ctx.stop.load(Relaxed) {
        if let Some(f) = &ctx.faults {
            if lead {
                f.note_sweep(ctx.trainer_id);
            }
            if f.crashed(ctx.trainer_id) {
                crashed = true;
                break 'run;
            }
            if let Some(d) = f.lap_delay(ctx.trainer_id) {
                // straggling: every lap pays the stall
                thread::sleep(d);
            }
        }
        let mut worked = false;
        if !chain.is_empty() {
            let t = &mut chain[chain_idx % chain.len()];
            chain_idx += 1;
            let sctx = super::SyncCtx {
                local: &ctx.local,
                range: t.range,
                partition: t.partition,
                trainer_node: ctx.trainer_node,
                net: &ctx.net,
                metrics: &ctx.metrics,
            };
            match t.strategy.sync_round(&sctx) {
                Ok(_) => {
                    ctx.metrics.record_partition_sync(t.partition);
                    rounds += 1;
                    worked = true;
                }
                Err(e) => {
                    err = Some(e);
                    break 'run;
                }
            }
        }
        if !pool.tasks.is_empty() {
            // work-stealing round-robin: start at the shared ticket and
            // walk forward past partitions another thread is busy syncing
            let start = pool.ticket.fetch_add(1, Relaxed);
            for off in 0..pool.tasks.len() {
                let slot = &pool.tasks[(start.wrapping_add(off)) % pool.tasks.len()];
                let Ok(mut t) = slot.try_lock() else { continue };
                let sctx = super::SyncCtx {
                    local: &ctx.local,
                    range: t.range,
                    partition: t.partition,
                    trainer_node: ctx.trainer_node,
                    net: &ctx.net,
                    metrics: &ctx.metrics,
                };
                match t.strategy.sync_round(&sctx) {
                    Ok(_) => {
                        ctx.metrics.record_partition_sync(t.partition);
                        rounds += 1;
                        worked = true;
                    }
                    Err(e) => {
                        err = Some(e);
                    }
                }
                break;
            }
            if err.is_some() {
                break 'run;
            }
        }
        if !worked {
            thread::yield_now();
        }
        if !ctx.interval.is_zero() {
            thread::sleep(ctx.interval);
        }
        if let Some(c) = &ctx.ctrl {
            if lead {
                // feed the measured write rates: dirty-epoch bumps since
                // this worker's previous sweep (empty when untracked; the
                // first observation only primes the baseline — re-adding
                // cumulative counts after every cutover would multiply the
                // profile by its own history)
                let delta = match ctx.local.dirty_chunk_epochs() {
                    Some(now) => {
                        let delta = if last_epochs.len() == now.len() {
                            now.iter()
                                .zip(last_epochs.iter())
                                .map(|(n, l)| n.wrapping_sub(*l))
                                .collect()
                        } else {
                            Vec::new()
                        };
                        *last_epochs = now;
                        delta
                    }
                    None => Vec::new(),
                };
                c.record_sweep(&delta);
            }
            if c.generation() != my_gen {
                break 'run; // quiesce for the cutover
            }
        }
    }
    if !crashed {
        // leaving the owned chain is what unblocks peer trainers mid-round
        // — at shutdown and at a repartition cutover alike; a crash keeps
        // its memberships (a dead process doesn't say goodbye)
        for t in chain.iter_mut() {
            t.strategy.leave();
        }
    }
    LapExit { rounds, err, crashed }
}

/// Foreground gate: workers hold a read lock while training; a fixed-rate
/// AllReduce syncer takes the write lock, stopping the trainer's world.
#[derive(Default)]
pub struct Gate {
    lock: RwLock<()>,
}

impl Gate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Workers wrap each iteration in this.
    pub fn working(&self) -> RwLockReadGuard<'_, ()> {
        self.lock.read().unwrap()
    }

    /// The foreground syncer wraps the collective in this.
    pub fn stop_the_world(&self) -> RwLockWriteGuard<'_, ()> {
        self.lock.write().unwrap()
    }
}

/// Per-trainer shared iteration counter driving fixed-rate scheduling.
#[derive(Default)]
pub struct IterCounter(AtomicU64);

impl IterCounter {
    pub fn bump(&self) -> u64 {
        self.0.fetch_add(1, Relaxed) + 1
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Role;
    use crate::sync::{NoSync, SyncCtx, SyncStrategy};

    struct CountingSync {
        rounds: Arc<AtomicU64>,
        left: Arc<AtomicBool>,
    }

    impl SyncStrategy for CountingSync {
        fn sync_round(&mut self, _ctx: &SyncCtx<'_>) -> Result<f32> {
            self.rounds.fetch_add(1, Relaxed);
            Ok(0.0)
        }
        fn leave(&mut self) {
            self.left.store(true, Relaxed);
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    #[test]
    fn shadow_thread_runs_until_stopped_then_leaves() {
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow(
            Box::new(CountingSync { rounds: rounds.clone(), left: left.clone() }),
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop.clone(),
            Duration::from_millis(1),
            0,
        );
        while rounds.load(Relaxed) < 5 {
            std::thread::yield_now();
        }
        stop.store(true, Relaxed);
        let n = h.join().unwrap().unwrap();
        assert!(n >= 5);
        assert!(left.load(Relaxed));
    }

    #[test]
    fn shadow_free_runs_without_interval() {
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow(
            Box::new(CountingSync { rounds: rounds.clone(), left }),
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop.clone(),
            Duration::ZERO,
            1,
        );
        std::thread::sleep(Duration::from_millis(20));
        stop.store(true, Relaxed);
        let n = h.join().unwrap().unwrap();
        assert!(n > 100, "free-running shadow only did {n} rounds");
    }

    #[test]
    fn shadow_pool_services_every_partition_and_records_gaps() {
        // 4 partitions, 2 threads: every partition keeps getting rounds,
        // each round lands in its partition's metrics counter, and every
        // strategy leaves at shutdown
        let p = 4usize;
        let counters: Vec<_> = (0..p).map(|_| Arc::new(AtomicU64::new(0))).collect();
        let lefts: Vec<_> = (0..p).map(|_| Arc::new(AtomicBool::new(false))).collect();
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let tasks: Vec<ShadowTask> = (0..p)
            .map(|i| ShadowTask {
                partition: i,
                range: ParamRange { offset: i * 4, len: 4 },
                strategy: Box::new(CountingSync {
                    rounds: counters[i].clone(),
                    left: lefts[i].clone(),
                }),
            })
            .collect();
        let h = spawn_shadow_pool(
            tasks,
            Arc::new(HogwildBuffer::zeros(16)),
            node,
            Arc::new(net),
            metrics.clone(),
            stop.clone(),
            Duration::ZERO,
            0,
            2,
        );
        while counters.iter().any(|c| c.load(Relaxed) < 5) {
            std::thread::yield_now();
        }
        stop.store(true, Relaxed);
        let total = h.join().unwrap().unwrap();
        let per_partition: Vec<u64> = counters.iter().map(|c| c.load(Relaxed)).collect();
        assert!(per_partition.iter().all(|&c| c >= 5), "starved partition: {per_partition:?}");
        assert_eq!(total, per_partition.iter().sum::<u64>());
        // the pool's rounds flow into the per-partition metrics counters
        let snap = metrics.snapshot();
        assert_eq!(snap.partition_syncs.len(), p);
        assert_eq!(snap.partition_syncs, per_partition);
        assert!(lefts.iter().all(|l| l.load(Relaxed)), "a strategy never left");
    }

    #[test]
    fn pool_threads_clamp_to_task_count() {
        // more threads than tasks: the pool clamps instead of spinning
        // idle threads
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow_pool(
            vec![ShadowTask {
                partition: 0,
                range: ParamRange::full(4),
                strategy: Box::new(CountingSync { rounds: rounds.clone(), left: left.clone() }),
            }],
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop.clone(),
            Duration::from_millis(1),
            7,
            8,
        );
        while rounds.load(Relaxed) < 3 {
            std::thread::yield_now();
        }
        stop.store(true, Relaxed);
        assert!(h.join().unwrap().unwrap() >= 3);
        assert!(left.load(Relaxed));
    }

    #[test]
    fn crash_window_quiesces_the_pool_then_resumes() {
        // a transient crash window: the pool goes dark at the window's
        // sweep, the controller ticks the clock until it closes, the same
        // tasks are reinstalled, and rounds keep flowing afterwards
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let faults =
            Arc::new(FaultPlan::parse("crash:t0@sweep5+3", 7).expect("valid plan"));
        let net = net.with_faults(faults.clone());
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow_pool(
            vec![ShadowTask {
                partition: 0,
                range: ParamRange::full(4),
                strategy: Box::new(CountingSync { rounds: rounds.clone(), left: left.clone() }),
            }],
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop.clone(),
            Duration::ZERO,
            0,
            1,
        );
        // wait until the window has definitely opened and closed again
        while faults.sweep(0) < 20 {
            std::thread::yield_now();
        }
        let before = rounds.load(Relaxed);
        while rounds.load(Relaxed) <= before {
            std::thread::yield_now();
        }
        stop.store(true, Relaxed);
        let total = h.join().unwrap().unwrap();
        assert!(total > before, "no rounds after the crash window closed");
        assert!(left.load(Relaxed), "resumed pool must still leave at shutdown");
    }

    #[test]
    fn permanent_crash_shuts_the_pool_down_without_goodbyes() {
        // a permanent crash (no +duration): the pool returns on its own,
        // without stop ever being raised, and the dead strategies never
        // leave their groups — that's the watchdog's job
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let faults = Arc::new(FaultPlan::parse("crash:t0@sweep3", 7).expect("valid plan"));
        let net = net.with_faults(faults);
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow_pool(
            vec![ShadowTask {
                partition: 0,
                range: ParamRange::full(4),
                strategy: Box::new(CountingSync { rounds: rounds.clone(), left: left.clone() }),
            }],
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop,
            Duration::ZERO,
            0,
            2,
        );
        let total = h.join().unwrap().unwrap();
        assert_eq!(total, rounds.load(Relaxed));
        assert!(!left.load(Relaxed), "a crashed trainer must not say goodbye");
    }

    #[test]
    fn gate_blocks_workers_during_sync() {
        let gate = Arc::new(Gate::new());
        let in_crit = Arc::new(AtomicU64::new(0));
        let g = gate.clone();
        let ic = in_crit.clone();
        let w = gate.stop_the_world();
        let worker = std::thread::spawn(move || {
            let _guard = g.working();
            ic.store(1, Relaxed);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(in_crit.load(Relaxed), 0, "worker entered during stop-the-world");
        drop(w);
        worker.join().unwrap();
        assert_eq!(in_crit.load(Relaxed), 1);
    }

    #[test]
    fn iter_counter() {
        let c = IterCounter::default();
        assert_eq!(c.bump(), 1);
        assert_eq!(c.bump(), 2);
        assert_eq!(c.get(), 2);
    }

    #[test]
    fn nosync_is_noop() {
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let metrics = Metrics::new();
        let local = HogwildBuffer::from_slice(&[1.0]);
        let ctx = SyncCtx::full(&local, node, &net, &metrics);
        assert_eq!(NoSync.sync_round(&ctx).unwrap(), 0.0);
        assert_eq!(metrics.snapshot().syncs, 0);
    }
}
