//! Sync drivers: the shadow thread (background) and the foreground
//! fixed-rate hook.
//!
//! **Shadow** (the paper's framework, Algorithm 1 lines 10–12): one extra
//! thread per trainer loops sync rounds while worker threads train — the
//! synchronization is "neither part of the backward pass nor happens every
//! k iterations". An optional interval throttles the loop (the
//! `ablate-shadow-rate` experiment sweeps it; 0 = free-running as in the
//! paper).
//!
//! **Foreground fixed-rate**: the baselines. For EASGD every worker thread
//! syncs inline every `gap` of its own iterations (this is what makes
//! FR-EASGD's sync-PS traffic `m×` larger). For AllReduce algorithms the
//! trainer's designated syncer (worker 0) runs the collective every `gap`
//! trainer-level iterations while a write-lock gate stops that trainer's
//! other workers — synchronization literally interrupts training.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::metrics::Metrics;
use crate::net::{Network, NodeId};
use crate::tensor::HogwildBuffer;

use super::SyncStrategy;

/// Shared flag a trainer raises when its shard is exhausted.
pub type StopFlag = Arc<AtomicBool>;

/// Spawn the shadow thread for one trainer.
///
/// The thread loops `strategy.sync_round` until `stop` is raised, then calls
/// `strategy.leave()` so decentralized groups shrink. Returns the join
/// handle; the thread returns the number of rounds it ran.
#[allow(clippy::too_many_arguments)]
pub fn spawn_shadow(
    mut strategy: Box<dyn SyncStrategy>,
    local: Arc<HogwildBuffer>,
    trainer_node: NodeId,
    net: Arc<Network>,
    metrics: Arc<Metrics>,
    stop: StopFlag,
    interval: Duration,
    trainer_id: usize,
) -> JoinHandle<Result<u64>> {
    std::thread::Builder::new()
        .name(format!("shadow-{trainer_id}"))
        .spawn(move || {
            let mut rounds = 0u64;
            while !stop.load(Relaxed) {
                let ctx = super::SyncCtx {
                    local: &local,
                    trainer_node,
                    net: &net,
                    metrics: &metrics,
                };
                strategy.sync_round(&ctx)?;
                rounds += 1;
                if !interval.is_zero() {
                    std::thread::sleep(interval);
                }
            }
            strategy.leave();
            Ok(rounds)
        })
        .expect("spawn shadow thread")
}

/// Foreground gate: workers hold a read lock while training; a fixed-rate
/// AllReduce syncer takes the write lock, stopping the trainer's world.
#[derive(Default)]
pub struct Gate {
    lock: RwLock<()>,
}

impl Gate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Workers wrap each iteration in this.
    pub fn working(&self) -> std::sync::RwLockReadGuard<'_, ()> {
        self.lock.read().unwrap()
    }

    /// The foreground syncer wraps the collective in this.
    pub fn stop_the_world(&self) -> std::sync::RwLockWriteGuard<'_, ()> {
        self.lock.write().unwrap()
    }
}

/// Per-trainer shared iteration counter driving fixed-rate scheduling.
#[derive(Default)]
pub struct IterCounter(AtomicU64);

impl IterCounter {
    pub fn bump(&self) -> u64 {
        self.0.fetch_add(1, Relaxed) + 1
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Role;
    use crate::sync::{NoSync, SyncCtx, SyncStrategy};

    struct CountingSync {
        rounds: Arc<AtomicU64>,
        left: Arc<AtomicBool>,
    }

    impl SyncStrategy for CountingSync {
        fn sync_round(&mut self, _ctx: &SyncCtx<'_>) -> Result<f32> {
            self.rounds.fetch_add(1, Relaxed);
            Ok(0.0)
        }
        fn leave(&mut self) {
            self.left.store(true, Relaxed);
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    #[test]
    fn shadow_thread_runs_until_stopped_then_leaves() {
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow(
            Box::new(CountingSync { rounds: rounds.clone(), left: left.clone() }),
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop.clone(),
            Duration::from_millis(1),
            0,
        );
        while rounds.load(Relaxed) < 5 {
            std::thread::yield_now();
        }
        stop.store(true, Relaxed);
        let n = h.join().unwrap().unwrap();
        assert!(n >= 5);
        assert!(left.load(Relaxed));
    }

    #[test]
    fn shadow_free_runs_without_interval() {
        let rounds = Arc::new(AtomicU64::new(0));
        let left = Arc::new(AtomicBool::new(false));
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_shadow(
            Box::new(CountingSync { rounds: rounds.clone(), left }),
            Arc::new(HogwildBuffer::zeros(4)),
            node,
            Arc::new(net),
            Arc::new(Metrics::new()),
            stop.clone(),
            Duration::ZERO,
            1,
        );
        std::thread::sleep(Duration::from_millis(20));
        stop.store(true, Relaxed);
        let n = h.join().unwrap().unwrap();
        assert!(n > 100, "free-running shadow only did {n} rounds");
    }

    #[test]
    fn gate_blocks_workers_during_sync() {
        let gate = Arc::new(Gate::new());
        let in_crit = Arc::new(AtomicU64::new(0));
        let g = gate.clone();
        let ic = in_crit.clone();
        let w = gate.stop_the_world();
        let worker = std::thread::spawn(move || {
            let _guard = g.working();
            ic.store(1, Relaxed);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(in_crit.load(Relaxed), 0, "worker entered during stop-the-world");
        drop(w);
        worker.join().unwrap();
        assert_eq!(in_crit.load(Relaxed), 1);
    }

    #[test]
    fn iter_counter() {
        let c = IterCounter::default();
        assert_eq!(c.bump(), 1);
        assert_eq!(c.bump(), 2);
        assert_eq!(c.get(), 2);
    }

    #[test]
    fn nosync_is_noop() {
        let mut net = Network::new(None);
        let node = net.add_node(Role::Trainer);
        let metrics = Metrics::new();
        let local = HogwildBuffer::from_slice(&[1.0]);
        let ctx = SyncCtx { local: &local, trainer_node: node, net: &net, metrics: &metrics };
        assert_eq!(NoSync.sync_round(&ctx).unwrap(), 0.0);
        assert_eq!(metrics.snapshot().syncs, 0);
    }
}
