//! Straggler-adaptive algorithm switching and crash recovery — the
//! fabric's health controller.
//!
//! A rendezvous partition (MA/BMUF) is only as fast as its slowest member:
//! one straggling trainer stretches every peer's round, and a *crashed*
//! trainer would stall rounds forever. This module closes both holes with
//! a [`HealthController`] shared by every trainer, a sibling of
//! [`RepartitionController`] that reuses its epoch-gated cutover instead
//! of inventing a second protocol:
//!
//! * **Liveness** — every training worker stamps a per-trainer heartbeat
//!   each iteration ([`HealthController::note_lap`]). Heartbeats come from
//!   the *training* loop, not the shadow pool, deliberately: in shadow
//!   mode workers never block on sync, so a healthy trainer whose shadow
//!   thread is parked in a rendezvous round behind a straggler still beats
//!   at full rate — pool-side heartbeats would depart the victims before
//!   the culprit. A watchdog thread ([`HealthController::spawn_watchdog`])
//!   departs any trainer silent past `--heartbeat-timeout-ms`: it proxies
//!   the dead trainer's `leave()` on every collective group of the epoch
//!   it last adopted, then runs the normal
//!   [`RepartitionController::depart`] (which also vacates the trainer's
//!   slots in a pending epoch), so survivors keep closing rounds and the
//!   next rebuild sizes rings to the real roster. The departed trainer's
//!   pool later rejoins through [`RepartitionController::rejoin`] once its
//!   crash window closes.
//! * **Straggler adaptation** (`--health-adaptive`) — the controller keeps
//!   an EWMA of each trainer's beat interval and compares every alive
//!   trainer against the roster's lower-median rate. When some trainer's
//!   effective interval (its EWMA, or its current silence if longer)
//!   exceeds `--health-stall-factor ×` the median, the controller
//!   *demotes*: it publishes an algo-map override that re-resolves every
//!   rendezvous partition to EASGD — same ranges, no rounds to stall —
//!   and forces an epoch cutover. Trainers then sync the demoted
//!   partitions through the sync-PS tier at their own pace (which is why
//!   `--health-adaptive` requires `--num-sync-ps ≥ 1`). When the roster
//!   stays healthy for [`PROMOTE_AFTER`] consecutive watchdog ticks, the
//!   override is cleared and a second forced cutover *promotes* the
//!   partitions back; BMUF momentum survives the round trip inside
//!   [`crate::sync::RepartitionCarry`] (parked by the interim EASGD
//!   strategy), because forced rebuilds keep partition ranges fixed.
//!
//! Orderings (enforced by `cargo run -p xtask -- lint`, documented in
//! docs/CONCURRENCY.md): `heartbeat` stamps are Release stores paired with
//! Acquire loads in the watchdog, so a depart decision never acts on a
//! stale-but-published beat; `departed` flags only *transition* inside the
//! controller's state lock (reads stay lock-free Acquire loads), and every
//! depart re-validates staleness under that lock. The lock is what makes
//! the three racing claimants — watchdog ticks ([`Self::check_heartbeats`]),
//! a pool resuming from a closed crash window ([`Self::try_resume`]), and a
//! pool's terminal goodbye ([`Self::claim_exit`]) — mutually exclusive: the
//! proxy-leave runs exactly once per crash, a resume can never be
//! invalidated by a tick that measured pre-resume silence, and a terminal
//! `leave()` can never double with a proxy one. `tests/loom_models.rs`
//! model-checks this handshake exhaustively.

use std::time::{Duration, Instant};

use crate::config::{AlgoMap, RunConfig, SyncAlgo};

use super::prim::{
    thread::{self, JoinHandle},
    Arc, AtomicBool, AtomicU64, Mutex,
    Ordering::{Acquire, Relaxed, Release},
};
use super::repartition::{PlanEpoch, RepartitionController};

/// Consecutive healthy watchdog ticks before a demoted fabric is promoted
/// back to its configured algorithms (hysteresis: one clean tick is not
/// recovery).
pub const PROMOTE_AFTER: u32 = 8;

/// EWMA weight of the newest beat interval.
const EWMA_NEW: f64 = 0.3;

/// Effective intervals at or below this (ms) are never called straggling,
/// whatever the ratio: sub-5ms jitter is scheduler noise, not a stall.
const MIN_STALL_MS: f64 = 5.0;

/// Per-trainer EWMA/clock bookkeeping plus the demote/promote hysteresis.
/// Everything time-flavored lives here, under one lock, so the watchdog
/// evaluates a consistent snapshot.
struct HealthState {
    /// EWMA of each trainer's beat interval in ms (0.0 = not yet primed)
    ewma: Vec<f64>,
    /// previous beat timestamp, for the EWMA delta (None = never beat)
    last_beat: Vec<Option<u64>>,
    /// the epoch each trainer most recently adopted; *taken* by a depart,
    /// so the proxy-leave of its groups can only happen once
    adopted: Vec<Option<Arc<PlanEpoch>>>,
    /// consecutive straggler-free ticks while demoted
    healthy_ticks: u32,
    /// is the demotion override currently published?
    demoted: bool,
    /// an override flip happened while an epoch was pending adoption; the
    /// forced cutover is retried on later ticks until the gate opens
    cut_pending: bool,
}

/// Shared per-run health brain: heartbeat registry, crash watchdog, and
/// the straggler demote/promote lever over [`RepartitionController`].
pub struct HealthController {
    ctrl: Arc<RepartitionController>,
    /// heartbeat staleness budget in ms (0 = crash watchdog disabled)
    timeout_ms: u64,
    /// demote when an interval exceeds this multiple of the median
    stall_factor: f64,
    /// straggler adaptation armed (config flag + at least one rendezvous
    /// partition to demote)
    adaptive: bool,
    /// the override published on demotion: every rendezvous partition
    /// re-resolved to EASGD, everything else untouched
    demoted_map: AlgoMap,
    start: Instant,
    /// per-trainer last-heartbeat stamp, ms since `start` (Release store
    /// by workers / Acquire load by the watchdog)
    heartbeat: Vec<AtomicU64>,
    /// per-trainer crash flag: read lock-free (Acquire), but only ever
    /// *written* under `state`'s lock, which serializes the three racing
    /// claimants (watchdog depart, pool resume, pool terminal exit)
    departed: Vec<AtomicBool>,
    /// per-trainer shard-exhausted flag: a finished trainer stops beating
    /// legitimately (its workers are done, its pool drains until the
    /// coordinator raises stop) and must never be departed or counted as
    /// a straggler
    done: Vec<AtomicBool>,
    state: Mutex<HealthState>,
    stat_departs: AtomicU64,
    stat_demotions: AtomicU64,
    stat_promotions: AtomicU64,
}

impl HealthController {
    pub fn new(cfg: &RunConfig, ctrl: Arc<RepartitionController>) -> Self {
        let n = cfg.num_trainers;
        let p = cfg.sync_partitions.max(1);
        let entries: Vec<(SyncAlgo, usize, usize)> = (0..p)
            .map(|i| {
                let algo = match cfg.partition_algo(i) {
                    SyncAlgo::Ma | SyncAlgo::Bmuf => SyncAlgo::Easgd,
                    keep => keep,
                };
                (algo, i, i)
            })
            .collect();
        let has_rendezvous =
            (0..p).any(|i| matches!(cfg.partition_algo(i), SyncAlgo::Ma | SyncAlgo::Bmuf));
        let epoch0 = ctrl.current_epoch();
        let mut heartbeat = Vec::with_capacity(n);
        heartbeat.resize_with(n, || AtomicU64::new(0));
        let mut departed = Vec::with_capacity(n);
        departed.resize_with(n, || AtomicBool::new(false));
        let mut done = Vec::with_capacity(n);
        done.resize_with(n, || AtomicBool::new(false));
        Self {
            ctrl,
            timeout_ms: cfg.heartbeat_timeout_ms,
            stall_factor: cfg.health_stall_factor,
            adaptive: cfg.health_adaptive && has_rendezvous,
            demoted_map: AlgoMap::from_entries(entries)
                .expect("per-partition identity entries cannot overlap"),
            start: Instant::now(),
            heartbeat,
            departed,
            done,
            state: Mutex::new(HealthState {
                ewma: vec![0.0; n],
                last_beat: vec![None; n],
                adopted: (0..n).map(|_| Some(epoch0.clone())).collect(),
                healthy_ticks: 0,
                demoted: false,
                cut_pending: false,
            }),
            stat_departs: AtomicU64::new(0),
            stat_demotions: AtomicU64::new(0),
            stat_promotions: AtomicU64::new(0),
        }
    }

    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// One training iteration happened on trainer `t`: stamp its
    /// heartbeat. Called from every Hogwild worker, every iteration —
    /// the stamp is a lock-free store; the EWMA bookkeeping is
    /// best-effort (`try_lock`, a contended beat just skips its sample).
    pub fn note_lap(&self, t: usize) {
        self.observe_beat(t, self.now_ms());
    }

    fn observe_beat(&self, t: usize, now: u64) {
        self.heartbeat[t].store(now, Release);
        if !self.adaptive {
            return;
        }
        if let Ok(mut st) = self.state.try_lock() {
            if let Some(prev) = st.last_beat[t] {
                let dt = now.saturating_sub(prev) as f64;
                st.ewma[t] =
                    if st.ewma[t] > 0.0 { EWMA_NEW * dt + (1.0 - EWMA_NEW) * st.ewma[t] } else { dt };
            }
            st.last_beat[t] = Some(now);
        }
    }

    /// Trainer `t` cut over to `epoch` (the pool's adopt path). The stored
    /// epoch is what a later crash proxy-leaves.
    pub fn note_adopt(&self, t: usize, epoch: &Arc<PlanEpoch>) {
        self.state.lock().unwrap().adopted[t] = Some(epoch.clone());
    }

    /// Trainer `t` exhausted its shard: it will stop beating for the
    /// legitimate reason. The watchdog must neither depart it (its shadow
    /// pool is still alive and will `leave()` properly at stop — a proxy
    /// depart now would make the groups shrink twice) nor read its silence
    /// as straggling.
    pub fn mark_done(&self, t: usize) {
        self.done[t].store(true, Release);
    }

    /// Has trainer `t` left the roster — by watchdog proxy-depart or by
    /// its own claimed exit? Observational only: the pool never branches
    /// on this read-then-act (that would race the watchdog); it uses the
    /// claiming APIs [`Self::claim_exit`] / [`Self::try_resume`] instead.
    pub fn is_departed(&self, t: usize) -> bool {
        self.departed[t].load(Acquire)
    }

    /// Trainer `t` re-entered via [`RepartitionController::rejoin`] with
    /// the returned `epoch`: reset its clocks (so the watchdog doesn't
    /// instantly re-depart it off the stale stamp) and lower the flag.
    pub fn mark_rejoined(&self, t: usize, epoch: &Arc<PlanEpoch>) {
        let now = self.now_ms();
        self.heartbeat[t].store(now, Release);
        let mut st = self.state.lock().unwrap();
        st.adopted[t] = Some(epoch.clone());
        st.ewma[t] = 0.0;
        st.last_beat[t] = Some(now);
        // lowered under the lock, like every `departed` transition
        self.departed[t].store(false, Release);
    }

    /// Depart trainer `t` on its behalf: claim the `departed` flag under
    /// the state lock (one winner, ever — a racing [`Self::try_resume`] or
    /// [`Self::claim_exit`] excludes this call entirely), `leave()` every
    /// collective group of the epoch the trainer last adopted so peers
    /// mid-round stop waiting on it, then run the controller's normal
    /// depart (which also vacates its slots in a pending epoch). Returns
    /// whether this call was the one that did it.
    pub fn depart_trainer(&self, t: usize) -> bool {
        self.depart_with(t, None)
    }

    /// The depart claim. With `stale_check = Some(now)` (the watchdog
    /// path) staleness is re-validated *under the lock*: a pool that
    /// resumed through [`Self::try_resume`] stamped a fresh heartbeat
    /// under this same lock first, so a tick that measured pre-resume
    /// silence aborts here instead of departing a live trainer.
    fn depart_with(&self, t: usize, stale_check: Option<u64>) -> bool {
        let epoch = {
            let mut st = self.state.lock().unwrap();
            if self.departed[t].load(Acquire) {
                return false;
            }
            if let Some(now) = stale_check {
                if now.saturating_sub(self.heartbeat[t].load(Acquire)) <= self.timeout_ms {
                    return false;
                }
            }
            self.departed[t].store(true, Release);
            st.adopted[t].take()
        };
        let Some(epoch) = epoch else { return false };
        for g in epoch.groups.iter().flatten() {
            g.leave();
        }
        self.ctrl.depart(epoch.gen);
        self.stat_departs.fetch_add(1, Relaxed);
        true
    }

    /// A pool controller resuming from a *closed* crash window calls this
    /// before touching its strategies again: under the same lock the
    /// watchdog departs under, it re-checks the flag and stamps a fresh
    /// heartbeat, so the answer cannot be invalidated by a tick that
    /// measured pre-resume silence. `true` means the trainer still owns
    /// its memberships and simply carries on; `false` means the watchdog
    /// already departed it — the pool must drop its dead strategies and
    /// re-enter through [`RepartitionController::rejoin`].
    pub fn try_resume(&self, t: usize) -> bool {
        self.resume_at(t, self.now_ms())
    }

    fn resume_at(&self, t: usize, now: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        if self.departed[t].load(Acquire) {
            return false;
        }
        self.heartbeat[t].store(now, Release);
        st.last_beat[t] = Some(now);
        st.ewma[t] = 0.0;
        true
    }

    /// Deterministic clock hooks for the integration tests and the loom
    /// models in `tests/` (a separate crate, where the private `*_at`
    /// internals are unreachable and model checking cannot consult wall
    /// clocks). `now` is milliseconds since construction; the production
    /// paths ([`Self::note_lap`], [`Self::check_heartbeats`],
    /// [`Self::try_resume`], [`Self::spawn_watchdog`]) use the real clock.
    #[doc(hidden)]
    pub fn beat_at_ms(&self, t: usize, now: u64) {
        self.observe_beat(t, now);
    }

    #[doc(hidden)]
    pub fn check_at_ms(&self, now: u64) -> usize {
        self.check_at(now)
    }

    #[doc(hidden)]
    pub fn resume_at_ms(&self, t: usize, now: u64) -> bool {
        self.resume_at(t, now)
    }

    /// A pool's terminal paths (stop raised, shard drained, strategy
    /// error) claim the exit before saying their goodbyes: whoever flips
    /// the flag — this claim or a watchdog depart — owns the teardown, so
    /// the `leave()`/`depart()` pair can never run twice for one trainer.
    /// `true` means the pool leaves its own strategies (the normal case);
    /// `false` means a watchdog depart already proxied them.
    pub fn claim_exit(&self, t: usize) -> bool {
        let mut st = self.state.lock().unwrap();
        if self.departed[t].load(Acquire) {
            return false;
        }
        self.departed[t].store(true, Release);
        st.adopted[t] = None;
        true
    }

    /// Scan for trainers silent past the timeout and depart them. Returns
    /// how many were departed by this scan. No-op when the watchdog
    /// timeout is 0.
    pub fn check_heartbeats(&self) -> usize {
        self.check_at(self.now_ms())
    }

    fn check_at(&self, now: u64) -> usize {
        if self.timeout_ms == 0 {
            return 0;
        }
        let mut taken = 0;
        for t in 0..self.heartbeat.len() {
            if self.departed[t].load(Acquire) || self.done[t].load(Acquire) {
                continue;
            }
            let last = self.heartbeat[t].load(Acquire);
            if now.saturating_sub(last) > self.timeout_ms && self.depart_with(t, Some(now)) {
                taken += 1;
            }
        }
        taken
    }

    /// One adaptation tick: compare every alive trainer's effective beat
    /// interval against the roster's lower median and flip the demotion
    /// override when a straggler appears / the roster recovers.
    pub fn tick(&self) {
        self.eval_at(self.now_ms());
    }

    fn eval_at(&self, now: u64) {
        if !self.adaptive {
            return;
        }
        let mut st = self.state.lock().unwrap();
        if st.cut_pending {
            // an earlier flip is still waiting for the adoption gate
            if self.ctrl.force_rebuild() {
                st.cut_pending = false;
            } else {
                return;
            }
        }
        // effective interval = smoothed rate, or the current silence if
        // longer (a fresh stall shows up before its next beat ever lands)
        let mut eff: Vec<f64> = Vec::with_capacity(self.heartbeat.len());
        for t in 0..self.heartbeat.len() {
            if self.departed[t].load(Acquire) || self.done[t].load(Acquire) {
                continue;
            }
            let silent = now.saturating_sub(self.heartbeat[t].load(Acquire)) as f64;
            eff.push(if st.ewma[t] > 0.0 { st.ewma[t].max(silent) } else { silent });
        }
        if eff.len() < 2 {
            return; // nobody to straggle relative to
        }
        let mut sorted = eff.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        // lower median: with a 2-trainer roster the baseline is the FASTER
        // one, so a straggling half is still detected
        let baseline = sorted[(sorted.len() - 1) / 2].max(0.1);
        let straggling =
            eff.iter().any(|&e| e > MIN_STALL_MS && e > self.stall_factor * baseline);
        if straggling {
            st.healthy_ticks = 0;
            if !st.demoted {
                st.demoted = true;
                self.stat_demotions.fetch_add(1, Relaxed);
                self.ctrl.set_algo_override(Some(self.demoted_map.clone()));
                st.cut_pending = !self.ctrl.force_rebuild();
            }
        } else if st.demoted {
            st.healthy_ticks += 1;
            if st.healthy_ticks >= PROMOTE_AFTER {
                st.demoted = false;
                st.healthy_ticks = 0;
                self.stat_promotions.fetch_add(1, Relaxed);
                self.ctrl.set_algo_override(None);
                st.cut_pending = !self.ctrl.force_rebuild();
            }
        }
    }

    /// Run the watchdog on its own thread until `stop` is raised:
    /// heartbeat scan + adaptation tick, every few ms (a quarter of the
    /// heartbeat timeout, clamped, so a crash is caught within ~1.25
    /// timeouts worst-case).
    pub fn spawn_watchdog(self: &Arc<Self>, stop: Arc<AtomicBool>) -> JoinHandle<()> {
        let h = self.clone();
        let poll = if h.timeout_ms > 0 {
            Duration::from_millis((h.timeout_ms / 4).clamp(1, 20))
        } else {
            Duration::from_millis(2)
        };
        thread::Builder::new()
            .name("health-watchdog".into())
            .spawn(move || {
                while !stop.load(Acquire) {
                    h.check_heartbeats();
                    h.tick();
                    thread::sleep(poll);
                }
            })
            .expect("spawn health watchdog")
    }

    /// Is the demotion override currently published?
    pub fn demoted(&self) -> bool {
        self.state.lock().unwrap().demoted
    }

    /// Trainers departed by the watchdog (crashes caught).
    pub fn departs(&self) -> u64 {
        self.stat_departs.load(Relaxed)
    }

    /// Straggler demotions published.
    pub fn demotions(&self) -> u64 {
        self.stat_demotions.load(Relaxed)
    }

    /// Recovery promotions published.
    pub fn promotions(&self) -> u64 {
        self.stat_promotions.load(Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::partition::PartitionPlan;

    fn fixture(cfg: &RunConfig, len: usize) -> (Arc<RepartitionController>, HealthController) {
        let plan = PartitionPlan::build(len, cfg).unwrap();
        let groups = plan
            .partitions
            .iter()
            .map(|p| match p.algo {
                SyncAlgo::Ma | SyncAlgo::Bmuf => {
                    Some(crate::sync::build_group(cfg, p.index, p.range.len))
                }
                _ => None,
            })
            .collect();
        let ctrl = Arc::new(RepartitionController::new(cfg, len, None, plan, groups));
        let health = HealthController::new(cfg, ctrl.clone());
        (ctrl, health)
    }

    #[test]
    fn stale_heartbeat_departs_once_and_vacates_groups() {
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 2,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            algo: SyncAlgo::Ma,
            num_sync_ps: 0,
            heartbeat_timeout_ms: 50,
            ..RunConfig::default()
        };
        let (ctrl, health) = fixture(&cfg, 64);
        let epoch0 = ctrl.current_epoch();
        health.observe_beat(0, 10);
        health.observe_beat(1, 10);
        assert_eq!(health.check_at(40), 0, "nobody is stale yet");
        assert_eq!(ctrl.active(), 2);
        // trainer 1 goes silent; trainer 0 keeps beating
        health.observe_beat(0, 100);
        assert_eq!(health.check_at(100), 1);
        assert!(health.is_departed(1));
        assert!(!health.is_departed(0));
        assert_eq!(ctrl.active(), 1);
        for g in epoch0.groups.iter().flatten() {
            assert_eq!(g.active(), 1, "the crash must proxy-leave every ring");
        }
        assert_eq!(health.departs(), 1);
        // re-scans are idempotent on an already-departed trainer
        health.observe_beat(0, 190);
        assert_eq!(health.check_at(200), 0);
        assert_eq!(ctrl.active(), 1);
        assert_eq!(health.departs(), 1);
        // ... and the rejoin path resets the clocks and lowers the flag
        let ep = ctrl.rejoin().expect("roster is idle");
        health.mark_rejoined(1, &ep);
        assert!(!health.is_departed(1));
        assert_eq!(ctrl.active(), 2);
    }

    #[test]
    fn exit_and_resume_claims_exclude_the_watchdog() {
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 1,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            algo: SyncAlgo::Ma,
            num_sync_ps: 0,
            heartbeat_timeout_ms: 40,
            ..RunConfig::default()
        };
        let (ctrl, health) = fixture(&cfg, 64);
        // trainer 1's pool claims its terminal exit: from here on no
        // watchdog depart (and no second claim) can double its goodbye
        assert!(health.claim_exit(1));
        assert!(!health.claim_exit(1));
        assert!(!health.depart_trainer(1));
        assert!(health.is_departed(1));
        assert_eq!(health.departs(), 0, "a claimed exit is not a crash");
        assert_eq!(ctrl.active(), 2, "the pool runs its own leave/depart");
        // trainer 0 goes silent past the timeout, but resumes (stamping a
        // fresh beat under the lock) before the watchdog's next scan: the
        // scan re-validates staleness under the same lock and aborts
        std::thread::sleep(Duration::from_millis(60));
        assert!(health.try_resume(0));
        assert_eq!(health.check_heartbeats(), 0);
        assert!(!health.is_departed(0));
        // without a resume, the same silence is departed — and a resume
        // attempted after losing the race reports the depart
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(health.check_heartbeats(), 1);
        assert!(!health.try_resume(0));
        assert_eq!(health.departs(), 1);
        assert_eq!(ctrl.active(), 1);
    }

    #[test]
    fn straggler_demotes_to_easgd_and_recovery_promotes_back() {
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 2,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            algo: SyncAlgo::Bmuf,
            num_sync_ps: 0,
            health_adaptive: true,
            health_stall_factor: 4.0,
            ..RunConfig::default()
        };
        let (ctrl, health) = fixture(&cfg, 64);
        // prime both trainers at a 1ms cadence: healthy, nothing happens
        for t in 1..=20u64 {
            health.observe_beat(0, t);
            health.observe_beat(1, t);
        }
        health.eval_at(21);
        assert!(!health.demoted());
        assert_eq!(ctrl.current_epoch().gen, 0);
        // trainer 1 stalls to a 40ms cadence while trainer 0 keeps 1ms
        let mut now = 21;
        for _ in 0..10 {
            for _ in 0..40 {
                now += 1;
                health.observe_beat(0, now);
            }
            health.observe_beat(1, now);
        }
        health.eval_at(now + 1);
        assert!(health.demoted());
        assert_eq!(health.demotions(), 1);
        let demoted = ctrl.current_epoch();
        assert_eq!(demoted.gen, 1, "demotion must force a cutover");
        assert!(demoted.plan.partitions.iter().all(|p| p.algo == SyncAlgo::Easgd));
        // a second straggling tick does not re-demote
        health.eval_at(now + 2);
        assert_eq!(health.demotions(), 1);
        // both pools adopt the demoted epoch
        ctrl.adopt(0);
        ctrl.adopt(0);
        // trainer 1 recovers; the EWMA has to decay below the threshold and
        // the roster must stay healthy for PROMOTE_AFTER consecutive ticks
        for round in 0..PROMOTE_AFTER {
            for _ in 0..40 {
                now += 1;
                health.observe_beat(0, now);
                health.observe_beat(1, now);
            }
            health.eval_at(now);
            assert_eq!(
                health.promotions(),
                u64::from(round + 1 >= PROMOTE_AFTER),
                "promotion requires {PROMOTE_AFTER} healthy ticks"
            );
        }
        assert!(!health.demoted());
        let promoted = ctrl.current_epoch();
        assert_eq!(promoted.gen, 2, "promotion must force a second cutover");
        assert!(promoted.plan.partitions.iter().all(|p| p.algo == SyncAlgo::Bmuf));
        assert!(ctrl.algo_override().is_none());
        // ranges survived the round trip (what makes the BMUF carry fit)
        let r0: Vec<_> = demoted.plan.partitions.iter().map(|p| p.range).collect();
        let r1: Vec<_> = promoted.plan.partitions.iter().map(|p| p.range).collect();
        assert_eq!(r0, r1);
    }

    #[test]
    fn promotion_retries_while_the_adoption_gate_is_closed() {
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 1,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            algo: SyncAlgo::Ma,
            num_sync_ps: 0,
            health_adaptive: true,
            health_stall_factor: 4.0,
            ..RunConfig::default()
        };
        let (ctrl, health) = fixture(&cfg, 64);
        for t in 1..=20u64 {
            health.observe_beat(0, t);
            health.observe_beat(1, t);
        }
        // stall trainer 1 hard, then demote
        health.observe_beat(0, 100);
        health.eval_at(100);
        assert_eq!(health.demotions(), 1);
        assert_eq!(ctrl.current_epoch().gen, 1);
        // only ONE pool adopts: the gate stays closed. Recovery ticks want
        // to promote, but the forced cutover must wait...
        ctrl.adopt(0);
        let mut now = 100;
        for _ in 0..=PROMOTE_AFTER {
            for _ in 0..20 {
                now += 1;
                health.observe_beat(0, now);
                health.observe_beat(1, now);
            }
            health.eval_at(now);
        }
        assert_eq!(health.promotions(), 1, "the flip itself is recorded");
        assert_eq!(ctrl.current_epoch().gen, 1, "cutover is gated on adoption");
        assert!(ctrl.algo_override().is_none(), "the override is already cleared");
        // ...until the second pool catches up, when a later tick lands it
        ctrl.adopt(0);
        health.eval_at(now + 1);
        assert_eq!(ctrl.current_epoch().gen, 2);
        assert!(ctrl.current_epoch().plan.partitions.iter().all(|p| p.algo == SyncAlgo::Ma));
    }

    #[test]
    fn finished_trainers_are_never_departed_or_called_stragglers() {
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 1,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            algo: SyncAlgo::Ma,
            num_sync_ps: 0,
            heartbeat_timeout_ms: 50,
            health_adaptive: true,
            health_stall_factor: 4.0,
            ..RunConfig::default()
        };
        let (ctrl, health) = fixture(&cfg, 64);
        for t in 1..=20u64 {
            health.observe_beat(0, t);
            health.observe_beat(1, t);
        }
        // trainer 1 drains its shard and goes legitimately silent
        health.mark_done(1);
        health.observe_beat(0, 500);
        assert_eq!(health.check_at(500), 0, "a finished trainer is not a crash");
        assert!(!health.is_departed(1));
        assert_eq!(ctrl.active(), 2, "its pool still owns its memberships");
        health.eval_at(500);
        assert!(!health.demoted(), "a finished trainer is not a straggler");
    }

    #[test]
    fn adaptation_disarms_without_rendezvous_partitions() {
        // an all-EASGD map has nothing to demote: adaptive must disarm
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 1,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            algo: SyncAlgo::Easgd,
            health_adaptive: true,
            health_stall_factor: 4.0,
            ..RunConfig::default()
        };
        let (ctrl, health) = fixture(&cfg, 64);
        for t in 1..=20u64 {
            health.observe_beat(0, t);
        }
        health.eval_at(1_000); // trainer 1 looks infinitely slow
        assert_eq!(health.demotions(), 0);
        assert_eq!(ctrl.current_epoch().gen, 0);
    }
}
