//! Measured-cost adaptive repartitioning for the shadow-sync fabric.
//!
//! PR 4's [`PartitionPlan`] packs *uniform*-cost blocks, so a hot
//! (frequently written) range and a cold tail get equal shadow attention.
//! This module closes the ROADMAP follow-on: a [`RepartitionController`]
//! shared by every trainer accumulates measured per-block write rates
//! (dirty-epoch bump counts exported by
//! [`crate::tensor::HogwildBuffer::dirty_chunk_epochs`]) and, every
//! `--repartition-every N` shadow sweeps (aggregated across trainers),
//! rebuilds the plan with the weighted contiguous cut
//! ([`crate::sync::partition::lpt_contiguous_ranges_weighted`]) — hot
//! partitions shrink, cold ones grow, so every partition's background
//! round costs about the same and the worst per-partition Eq.-2 gap drops.
//!
//! ## Epochs and the cross-trainer cutover protocol
//!
//! Plans are published as [`PlanEpoch`]s, one generation at a time, with a
//! hard invariant: **a new epoch is built only after every active trainer
//! adopted the current one** (`adopted == active`). A trainer is therefore
//! never more than one epoch behind, and the cutover needs no global
//! barrier:
//!
//! 1. a trainer's shadow pool notices `generation()` moved at a sweep
//!    boundary and quiesces (its pool threads finish their in-flight
//!    rounds and exit);
//! 2. it retires the old strategies — rendezvous (MA/BMUF) strategies
//!    `leave()` their old per-partition [`AllReduceGroup`]s, which is
//!    exactly the shutdown path, so peers still on the old epoch keep
//!    closing rounds with fewer contributors and can never deadlock on a
//!    departed trainer;
//! 3. it [`RepartitionController::adopt`]s the new epoch and rebuilds its
//!    [`ShadowTask`]s against the new ranges, carrying each EASGD
//!    partition's [`crate::sync::RepartitionCarry`] (delta-gate sketch +
//!    scan cache) across — cache entries stay keyed by *global* push-chunk
//!    ordinal, so an entry is still valid for any chunk whose dirty
//!    signature and central version survived the move, wherever the chunk
//!    now lives.
//!
//! New-epoch [`AllReduceGroup`]s are pre-sized to the trainers active at
//! build time; a trainer that stops before ever adopting a pending epoch
//! vacates its membership slots via [`RepartitionController::depart`], so
//! peers that did adopt are never left waiting on a ghost.

use anyhow::Result;

use crate::config::{AlgoMap, RunConfig, SyncAlgo};

use super::driver::ShadowTask;
use super::partition::{lpt_contiguous_ranges_weighted, ParamRange, PartitionPlan};
use super::prim::{
    Arc, AtomicU64, Mutex,
    Ordering::{AcqRel, Acquire, Relaxed, Release},
};
use super::ps::SyncPsGroup;
use super::{AllReduceGroup, RepartitionCarry};

/// The embedding tier's rebalancing handle: everything the controller
/// needs to drag hot embedding buckets along with a dense replan. Attached
/// after cluster build (the tier and the controller are constructed
/// independently), consulted at every published epoch.
pub struct EmbHook {
    pub sys: Arc<crate::embedding::EmbeddingSystem>,
    pub net: Arc<crate::net::Network>,
    pub metrics: Arc<crate::metrics::Metrics>,
}

/// One published generation of the fabric's layout: the plan plus the
/// per-partition ring fabrics (None for centralized/none partitions),
/// shared by every trainer that adopts the generation.
pub struct PlanEpoch {
    pub gen: u64,
    pub plan: PartitionPlan,
    pub groups: Vec<Option<Arc<AllReduceGroup>>>,
}

struct CtrlState {
    /// trainers that haven't departed (shard exhausted / shutdown)
    active: usize,
    /// active trainers running the current epoch
    adopted: usize,
    /// shadow sweeps recorded since the last rebuild, summed over trainers
    sweeps: u64,
    epoch: Arc<PlanEpoch>,
}

/// The shared repartitioning brain: write-rate accumulator + epoch store.
/// One instance per run, shared by every trainer's shadow pool.
pub struct RepartitionController {
    cfg: RunConfig,
    num_params: usize,
    /// block granule of the write-rate accumulator (the EASGD push-chunk /
    /// dirty-epoch granule, so replica epoch counters map 1:1 onto blocks)
    granule: usize,
    /// sweeps per trainer between rebuilds (0 = never repartition)
    every: u64,
    sync_ps: Option<Arc<SyncPsGroup>>,
    /// accumulated per-block write counts (dirty-epoch bumps); halved on
    /// every rebuild so the profile tracks a drifting workload
    writes: Vec<AtomicU64>,
    /// lock-free mirror of the current epoch's generation, checked by pool
    /// threads once per lap
    gen: AtomicU64,
    /// highest generation any trainer actually adopted — the "repartitions
    /// performed" count (a published-but-never-adopted epoch doesn't count)
    adopted_gen: AtomicU64,
    /// live replacement for `cfg.algo_map`, published by the health
    /// controller (straggler demotions); `None` = run the configured map
    algo_override: Mutex<Option<AlgoMap>>,
    /// embedding tier to rebalance alongside dense replans (attached after
    /// build; separate lock from `state` — the hook never locks back)
    emb: Mutex<Option<EmbHook>>,
    /// cumulative hot-bucket migrations driven through the hook (stat)
    emb_moves: AtomicU64,
    state: Mutex<CtrlState>,
}

impl RepartitionController {
    /// Wrap the run's initial layout (generation 0). `plan` and `groups`
    /// must be the ones the trainers' generation-0 strategies were built
    /// from, so epoch bookkeeping starts consistent.
    pub fn new(
        cfg: &RunConfig,
        num_params: usize,
        sync_ps: Option<Arc<SyncPsGroup>>,
        plan: PartitionPlan,
        groups: Vec<Option<Arc<AllReduceGroup>>>,
    ) -> Self {
        let granule = cfg.easgd_chunk_elems.max(1);
        let blocks = num_params.div_ceil(granule).max(1);
        let mut writes = Vec::with_capacity(blocks);
        writes.resize_with(blocks, || AtomicU64::new(0));
        Self {
            cfg: cfg.clone(),
            num_params,
            granule,
            every: cfg.repartition_every,
            sync_ps,
            writes,
            gen: AtomicU64::new(0),
            adopted_gen: AtomicU64::new(0),
            algo_override: Mutex::new(None),
            emb: Mutex::new(None),
            emb_moves: AtomicU64::new(0),
            state: Mutex::new(CtrlState {
                active: cfg.num_trainers,
                adopted: cfg.num_trainers,
                sweeps: 0,
                epoch: Arc::new(PlanEpoch { gen: 0, plan, groups }),
            }),
        }
    }

    /// Attach the embedding tier: from now on, every published epoch —
    /// periodic, forced, or rejoin — also rebalances hot embedding buckets
    /// by their measured lookup rates, so the embedding tier follows the
    /// same "profile, then repack" cadence as the dense ranges.
    pub fn attach_embeddings(
        &self,
        sys: Arc<crate::embedding::EmbeddingSystem>,
        net: Arc<crate::net::Network>,
        metrics: Arc<crate::metrics::Metrics>,
    ) {
        *self.emb.lock().unwrap() = Some(EmbHook { sys, net, metrics });
    }

    /// Hot-bucket migrations driven through the attached embedding tier.
    pub fn embedding_migrations(&self) -> u64 {
        self.emb_moves.load(Relaxed)
    }

    /// Rebalance the attached embedding tier (no-op when none is attached).
    /// Called with `state` held; the hook takes no controller locks back.
    fn rebalance_embeddings(&self) {
        if let Some(h) = &*self.emb.lock().unwrap() {
            let moved = h.sys.rebalance(&h.net, &h.metrics);
            self.emb_moves.fetch_add(moved as u64, Relaxed);
        }
    }

    /// Generation of the current epoch — pool threads compare this against
    /// the generation they adopted, once per lap, to detect a pending
    /// cutover without taking the state lock (Acquire: pairs with the
    /// Release publish in [`Self::record_sweep`]).
    pub fn generation(&self) -> u64 {
        self.gen.load(Acquire)
    }

    /// Record one shadow sweep: `write_delta` is the per-block dirty-epoch
    /// bump count observed since the trainer's previous sweep (empty when
    /// the replica doesn't track dirty epochs — the sweep still counts, and
    /// rebuilds fall back toward uniform costs). Triggers a rebuild once
    /// `every × active` sweeps accumulated *and* every active trainer runs
    /// the current epoch — so at most one epoch is ever pending.
    pub fn record_sweep(&self, write_delta: &[u64]) {
        for (w, d) in self.writes.iter().zip(write_delta) {
            if *d > 0 {
                w.fetch_add(*d, Relaxed);
            }
        }
        if self.every == 0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        st.sweeps += 1;
        if st.active > 0 && st.adopted == st.active && st.sweeps >= self.every * st.active as u64 {
            let epoch = self.rebuild(st.epoch.gen + 1, st.active);
            st.epoch = Arc::new(epoch);
            st.adopted = 0;
            st.sweeps = 0;
            self.rebalance_embeddings();
            // Release: a pool thread that observes the new generation (even
            // without the lock) must also observe the epoch it names
            self.gen.store(st.epoch.gen, Release);
        }
    }

    /// Adopt the epoch after `prev_gen` (a trainer is never more than one
    /// behind, enforced by the rebuild gate). Returns the epoch to rebuild
    /// tasks against.
    pub fn adopt(&self, prev_gen: u64) -> Arc<PlanEpoch> {
        let mut st = self.state.lock().unwrap();
        debug_assert_eq!(st.epoch.gen, prev_gen + 1, "a trainer can only be one epoch behind");
        st.adopted += 1;
        self.adopted_gen.fetch_max(st.epoch.gen, AcqRel);
        st.epoch.clone()
    }

    /// Repartitions actually *performed*: the highest generation some
    /// trainer adopted. A plan published right at the end of a run that no
    /// pool ever cut over to does not count.
    pub fn repartitions(&self) -> u64 {
        self.adopted_gen.load(Acquire)
    }

    /// A trainer stops syncing for good (shard exhausted, shutdown, or a
    /// strategy error) while running `adopted_gen`. If an epoch this
    /// trainer never adopted is pending, its membership slots in that
    /// epoch's collective groups are vacated here, so adopters never block
    /// on a trainer that will not arrive.
    pub fn depart(&self, adopted_gen: u64) {
        let mut st = self.state.lock().unwrap();
        st.active = st.active.saturating_sub(1);
        if st.epoch.gen > adopted_gen {
            for g in st.epoch.groups.iter().flatten() {
                g.leave();
            }
        } else {
            st.adopted = st.adopted.saturating_sub(1);
        }
    }

    /// Build one trainer's shadow tasks for `epoch`: fresh strategies over
    /// the new ranges (`seed_w` seeds BMUF's private `w^global` with the
    /// replica's current values — the pre-cutover state, not the long-gone
    /// `w0`), with each EASGD partition's carried gate state re-installed.
    /// `carry` is indexed by partition; entries are consumed.
    pub fn build_tasks(
        &self,
        trainer_id: usize,
        epoch: &PlanEpoch,
        seed_w: &[f32],
        mut carry: Vec<Option<RepartitionCarry>>,
    ) -> Result<Vec<ShadowTask>> {
        carry.resize_with(epoch.plan.len(), || None);
        epoch
            .plan
            .partitions
            .iter()
            .filter(|p| p.algo != SyncAlgo::None)
            .map(|p| {
                let mut strategy = super::build_strategy(
                    &self.cfg,
                    p,
                    trainer_id,
                    seed_w,
                    self.sync_ps.clone(),
                    epoch.groups[p.index].clone(),
                )?;
                if let Some(c) = carry[p.index].take() {
                    strategy.install_repartition_carry(c);
                }
                Ok(ShadowTask { partition: p.index, range: p.range, strategy })
            })
            .collect()
    }

    /// The current epoch (test / report observability).
    pub fn current_epoch(&self) -> Arc<PlanEpoch> {
        self.state.lock().unwrap().epoch.clone()
    }

    /// Trainers that haven't departed (test / health observability).
    pub fn active(&self) -> usize {
        self.state.lock().unwrap().active
    }

    /// The sync-PS tier strategies are built against, if the run has one —
    /// the warm-start source for a rejoining trainer's replica.
    pub fn sync_ps(&self) -> Option<&Arc<SyncPsGroup>> {
        self.sync_ps.as_ref()
    }

    /// Publish (or clear, with `None`) a live algo-map override. The next
    /// rebuild — periodic or [`Self::force_rebuild`] — resolves partition
    /// algorithms through this map instead of the configured one: the
    /// health controller's demote/promote lever.
    pub fn set_algo_override(&self, map: Option<AlgoMap>) {
        *self.algo_override.lock().unwrap() = map;
    }

    /// The override currently published, if any.
    pub fn algo_override(&self) -> Option<AlgoMap> {
        self.algo_override.lock().unwrap().clone()
    }

    /// Publish a new epoch *now*, keeping the current ranges but re-resolving
    /// each partition's algorithm (through the live override) and re-sizing
    /// the collective groups — the health controller's cutover trigger.
    /// Subject to the same gate as periodic rebuilds: refused (returns
    /// `false`) while an epoch is still pending adoption, so at most one
    /// generation is ever in flight. Keeping the ranges fixed is what lets a
    /// demote→promote cycle rehydrate BMUF momentum exactly (the carried
    /// state is range-shaped).
    pub fn force_rebuild(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.active == 0 || st.adopted != st.active {
            return false;
        }
        let ranges: Vec<ParamRange> =
            st.epoch.plan.partitions.iter().map(|p| p.range).collect();
        let epoch = self.rebuild_over(st.epoch.gen + 1, st.active, ranges);
        st.epoch = Arc::new(epoch);
        st.adopted = 0;
        st.sweeps = 0;
        self.rebalance_embeddings();
        self.gen.store(st.epoch.gen, Release);
        true
    }

    /// A departed trainer comes back (its crash window closed): grow the
    /// membership back by one and publish a fresh epoch over the current
    /// ranges, pre-sized to the enlarged roster. The rejoiner counts as
    /// having adopted the new epoch at birth (it builds its tasks straight
    /// from the returned [`PlanEpoch`], never calling [`Self::adopt`] —
    /// which would trip the one-behind invariant for a trainer that sat out
    /// several generations); every surviving trainer cuts over through the
    /// normal adopt path. Returns `None` while an epoch is still pending
    /// adoption — the caller retries after the survivors catch up.
    pub fn rejoin(&self) -> Option<Arc<PlanEpoch>> {
        let mut st = self.state.lock().unwrap();
        if st.adopted != st.active {
            return None;
        }
        st.active += 1;
        let ranges: Vec<ParamRange> =
            st.epoch.plan.partitions.iter().map(|p| p.range).collect();
        let epoch = self.rebuild_over(st.epoch.gen + 1, st.active, ranges);
        st.epoch = Arc::new(epoch);
        st.adopted = 1; // the rejoiner itself
        st.sweeps = 0;
        self.rebalance_embeddings();
        self.adopted_gen.fetch_max(st.epoch.gen, AcqRel);
        self.gen.store(st.epoch.gen, Release);
        Some(st.epoch.clone())
    }

    /// Accumulated per-block write counts (test / report observability).
    pub fn write_profile(&self) -> Vec<u64> {
        self.writes.iter().map(|w| w.load(Relaxed)).collect()
    }

    /// Cut a new plan over the measured write profile and size fresh
    /// collective groups for its decentralized partitions.
    fn rebuild(&self, gen: u64, active: usize) -> PlanEpoch {
        let writes: Vec<u64> = self.writes.iter().map(|w| w.load(Relaxed)).collect();
        let granule = self.granule;
        let num_params = self.num_params;
        // block cost = one uniform unit per element (the floor that keeps
        // never-written tails packable) + the accumulated write mass of the
        // overlapping accumulator blocks, prorated by overlap
        let cost = |lo: usize, hi: usize| -> f64 {
            let mut c = (hi - lo) as f64;
            let b1 = (hi - 1) / granule;
            for (b, w) in writes.iter().enumerate().take(b1 + 1).skip(lo / granule) {
                let blo = b * granule;
                let bhi = (blo + granule).min(num_params);
                let overlap = hi.min(bhi).saturating_sub(lo.max(blo));
                c += *w as f64 * overlap as f64 / (bhi - blo) as f64;
            }
            c
        };
        let p = self.cfg.sync_partitions.max(1);
        let ranges = lpt_contiguous_ranges_weighted(num_params, p, granule, cost);
        // decay: rebuilds see a half-life-weighted profile, so the plan
        // follows a drifting workload instead of its all-time average
        for w in &self.writes {
            let v = w.load(Relaxed);
            w.store(v / 2, Relaxed);
        }
        self.rebuild_over(gen, active, ranges)
    }

    /// Assemble a [`PlanEpoch`] over the given ranges: partition algorithms
    /// resolved through the live override (when one is published), one
    /// collective group per decentralized partition sized to `active`.
    fn rebuild_over(&self, gen: u64, active: usize, ranges: Vec<ParamRange>) -> PlanEpoch {
        let cfg = match &*self.algo_override.lock().unwrap() {
            Some(map) => {
                let mut c = self.cfg.clone();
                c.algo_map = Some(map.clone());
                c
            }
            None => self.cfg.clone(),
        };
        let plan = PartitionPlan::from_ranges(ranges, &cfg);
        let groups = plan
            .partitions
            .iter()
            .map(|part| match part.algo {
                SyncAlgo::Ma | SyncAlgo::Bmuf => {
                    Some(super::build_group_sized(&cfg, part.index, active, part.range.len))
                }
                _ => None,
            })
            .collect();
        PlanEpoch { gen, plan, groups }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctrl(cfg: &RunConfig, len: usize) -> RepartitionController {
        let plan = PartitionPlan::build(len, cfg).unwrap();
        let groups = plan
            .partitions
            .iter()
            .map(|p| match p.algo {
                SyncAlgo::Ma | SyncAlgo::Bmuf => {
                    Some(super::super::build_group(cfg, p.index, p.range.len))
                }
                _ => None,
            })
            .collect();
        RepartitionController::new(cfg, len, None, plan, groups)
    }

    #[test]
    fn skewed_writes_shrink_hot_partitions() {
        let cfg = RunConfig {
            num_trainers: 1,
            sync_partitions: 4,
            shadow_threads: 2,
            easgd_chunk_elems: 64,
            repartition_every: 1,
            algo: SyncAlgo::None, // plan-shape test: no strategies built
            ..RunConfig::default()
        };
        let len = 4096usize;
        let c = ctrl(&cfg, len);
        assert_eq!(c.generation(), 0);
        // the first quarter of the blocks absorbs ~all writes
        let blocks = len / 64;
        let delta: Vec<u64> =
            (0..blocks).map(|b| if b < blocks / 4 { 1_000 } else { 0 }).collect();
        c.record_sweep(&delta); // every=1, active=1: rebuilds immediately
        assert_eq!(c.generation(), 1);
        let epoch = c.current_epoch();
        assert_eq!(epoch.gen, 1);
        let plan = &epoch.plan;
        assert_eq!(plan.len(), 4);
        assert_eq!(plan.partitions[0].range.lo(), 0);
        assert_eq!(plan.partitions[3].range.hi(), len);
        let uniform = len / 4;
        assert!(
            plan.partitions[0].range.len < uniform,
            "hot partition did not shrink: {:?}",
            plan.partitions.iter().map(|p| p.range).collect::<Vec<_>>()
        );
        assert!(
            plan.partitions[3].range.len > uniform,
            "cold partition did not grow: {:?}",
            plan.partitions.iter().map(|p| p.range).collect::<Vec<_>>()
        );
        // profile decays across rebuilds (half-life weighting)
        assert!(c.write_profile()[0] <= 500);
    }

    #[test]
    fn rebuild_waits_for_every_trainer_to_adopt() {
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 2,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            repartition_every: 1,
            algo: SyncAlgo::None,
            ..RunConfig::default()
        };
        let c = ctrl(&cfg, 64);
        // 2 sweeps (= every * active) trigger the first rebuild
        c.record_sweep(&[]);
        assert_eq!(c.generation(), 0);
        c.record_sweep(&[]);
        assert_eq!(c.generation(), 1);
        // more sweeps do NOT rebuild again until both trainers adopt
        for _ in 0..10 {
            c.record_sweep(&[]);
        }
        assert_eq!(c.generation(), 1, "rebuild must wait for adoption");
        // published but not yet adopted: not a performed repartition
        assert_eq!(c.repartitions(), 0);
        let e = c.adopt(0);
        assert_eq!(e.gen, 1);
        assert_eq!(c.repartitions(), 1, "first adoption makes the replan real");
        c.record_sweep(&[]);
        assert_eq!(c.generation(), 1, "one of two trainers is still behind");
        c.adopt(0);
        c.record_sweep(&[]);
        c.record_sweep(&[]);
        assert_eq!(c.generation(), 2, "all adopted: the next rebuild may land");
        assert_eq!(c.repartitions(), 1, "generation 2 is pending, not performed");
    }

    #[test]
    fn depart_before_adopt_vacates_pending_group_slots() {
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 2,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            repartition_every: 1,
            algo: SyncAlgo::Ma,
            num_sync_ps: 0,
            ..RunConfig::default()
        };
        let c = ctrl(&cfg, 64);
        c.record_sweep(&[]);
        c.record_sweep(&[]);
        assert_eq!(c.generation(), 1);
        let pending = c.current_epoch();
        for g in pending.groups.iter().flatten() {
            assert_eq!(g.active(), 2, "new groups pre-size to active trainers");
        }
        // trainer A adopts; trainer B departs while still on generation 0:
        // B's slots in the pending groups must be vacated so A never blocks
        c.adopt(0);
        c.depart(0);
        for g in pending.groups.iter().flatten() {
            assert_eq!(g.active(), 1, "departed trainer must vacate pending slots");
        }
        // with one active (and adopted) trainer left, rebuilds size for 1
        c.record_sweep(&[]);
        let next = c.current_epoch();
        assert_eq!(next.gen, 2);
        for g in next.groups.iter().flatten() {
            assert_eq!(g.active(), 1);
        }
    }

    #[test]
    fn algo_override_demotes_then_promotes_over_fixed_ranges() {
        let cfg = RunConfig {
            num_trainers: 1,
            sync_partitions: 2,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            algo: SyncAlgo::Ma,
            num_sync_ps: 0,
            ..RunConfig::default()
        };
        let c = ctrl(&cfg, 64);
        let base_ranges: Vec<_> =
            c.current_epoch().plan.partitions.iter().map(|p| p.range).collect();
        // demote: every partition to EASGD, published as a forced epoch
        c.set_algo_override(Some(
            AlgoMap::from_entries(vec![(SyncAlgo::Easgd, 0, 1)]).unwrap(),
        ));
        assert!(c.force_rebuild(), "idle controller must accept a forced rebuild");
        let demoted = c.current_epoch();
        assert_eq!(demoted.gen, 1);
        assert!(demoted.plan.partitions.iter().all(|p| p.algo == SyncAlgo::Easgd));
        assert!(demoted.groups.iter().all(|g| g.is_none()), "EASGD needs no rings");
        // one pending generation max: a second force must refuse until adopted
        assert!(!c.force_rebuild(), "forced rebuild must respect the adoption gate");
        c.adopt(0);
        // promote: clearing the override restores the configured map
        c.set_algo_override(None);
        assert!(c.force_rebuild());
        let promoted = c.current_epoch();
        assert_eq!(promoted.gen, 2);
        assert!(promoted.plan.partitions.iter().all(|p| p.algo == SyncAlgo::Ma));
        // both cutovers kept the ranges — what makes carried state re-installable
        for (ep, r0) in [(&demoted, &base_ranges), (&promoted, &base_ranges)] {
            let got: Vec<_> = ep.plan.partitions.iter().map(|p| p.range).collect();
            assert_eq!(&got, r0, "forced rebuilds must preserve ranges");
        }
    }

    #[test]
    fn published_epochs_rebalance_the_attached_embedding_tier() {
        let cfg = RunConfig {
            num_trainers: 1,
            sync_partitions: 2,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            repartition_every: 1,
            algo: SyncAlgo::None,
            ..RunConfig::default()
        };
        let c = ctrl(&cfg, 64);
        let meta = crate::config::ModelMeta::parse(
            r#"{
          "batch": 4, "bot_mlp": [16, 8], "emb_dim": 8,
          "name": "t", "num_dense": 4, "num_feats": 5, "num_interactions": 10,
          "num_params": 537, "num_tables": 4, "seed": 1, "top_mlp": [16]
        }"#,
        )
        .unwrap();
        let mut net = crate::net::Network::new(None);
        let emb_cfg =
            crate::config::EmbeddingConfig { rows_per_table: 48, ..Default::default() };
        let sys = Arc::new(
            crate::embedding::EmbeddingSystem::build(&meta, &emb_cfg, 2, &mut net, 7).unwrap(),
        );
        let net = Arc::new(net);
        let metrics = Arc::new(crate::metrics::Metrics::new());
        // load all hot-key mass onto whichever PS hosts >= 2 buckets, so the
        // LPT repack provably has to move at least one bucket off it
        let on_ps0 = sys.shards().filter(|s| s.ps_node() == sys.ps_nodes[0]).count();
        let heavy = if on_ps0 >= 2 { sys.ps_nodes[0] } else { sys.ps_nodes[1] };
        for s in sys.shards() {
            if s.ps_node() == heavy {
                s.note_hits(1_000);
            }
        }
        c.attach_embeddings(sys.clone(), net.clone(), metrics.clone());
        assert_eq!(c.embedding_migrations(), 0);
        c.record_sweep(&[]); // every=1, active=1: publishes gen 1 + rebalances
        assert_eq!(c.generation(), 1);
        assert!(c.embedding_migrations() >= 1, "hot buckets must migrate with the replan");
        assert!(sys.placement_version() >= 1, "migrations must bump the placement version");
        // the migrations kept the embedding byte ledger exact (PS<->PS legs
        // are counted once per NIC on both ledgers)
        assert_eq!(
            metrics.snapshot().embedding_bytes,
            net.role_bytes(crate::net::Role::EmbeddingPs)
        );
    }

    #[test]
    fn rejoin_grows_membership_and_preadopts_the_rejoiner() {
        let cfg = RunConfig {
            num_trainers: 2,
            sync_partitions: 2,
            shadow_threads: 1,
            easgd_chunk_elems: 8,
            algo: SyncAlgo::Ma,
            num_sync_ps: 0,
            ..RunConfig::default()
        };
        let c = ctrl(&cfg, 64);
        c.depart(0); // the watchdog takes a crashed trainer out
        assert_eq!(c.active(), 1);
        let ep = c.rejoin().expect("idle controller must accept a rejoin");
        assert_eq!(ep.gen, 1);
        assert_eq!(c.active(), 2);
        for g in ep.groups.iter().flatten() {
            assert_eq!(g.active(), 2, "rejoin epoch must be sized to the new roster");
        }
        // the rejoiner adopted at birth; the survivor adopts normally, after
        // which the next generation may land
        assert_eq!(c.repartitions(), 1);
        c.adopt(0);
        assert!(c.force_rebuild());
        // ... and a rejoin attempted while that epoch is pending must wait
        assert!(c.rejoin().is_none(), "rejoin must respect the adoption gate");
    }
}
